"""OT-GAN with adversarially-learned positive-feature kernels (paper §4).

    PYTHONPATH=src python examples/ot_gan.py [--steps 300] [--pixels]

Reproduces the paper's Eq. (18) objective at container scale:

    min_rho  max_{gamma, theta}  (1/B) sum_b  Wbar_{eps, c_theta o h_gamma}

* g_rho   — generator MLP z -> x
* f_gamma — adversarial embedding x -> R^d_latent  (the "cost" tower)
* phi_theta — Lemma-1 Gaussian positive features with LEARNED anchors

The whole loss is ONE ``OTObjective``: the embedded clouds and learnable
anchors become a ``GaussianPointCloud`` geometry, the divergence runs
through the shared execution stack (fused megakernel + bf16 under the
training :class:`ExecutionPolicy`), and gradients flow through the
envelope-theorem VJP — both of the paper's claimed advantages (linear
batch cost; no unrolled loop in the backward graph).

Default target: 8-mode Gaussian ring in R^2 (mode coverage printed).
--pixels switches to a 12x12 synthetic "two-moons pixels" image domain to
exercise the DCGAN-shaped pipeline (conv stubs replaced by MLPs on CPU).

--eval-kernel prints the Table-1 analogue: learned kernel values between
data/data, data/noise, noise/noise pairs.

--strict is the CI train-smoke contract: assert the fused bf16 plan was
selected (plan observability), all losses finite, zero post-warmup
retraces, and a decreasing divergence trend.
"""
import argparse
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.objective import ExecutionPolicy, OTObjective
from repro.core.features import GaussianFeatureMap, gaussian_log_features
from repro.kernels.ops import observe_plan_selection
from repro.models.layers import init_linear, linear

LATENT_Z = 16
LATENT_D = 8         # f_gamma output dim (the paper embeds into R^d)
EPS = 0.5
R_BALL = 3.0


def init_mlp_stack(key, dims, std=None):
    ks = jax.random.split(key, len(dims) - 1)
    return [init_linear(k, a, b, bias=True,
                        std=(std or (2.0 / a) ** 0.5))
            for k, a, b in zip(ks, dims[:-1], dims[1:])]


def mlp_apply(stack, x, final_tanh=False):
    for i, p in enumerate(stack):
        x = linear(p, x)
        if i < len(stack) - 1:
            x = jax.nn.gelu(x)
    return jnp.tanh(x) if final_tanh else x


def make_data(key, n, pixels=False):
    if pixels:
        # two-moons rendered to 12x12 binary-ish images
        k1, k2 = jax.random.split(key)
        t = jnp.pi * jax.random.uniform(k1, (n,))
        moon = jax.random.bernoulli(k2, 0.5, (n,))
        cx = jnp.where(moon, 0.5 + 0.4 * jnp.cos(t), 0.5 - 0.4 * jnp.cos(t))
        cy = jnp.where(moon, 0.35 + 0.3 * jnp.sin(t), 0.65 - 0.3 * jnp.sin(t))
        gx, gy = jnp.meshgrid(jnp.linspace(0, 1, 12), jnp.linspace(0, 1, 12))
        img = jnp.exp(-(((gx[None] - cx[:, None, None]) ** 2
                         + (gy[None] - cy[:, None, None]) ** 2) / 0.01))
        return img.reshape(n, 144)
    # ring of 8 gaussians
    k1, k2 = jax.random.split(key)
    mode = jax.random.randint(k1, (n,), 0, 8)
    ang = 2 * jnp.pi * mode / 8
    centers = jnp.stack([jnp.cos(ang), jnp.sin(ang)], -1) * 2.0
    return centers + 0.05 * jax.random.normal(k2, (n, 2))


def embed(f, pts):
    """h_gamma: the adversarial tower into B(0, R_BALL)."""
    return mlp_apply(f, pts, final_tanh=True) * R_BALL


def gan_losses(params, key, data, obj: OTObjective):
    """Eq. 18 inner term as ONE objective call: geometry from the embedded
    clouds + learnable anchors, divergence under the shared policy."""
    g, f, anchors = params["gen"], params["emb"], params["anchors"]
    B = data.shape[0]
    z = jax.random.normal(key, (B, LATENT_Z))
    fake = mlp_apply(g, z)
    geom = obj.gaussian(embed(f, fake), embed(f, data), anchors, R=R_BALL)
    return obj.divergence(geom), fake


def mode_coverage(fake):
    ang = jnp.arctan2(fake[:, 1], fake[:, 0])
    mode = jnp.round(ang / (2 * jnp.pi / 8)).astype(jnp.int32) % 8
    radius_ok = jnp.abs(jnp.linalg.norm(fake[:, :2], axis=1) - 2.0) < 0.5
    covered = jnp.zeros((8,)).at[mode].max(radius_ok.astype(jnp.float32))
    return int(jnp.sum(covered))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--r", type=int, default=128)
    ap.add_argument("--iters", type=int, default=40,
                    help="Sinkhorn iterations per solve")
    ap.add_argument("--nc", type=int, default=3,
                    help="adversary steps per generator step (paper's n_c)")
    ap.add_argument("--pixels", action="store_true")
    ap.add_argument("--eval-kernel", action="store_true")
    ap.add_argument("--strict", action="store_true",
                    help="CI mode: force the fused bf16 plan and assert "
                    "plan selection, finite losses, zero post-warmup "
                    "retraces, decreasing divergence")
    args = ap.parse_args()

    x_dim = 144 if args.pixels else 2
    key = jax.random.PRNGKey(0)
    kg, ke, ka, kd = jax.random.split(key, 4)
    fm = GaussianFeatureMap(r=args.r, d=LATENT_D, eps=EPS, R=R_BALL)
    params = {
        "gen": init_mlp_stack(kg, [LATENT_Z, 128, 128, x_dim]),
        "emb": init_mlp_stack(ke, [x_dim, 64, LATENT_D]),
        "anchors": fm.init(ka),
    }

    # ONE objective per run: geometry construction, divergence, envelope
    # VJP and execution policy (bf16 factors; fused plan auto on compiled
    # backends, forced interpret-mode in --strict so CI verifies it)
    policy = ExecutionPolicy.training(
        use_pallas=True if args.strict else None)
    obj = OTObjective(eps=EPS, tol=0.0, max_iter=args.iters, policy=policy)
    print(f"[ot-gan] ot-policy {policy.describe()}")

    from functools import partial

    @partial(jax.jit, static_argnames=("adv",))
    def train_step(params, key, data, lr_g=3e-3, lr_adv=1e-3, adv=False):
        def loss_fn(p):
            d, fake = gan_losses(p, key, data, obj)
            return d, fake
        (d, fake), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        sign = {"gen": -1.0, "emb": +1.0, "anchors": +1.0}
        new = {}
        for name in params:
            lr = lr_g if name == "gen" else lr_adv
            s = sign[name] * lr
            upd = (lambda p_, g_: p_ + s * g_)
            if adv and name == "gen":
                new[name] = params[name]
            elif (not adv) and name != "gen":
                new[name] = params[name]
            else:
                new[name] = jax.tree.map(upd, params[name], grads[name])
        return new, d, fake

    if args.strict:
        # warm both trace variants under the observability hook: the GAN
        # loss must run through the fused plan at the policy's precision
        with observe_plan_selection() as events:
            kw, kb = jax.random.split(kd)
            data0 = make_data(kb, args.batch, pixels=args.pixels)
            train_step(params, kw, data0, adv=True)
            train_step(params, kw, data0, adv=False)
        sel = [e for e in events if e["geometry"] == "GaussianPointCloud"]
        assert sel, f"no fused plan selected for the GAN loss: {events}"
        assert all(e["precision"] == "bf16" for e in sel), sel
        print(f"[ot-gan] strict: fused plan active "
              f"({sel[0]['kind']}/{sel[0]['mode']}, precision=bf16, "
              f"{len(sel)} solves/trace)")
        traces0 = train_step._cache_size()

    t0 = time.time()
    divergences = []
    for step in range(args.steps):
        kd, ks, kb = jax.random.split(kd, 3)
        data = make_data(kb, args.batch, pixels=args.pixels)
        adv = bool((step % (args.nc + 1)) != args.nc)  # n_c adversary : 1 gen
        params, d, fake = train_step(params, ks, data, adv=adv)
        divergences.append(float(d))
        if step % 50 == 0 or step == args.steps - 1:
            msg = f"[ot-gan] step {step:4d} Wbar={float(d):+.4f}"
            if not args.pixels:
                msg += f" modes={mode_coverage(fake)}/8"
            print(msg + f" ({time.time() - t0:.1f}s)")

    if args.strict:
        assert all(math.isfinite(d) for d in divergences), "non-finite Wbar"
        retraces = train_step._cache_size() - traces0
        assert retraces == 0, f"{retraces} post-warmup retraces"
        k = max(5, args.steps // 10)
        head = float(np.mean(divergences[:k]))
        tail = float(np.mean(divergences[-k:]))
        assert tail < head, (
            f"divergence did not decrease: first-{k} mean {head:.4f} "
            f"-> last-{k} mean {tail:.4f}")
        print(f"[ot-gan] strict: finite losses, 0 post-warmup retraces, "
              f"Wbar {head:.4f} -> {tail:.4f} (decreasing)")

    if args.eval_kernel:
        # Table-1 analogue: learned kernel geometry
        kd1, kd2 = jax.random.split(kd)
        data = make_data(kd1, 64, pixels=args.pixels)
        noise = jax.random.normal(kd2, (64, x_dim))

        def k_mean(p, q_):
            lp = gaussian_log_features(
                embed(params["emb"], p), params["anchors"], eps=EPS, q=fm.q)
            lq = gaussian_log_features(
                embed(params["emb"], q_), params["anchors"], eps=EPS, q=fm.q)
            return float(jnp.mean(jnp.exp(lp) @ jnp.exp(lq).T))
        print("learned kernel k_theta(f(x), f(y)) means "
              "(Table 1 analogue):")
        print(f"  data/data   = {k_mean(data, data):.4e}")
        print(f"  data/noise  = {k_mean(data, noise):.4e}")
        print(f"  noise/noise = {k_mean(noise, noise):.4e}")


if __name__ == "__main__":
    main()
