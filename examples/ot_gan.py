"""OT-GAN with adversarially-learned positive-feature kernels (paper §4).

    PYTHONPATH=src python examples/ot_gan.py [--steps 300] [--pixels]

Reproduces the paper's Eq. (18) objective at container scale:

    min_rho  max_{gamma, theta}  (1/B) sum_b  Wbar_{eps, c_theta o h_gamma}

* g_rho   — generator MLP z -> x
* f_gamma — adversarial embedding x -> R^d_latent  (the "cost" tower)
* phi_theta — Lemma-1 Gaussian positive features with LEARNED anchors

The Sinkhorn divergence is evaluated with the linear-time factored solver,
and its gradients flow through the envelope-theorem VJP — both of the
paper's claimed advantages (linear batch cost; no unrolled loop in the
backward graph).

Default target: 8-mode Gaussian ring in R^2 (mode coverage printed).
--pixels switches to a 12x12 synthetic "two-moons pixels" image domain to
exercise the DCGAN-shaped pipeline (conv stubs replaced by MLPs on CPU).

--eval-kernel prints the Table-1 analogue: learned kernel values between
data/data, data/noise, noise/noise pairs.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rot_log_factored
from repro.core.features import GaussianFeatureMap, gaussian_log_features
from repro.models.layers import init_linear, linear

LATENT_Z = 16
LATENT_D = 8         # f_gamma output dim (the paper embeds into R^d)
EPS = 0.5
R_BALL = 3.0


def init_mlp_stack(key, dims, std=None):
    ks = jax.random.split(key, len(dims) - 1)
    return [init_linear(k, a, b, bias=True,
                        std=(std or (2.0 / a) ** 0.5))
            for k, a, b in zip(ks, dims[:-1], dims[1:])]


def mlp_apply(stack, x, final_tanh=False):
    for i, p in enumerate(stack):
        x = linear(p, x)
        if i < len(stack) - 1:
            x = jax.nn.gelu(x)
    return jnp.tanh(x) if final_tanh else x


def make_data(key, n, pixels=False):
    if pixels:
        # two-moons rendered to 12x12 binary-ish images
        k1, k2 = jax.random.split(key)
        t = jnp.pi * jax.random.uniform(k1, (n,))
        moon = jax.random.bernoulli(k2, 0.5, (n,))
        cx = jnp.where(moon, 0.5 + 0.4 * jnp.cos(t), 0.5 - 0.4 * jnp.cos(t))
        cy = jnp.where(moon, 0.35 + 0.3 * jnp.sin(t), 0.65 - 0.3 * jnp.sin(t))
        gx, gy = jnp.meshgrid(jnp.linspace(0, 1, 12), jnp.linspace(0, 1, 12))
        img = jnp.exp(-(((gx[None] - cx[:, None, None]) ** 2
                         + (gy[None] - cy[:, None, None]) ** 2) / 0.01))
        return img.reshape(n, 144)
    # ring of 8 gaussians
    k1, k2 = jax.random.split(key)
    mode = jax.random.randint(k1, (n,), 0, 8)
    ang = 2 * jnp.pi * mode / 8
    centers = jnp.stack([jnp.cos(ang), jnp.sin(ang)], -1) * 2.0
    return centers + 0.05 * jax.random.normal(k2, (n, 2))


def gan_losses(params, key, data, fm: GaussianFeatureMap, n_iter=40):
    g, f, anchors = params["gen"], params["emb"], params["anchors"]
    B = data.shape[0]
    z = jax.random.normal(key, (B, LATENT_Z))
    fake = mlp_apply(g, z)
    a = jnp.full((B,), 1.0 / B)

    def embed(pts):
        h = mlp_apply(f, pts, final_tanh=True) * R_BALL   # h_gamma into B(0,R)
        return h

    def div(p, q_):
        lx = gaussian_log_features(embed(p), anchors, eps=EPS, q=fm.q)
        ly = gaussian_log_features(embed(q_), anchors, eps=EPS, q=fm.q)
        w_xy = rot_log_factored(lx, ly, a, a, EPS, 0.0, n_iter)
        w_xx = rot_log_factored(lx, lx, a, a, EPS, 0.0, n_iter)
        w_yy = rot_log_factored(ly, ly, a, a, EPS, 0.0, n_iter)
        return w_xy - 0.5 * (w_xx + w_yy)

    d = div(fake, data)
    return d, fake


def mode_coverage(fake):
    ang = jnp.arctan2(fake[:, 1], fake[:, 0])
    mode = jnp.round(ang / (2 * jnp.pi / 8)).astype(jnp.int32) % 8
    radius_ok = jnp.abs(jnp.linalg.norm(fake[:, :2], axis=1) - 2.0) < 0.5
    covered = jnp.zeros((8,)).at[mode].max(radius_ok.astype(jnp.float32))
    return int(jnp.sum(covered))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--r", type=int, default=128)
    ap.add_argument("--nc", type=int, default=3,
                    help="adversary steps per generator step (paper's n_c)")
    ap.add_argument("--pixels", action="store_true")
    ap.add_argument("--eval-kernel", action="store_true")
    args = ap.parse_args()

    x_dim = 144 if args.pixels else 2
    key = jax.random.PRNGKey(0)
    kg, ke, ka, kd = jax.random.split(key, 4)
    fm = GaussianFeatureMap(r=args.r, d=LATENT_D, eps=EPS, R=R_BALL)
    params = {
        "gen": init_mlp_stack(kg, [LATENT_Z, 128, 128, x_dim]),
        "emb": init_mlp_stack(ke, [x_dim, 64, LATENT_D]),
        "anchors": fm.init(ka),
    }

    from functools import partial

    @partial(jax.jit, static_argnames=("adv",))
    def train_step(params, key, data, lr_g=3e-3, lr_adv=1e-3, adv=False):
        def loss_fn(p):
            d, fake = gan_losses(p, key, data, fm)
            return d, fake
        (d, fake), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        sign = {"gen": -1.0, "emb": +1.0, "anchors": +1.0}
        new = {}
        for name in params:
            lr = lr_g if name == "gen" else lr_adv
            s = sign[name] * lr
            upd = (lambda p_, g_: p_ + s * g_)
            if adv and name == "gen":
                new[name] = params[name]
            elif (not adv) and name != "gen":
                new[name] = params[name]
            else:
                new[name] = jax.tree.map(upd, params[name], grads[name])
        return new, d, fake

    t0 = time.time()
    for step in range(args.steps):
        kd, ks, kb = jax.random.split(kd, 3)
        data = make_data(kb, args.batch, pixels=args.pixels)
        adv = bool((step % (args.nc + 1)) != args.nc)  # n_c adversary : 1 gen
        params, d, fake = train_step(params, ks, data, adv=adv)
        if step % 50 == 0 or step == args.steps - 1:
            msg = f"[ot-gan] step {step:4d} Wbar={float(d):+.4f}"
            if not args.pixels:
                msg += f" modes={mode_coverage(fake)}/8"
            print(msg + f" ({time.time() - t0:.1f}s)")

    if args.eval_kernel:
        # Table-1 analogue: learned kernel geometry
        kd1, kd2 = jax.random.split(kd)
        data = make_data(kd1, 64, pixels=args.pixels)
        noise = jax.random.normal(kd2, (64, x_dim))
        def k_mean(p, q_):
            lp = gaussian_log_features(
                jnp.tanh(mlp_apply(params["emb"], p, final_tanh=True)) * R_BALL
                if False else mlp_apply(params["emb"], p, final_tanh=True) * R_BALL,
                params["anchors"], eps=EPS, q=fm.q)
            lq = gaussian_log_features(
                mlp_apply(params["emb"], q_, final_tanh=True) * R_BALL,
                params["anchors"], eps=EPS, q=fm.q)
            return float(jnp.mean(jnp.exp(lp) @ jnp.exp(lq).T))
        print("learned kernel k_theta(f(x), f(y)) means "
              "(Table 1 analogue):")
        print(f"  data/data   = {k_mean(data, data):.4e}")
        print(f"  data/noise  = {k_mean(data, noise):.4e}")
        print(f"  noise/noise = {k_mean(noise, noise):.4e}")


if __name__ == "__main__":
    main()
