"""Paper Fig. 6 / Remark 1: Wasserstein barycenters on the positive sphere
with the cost c(x, y) = -log(x^T y).

    PYTHONPATH=src python examples/sphere_barycenter.py

On the positive sphere the Gibbs kernel of this cost at eps=1 is the
LINEAR kernel k(x,y) = x^T y — i.e. the positive feature map is the
identity, phi(x) = x, with r = 3 features. Sinkhorn iterations therefore
cost O(3n) — the most extreme instance of the paper's factorization.

We discretize the positive octant (50x50), place three blurred corner
histograms (the paper's a, b, c), and run iterative Bregman projections
[Benamou et al. '15] entirely through the factored kernel to compute
their barycenter. A softmax sharpening reveals the barycenter mass
concentrates between the corners, as in the paper's panel (e).
"""
import jax
import jax.numpy as jnp
import numpy as np


def positive_sphere_grid(m=50):
    th = jnp.linspace(0.02, jnp.pi / 2 - 0.02, m)
    ph = jnp.linspace(0.02, jnp.pi / 2 - 0.02, m)
    T, P = jnp.meshgrid(th, ph)
    pts = jnp.stack([
        jnp.sin(T) * jnp.cos(P), jnp.sin(T) * jnp.sin(P), jnp.cos(T)
    ], axis=-1).reshape(-1, 3)
    return pts  # (m*m, 3) on the positive sphere


def corner_hist(pts, corner, sharp=60.0):
    w = jnp.exp(sharp * (pts @ corner - 1.0))
    return w / jnp.sum(w)


def barycenter_ibp(Phi, hists, n_iter=200):
    """IBP barycenter through the factored kernel K = Phi Phi^T (r=3)."""
    n, _ = Phi.shape
    K = lambda v: Phi @ (Phi.T @ v)          # O(3n) matvec
    KT = K                                   # symmetric
    u = jnp.ones((len(hists), n))
    v = jnp.ones((len(hists), n))

    def body(carry, _):
        u, v = carry
        Ktu = jax.vmap(lambda ui: KT(ui))(u)              # (k, n)
        logb = jnp.mean(jnp.log(jnp.maximum(v * Ktu, 1e-38)), axis=0)
        b = jnp.exp(logb)
        v = b[None, :] / jnp.maximum(Ktu, 1e-38)
        Kv = jax.vmap(lambda vi: K(vi))(v)
        u = jnp.stack(hists) / jnp.maximum(Kv, 1e-38)
        return (u, v), b

    (u, v), bs = jax.lax.scan(body, (u, v), None, length=n_iter)
    return bs[-1]


def main():
    pts = positive_sphere_grid(50)
    corners = [jnp.array(c, jnp.float32) for c in
               ([1, 0, 0], [0, 1, 0], [0, 0, 1])]
    hists = [corner_hist(pts, c) for c in corners]
    b = jax.jit(lambda: barycenter_ibp(pts, hists))()
    # softmax sharpening (paper temperature 1000)
    sharp = jax.nn.softmax(1000.0 * b / jnp.max(b))
    peak = pts[jnp.argmax(sharp)]
    center = jnp.array([1.0, 1.0, 1.0]) / jnp.sqrt(3.0)
    ang = float(jnp.degrees(jnp.arccos(jnp.clip(peak @ center, -1, 1))))
    print(f"barycenter mass peak at {np.asarray(peak).round(3)} "
          f"({ang:.1f} deg from the octant center — mass sits between "
          f"the three corners, paper Fig. 6e)")
    mass_near_center = float(jnp.sum(jnp.where(pts @ center > 0.95, b, 0.0))
                             / jnp.sum(b))
    print(f"fraction of barycenter mass within 18deg of center: "
          f"{mass_near_center:.2f}")
    assert ang < 25.0, "barycenter should concentrate mid-octant"
    print("OK — factored-kernel (r=3) barycenter via IBP")


if __name__ == "__main__":
    main()
