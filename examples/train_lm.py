"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the paper's Sinkhorn-divergence loss in the objective (DESIGN.md §4).

    PYTHONPATH=src python examples/train_lm.py --steps 300          # ~100M
    PYTHONPATH=src python examples/train_lm.py --steps 200 --tiny   # CI-fast

Uses the production stack end to end: config system (smollm-135m family),
deterministic data pipeline, AdamW + cosine schedule, checkpointing +
fault-tolerant supervisor, OT prototype loss (learned positive features).
"""
import argparse
import dataclasses
import math
import time

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core.objective import ExecutionPolicy
from repro.data import DataConfig, DataPipeline
from repro.distributed.fault_tolerance import (
    FaultToleranceConfig,
    TrainingSupervisor,
)
from repro.kernels.ops import observe_plan_selection
from repro.models import init_params, param_count, train_loss
from repro.optim import (
    AdamWConfig,
    adamw_update,
    init_adamw,
    linear_warmup_cosine,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--arch", default="smollm_135m",
                    help="config name (e.g. deepseek-v2-236b for the "
                    "sinkhorn-router MoE path)")
    ap.add_argument("--no-ot", action="store_true",
                    help="ablation: drop the Sinkhorn loss")
    ap.add_argument("--router", default=None,
                    choices=("softmax", "sinkhorn"),
                    help="override the config's MoE router")
    ap.add_argument("--strict", action="store_true",
                    help="CI mode: force the fused bf16 plan (interpret), "
                    "assert plan selection, finite losses and zero "
                    "post-warmup retraces")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.tiny:
        cfg = cfg.tiny()
    else:
        # ~100M-class config: shorter depth for CPU speed
        cfg = dataclasses.replace(cfg, n_layers=8, ot_iters=20,
                                  ot_tokens=256)
    if args.no_ot:
        cfg = dataclasses.replace(cfg, ot_loss_weight=0.0)
    if args.router:
        cfg = dataclasses.replace(cfg, router=args.router)
    if args.strict:
        # force the fused megakernel path even on interpret-only backends
        # so plan-selection observability can verify the policy is active
        cfg = dataclasses.replace(cfg, ot_use_pallas=True)

    # the run-wide OT execution policy: constructed ONCE from the config +
    # resolved backend, shared by the prototype loss and sinkhorn router
    policy = ExecutionPolicy.from_config(cfg)

    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    print(f"[train_lm] arch={cfg.name}({'tiny' if args.tiny else '8L'}) "
          f"params={param_count(params) / 1e6:.1f}M "
          f"ot_loss={'off' if args.no_ot else cfg.ot_loss_weight} "
          f"router={cfg.router}")
    print(f"[train_lm] ot-policy {policy.describe()}")

    ocfg = AdamWConfig(lr=args.lr)
    opt_state = init_adamw(params, ocfg)
    sched = linear_warmup_cosine(args.lr, warmup=20, total_steps=args.steps)
    data = DataPipeline(DataConfig(
        seed=0, global_batch=args.batch, seq_len=args.seq, vocab=cfg.vocab))

    @jax.jit
    def step_fn(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: train_loss(p, cfg, batch, policy=policy),
            has_aux=True)(params)
        params, opt_state, om = adamw_update(params, grads, opt_state,
                                             ocfg, lr_schedule=sched)
        metrics.update(om)
        return params, opt_state, metrics

    if args.strict:
        # warm up under the observability hook: the trace must select the
        # fused plan with the policy's precision for the prototype loss
        with observe_plan_selection() as plan_events:
            b0 = DataPipeline(DataConfig(
                seed=0, global_batch=args.batch, seq_len=args.seq,
                vocab=cfg.vocab)).batch_at(0)
            step_fn(params, opt_state, b0)
        if cfg.ot_loss_weight > 0:
            sel = [e for e in plan_events
                   if e["geometry"] == "FactoredPositive"]
            assert sel, f"no fused plan for the OT loss: {plan_events}"
            assert all(e["precision"] == cfg.ot_precision for e in sel), sel
            print(f"[train_lm] strict: fused plan active "
                  f"({sel[0]['kind']}/{sel[0]['mode']}, "
                  f"precision={sel[0]['precision']}, {len(sel)} solves)")

    ckpt = CheckpointManager(args.ckpt_dir, keep=2, async_save=True)
    sup = TrainingSupervisor(ckpt, FaultToleranceConfig(save_every=100))
    t0 = time.time()
    hist = []

    def one_step(state, step):
        params, opt_state = state
        new_params, new_opt, m = step_fn(params, opt_state,
                                         data.batch_at(step))
        mm = {k: float(v) for k, v in jax.device_get(m).items()}
        if not sup.admit_step(mm):
            # non-finite OT loss / grad norm: applying this update would
            # poison the parameters permanently — keep the OLD state and
            # train on the next batch (the supervisor bounds the streak)
            print(f"[train_lm] step {step:4d} SKIPPED on non-finite "
                  f"metrics (streak {sup.consecutive_skips})")
            return params, opt_state
        if step % 20 == 0:
            hist.append(mm)
            print(f"[train_lm] step {step:4d} loss {mm['loss']:.4f} "
                  f"ce {mm['ce']:.4f} ot {mm.get('ot', 0):.4f} "
                  f"lr {mm['lr']:.2e} ({time.time() - t0:.0f}s)")
        return new_params, new_opt

    traces_after_warmup = step_fn._cache_size() if args.strict else None
    (params, opt_state), end = sup.run((params, opt_state), 0, args.steps,
                                       one_step)
    first, last = hist[0]["ce"], hist[-1]["ce"]
    print(f"[train_lm] CE {first:.4f} -> {last:.4f} over {end} steps "
          f"({'improved' if last < first else 'NO IMPROVEMENT'}); "
          f"checkpoints in {args.ckpt_dir}")
    if args.strict:
        assert all(math.isfinite(m[k]) for m in hist for k in m), hist
        retraces = step_fn._cache_size() - traces_after_warmup
        assert retraces == 0, f"{retraces} post-warmup retraces"
        assert sup.skipped_steps == 0, (
            f"{sup.skipped_steps} steps skipped on non-finite metrics in "
            "a clean run")
        print(f"[train_lm] strict: all losses finite, 0 skipped steps, "
              f"0 post-warmup retraces ({step_fn._cache_size()} trace)")


if __name__ == "__main__":
    main()
