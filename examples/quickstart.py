"""Quickstart: linear-time Sinkhorn divergences through the unified API.

    PYTHONPATH=src python examples/quickstart.py

One entry point — ``repro.core.solve`` — reaches every solver in the repo:

    problem = OTProblem.from_point_clouds(x, y, anchors, eps=0.5)
    res = solve(problem, method="log_factored")

Method selection cheat-sheet:
  "factored"       scaling-space O(r(n+m)) per iter — fastest at eps >~ 0.3
  "log_factored"   same cost, log-domain — the default; safe at any eps
  "accelerated"    Nesterov-AGM variant (Remark 2) — best iteration rate,
                   but its two-marginal error check doubles the f32 noise
                   floor: keep tol >= 1e-6 or it will report converged=False
  "quadratic"      dense O(nm) Cuturi baseline — ground truth at small n
  "log_quadratic"  dense log-domain — the oracle the tests compare against
  "sharded"        shard_map multi-device (pass mesh=...)
Schedule selection: pass ``EpsSchedule(eps_init=..., decay=...)`` whenever
the target eps is small (<= 0.05) and the problem was built from point
clouds or a cost matrix — the geometric eps cascade warm-starts each stage
and converges in fewer total iterations than a cold start.

Walks the paper's pipeline end to end:
  1. sample two clouds and build a geometry problem (Lemma-1 features);
  2. solve with the factored O(r(n+m)) path and the exact dense oracle;
  3. solve a small-eps problem with and without annealing;
  4. batch-solve a GAN-shaped minibatch with the vmapped engine;
  5. differentiate the divergence w.r.t. the cloud (envelope theorem).
"""
import time

import jax
import jax.numpy as jnp

from repro.core import (
    BatchedSinkhorn,
    EpsSchedule,
    OTProblem,
    data_radius,
    sinkhorn_divergence_gaussian,
    solve,
    solve_annealed,
)
from repro.core.features import GaussianFeatureMap
from repro.data import gaussian_clouds


def main():
    n, d, eps, r = 4000, 2, 0.5, 500
    x, y = gaussian_clouds(seed=0, n=n, d=d)
    R = float(data_radius(x, y))
    print(f"clouds: n={n}, d={d}, radius={R:.2f}, eps={eps}, r={r}")

    fm = GaussianFeatureMap(r=r, d=d, eps=eps, R=R)
    U = fm.init(jax.random.PRNGKey(0))
    problem = OTProblem.from_point_clouds(x, y, U, eps=eps, R=R)

    # --- exact (quadratic) reference through the same front-end ---
    t0 = time.perf_counter()
    ref = solve(problem, method="log_quadratic", tol=1e-6, max_iter=5000)
    t_ref = time.perf_counter() - t0
    print(f"exact ROT   = {float(ref.cost):+.5f}   ({t_ref:.2f}s, "
          f"{int(ref.n_iter)} iters, O(n^2) per iter)")

    # --- linear-time positive features (the paper; method='auto' picks it) ---
    t0 = time.perf_counter()
    rf = solve(problem, tol=1e-6, max_iter=5000)
    t_rf = time.perf_counter() - t0
    dev = abs(float(rf.cost - ref.cost) / ref.cost) * 100
    print(f"RF ROT      = {float(rf.cost):+.5f}   ({t_rf:.2f}s, "
          f"{int(rf.n_iter)} iters, O(nr) per iter) — {dev:.2f}% off")

    # --- small eps: annealing cuts iterations ---
    small = OTProblem.from_point_clouds(x[:500], y[:500], U, eps=0.02, R=R)
    cold = solve(small, method="log_factored", tol=1e-4, max_iter=50000)
    ann = solve_annealed(small, method="log_factored", tol=1e-4,
                         max_iter=50000,
                         schedule=EpsSchedule(eps_init=0.8, decay=0.4))
    print(f"eps=0.02    : cold {int(cold.n_iter)} iters vs annealed "
          f"{int(ann.result.n_iter)} iters over {len(ann.stage_eps)} stages "
          f"(same cost to {abs(float(ann.result.cost - cold.cost)):.1e})")

    # --- GAN-shaped minibatch: one vmapped engine call, B problems ---
    B, nb = 8, 256
    xs = x[: B * nb].reshape(B, nb, d)
    ys = y[: B * nb].reshape(B, nb, d)
    engine = BatchedSinkhorn(eps=eps, method="log_factored", tol=1e-6,
                             max_iter=2000)
    t0 = time.perf_counter()
    batch = engine.solve_point_clouds(xs, ys, U, R=R)
    t_b = time.perf_counter() - t0
    print(f"batched     : {B} problems of n={nb} in {t_b:.2f}s, costs "
          f"[{float(batch.cost.min()):+.4f}, {float(batch.cost.max()):+.4f}]")

    # --- differentiable Sinkhorn divergence (envelope theorem) ---
    div_fn = jax.jit(lambda x_: sinkhorn_divergence_gaussian(
        x_, y, U, eps=eps, q=fm.q, tol=1e-6, max_iter=2000))
    grad_fn = jax.jit(jax.grad(lambda x_: sinkhorn_divergence_gaussian(
        x_, y, U, eps=eps, q=fm.q, tol=1e-6, max_iter=2000)))
    div = float(div_fn(x))
    g = grad_fn(x)
    print(f"divergence  = {div:+.5f}; |grad wrt locations| = "
          f"{float(jnp.linalg.norm(g)):.4f} "
          f"(envelope theorem — no backprop through the loop)")

    # gradient step moves the cloud closer
    x2 = x - 50.0 * g
    print(f"after one gradient step: divergence = {float(div_fn(x2)):+.5f}")


if __name__ == "__main__":
    main()
