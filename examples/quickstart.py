"""Quickstart: linear-time Sinkhorn divergence between two point clouds.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's pipeline end to end:
  1. sample two clouds;
  2. build Lemma-1 positive random features for the Gaussian kernel at eps;
  3. run the factored O(r(n+m)) Sinkhorn (Alg. 1);
  4. compare against the exact dense solver;
  5. differentiate the divergence w.r.t. the cloud (envelope theorem).
"""
import time

import jax
import jax.numpy as jnp

from repro.core import (
    data_radius,
    gaussian_log_features,
    sinkhorn_divergence_gaussian,
    sinkhorn_log_factored,
    sinkhorn_log_quadratic,
    squared_euclidean,
)
from repro.core.features import GaussianFeatureMap
from repro.data import gaussian_clouds


def main():
    n, d, eps, r = 4000, 2, 0.5, 500
    x, y = gaussian_clouds(seed=0, n=n, d=d)
    a = jnp.full((n,), 1.0 / n)
    R = float(data_radius(x, y))
    print(f"clouds: n={n}, d={d}, radius={R:.2f}, eps={eps}, r={r}")

    # --- exact (quadratic) reference ---
    t0 = time.perf_counter()
    C = squared_euclidean(x, y)
    ref = sinkhorn_log_quadratic(C, a, a, eps=eps, tol=1e-6, max_iter=5000)
    t_ref = time.perf_counter() - t0
    print(f"exact ROT   = {float(ref.cost):+.5f}   ({t_ref:.2f}s, "
          f"{int(ref.n_iter)} iters, O(n^2) per iter)")

    # --- linear-time positive features (the paper) ---
    fm = GaussianFeatureMap(r=r, d=d, eps=eps, R=R)
    U = fm.init(jax.random.PRNGKey(0))
    t0 = time.perf_counter()
    lxi = gaussian_log_features(x, U, eps=eps, q=fm.q)
    lzt = gaussian_log_features(y, U, eps=eps, q=fm.q)
    rf = sinkhorn_log_factored(lxi, lzt, a, a, eps=eps, tol=1e-6,
                               max_iter=5000)
    t_rf = time.perf_counter() - t0
    dev = abs(float(rf.cost - ref.cost) / ref.cost) * 100
    print(f"RF ROT      = {float(rf.cost):+.5f}   ({t_rf:.2f}s, "
          f"{int(rf.n_iter)} iters, O(nr) per iter) — {dev:.2f}% off")

    # --- differentiable Sinkhorn divergence ---
    div_fn = jax.jit(lambda x_: sinkhorn_divergence_gaussian(
        x_, y, U, eps=eps, q=fm.q, tol=1e-6, max_iter=2000))
    grad_fn = jax.jit(jax.grad(lambda x_: sinkhorn_divergence_gaussian(
        x_, y, U, eps=eps, q=fm.q, tol=1e-6, max_iter=2000)))
    div = float(div_fn(x))
    g = grad_fn(x)
    print(f"divergence  = {div:+.5f}; |grad wrt locations| = "
          f"{float(jnp.linalg.norm(g)):.4f} "
          f"(envelope theorem — no backprop through the loop)")

    # gradient step moves the cloud closer
    x2 = x - 50.0 * g
    print(f"after one gradient step: divergence = {float(div_fn(x2)):+.5f}")


if __name__ == "__main__":
    main()
