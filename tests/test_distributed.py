"""Multi-device tests in a subprocess (8 virtual CPU devices).

The parent test process keeps the single real device; each test spawns
``python -c`` with XLA_FLAGS=--xla_force_host_platform_device_count=8 so
smoke tests/benches elsewhere are unaffected.
"""
import os
import subprocess
import sys
import textwrap


_ENV = {**os.environ,
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src")}


def _run(code: str):
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=_ENV, capture_output=True, text=True, timeout=600,
    )
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return res.stdout


def test_sharded_sinkhorn_matches_single_device():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.core import (sinkhorn_factored, sharded_sinkhorn_factored,
                                gaussian_features)
        from repro.core.features import GaussianFeatureMap
        key = jax.random.PRNGKey(0)
        n, m, d, r, eps = 64, 64, 2, 128, 0.7
        x = jax.random.normal(key, (n, d))
        y = jax.random.normal(jax.random.fold_in(key, 1), (m, d)) * 0.5
        fm = GaussianFeatureMap(r=r, d=d, eps=eps, R=3.0)
        U = fm.init(jax.random.fold_in(key, 2))
        xi = gaussian_features(x, U, eps=eps, q=fm.q)
        zt = gaussian_features(y, U, eps=eps, q=fm.q)
        a = jnp.full((n,), 1/n); b = jnp.full((m,), 1/m)
        ref = sinkhorn_factored(xi, zt, a, b, eps=eps, tol=1e-7, max_iter=3000)
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        out = sharded_sinkhorn_factored(mesh, xi, zt, a, b, eps=eps,
                                        tol=1e-7, max_iter=3000)
        np.testing.assert_allclose(float(out.cost), float(ref.cost), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(out.u), np.asarray(ref.u), rtol=1e-3)
        print("sharded sinkhorn OK", float(out.cost))
    """)


def test_api_solve_sharded_dispatch():
    """solve(method='sharded') routes through the shard_map solver and
    matches the single-device factored path."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.core import OTProblem, solve, gaussian_features
        from repro.core.features import GaussianFeatureMap
        key = jax.random.PRNGKey(0)
        n, m, d, r, eps = 64, 64, 2, 128, 0.7
        x = jax.random.normal(key, (n, d))
        y = jax.random.normal(jax.random.fold_in(key, 1), (m, d)) * 0.5
        fm = GaussianFeatureMap(r=r, d=d, eps=eps, R=3.0)
        U = fm.init(jax.random.fold_in(key, 2))
        xi = gaussian_features(x, U, eps=eps, q=fm.q)
        zt = gaussian_features(y, U, eps=eps, q=fm.q)
        p = OTProblem.from_features(xi, zt, eps=eps)
        ref = solve(p, method="factored", tol=1e-7, max_iter=3000)
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        out = solve(p, method="sharded", mesh=mesh, tol=1e-7, max_iter=3000)
        np.testing.assert_allclose(float(out.cost), float(ref.cost), rtol=1e-5)
        print("api sharded dispatch OK", float(out.cost))
    """)


def test_moe_ep_multidevice_matches_dense():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.distributed.sharding import shard_map
        from repro.models.moe import init_moe, moe_dense, moe_ep_local
        key = jax.random.PRNGKey(0)
        T, d, f, E = 128, 16, 32, 8
        p = init_moe(key, d, f, E)
        x = jax.random.normal(jax.random.fold_in(key, 1), (T, d)) * 0.5
        out_d, _ = moe_dense(p, x, top_k=2)
        mesh = Mesh(np.array(jax.devices()).reshape(1, 8), ("data", "model"))
        fn = shard_map(
            lambda p_, x_: moe_ep_local(p_, x_, top_k=2, n_experts=E,
                                        axis="model", capacity_factor=8.0),
            mesh=mesh,
            in_specs=({"router": P(None, None), "up": P("model", None, None),
                       "gate": P("model", None, None),
                       "down": P("model", None, None)}, P("model", None)),
            out_specs=(P("model", None), P()),
            check_vma=False)
        with mesh:
            out_e, _ = fn(p, x)
        np.testing.assert_allclose(np.asarray(out_e), np.asarray(out_d),
                                   rtol=2e-3, atol=2e-4)
        print("EP MoE 8-device OK")
    """)


def test_compressed_psum_close_to_exact():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.distributed.sharding import shard_map
        from repro.optim import compressed_psum
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 512)) * 0.1
        fn = shard_map(
            lambda v: (jax.lax.psum(v, "data"),
                       compressed_psum(v, "data")),
            mesh=mesh, in_specs=P("data", None),
            out_specs=(P("data", None), P("data", None)), check_vma=False)
        with mesh:
            exact, comp = fn(x)
        err = float(jnp.max(jnp.abs(exact - comp)))
        scale = float(jnp.max(jnp.abs(exact)))
        assert err < 0.05 * scale + 1e-3, (err, scale)
        print("compressed psum OK", err, scale)
    """)


def test_ssd_context_parallel_8dev_matches_plain():
    """The §Perf mamba2 hillclimb path: CP SSD across 8 'model' ranks must
    be numerically identical to the single-device chunked SSD."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.distributed.sharding import MeshContext, use_mesh_context
        from repro.models.ssm import ssd_chunked, ssd_context_parallel
        key = jax.random.PRNGKey(3)
        B, S, H, P, N = 2, 64, 2, 4, 3
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (B, S, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
        Bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
        Cm = jax.random.normal(ks[4], (B, S, N)) * 0.5
        y_ref, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=8)
        mesh = Mesh(np.array(jax.devices()).reshape(1, 8),
                    ("data", "model"))
        with mesh, use_mesh_context(MeshContext(mesh)):
            y_cp = ssd_context_parallel(x, dt, A, Bm, Cm, chunk=8)
        np.testing.assert_allclose(np.asarray(y_cp), np.asarray(y_ref),
                                   rtol=2e-3, atol=2e-3)
        print("CP SSD 8-device OK")
    """)


def test_tiny_train_step_on_2x2_mesh():
    """End-to-end sharded train step (pjit + shard_map MoE) on 4 devices."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.shapes import ShapeSpec
        from repro.launch.mesh import make_local_mesh
        from repro.launch.steps import make_train_step
        from repro.models import init_params
        from repro.optim import AdamWConfig, init_adamw
        cfg = get_config("deepseek_v3_671b").tiny(
            param_dtype="float32", compute_dtype="float32",
            d_model=64, n_experts=8, vocab=256, ot_iters=5)
        mesh = make_local_mesh(2, 2)
        shape = ShapeSpec("t", 32, 4, "train")
        step, shapes, shards = make_train_step(cfg, mesh, shape,
                                               AdamWConfig(lr=1e-3))
        key = jax.random.PRNGKey(0)
        params = init_params(key, cfg)
        opt = init_adamw(params, AdamWConfig(lr=1e-3))
        tok = jax.random.randint(key, (4, 32), 0, cfg.vocab)
        batch = {"tokens": tok, "labels": tok}
        with mesh:
            params, opt, metrics = step(params, opt, batch)
        loss = float(metrics["loss"])
        assert np.isfinite(loss), metrics
        print("2x2 sharded MoE train step OK, loss", loss)
    """)
