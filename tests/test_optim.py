"""Optimizer + gradient compression unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (
    AdamWConfig,
    adamw_update,
    clip_by_global_norm,
    dequantize_int8,
    ef_compress_tree,
    init_adamw,
    init_error_buffers,
    linear_warmup_cosine,
    quantize_int8,
)


def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=None)
    params = {"w": jnp.array([3.0, -2.0, 1.5]).reshape(1, 3)}
    target = jnp.array([1.0, 1.0, 1.0]).reshape(1, 3)
    state = init_adamw(params, cfg)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, cfg)
    assert float(loss(params)) < 1e-3


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(jnp.linalg.norm(clipped["a"])), 1.0,
                               rtol=1e-5)


def test_bf16_moments_still_converge():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=None,
                      moment_dtype="bfloat16")
    params = {"w": jnp.array([[2.0, -1.0]])}
    state = init_adamw(params, cfg)
    assert state.m["w"].dtype == jnp.bfloat16
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, cfg)
    assert float(loss(params)) < 1e-2


def test_schedule_warmup_then_decay():
    sched = linear_warmup_cosine(1.0, warmup=10, total_steps=100)
    assert float(sched(jnp.asarray(5))) < 1.0
    near_peak = float(sched(jnp.asarray(11)))
    assert near_peak > 0.9
    assert float(sched(jnp.asarray(100))) < near_peak


def test_quantize_roundtrip_error():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1000,)) * 0.01
    q = quantize_int8(x)
    y = dequantize_int8(q, x.shape)
    # per-block max / 127 bounds the element error
    assert float(jnp.max(jnp.abs(x - y))) <= float(jnp.max(jnp.abs(x))) / 127 + 1e-8


def test_error_feedback_removes_bias():
    """Summed EF-compressed gradients converge to the true sum."""
    key = jax.random.PRNGKey(1)
    g = {"w": jax.random.normal(key, (512,)) * 0.1}
    buf = init_error_buffers(g)
    total_true = jnp.zeros((512,))
    total_comp = jnp.zeros((512,))
    for i in range(50):
        gi = {"w": g["w"] * (1.0 + 0.01 * i)}
        comp, buf = ef_compress_tree(gi, buf)
        total_true += gi["w"]
        total_comp += comp["w"]
    # residual is bounded by one quantization step, not accumulated
    err = float(jnp.max(jnp.abs(total_true - total_comp)))
    single_step = float(jnp.max(jnp.abs(g["w"]))) / 127 * 2
    assert err < single_step * 5, (err, single_step)
