"""Unified front-end (repro.core.api): oracle-consistency across methods,
batched engine vs per-problem loop, bucket padding exactness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.shapes import OTBatchShape, OT_SUPPORT_BUCKETS, ot_bucket
from repro.core import (
    BatchedSinkhorn,
    EpsSchedule,
    OTProblem,
    gaussian_features,
    gaussian_log_features,
    solve,
    solve_many,
)
from repro.core.features import GaussianFeatureMap

EPS = 0.6
R_FEAT = 128

ALL_METHODS = ("factored", "log_factored", "accelerated", "quadratic",
               "log_quadratic")


@pytest.fixture(scope="module")
def fixture():
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    n, m, d = 60, 50, 2
    x = jnp.clip(jax.random.normal(k1, (n, d)), -2, 2)
    y = jnp.clip(jax.random.normal(k2, (m, d)) * 0.7 + 0.3, -2, 2)
    fm = GaussianFeatureMap(r=R_FEAT, d=d, eps=EPS, R=3.0)
    U = fm.init(k3)
    xi = gaussian_features(x, U, eps=EPS, q=fm.q)
    zeta = gaussian_features(y, U, eps=EPS, q=fm.q)
    return x, y, U, fm, xi, zeta


# ---------------------------------------------------------------------------
# solve(): oracle-consistency matrix
# ---------------------------------------------------------------------------


def test_solve_method_matrix_agrees(fixture):
    """All five methods on a feature-built problem share ONE fixed point
    (the quadratic baselines run on the induced cost), so every pair of
    costs must agree to solver tolerance."""
    _, _, _, _, xi, zeta = fixture
    p = OTProblem.from_features(xi, zeta, eps=EPS)
    # tol=1e-6 converges on every method; tighter is below the f32
    # marginal-error floor and would just exhaust max_iter
    costs = {
        meth: float(solve(p, method=meth, tol=1e-6, max_iter=8000).cost)
        for meth in ALL_METHODS
    }
    ref = costs["log_quadratic"]
    for meth, c in costs.items():
        np.testing.assert_allclose(c, ref, rtol=1e-5, err_msg=meth)


def test_solve_auto_dispatch(fixture):
    x, y, U, fm, xi, zeta = fixture
    lxi = gaussian_log_features(x, U, eps=EPS, q=fm.q)
    lzt = gaussian_log_features(y, U, eps=EPS, q=fm.q)
    r_feat = solve(OTProblem.from_features(xi, zeta, eps=EPS))
    r_log = solve(OTProblem.from_log_features(lxi, lzt, eps=EPS))
    r_geo = solve(OTProblem.from_point_clouds(x, y, U, eps=EPS))
    np.testing.assert_allclose(float(r_feat.cost), float(r_log.cost),
                               rtol=1e-4)
    assert np.isfinite(float(r_geo.cost))


def test_solve_converged_flags(fixture):
    _, _, _, _, xi, zeta = fixture
    p = OTProblem.from_features(xi, zeta, eps=EPS)
    res = solve(p, method="log_factored", tol=1e-6, max_iter=4000)
    assert bool(res.converged)
    assert float(res.marginal_err) <= 1e-6


def test_solve_rejects_unknown_method(fixture):
    _, _, _, _, xi, zeta = fixture
    p = OTProblem.from_features(xi, zeta, eps=EPS)
    with pytest.raises(ValueError, match="unknown method"):
        solve(p, method="nope")


def test_feature_problem_rejects_annealing(fixture):
    _, _, _, _, xi, zeta = fixture
    p = OTProblem.from_features(xi, zeta, eps=EPS)
    with pytest.raises(ValueError, match="anneal"):
        solve(p, method="log_factored", schedule=EpsSchedule(eps_init=1.0))


# ---------------------------------------------------------------------------
# Batched engine
# ---------------------------------------------------------------------------


def _batch_clouds(B, n, m, d=2, seed=5):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = jnp.clip(jax.random.normal(ks[0], (B, n, d)), -2, 2)
    y = jnp.clip(jax.random.normal(ks[1], (B, m, d)) * 0.7, -2, 2)
    return x, y


@pytest.mark.parametrize("method", ["factored", "log_factored"])
def test_batched_matches_per_problem_loop(fixture, method):
    """The tentpole contract: stacked vmapped solves match a Python loop of
    single solves element-wise to <= 1e-5 relative cost error."""
    _, _, U, fm, _, _ = fixture
    B, n, m = 4, 48, 40
    x, y = _batch_clouds(B, n, m)
    feat = gaussian_log_features if method == "log_factored" else \
        gaussian_features
    ka = jnp.stack([feat(x[i], U, eps=EPS, q=fm.q) for i in range(B)])
    kb = jnp.stack([feat(y[i], U, eps=EPS, q=fm.q) for i in range(B)])
    a = jnp.full((B, n), 1.0 / n)
    b = jnp.full((B, m), 1.0 / m)
    eng = BatchedSinkhorn(eps=EPS, method=method, tol=1e-7, max_iter=4000)
    res = eng.solve_stacked(ka, kb, a, b)
    assert res.cost.shape == (B,)
    for i in range(B):
        if method == "log_factored":
            p = OTProblem.from_log_features(ka[i], kb[i], eps=EPS)
        else:
            p = OTProblem.from_features(ka[i], kb[i], eps=EPS)
        single = solve(p, method=method, tol=1e-7, max_iter=4000)
        rel = abs(float(res.cost[i] - single.cost)) / abs(float(single.cost))
        assert rel <= 1e-5, (i, rel)


def test_solve_many_ragged_buckets(fixture):
    """Ragged sizes land in different buckets; padded solves must match
    unpadded per-problem solves exactly (zero-weight atoms are masked)."""
    _, _, U, fm, _, _ = fixture
    sizes = [(60, 50), (40, 70), (100, 30), (60, 50)]
    probs = []
    for i, (n, m) in enumerate(sizes):
        kk = jax.random.fold_in(jax.random.PRNGKey(9), i)
        x = jnp.clip(jax.random.normal(kk, (n, 2)), -2, 2)
        y = jnp.clip(jax.random.normal(jax.random.fold_in(kk, 1), (m, 2)),
                     -2, 2)
        probs.append(OTProblem.from_log_features(
            gaussian_log_features(x, U, eps=EPS, q=fm.q),
            gaussian_log_features(y, U, eps=EPS, q=fm.q), eps=EPS))
    outs = solve_many(probs, method="log_factored", tol=1e-7, max_iter=4000)
    assert len(outs) == len(probs)
    for p, o in zip(probs, outs):
        n, m = p.a.shape[0], p.b.shape[0]
        assert o.u.shape == (n,) and o.v.shape == (m,)
        single = solve(p, method="log_factored", tol=1e-7, max_iter=4000)
        rel = abs(float(o.cost - single.cost)) / abs(float(single.cost))
        assert rel <= 1e-5


def test_solve_many_quadratic_padding(fixture):
    """Dense-cost problems pad on both axes; still exact."""
    sizes = [(30, 45), (50, 20)]
    probs = []
    for i, (n, m) in enumerate(sizes):
        kk = jax.random.fold_in(jax.random.PRNGKey(11), i)
        x = jax.random.normal(kk, (n, 2))
        y = jax.random.normal(jax.random.fold_in(kk, 1), (m, 2)) * 0.5
        from repro.core import squared_euclidean
        probs.append(OTProblem.from_cost(squared_euclidean(x, y), eps=EPS))
    outs = solve_many(probs, method="log_quadratic", tol=1e-7, max_iter=4000)
    for p, o in zip(probs, outs):
        single = solve(p, method="log_quadratic", tol=1e-7, max_iter=4000)
        rel = abs(float(o.cost - single.cost)) / abs(float(single.cost))
        assert rel <= 1e-5


def test_batched_point_cloud_mode(fixture):
    """Geometry mode with shared anchors matches per-problem geometry
    solves."""
    _, _, U, fm, _, _ = fixture
    B, n, m = 3, 40, 36
    x, y = _batch_clouds(B, n, m, seed=13)
    R = 3.0     # shared bound so batch and single use identical features
    eng = BatchedSinkhorn(eps=EPS, method="log_factored", tol=1e-7,
                          max_iter=4000)
    res = eng.solve_point_clouds(x, y, U, R=R)
    for i in range(B):
        p = OTProblem.from_point_clouds(x[i], y[i], U, eps=EPS, R=R)
        single = solve(p, method="log_factored", tol=1e-7, max_iter=4000)
        np.testing.assert_allclose(float(res.cost[i]), float(single.cost),
                                   rtol=1e-5)


def test_momentum_threaded_or_rejected_for_every_method(fixture):
    """Over-relaxation regression: ``momentum != 1`` used to be silently
    DROPPED by the log-domain and accelerated runners. Now every method in
    METHODS either changes the iterate trajectory or raises a clear error
    naming momentum."""
    from repro.core.api import METHODS

    x, y, U, fm, xi, zeta = fixture
    feat_p = OTProblem.from_features(xi, zeta, eps=EPS)
    cloud_p = OTProblem.from_point_clouds(x, y, U, eps=EPS)
    from jax.sharding import Mesh

    mesh1 = Mesh(np.array(jax.devices()[:1]), ("data",))
    for method in METHODS:
        prob = cloud_p if method in ("arccos", "nystrom") else feat_p
        if method == "accelerated":
            with pytest.raises(ValueError, match="momentum"):
                solve(prob, method=method, momentum=1.3, rank=16)
            continue
        # fixed iteration count, compare raw trajectories; the sharded
        # methods now thread momentum through the same make_*_step blocks
        # (exercised here on a 1-device mesh)
        mesh = mesh1 if method.startswith("sharded") else None
        kw = dict(method=method, tol=0.0, max_iter=6, rank=16, mesh=mesh,
                  key=jax.random.PRNGKey(2))
        base = solve(prob, momentum=1.0, **kw)
        mom = solve(prob, momentum=1.3, **kw)
        diff = float(jnp.max(jnp.abs(mom.g - base.g)))
        assert np.isfinite(diff) and diff > 1e-7, (method, diff)


def test_batched_engine_momentum_changes_log_trajectory(fixture):
    """The vmapped engine threads momentum through the log runner too."""
    _, _, U, fm, _, _ = fixture
    B, n, m = 2, 32, 28
    x, y = _batch_clouds(B, n, m, seed=21)
    ka = jnp.stack([gaussian_log_features(x[i], U, eps=EPS, q=fm.q)
                    for i in range(B)])
    kb = jnp.stack([gaussian_log_features(y[i], U, eps=EPS, q=fm.q)
                    for i in range(B)])
    a = jnp.full((B, n), 1.0 / n)
    b = jnp.full((B, m), 1.0 / m)
    eng1 = BatchedSinkhorn(eps=EPS, method="log_factored", tol=0.0,
                           max_iter=5, momentum=1.0)
    eng2 = BatchedSinkhorn(eps=EPS, method="log_factored", tol=0.0,
                           max_iter=5, momentum=1.3)
    g1 = eng1.solve_stacked(ka, kb, a, b).g
    g2 = eng2.solve_stacked(ka, kb, a, b).g
    assert float(jnp.max(jnp.abs(g1 - g2))) > 1e-7


def test_solve_point_clouds_default_R_under_jit_raises(fixture):
    """float(data_radius(...)) on a tracer used to raise an opaque
    ConcretizationTypeError; now a clear 'pass R=' ValueError."""
    _, _, U, _, _, _ = fixture
    x, y = _batch_clouds(2, 16, 12, seed=3)
    eng = BatchedSinkhorn(eps=EPS, method="log_factored", tol=1e-5,
                          max_iter=200)
    with pytest.raises(ValueError, match="[Pp]ass R="):
        jax.jit(lambda x_, y_: eng.solve_point_clouds(x_, y_, U).cost)(x, y)
    # explicit R inside jit works
    cost = jax.jit(
        lambda x_, y_: eng.solve_point_clouds(x_, y_, U, R=4.0).cost
    )(x, y)
    assert np.all(np.isfinite(np.asarray(cost)))


def test_engine_rejects_bad_config():
    with pytest.raises(ValueError, match="batched engine supports"):
        BatchedSinkhorn(eps=0.5, method="sharded")
    with pytest.raises(ValueError, match="log domain"):
        BatchedSinkhorn(eps=0.5, method="factored",
                        schedule=EpsSchedule(eps_init=1.0))


# ---------------------------------------------------------------------------
# Bucket machinery (configs.shapes)
# ---------------------------------------------------------------------------


def test_ot_bucket_rounding():
    assert ot_bucket(1) == 64
    assert ot_bucket(64) == 64
    assert ot_bucket(65) == 128
    assert ot_bucket(1000) == 1024
    top = OT_SUPPORT_BUCKETS[-1]
    assert ot_bucket(top + 1) == 2 * top
    with pytest.raises(ValueError):
        ot_bucket(0)


def test_ot_batch_shape_groups():
    s1 = OTBatchShape.for_problem(60, 50, 128)
    s2 = OTBatchShape.for_problem(33, 64, 128)
    assert s1 == OTBatchShape(64, 64, 128) == s2
    assert OTBatchShape.for_problem(100, 50, 128) != s1


# ---------------------------------------------------------------------------
# Warm starts through solve_many (the serving re-serving path)
# ---------------------------------------------------------------------------


def _ragged_problems(fixture, sizes, seed=9):
    _, _, U, fm, _, _ = fixture
    probs = []
    for i, (n, m) in enumerate(sizes):
        kk = jax.random.fold_in(jax.random.PRNGKey(seed), i)
        x = jnp.clip(jax.random.normal(kk, (n, 2)), -2, 2)
        y = jnp.clip(jax.random.normal(jax.random.fold_in(kk, 1), (m, 2)),
                     -2, 2)
        probs.append(OTProblem.from_log_features(
            gaussian_log_features(x, U, eps=EPS, q=fm.q),
            gaussian_log_features(y, U, eps=EPS, q=fm.q), eps=EPS))
    return probs


def test_solve_many_warm_start_exact_and_fewer_iters(fixture):
    """Re-serving converged potentials must reproduce the cold solution
    (<= 1e-6 relative cost) while measurably cutting iterations."""
    probs = _ragged_problems(fixture, [(60, 50), (40, 70)])
    cold = solve_many(probs, method="log_factored", tol=1e-6, max_iter=2000)
    warm = solve_many(probs, method="log_factored", tol=1e-6, max_iter=2000,
                      f_inits=[o.f for o in cold],
                      g_inits=[o.g for o in cold])
    for c, w in zip(cold, warm):
        rel = abs(float(w.cost - c.cost)) / abs(float(c.cost))
        assert rel <= 1e-6, rel
        # potentials are defined up to an additive constant (f+c, g-c):
        # compare gauge-fixed
        wf, cf = np.asarray(w.f), np.asarray(c.f)
        np.testing.assert_allclose(wf - wf.mean(), cf - cf.mean(),
                                   rtol=1e-4, atol=1e-5)
        assert int(w.n_iter) < int(c.n_iter)


def test_solve_many_mixed_warm_cold_bucket_exact(fixture):
    """A bucket mixing warm and cold lanes (zero-padded inits for the cold
    ones) must stay elementwise-exact for BOTH classes."""
    probs = _ragged_problems(fixture, [(60, 50), (60, 50), (40, 70)],
                             seed=11)
    cold = solve_many(probs, method="log_factored", tol=1e-6, max_iter=2000)
    # warm only the first problem; second shares its bucket but cold-starts
    warm = solve_many(probs, method="log_factored", tol=1e-6, max_iter=2000,
                      f_inits=[cold[0].f, None, None],
                      g_inits=[cold[0].g, None, None])
    for i, (c, w) in enumerate(zip(cold, warm)):
        rel = abs(float(w.cost - c.cost)) / abs(float(c.cost))
        assert rel <= 1e-6, (i, rel)
    assert int(warm[0].n_iter) < int(cold[0].n_iter)
    assert int(warm[1].n_iter) == int(cold[1].n_iter)   # cold lane unchanged


def test_solve_many_warm_start_validation(fixture):
    probs = _ragged_problems(fixture, [(60, 50)], seed=12)
    cold = solve_many(probs, method="log_factored", tol=1e-7)
    with pytest.raises(ValueError, match="both f_inits and g_inits"):
        solve_many(probs, method="log_factored", f_inits=[cold[0].f])
    with pytest.raises(ValueError, match="must match problems"):
        solve_many(probs, method="log_factored",
                   f_inits=[cold[0].f, cold[0].f],
                   g_inits=[cold[0].g, cold[0].g])
    with pytest.raises(ValueError, match="both f_init and g_init"):
        solve_many(probs, method="log_factored",
                   f_inits=[cold[0].f], g_inits=[None])
    with pytest.raises(ValueError, match="warm starts"):
        solve_many(probs, method="log_factored", mesh=object(),
                   f_inits=[cold[0].f], g_inits=[cold[0].g])
