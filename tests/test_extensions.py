"""Accelerated solver (Remark 2 / App A.2) + factored-kernel barycenters."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gaussian_log_features, sinkhorn_log_factored
from repro.core.accelerated import accelerated_sinkhorn_log_factored
from repro.core.barycenter import barycenter_log_factored
from repro.core.features import GaussianFeatureMap


def _problem(seed=0, n=80, m=70, d=2, eps=0.5):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (n, d))
    y = 0.6 * jax.random.normal(k2, (m, d)) + 0.4
    fm = GaussianFeatureMap(r=256, d=d, eps=eps, R=3.5)
    U = fm.init(k3)
    lxi = gaussian_log_features(x, U, eps=eps, q=fm.q)
    lzt = gaussian_log_features(y, U, eps=eps, q=fm.q)
    a = jnp.full((n,), 1.0 / n)
    b = jnp.full((m,), 1.0 / m)
    return lxi, lzt, a, b, eps


def test_accelerated_matches_plain_cost():
    lxi, lzt, a, b, eps = _problem()
    plain = sinkhorn_log_factored(lxi, lzt, a, b, eps=eps, tol=1e-6,
                                  max_iter=5000)
    acc = accelerated_sinkhorn_log_factored(lxi, lzt, a, b, eps=eps,
                                            tol=1e-6, max_iter=5000)
    assert bool(acc.converged)
    np.testing.assert_allclose(float(acc.cost), float(plain.cost),
                               rtol=2e-3, atol=1e-4)


def test_accelerated_marginals_feasible():
    lxi, lzt, a, b, eps = _problem(seed=3)
    acc = accelerated_sinkhorn_log_factored(lxi, lzt, a, b, eps=eps,
                                            tol=1e-7, max_iter=5000)
    # column marginal of the induced plan
    t = jax.scipy.special.logsumexp(lxi + (acc.f / eps)[:, None], axis=0)
    lcol = jax.scipy.special.logsumexp(lzt + t[None, :], axis=1) + acc.g / eps
    np.testing.assert_allclose(np.asarray(jnp.exp(lcol)), np.asarray(b),
                               atol=1e-5)


def test_barycenter_k_invariance_and_validity():
    """The entropic barycenter of k identical copies of h is independent
    of k (it is the eps-blur of h, NOT h itself) and a valid histogram."""
    key = jax.random.PRNGKey(1)
    n, d, eps = 60, 2, 0.3
    pts = jax.random.normal(key, (n, d))
    fm = GaussianFeatureMap(r=512, d=d, eps=eps, R=3.0)
    U = fm.init(jax.random.fold_in(key, 1))
    lxi = gaussian_log_features(pts, U, eps=eps, q=fm.q)
    h = jax.random.uniform(jax.random.fold_in(key, 2), (n,)) + 0.2
    h = h / h.sum()
    r1 = barycenter_log_factored(lxi, h[None, :], eps=eps, tol=1e-9,
                                 max_iter=1000)
    r3 = barycenter_log_factored(lxi, jnp.stack([h, h, h]), eps=eps,
                                 tol=1e-9, max_iter=1000)
    assert bool(jnp.all(r1.weights >= 0)) and bool(jnp.all(r3.weights >= 0))
    np.testing.assert_allclose(float(jnp.sum(r3.weights)), 1.0, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(r1.weights),
                               np.asarray(r3.weights), atol=1e-4)


def test_barycenter_interpolates_between_corners():
    """Two opposite corner blobs on a 1-D grid -> barycenter mass sits
    BETWEEN them (entropic barycenters interpolate, unlike L2 averages)."""
    n, eps = 64, 0.1
    grid = jnp.linspace(-1, 1, n)[:, None]
    fm = GaussianFeatureMap(r=256, d=1, eps=eps, R=1.5)
    U = fm.init(jax.random.PRNGKey(5))
    lxi = gaussian_log_features(grid, U, eps=eps, q=fm.q)
    blob = lambda c: jax.nn.softmax(-((grid[:, 0] - c) ** 2) / 0.005)
    res = barycenter_log_factored(
        lxi, jnp.stack([blob(-0.8), blob(0.8)]), eps=eps, max_iter=1000)
    com = float(jnp.sum(res.weights * grid[:, 0]))
    spread = float(jnp.sum(res.weights * jnp.abs(grid[:, 0])))
    assert abs(com) < 0.15, com             # centered between corners
    # mass should NOT just stay at the corners (bimodal L2 average)
    mid_mass = float(jnp.sum(jnp.where(jnp.abs(grid[:, 0]) < 0.4,
                                       res.weights, 0.0)))
    assert mid_mass > 0.3, (mid_mass, spread)
