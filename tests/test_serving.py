"""Serving layer: bucket/batch-shape edge cases, fingerprinting, the
admission policy under a fake clock, runner-cache zero-recompile + LRU,
the engine LRU, and OTService end-to-end vs the one-shot solver."""
import subprocess
import sys

import numpy as np
import pytest

from repro.configs.shapes import (
    OT_SUPPORT_BUCKETS,
    OTBatchShape,
    ot_batch_bucket,
    ot_bucket,
)
from repro.core import (
    OTProblem,
    clear_engine_cache,
    engine_cache_info,
    get_engine,
    set_engine_cache_capacity,
    solve,
)
from repro.serving import (
    AdmissionQueue,
    OTService,
    WarmStartCache,
    fingerprint,
    request_keys,
)

EPS = 0.6


def _problem(n, m, r=8, seed=0, eps=EPS):
    rng = np.random.default_rng(seed)
    xi = np.asarray(rng.uniform(0.05, 1.05, (n, r)), np.float32)
    zeta = np.asarray(rng.uniform(0.05, 1.05, (m, r)), np.float32)
    a = np.asarray(rng.dirichlet(np.full(n, 2.0)), np.float32)
    b = np.asarray(rng.dirichlet(np.full(m, 2.0)), np.float32)
    a, b = a / a.sum(), b / b.sum()
    return OTProblem.from_features(xi, zeta, a, b, eps=eps)


# -- bucket edge cases --------------------------------------------------------


def test_ot_bucket_edges():
    top = OT_SUPPORT_BUCKETS[-1]
    assert ot_bucket(1) == OT_SUPPORT_BUCKETS[0]
    assert ot_bucket(top) == top
    # above the top bucket: round UP to a multiple of the top bucket,
    # never truncate
    assert ot_bucket(top + 1) == 2 * top
    assert ot_bucket(3 * top - 5) == 3 * top
    with pytest.raises(ValueError):
        ot_bucket(0)


def test_ot_batch_bucket():
    assert [ot_batch_bucket(b, 8) for b in (1, 2, 3, 4, 5, 8)] == \
        [1, 2, 4, 4, 8, 8]
    assert ot_batch_bucket(7, 4) == 4          # capped at max_batch
    assert ot_batch_bucket(1, 1) == 1
    with pytest.raises(ValueError):
        ot_batch_bucket(0, 8)


def test_batch_shape_grouping():
    # ragged sizes inside one bucket share a cell; r and quadratic differ
    s1 = OTBatchShape.for_problem(40, 56, 8)
    s2 = OTBatchShape.for_problem(61, 33, 8)
    assert s1 == s2
    assert OTBatchShape.for_problem(40, 56, 16) != s1
    assert OTBatchShape.for_problem(65, 56, 8) != s1   # crosses a bucket
    q = OTBatchShape.for_quadratic(40, 56)
    assert q.r == 0 and q != s1


# -- fingerprinting -----------------------------------------------------------


def test_fingerprint_quantization():
    rng = np.random.default_rng(0)
    x = np.asarray(rng.uniform(0.0, 1.0, (32, 4)), np.float32)
    base = fingerprint([x], quant=1e-4)
    # sub-quant jitter hashes identically (float fuzz is absorbed) ...
    assert fingerprint([x + 1e-6], quant=1e-4) == base
    # ... while a change of many quanta does not
    assert fingerprint([x + 1e-2], quant=1e-4) != base
    # shape is part of the identity, even with identical bytes
    assert fingerprint([x.reshape(4, 32)], quant=1e-4) != base


def test_fingerprint_nonfinite_stable():
    x = np.array([np.inf, -np.inf, np.nan, 1.0], np.float32)
    assert fingerprint([x]) == fingerprint([x.copy()])
    assert fingerprint([x]) != fingerprint([np.ones(4, np.float32)])


def test_request_keys_two_level():
    rng = np.random.default_rng(1)
    ka = np.asarray(rng.uniform(size=(16, 4)), np.float32)
    kb = np.asarray(rng.uniform(size=(12, 4)), np.float32)
    a = np.full(16, 1 / 16, np.float32)
    b = np.full(12, 1 / 12, np.float32)
    sk, fk = request_keys(ka, kb, a, b)
    # same supports, re-jittered weights: support key holds, full differs
    a2 = a * np.asarray(rng.uniform(0.9, 1.1, 16), np.float32)
    a2 /= a2.sum()
    sk2, fk2 = request_keys(ka, kb, a2, b)
    assert sk2 == sk and fk2 != fk
    # different supports: both differ
    sk3, fk3 = request_keys(ka + 0.5, kb, a, b)
    assert sk3 != sk and fk3 != fk


def test_warmstart_cache_exact_near_lru():
    cache = WarmStartCache(capacity=2)
    f, g = np.ones(4, np.float32), np.ones(3, np.float32)
    cache.store(b"s1", b"f1", f, g)
    hit = cache.lookup(b"s1", b"f1")
    assert hit is not None and hit.exact
    np.testing.assert_array_equal(hit.f, f)
    near = cache.lookup(b"s1", b"f-other")      # same supports, new weights
    assert near is not None and not near.exact
    assert cache.lookup(b"s2", b"f1") is None
    cache.store(b"s2", b"f2", f, g)
    cache.store(b"s3", b"f3", f, g)             # evicts s1 (capacity 2)
    assert cache.lookup(b"s1", b"f1") is None
    assert cache.lookup(b"s3", b"f3").exact
    snap = cache.snapshot()
    assert snap["evictions"] == 1 and snap["size"] == 2


# -- admission policy (fake clock) -------------------------------------------


def test_admission_max_batch_flush_chunks():
    q = AdmissionQueue(max_batch=2, max_wait=10.0)
    for i in range(5):
        q.add("cell", i, now=0.0)
    due = q.pop_due(now=0.0)
    # two full chunks flush immediately; the remainder is younger than
    # max_wait and stays queued
    assert [items for _, items in due] == [[0, 1], [2, 3]]
    assert len(q) == 1
    assert q.pop_due(now=5.0) == []
    # ... until its oldest arrival ages past the deadline
    assert q.pop_due(now=10.0) == [("cell", [4])]
    assert len(q) == 0
    assert q.flushed_full == 2 and q.flushed_aged == 1


def test_admission_order_and_force():
    q = AdmissionQueue(max_batch=4, max_wait=1.0)
    q.add("a", "a0", now=0.0)
    q.add("b", "b0", now=0.1)
    q.add("a", "a1", now=0.2)
    assert q.next_deadline() == pytest.approx(1.0)
    due = q.pop_due(now=0.5, force=True)
    assert dict(due) == {"a": ["a0", "a1"], "b": ["b0"]}
    assert q.next_deadline() is None


def test_admission_validation():
    with pytest.raises(ValueError):
        AdmissionQueue(max_batch=0)
    with pytest.raises(ValueError):
        AdmissionQueue(max_wait=-1.0)


# -- engine LRU ---------------------------------------------------------------


def test_engine_cache_lru_eviction():
    clear_engine_cache()
    old_cap = engine_cache_info()["capacity"]
    try:
        set_engine_cache_capacity(2)
        e1 = get_engine(eps=0.5, tol=1e-4)
        assert get_engine(eps=0.5, tol=1e-4) is e1       # hit
        get_engine(eps=0.6, tol=1e-4)
        get_engine(eps=0.5, tol=1e-4)                    # refresh e1
        get_engine(eps=0.7, tol=1e-4)                    # evicts eps=0.6
        info = engine_cache_info()
        assert info["size"] == 2 and info["evictions"] == 1
        assert get_engine(eps=0.5, tol=1e-4) is e1       # survived (MRU)
        assert get_engine(eps=0.6, tol=1e-4) is not e1   # rebuilt (miss)
    finally:
        clear_engine_cache()
        set_engine_cache_capacity(old_cap)


# -- service end-to-end (one compiled cell, module-scoped) --------------------


@pytest.fixture(scope="module")
def service():
    svc = OTService(eps=EPS, method="log_factored", tol=1e-6,
                    max_batch=2, max_wait=0.001)
    svc.warmup([(40, 56, 8)])        # one cell: (64, 64, 8) x B in {1, 2}
    return svc


@pytest.mark.slow
def test_service_matches_oracle_and_preserves_order(service):
    probs = [_problem(40, 56, seed=s) for s in (0, 1, 2)] + \
        [_problem(33, 61, seed=3)]               # ragged, same bucket cell
    results = service.solve_many(probs)
    for p, res in zip(probs, results):
        assert res.f.shape == (p.a.shape[0],)    # unpadded to request size
        assert res.g.shape == (p.b.shape[0],)
        ref = solve(p, method="log_factored", tol=1e-6)
        rel = abs(float(res.cost) - float(ref.cost)) / abs(float(ref.cost))
        assert rel < 1e-5
    # all four solved within the pre-planned runners: no new compiles
    snap = service.runners.snapshot()
    assert snap["misses"] == 2 and snap["extra_traces"] == 0


@pytest.mark.slow
def test_service_warm_start_exact_and_faster(service):
    p = _problem(40, 56, seed=10)
    cold = service.solve_many([p])[0]
    t = service.submit(p)
    service.drain()
    warm = t.result
    assert t.warm_hit and t.warm_exact
    # repeat request re-served from cached potentials: equal to the cold
    # solve (well under solver tol) in fewer iterations
    np.testing.assert_allclose(np.asarray(warm.f), np.asarray(cold.f),
                               rtol=1e-6, atol=1e-6)
    assert abs(float(warm.cost) - float(cold.cost)) <= \
        1e-6 * abs(float(cold.cost))
    assert int(warm.n_iter) < int(cold.n_iter)
    # near-repeat: same supports, new weights -> non-exact hit, still
    # correct vs the oracle
    a2 = np.asarray(p.a) * np.asarray(
        np.random.default_rng(5).uniform(0.9, 1.1, p.a.shape[0]), np.float32)
    a2 /= a2.sum()
    p2 = OTProblem(geometry=p.geometry, a=a2, b=p.b)
    t2 = service.submit(p2)
    service.drain()
    assert t2.warm_hit and not t2.warm_exact
    ref2 = solve(p2, method="log_factored", tol=1e-6)
    assert abs(float(t2.result.cost) - float(ref2.cost)) < \
        1e-5 * abs(float(ref2.cost))


@pytest.mark.slow
def test_service_zero_recompiles_after_warmup(service):
    snap0 = service.runners.snapshot()
    for s in (20, 21, 22):
        service.solve_many([_problem(40, 56, seed=s)])
    snap1 = service.runners.snapshot()
    assert snap1["misses"] == snap0["misses"]
    assert snap1["extra_traces"] == 0


@pytest.mark.slow
def test_service_rejects_wrong_eps(service):
    with pytest.raises(ValueError, match="eps"):
        service.submit(_problem(40, 56, eps=EPS / 2))


@pytest.mark.slow
def test_service_max_wait_holds_then_flushes(service):
    fake = [100.0]
    real_clock = service.clock
    service.clock = lambda: fake[0]
    try:
        t = service.submit(_problem(40, 56, seed=30))
        # younger than max_wait: nothing dispatches
        assert service.pump() == 0 and not t.done
        fake[0] += 0.002                         # past max_wait (0.001)
        assert service.pump() == 1 and t.done
        assert t.latency == pytest.approx(0.002)
    finally:
        service.clock = real_clock


@pytest.mark.slow
def test_serve_driver_smoke():
    # the LM serving driver: prefill/decode timings split, no crash
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "smollm-135m", "--tiny", "--batch", "2", "--prompt-len", "4",
         "--gen", "2"],
        capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "prefill:" in out.stdout and "decode:" in out.stdout
