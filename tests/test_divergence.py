"""Sinkhorn divergence properties — property tests via hypothesis when it is
installed, falling back to a seeded parametrization on clean environments
(tier-1 must collect and run without optional deps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sinkhorn_divergence_gaussian
from repro.core.features import GaussianFeatureMap

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False


def property_cases(fallback, max_examples, **strategies):
    """``@given(**strategies)`` when hypothesis is available; otherwise a
    deterministic ``@pytest.mark.parametrize`` over the seeded ``fallback``
    cases (each a dict of the same argument names)."""
    if HAVE_HYPOTHESIS:

        def deco(fn):
            return settings(max_examples=max_examples, deadline=None)(
                given(**{k: st.sampled_from(v) if isinstance(v, (list, tuple))
                         else v for k, v in strategies.items()})(fn)
            )

        return deco

    names = sorted(fallback[0].keys())
    if len(names) == 1:
        values = [case[names[0]] for case in fallback]
    else:
        values = [tuple(case[k] for k in names) for case in fallback]

    def deco(fn):
        return pytest.mark.parametrize(",".join(names), values)(fn)

    return deco


def _clouds(seed, n, m, d=2, scale=1.0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jnp.clip(jax.random.normal(k1, (n, d)), -2, 2)
    y = jnp.clip(scale * jax.random.normal(k2, (m, d)) + 0.5, -2, 2)
    return x, y


def _anchors(eps, d=2, r=256, seed=0):
    fm = GaussianFeatureMap(r=r, d=d, eps=eps, R=3.0)
    return fm.init(jax.random.PRNGKey(seed)), fm.q


def test_self_divergence_zero():
    x, _ = _clouds(0, 50, 50)
    U, q = _anchors(0.5)
    div = sinkhorn_divergence_gaussian(x, x, U, eps=0.5, q=q, tol=1e-8,
                                       max_iter=5000)
    assert abs(float(div)) < 1e-4


def test_symmetry():
    x, y = _clouds(1, 40, 60)
    U, q = _anchors(0.5, seed=2)
    d1 = sinkhorn_divergence_gaussian(x, y, U, eps=0.5, q=q, tol=1e-8,
                                      max_iter=5000)
    d2 = sinkhorn_divergence_gaussian(y, x, U, eps=0.5, q=q, tol=1e-8,
                                      max_iter=5000)
    np.testing.assert_allclose(float(d1), float(d2), rtol=1e-4, atol=1e-6)


def test_separates_distributions():
    x, y = _clouds(2, 60, 60, scale=0.3)
    U, q = _anchors(0.5, seed=3)
    d_xy = sinkhorn_divergence_gaussian(x, y, U, eps=0.5, q=q, tol=1e-8,
                                        max_iter=5000)
    assert float(d_xy) > 1e-3


@property_cases(
    fallback=[
        dict(seed=0, n=10, m=60, eps=0.3),
        dict(seed=271, n=33, m=21, eps=0.5),
        dict(seed=542, n=57, m=44, eps=1.0),
        dict(seed=813, n=24, m=12, eps=0.5),
    ],
    max_examples=10,
    seed=st.integers(0, 1000) if HAVE_HYPOTHESIS else None,
    n=st.integers(10, 60) if HAVE_HYPOTHESIS else None,
    m=st.integers(10, 60) if HAVE_HYPOTHESIS else None,
    eps=[0.3, 0.5, 1.0],
)
def test_property_nonnegative_and_finite(seed, n, m, eps):
    """Wbar >= -tol and finite for arbitrary bounded clouds (the paper's
    positivity-by-design claim: any r, any draw, Sinkhorn converges)."""
    x, y = _clouds(seed, n, m)
    U, q = _anchors(eps, seed=seed)
    div = sinkhorn_divergence_gaussian(x, y, U, eps=eps, q=q, tol=1e-7,
                                       max_iter=4000)
    assert np.isfinite(float(div))
    assert float(div) > -1e-3


@property_cases(
    fallback=[
        dict(seed=7, r=16),
        dict(seed=389, r=64),
        dict(seed=771, r=256),
    ],
    max_examples=10,
    seed=st.integers(0, 1000) if HAVE_HYPOTHESIS else None,
    r=[16, 64, 256],
)
def test_property_any_feature_count_converges(seed, r):
    """Theorem 3.1 note: unlike Nystrom, ANY r yields a convergent solve."""
    x, y = _clouds(seed, 30, 30)
    fm = GaussianFeatureMap(r=r, d=2, eps=0.5, R=3.0)
    U = fm.init(jax.random.PRNGKey(seed + 1))
    div = sinkhorn_divergence_gaussian(x, y, U, eps=0.5, q=fm.q, tol=1e-6,
                                       max_iter=4000)
    assert np.isfinite(float(div))


@property_cases(
    fallback=[dict(seed=3), dict(seed=41), dict(seed=88)],
    max_examples=8,
    seed=st.integers(0, 100) if HAVE_HYPOTHESIS else None,
)
def test_property_triangle_like_separation(seed):
    """Wbar(x,y) should dominate Wbar(x,x') for x' a tiny jitter of x."""
    x, y = _clouds(seed, 40, 40, scale=0.2)
    U, q = _anchors(0.5, seed=seed)
    jitter = x + 0.01 * jax.random.normal(jax.random.PRNGKey(seed), x.shape)
    d_far = sinkhorn_divergence_gaussian(x, y, U, eps=0.5, q=q, tol=1e-7,
                                         max_iter=4000)
    d_near = sinkhorn_divergence_gaussian(x, jitter, U, eps=0.5, q=q,
                                          tol=1e-7, max_iter=4000)
    assert float(d_near) < float(d_far)
