"""Megakernel / cadence / mixed-precision coverage.

Contracts under test (the ISSUE-5 acceptance bar):

* the persistent multi-iteration block step (``kernels.fused_loop`` via
  ``GeometryOps.make_block_step``) matches ``inner_steps`` unfused plan
  steps ELEMENTWISE at block boundaries — factored + gaussian, scaling +
  log, with momentum, warm starts and ot_bucket-style zero-weight padding;
* the ``inner_steps`` / ``check_every`` cadence invariance matrix: final
  cost/potentials match the ``check_every=1`` solve to <= 1e-6 rel across
  families and modes, and iteration counts are exact multiples of the
  cadence;
* the bf16 mixed-precision policy stays within documented parity bounds of
  fp32 and actually stores the factors in bfloat16;
* the refusal surfaces: sharded solves reject ``inner_steps``, accelerated
  rejects it too, mis-aligned cadences raise, unknown precisions raise.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BatchedSinkhorn, OTProblem, solve
from repro.core.geometry import (
    ArcCosinePointCloud,
    FactoredPositive,
    GaussianPointCloud,
)
from repro.kernels import fused_loop
from repro.kernels.ops import geometry_ops

KEY = jax.random.PRNGKey(0)


def _factored(n=96, m=80, r=17, eps=0.5, dead=0):
    xi = jax.random.uniform(KEY, (n, r)) + 0.05
    zt = jax.random.uniform(jax.random.fold_in(KEY, 1), (m, r)) + 0.05
    a = jnp.full((n,), 1.0 / n)
    if dead:
        a = a.at[-dead:].set(0.0)
        a = a / a.sum()
    b = jnp.full((m,), 1.0 / m)
    return FactoredPositive(xi=xi, zeta=zt, eps=eps), a, b


def _gaussian(n=60, m=70, r=33, eps=0.4):
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (n, 2))
    y = jax.random.normal(jax.random.fold_in(KEY, 3), (m, 2)) * 0.7
    anchors = jax.random.normal(jax.random.fold_in(KEY, 4), (r, 2)) * 0.5
    a = jnp.full((n,), 1.0 / n)
    b = jnp.full((m,), 1.0 / m)
    return GaussianPointCloud.build(x, y, anchors, eps=eps), a, b


def _arccos(n=50, m=55, r=21, eps=0.5):
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (n, 2))
    y = jax.random.normal(jax.random.fold_in(KEY, 6), (m, 2)) * 0.8
    anchors = 1.5 * jax.random.normal(jax.random.fold_in(KEY, 7), (r, 2))
    a = jnp.full((n,), 1.0 / n)
    b = jnp.full((m,), 1.0 / m)
    return ArcCosinePointCloud(x, y, anchors, eps=eps), a, b


GEOMS = {"factored": _factored, "gaussian": _gaussian, "arccos": _arccos}


# ---------------------------------------------------------------------------
# Block step == inner_steps unfused plan steps (elementwise at boundaries)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["factored", "gaussian"])
@pytest.mark.parametrize("mode", ["scaling", "log"])
@pytest.mark.parametrize("momentum", [1.0, 1.3])
def test_block_step_matches_unfused(family, mode, momentum):
    geom, a, b = GEOMS[family]()
    # zero-weight atoms on the factored case exercise the masked relax
    if family == "factored":
        geom, a, b = _factored(dead=3)
    plan = geometry_ops(geom, backend="interpret", mode=mode)
    inner = 4
    step, init = plan.make_step(a, b, momentum=momentum)
    block = plan.make_block_step(a, b, inner_steps=inner, momentum=momentum)
    assert block is not None
    bstep, binit = block
    n, m = a.shape[0], b.shape[0]
    if mode == "scaling":
        z0 = (jnp.ones((n,)) * jnp.where(a > 0, 1.0, 0.0), jnp.ones((m,)))
    else:
        z0 = (jnp.where(a > 0, 0.0, -jnp.inf), jnp.zeros((m,)))
    carry = init(*z0)
    for _ in range(inner):
        carry, err = step(carry)
    bcarry, berr = bstep(binit(*z0))
    for ref, got in zip(carry, bcarry):
        finite = jnp.isfinite(ref)
        assert bool(jnp.all(finite == jnp.isfinite(got)))
        np.testing.assert_allclose(
            np.where(np.asarray(finite), np.asarray(ref), 0.0),
            np.where(np.asarray(finite), np.asarray(got), 0.0),
            rtol=2e-6, atol=2e-6,
        )
    # the block-boundary error agrees with the per-iteration error up to
    # f32 reduction-order noise
    np.testing.assert_allclose(float(err), float(berr), rtol=1e-3,
                               atol=1e-7)


def test_block_step_warm_start_boundary():
    """A SECOND block continues exactly where the first stopped — the
    megakernel carry round-trips through HBM unchanged."""
    geom, a, b = _factored()
    plan = geometry_ops(geom, backend="interpret", mode="scaling")
    step, init = plan.make_step(a, b)
    bstep, binit = plan.make_block_step(a, b, inner_steps=3)
    carry = init(jnp.ones_like(a), jnp.ones_like(b))
    for _ in range(6):
        carry, _ = step(carry)
    bcarry = binit(jnp.ones_like(a), jnp.ones_like(b))
    for _ in range(2):
        bcarry, _ = bstep(bcarry)
    for ref, got in zip(carry, bcarry):
        np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                                   rtol=2e-6, atol=2e-6)


# ---------------------------------------------------------------------------
# Cadence invariance matrix (solve surface)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family,method", [
    ("factored", "factored"),
    ("factored", "log_factored"),
    ("gaussian", "log_factored"),
    ("gaussian", "factored"),
    ("arccos", "log_factored"),
])
@pytest.mark.parametrize("knobs", [
    dict(use_pallas=True, inner_steps=4),
    dict(use_pallas=False, check_every=4),
    dict(use_pallas=False, inner_steps=4),   # degrades to the cadence
])
def test_cadence_invariance(family, method, knobs):
    geom, a, b = GEOMS[family]()
    p = OTProblem.from_geometry(geom, a, b)
    ref = solve(p, method=method, tol=1e-6, use_pallas=False)
    res = solve(p, method=method, tol=1e-6, **knobs)
    assert int(res.n_iter) % 4 == 0
    assert int(res.n_iter) >= int(ref.n_iter)
    assert bool(res.converged)
    rel = abs(float(res.cost - ref.cost)) / max(abs(float(ref.cost)), 1e-12)
    assert rel <= 1e-6, rel
    live = np.asarray(a) > 0
    np.testing.assert_allclose(np.asarray(res.f)[live],
                               np.asarray(ref.f)[live],
                               rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("method", ["factored", "log_factored"])
def test_cadence_with_momentum_and_warm_start(method):
    geom, a, b = _factored(eps=0.3)
    p = OTProblem.from_geometry(geom, a, b)
    ref = solve(p, method=method, tol=1e-6, momentum=1.4)
    warm = solve(p, method=method, tol=1e-2)
    res = solve(p, method=method, tol=1e-6, momentum=1.4,
                use_pallas=True, inner_steps=2, check_every=4)
    assert int(res.n_iter) % 4 == 0
    rel = abs(float(res.cost - ref.cost)) / abs(float(ref.cost))
    assert rel <= 1e-6, rel
    # warm-started run through the megakernel: the solver entry points
    # accept f_init via the stage machinery — exercise through
    # sinkhorn_log_geometry directly
    from repro.core.sinkhorn import sinkhorn_log_geometry
    res_w = sinkhorn_log_geometry(geom, a, b, tol=1e-6,
                                  f_init=warm.f, g_init=warm.g,
                                  use_pallas=True, inner_steps=4)
    assert int(res_w.n_iter) % 4 == 0
    rel = abs(float(res_w.cost - ref.cost)) / abs(float(ref.cost))
    assert rel <= 1e-6, rel


def test_cadence_with_zero_weight_padding():
    """ot_bucket-style padding: dead atoms with zero weight stay inert
    through the megakernel (scaling AND log), matching the unpadded solve
    elementwise on live atoms."""
    geom, a, b = _factored(n=90, m=90, r=9, eps=0.5)
    n_pad = 128
    xi_p = jnp.concatenate(
        [geom.xi, jnp.broadcast_to(geom.xi[-1:], (n_pad - 90, 9))])
    zt_p = jnp.concatenate(
        [geom.zeta, jnp.broadcast_to(geom.zeta[-1:], (n_pad - 90, 9))])
    a_p = jnp.concatenate([a, jnp.zeros((n_pad - 90,))])
    b_p = jnp.concatenate([b, jnp.zeros((n_pad - 90,))])
    pp = OTProblem.from_features(xi_p, zt_p, a_p, b_p, eps=0.5)
    p = OTProblem.from_geometry(geom, a, b)
    for method in ("factored", "log_factored"):
        ref = solve(p, method=method, tol=1e-6)
        res = solve(pp, method=method, tol=1e-6, use_pallas=True,
                    inner_steps=4)
        pad_ref = solve(pp, method=method, tol=1e-6, use_pallas=False)
        assert bool(res.converged)
        # megakernel == unfused XLA path on the SAME padded problem,
        # elementwise on live atoms (the fused-vs-unfused contract)
        np.testing.assert_allclose(np.asarray(res.f)[:90],
                                   np.asarray(pad_ref.f)[:90],
                                   rtol=1e-4, atol=1e-5)
        # padded vs unpadded agree on the (normalization-free) cost: the
        # scaling path starts dead atoms at u0 = 1 — they pin to 0 after
        # one update, so the transient (and the dual's free constant)
        # differ while the optimum does not; the log path pins f0 = -inf
        # from iteration 0 and matches elementwise too
        rel = abs(float(res.cost - ref.cost)) / abs(float(ref.cost))
        assert rel <= 1e-5, rel
        if method == "factored":
            assert np.all(np.asarray(res.u)[90:] == 0.0)
        else:
            assert np.all(np.asarray(res.f)[90:] == -np.inf)
            np.testing.assert_allclose(np.asarray(res.f)[:90],
                                       np.asarray(ref.f),
                                       rtol=1e-4, atol=1e-5)


def test_annealed_cadence():
    from repro.core import EpsSchedule
    geom, a, b = _gaussian(eps=0.05)
    p = OTProblem.from_geometry(geom, a, b)
    sched = EpsSchedule(eps_init=1.0, decay=0.5)
    ref = solve(p, schedule=sched, tol=1e-5)
    res = solve(p, schedule=sched, tol=1e-5, check_every=4)
    assert bool(res.converged)
    rel = abs(float(res.cost - ref.cost)) / max(abs(float(ref.cost)), 1e-12)
    assert rel <= 1e-5, rel


# ---------------------------------------------------------------------------
# Mixed-precision policy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family,method", [
    ("factored", "factored"),
    ("factored", "log_factored"),
    ("gaussian", "log_factored"),
])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_bf16_policy_parity(family, method, use_pallas):
    geom, a, b = GEOMS[family]()
    p = OTProblem.from_geometry(geom, a, b)
    ref = solve(p, method=method, tol=1e-5)
    res = solve(p, method=method, tol=1e-5, precision="bf16",
                use_pallas=use_pallas)
    assert bool(res.converged)
    # bf16 stores ~3 significant decimal digits: the fixed point moves by
    # the feature rounding, not by accumulation error (stays f32)
    rel = abs(float(res.cost - ref.cost)) / max(abs(float(ref.cost)), 1e-12)
    assert rel <= 5e-3, rel
    np.testing.assert_allclose(np.asarray(res.f), np.asarray(ref.f),
                               rtol=0.1, atol=5e-2)


def test_bf16_storage_dtype():
    geom, a, b = _factored()
    plan = geometry_ops(geom, backend="interpret", mode="scaling",
                        precision="bf16")
    assert plan.features[0].dtype == jnp.bfloat16
    assert plan.precision == "bf16"
    plan32 = geometry_ops(geom, backend="interpret", mode="scaling")
    assert plan32.features[0].dtype == jnp.float32
    # the XLA operator path stores bf16 too but accumulates/returns f32 —
    # even for a WEAK-typed operand, which dtype promotion alone would
    # silently demote to a bf16 contraction
    mv, _ = geom.operators(precision="bf16")
    out = mv(jnp.ones_like(b))
    assert out.dtype == jnp.float32 and not out.weak_type


def test_bf16_megakernel_block():
    geom, a, b = _factored()
    plan = geometry_ops(geom, backend="interpret", mode="scaling",
                        precision="bf16")
    bstep, binit = plan.make_block_step(a, b, inner_steps=4)
    step, init = plan.make_step(a, b)
    carry = init(jnp.ones_like(a), jnp.ones_like(b))
    for _ in range(4):
        carry, _ = step(carry)
    bcarry, _ = bstep(binit(jnp.ones_like(a), jnp.ones_like(b)))
    np.testing.assert_allclose(np.asarray(carry[0]), np.asarray(bcarry[0]),
                               rtol=2e-6, atol=2e-6)


# ---------------------------------------------------------------------------
# Budget + refusal surfaces
# ---------------------------------------------------------------------------


def test_vmem_budget_policy():
    # the compiled budget refuses what real VMEM cannot hold; interpret
    # mode (CI/bench) gets headroom
    assert fused_loop.block_plan_fits(4096, 4096, 256, 1,
                                      jnp.float32, interpret=False)
    assert not fused_loop.block_plan_fits(16384, 16384, 1024, 1,
                                          jnp.float32, interpret=False)
    assert fused_loop.block_plan_fits(16384, 16384, 1024, 1,
                                      jnp.float32, interpret=True)
    # bf16 halves the factor bytes — shapes near the boundary fit again
    assert fused_loop.block_vmem_bytes(8192, 8192, 128, 1, jnp.bfloat16) \
        < fused_loop.block_vmem_bytes(8192, 8192, 128, 1, jnp.float32)


def test_misaligned_cadence_raises():
    geom, a, b = _factored()
    p = OTProblem.from_geometry(geom, a, b)
    with pytest.raises(ValueError, match="multiple of inner_steps"):
        solve(p, method="factored", inner_steps=4, check_every=6,
              use_pallas=True)
    with pytest.raises(ValueError, match="inner_steps must be >= 1"):
        solve(p, method="factored", inner_steps=0)
    with pytest.raises(ValueError, match="unknown precision"):
        solve(p, method="factored", precision="fp8")


def test_accelerated_refuses_block():
    geom, a, b = _factored()
    p = OTProblem.from_geometry(geom, a, b)
    with pytest.raises(ValueError, match="not available"):
        solve(p, method="accelerated", inner_steps=4)
    # check_every alone is supported
    ref = solve(p, method="accelerated", tol=1e-5)
    res = solve(p, method="accelerated", tol=1e-5, check_every=3)
    assert int(res.n_iter) % 3 == 0
    rel = abs(float(res.cost - ref.cost)) / abs(float(ref.cost))
    assert rel <= 1e-5, rel


def test_sharded_refuses_block_honors_cadence():
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    geom, a, b = _factored(n=64, m=64)
    p = OTProblem.from_geometry(geom, a, b)
    with pytest.raises(ValueError, match="megakernel"):
        solve(p, mesh=mesh, inner_steps=4)
    from repro.core import solve_many
    with pytest.raises(ValueError, match="megakernel"):
        solve_many([p], method="factored", mesh=mesh, inner_steps=4)
    ref = solve(p, method="factored", tol=1e-6)
    res = solve(p, mesh=mesh, method="factored", tol=1e-6, check_every=2)
    assert int(res.n_iter) % 2 == 0
    rel = abs(float(res.cost - ref.cost)) / abs(float(ref.cost))
    assert rel <= 1e-6, rel


# ---------------------------------------------------------------------------
# Batched engine: knobs + donated warm starts
# ---------------------------------------------------------------------------


def test_batched_engine_inner_steps():
    geom, a, b = _factored(n=64, m=64, r=8)
    ka = jnp.stack([geom.xi, geom.xi * 1.1])
    kb = jnp.stack([geom.zeta, geom.zeta])
    aw = jnp.stack([a, a])
    bw = jnp.stack([b, b])
    ref = BatchedSinkhorn(eps=0.5, method="factored", tol=1e-6) \
        .solve_stacked(ka, kb, aw, bw)
    eng = BatchedSinkhorn(eps=0.5, method="factored", tol=1e-6,
                          use_pallas=True, inner_steps=2)
    res = eng.solve_stacked(ka, kb, aw, bw)
    assert np.all(np.asarray(res.n_iter) % 2 == 0)
    np.testing.assert_allclose(np.asarray(res.cost), np.asarray(ref.cost),
                               rtol=1e-6)


def test_batched_warm_start_donates():
    geom, a, b = _factored(n=64, m=64, r=8)
    ka = jnp.stack([geom.xi, geom.xi])
    kb = jnp.stack([geom.zeta, geom.zeta])
    aw = jnp.stack([a, a])
    bw = jnp.stack([b, b])
    eng = BatchedSinkhorn(eps=0.5, method="log_factored", tol=1e-6)
    cold = eng.solve_stacked(ka, kb, aw, bw)
    f0, g0 = cold.f, cold.g
    warm = eng.solve_stacked(ka, kb, aw, bw, f_init=f0, g_init=g0)
    np.testing.assert_allclose(np.asarray(warm.cost),
                               np.asarray(cold.cost), rtol=1e-6)
    # a warm start at the fixed point converges in the minimum one check
    assert np.all(np.asarray(warm.n_iter) <= np.asarray(cold.n_iter))
    # the donated buffers are invalidated on backends that support
    # donation; either way the handles must not be silently reused
    with pytest.raises(ValueError, match="donates the pair"):
        eng.solve_stacked(ka, kb, aw, bw, f_init=cold.f)
