# NOTE: no XLA_FLAGS / device-count overrides here — smoke tests and
# benches must see the 1 real CPU device. Multi-device distributed tests
# spawn subprocesses that set --xla_force_host_platform_device_count
# themselves (tests/test_distributed.py).
import jax
import pytest

jax.config.update("jax_enable_x64", False)

# Modules dominated by many-iteration solver convergence runs (minutes on
# CPU). Everything else is a fast smoke/unit module (seconds). The split
# lets `pytest -m fast` gate a quick inner loop while the tier-1 command
# (plain `pytest -x -q`) still runs everything.
_SLOW_MODULES = {
    "test_api",
    "test_distributed",
    "test_divergence",
    "test_schedule",
    "test_sharded",
    "test_sinkhorn",
    "test_system",
}


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "fast: quick unit/smoke test (seconds on CPU); "
        "run the fast gate with `pytest -m fast`"
    )
    config.addinivalue_line(
        "markers", "slow: convergence-heavy test (minutes on CPU); "
        "deselect with `pytest -m 'not slow'`"
    )


def pytest_collection_modifyitems(config, items):
    for item in items:
        already = {m.name for m in item.iter_markers()} & {"fast", "slow"}
        if already:
            continue
        name = item.module.__name__ if item.module else ""
        if name in _SLOW_MODULES:
            item.add_marker(pytest.mark.slow)
        else:
            item.add_marker(pytest.mark.fast)
