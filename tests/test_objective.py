"""OTObjective / ExecutionPolicy — the one training-facing OT layer.

Covers the contracts the training surfaces rely on: gradient flow into
every learnable (anchors / prototypes / projection), exact fp32 parity
against the legacy hand-derived rot_log_factored rule, routing parity
between the legacy loop and the policy path (incl. straight-through
gradients), the 1-device sharded mesh path, the exact token-subsample
budget, plan-selection observability, and jit stability of a closed-over
policy.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.features import GaussianFeatureMap, gaussian_log_features
from repro.core.grad import rot_log_factored
from repro.core.objective import ExecutionPolicy, OTObjective
from repro.core.routing import sinkhorn_route
from repro.kernels.ops import observe_plan_selection
from repro.models.ot_loss import (
    init_ot_loss,
    ot_prototype_loss,
    subsample_tokens,
)


@pytest.fixture(scope="module")
def log_features():
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    n, m, d, r = 24, 18, 2, 48
    eps = 0.8
    x = jax.random.normal(k1, (n, d))
    y = jax.random.normal(k2, (m, d)) * 0.7
    fm = GaussianFeatureMap(r=r, d=d, eps=eps, R=3.0)
    U = fm.init(k3)
    lxi = gaussian_log_features(x, U, eps=eps, q=fm.q)
    lzeta = gaussian_log_features(y, U, eps=eps, q=fm.q)
    a = jnp.full((n,), 1.0 / n)
    b = jnp.full((m,), 1.0 / m)
    return lxi, lzeta, a, b, eps


def test_objective_matches_legacy_fp32(log_features):
    """OTObjective.divergence == the hand-derived three-solve divergence
    built on rot_log_factored, value AND gradient, at fp32."""
    lxi, lzeta, a, b, eps = log_features
    obj = OTObjective(eps=eps, tol=0.0, max_iter=200,
                      policy=ExecutionPolicy(precision="highest"))

    def new(lx):
        geom = obj.factored(lx, lzeta)
        return obj.divergence(geom, a, b)

    def legacy(lx):
        w_xy = rot_log_factored(lx, lzeta, a, b, eps, 0.0, 200)
        w_xx = rot_log_factored(lx, lx, a, a, eps, 0.0, 200)
        w_yy = rot_log_factored(lzeta, lzeta, b, b, eps, 0.0, 200)
        return w_xy - 0.5 * (w_xx + w_yy)

    v_new, g_new = jax.value_and_grad(new)(lxi)
    v_old, g_old = jax.value_and_grad(legacy)(lxi)
    np.testing.assert_allclose(float(v_new), float(v_old), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(g_new), np.asarray(g_old),
                               rtol=1e-4, atol=1e-7)


def test_gradient_flows_to_every_learnable():
    """The LM prototype loss: grads must reach the projection, the
    prototypes AND the anchors (the paper's full theta), finite and
    nonzero."""
    key = jax.random.PRNGKey(1)
    d_model = 16
    p_ot = init_ot_loss(key, d_model, ot_dim=4, n_protos=8, n_features=32,
                        eps=0.5)
    hidden = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, d_model))

    loss = lambda p: ot_prototype_loss(
        p, hidden, eps=0.5, n_tokens=12, n_iter=20,
        policy=ExecutionPolicy(precision="highest"))
    val, grads = jax.value_and_grad(loss)(p_ot)
    assert np.isfinite(float(val))
    for name in ("proj", "protos", "anchors"):
        g = np.asarray(grads[name])
        assert np.all(np.isfinite(g)), f"non-finite grad for {name}"
        assert np.linalg.norm(g) > 0, f"zero grad for {name}"


def test_routing_parity_legacy_vs_policy():
    """The sinkhorn router through the objective layer (training policy,
    check-once cadence) must produce the same dispatch and the same
    straight-through gradients as the legacy default path."""
    key = jax.random.PRNGKey(2)
    logits = jax.random.normal(key, (32, 8))

    def combine_sum(lg, policy):
        r = sinkhorn_route(lg, top_k=2, eps=0.05, n_iter=8, policy=policy)
        return jnp.sum(r.combine * jnp.arange(8.0)), r

    (s_old, r_old), g_old = jax.value_and_grad(
        lambda lg: combine_sum(lg, None), has_aux=True)(logits)
    (s_new, r_new), g_new = jax.value_and_grad(
        lambda lg: combine_sum(lg, ExecutionPolicy.training()),
        has_aux=True)(logits)
    np.testing.assert_array_equal(np.asarray(r_old.dispatch),
                                  np.asarray(r_new.dispatch))
    np.testing.assert_allclose(np.asarray(r_old.combine),
                               np.asarray(r_new.combine), atol=1e-6)
    np.testing.assert_allclose(np.asarray(g_old), np.asarray(g_new),
                               atol=1e-6)
    np.testing.assert_allclose(float(r_old.balance_loss),
                               float(r_new.balance_loss), atol=1e-6)


def test_mesh_policy_smoke(log_features):
    """policy.mesh set: the divergence runs as a sharded solve on the
    1-device mesh and stays differentiable."""
    lxi, lzeta, a, b, eps = log_features
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    obj = OTObjective(eps=eps, tol=0.0, max_iter=50,
                      policy=ExecutionPolicy(mesh=mesh))

    def f(lx):
        return obj.divergence(obj.factored(lx, lzeta), a, b)

    val, grad = jax.value_and_grad(f)(lxi)
    assert np.isfinite(float(val))
    assert np.all(np.isfinite(np.asarray(grad)))
    # and it agrees with the unsharded objective
    plain = OTObjective(eps=eps, tol=0.0, max_iter=50)
    np.testing.assert_allclose(
        float(val), float(plain.divergence(plain.factored(lxi, lzeta),
                                           a, b)), rtol=1e-5)


def test_subsample_tokens_exact_budget():
    """The token budget is honored EXACTLY (the old stride math overshot
    for small S and collapsed whenever n_tokens < B)."""
    hidden = jnp.arange(4 * 3 * 5, dtype=jnp.float32).reshape(4, 3, 5)
    assert subsample_tokens(hidden, 2).shape == (2, 5)     # n_tokens < B
    assert subsample_tokens(hidden, 7).shape == (7, 5)
    assert subsample_tokens(hidden, 12).shape == (12, 5)
    assert subsample_tokens(hidden, 999).shape == (12, 5)  # capped at B*S
    # evenly spaced: first and last flattened tokens are always included
    two = subsample_tokens(hidden, 2)
    np.testing.assert_array_equal(np.asarray(two[0]),
                                  np.asarray(hidden[0, 0]))
    np.testing.assert_array_equal(np.asarray(two[-1]),
                                  np.asarray(hidden[-1, -1]))


def test_plan_selection_observability(log_features):
    """A use_pallas=True policy must select the fused plan at the policy's
    precision — the hook CI's strict train-smoke lanes rely on."""
    lxi, lzeta, a, b, eps = log_features
    obj = OTObjective(
        eps=eps, tol=0.0, max_iter=10,
        policy=ExecutionPolicy.training(use_pallas=True))
    with observe_plan_selection() as events:
        val = obj.divergence(obj.factored(lxi, lzeta), a, b)
    assert np.isfinite(float(val))
    sel = [e for e in events if e["geometry"] == "FactoredPositive"]
    assert sel, f"no fused plan selected: {events}"
    assert all(e["precision"] == "bf16" for e in sel), sel


def test_policy_is_jit_stable(log_features):
    """A closed-over policy is static: re-calling the jitted loss with new
    array values must not retrace."""
    lxi, lzeta, a, b, eps = log_features
    obj = OTObjective(eps=eps, tol=0.0, max_iter=10,
                      policy=ExecutionPolicy.training())

    @jax.jit
    def loss(lx):
        return obj.divergence(obj.factored(lx, lzeta), a, b)

    loss(lxi).block_until_ready()
    n0 = loss._cache_size()
    loss(lxi + 0.01).block_until_ready()
    assert loss._cache_size() == n0
    # policies compare/hash by value — a rebuilt equal policy is the same
    # static closure ingredient
    assert ExecutionPolicy.training() == obj.policy
    assert hash(ExecutionPolicy.training()) == hash(obj.policy)
