"""End-to-end system tests: tiny training runs, loss goes down, resume is
bit-deterministic, OT loss trains (the paper's technique in the loop)."""

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, DataPipeline
from repro.models import init_params, train_loss
from repro.optim import AdamWConfig, adamw_update, init_adamw


def _train(cfg, steps, seed=0, params=None, opt_state=None, start=0,
           lr=3e-3, batch=8, seq=64):
    ocfg = AdamWConfig(lr=lr, weight_decay=0.0)
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = init_params(key, cfg)
        opt_state = init_adamw(params, ocfg)
    data = DataPipeline(DataConfig(
        seed=1, global_batch=batch, seq_len=seq, vocab=cfg.vocab,
        input_kind=cfg.input_kind, d_model=cfg.d_model))

    @jax.jit
    def step_fn(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: train_loss(p, cfg, batch), has_aux=True)(params)
        params, opt_state, _ = adamw_update(params, grads, opt_state, ocfg)
        return params, opt_state, metrics

    losses = []
    for s in range(start, start + steps):
        params, opt_state, m = step_fn(params, opt_state, data.batch_at(s))
        losses.append(float(m["loss"]))
    return params, opt_state, losses


def test_loss_decreases_smollm_tiny():
    cfg = get_config("smollm_135m").tiny(ot_iters=5)
    _, _, losses = _train(cfg, 80)
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.25, (
        losses[:10], losses[-10:])


def test_ot_loss_decreases_when_trained():
    """The paper's divergence, used as the only trainable objective over
    the OT params: prototypes must move toward the token cloud."""
    cfg = get_config("smollm_135m").tiny(ot_iters=15, ot_loss_weight=1.0)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    data = DataPipeline(DataConfig(seed=1, global_batch=4, seq_len=32,
                                   vocab=cfg.vocab))
    from repro.models.model import forward
    from repro.models.ot_loss import ot_prototype_loss
    batch = data.batch_at(0)
    h, _ = forward(params, cfg, batch)
    h = jax.lax.stop_gradient(h)

    def loss_fn(p_ot):
        return ot_prototype_loss(p_ot, h, eps=cfg.ot_eps,
                                 n_tokens=cfg.ot_tokens,
                                 n_iter=cfg.ot_iters)

    p_ot = params["ot"]
    l0 = float(loss_fn(p_ot))
    g = jax.grad(loss_fn)
    for _ in range(60):
        grads = g(p_ot)
        p_ot = jax.tree.map(lambda p, gr: p - 0.05 * gr, p_ot, grads)
    l1 = float(loss_fn(p_ot))
    assert l1 < l0, (l0, l1)


def test_resume_is_deterministic(tmp_path):
    cfg = get_config("qwen2_1p5b").tiny(ot_iters=5)
    # run 10 straight
    p_full, o_full, _ = _train(cfg, 10)
    # run 5, checkpoint, restore, run 5 more
    p5, o5, _ = _train(cfg, 5)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(4, {"p": p5, "o": o5})
    (restored, ) = (mgr.restore(None, {"p": p5, "o": o5})[0], )
    p_res, o_res, _ = _train(cfg, 5, params=restored["p"],
                             opt_state=restored["o"], start=5)
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_moe_arch_trains_with_sinkhorn_router():
    cfg = get_config("deepseek_v2_236b").tiny(
        param_dtype="float32", compute_dtype="float32", ot_iters=5)
    assert cfg.router == "sinkhorn"
    _, _, losses = _train(cfg, 12, lr=1e-3)
    assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0] + 0.5   # not diverging
