"""SolveSpec: one record accepted by every solve surface.

Covers the API-redesign satellite: construction validation, the
solve(spec) / solve_many(specs) / OTService.submit(spec) front doors all
agreeing with the legacy keyword paths, the OTObjective.spec bridge, and
the DeprecationWarning on legacy execution kwargs.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    EpsSchedule,
    ExecutionPolicy,
    FactoredPositive,
    OTObjective,
    OTProblem,
    SolveSpec,
    solve,
    solve_many,
)
from repro.serving import OTService

RNG = np.random.default_rng(7)
EPS = 0.5


def _geom(n=24, m=20, r=6, rng=RNG):
    xi = jnp.asarray(np.abs(rng.normal(size=(n, r))).astype(np.float32)
                     + 0.1)
    zeta = jnp.asarray(np.abs(rng.normal(size=(m, r))).astype(np.float32)
                       + 0.1)
    return FactoredPositive(xi=xi, zeta=zeta, eps=EPS)


def test_spec_validation():
    g = _geom()
    with pytest.raises(TypeError, match="Geometry"):
        SolveSpec(geometry=jnp.ones((4, 4)))
    with pytest.raises(ValueError, match="method"):
        SolveSpec(geometry=g, method="nope")
    with pytest.raises(TypeError, match="ExecutionPolicy"):
        SolveSpec(geometry=g, policy="cpu")
    spec = SolveSpec(geometry=g, method="factored")
    assert spec.eps == EPS
    assert "FactoredPositive" in spec.describe()
    assert spec.replace(tol=1e-4).tol == 1e-4
    prob = spec.problem()
    assert isinstance(prob, OTProblem)
    round_trip = SolveSpec.from_problem(prob, method="factored", tol=1e-5)
    assert round_trip.method == "factored" and round_trip.tol == 1e-5


def test_solve_spec_matches_keyword_path():
    g = _geom()
    spec = SolveSpec(geometry=g, method="factored", tol=1e-6)
    res_spec = solve(spec)
    res_kw = solve(OTProblem.from_geometry(g), method="factored", tol=1e-6)
    np.testing.assert_allclose(float(res_spec.cost), float(res_kw.cost),
                               rtol=0, atol=1e-7)
    np.testing.assert_allclose(np.asarray(res_spec.f), np.asarray(res_kw.f),
                               rtol=0, atol=1e-6)


def test_solve_spec_annealed():
    from repro.core import GaussianPointCloud
    x = jnp.asarray(RNG.normal(size=(24, 3)).astype(np.float32))
    y = jnp.asarray(RNG.normal(size=(20, 3)).astype(np.float32))
    anchors = jnp.asarray(RNG.normal(size=(32, 3)).astype(np.float32))
    g = GaussianPointCloud.build(x, y, anchors, eps=EPS)
    spec = SolveSpec(geometry=g, method="log_factored",
                     schedule=EpsSchedule(eps_init=4.0, decay=0.5))
    res = solve(spec)
    assert bool(res.converged)


def test_solve_many_specs():
    g1, g2 = _geom(), _geom()
    s1 = SolveSpec(geometry=g1, method="factored")
    s2 = SolveSpec(geometry=g2, method="factored")
    r1, r2 = solve_many([s1, s2])
    ref = solve(s2)
    np.testing.assert_allclose(float(r2.cost), float(ref.cost),
                               rtol=0, atol=1e-5)
    del r1


def test_solve_many_rejects_heterogeneous_specs():
    g = _geom()
    s1 = SolveSpec(geometry=g, method="factored", tol=1e-6)
    s2 = s1.replace(tol=1e-4)
    with pytest.raises(ValueError, match="heterogeneous"):
        solve_many([s1, s2])
    with pytest.raises(TypeError, match="mixed"):
        solve_many([s1, OTProblem.from_geometry(g)])


def test_legacy_execution_kwargs_deprecated():
    g = _geom()
    prob = OTProblem.from_geometry(g)
    with pytest.warns(DeprecationWarning, match="SolveSpec"):
        solve(prob, method="factored", use_pallas=False)
    with pytest.warns(DeprecationWarning, match="SolveSpec"):
        solve_many([prob], method="factored", precision="bf16")
    with warnings.catch_warnings():
        warnings.simplefilter("error")      # spec path must be silent
        solve(SolveSpec(geometry=g, method="factored",
                        policy=ExecutionPolicy(use_pallas=False)))


def test_objective_spec_bridge():
    g = _geom()
    obj = OTObjective(eps=EPS, tol=1e-6, max_iter=500,
                      policy=ExecutionPolicy(use_pallas=False))
    spec = obj.spec(g, method="factored")
    assert spec.tol == obj.tol and spec.max_iter == obj.max_iter
    assert spec.policy is obj.policy
    res = solve(spec)
    assert bool(res.converged)
    bad = FactoredPositive(xi=g.xi, zeta=g.zeta, eps=2 * EPS)
    with pytest.raises(ValueError, match="eps"):
        obj.spec(bad)


def test_service_submit_spec():
    g = _geom()
    svc = OTService(eps=EPS, method="factored", tol=1e-6, max_batch=4,
                    max_wait=0.0)
    spec = SolveSpec(geometry=g, method="factored", tol=1e-6)
    ticket = svc.submit(spec)
    svc.drain()
    assert ticket.done
    ref = solve(spec)
    np.testing.assert_allclose(float(ticket.result.cost), float(ref.cost),
                               rtol=0, atol=1e-5)
    # mismatched target -> explicit rejection, not silent reconfiguration
    with pytest.raises(ValueError, match="one service per configuration"):
        svc.submit(spec.replace(tol=1e-3))
    with pytest.raises(ValueError, match="schedule"):
        svc.submit(spec.replace(schedule=EpsSchedule(eps_init=4.0,
                                                     decay=0.5)))
