"""MoE: EP shard_map path vs dense oracle; Sinkhorn routing balance."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.routing import sinkhorn_route
from repro.distributed.sharding import shard_map
from repro.models.moe import init_moe, moe_dense, moe_ep_local, router_probs


def _setup(T=64, d=16, f=32, E=8, seed=0):
    key = jax.random.PRNGKey(seed)
    p = init_moe(key, d, f, E)
    x = jax.random.normal(jax.random.fold_in(key, 1), (T, d)) * 0.5
    return p, x


def test_ep_matches_dense_single_rank():
    """With 1 rank and ample capacity, EP must equal the dense path exactly
    (same experts, same gates; no drops)."""
    p, x = _setup()
    out_d, aux_d = moe_dense(p, x, top_k=2)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    fn = shard_map(
        lambda p_, x_: moe_ep_local(p_, x_, top_k=2, n_experts=8,
                                    axis="model", capacity_factor=8.0),
        mesh=mesh,
        in_specs=({"router": P(None, None), "up": P("model", None, None),
                   "gate": P("model", None, None),
                   "down": P("model", None, None)}, P(None, None)),
        out_specs=(P(None, None), P()),
        check_vma=False,
    )
    with mesh:
        out_e, aux_e = fn(p, x)
    np.testing.assert_allclose(np.asarray(out_e), np.asarray(out_d),
                               rtol=2e-4, atol=2e-5)


def test_ep_gradients_flow():
    p, x = _setup()
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))

    def loss(p_, x_):
        fn = shard_map(
            lambda pp, xx: moe_ep_local(pp, xx, top_k=2, n_experts=8,
                                        axis="model", capacity_factor=8.0),
            mesh=mesh,
            in_specs=({"router": P(None, None),
                       "up": P("model", None, None),
                       "gate": P("model", None, None),
                       "down": P("model", None, None)}, P(None, None)),
            out_specs=(P(None, None), P()),
            check_vma=False,
        )
        out, aux = fn(p_, x_)
        return jnp.sum(out ** 2) + 0.01 * aux

    with mesh:
        g = jax.grad(loss)(p, x)
    norms = {k: float(jnp.linalg.norm(v)) for k, v in
             jax.tree_util.tree_flatten_with_path(g)[0] and
             [(str(kp), jnp.linalg.norm(l)) for kp, l in
              jax.tree_util.tree_flatten_with_path(g)[0]]}
    for k, v in norms.items():
        assert np.isfinite(v), k
    assert norms and any(v > 0 for v in norms.values())


def test_capacity_drops_bounded():
    """Adversarial routing (all tokens to one expert) must drop to capacity,
    not corrupt outputs."""
    p, x = _setup(T=32)
    # rig the router so every token picks expert 0 hardest
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(5.0)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    fn = shard_map(
        lambda p_, x_: moe_ep_local(p_, x_, top_k=1, n_experts=8,
                                    axis="model", capacity_factor=0.25),
        mesh=mesh,
        in_specs=({"router": P(None, None), "up": P("model", None, None),
                   "gate": P("model", None, None),
                   "down": P("model", None, None)}, P(None, None)),
        out_specs=(P(None, None), P()),
        check_vma=False,
    )
    with mesh:
        out, aux = fn(p, x)
    assert bool(jnp.all(jnp.isfinite(out)))
    # dropped tokens produce zero rows
    nz = jnp.sum(jnp.any(out != 0, axis=-1))
    assert int(nz) < 32


def test_sinkhorn_router_balances_load():
    """The paper-integrated router: balanced assignment beats raw softmax
    top-k load imbalance on skewed logits."""
    key = jax.random.PRNGKey(0)
    T, E, k = 256, 8, 2
    skew = jnp.array([3.0, 1.0] + [0.0] * (E - 2))
    logits = jax.random.normal(key, (T, E)) + skew[None, :]
    r = sinkhorn_route(logits, top_k=k, eps=0.3, n_iter=50)
    load_sink = jnp.mean(r.dispatch, axis=0)
    probs = jax.nn.softmax(logits, -1)
    _, idx = jax.lax.top_k(probs, k)
    disp = jnp.zeros((T, E)).at[jnp.arange(T)[:, None], idx].set(1.0)
    load_soft = jnp.mean(disp, axis=0)
    imb = lambda l: float(jnp.max(l) / jnp.maximum(jnp.mean(l), 1e-9))
    assert imb(load_sink) < imb(load_soft), (load_sink, load_soft)


def test_router_probs_topk_structure():
    p, x = _setup()
    for router in ("softmax", "sinkhorn"):
        combine, aux = router_probs(p, x, top_k=2, router=router)
        nz = jnp.sum(combine > 0, axis=-1)
        assert bool(jnp.all(nz <= 2))
        np.testing.assert_allclose(np.asarray(jnp.sum(combine, -1)),
                                   np.ones(x.shape[0]), atol=1e-5)
        assert np.isfinite(float(aux))
