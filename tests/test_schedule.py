"""Epsilon-annealing schedule properties (repro.core.api.EpsSchedule).

The three contracts promised by the schedule design:
  1. the annealed solve lands on the SAME cost as a direct small-eps solve;
  2. per-stage marginal error is monotone non-increasing (enforced by the
     adaptive cap at the previous stage's achieved error);
  3. at small eps (<= 0.05) the cascade takes strictly fewer TOTAL
     iterations than a cold start — the reason the schedule exists.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EpsSchedule, OTProblem, solve, solve_annealed
from repro.core.features import GaussianFeatureMap

EPS_TARGET = 0.02           # the paper's hard small-regularization regime
TOL = 1e-4                  # above the f32 L1-marginal noise floor
SCHED = EpsSchedule(eps_init=0.8, decay=0.4)
SEEDS = (0, 3, 4, 5)


@pytest.fixture(scope="module")
def anchors():
    return GaussianFeatureMap(r=128, d=2, eps=EPS_TARGET, R=3.0).init(
        jax.random.PRNGKey(7)
    )


def _problem(seed, anchors, n=60, m=50, d=2):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jnp.clip(jax.random.normal(k1, (n, d)), -2, 2)
    y = jnp.clip(jax.random.normal(k2, (m, d)) * 0.7 + 0.3, -2, 2)
    return OTProblem.from_point_clouds(x, y, anchors, eps=EPS_TARGET)


def _pair(seed, anchors):
    p = _problem(seed, anchors)
    ann = solve_annealed(p, method="log_factored", schedule=SCHED, tol=TOL,
                         max_iter=100_000)
    cold = solve(p, method="log_factored", tol=TOL, max_iter=100_000)
    return ann, cold


@pytest.mark.parametrize("seed", SEEDS)
def test_annealed_cost_matches_direct_solve(seed, anchors):
    ann, cold = _pair(seed, anchors)
    assert bool(ann.result.converged) and bool(cold.converged)
    rel = abs(float(ann.result.cost - cold.cost)) / abs(float(cold.cost))
    assert rel <= 1e-3, rel


@pytest.mark.parametrize("seed", SEEDS)
def test_stage_errors_monotone_non_increasing(seed, anchors):
    ann, _ = _pair(seed, anchors)
    errs = np.asarray(ann.stage_errs)
    assert len(errs) == len(ann.stage_eps) >= 3
    assert np.all(np.isfinite(errs))
    assert np.all(errs[1:] <= errs[:-1]), errs


@pytest.mark.parametrize("seed", SEEDS)
def test_annealing_beats_cold_start_iterations(seed, anchors):
    assert EPS_TARGET <= 0.05
    ann, cold = _pair(seed, anchors)
    assert int(ann.result.n_iter) < int(cold.n_iter), (
        int(ann.result.n_iter), int(cold.n_iter)
    )
    # and n_iter really is the total over stages
    assert int(ann.result.n_iter) == int(np.sum(np.asarray(ann.stage_iters)))


def test_stage_ladder_shape():
    s = EpsSchedule(eps_init=0.8, decay=0.4)
    stages = s.stages(0.02)
    assert stages[0] == 0.8 and stages[-1] == 0.02
    assert all(b < a for a, b in zip(stages, stages[1:]))
    # degenerate: eps_init at or below target collapses to one stage
    assert s.stages(0.9) == (0.9,)


def test_stage_tols_ladder():
    s = EpsSchedule(eps_init=0.8, decay=0.4, stage_tol=1e-2)
    tols = s.stage_tols(1e-4, 6)
    assert tols[0] == 1e-2 and tols[-1] == 1e-4
    assert all(b <= a for a, b in zip(tols, tols[1:]))
    # intermediates stay loose: none tighter than sqrt(stage_tol * tol)
    assert min(tols[:-1]) >= np.sqrt(1e-2 * 1e-4) * (1 - 1e-6)


def test_schedule_validation():
    with pytest.raises(ValueError, match="decay"):
        EpsSchedule(eps_init=1.0, decay=1.5)
    with pytest.raises(ValueError, match="eps_init"):
        EpsSchedule(eps_init=-1.0)
