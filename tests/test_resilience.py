"""Resilience: failure classification, the recovery ladder, and the
fault-injection contracts.

The subsystem's promises under test:

* classification — ``classify`` reads one solve into an ``ok`` /
  ``maxed_out`` / ``diverged`` / ``poisoned_warm_start`` verdict, with
  ``-inf`` potentials on ZERO-WEIGHT atoms recognised as the legitimate
  padding contract, not poison;
* the core ladder — a scaling-domain solve that underflows at small eps
  recovers through the ``log_domain`` rung and lands within solver
  tolerance of the log-domain ground truth;
* lane isolation — a diverged lane inside a ``solve_many`` bucket (and
  inside an ``OTService`` megabatch with replicated padding) never
  perturbs its healthy siblings: their results match solo solves
  elementwise;
* warm-cache hygiene — non-finite potentials are rejected at ``store``,
  evicted at ``lookup``, and a diverged solve can never poison the next
  exact-repeat request;
* bounded-queue shedding, quarantine of repeat offenders, skewed-clock
  admission aging, the streaming cold-fallback/state-reset path, and the
  training-step admission guard.
"""
import numpy as np
import pytest

from repro.core import OTProblem, solve, solve_many
from repro.core.geometry import GaussianPointCloud
from repro.core.sinkhorn import SinkhornResult
from repro.core.spec import SolveSpec
from repro.distributed.fault_tolerance import (
    FaultToleranceConfig,
    TrainingSupervisor,
)
from repro.resilience import (
    RUNGS,
    ChaosInjector,
    ChaosSpec,
    RecoveryPolicy,
    classify,
    solve_with_recovery,
    warm_is_poisoned,
)
from repro.serving import (
    AdmissionQueue,
    OTService,
    QuarantineError,
    QueueFullError,
    WarmStartCache,
)
from repro.streaming import StreamingDistribution, StreamingSolver

EPS = 0.5
SMALL_EPS = 1e-4       # scaling-domain Gaussian features underflow here


def _problem(n, m, r=8, seed=0, eps=EPS, nan_row=None):
    rng = np.random.default_rng(seed)
    xi = np.asarray(rng.uniform(0.05, 1.05, (n, r)), np.float32)
    zeta = np.asarray(rng.uniform(0.05, 1.05, (m, r)), np.float32)
    if nan_row is not None:
        xi[nan_row] = np.nan
    a = np.asarray(rng.dirichlet(np.full(n, 2.0)), np.float32)
    b = np.asarray(rng.dirichlet(np.full(m, 2.0)), np.float32)
    return OTProblem.from_features(xi, zeta, a / a.sum(), b / b.sum(),
                                   eps=eps)


def _gauss_problem(n=14, m=12, r=8, seed=0, eps=SMALL_EPS):
    """True point clouds: recoverable small-eps failure class (the
    scaling-domain kernel underflows; log features stay finite)."""
    rng = np.random.default_rng(seed)
    x = np.asarray(rng.normal(size=(n, 2)), np.float32)
    y = np.asarray(rng.normal(size=(m, 2)), np.float32)
    anchors = np.asarray(rng.normal(size=(r, 2)), np.float32)
    geom = GaussianPointCloud.build(x, y, anchors, eps=eps)
    a = np.full(n, 1.0 / n, np.float32)
    b = np.full(m, 1.0 / m, np.float32)
    return OTProblem.from_geometry(geom, a, b)


def _result(err, cost, n_iter=7, converged=True, n=3, m=3):
    z = np.zeros(n, np.float32)
    w = np.zeros(m, np.float32)
    return SinkhornResult(u=z, v=w, f=z, g=w,
                          cost=np.float64(cost), n_iter=np.int32(n_iter),
                          marginal_err=np.float64(err),
                          converged=np.bool_(converged))


# -- classification -----------------------------------------------------------


def test_classify_verdicts():
    ok = classify(_result(1e-8, 0.3, converged=True))
    assert ok.verdict == "ok" and ok.ok and ok.finite and not ok.failed
    assert "ok" in ok.describe()

    maxed = classify(_result(1e-3, 0.3, converged=False))
    assert maxed.verdict == "maxed_out"
    assert maxed.finite and not maxed.ok and not maxed.failed

    div = classify(_result(np.nan, np.nan, converged=False))
    assert div.verdict == "diverged" and div.failed and not div.finite

    # same diagnostics, but the warm start handed in was already corrupt
    f0 = np.array([0.0, np.nan, 0.0])
    poisoned = classify(_result(np.nan, np.nan, converged=False),
                        f_init=f0, g_init=np.zeros(3))
    assert poisoned.verdict == "poisoned_warm_start" and poisoned.failed


def test_warm_is_poisoned_weight_masking():
    assert not warm_is_poisoned(None, None)
    assert not warm_is_poisoned(np.zeros(3), np.zeros(3))
    assert warm_is_poisoned(np.array([0.0, np.nan]), None)
    assert warm_is_poisoned(None, np.array([np.inf, 0.0]))
    # -inf without weights: conservative poison
    neg = np.array([0.0, -np.inf, 0.0])
    assert warm_is_poisoned(neg, None)
    # -inf on a ZERO-weight atom is the padding contract, not poison
    a_dead = np.array([0.5, 0.0, 0.5])
    assert not warm_is_poisoned(neg, None, a=a_dead)
    # ... but on a mass-carrying atom it is poison
    a_live = np.array([0.3, 0.4, 0.3])
    assert warm_is_poisoned(neg, None, a=a_live)


def test_result_health_property_end_to_end():
    good = solve(_problem(10, 9, seed=1), method="factored", tol=1e-6,
                 max_iter=500)
    assert good.health.ok

    bad = solve(_problem(10, 9, seed=1, nan_row=2), method="factored",
                tol=1e-6, max_iter=50)
    assert bad.health.verdict == "diverged" and bad.health.failed


# -- policy validation --------------------------------------------------------


def test_recovery_policy_validation():
    RecoveryPolicy()                       # defaults are legal
    with pytest.raises(ValueError, match="unknown recovery rungs"):
        RecoveryPolicy(rungs=("log_domain", "reboot"))
    with pytest.raises(ValueError, match="duplicate"):
        RecoveryPolicy(rungs=("log_domain", "log_domain"))
    with pytest.raises(ValueError, match="max_attempts"):
        RecoveryPolicy(max_attempts=0)
    with pytest.raises(ValueError, match="eps_scale"):
        RecoveryPolicy(eps_scale=1.0)
    with pytest.raises(ValueError, match="deadline_s"):
        RecoveryPolicy(deadline_s=0.0)
    with pytest.raises(ValueError, match="unknown verdicts"):
        RecoveryPolicy(accept=("ok", "fine"))
    with pytest.raises(ValueError, match="at least one"):
        RecoveryPolicy(accept=())


def test_ordered_rungs_poisoned_pulls_cold_restart_first():
    pol = RecoveryPolicy()
    assert pol.ordered_rungs("diverged") == RUNGS
    reordered = pol.ordered_rungs("poisoned_warm_start")
    assert reordered[0] == "cold_restart"
    assert set(reordered) == set(RUNGS)
    # a ladder without cold_restart keeps its order
    pol2 = RecoveryPolicy(rungs=("log_domain",))
    assert pol2.ordered_rungs("poisoned_warm_start") == ("log_domain",)


def test_spec_recovery_type_checked():
    p = _problem(8, 8)
    with pytest.raises(TypeError, match="RecoveryPolicy"):
        SolveSpec.from_problem(p, recovery="retry-hard")


# -- the core ladder ----------------------------------------------------------


def test_ladder_recovers_small_eps_underflow():
    p = _gauss_problem(seed=3)
    spec = SolveSpec.from_problem(p, method="factored", tol=1e-4,
                                  max_iter=300,
                                  recovery=RecoveryPolicy())
    # base configuration genuinely fails ...
    base = solve(spec.replace(recovery=None))
    assert base.health.failed

    rec = solve_with_recovery(spec)
    assert rec.health.finite and rec.recovered
    assert rec.attempts >= 2 and rec.rungs[0] == "log_domain"
    assert rec.history[0][0] == "initial"
    assert rec.history[0][1].failed
    # ... and the recovered answer matches the log-domain ground truth
    ref = solve(p, method="log_factored", tol=1e-4, max_iter=300)
    assert abs(float(rec.result.cost) - float(ref.cost)) <= \
        1e-6 + 1e-5 * abs(float(ref.cost))

    # solve(spec) with recovery attached routes through the same ladder
    auto = solve(spec)
    assert auto.health.finite
    np.testing.assert_allclose(np.asarray(auto.f),
                               np.asarray(rec.result.f),
                               rtol=1e-6, atol=1e-6)


def test_ladder_healthy_solve_is_single_attempt():
    spec = SolveSpec.from_problem(_problem(10, 9, seed=5),
                                  method="factored", tol=1e-6,
                                  max_iter=500, recovery=RecoveryPolicy())
    rec = solve_with_recovery(spec)
    assert rec.health.ok and rec.attempts == 1
    assert rec.rungs == () and not rec.recovered


def test_ladder_exhausts_on_unrecoverable_input():
    # NaN features defeat every rung: the ladder must terminate with a
    # failed verdict inside its attempt budget, not loop or raise
    p = _problem(10, 9, seed=7, nan_row=1)
    spec = SolveSpec.from_problem(
        p, method="factored", tol=1e-6, max_iter=50,
        recovery=RecoveryPolicy(max_attempts=3))
    rec = solve_with_recovery(spec)
    assert rec.health.failed and not rec.recovered
    assert rec.attempts <= 3
    assert all(h.failed for _, h in rec.history)


# -- lane isolation (satellite: diverged lane never poisons siblings) ---------


def test_solve_many_diverged_lane_sibling_parity():
    healthy = [_gauss_problem(seed=s, eps=EPS) for s in (1, 2)]
    bad = _problem(14, 12, seed=9, eps=EPS, nan_row=0)
    alt = _problem(14, 12, seed=10, eps=EPS)
    mk = lambda p: SolveSpec.from_problem(p, method="factored", tol=1e-6,
                                          max_iter=400,
                                          recovery=RecoveryPolicy())

    batched = solve_many([mk(healthy[0]), mk(bad), mk(healthy[1])])
    # swap the bad lane for a healthy one, same batch size/positions: the
    # siblings must be BITWISE identical — the NaN lane shared their
    # vmapped loop but never touched them (converged lanes are frozen)
    clean = solve_many([mk(healthy[0]), mk(alt), mk(healthy[1])])
    for i in (0, 2):
        assert np.array_equal(np.asarray(batched[i].f),
                              np.asarray(clean[i].f))
        assert np.array_equal(np.asarray(batched[i].g),
                              np.asarray(clean[i].g))
        assert batched[i].health.ok
    # ... and match solo (batch-1) solves to float32 matmul roundoff
    solo = [solve_many([mk(p)])[0] for p in healthy]
    for got, ref in zip((batched[0], batched[2]), solo):
        np.testing.assert_allclose(np.asarray(got.f), np.asarray(ref.f),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(got.g), np.asarray(ref.g),
                                   rtol=1e-5, atol=1e-5)
    # the bad lane climbed the ladder individually and stayed failed
    # (NaN input is unrecoverable) without raising
    assert batched[1].health.failed


def test_service_bad_lane_isolated_and_recovered():
    svc = OTService(eps=SMALL_EPS, method="factored", tol=1e-4,
                    max_iter=300, max_batch=4, max_wait=0.0,
                    recovery=RecoveryPolicy(), quarantine_after=3)
    healthy = [_problem(14, 12, seed=s, eps=SMALL_EPS) for s in (1, 2)]
    gauss = _gauss_problem(seed=4)               # recoverable divergence
    nan = _problem(14, 12, seed=9, eps=SMALL_EPS, nan_row=0)

    tickets = [svc.submit(p) for p in (healthy[0], gauss, nan, healthy[1])]
    svc.drain()
    t_h0, t_gauss, t_nan, t_h1 = tickets
    assert all(t.done for t in tickets)

    # healthy lanes: elementwise parity vs a solo (batch-1) service solve
    solo = [OTService(eps=SMALL_EPS, method="factored", tol=1e-4,
                      max_iter=300, max_batch=1).solve_many([p])[0]
            for p in healthy]
    for t, ref in zip((t_h0, t_h1), solo):
        assert t.health.finite and t.refusal is None
        np.testing.assert_allclose(np.asarray(t.result.f),
                                   np.asarray(ref.f),
                                   rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(np.asarray(t.result.g),
                                   np.asarray(ref.g),
                                   rtol=1e-6, atol=1e-7)

    # the underflow lane climbed the ladder: finite, via log_domain
    assert t_gauss.health is not None and t_gauss.health.finite
    assert t_gauss.attempts > 1 and "log_domain" in t_gauss.rungs
    ref_g = solve(gauss, method="log_factored", tol=1e-4, max_iter=300)
    assert abs(float(t_gauss.result.cost) - float(ref_g.cost)) <= \
        1e-6 + 1e-4 * abs(float(ref_g.cost))

    # the NaN lane exhausted the ladder: structured refusal, no NaN served
    assert t_nan.result is None and t_nan.refusal is not None
    assert t_nan.refusal.reason == "recovery_exhausted"
    assert t_nan.refusal.health is not None and t_nan.refusal.health.failed

    s = svc.stats()
    assert s["recovery"]["recovered"] >= 1
    assert s["recovery"]["refused"] == 1
    assert s["recovery"]["rung_hist"].get("log_domain", 0) >= 1
    assert s["health"].get("diverged", 0) >= 1


# -- warm-start cache hygiene (satellite: cache poisoning) --------------------


def test_warmstart_rejects_poison_at_store():
    cache = WarmStartCache()
    a = np.array([0.5, 0.5], np.float32)
    b = np.array([0.25, 0.75], np.float32)
    sk, fk = b"s", b"f"
    assert not cache.store(sk, fk, np.array([np.nan, 0.0]), np.zeros(2),
                           a, b)
    assert len(cache) == 0 and cache.snapshot()["poisoned_rejects"] == 1

    # -inf on a dead atom is the padding contract: accepted, sanitized
    a_dead = np.array([1.0, 0.0], np.float32)
    assert cache.store(sk, fk, np.array([0.1, -np.inf]), np.zeros(2),
                       a_dead, b)
    hit = cache.lookup(sk, fk)
    assert hit is not None and np.isfinite(np.asarray(hit.f)).all()


def test_warmstart_evicts_poison_at_lookup():
    cache = WarmStartCache()
    sk, fk = b"s", b"f"
    cache.store(sk, fk, np.array([np.nan, 1.0]), np.zeros(2),
                validate=False)         # simulated corrupted snapshot
    assert len(cache) == 1
    assert cache.lookup(sk, fk) is None
    assert len(cache) == 0
    assert cache.snapshot()["poisoned_evictions"] == 1


def test_service_diverged_solve_never_poisons_next_request():
    # regression: pre-fix, a diverged solve stored NaN potentials and the
    # exact repeat warm-started from them
    svc = OTService(eps=EPS, method="factored", tol=1e-6, max_iter=50,
                    max_batch=1)
    bad = _problem(10, 9, seed=11, nan_row=3)
    t1 = svc.submit(bad)
    svc.drain()
    assert t1.health.failed          # served as-is: no recovery configured
    assert svc.warm.snapshot()["poisoned_rejects"] >= 1

    t2 = svc.submit(bad)             # exact repeat must cold-solve
    svc.drain()
    assert not t2.warm_hit


# -- admission shedding (satellite: bounded queue depth) ----------------------


def test_admission_queue_sheds_at_max_depth():
    with pytest.raises(ValueError, match="max_depth"):
        AdmissionQueue(max_depth=0)
    q = AdmissionQueue(max_batch=8, max_wait=10.0, max_depth=2)
    q.add("cell", "r0", now=0.0)
    q.add("cell", "r1", now=0.0)
    assert q.full
    with pytest.raises(QueueFullError):
        q.add("cell", "r2", now=0.0)
    assert q.shed == 1 and len(q) == 2
    # draining restores capacity
    q.pop_due(now=0.0, force=True)
    q.add("cell", "r3", now=0.0)
    assert q.shed == 1 and len(q) == 1


def test_admission_survives_clock_skew():
    # a skewed `now` can run BACKWARDS between reads; aging must neither
    # crash nor wedge the group
    q = AdmissionQueue(max_batch=4, max_wait=0.5)
    q.add("cell", "r0", now=10.0)
    assert q.pop_due(now=9.7) == []          # clock went backwards
    assert q.next_deadline() == pytest.approx(10.5)
    due = q.pop_due(now=10.6)                # recovered past the deadline
    assert [k for k, _ in due] == ["cell"]
    inj = ChaosInjector(ChaosSpec(seed=1, clock_skew_s=0.01))
    base = [100.0]
    skewed = inj.skewed(lambda: base[0])
    reads = [skewed() for _ in range(32)]
    assert all(abs(r - 100.0) <= 0.01 for r in reads)
    assert inj.clock_reads == 32


# -- quarantine ---------------------------------------------------------------


def test_service_quarantines_repeat_offenders():
    svc = OTService(eps=EPS, method="factored", tol=1e-4, max_iter=40,
                    max_batch=1, quarantine_after=2,
                    recovery=RecoveryPolicy(
                        rungs=("log_domain", "cold_restart"),
                        max_attempts=2))
    bad = _problem(10, 9, seed=13, nan_row=2)
    for _ in range(2):
        t = svc.submit(bad)
        svc.drain()
        assert t.refusal is not None
    with pytest.raises(QuarantineError):
        svc.submit(bad)
    s = svc.stats()
    assert s["recovery"]["quarantine_rejects"] == 1
    assert s["recovery"]["quarantined"] == 1
    # a DIFFERENT request is unaffected
    t_ok = svc.submit(_problem(10, 9, seed=14))
    svc.drain()
    assert t_ok.health.ok


# -- chaos injector determinism -----------------------------------------------


def test_chaos_spec_validation_and_determinism():
    with pytest.raises(ValueError, match="partition"):
        ChaosSpec(nan_feature_frac=0.8, inf_feature_frac=0.3)
    s = ChaosSpec(seed=5, nan_feature_frac=0.25, inf_feature_frac=0.125,
                  nan_weight_frac=0.125)
    assigned = ChaosInjector(s).assign_faults(16)
    assert assigned == ChaosInjector(s).assign_faults(16)   # replayable
    assert assigned.count("nan_feature") == 4
    assert assigned.count("inf_feature") == 2
    assert assigned.count("nan_weight") == 2
    assert assigned.count("") == 8

    inj = ChaosInjector(s)
    xi = np.ones((6, 3), np.float32)
    out = inj.corrupt_features(xi, "nan_feature")
    assert np.isnan(out).any() and np.isfinite(xi).all()    # copy, not alias
    assert int(np.isnan(out).any(axis=1).sum()) == 1        # one row
    w = inj.corrupt_weights(np.ones(5, np.float32))
    assert int(np.isnan(w).sum()) == 1
    stats = inj.stats()
    assert stats["nan_feature"] == 1 and stats["inf_feature"] == 0
    assert stats["nan_weight"] == 1 and stats["runner_faults"] == 0


def test_chaos_fault_hook_raises_and_counts():
    inj = ChaosInjector(ChaosSpec(seed=0, runner_fault_frac=1.0,
                                  nan_feature_frac=0.0,
                                  inf_feature_frac=0.0,
                                  nan_weight_frac=0.0))
    hook = inj.fault_hook()
    with pytest.raises(RuntimeError, match="chaos"):
        hook((16, 16, 8), 2)
    assert inj.runner_faults == 1


# -- streaming resilience -----------------------------------------------------


def _streams(n=10, m=9, r=6, seed=21):
    rng = np.random.default_rng(seed)
    feats = lambda k: np.asarray(rng.uniform(0.05, 1.05, (k, r)), np.float32)
    w = lambda k: np.asarray(rng.uniform(0.5, 1.5, k), np.float32)
    dx = StreamingDistribution.from_features(
        [f"x{i}" for i in range(n)], feats(n), w(n), eps=EPS, page_size=8)
    dy = StreamingDistribution.from_features(
        [f"y{i}" for i in range(m)], feats(m), w(m), eps=EPS, page_size=8)
    return dx, dy


def test_streaming_warm_reset_and_cold_fallback():
    solver = StreamingSolver(method="scaling", tol=1e-6, max_iter=500)
    pair = solver.register("p", *_streams())
    solver.warmup(pair)
    res = solver.re_solve(pair)
    assert pair.last_health.finite and np.isfinite(float(res.cost))
    cost_good = float(res.cost)

    # NaN entries in the persisted potentials: sanitized BEFORE the solve
    pair.f = np.where(np.arange(pair.f.shape[0]) % 3 == 0, np.nan,
                      pair.f).astype(np.float32)
    res = solver.re_solve(pair)
    assert solver.warm_resets > 0 and pair.last_health.finite
    assert abs(float(res.cost) - cost_good) <= 1e-5 * (1 + abs(cost_good))

    # finite-but-absurd potentials overflow the scaling warm start: the
    # retry reruns COLD through the same runner and succeeds
    traces0 = solver.traces
    pair.f = np.full(pair.f.shape, 1e30, np.float32)
    res = solver.re_solve(pair)
    assert solver.cold_fallbacks == 1 and pair.last_health.finite
    assert solver.traces == traces0          # no retrace for the fallback
    assert abs(float(res.cost) - cost_good) <= 1e-5 * (1 + abs(cost_good))


def test_streaming_store_rejects_nonfinite_rows():
    # NaN slips past a bare `<= 0` check (NaN <= 0 is False): the store
    # must reject non-finite rows at its only write boundary, because a
    # NaN row in a LIVE page cannot be scrubbed by weight masking
    dx, _ = _streams()
    for bad in (np.nan, np.inf):
        with pytest.raises(ValueError, match="finite"):
            dx.add(["poison"], feats=np.full((1, 6), bad, np.float32),
                   weights=np.ones(1, np.float32))
    with pytest.raises(ValueError, match="finite"):
        dx.add(["poison"], feats=np.ones((1, 6), np.float32),
               weights=np.full(1, np.nan, np.float32))


def test_streaming_terminal_divergence_resets_state():
    solver = StreamingSolver(method="scaling", tol=1e-6, max_iter=100)
    pair = solver.register("p", *_streams(seed=22))
    solver.warmup(pair)
    solver.re_solve(pair)
    assert pair.f is not None

    # a denormal feature row underflows its kernel contraction to exactly
    # 0 (a/0 = inf on the live atom): warm AND cold solves fail, so the
    # persisted potentials must drop — the poison dies with this solve
    pair.x.add(["poison"], feats=np.full((1, 6), 1e-44, np.float32),
               weights=np.ones(1, np.float32))
    solver.re_solve(pair)
    assert pair.last_health.failed
    assert solver.diverged == 1 and solver.state_resets == 1
    assert solver.cold_fallbacks == 1
    assert pair.f is None and pair.g is None

    # removing the poison heals: the stale row is now a DEAD slot, which
    # the masked scaling step pins to 0 (never 0/0), and the next solve
    # cold-starts healthy
    pair.x.remove(["poison"])
    res = solver.re_solve(pair)
    assert pair.last_health.finite and np.isfinite(float(res.cost))
    assert pair.f is not None
    for key in ("diverged", "cold_fallbacks", "state_resets",
                "warm_resets"):
        assert key in solver.stats()


# -- training-step admission guard --------------------------------------------


def test_supervisor_admit_step_guard():
    sup = TrainingSupervisor(None, FaultToleranceConfig(
        max_consecutive_skips=2))
    assert sup.admit_step({"loss": 1.25, "ot": 0.3, "tag": "warmup"})
    assert sup.skipped_steps == 0

    assert not sup.admit_step({"loss": 1.2, "ot": float("nan")})
    assert not sup.admit_step({"loss": float("inf"), "ot": 0.2})
    assert sup.skipped_steps == 2 and sup.consecutive_skips == 2

    # a finite step resets the streak (but not the total)
    assert sup.admit_step({"loss": 1.1, "ot": 0.2})
    assert sup.consecutive_skips == 0 and sup.skipped_steps == 2

    # a streak past the bound aborts instead of spinning forever
    assert not sup.admit_step({"loss": float("nan")})
    assert not sup.admit_step({"loss": float("nan")})
    with pytest.raises(RuntimeError, match="consecutive"):
        sup.admit_step({"loss": float("nan")})
