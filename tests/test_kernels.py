"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.features import GaussianFeatureMap
from repro.kernels import (
    feature_contract,
    fused_sinkhorn_iteration,
    gaussian_feature_map,
    log_matvec,
    sinkhorn_halfstep,
)
from repro.kernels import ref


@pytest.mark.parametrize("n,r,d", [
    (8, 8, 2), (130, 60, 5), (256, 512, 16), (300, 100, 64), (17, 513, 3),
])
def test_feature_map_shapes(n, r, d):
    key = jax.random.PRNGKey(n + r + d)
    x = jax.random.normal(key, (n, d))
    fm = GaussianFeatureMap(r=r, d=d, eps=0.6, R=3.0)
    U = fm.init(jax.random.fold_in(key, 1))
    logc = (0.25 * d * jnp.log(2 * fm.q)
            + jnp.sum(U * U, -1) / (fm.q * 0.6) - 0.5 * jnp.log(float(r)))
    out = gaussian_feature_map(x, U, logc, inv_eps=1 / 0.6, interpret=True)
    want = ref.gaussian_feature_map_ref(x, U, logc, inv_eps=1 / 0.6)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=1e-6)


@pytest.mark.parametrize("n,r,B", [
    (16, 8, 1), (513, 60, 3), (1024, 512, 4), (100, 1000, 2),
])
def test_feature_contract_shapes(n, r, B):
    key = jax.random.PRNGKey(n * 7 + r)
    xi = jax.random.uniform(key, (n, r)) + 0.05
    u = jax.random.uniform(jax.random.fold_in(key, 1), (n, B)) + 0.05
    out = feature_contract(xi, u, interpret=True)
    want = ref.feature_contract_ref(xi, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=1e-5)


@pytest.mark.parametrize("m,r,B", [
    (16, 8, 1), (500, 64, 3), (1025, 256, 2),
])
def test_halfstep_shapes(m, r, B):
    key = jax.random.PRNGKey(m + r + B)
    zeta = jax.random.uniform(key, (m, r)) + 0.05
    t = jax.random.uniform(jax.random.fold_in(key, 1), (r, B)) + 0.05
    marg = jax.random.uniform(jax.random.fold_in(key, 2), (m, B)) + 0.5
    out = sinkhorn_halfstep(zeta, t, marg, interpret=True)
    want = ref.sinkhorn_halfstep_ref(zeta, t, marg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=1e-6)


@pytest.mark.parametrize("m,r", [(16, 8), (500, 64), (1023, 300)])
def test_log_matvec_shapes(m, r):
    key = jax.random.PRNGKey(m * 3 + r)
    log_m = jax.random.normal(key, (m, r)) * 3.0
    t = jax.random.normal(jax.random.fold_in(key, 1), (r,)) * 2.0
    out = log_matvec(log_m, t, interpret=True)
    want = ref.log_matvec_ref(log_m, t)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32])
def test_fused_iteration_converges_like_reference(dtype):
    """Run 50 fused Pallas iterations; marginals must match the jnp loop."""
    key = jax.random.PRNGKey(0)
    n, m, r, B = 64, 48, 32, 2
    xi = (jax.random.uniform(key, (n, r)) + 0.05).astype(dtype)
    zeta = (jax.random.uniform(jax.random.fold_in(key, 1), (m, r)) + 0.05
            ).astype(dtype)
    a = jnp.full((n, B), 1.0 / n, dtype)
    b = jnp.full((m, B), 1.0 / m, dtype)
    u_k = jnp.ones((n, B), dtype)
    u_r = jnp.ones((n, B), dtype)
    v_r = None
    for _ in range(50):
        u_k, v_k = fused_sinkhorn_iteration(xi, zeta, a, b, u_k,
                                            interpret=True)
        t = xi.T @ u_r
        v_r = b / (zeta @ t)
        u_r = a / (xi @ (zeta.T @ v_r))
    np.testing.assert_allclose(np.asarray(u_k), np.asarray(u_r), rtol=1e-3)
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_r), rtol=1e-3)
    # marginal feasibility of the final plan
    col = v_k * (zeta @ (xi.T @ u_k))
    np.testing.assert_allclose(np.asarray(col), np.asarray(b), atol=1e-4)


def test_feature_map_dtype_bf16_inputs():
    """bf16 inputs upcast inside the kernel; output stays f32-accurate."""
    n, r, d = 64, 64, 8
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, d)).astype(jnp.bfloat16)
    fm = GaussianFeatureMap(r=r, d=d, eps=1.0, R=3.0)
    U = fm.init(jax.random.fold_in(key, 1)).astype(jnp.bfloat16)
    logc = jnp.zeros((r,), jnp.float32)
    out = gaussian_feature_map(x.astype(jnp.float32),
                               U.astype(jnp.float32), logc,
                               inv_eps=1.0, interpret=True)
    want = ref.gaussian_feature_map_ref(x.astype(jnp.float32),
                                        U.astype(jnp.float32), logc,
                                        inv_eps=1.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-3,
                               atol=1e-5)


def test_fused_batched_iteration_matches_reference():
    """Per-problem-features batched Pallas iteration (the TPU lowering of
    the BatchedSinkhorn hot loop) vs the plain jnp math, problem by
    problem."""
    from repro.kernels import fused_batched_sinkhorn_iteration

    key = jax.random.PRNGKey(3)
    B, n, m, r = 3, 64, 48, 32
    xi = jax.random.uniform(key, (B, n, r)) + 0.05
    zeta = jax.random.uniform(jax.random.fold_in(key, 1), (B, m, r)) + 0.05
    a = jnp.full((B, n), 1.0 / n)
    b = jnp.full((B, m), 1.0 / m)
    u = jnp.ones((B, n))
    for _ in range(5):
        u, v = fused_batched_sinkhorn_iteration(xi, zeta, a, b, u,
                                                interpret=True)
    for i in range(B):
        u_r = jnp.ones((n,))
        for _ in range(5):
            v_r = b[i] / (zeta[i] @ (xi[i].T @ u_r))
            u_r = a[i] / (xi[i] @ (zeta[i].T @ v_r))
        np.testing.assert_allclose(np.asarray(u[i]), np.asarray(u_r),
                                   rtol=1e-4)
        np.testing.assert_allclose(np.asarray(v[i]), np.asarray(v_r),
                                   rtol=1e-4)
