"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.features import GaussianFeatureMap
from repro.kernels import (
    feature_contract,
    feature_matvec,
    fused_log_sinkhorn_iteration,
    fused_sinkhorn_iteration,
    gaussian_feature_map,
    log_feature_contract,
    log_halfstep,
    log_matvec,
    sinkhorn_halfstep,
)
from repro.kernels import ref
from repro.kernels.tiling import pad_axis, pick_block


@pytest.mark.parametrize("n,r,d", [
    (8, 8, 2), (130, 60, 5), (256, 512, 16), (300, 100, 64), (17, 513, 3),
])
def test_feature_map_shapes(n, r, d):
    key = jax.random.PRNGKey(n + r + d)
    x = jax.random.normal(key, (n, d))
    fm = GaussianFeatureMap(r=r, d=d, eps=0.6, R=3.0)
    U = fm.init(jax.random.fold_in(key, 1))
    logc = (0.25 * d * jnp.log(2 * fm.q)
            + jnp.sum(U * U, -1) / (fm.q * 0.6) - 0.5 * jnp.log(float(r)))
    out = gaussian_feature_map(x, U, logc, inv_eps=1 / 0.6, backend="interpret")
    want = ref.gaussian_feature_map_ref(x, U, logc, inv_eps=1 / 0.6)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=1e-6)


@pytest.mark.parametrize("n,r,B", [
    (16, 8, 1), (513, 60, 3), (1024, 512, 4), (100, 1000, 2),
])
def test_feature_contract_shapes(n, r, B):
    key = jax.random.PRNGKey(n * 7 + r)
    xi = jax.random.uniform(key, (n, r)) + 0.05
    u = jax.random.uniform(jax.random.fold_in(key, 1), (n, B)) + 0.05
    out = feature_contract(xi, u, backend="interpret")
    want = ref.feature_contract_ref(xi, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=1e-5)


@pytest.mark.parametrize("m,r,B", [
    (16, 8, 1), (500, 64, 3), (1025, 256, 2),
])
def test_halfstep_shapes(m, r, B):
    key = jax.random.PRNGKey(m + r + B)
    zeta = jax.random.uniform(key, (m, r)) + 0.05
    t = jax.random.uniform(jax.random.fold_in(key, 1), (r, B)) + 0.05
    marg = jax.random.uniform(jax.random.fold_in(key, 2), (m, B)) + 0.5
    out = sinkhorn_halfstep(zeta, t, marg, backend="interpret")
    want = ref.sinkhorn_halfstep_ref(zeta, t, marg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=1e-6)


@pytest.mark.parametrize("m,r", [(16, 8), (500, 64), (1023, 300)])
def test_log_matvec_shapes(m, r):
    key = jax.random.PRNGKey(m * 3 + r)
    log_m = jax.random.normal(key, (m, r)) * 3.0
    t = jax.random.normal(jax.random.fold_in(key, 1), (r,)) * 2.0
    out = log_matvec(log_m, t, backend="interpret")
    want = ref.log_matvec_ref(log_m, t)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32])
def test_fused_iteration_converges_like_reference(dtype):
    """Run 50 fused Pallas iterations; marginals must match the jnp loop."""
    key = jax.random.PRNGKey(0)
    n, m, r, B = 64, 48, 32, 2
    xi = (jax.random.uniform(key, (n, r)) + 0.05).astype(dtype)
    zeta = (jax.random.uniform(jax.random.fold_in(key, 1), (m, r)) + 0.05
            ).astype(dtype)
    a = jnp.full((n, B), 1.0 / n, dtype)
    b = jnp.full((m, B), 1.0 / m, dtype)
    u_k = jnp.ones((n, B), dtype)
    u_r = jnp.ones((n, B), dtype)
    v_r = None
    for _ in range(50):
        u_k, v_k = fused_sinkhorn_iteration(xi, zeta, a, b, u_k,
                                            backend="interpret")
        t = xi.T @ u_r
        v_r = b / (zeta @ t)
        u_r = a / (xi @ (zeta.T @ v_r))
    np.testing.assert_allclose(np.asarray(u_k), np.asarray(u_r), rtol=1e-3)
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_r), rtol=1e-3)
    # marginal feasibility of the final plan
    col = v_k * (zeta @ (xi.T @ u_k))
    np.testing.assert_allclose(np.asarray(col), np.asarray(b), atol=1e-4)


# ---------------------------------------------------------------------------
# Lane-padding regression sweep: odd r / B (TPU tiles quantize the trailing
# dim to 128 — these shapes exercise the neutral-fill padding of every
# kernel, including the B=1 single-problem solver shape)
# ---------------------------------------------------------------------------


ODD_SHAPES = [(19, 3, 1), (19, 3, 5), (200, 129, 5), (64, 127, 2)]


@pytest.mark.parametrize("n,r,B", ODD_SHAPES)
def test_lane_padding_parity_scaling_kernels(n, r, B):
    key = jax.random.PRNGKey(n * 11 + r + B)
    xi = jax.random.uniform(key, (n, r)) + 0.05
    u = jax.random.uniform(jax.random.fold_in(key, 1), (n, B)) + 0.05
    t = jax.random.uniform(jax.random.fold_in(key, 2), (r, B)) + 0.05
    marg = jax.random.uniform(jax.random.fold_in(key, 3), (n, B)) + 0.5
    np.testing.assert_allclose(
        np.asarray(feature_contract(xi, u, backend="interpret")),
        np.asarray(ref.feature_contract_ref(xi, u)), rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(sinkhorn_halfstep(xi, t, marg, backend="interpret")),
        np.asarray(ref.sinkhorn_halfstep_ref(xi, t, marg)),
        rtol=2e-4, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(feature_matvec(xi, t, backend="interpret")),
        np.asarray(xi @ t), rtol=2e-4, atol=1e-6)


@pytest.mark.parametrize("n,r,B", ODD_SHAPES)
def test_lane_padding_parity_log_kernels(n, r, B):
    key = jax.random.PRNGKey(n * 7 + r * 3 + B)
    lw = jax.random.normal(key, (n, r)) * 3.0
    s = jax.random.normal(jax.random.fold_in(key, 1), (n, B)) * 2.0
    t = jax.random.normal(jax.random.fold_in(key, 2), (r, B)) * 2.0
    lmarg = jax.random.normal(jax.random.fold_in(key, 3), (n, B))
    out_c = log_feature_contract(lw, s, backend="interpret")
    np.testing.assert_allclose(
        np.asarray(out_c), np.asarray(ref.log_feature_contract_ref(lw, s)),
        rtol=1e-4, atol=1e-4)
    out_h = log_halfstep(lw, t, lmarg, scale=0.37, backend="interpret")
    np.testing.assert_allclose(
        np.asarray(out_h),
        np.asarray(ref.log_halfstep_ref(lw, t, lmarg, scale=0.37)),
        rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,r", [(19, 3), (64, 127), (33, 129)])
def test_log_matvec_odd_rank_lane_padding(m, r):
    """r is the trailing (lane) dim of log_m — padding fills with -inf, the
    logsumexp identity, so odd ranks match the oracle exactly."""
    key = jax.random.PRNGKey(m + r)
    log_m = jax.random.normal(key, (m, r)) * 3.0
    t = jax.random.normal(jax.random.fold_in(key, 1), (r,)) * 2.0
    np.testing.assert_allclose(
        np.asarray(log_matvec(log_m, t, backend="interpret")),
        np.asarray(ref.log_matvec_ref(log_m, t)), rtol=1e-5, atol=1e-5)


def test_log_kernels_masked_neutral_entries():
    """-inf log-features (zero-weight / padded atoms) are the LSE identity:
    rows carrying them contribute nothing and produce no NaNs."""
    n, r, B = 12, 5, 2
    key = jax.random.PRNGKey(0)
    lw = jax.random.normal(key, (n, r))
    lw = lw.at[3, :].set(-jnp.inf)          # fully masked feature row
    s = jax.random.normal(jax.random.fold_in(key, 1), (n, B))
    s = s.at[5, :].set(-jnp.inf)            # masked potential (zero weight)
    out = log_feature_contract(lw, s, backend="interpret")
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.log_feature_contract_ref(lw, s)),
        rtol=1e-4, atol=1e-4)
    assert np.all(np.isfinite(np.asarray(out)))


def test_fused_log_iteration_matches_xla_two_stage():
    """One fused log iteration == the exact two-stage LSE update."""
    n, m, r, B, eps = 40, 30, 16, 3, 0.5
    key = jax.random.PRNGKey(2)
    lxi = jax.random.normal(key, (n, r))
    lzt = jax.random.normal(jax.random.fold_in(key, 1), (m, r))
    loga = jnp.log(jnp.full((n, B), 1.0 / n))
    logb = jnp.log(jnp.full((m, B), 1.0 / m))
    f = jax.random.normal(jax.random.fold_in(key, 2), (n, B))
    f_new, g = fused_log_sinkhorn_iteration(
        lxi, lzt, loga, logb, f, eps=eps, backend="interpret")
    lse = jax.scipy.special.logsumexp
    for c in range(B):
        t = lse(lxi + (f[:, c] / eps)[:, None], axis=0)
        g_ref = eps * (logb[:, c] - lse(lzt + t[None, :], axis=1))
        t2 = lse(lzt + (g_ref / eps)[:, None], axis=0)
        f_ref = eps * (loga[:, c] - lse(lxi + t2[None, :], axis=1))
        np.testing.assert_allclose(np.asarray(g[:, c]), np.asarray(g_ref),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(f_new[:, c]),
                                   np.asarray(f_ref), rtol=1e-4, atol=1e-5)


def test_feature_map_log_space_epilogue():
    """log_space=True skips the exp: output == log of the linear features,
    with padded anchors at exactly -inf upstream (neutral for LSE)."""
    n, r, d = 50, 7, 3
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (n, d))
    fm = GaussianFeatureMap(r=r, d=d, eps=0.7, R=3.0)
    U = fm.init(jax.random.fold_in(key, 1))
    logc = jnp.zeros((r,), jnp.float32)
    lin = gaussian_feature_map(x, U, logc, inv_eps=1 / 0.7, backend="interpret")
    log = gaussian_feature_map(x, U, logc, inv_eps=1 / 0.7, backend="interpret",
                               log_space=True)
    np.testing.assert_allclose(np.asarray(jnp.exp(log)), np.asarray(lin),
                               rtol=2e-4, atol=1e-6)


def test_tiling_helpers():
    assert pick_block(3) == 128
    assert pick_block(129) == 256
    assert pick_block(4096) == 512          # capped
    assert pick_block(200, cap=256) == 256
    arr = jnp.ones((5, 3))
    padded = pad_axis(arr, 1, 128, value=-jnp.inf)
    assert padded.shape == (5, 128)
    assert bool(jnp.all(jnp.isinf(padded[:, 3:])))
    assert pad_axis(arr, 0, 5) is arr       # already aligned: no copy


def test_feature_map_dtype_bf16_inputs():
    """bf16 inputs upcast inside the kernel; output stays f32-accurate."""
    n, r, d = 64, 64, 8
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, d)).astype(jnp.bfloat16)
    fm = GaussianFeatureMap(r=r, d=d, eps=1.0, R=3.0)
    U = fm.init(jax.random.fold_in(key, 1)).astype(jnp.bfloat16)
    logc = jnp.zeros((r,), jnp.float32)
    out = gaussian_feature_map(x.astype(jnp.float32),
                               U.astype(jnp.float32), logc,
                               inv_eps=1.0, backend="interpret")
    want = ref.gaussian_feature_map_ref(x.astype(jnp.float32),
                                        U.astype(jnp.float32), logc,
                                        inv_eps=1.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-3,
                               atol=1e-5)


def test_fused_batched_iteration_matches_reference():
    """Per-problem-features batched Pallas iteration (the TPU lowering of
    the BatchedSinkhorn hot loop) vs the plain jnp math, problem by
    problem."""
    from repro.kernels import fused_batched_sinkhorn_iteration

    key = jax.random.PRNGKey(3)
    B, n, m, r = 3, 64, 48, 32
    xi = jax.random.uniform(key, (B, n, r)) + 0.05
    zeta = jax.random.uniform(jax.random.fold_in(key, 1), (B, m, r)) + 0.05
    a = jnp.full((B, n), 1.0 / n)
    b = jnp.full((B, m), 1.0 / m)
    u = jnp.ones((B, n))
    for _ in range(5):
        u, v = fused_batched_sinkhorn_iteration(xi, zeta, a, b, u,
                                                backend="interpret")
    for i in range(B):
        u_r = jnp.ones((n,))
        for _ in range(5):
            v_r = b[i] / (zeta[i] @ (xi[i].T @ u_r))
            u_r = a[i] / (xi[i] @ (zeta[i].T @ v_r))
        np.testing.assert_allclose(np.asarray(u[i]), np.asarray(u_r),
                                   rtol=1e-4)
        np.testing.assert_allclose(np.asarray(v[i]), np.asarray(v_r),
                                   rtol=1e-4)
