"""Positive-feature maps: unbiasedness, positivity, ratio concentration."""
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    arccos_features,
    gaussian_features,
    gaussian_log_features,
    gaussian_q,
    lambert_w0,
    squared_euclidean,
)
from repro.core.features import ArcCosineFeatureMap, GaussianFeatureMap


def test_lambert_w0():
    for z in (0.0, 1e-6, 0.5, 1.0, math.e, 10.0, 1e4):
        w = lambert_w0(z)
        assert abs(w * math.exp(w) - z) < 1e-9 * (1 + z)


def test_gaussian_features_positive_and_unbiased():
    key = jax.random.PRNGKey(0)
    d, eps, R = 2, 0.7, 2.0
    fm = GaussianFeatureMap(r=60000, d=d, eps=eps, R=R)
    U = fm.init(key)
    x = jnp.array([[0.5, -0.3], [1.2, 0.8], [-1.0, 0.1]])
    xi = gaussian_features(x, U, eps=eps, q=fm.q)
    assert bool(jnp.all(xi > 0))
    K_hat = xi @ xi.T
    K_true = jnp.exp(-squared_euclidean(x, x) / eps)
    np.testing.assert_allclose(np.asarray(K_hat), np.asarray(K_true),
                               rtol=0.08)


def test_ratio_concentration_improves_with_r():
    """Prop 3.1: sup |k_theta/k - 1| decreases with the number of features."""
    key = jax.random.PRNGKey(1)
    kx, ky = jax.random.split(key)
    d, eps, R = 2, 0.9, 2.0
    x = jnp.clip(jax.random.normal(kx, (40, d)), -1.2, 1.2)
    y = jnp.clip(jax.random.normal(ky, (40, d)), -1.2, 1.2)
    K = jnp.exp(-squared_euclidean(x, y) / eps)
    sups = []
    for r in (100, 1000, 10000):
        fm = GaussianFeatureMap(r=r, d=d, eps=eps, R=R)
        U = fm.init(jax.random.PRNGKey(5))
        xi = gaussian_features(x, U, eps=eps, q=fm.q)
        zeta = gaussian_features(y, U, eps=eps, q=fm.q)
        ratio = (xi @ zeta.T) / K
        sups.append(float(jnp.max(jnp.abs(ratio - 1.0))))
    assert sups[2] < sups[0], sups


def test_gaussian_log_features_match_exp():
    fm = GaussianFeatureMap(r=32, d=4, eps=0.5, R=1.5)
    U = fm.init(jax.random.PRNGKey(2))
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(3), (10, 4))
    lf = gaussian_log_features(x, U, eps=0.5, q=fm.q)
    f = gaussian_features(x, U, eps=0.5, q=fm.q)
    np.testing.assert_allclose(np.asarray(jnp.exp(lf)), np.asarray(f),
                               rtol=1e-6)


def test_arccos_features_positive_kernel_floor():
    fm = ArcCosineFeatureMap(r=2000, d=3, s=1, sigma=1.4, kappa=0.05)
    U = fm.init(jax.random.PRNGKey(4))
    x = jax.random.normal(jax.random.PRNGKey(5), (20, 3))
    phi = arccos_features(x, U, s=1, sigma=1.4, kappa=0.05)
    K = phi @ phi.T
    assert bool(jnp.all(K >= 0.05 - 1e-6))      # kappa floor (Lemma 3)


def test_arccos_matches_closed_form_s1():
    """k_1(x,y) = ||x|| ||y|| (sin t + (pi - t) cos t) / pi  (Cho & Saul)."""
    fm = ArcCosineFeatureMap(r=200000, d=2, s=1, sigma=1.3, kappa=0.0)
    U = fm.init(jax.random.PRNGKey(6))
    x = jnp.array([[1.0, 0.0], [0.6, 0.8]])
    phi = arccos_features(x, U, s=1, sigma=1.3, kappa=0.0)
    K = (phi @ phi.T)
    t = jnp.arccos(jnp.clip(x[0] @ x[1], -1, 1))
    closed = (jnp.sin(t) + (jnp.pi - t) * jnp.cos(t)) / jnp.pi
    np.testing.assert_allclose(float(K[0, 1]), float(closed), rtol=0.1)


def test_q_balances_amplitude():
    # Lemma 1's q keeps psi = 2(2q)^{d/2} moderate as eps shrinks
    for eps in (1.0, 0.1, 0.01):
        q = gaussian_q(1.0, eps, 4)
        assert q > 0.5
        assert np.isfinite(q)
