"""Sharded-vs-single-device parity matrix on 8 virtual CPU devices.

Each test spawns ``python -c`` with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the parent keeps
the single real device — see conftest note). CI's ``multi-device`` job
runs this module plus ``test_distributed.py`` on every PR so the SPMD
code paths are exercised without real meshes.

Covers the tentpole contracts:
  * log-domain sharded solver == ``sinkhorn_log_geometry`` to <= 1e-6 rel
    (iterates AND cost) at eps = 0.01, where the scaling-space sharded
    path over/underflows — the acceptance criterion;
  * the scaling/log x factored/gaussian/arccos parity matrix, with
    warm-started second solves and uneven ``n % p != 0`` supports;
  * pad-safety at ``ot_bucket``-padded shapes with zero-weight rows
    landing on >= 2 shards (regression: the old ``_sharded_body``
    initialized u0 = v0 = ones and never masked zero-weight atoms);
  * ``rot_geometry``'s envelope VJP under ``shard_map`` (psum'd dual
    value replicated; feature gradients match single-device);
  * the sharded Sinkhorn divergence and its gradients, including the
    REPLICATED shared anchors;
  * ``solve(mesh=)`` auto-dispatch and ``solve_many(mesh=)``.
"""
import os
import subprocess
import sys
import textwrap

_ENV = {**os.environ,
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src")}

_PRELUDE = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.core import (
        ArcCosinePointCloud, FactoredPositive, GaussianPointCloud,
        OTProblem, sharded_sinkhorn_geometry, sinkhorn_geometry,
        sinkhorn_log_geometry, solve, solve_many,
    )
    key = jax.random.PRNGKey(0)
    mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))

    def clouds(n, m, d=2, scale=0.5):
        x = jax.random.normal(key, (n, d)) * scale
        y = jax.random.normal(jax.random.fold_in(key, 1), (m, d)) * scale
        return x, y

    def uniform(n, m):
        return jnp.full((n,), 1.0 / n), jnp.full((m,), 1.0 / m)
"""


def _run(code: str):
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_PRELUDE + code)],
        env=_ENV, capture_output=True, text=True, timeout=600,
    )
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return res.stdout


def test_log_sharded_matches_single_device_at_small_eps():
    """ACCEPTANCE: at eps = 0.01 the log-domain sharded solver matches
    ``sinkhorn_log_geometry`` iterates and cost to <= 1e-6 rel on 8
    devices — the regime where the scaling-space sharded path is not even
    runnable (exp(-C/eps) under/overflows)."""
    _run("""
        eps = 0.01
        n, m, r = 96, 80, 64
        x, y = clouds(n, m)
        anchors = jax.random.normal(jax.random.fold_in(key, 2), (r, 2)) * 0.5
        a, b = uniform(n, m)
        geom = GaussianPointCloud.build(x, y, anchors, eps=eps, R=2.0)
        # fixed iteration count -> raw trajectory comparison
        ref = sinkhorn_log_geometry(geom, a, b, tol=0.0, max_iter=250)
        out = sharded_sinkhorn_geometry(mesh, geom, a, b, mode="log",
                                        tol=0.0, max_iter=250)
        scale_f = float(jnp.max(jnp.abs(ref.f)))
        df = float(jnp.max(jnp.abs(out.f - ref.f))) / scale_f
        dg = float(jnp.max(jnp.abs(out.g - ref.g))) / scale_f
        dc = abs(float(out.cost - ref.cost)) / abs(float(ref.cost))
        assert df <= 1e-6 and dg <= 1e-6, (df, dg)
        assert dc <= 1e-6, dc
        # and the scaling-space path really is out of reach at this eps:
        # the Gibbs kernel entries underflow f32, poisoning the scalings
        sc = sharded_sinkhorn_geometry(mesh, geom, a, b, mode="scaling",
                                       tol=1e-6, max_iter=50)
        assert bool(sc.diverged) or not bool(sc.converged)
        print("small-eps log parity OK", df, dg, dc)
    """)


def test_parity_matrix_families_modes_warm_uneven():
    """scaling AND log x factored/gaussian/arccos, warm-started second
    solve, uneven n % 8 != 0 supports — all vs the single-device
    geometry solvers, elementwise on fixed-iteration trajectories."""
    _run("""
        eps = 0.2
        for n, m in ((64, 56), (91, 77)):          # even and uneven shards
            x, y = clouds(n, m)
            anchors = jax.random.normal(
                jax.random.fold_in(key, 2), (32, 2)) * 0.5
            a, b = uniform(n, m)
            xi = jax.random.uniform(key, (n, 24)) + 0.05
            zt = jax.random.uniform(jax.random.fold_in(key, 3), (m, 24)) + 0.05
            fams = dict(
                factored=FactoredPositive(xi=xi, zeta=zt, eps=eps),
                gaussian=GaussianPointCloud.build(x, y, anchors, eps=eps,
                                                  R=2.0),
                arccos=ArcCosinePointCloud(x, y, anchors, eps=eps),
            )
            for fam, geom in fams.items():
                for mode in ("scaling", "log"):
                    runner = (sinkhorn_geometry if mode == "scaling"
                              else sinkhorn_log_geometry)
                    ref = runner(geom, a, b, tol=0.0, max_iter=40)
                    out = sharded_sinkhorn_geometry(
                        mesh, geom, a, b, mode=mode, tol=0.0, max_iter=40)
                    np.testing.assert_allclose(
                        np.asarray(out.g), np.asarray(ref.g),
                        rtol=2e-5, atol=2e-6,
                        err_msg=f"{fam}/{mode}/n{n}")
                    np.testing.assert_allclose(
                        float(out.cost), float(ref.cost), rtol=1e-5,
                        err_msg=f"{fam}/{mode}/n{n}")
                # warm-started second solve (log): must match the
                # single-device warm start AND take fewer iters than cold
                cold = sharded_sinkhorn_geometry(
                    mesh, geom, a, b, mode="log", tol=1e-5, max_iter=2000)
                warm = sharded_sinkhorn_geometry(
                    mesh, geom, a, b, mode="log", tol=1e-5, max_iter=2000,
                    f_init=cold.f, g_init=cold.g)
                ref_warm = sinkhorn_log_geometry(
                    geom, a, b, tol=1e-5, max_iter=2000,
                    f_init=cold.f, g_init=cold.g)
                assert int(warm.n_iter) <= int(cold.n_iter), fam
                np.testing.assert_allclose(
                    float(warm.cost), float(ref_warm.cost), rtol=1e-5,
                    err_msg=f"warm/{fam}/n{n}")
                print("parity OK", fam, n, m)
    """)


def test_pad_safety_zero_weight_rows_across_shards():
    """Regression: zero-weight atoms at ot_bucket-padded shapes, with the
    zero rows landing on >= 2 different shards. The old ``_sharded_body``
    initialized u0 = v0 = ones with no masking; the padded solve must
    match the single-device masked solve elementwise and keep u = 0 /
    f = -inf on every zero-weight atom."""
    _run("""
        from repro.configs.shapes import ot_bucket
        eps = 0.3
        n_live, m_live = 50, 44
        n, m = ot_bucket(n_live), ot_bucket(m_live)       # 64, 64
        assert n % 8 == 0
        xi = jax.random.uniform(key, (n, 16)) + 0.05
        zt = jax.random.uniform(jax.random.fold_in(key, 3), (m, 16)) + 0.05
        # zero weights: the padded tail (shards 7, 8) plus a few interior
        # rows on shard 1 -> zero-weight atoms on >= 3 different shards
        a = jnp.full((n,), 0.0).at[:n_live].set(1.0 / (n_live - 2))
        a = a.at[jnp.array([3, 5])].set(0.0)
        b = jnp.full((m,), 0.0).at[:m_live].set(1.0 / m_live)
        geom = FactoredPositive(xi=xi, zeta=zt, eps=eps)
        for mode, runner in (("scaling", sinkhorn_geometry),
                             ("log", sinkhorn_log_geometry)):
            ref = runner(geom, a, b, tol=1e-6, max_iter=2000)
            out = sharded_sinkhorn_geometry(mesh, geom, a, b, mode=mode,
                                            tol=1e-6, max_iter=2000)
            assert np.isfinite(float(out.cost)), mode
            np.testing.assert_allclose(float(out.cost), float(ref.cost),
                                       rtol=1e-5, err_msg=mode)
            np.testing.assert_allclose(np.asarray(out.u), np.asarray(ref.u),
                                       rtol=2e-4, atol=1e-7, err_msg=mode)
            u = np.asarray(out.u); f = np.asarray(out.f)
            dead = np.asarray(a) == 0
            assert np.all(u[dead] == 0.0), mode
            assert np.all(np.isneginf(f[dead])), mode
            print("pad safety OK", mode, float(out.cost))
    """)


def test_rot_geometry_envelope_vjp_under_shard_map():
    """The generic envelope VJP runs INSIDE shard_map unchanged: the
    psum'd dual value is replicated, and the log-feature gradients match
    the single-device rule (psum's transpose routes every shard's
    contribution into the cotangents)."""
    _run("""
        from jax.sharding import PartitionSpec as P
        from repro.core import rot_geometry
        from repro.core.sharded import RowShardedFactored
        from repro.distributed.sharding import shard_map
        eps, n, m, r = 0.1, 48, 40, 32
        a, b = uniform(n, m)
        lxi = jnp.log(jax.random.uniform(key, (n, r)) + 0.05)
        lzt = jnp.log(jax.random.uniform(jax.random.fold_in(key, 5),
                                         (m, r)) + 0.05)

        def rot_ref(lx, lz):
            return rot_geometry(
                FactoredPositive(log_xi=lx, log_zeta=lz, eps=eps),
                a, b, 1e-6, 2000)

        def rot_sh(lx, lz):
            def body(lx_, lz_, a_, b_):
                g = RowShardedFactored(log_xi=lx_, log_zeta=lz_, eps=eps,
                                       axis="data")
                return rot_geometry(g, a_, b_, 1e-6, 2000)
            fn = shard_map(
                body, mesh=mesh,
                in_specs=(P("data", None), P("data", None),
                          P("data"), P("data")),
                out_specs=P(), check_vma=False)
            return fn(lx, lz, a, b)

        v1, g1 = jax.value_and_grad(rot_ref, argnums=(0, 1))(lxi, lzt)
        v2, g2 = jax.value_and_grad(rot_sh, argnums=(0, 1))(lxi, lzt)
        np.testing.assert_allclose(float(v2), float(v1), rtol=1e-6)
        for name, gr, gs in zip(("log_xi", "log_zeta"), g1, g2):
            np.testing.assert_allclose(np.asarray(gs), np.asarray(gr),
                                       rtol=1e-4, atol=1e-9, err_msg=name)
        print("sharded rot_geometry OK", float(v2))
    """)


def test_sharded_divergence_value_and_gradients():
    """``sinkhorn_divergence_geometry(mesh=...)``: value and gradients —
    including the REPLICATED shared anchors (the GAN theta) — match the
    single-device divergence."""
    _run("""
        from repro.core import sinkhorn_divergence_geometry
        eps, r = 0.1, 32
        anchors = jax.random.normal(jax.random.fold_in(key, 2), (r, 2)) * 0.5
        for n, m in ((48, 40), (53, 41)):      # even and uneven shards
            x, y = clouds(n, m)

            def div(x_, y_, anc, mesh_=None):
                g = GaussianPointCloud.build(x_, y_, anc, eps=eps, R=2.0)
                return sinkhorn_divergence_geometry(
                    g, tol=1e-6, max_iter=2000, mesh=mesh_)

            v1, g1 = jax.value_and_grad(div, argnums=(0, 1, 2))(x, y, anchors)
            v2, g2 = jax.value_and_grad(
                lambda x_, y_, anc: div(x_, y_, anc, mesh))(x, y, anchors)
            np.testing.assert_allclose(float(v2), float(v1), rtol=1e-5,
                                       atol=1e-7)
            for name, gr, gs in zip(("x", "y", "anchors"), g1, g2):
                np.testing.assert_allclose(np.asarray(gs), np.asarray(gr),
                                           rtol=1e-3, atol=1e-6, err_msg=name)
            # uneven pads are exactly inert from iteration 0 (masked
            # _log_init): the fixed-iteration transient matches too
            t1 = div(x, y, anchors)
            t2 = div(x, y, anchors, mesh)
            np.testing.assert_allclose(float(t2), float(t1), rtol=1e-6)
            print("sharded divergence OK", n, m, float(v2))
    """)


def test_solve_mesh_auto_dispatch_and_solve_many():
    """``solve(mesh=)`` auto-selects the sharded twin of the local auto
    table (log for point clouds, scaling for linear factors) and
    ``solve_many(mesh=)`` routes every problem through the mesh."""
    _run("""
        from repro.core.api import _auto_method
        eps, n, m = 0.1, 64, 56
        x, y = clouds(n, m)
        anchors = jax.random.normal(jax.random.fold_in(key, 2), (32, 2)) * 0.5
        cloud_p = OTProblem.from_point_clouds(x, y, anchors, eps=eps, R=2.0)
        xi = jax.random.uniform(key, (n, 24)) + 0.05
        zt = jax.random.uniform(jax.random.fold_in(key, 3), (m, 24)) + 0.05
        feat_p = OTProblem.from_features(xi, zt, eps=0.5)
        assert _auto_method(cloud_p, mesh) == "sharded_log"
        assert _auto_method(feat_p, mesh) == "sharded"
        for p, meth in ((cloud_p, "log_factored"), (feat_p, "factored")):
            ref = solve(p, method=meth, tol=1e-6, max_iter=2000)
            out = solve(p, mesh=mesh, tol=1e-6, max_iter=2000)
            np.testing.assert_allclose(float(out.cost), float(ref.cost),
                                       rtol=1e-5)
        outs = solve_many([cloud_p, cloud_p], method="log_factored",
                          mesh=mesh, tol=1e-6, max_iter=2000)
        refc = float(solve(cloud_p, method="log_factored", tol=1e-6,
                           max_iter=2000).cost)
        for o in outs:
            np.testing.assert_allclose(float(o.cost), refc, rtol=1e-5)
        print("solve(mesh=) auto + solve_many OK")
    """)
