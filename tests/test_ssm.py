"""SSD chunked algorithm vs naive recurrence; decode==train consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import (
    init_mamba2,
    init_mamba2_cache,
    mamba2_decode,
    mamba2_train,
    ssd_chunked,
)


def _ssd_naive(x, dt, A, Bm, Cm):
    """Token-by-token linear recurrence oracle."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    h = jnp.zeros((Bsz, H, P, N))
    ys = []
    for t in range(S):
        decay = jnp.exp(dt[:, t] * A[None, :])              # (B, H)
        upd = jnp.einsum("bhp,bn,bh->bhpn", x[:, t], Bm[:, t], dt[:, t])
        h = h * decay[..., None, None] + upd
        ys.append(jnp.einsum("bhpn,bn->bhp", h, Cm[:, t]))
    return jnp.stack(ys, axis=1), h


@pytest.mark.parametrize("S,chunk", [(16, 4), (17, 4), (32, 32), (30, 7)])
def test_ssd_chunked_matches_naive(S, chunk):
    key = jax.random.PRNGKey(0)
    B, H, P, N = 2, 3, 4, 5
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.5
    y, hf = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    y_ref, h_ref = _ssd_naive(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(h_ref), rtol=2e-4,
                               atol=2e-4)


def test_ssd_initial_state_threading():
    """Splitting a sequence in two with state carry == single pass (the
    context-parallel cross-chunk contract)."""
    key = jax.random.PRNGKey(1)
    B, S, H, P, N = 1, 24, 2, 4, 3
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.5
    y_full, h_full = ssd_chunked(x, dt, A, Bm, Cm, chunk=8)
    y1, h1 = ssd_chunked(x[:, :12], dt[:, :12], A, Bm[:, :12], Cm[:, :12],
                         chunk=8)
    y2, h2 = ssd_chunked(x[:, 12:], dt[:, 12:], A, Bm[:, 12:], Cm[:, 12:],
                         chunk=8, init_state=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=2e-4, atol=2e-4)


def test_ssd_context_parallel_matches_plain():
    """shard_map CP SSD (state relay over 'model') == plain chunked SSD.
    Runs on a 1x1 mesh here; the 8-device version lives in
    tests/test_distributed.py."""
    import numpy as onp
    from jax.sharding import Mesh
    from repro.distributed.sharding import MeshContext, use_mesh_context
    from repro.models.ssm import ssd_context_parallel
    key = jax.random.PRNGKey(3)
    B, S, H, P, N = 2, 32, 2, 4, 3
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.5
    y_ref, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=8)
    mesh = Mesh(onp.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    with mesh, use_mesh_context(MeshContext(mesh)):
        y_cp = ssd_context_parallel(x, dt, A, Bm, Cm, chunk=8)
    np.testing.assert_allclose(np.asarray(y_cp), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


def test_mamba2_decode_matches_train():
    key = jax.random.PRNGKey(2)
    B, S, d = 2, 10, 32
    p = init_mamba2(key, d, d_state=8, head_dim=8, expand=2, conv_kernel=4)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, d)) * 0.5
    full = mamba2_train(p, x, d_state=8, head_dim=8, expand=2, chunk=4)
    cache = init_mamba2_cache(B, d, d_state=8, head_dim=8, expand=2,
                              conv_kernel=4)
    outs = []
    for t in range(S):
        o, cache = mamba2_decode(p, x[:, t:t + 1], cache, d_state=8,
                                 head_dim=8, expand=2)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=3e-3, atol=3e-4)
