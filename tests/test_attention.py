"""Attention correctness: chunked==direct, decode==train prefix, MLA absorb."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    chunked_attention,
    gqa_decode,
    gqa_train,
    init_gqa,
    init_gqa_cache,
    init_mla,
    init_mla_cache,
    mla_decode,
    mla_train,
)


def _direct_attention(q, k, v, n_kv, mask):
    B, Q, H, D = q.shape
    G = H // n_kv
    qg = q.reshape(B, Q, n_kv, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).reshape(B, H, Q, -1)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    pg = p.reshape(B, n_kv, G, Q, -1)
    return jnp.einsum("bkgqs,bskd->bqkgd", pg, v).reshape(B, Q, H, D)


@pytest.mark.parametrize("S,kv_chunk", [(64, 16), (65, 16), (128, 128),
                                        (100, 33)])
def test_chunked_equals_direct(S, kv_chunk):
    key = jax.random.PRNGKey(0)
    B, H, KH, D = 2, 4, 2, 16
    q = jax.random.normal(key, (B, S, H, D)) * 0.3
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KH, D)) * 0.3
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KH, D))
    pos = jnp.arange(S)
    mask = (pos[None, :] <= pos[:, None])[None, None]

    from repro.models.attention import _causal_window_mask, _gqa_score_fn, _gqa_value_fn
    out = chunked_attention(
        q, {"k": k, "v": v}, S,
        score_fn=_gqa_score_fn(KH), value_fn=_gqa_value_fn(KH),
        mask_fn=_causal_window_mask(pos, None), kv_chunk=kv_chunk,
    )
    want = _direct_attention(q, k, v, KH, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_gqa_decode_matches_train():
    """Decoding token-by-token must reproduce the train-mode forward."""
    key = jax.random.PRNGKey(1)
    B, S, d, H, KH, hd = 2, 12, 32, 4, 2, 8
    p = init_gqa(key, d, H, KH, hd)
    x = jax.random.normal(jax.random.fold_in(key, 2), (B, S, d)) * 0.5
    full = gqa_train(p, x, n_heads=H, n_kv=KH, head_dim=hd)
    cache = init_gqa_cache(B, S, KH, hd)
    outs = []
    for t in range(S):
        o, cache = gqa_decode(p, x[:, t:t + 1], cache, n_heads=H, n_kv=KH,
                              head_dim=hd)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-4)


def test_gqa_decode_sliding_window_rolls():
    """Rolling cache (window < S) must equal full-cache attention with the
    window mask."""
    key = jax.random.PRNGKey(2)
    B, S, d, H, KH, hd, W = 1, 20, 16, 2, 2, 8, 6
    p = init_gqa(key, d, H, KH, hd)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, d)) * 0.5
    full = gqa_train(p, x, n_heads=H, n_kv=KH, head_dim=hd, window=W)
    cache = init_gqa_cache(B, S, KH, hd, window=W)
    assert cache.k.shape[1] == W         # rolling buffer, not S
    outs = []
    for t in range(S):
        o, cache = gqa_decode(p, x[:, t:t + 1], cache, n_heads=H, n_kv=KH,
                              head_dim=hd, window=W)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-4)


def test_gqa_attend_step_matches_train():
    """Append-then-write decode (read-only cache + external scatter) must
    equal the train forward — the §Perf decode-hillclimb path."""
    from repro.models.attention import gqa_attend_step
    key = jax.random.PRNGKey(4)
    B, S, d, H, KH, hd = 2, 12, 32, 4, 2, 8
    p = init_gqa(key, d, H, KH, hd)
    x = jax.random.normal(jax.random.fold_in(key, 2), (B, S, d)) * 0.5
    full = gqa_train(p, x, n_heads=H, n_kv=KH, head_dim=hd)
    k_cache = jnp.zeros((B, S, KH, hd))
    v_cache = jnp.zeros((B, S, KH, hd))
    outs = []
    for t in range(S):
        o, k_new, v_new = gqa_attend_step(
            p, x[:, t:t + 1], k_cache, v_cache, jnp.asarray(t),
            n_heads=H, n_kv=KH, head_dim=hd)
        k_cache = k_cache.at[:, t].set(k_new)
        v_cache = v_cache.at[:, t].set(v_new)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-4)


def test_gqa_attend_step_rolling_window():
    from repro.models.attention import gqa_attend_step
    key = jax.random.PRNGKey(5)
    B, S, d, H, KH, hd, W = 1, 20, 16, 2, 2, 8, 6
    p = init_gqa(key, d, H, KH, hd)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, d)) * 0.5
    full = gqa_train(p, x, n_heads=H, n_kv=KH, head_dim=hd, window=W)
    k_cache = jnp.zeros((B, W, KH, hd))
    v_cache = jnp.zeros((B, W, KH, hd))
    outs = []
    for t in range(S):
        o, k_new, v_new = gqa_attend_step(
            p, x[:, t:t + 1], k_cache, v_cache, jnp.asarray(t),
            n_heads=H, n_kv=KH, head_dim=hd, window=W)
        k_cache = k_cache.at[:, t % W].set(k_new)
        v_cache = v_cache.at[:, t % W].set(v_new)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-4)


def test_mla_attend_step_matches_train():
    from repro.models.attention import mla_attend_step
    key = jax.random.PRNGKey(6)
    B, S, d, H = 2, 10, 64, 4
    kv_lora, q_lora, nope, rope, vh = 32, 48, 16, 8, 16
    p = init_mla(key, d, H, kv_lora=kv_lora, q_lora=q_lora, qk_nope=nope,
                 qk_rope=rope, v_head=vh)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, d)) * 0.5
    full = mla_train(p, x, n_heads=H, kv_lora=kv_lora, qk_nope=nope,
                     qk_rope=rope, v_head=vh)
    c_cache = jnp.zeros((B, S, kv_lora))
    r_cache = jnp.zeros((B, S, rope))
    outs = []
    for t in range(S):
        o, c_new, r_new = mla_attend_step(
            p, x[:, t:t + 1], c_cache, r_cache, jnp.asarray(t),
            n_heads=H, kv_lora=kv_lora, qk_nope=nope, qk_rope=rope,
            v_head=vh)
        c_cache = c_cache.at[:, t].set(c_new)
        r_cache = r_cache.at[:, t].set(r_new)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=3e-3, atol=3e-4)


def test_mla_decode_absorbed_matches_train():
    key = jax.random.PRNGKey(3)
    B, S, d, H = 2, 10, 64, 4
    kv_lora, q_lora, nope, rope, vh = 32, 48, 16, 8, 16
    p = init_mla(key, d, H, kv_lora=kv_lora, q_lora=q_lora, qk_nope=nope,
                 qk_rope=rope, v_head=vh)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, d)) * 0.5
    full = mla_train(p, x, n_heads=H, kv_lora=kv_lora, qk_nope=nope,
                     qk_rope=rope, v_head=vh)
    cache = init_mla_cache(B, S, kv_lora=kv_lora, qk_rope=rope)
    outs = []
    for t in range(S):
        o, cache = mla_decode(p, x[:, t:t + 1], cache, n_heads=H,
                              kv_lora=kv_lora, qk_nope=nope, qk_rope=rope,
                              v_head=vh)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=3e-3, atol=3e-4)
