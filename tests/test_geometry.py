"""The Geometry protocol: every cost family's operators vs its dense oracle.

Universal contracts, parametrized over all families (including the padded
bucket shapes of ``configs.shapes.ot_bucket``):

  * ``apply_k`` / ``apply_kt``       match ``dense_kernel()`` matvecs
  * ``log_apply_k`` / ``log_apply_kt`` match ``logsumexp(-C/eps + ./eps)``
    on the geometry's own dense kernel (log-capable families)
  * ``cost_matrix()``                matches the family's dense oracle
  * ``rebuild_at`` / ``anneal_capable`` semantics
  * the Pallas dispatch hook (``kernels.ops.geometry_ops``) reproduces the
    geometry's XLA operators in interpret mode
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.shapes import ot_bucket
from repro.core import (
    ArcCosinePointCloud,
    DenseCost,
    FactoredPositive,
    GaussianPointCloud,
    GridSeparable,
    NystromLowRank,
    OTProblem,
    solve,
    squared_euclidean,
)
from repro.core.features import GaussianFeatureMap

EPS = 0.55
LSE = jax.scipy.special.logsumexp


def _clouds(n, m, d=2, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jnp.clip(jax.random.normal(k1, (n, d)), -2, 2)
    y = jnp.clip(0.7 * jax.random.normal(k2, (m, d)) + 0.2, -2, 2)
    return x, y


def _gaussian_anchors(d=2, r=96, seed=3):
    fm = GaussianFeatureMap(r=r, d=d, eps=EPS, R=3.0)
    return fm.init(jax.random.PRNGKey(seed))


def _make_geometry(family: str, n: int, m: int):
    """Build one geometry of ``family`` with supports of size (n, m)."""
    x, y = _clouds(n, m)
    if family == "dense":
        return DenseCost(squared_euclidean(x, y), EPS)
    if family == "factored":
        U = _gaussian_anchors()
        g = GaussianPointCloud.build(x, y, U, eps=EPS, R=3.0)
        xi, zeta = g.features()
        return FactoredPositive(xi=xi, zeta=zeta, eps=EPS)
    if family == "log_factored":
        U = _gaussian_anchors()
        g = GaussianPointCloud.build(x, y, U, eps=EPS, R=3.0)
        lxi, lzt = g.log_features()
        return FactoredPositive(log_xi=lxi, log_zeta=lzt, eps=EPS)
    if family == "gaussian":
        return GaussianPointCloud.build(x, y, _gaussian_anchors(), eps=EPS,
                                        R=3.0)
    if family == "arccos":
        anchors = 1.5 * jax.random.normal(jax.random.PRNGKey(5), (80, 2))
        return ArcCosinePointCloud(x, y, anchors, eps=EPS, kappa=1e-3)
    if family == "nystrom":
        return NystromLowRank.from_point_clouds(
            x, y, eps=EPS, rank=min(16, n, m), key=jax.random.PRNGKey(7))
    if family == "grid":
        # factor (n, m) into 2-D grids; oracle sizes stay exact
        n1 = max(2, n // 8)
        m1 = max(2, m // 8)
        ax = (jnp.linspace(0.0, 1.0, n1), jnp.linspace(0.0, 1.0, n // n1))
        ay = (jnp.linspace(0.0, 1.2, m1), jnp.linspace(0.0, 1.2, m // m1))
        return GridSeparable.build(ax, ay, eps=EPS)
    raise AssertionError(family)


FAMILIES = ("dense", "factored", "log_factored", "gaussian", "arccos",
            "nystrom", "grid")

# ragged "real" sizes plus the padded power-of-two bucket shapes the
# batched engine actually solves at
SIZES = ((40, 36), (ot_bucket(40), ot_bucket(36)))


# ---------------------------------------------------------------------------
# Universal operator oracles
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", FAMILIES)
@pytest.mark.parametrize("size", SIZES, ids=lambda s: f"n{s[0]}m{s[1]}")
def test_operators_match_dense_kernel(family, size):
    geom = _make_geometry(family, *size)
    n, m = geom.shape
    key = jax.random.PRNGKey(11)
    v = jax.random.uniform(key, (m,)) + 0.1
    u = jax.random.uniform(jax.random.fold_in(key, 1), (n,)) + 0.1
    K = geom.dense_kernel()
    np.testing.assert_allclose(np.asarray(geom.apply_k(v)),
                               np.asarray(K @ v), rtol=3e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(geom.apply_kt(u)),
                               np.asarray(K.T @ u), rtol=3e-4, atol=1e-6)


@pytest.mark.parametrize("family",
                         [f for f in FAMILIES if f != "nystrom"])
@pytest.mark.parametrize("size", SIZES, ids=lambda s: f"n{s[0]}m{s[1]}")
def test_log_operators_match_lse_oracle(family, size):
    """log_apply_k(g) == LSE_j( log K_ij + g_j/eps ) on the geometry's own
    dense kernel — exactly the -C/eps Gibbs form for cost-defined families."""
    geom = _make_geometry(family, *size)
    assert geom.supports_log
    n, m = geom.shape
    key = jax.random.PRNGKey(13)
    g = jax.random.normal(key, (m,)) * 0.3
    f = jax.random.normal(jax.random.fold_in(key, 1), (n,)) * 0.3
    logK = geom.log_dense_kernel()
    np.testing.assert_allclose(
        np.asarray(geom.log_apply_k(g)),
        np.asarray(LSE(logK + (g / EPS)[None, :], axis=1)),
        rtol=2e-4, atol=2e-5,
    )
    np.testing.assert_allclose(
        np.asarray(geom.log_apply_kt(f)),
        np.asarray(LSE(logK + (f / EPS)[:, None], axis=0)),
        rtol=2e-4, atol=2e-5,
    )


@pytest.mark.parametrize("family",
                         [f for f in FAMILIES if f != "nystrom"])
def test_log_operators_match_cost_gibbs(family):
    """The Gibbs form of the same oracle: log_apply_k == LSE(-C/eps + g/eps)
    with C the kernel-consistent (induced) cost -eps * log_dense_kernel().
    For cost-defined families that IS cost_matrix(); Gaussian point clouds
    instead define cost_matrix() as the TRUE sq-Euclidean cost (the Sin
    baseline) and their Monte-Carlo kernel error is pinned separately by
    test_features (Prop 3.1 concentration needs r in the thousands)."""
    geom = _make_geometry(family, 40, 36)
    m = geom.shape[1]
    g = jax.random.normal(jax.random.PRNGKey(17), (m,)) * 0.3
    C_induced = -EPS * geom.log_dense_kernel()
    np.testing.assert_allclose(
        np.asarray(geom.log_apply_k(g)),
        np.asarray(LSE((-C_induced + g[None, :]) / EPS, axis=1)),
        rtol=2e-4, atol=2e-5,
    )
    if family in ("dense", "grid"):
        np.testing.assert_allclose(np.asarray(C_induced),
                                   np.asarray(geom.cost_matrix()),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# cost_matrix family oracles
# ---------------------------------------------------------------------------


def test_dense_cost_matrix_roundtrip():
    x, y = _clouds(30, 25)
    C = squared_euclidean(x, y)
    geom = DenseCost(C, EPS)
    np.testing.assert_allclose(np.asarray(geom.cost_matrix()),
                               np.asarray(C))


def test_gaussian_cost_matrix_is_true_cost():
    x, y = _clouds(30, 25)
    geom = GaussianPointCloud.build(x, y, _gaussian_anchors(), eps=EPS)
    np.testing.assert_allclose(np.asarray(geom.cost_matrix()),
                               np.asarray(squared_euclidean(x, y)),
                               rtol=1e-5, atol=1e-6)


def test_factored_cost_matrix_is_induced():
    geom = _make_geometry("factored", 30, 25)
    xi, zeta = geom.features()
    np.testing.assert_allclose(
        np.asarray(geom.cost_matrix()),
        np.asarray(-EPS * jnp.log(xi @ zeta.T)),
        rtol=1e-4, atol=1e-4,
    )


def test_grid_cost_matrix_is_separable_sum():
    ax = (jnp.linspace(0, 1, 5), jnp.linspace(0, 2, 4))
    ay = (jnp.linspace(0, 1, 3), jnp.linspace(0, 2, 6))
    geom = GridSeparable.build(ax, ay, eps=EPS)
    px = jnp.stack(jnp.meshgrid(*ax, indexing="ij"), -1).reshape(-1, 2)
    py = jnp.stack(jnp.meshgrid(*ay, indexing="ij"), -1).reshape(-1, 2)
    np.testing.assert_allclose(np.asarray(geom.cost_matrix()),
                               np.asarray(squared_euclidean(px, py)),
                               rtol=1e-5, atol=1e-6)


def test_nystrom_refuses_log_and_cost():
    geom = _make_geometry("nystrom", 30, 25)
    with pytest.raises(ValueError, match="log-domain"):
        geom.log_apply_k(jnp.zeros((geom.shape[1],)))
    with pytest.raises(ValueError, match="signed"):
        geom.cost_matrix()


# ---------------------------------------------------------------------------
# rebuild_at / anneal semantics
# ---------------------------------------------------------------------------


def test_rebuild_semantics():
    dense = _make_geometry("dense", 20, 20)
    assert dense.anneal_capable
    assert dense.rebuild_at(0.1).eps == 0.1
    assert dense.rebuild_at(EPS) is dense

    gauss = _make_geometry("gaussian", 20, 20)
    assert gauss.anneal_capable
    g2 = gauss.rebuild_at(0.1)
    assert g2.eps == 0.1 and g2.R == gauss.R

    grid = _make_geometry("grid", 16, 16)
    assert grid.anneal_capable
    assert grid.rebuild_at(0.2).eps == 0.2

    for pinned in ("factored", "log_factored", "arccos", "nystrom"):
        geom = _make_geometry(pinned, 20, 20)
        assert not geom.anneal_capable
        assert geom.rebuild_at(EPS) is geom
        with pytest.raises(ValueError, match="pins the kernel"):
            geom.rebuild_at(EPS / 2)


def test_divergence_subgeometries_are_symmetric():
    for family in ("factored", "log_factored", "gaussian", "arccos", "grid"):
        geom = _make_geometry(family, 24, 20)
        n, m = geom.shape
        assert geom.xx().shape == (n, n)
        assert geom.yy().shape == (m, m)


# ---------------------------------------------------------------------------
# solve() integration: the two new scenarios
# ---------------------------------------------------------------------------


def test_arccos_solve_matches_dense_oracle():
    """Satellite contract: solve(method='arccos') vs the dense log-domain
    solver on the cost induced by the PERTURBED arc-cosine kernel
    k_s + kappa (Lemma 3) — one fixed point, agreement to solver tol."""
    x, y = _clouds(36, 30, seed=21)
    anchors = 1.4 * jax.random.normal(jax.random.PRNGKey(23), (120, 2))
    geom = ArcCosinePointCloud(x, y, anchors, eps=EPS, s=1, sigma=1.4,
                               kappa=5e-3)
    p = OTProblem.from_geometry(geom)
    # tol=1e-6 is the f32 marginal-error floor; tighter just exhausts iters
    res = solve(p, method="arccos", tol=1e-6, max_iter=8000)
    assert bool(res.converged)
    # dense perturbed arc-cosine kernel, straight from the feature product
    xi, zeta = geom.features()
    K_dense = xi @ zeta.T
    assert float(jnp.min(K_dense)) >= 5e-3 - 1e-6      # kappa floor
    oracle = solve(
        OTProblem.from_cost(-EPS * jnp.log(K_dense), eps=EPS),
        method="log_quadratic", tol=1e-6, max_iter=8000,
    )
    np.testing.assert_allclose(float(res.cost), float(oracle.cost),
                               rtol=1e-5)


def test_arccos_reachable_from_gaussian_problem():
    """method='arccos' swaps the cost family on a point-cloud problem."""
    x, y = _clouds(30, 30, seed=25)
    p = OTProblem.from_point_clouds(x, y, _gaussian_anchors(), eps=EPS)
    res = solve(p, method="arccos", rank=64, key=jax.random.PRNGKey(1))
    assert np.isfinite(float(res.cost))
    assert bool(res.converged)


def test_grid_solve_matches_dense():
    ax = (jnp.linspace(0, 1, 8), jnp.linspace(0, 1, 8))
    p = OTProblem.from_grid(ax, eps=0.2)
    res = solve(p, tol=1e-7, max_iter=6000)
    oracle = solve(
        OTProblem.from_cost(p.geometry.cost_matrix(), eps=0.2),
        method="log_quadratic", tol=1e-7, max_iter=6000,
    )
    np.testing.assert_allclose(float(res.cost), float(oracle.cost),
                               rtol=1e-5, atol=1e-7)


def test_nystrom_solve_reports_structured_divergence():
    """Small-eps Nystrom blow-up (paper Figs. 1/3/5) surfaces as
    result.diverged — a structured flag, not unexplained NaNs."""
    x, y = _clouds(60, 60, seed=27)
    p = OTProblem.from_point_clouds(x, y, _gaussian_anchors(), eps=0.02)
    res = solve(p, method="nystrom", rank=12)
    assert not bool(res.converged)
    assert bool(res.diverged)
    # moderate eps: same method, healthy run
    p2 = OTProblem.from_point_clouds(x, y, _gaussian_anchors(), eps=5.0)
    res2 = solve(p2, method="nystrom", rank=48, tol=1e-5)
    assert not bool(res2.diverged)
    assert np.isfinite(float(res2.cost))


def test_nystrom_is_auto_method_for_nystrom_geometry():
    from repro.core.api import _auto_method

    geom = _make_geometry("nystrom", 20, 20)
    assert _auto_method(OTProblem.from_geometry(geom)) == "nystrom"


# ---------------------------------------------------------------------------
# Pallas dispatch hook
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["factored", "gaussian", "arccos"])
def test_geometry_ops_matches_xla_operators(family):
    """The geometry-chosen fused plan reproduces the XLA operators: one
    fused Alg.-1 iteration == the geometry's apply_k/apply_kt math."""
    from repro.kernels.ops import geometry_ops

    geom = _make_geometry(family, 24, 20)
    plan = geometry_ops(geom, backend="interpret")
    assert plan is not None
    xi, zeta = plan.features
    xi_ref, zeta_ref = geom.features()
    np.testing.assert_allclose(np.asarray(xi), np.asarray(xi_ref),
                               rtol=2e-5, atol=1e-6)
    n, m = geom.shape
    a = jnp.full((n, 1), 1.0 / n)
    b = jnp.full((m, 1), 1.0 / m)
    u0 = jnp.ones((n, 1))
    u1, v1 = plan.iteration(a, b, u0)
    # reference iteration through the geometry's XLA operators
    v_ref = (b[:, 0]) / geom.apply_kt(u0[:, 0])
    u_ref = (a[:, 0]) / geom.apply_k(v_ref)
    np.testing.assert_allclose(np.asarray(v1[:, 0]), np.asarray(v_ref),
                               rtol=2e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(u1[:, 0]), np.asarray(u_ref),
                               rtol=2e-4, atol=1e-6)


def test_geometry_ops_none_for_unfused_families():
    from repro.kernels.ops import geometry_ops

    for mode in ("scaling", "log"):
        assert geometry_ops(_make_geometry("dense", 10, 10),
                            mode=mode) is None
        assert geometry_ops(_make_geometry("nystrom", 10, 10),
                            mode=mode) is None
        assert geometry_ops(_make_geometry("grid", 16, 16),
                            mode=mode) is None


@pytest.mark.parametrize("family", ["factored", "log_factored", "gaussian",
                                    "arccos"])
def test_geometry_ops_log_mode_matches_xla_operators(family):
    """The fused LOG plan reproduces the geometry's exact two-stage LSE:
    one fused log iteration == log_apply_kt / log_apply_k math."""
    from repro.core.geometry import _masked_log
    from repro.kernels.ops import geometry_ops

    geom = _make_geometry(family, 24, 20)
    plan = geometry_ops(geom, backend="interpret", mode="log")
    assert plan is not None and plan.mode == "log"
    lxi, lzt = plan.features
    lxi_ref, lzt_ref = geom.log_features()
    np.testing.assert_allclose(np.asarray(lxi), np.asarray(lxi_ref),
                               rtol=2e-4, atol=2e-4)
    n, m = geom.shape
    a = jnp.full((n,), 1.0 / n)
    b = jnp.full((m,), 1.0 / m)
    f0 = jnp.zeros((n, 1))
    f1, g1 = plan.iteration(_masked_log(a)[:, None], _masked_log(b)[:, None],
                            f0)
    eps = geom.eps
    g_ref = eps * (jnp.log(b) - geom.log_apply_kt(f0[:, 0]))
    f_ref = eps * (jnp.log(a) - geom.log_apply_k(g_ref))
    np.testing.assert_allclose(np.asarray(g1[:, 0]), np.asarray(g_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(f1[:, 0]), np.asarray(f_ref),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Fused plan on the solver hot path (use_pallas)
# ---------------------------------------------------------------------------


def _bucket_padded_problem(family: str, n: int, m: int):
    """A problem padded to the engine's power-of-two buckets with
    ZERO-WEIGHT atoms (replicated feature rows carry no mass) — the exact
    shape ``BatchedSinkhorn`` solves at, exercising the unguarded divide in
    ``_halfstep_kernel`` against padded rows."""
    geom = _make_geometry(family, n, m)
    n_pad, m_pad = ot_bucket(n), ot_bucket(m)
    a = jnp.concatenate([jnp.full((n,), 1.0 / n), jnp.zeros((n_pad - n,))])
    b = jnp.concatenate([jnp.full((m,), 1.0 / m), jnp.zeros((m_pad - m,))])
    if family == "factored":
        xi, zeta = geom.features()
        pad = lambda w, k: jnp.concatenate(
            [w, jnp.broadcast_to(w[-1:], (k - w.shape[0],) + w.shape[1:])])
        geom = FactoredPositive(xi=pad(xi, n_pad), zeta=pad(zeta, m_pad),
                                eps=geom.eps)
    else:
        assert family == "gaussian"
        pad = lambda p, k: jnp.concatenate(
            [p, jnp.broadcast_to(p[-1:], (k - p.shape[0],) + p.shape[1:])])
        geom = GaussianPointCloud.build(
            pad(geom.x, n_pad), pad(geom.y, m_pad), geom.anchors,
            eps=geom.eps, R=geom.R)
    return geom, a, b


@pytest.mark.parametrize("family", ["factored", "gaussian"])
def test_fused_hot_loop_parity_bucket_padded_zero_weights(family):
    """Acceptance: a factored/Gaussian solve runs THROUGH the fused plan
    (plan-selection hook fires) and matches the XLA operator path
    elementwise at bucket-padded shapes with zero-weight atoms."""
    from repro.core.sinkhorn import sinkhorn_geometry
    from repro.kernels import observe_plan_selection

    geom, a, b = _bucket_padded_problem(family, 40, 36)
    with observe_plan_selection() as events:
        res_p = sinkhorn_geometry(geom, a, b, tol=1e-6, max_iter=4000,
                                  use_pallas=True)
    assert events and events[0]["mode"] == "scaling"
    assert events[0]["geometry"] == type(geom).__name__
    res_x = sinkhorn_geometry(geom, a, b, tol=1e-6, max_iter=4000,
                              use_pallas=False)
    assert int(res_p.n_iter) == int(res_x.n_iter)
    for field in ("u", "v", "f", "g"):
        got, want = getattr(res_p, field), getattr(res_x, field)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=2e-4, atol=1e-6,
            err_msg=f"{family}.{field}")
    np.testing.assert_allclose(float(res_p.cost), float(res_x.cost),
                               rtol=1e-5, atol=1e-7)
    # zero-weight atoms: scalings exactly 0, potentials exactly -inf
    assert np.all(np.asarray(res_p.u[40:]) == 0.0)
    assert np.all(np.isneginf(np.asarray(res_p.f[40:])))


@pytest.mark.parametrize("use_pallas", [False, True])
def test_momentum_with_zero_weight_padded_atoms(use_pallas):
    """Over-relaxation on a bucket-padded problem: padded atoms pin u = 0,
    and 0^{1-w} in the geometric blend used to produce inf * 0 = NaN,
    silently stopping the while_loop after ~2 iterations. The masked relax
    must keep the solve converging on both the XLA and fused paths."""
    from repro.core.sinkhorn import sinkhorn_geometry

    geom, a, b = _bucket_padded_problem("factored", 40, 36)
    res = sinkhorn_geometry(geom, a, b, tol=1e-6, max_iter=4000,
                            momentum=1.3, use_pallas=use_pallas)
    assert bool(res.converged), int(res.n_iter)
    assert np.isfinite(float(res.cost))
    assert np.all(np.asarray(res.u[40:]) == 0.0)
    # same fixed point as the plain solve
    ref = sinkhorn_geometry(geom, a, b, tol=1e-6, max_iter=4000,
                            use_pallas=False)
    np.testing.assert_allclose(float(res.cost), float(ref.cost), rtol=1e-4)


@pytest.mark.parametrize("family", ["log_factored", "gaussian"])
def test_fused_log_hot_loop_parity(family):
    """Log-domain twin: sinkhorn_log_geometry through the fused LSE plan
    elementwise-matches the exact two-stage XLA path, zero weights masked."""
    from repro.core.sinkhorn import sinkhorn_log_geometry
    from repro.kernels import observe_plan_selection

    geom = _make_geometry(family, 28, 24)
    n, m = geom.shape
    a = jnp.full((n,), 1.0 / n).at[-2:].set(0.0)
    a = a / jnp.sum(a)
    b = jnp.full((m,), 1.0 / m)
    with observe_plan_selection() as events:
        res_p = sinkhorn_log_geometry(geom, a, b, tol=1e-6, max_iter=4000,
                                      use_pallas=True)
    assert events and events[0]["mode"] == "log"
    res_x = sinkhorn_log_geometry(geom, a, b, tol=1e-6, max_iter=4000,
                                  use_pallas=False)
    assert int(res_p.n_iter) == int(res_x.n_iter)
    np.testing.assert_allclose(np.asarray(res_p.g), np.asarray(res_x.g),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(res_p.cost), float(res_x.cost),
                               rtol=1e-5, atol=1e-6)
    assert np.all(np.isneginf(np.asarray(res_p.f[-2:])))


def test_batched_engine_fused_plan_parity():
    """Acceptance: BatchedSinkhorn.solve_stacked routes every problem in
    the bucket through the fused plan (vmap adds B as a leading Pallas grid
    axis) and matches the XLA engine elementwise."""
    from repro.core import BatchedSinkhorn
    from repro.kernels import observe_plan_selection

    key = jax.random.PRNGKey(9)
    B, n, m, r, eps = 3, 32, 24, 8, 0.5
    xi = jax.random.uniform(key, (B, n, r)) + 0.05
    zt = jax.random.uniform(jax.random.fold_in(key, 1), (B, m, r)) + 0.05
    a = jnp.full((B, n), 1.0 / n)
    b = jnp.full((B, m), 1.0 / m)
    with observe_plan_selection() as events:
        eng_p = BatchedSinkhorn(eps=eps, method="factored", tol=1e-6,
                                max_iter=1000, use_pallas=True)
        res_p = eng_p.solve_stacked(xi, zt, a, b)
    assert events and events[0]["kind"] == "factored"
    eng_x = BatchedSinkhorn(eps=eps, method="factored", tol=1e-6,
                            max_iter=1000, use_pallas=False)
    res_x = eng_x.solve_stacked(xi, zt, a, b)
    np.testing.assert_allclose(np.asarray(res_p.u), np.asarray(res_x.u),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(res_p.cost),
                               np.asarray(res_x.cost), rtol=1e-5)
    assert np.array_equal(np.asarray(res_p.n_iter), np.asarray(res_x.n_iter))
