"""Solver unit tests: Alg. 1 on factored kernels vs dense ground truth."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    gaussian_features,
    gaussian_log_features,
    sinkhorn_factored,
    sinkhorn_log_factored,
    sinkhorn_log_quadratic,
    sinkhorn_operator,
    sinkhorn_quadratic,
    squared_euclidean,
)
from repro.core.features import GaussianFeatureMap


@pytest.fixture(scope="module")
def problem():
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    n, m, d = 120, 90, 3
    x = jax.random.normal(k1, (n, d))
    y = jax.random.normal(k2, (m, d)) * 0.5 + 0.3
    a = jax.random.uniform(k3, (n,)) + 0.5
    a = a / a.sum()
    b = jnp.full((m,), 1.0 / m)
    return x, y, a, b


def test_quadratic_matches_log_domain(problem):
    x, y, a, b = problem
    eps = 0.5
    C = squared_euclidean(x, y)
    K = jnp.exp(-C / eps)
    r1 = sinkhorn_quadratic(K, a, b, eps=eps, tol=1e-6, max_iter=5000)
    r2 = sinkhorn_log_quadratic(C, a, b, eps=eps, tol=1e-6, max_iter=5000)
    assert r1.converged and r2.converged
    np.testing.assert_allclose(float(r1.cost), float(r2.cost), rtol=1e-4)


def test_marginals_satisfied(problem):
    x, y, a, b = problem
    eps = 0.5
    K = jnp.exp(-squared_euclidean(x, y) / eps)
    r = sinkhorn_quadratic(K, a, b, eps=eps, tol=1e-7, max_iter=5000)
    P = r.u[:, None] * K * r.v[None, :]
    np.testing.assert_allclose(np.asarray(P.sum(1)), np.asarray(a), atol=1e-5)
    np.testing.assert_allclose(np.asarray(P.sum(0)), np.asarray(b), atol=1e-5)


def test_factored_equals_quadratic_on_same_kernel(problem):
    """With the SAME positive factored kernel, the factored solver must
    match the dense solver exactly (it IS the same fixed point)."""
    x, y, a, b = problem
    eps = 0.8
    fm = GaussianFeatureMap(r=400, d=3, eps=eps, R=3.5)
    U = fm.init(jax.random.PRNGKey(7))
    xi = gaussian_features(x, U, eps=eps, q=fm.q)
    zeta = gaussian_features(y, U, eps=eps, q=fm.q)
    K = xi @ zeta.T
    r_f = sinkhorn_factored(xi, zeta, a, b, eps=eps, tol=1e-7, max_iter=5000)
    r_q = sinkhorn_quadratic(K, a, b, eps=eps, tol=1e-7, max_iter=5000)
    np.testing.assert_allclose(float(r_f.cost), float(r_q.cost), rtol=1e-5)


def test_factored_approximates_true_rot(problem):
    """Theorem 3.1 empirically: RF cost -> true ROT cost as r grows."""
    x, y, a, b = problem
    eps = 0.8
    C = squared_euclidean(x, y)
    gt = sinkhorn_log_quadratic(C, a, b, eps=eps, tol=1e-8, max_iter=10000)
    errs = []
    for r in (50, 400, 3200):
        fm = GaussianFeatureMap(r=r, d=3, eps=eps, R=3.5)
        U = fm.init(jax.random.PRNGKey(3))
        lxi = gaussian_log_features(x, U, eps=eps, q=fm.q)
        lz = gaussian_log_features(y, U, eps=eps, q=fm.q)
        rr = sinkhorn_log_factored(lxi, lz, a, b, eps=eps, tol=1e-8,
                                   max_iter=10000)
        errs.append(abs(float(rr.cost - gt.cost)))
    assert errs[2] < errs[0], errs
    assert errs[2] / max(abs(float(gt.cost)), 1e-9) < 0.05, errs


def test_log_and_scaling_domains_agree(problem):
    x, y, a, b = problem
    eps = 0.6
    fm = GaussianFeatureMap(r=300, d=3, eps=eps, R=3.5)
    U = fm.init(jax.random.PRNGKey(1))
    lxi = gaussian_log_features(x, U, eps=eps, q=fm.q)
    lz = gaussian_log_features(y, U, eps=eps, q=fm.q)
    r1 = sinkhorn_factored(jnp.exp(lxi), jnp.exp(lz), a, b, eps=eps,
                           tol=1e-7, max_iter=3000)
    r2 = sinkhorn_log_factored(lxi, lz, a, b, eps=eps, tol=1e-7,
                               max_iter=3000)
    np.testing.assert_allclose(float(r1.cost), float(r2.cost), rtol=1e-4,
                               atol=1e-6)


def test_small_eps_log_domain_stable(problem):
    """The paper's small-regularization regime: scaling-space under/overflows
    are avoided in log space."""
    x, y, a, b = problem
    eps = 0.01
    C = squared_euclidean(x, y)
    r = sinkhorn_log_quadratic(C, a, b, eps=eps, tol=1e-6, max_iter=20000)
    assert np.isfinite(float(r.cost))


def test_momentum_accelerates(problem):
    x, y, a, b = problem
    eps = 0.3   # scaling-space-safe regime (kernel stays > f32 tiny)
    K = jnp.exp(-squared_euclidean(x, y) / eps)
    r_plain = sinkhorn_quadratic(K, a, b, eps=eps, tol=1e-6, max_iter=20000)
    r_mom = sinkhorn_quadratic(K, a, b, eps=eps, tol=1e-6, max_iter=20000,
                               momentum=1.5)
    assert r_mom.converged
    assert int(r_mom.n_iter) < int(r_plain.n_iter)
    np.testing.assert_allclose(float(r_mom.cost), float(r_plain.cost),
                               rtol=1e-3)


def test_operator_interface_generic(problem):
    x, y, a, b = problem
    eps = 0.5
    K = jnp.exp(-squared_euclidean(x, y) / eps)
    r1 = sinkhorn_operator(lambda v: K @ v, lambda u: K.T @ u, a, b,
                           eps=eps, tol=1e-7, max_iter=3000)
    r2 = sinkhorn_quadratic(K, a, b, eps=eps, tol=1e-7, max_iter=3000)
    np.testing.assert_allclose(float(r1.cost), float(r2.cost), rtol=1e-6)
