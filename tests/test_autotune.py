"""Backend policy + block-shape autotuner coverage (ISSUE-7 acceptance).

Contracts under test:

* backend resolution: tpu -> tpu-mosaic, gpu/cuda/rocm -> gpu-triton with
  ``interpret=False`` (the regression for the old default-interpret
  trap that silently interpreted on GPU), everything else -> interpret;
  precedence of explicit record/name > set_backend/scope >
  ``REPRO_BACKEND`` env > platform;
* ``block_plan_fits`` reads its admission budget from the Backend record
  (GPU gets the shared-memory gate, not TPU's 12 MiB VMEM constant) while
  the positional legacy call keeps its interpret-flag behavior;
* GPU plans never interpret: ``geometry_ops`` under a gpu backend yields
  ``interpret=False`` plans whose megakernel REFUSES (``make_block_step``
  -> None) beyond the SMEM budget, and the fused Gaussian map refuses into
  the XLA map beyond the single-d-block bound;
* split-k kernel variants (the parallel-grid lowerings) match the oracles
  elementwise in interpret mode;
* tuner: ``deterministic`` bitwise-matches the static ``pick_block`` plan,
  cache round-trip (persist -> fresh reload -> ZERO re-timing), corrupt /
  stale-version cache files fall back cleanly, tuned candidates all
  produce elementwise-parity results, explicit ``block_*`` overrides are
  honored, and ``pick_block`` edge extents behave.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune
from repro.kernels.backend import (
    BACKEND_ENV,
    MEGAKERNEL_BUDGET_GPU,
    backend_scope,
    fused_map_admissible,
    resolve_backend,
    set_backend,
)
from repro.kernels.fused_loop import block_plan_fits, block_vmem_bytes
from repro.kernels.kermatvec import feature_contract_pallas
from repro.kernels.logmatvec import log_feature_contract_pallas
from repro.kernels.ops import (
    gaussian_feature_map,
    geometry_ops,
)
from repro.kernels.ref import (
    feature_contract_ref,
    gaussian_feature_map_ref,
    log_feature_contract_ref,
)
from repro.kernels.tiling import LANE, pick_block, round_up
from repro.core.geometry import FactoredPositive

KEY = jax.random.PRNGKey(7)


@pytest.fixture(autouse=True)
def _clean_policy(monkeypatch, tmp_path):
    """Every test starts from a pristine policy: no process override, no
    env override, deterministic tuner pointed at a throwaway cache."""
    monkeypatch.delenv(BACKEND_ENV, raising=False)
    monkeypatch.delenv(autotune.TUNE_ENV, raising=False)
    monkeypatch.setenv(autotune.CACHE_ENV, str(tmp_path / "tuning.json"))
    prev = set_backend(None)
    prev_cfg = autotune.configure(_reset=True)
    autotune.clear_cache()
    autotune.reset_stats()
    yield
    set_backend(prev)
    autotune._CONFIG.update(prev_cfg)
    autotune.clear_cache()
    autotune.reset_stats()


def _platform(monkeypatch, name):
    monkeypatch.setattr(jax, "default_backend", lambda: name)


# ---------------------------------------------------------------------------
# Backend resolution
# ---------------------------------------------------------------------------


def test_platform_defaults(monkeypatch):
    _platform(monkeypatch, "tpu")
    be = resolve_backend()
    assert (be.name, be.interpret, be.split_reduce) == \
        ("tpu-mosaic", False, False)
    _platform(monkeypatch, "cpu")
    assert resolve_backend().name == "interpret"
    assert resolve_backend().interpret is True


@pytest.mark.parametrize("platform", ["gpu", "cuda", "rocm"])
def test_gpu_never_interprets_silently(monkeypatch, platform):
    """THE regression: the old policy was ``interpret = backend != tpu``,
    which ran every kernel interpreted on GPU. A gpu platform must resolve
    to a compiled backend unless explicitly overridden."""
    _platform(monkeypatch, platform)
    be = resolve_backend()
    assert be.name == "gpu-triton"
    assert be.interpret is False
    assert be.split_reduce is True
    # the ambient resolution keeps the compiled gpu policy
    assert resolve_backend(None).name == "gpu-triton"
    # the interpreter stays reachable, but only by EXPLICIT name
    assert resolve_backend("interpret").interpret is True


def test_override_precedence(monkeypatch):
    _platform(monkeypatch, "cpu")
    # env beats platform
    monkeypatch.setenv(BACKEND_ENV, "gpu-triton")
    assert resolve_backend().name == "gpu-triton"
    # set_backend beats env
    set_backend("tpu-mosaic")
    assert resolve_backend().name == "tpu-mosaic"
    # explicit name beats set_backend
    assert resolve_backend("interpret").name == "interpret"
    # explicit record beats everything
    rec = resolve_backend("gpu-triton")
    assert resolve_backend(rec) is rec
    set_backend(None)
    assert resolve_backend().name == "gpu-triton"   # env again


def test_backend_scope_restores(monkeypatch):
    _platform(monkeypatch, "cpu")
    with backend_scope("gpu-triton") as be:
        assert be.name == "gpu-triton"
        assert resolve_backend().name == "gpu-triton"
    assert resolve_backend().name == "interpret"


def test_unknown_backend_name_raises():
    with pytest.raises(ValueError, match="unknown backend"):
        resolve_backend("cuda-graphs")


# ---------------------------------------------------------------------------
# Budgets / admission
# ---------------------------------------------------------------------------


def test_block_plan_fits_reads_backend_budget():
    gpu = resolve_backend("gpu-triton")
    tpu = resolve_backend("tpu-mosaic")
    # small problem: inside both budgets
    assert block_plan_fits(64, 64, 32, backend=gpu)
    assert block_plan_fits(64, 64, 32, backend=tpu)
    # mid-size problem: fits 12 MiB VMEM, blows the 192 KiB SMEM gate
    n, m, r = 4096, 4096, 256
    assert block_vmem_bytes(n, m, r) > MEGAKERNEL_BUDGET_GPU
    assert block_plan_fits(n, m, r, backend=tpu)
    assert not block_plan_fits(n, m, r, backend=gpu)
    # a record with megakernel lowering disabled refuses at ANY size
    off = gpu._replace(megakernel=False)
    assert not block_plan_fits(8, 8, 8, backend=off)
    # legacy positional/interpret-flag surface unchanged
    assert block_plan_fits(4096, 4096, 256, 1, jnp.float32, False)
    assert not block_plan_fits(40960, 40960, 4096, 1, jnp.float32, False)
    assert block_plan_fits(40960, 40960, 1024, 1, jnp.float32, True)


def test_gpu_plan_metadata_never_interpret():
    """A geometry plan built for gpu-triton: interpret=False end to end,
    megakernel refuses beyond SMEM instead of interpreting."""
    n, m, r = 4096, 4096, 256
    xi = jax.random.uniform(KEY, (n, r)) + 0.05
    zt = jax.random.uniform(jax.random.fold_in(KEY, 1), (m, r)) + 0.05
    geom = FactoredPositive(xi=xi, zeta=zt, eps=0.5)
    a = jnp.full((n,), 1.0 / n)
    b = jnp.full((m,), 1.0 / m)
    plan = geometry_ops(geom, backend=resolve_backend("gpu-triton"))
    assert plan.interpret is False
    assert plan.backend.name == "gpu-triton"
    assert plan.make_block_step(a, b, inner_steps=4) is None
    # the same shape on tpu-mosaic admits the megakernel
    plan_tpu = geometry_ops(geom, backend=resolve_backend("tpu-mosaic"))
    assert plan_tpu.make_block_step(a, b, inner_steps=4) is not None


def test_fused_map_admissibility_and_refusal():
    gpu = resolve_backend("gpu-triton")
    assert fused_map_admissible(2, gpu)
    assert fused_map_admissible(512, gpu)
    assert not fused_map_admissible(513, gpu)
    # no single-block constraint on sequential-grid backends
    assert fused_map_admissible(513, resolve_backend("tpu-mosaic"))
    assert fused_map_admissible(513, resolve_backend("interpret"))
    # the refusal EXECUTES (XLA map, no pallas lowering attempted) and
    # matches the oracle — on this CPU container a gpu-triton pallas_call
    # would fail to compile, so reaching the ref path IS the assertion.
    n, r, d = 24, 9, 513
    x = jax.random.normal(KEY, (n, d))
    anchors = jax.random.normal(jax.random.fold_in(KEY, 2), (r, d))
    c = jnp.full((r,), -0.5 * np.log(r))
    for log_space in (False, True):
        got = gaussian_feature_map(x, anchors, c, inv_eps=0.8,
                                   log_space=log_space, backend=gpu)
        want = gaussian_feature_map_ref(x, anchors, c, inv_eps=0.8,
                                        log_space=log_space)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Split-k lowerings (parallel-grid variants) vs oracles
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,r,B", [(19, 3, 1), (200, 129, 5), (64, 127, 2)])
def test_splitk_contract_matches_oracle(n, r, B):
    xi = jax.random.uniform(KEY, (n, r)) + 0.1
    u = jax.random.uniform(jax.random.fold_in(KEY, 3), (n, B)) + 0.1
    want = feature_contract_ref(xi, u)
    seq = feature_contract_pallas(xi, u, interpret=True)
    spl = feature_contract_pallas(xi, u, interpret=True, split_reduce=True)
    np.testing.assert_allclose(seq, want, rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(spl, want, rtol=2e-5, atol=1e-5)


@pytest.mark.parametrize("n,r,B", [(19, 3, 1), (200, 129, 2)])
def test_splitk_log_contract_matches_oracle(n, r, B):
    lw = jax.random.normal(KEY, (n, r)) * 3.0
    s = jax.random.normal(jax.random.fold_in(KEY, 4), (n, B)) * 3.0
    want = log_feature_contract_ref(lw, s)
    seq = log_feature_contract_pallas(lw, s, interpret=True)
    spl = log_feature_contract_pallas(lw, s, interpret=True,
                                      split_reduce=True)
    np.testing.assert_allclose(seq, want, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(spl, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# pick_block edges + prior table
# ---------------------------------------------------------------------------


def test_pick_block_edges():
    assert pick_block(1) == LANE                      # size 1 -> one lane
    assert pick_block(512) == 512                     # size == cap
    assert pick_block(513) == 512                     # just past cap
    assert pick_block(200) == 256                     # non-lane-multiple
    assert pick_block(128) == 128
    assert pick_block(64, cap=256) == 128
    assert pick_block(1000, cap=256) == 256


def test_feature_map_prior_owns_the_256_cap():
    """The n-cap of 256 moved out of feature_map.py into the PRIOR table."""
    plan = autotune.static_plan(
        "feature_map", {"n": 4096, "r": 512, "d": 64})
    assert plan == {"block_n": 256, "block_r": 512, "block_d": 128}


def test_static_plan_forces_single_seq_block_on_splitk_backends():
    gpu = resolve_backend("gpu-triton")
    plan = autotune.static_plan(
        "feature_map", {"n": 4096, "r": 512, "d": 300}, gpu)
    assert plan["block_d"] == round_up(300, LANE)     # d rides whole
    for cand in autotune.candidates(
            "feature_map", {"n": 4096, "r": 512, "d": 300}, gpu):
        assert cand["block_d"] == round_up(300, LANE)


def test_deterministic_bitwise_matches_static(monkeypatch):
    extents = {"n": 200, "r": 129, "B": 1}
    be = resolve_backend("interpret")
    want = autotune.static_plan("feature_contract", extents, be)
    got = autotune.resolve("feature_contract", extents, jnp.float32, be,
                           deterministic=True)
    assert got == want
    # default mode is deterministic too (no REPRO_TUNE, no configure)
    assert autotune.resolve("feature_contract", extents, jnp.float32,
                            be) == want
    assert autotune.stats()["trials"] == 0


def test_resolve_blocks_honors_explicit_overrides():
    got = autotune.resolve_blocks(
        "feature_contract", {"n": 200, "r": 129, "B": 1},
        {"block_n": 128, "block_r": None}, jnp.float32, True, None)
    assert got["block_n"] == 128                      # explicit wins
    assert got["block_r"] == pick_block(129)          # hole filled


def test_candidates_start_from_static_plan():
    extents = {"n": 2048, "r": 256, "B": 1}
    be = resolve_backend("interpret")
    cands = autotune.candidates("feature_contract", extents, be)
    assert cands[0] == autotune.static_plan("feature_contract", extents, be)
    assert 1 < len(cands) <= 8
    assert len({tuple(sorted(c.items())) for c in cands}) == len(cands)


# ---------------------------------------------------------------------------
# Measured tuning + persistent cache
# ---------------------------------------------------------------------------

_EXTENTS = {"n": 200, "r": 129, "B": 1}


def _tune_once():
    be = resolve_backend("interpret")
    return autotune.resolve("feature_contract", _EXTENTS, jnp.float32, be,
                            deterministic=False)


def test_cache_roundtrip_zero_retiming(tmp_path):
    path = tmp_path / "cache" / "tuning.json"
    autotune.configure(cache_path=str(path), deterministic=False)
    plan = _tune_once()
    assert set(plan) == {"block_n", "block_r"}
    first = autotune.stats()
    assert first["trials"] > 0 and first["keys_tuned"] == 1
    assert path.exists()
    payload = json.loads(path.read_text())
    assert payload["version"] == autotune.CACHE_VERSION
    (entry,) = payload["entries"].values()
    assert entry["blocks"] == plan

    # same process: memory hit, zero new trials
    autotune.reset_stats()
    assert _tune_once() == plan
    assert autotune.stats()["trials"] == 0
    assert autotune.stats()["memory_hits"] == 1

    # simulated fresh process: drop in-memory state, reload from disk
    autotune.clear_cache()
    autotune.reset_stats()
    assert _tune_once() == plan
    stats = autotune.stats()
    assert stats["trials"] == 0 and stats["keys_tuned"] == 0
    assert stats["disk_hits"] == 1


@pytest.mark.parametrize("payload", [
    "{ not json",
    json.dumps({"version": 999, "entries": {"k": {"blocks": {"block_n": 1}}}}),
    json.dumps({"entries": "nope"}),
    json.dumps([1, 2, 3]),
])
def test_corrupt_or_stale_cache_falls_back(tmp_path, payload):
    path = tmp_path / "tuning.json"
    path.write_text(payload)
    autotune.configure(cache_path=str(path), deterministic=False)
    plan = _tune_once()
    assert autotune.stats()["keys_tuned"] == 1        # re-timed, no crash
    # and the file was rewritten as a valid current-version cache
    fresh = json.loads(path.read_text())
    assert fresh["version"] == autotune.CACHE_VERSION
    (entry,) = fresh["entries"].values()
    assert entry["blocks"] == plan


def test_tuned_candidates_all_match_oracle():
    """Whatever plan the tuner lands on, numerics are unchanged: every
    candidate block shape produces the oracle result elementwise."""
    be = resolve_backend("interpret")
    for n, r, B in [(19, 3, 1), (200, 129, 5), (64, 127, 2)]:
        xi = jax.random.uniform(KEY, (n, r)) + 0.1
        u = jax.random.uniform(jax.random.fold_in(KEY, 5), (n, B)) + 0.1
        want = feature_contract_ref(xi, u)
        for cand in autotune.candidates(
                "feature_contract", {"n": n, "r": r, "B": B}, be):
            got = feature_contract_pallas(xi, u, interpret=True, **cand)
            np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-5)


def test_tuning_scope_and_env(monkeypatch, tmp_path):
    assert not autotune.tuning_enabled()
    monkeypatch.setenv(autotune.TUNE_ENV, "1")
    assert autotune.tuning_enabled()
    monkeypatch.delenv(autotune.TUNE_ENV)
    with autotune.tuning(cache_path=str(tmp_path / "t.json")):
        assert autotune.tuning_enabled()
        plan = _tune_once()
        assert autotune.stats()["keys_tuned"] == 1
        assert set(plan) == {"block_n", "block_r"}
    assert not autotune.tuning_enabled()


def test_unwritable_cache_dir_keeps_in_process_winner(monkeypatch):
    autotune.configure(cache_path="/proc/definitely/not/writable.json",
                       deterministic=False)
    plan = _tune_once()
    assert set(plan) == {"block_n", "block_r"}
    autotune.reset_stats()
    assert _tune_once() == plan                       # memory still serves
    assert autotune.stats()["memory_hits"] == 1
