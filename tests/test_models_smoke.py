"""Per-arch smoke tests (deliverable f): reduced same-family config, one
forward/train step on CPU, assert output shapes + no NaNs + decode works."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import (
    decode_step,
    init_caches,
    init_params,
    param_count,
    train_loss,
)

ARCHS = [
    "internvl2_26b", "h2o_danube3_4b", "deepseek_7b", "qwen2_1p5b",
    "smollm_135m", "whisper_base", "zamba2_1p2b", "deepseek_v2_236b",
    "deepseek_v3_671b", "mamba2_1p3b",
]


def _batch(cfg, key, B=2, S=32):
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab)
    if cfg.input_kind == "embeds":
        return {"embeds": 0.1 * jax.random.normal(key, (B, S, cfg.d_model)),
                "labels": tok}
    if cfg.input_kind == "encdec":
        return {"enc_embeds": 0.1 * jax.random.normal(key, (B, S, cfg.d_model)),
                "tokens": tok, "labels": tok}
    return {"tokens": tok, "labels": tok}


def _decode_inputs(cfg, key, B=2, S=32):
    if cfg.input_kind == "embeds":
        return {"embeds": 0.1 * jax.random.normal(key, (B, 1, cfg.d_model))}
    out = {"tokens": jax.random.randint(key, (B, 1), 0, cfg.vocab)}
    if cfg.input_kind == "encdec":
        kv = 0.1 * jax.random.normal(
            key, (cfg.n_layers, B, S, cfg.n_heads, cfg.head_dim))
        out["enc_kv"] = {"k": kv, "v": kv}
    return out


def test_all_archs_registered():
    assert sorted(ARCHS) == list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_train_step_smoke(arch):
    cfg = get_config(arch).tiny(
        param_dtype="float32", compute_dtype="float32",
        ot_iters=5,
    )
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    assert param_count(params) > 0
    batch = _batch(cfg, key)
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(lambda p, b: train_loss(p, cfg, b),
                           has_aux=True)
    )(params, batch)
    assert np.isfinite(float(loss)), metrics
    for k, v in metrics.items():
        assert np.isfinite(float(v)), (k, v)
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_decode_smoke(arch):
    cfg = get_config(arch).tiny(
        param_dtype="float32", compute_dtype="float32",
    )
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    B, S = 2, 32
    caches = init_caches(cfg, B, S)
    step = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c))
    logits, caches = step(params, _decode_inputs(cfg, key, B, S), caches)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # second step advances the cache
    logits2, caches = step(params, _decode_inputs(cfg, key, B, S), caches)
    lengths = [jax.tree.leaves(c)[-1] for c in caches]
    assert all(int(l.reshape(-1)[0]) == 2 for l in lengths)
