"""Streaming supports: paged store, incremental re-solve, serving front.

The tentpole contracts under test:

* paged-store PARITY MATRIX: a streamed support (insert/evict mutations,
  dead slots, arbitrary slot order) solved through the paged runner is
  elementwise-equal to the cold dense solve on the equivalent compact
  support — scaling AND log domains, cold and warm starts, across bucket
  -boundary crossings;
* the all-dead-page fast path: the paged Pallas kernels SKIP pages with
  no live slot (proven by planting garbage in the dead page's operand)
  while agreeing elementwise with the masked XLA oracles;
* zero post-warmup retraces: any number of insert/evict/re-solve cycles
  at fixed capacity replays one compiled executable;
* store bookkeeping: page-table CSR view, most-filled-page allocation,
  in-place overwrite, eviction, capacity errors, page-granular flush;
* serving: mutation coalescing through the admission queue — many
  submitted mutations per pair, ONE warm re-solve per flush.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.geometry import FactoredPositive
from repro.core.paged import PagedFactored
from repro.core.sinkhorn import sinkhorn_geometry, sinkhorn_log_geometry
from repro.kernels.paged import (
    paged_contract_ref,
    paged_feature_contract_pallas,
    paged_feature_matvec_pallas,
    paged_halfstep_pallas,
    paged_matvec_ref,
)
from repro.serving.streaming import StreamingOTService
from repro.streaming import (
    PagedFeatureStore,
    StreamingDistribution,
    StreamingSolver,
    bucket_capacity,
)

RNG = np.random.default_rng(42)
EPS = 0.4
TOL = 1e-6


def _feats(n, r, rng=RNG):
    return (np.abs(rng.normal(size=(n, r))) + 0.1).astype(np.float32)


def _weights(n, rng=RNG):
    return rng.uniform(0.5, 1.5, n).astype(np.float32)


def _dense_ref(xi, zeta, wa, wb, method, **kw):
    geom = FactoredPositive(xi=jnp.asarray(xi), zeta=jnp.asarray(zeta),
                            eps=EPS)
    a = jnp.asarray(wa / wa.sum())
    b = jnp.asarray(wb / wb.sum())
    f = sinkhorn_geometry if method == "scaling" else sinkhorn_log_geometry
    return f(geom, a, b, tol=TOL, use_pallas=False, **kw)


def _pair(n=50, m=40, r=8, method="scaling", use_pallas=False):
    xi, zeta = _feats(n, r), _feats(m, r)
    wa, wb = _weights(n), _weights(m)
    dx = StreamingDistribution.from_features(
        [("x", i) for i in range(n)], xi, wa, eps=EPS)
    dy = StreamingDistribution.from_features(
        [("y", j) for j in range(m)], zeta, wb, eps=EPS)
    sol = StreamingSolver(method=method, tol=TOL, use_pallas=use_pallas)
    pair = sol.register("p", dx, dy)
    return sol, pair, (xi, zeta, wa, wb)


def _live_rows(dist, ids):
    return [dist.store.slot_of(i) for i in ids]


# ---------------------------------------------------------------------------
# Parity matrix: streamed vs cold dense on the equivalent compact support
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["scaling", "log"])
def test_cold_parity_elementwise(method):
    """A paged cold solve (dead-slot padding, normalized-in-runner
    weights) is ELEMENTWISE equal to the compact dense solve — not just
    at the fixed point: scaling seeds u0 = live mask, log pins dead
    potentials to -inf, so the trajectories coincide from iteration 0."""
    sol, pair, (xi, zeta, wa, wb) = _pair(method=method)
    res = sol.cold_solve(pair)
    ref = _dense_ref(xi, zeta, wa, wb, method)
    rows = _live_rows(pair.x, [("x", i) for i in range(len(wa))])
    cols = _live_rows(pair.y, [("y", j) for j in range(len(wb))])
    assert bool(res.converged) and bool(ref.converged)
    assert int(res.n_iter) == int(ref.n_iter)
    np.testing.assert_allclose(float(res.cost), float(ref.cost),
                               rtol=0, atol=5e-6)
    np.testing.assert_allclose(np.asarray(res.f)[rows], np.asarray(ref.f),
                               rtol=0, atol=5e-6)
    np.testing.assert_allclose(np.asarray(res.g)[cols], np.asarray(ref.g),
                               rtol=0, atol=5e-6)
    # dead slots are exactly masked
    dead = ~pair.x.live_mask()
    if method == "scaling":
        assert np.all(np.asarray(res.u)[dead] == 0.0)
    assert np.all(np.isneginf(np.asarray(res.f)[dead]))


@pytest.mark.parametrize("method", ["scaling", "log"])
def test_insert_evict_warm_parity(method):
    """Insert + evict + warm re-solve converges to the same coupling as
    a cold dense solve of the post-mutation support (cost is invariant
    under the potentials' gauge freedom; both ends converged to tol)."""
    n, m, r = 50, 40, 8
    sol, pair, (xi, zeta, wa, wb) = _pair(n, m, r, method=method)
    sol.re_solve(pair)

    new_xi, new_w = _feats(6, r), _weights(6)
    res = sol.update(
        pair,
        remove_x=[("x", 0), ("x", 7), ("x", 13)],
        add_x=dict(ids=[("nx", k) for k in range(6)], feats=new_xi,
                   weights=new_w),
        remove_y=[("y", 2)],
    )
    assert bool(res.converged)
    assert pair.n_warm >= 1

    keep_x = [i for i in range(n) if i not in (0, 7, 13)]
    keep_y = [j for j in range(m) if j != 2]
    xi_m = np.concatenate([xi[keep_x], new_xi])
    wa_m = np.concatenate([wa[keep_x], new_w])
    ref = _dense_ref(xi_m, zeta[keep_y], wa_m, wb[keep_y], method)
    np.testing.assert_allclose(float(res.cost), float(ref.cost),
                               rtol=0, atol=1e-5)
    assert float(res.marginal_err) <= TOL
    # a second cold solve through the SAME paged runner is again
    # elementwise-identical to dense (the equivalent-support invariant
    # holds at any occupancy pattern, not just the fresh packing)
    res_cold = sol.cold_solve(pair)
    rows = _live_rows(pair.x, [("x", i) for i in keep_x]
                      + [("nx", k) for k in range(6)])
    np.testing.assert_allclose(np.asarray(res_cold.f)[rows],
                               np.asarray(ref.f), rtol=0, atol=5e-6)


@pytest.mark.parametrize("method", ["scaling", "log"])
def test_bucket_boundary_crossing(method):
    """Inserting past capacity compact-grows the store to the next
    bucket; the persisted potentials ride through the slot permutation
    and the post-crossing solve still matches dense cold."""
    n, m, r = 50, 40, 8
    sol, pair, (xi, zeta, wa, wb) = _pair(n, m, r, method=method)
    sol.re_solve(pair)
    cap0 = pair.x.capacity
    k = cap0 - n + 5                      # forces the crossing
    big_xi, big_w = _feats(k, r), _weights(k)
    res = sol.update(pair, add_x=dict(
        ids=[("big", i) for i in range(k)], feats=big_xi, weights=big_w))
    assert pair.x.capacity > cap0
    assert pair.x.capacity % pair.x.store.page_size == 0
    assert bool(res.converged)
    xi_m = np.concatenate([xi, big_xi])
    wa_m = np.concatenate([wa, big_w])
    ref = _dense_ref(xi_m, zeta, wa_m, wb, method)
    np.testing.assert_allclose(float(res.cost), float(ref.cost),
                               rtol=0, atol=1e-5)


def test_warm_restart_fewer_iterations():
    """Re-solving after a small mutation from the previous potentials
    takes no more iterations than the cold solve of the same state —
    the whole point of persisting duals."""
    sol, pair, _ = _pair(n=60, m=60, method="scaling")
    sol.re_solve(pair)
    res_noop = sol.re_solve(pair)         # no mutation: instant
    res_cold = sol.cold_solve(pair)
    assert int(res_noop.n_iter) <= int(res_cold.n_iter)


# ---------------------------------------------------------------------------
# Paged kernels: all-dead-page skip path
# ---------------------------------------------------------------------------


def test_all_dead_page_skipped_not_read():
    """The contract kernel must SKIP all-dead pages: garbage planted in
    a dead page's u-block changes nothing (the dense unmasked product
    would differ, proving the predicate actually gates the work)."""
    C, r, B, ps = 192, 8, 4, 64
    xi = jnp.asarray(_feats(C, r))
    u = jnp.asarray(np.abs(RNG.normal(size=(C, B))).astype(np.float32))
    # page 1 fully dead; plant non-zero garbage there
    u = u.at[ps:2 * ps].set(1e6)
    live = jnp.asarray(np.array([ps, 0, ps], np.int32))
    got = paged_feature_contract_pallas(xi, u, live, page_size=ps,
                                        interpret=True)
    want = paged_contract_ref(xi, u, live, page_size=ps)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)
    dense = np.asarray(xi).T @ np.asarray(u)
    assert not np.allclose(np.asarray(got), dense)


def test_paged_row_kernels_zero_dead_pages():
    C, r, B, ps = 128, 8, 3, 64
    xi = jnp.asarray(_feats(C, r))
    t = jnp.asarray(np.abs(RNG.normal(size=(r, B))).astype(np.float32) + .1)
    marg = jnp.asarray(np.abs(RNG.normal(size=(C, B))).astype(np.float32))
    marg = marg.at[:ps].set(0.0)          # dead page's marginal is zero
    live = jnp.asarray(np.array([0, ps], np.int32))
    half = paged_halfstep_pallas(xi, t, marg, live, page_size=ps,
                                 interpret=True)
    assert np.all(np.asarray(half)[:ps] == 0.0)
    mv = paged_feature_matvec_pallas(xi, t, live, page_size=ps,
                                     interpret=True)
    assert np.all(np.asarray(mv)[:ps] == 0.0)
    want = paged_matvec_ref(xi, t, live, page_size=ps)
    np.testing.assert_allclose(np.asarray(mv), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_pallas_paged_plan_parity_scaling():
    """End-to-end: the paged Pallas plan (use_pallas=True, interpret
    backend) solves to the same result as the XLA-operator path."""
    sol_x, pair_x, data = _pair(n=40, m=30, method="scaling",
                                use_pallas=False)
    res_xla = sol_x.cold_solve(pair_x)
    sol_p = StreamingSolver(method="scaling", tol=TOL, use_pallas=True)
    dxp = StreamingDistribution.from_features(
        [("x", i) for i in range(40)], data[0], data[2], eps=EPS)
    dyp = StreamingDistribution.from_features(
        [("y", j) for j in range(30)], data[1], data[3], eps=EPS)
    pair_p = sol_p.register("pal", dxp, dyp)
    res_pal = sol_p.cold_solve(pair_p)
    np.testing.assert_allclose(float(res_pal.cost), float(res_xla.cost),
                               rtol=0, atol=2e-4)
    assert bool(res_pal.converged)


def test_paged_geometry_validation():
    xi = jnp.ones((128, 4))
    live = jnp.ones((2,), jnp.int32)
    with pytest.raises(ValueError, match="exactly one factor pair"):
        PagedFactored(xi=xi, zeta=xi, log_xi=xi, log_zeta=xi,
                      page_live_x=live, page_live_y=live, eps=0.1)
    with pytest.raises(ValueError, match="page_live"):
        PagedFactored(xi=xi, zeta=xi, page_live_x=None, page_live_y=None,
                      eps=0.1)


# ---------------------------------------------------------------------------
# Retrace gate
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["scaling", "log"])
def test_zero_retraces_after_warmup(method):
    sol, pair, _ = _pair(n=30, m=30, method=method)
    sol.warmup(pair)
    t0 = sol.traces
    sol.cold_solve(pair)
    sol.re_solve(pair)
    for k in range(3):
        f = _feats(2, 8)
        sol.update(pair,
                   remove_x=[("x", 2 * k), ("x", 2 * k + 1)],
                   add_x=dict(ids=[("n", k, 0), ("n", k, 1)], feats=f,
                              weights=np.ones(2, np.float32)))
    assert sol.traces == t0, "occupancy changes must never retrace"


# ---------------------------------------------------------------------------
# Store bookkeeping
# ---------------------------------------------------------------------------


def test_store_pagetable_and_allocation():
    st = PagedFeatureStore(4, 256, page_size=64)
    st.add(list(range(70)), np.ones((70, 4), np.float32),
           np.ones(70, np.float32))
    assert st.n_live == 70
    np.testing.assert_array_equal(st.page_live, [64, 6, 0, 0])
    np.testing.assert_array_equal(st.page_indices, [0, 1])
    np.testing.assert_array_equal(st.page_indptr, [0, 64, 70])
    assert st.last_page_len == 6
    # eviction empties page 0 except one row -> new inserts pack into the
    # MOST-FILLED non-full page (page 1), not the emptier page 0
    st.remove(list(range(63)))
    st.add([1000], 2 * np.ones((1, 4), np.float32),
           np.ones(1, np.float32))
    assert st.slot_of(1000) // 64 == 1
    # overwrite stays in place
    slot = st.slot_of(1000)
    st.add([1000], 3 * np.ones((1, 4), np.float32),
           np.ones(1, np.float32))
    assert st.slot_of(1000) == slot
    assert st.weights_host()[slot] == 1.0
    assert np.all(np.asarray(st.device_features())[slot] == 3.0)


def test_store_flush_is_page_granular():
    st = PagedFeatureStore(4, 256, page_size=64)
    st.add([0], np.ones((1, 4), np.float32), np.ones(1, np.float32))
    assert st.flush() >= 0                 # initial full upload
    st.add([1], np.ones((1, 4), np.float32), np.ones(1, np.float32))
    assert st.flush() == 1                 # one dirty page
    st.add([2, 200], np.ones((2, 4), np.float32),
           np.ones(2, np.float32))
    st.remove([0])                         # eviction marks nothing dirty
    assert st.flush() == 1                 # both adds packed one page
    assert st.flush() == 0


def test_store_errors():
    st = PagedFeatureStore(4, 64, page_size=64)
    ones = np.ones((1, 4), np.float32)
    w1 = np.ones(1, np.float32)
    with pytest.raises(ValueError, match="strictly positive"):
        st.add([0], ones, np.zeros(1, np.float32))
    with pytest.raises(ValueError, match="strictly positive"):
        st.add([0], np.zeros((1, 4), np.float32), w1)
    with pytest.raises(KeyError):
        st.remove([99])
    st.add(list(range(64)), np.ones((64, 4), np.float32),
           np.ones(64, np.float32))
    with pytest.raises(ValueError, match="overflows capacity"):
        st.add([999], ones, w1)
    with pytest.raises(ValueError, match="multiple"):
        PagedFeatureStore(4, 100, page_size=64)
    with pytest.raises(ValueError, match="multiple"):
        PagedFeatureStore(4, 64, page_size=30)


def test_bucket_capacity_headroom():
    for n in (1, 63, 64, 500, 5000):
        cap = bucket_capacity(n, 64)
        assert cap % 64 == 0 and cap > n


def test_from_points_featurizes_consistently():
    r, d, n = 16, 3, 20
    anchors = RNG.normal(size=(r, d)).astype(np.float32)
    pts = RNG.normal(size=(n, d)).astype(np.float32)
    dist = StreamingDistribution.from_points(
        list(range(n)), pts, np.ones(n, np.float32), anchors, eps=1.0)
    assert dist.store.rank == r
    feats0 = np.asarray(dist.device_features())[
        [dist.store.slot_of(i) for i in range(n)]]
    assert np.all(feats0 > 0)
    dist.add([n], points=pts[:1], weights=np.ones(1, np.float32))
    row = np.asarray(dist.device_features())[dist.store.slot_of(n)]
    np.testing.assert_allclose(row, feats0[0], rtol=1e-6)


# ---------------------------------------------------------------------------
# Serving front end
# ---------------------------------------------------------------------------


def test_service_coalesces_mutations():
    n, m, r = 30, 30, 8
    xi, zeta = _feats(n, r), _feats(m, r)
    dx = StreamingDistribution.from_features(
        list(range(n)), xi, np.ones(n, np.float32), eps=EPS)
    dy = StreamingDistribution.from_features(
        list(range(m)), zeta, np.ones(m, np.float32), eps=EPS)
    clock = {"t": 0.0}
    svc = StreamingOTService(
        solver=StreamingSolver(method="scaling", tol=TOL,
                               use_pallas=False),
        max_batch=8, max_wait=1.0, clock=lambda: clock["t"])
    svc.register("p", dx, dy)
    t1 = svc.submit_update("p", remove_x=[0])
    t2 = svc.submit_update("p", add_x=dict(
        ids=[900], feats=_feats(1, r), weights=np.ones(1, np.float32)))
    t3 = svc.submit_update("p", remove_y=[5])
    assert svc.pump() == 0                 # before the deadline: nothing
    clock["t"] = 2.0
    assert svc.pump() == 3                 # one flush resolves all three
    assert svc.solves == 1                 # ... with ONE warm re-solve
    assert t1.result is t2.result is t3.result
    assert bool(t3.result.converged)
    assert svc.stats()["coalesce_ratio"] == 3.0
    # the post-batch state reflects every mutation
    assert dx.n_live == n and dy.n_live == m - 1
    ref = _dense_ref(np.concatenate([xi[1:], np.asarray(
        dx.store._feats[dx.store.slot_of(900)])[None]]),
        zeta[[j for j in range(m) if j != 5]],
        np.ones(n, np.float32),
        np.ones(m - 1, np.float32), "scaling")
    np.testing.assert_allclose(float(t1.result.cost), float(ref.cost),
                               rtol=0, atol=1e-5)


def test_service_unknown_pair_and_drain():
    svc = StreamingOTService(solver=StreamingSolver(use_pallas=False))
    with pytest.raises(KeyError):
        svc.submit_update("nope", remove_x=[0])
    n = 20
    xi = _feats(n, 8)
    dx = StreamingDistribution.from_features(
        list(range(n)), xi, np.ones(n, np.float32), eps=EPS)
    dy = StreamingDistribution.from_features(
        list(range(n)), xi, np.ones(n, np.float32), eps=EPS)
    svc.register("q", dx, dy)
    t = svc.submit_update("q", remove_x=[3])
    assert svc.pending == 1
    assert svc.drain() == 1
    assert t.done and t.latency >= 0.0
