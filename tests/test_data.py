"""Data pipeline determinism + shapes."""
import numpy as np

from repro.data import DataConfig, DataPipeline, lm_batch
from repro.data.synthetic import gaussian_clouds, highdim_clouds, sphere_clouds


def test_batch_pure_function_of_step():
    cfg = DataConfig(seed=3, global_batch=4, seq_len=16, vocab=100)
    p1, p2 = DataPipeline(cfg), DataPipeline(cfg)
    b1 = p1.batch_at(17)
    b2 = p2.batch_at(17)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))


def test_batches_differ_across_steps():
    cfg = DataConfig(seed=3, global_batch=4, seq_len=16, vocab=100)
    p = DataPipeline(cfg)
    assert not np.array_equal(np.asarray(p.batch_at(0)["tokens"]),
                              np.asarray(p.batch_at(1)["tokens"]))


def test_labels_are_shifted_tokens():
    b = lm_batch(0, 0, 2, 8, 50)
    assert b["tokens"].shape == (2, 8)
    assert b["labels"].shape == (2, 8)


def test_embeds_kinds():
    cfg = DataConfig(seed=0, global_batch=2, seq_len=8, vocab=50,
                     input_kind="embeds", d_model=16)
    b = DataPipeline(cfg).batch_at(0)
    assert b["embeds"].shape == (2, 8, 16)
    cfg = DataConfig(seed=0, global_batch=2, seq_len=8, vocab=50,
                     input_kind="encdec", d_model=16)
    b = DataPipeline(cfg).batch_at(0)
    assert b["enc_embeds"].shape == (2, 8, 16)
    assert b["tokens"].shape == (2, 8)


def test_paper_point_clouds():
    x, y = gaussian_clouds(0, 100, 2)
    assert x.shape == (100, 2) and y.shape == (100, 2)
    xs, ys = sphere_clouds(0, 50)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(xs), axis=1), 1.0,
                               atol=1e-5)
    xh, yh = highdim_clouds(0, 64)
    assert xh.shape == (64, 28)
