"""Checkpoint manager: roundtrip, atomicity, GC, supervisor restart."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.distributed.fault_tolerance import (
    FaultToleranceConfig,
    TrainingSupervisor,
    remesh_plan,
    suggest_save_every,
)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "b": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                  "s": jnp.zeros((), jnp.int32)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(7, t)
    restored, man = mgr.restore(None, jax.tree.map(jnp.zeros_like, t))
    assert man["step"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 5, 9):
        mgr.save(s, t)
    assert mgr.latest_step() == 9
    assert mgr.all_steps() == [5, 9]        # step 1 garbage-collected


def test_uncommitted_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(3, t)
    # simulate a crash mid-save at step 4: directory without COMMIT
    bad = os.path.join(str(tmp_path), "step_000000004")
    os.makedirs(bad)
    assert mgr.latest_step() == 3


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    t = _tree()
    mgr.save(2, t)
    mgr.wait()
    assert mgr.latest_step() == 2


def test_supervisor_recovers_from_failure(tmp_path):
    """Inject a failure at step 7; supervisor restores step 4 checkpoint and
    replays deterministically to the same final state."""
    mgr = CheckpointManager(str(tmp_path), keep=5)
    cfg = FaultToleranceConfig(save_every=5, max_restarts=3)
    sup = TrainingSupervisor(mgr, cfg)
    fail = {"armed": True}

    def step_fn(state, step):
        if step == 7 and fail["armed"]:
            fail["armed"] = False
            raise RuntimeError("simulated node failure")
        return jax.tree.map(lambda x: x + step, state)

    state0 = {"x": jnp.zeros((3,))}
    final, end = sup.run(state0, 0, 10, step_fn)
    assert sup.restarts == 1
    # deterministic replay: sum over steps 0..9
    np.testing.assert_allclose(np.asarray(final["x"]),
                               np.full(3, sum(range(10))))


def test_remesh_plan_and_save_interval():
    assert remesh_plan(2, 256)["shape"] == (2, 16, 16)
    assert remesh_plan(1, 256)["shape"] == (16, 16)
    assert remesh_plan(1, 64)["shape"] == (4, 16)
    k = suggest_save_every(step_time_s=1.0, ckpt_time_s=30.0,
                           node_mtbf_h=1000.0, n_nodes=1000)
    assert 100 <= k <= 1000
