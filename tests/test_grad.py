"""Envelope-theorem gradients (Prop 3.2) vs finite differences, plus
batched-VJP regressions against a differentiable dense oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    gaussian_features,
    gaussian_log_features,
    rot_factored,
    rot_factored_batched,
)
# legacy hand-derived rules: kept in grad.py as the reference implementation
# the OTObjective parity tests check against (no longer a public re-export)
from repro.core.grad import rot_log_factored, rot_log_factored_batched
from repro.core.features import GaussianFeatureMap


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    n, m, d, r = 40, 35, 2, 64
    x = jax.random.normal(k1, (n, d))
    y = jax.random.normal(k2, (m, d)) * 0.7
    eps = 0.8
    fm = GaussianFeatureMap(r=r, d=d, eps=eps, R=3.0)
    U = fm.init(k3)
    a = jnp.full((n,), 1.0 / n)
    b = jnp.full((m,), 1.0 / m)
    return x, y, U, a, b, eps, fm.q


def test_grad_xi_matches_fd(setup):
    x, y, U, a, b, eps, q = setup
    xi = gaussian_features(x, U, eps=eps, q=q)
    zeta = gaussian_features(y, U, eps=eps, q=q)

    f = lambda xi_: rot_factored(xi_, zeta, a, b, eps, 1e-9, 20000, 1.0)
    g = jax.grad(f)(xi)
    # directional finite difference
    key = jax.random.PRNGKey(9)
    v = jax.random.normal(key, xi.shape) * xi   # relative perturbation
    h = 1e-2    # f32: smaller steps drown in rounding noise
    fd = (f(xi + h * v) - f(xi - h * v)) / (2 * h)
    np.testing.assert_allclose(float(jnp.vdot(g, v)), float(fd), rtol=2e-2)


def test_grad_through_anchors_fd(setup):
    """The GAN path: d W / d anchors via features chain rule."""
    x, y, U, a, b, eps, q = setup

    def f(U_):
        xi = gaussian_features(x, U_, eps=eps, q=q)
        zeta = gaussian_features(y, U_, eps=eps, q=q)
        return rot_factored(xi, zeta, a, b, eps, 1e-9, 20000, 1.0)

    g = jax.grad(f)(U)
    key = jax.random.PRNGKey(11)
    v = jax.random.normal(key, U.shape)
    h = 3e-3    # f32-noise-safe step
    fd = (f(U + h * v) - f(U - h * v)) / (2 * h)
    np.testing.assert_allclose(float(jnp.vdot(g, v)), float(fd), rtol=3e-2)


def test_log_domain_grad_matches_scaling(setup):
    x, y, U, a, b, eps, q = setup

    def f_lin(U_):
        xi = gaussian_features(x, U_, eps=eps, q=q)
        zt = gaussian_features(y, U_, eps=eps, q=q)
        return rot_factored(xi, zt, a, b, eps, 1e-9, 20000, 1.0)

    def f_log(U_):
        lxi = gaussian_log_features(x, U_, eps=eps, q=q)
        lzt = gaussian_log_features(y, U_, eps=eps, q=q)
        return rot_log_factored(lxi, lzt, a, b, eps, 1e-9, 20000)

    g1 = jax.grad(f_lin)(U)
    g2 = jax.grad(f_log)(U)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=2e-3,
                               atol=1e-5)


def test_grad_weights_is_potential(setup):
    """d W / d a = alpha* (up to additive constant on the simplex)."""
    x, y, U, a, b, eps, q = setup
    xi = gaussian_features(x, U, eps=eps, q=q)
    zeta = gaussian_features(y, U, eps=eps, q=q)
    g_a = jax.grad(lambda a_: rot_factored(xi, zeta, a_, b, eps, 1e-9,
                                           20000, 1.0))(a)
    # tangent-space finite difference: move mass between two atoms
    h = 1e-4
    da = jnp.zeros_like(a).at[0].add(h).at[1].add(-h)
    f0 = rot_factored(xi, zeta, a, b, eps, 1e-9, 20000, 1.0)
    f1 = rot_factored(xi, zeta, a + da, b, eps, 1e-9, 20000, 1.0)
    fd = float((f1 - f0) / h)
    pred = float(g_a[0] - g_a[1])
    np.testing.assert_allclose(pred, fd, rtol=5e-2, atol=1e-4)


def test_memory_no_backprop_through_loop(setup):
    """The VJP must not depend on iteration count (envelope property):
    gradients from a 200-iter solve match a 20000-iter solve."""
    x, y, U, a, b, eps, q = setup
    xi = gaussian_features(x, U, eps=eps, q=q)
    zeta = gaussian_features(y, U, eps=eps, q=q)
    g1 = jax.grad(lambda z: rot_factored(z, zeta, a, b, eps, 1e-9, 200, 1.0))(xi)
    g2 = jax.grad(lambda z: rot_factored(z, zeta, a, b, eps, 1e-12, 20000, 1.0))(xi)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-3,
                               atol=1e-7)


# ---------------------------------------------------------------------------
# Batched envelope VJPs vs a differentiable dense oracle
# ---------------------------------------------------------------------------
#
# The production solvers use lax.while_loop (non-reverse-differentiable by
# design); the oracle here unrolls a FIXED number of dense log-domain
# Sinkhorn iterations with lax.scan on the induced cost
# C = -eps log(Xi Zeta^T), so jax.grad backprops straight through the
# iterations. At convergence both must produce the same cost and the same
# gradients — the envelope theorem versus brute-force unrolling.


def _log_sinkhorn_scan_cost(C, a, b, eps, iters=400):
    """Differentiable finite-size oracle: `iters` unrolled dense log-domain
    Sinkhorn iterations, returns the Eq.-6 dual value."""
    loga, logb = jnp.log(a), jnp.log(b)
    negC = -C / eps
    lse = jax.scipy.special.logsumexp

    def body(carry, _):
        f, g = carry
        g = eps * (logb - lse(negC + (f / eps)[:, None], axis=0))
        f = eps * (loga - lse(negC + (g / eps)[None, :], axis=1))
        return (f, g), None

    f0 = jnp.zeros_like(a)
    g0 = jnp.zeros_like(b)
    (f, g), _ = jax.lax.scan(body, (f0, g0), None, length=iters)
    return jnp.vdot(a, f) + jnp.vdot(b, g)


@pytest.fixture(scope="module")
def batched_setup(setup):
    x, y, U, a, b, eps, q = setup
    B = 3
    keys = jax.random.split(jax.random.PRNGKey(21), 2 * B)
    xs = jnp.stack([x + 0.05 * jax.random.normal(keys[i], x.shape)
                    for i in range(B)])
    ys = jnp.stack([y + 0.05 * jax.random.normal(keys[B + i], y.shape)
                    for i in range(B)])
    xi = jnp.stack([gaussian_features(xs[i], U, eps=eps, q=q)
                    for i in range(B)])
    zeta = jnp.stack([gaussian_features(ys[i], U, eps=eps, q=q)
                      for i in range(B)])
    aB = jnp.broadcast_to(a, (B,) + a.shape)
    bB = jnp.broadcast_to(b, (B,) + b.shape)
    return xi, zeta, aB, bB, eps


def test_batched_cost_matches_oracle(batched_setup):
    xi, zeta, a, b, eps = batched_setup
    w = rot_factored_batched(xi, zeta, a, b, eps, 1e-9, 20000, 1.0)
    for i in range(xi.shape[0]):
        C = -eps * jnp.log(xi[i] @ zeta[i].T)
        w_ref = _log_sinkhorn_scan_cost(C, a[i], b[i], eps)
        np.testing.assert_allclose(float(w[i]), float(w_ref), rtol=1e-5)


def test_batched_vjp_matches_grad_through_oracle(batched_setup):
    """Batched envelope VJP w.r.t. the features == jax.grad through the
    unrolled dense oracle chained through C(Xi) = -eps log(Xi Zeta^T)."""
    xi, zeta, a, b, eps = batched_setup
    gB = jax.grad(lambda z: jnp.sum(
        rot_factored_batched(z, zeta, a, b, eps, 1e-9, 20000, 1.0)))(xi)
    for i in range(xi.shape[0]):
        oracle = lambda z: _log_sinkhorn_scan_cost(
            -eps * jnp.log(z @ zeta[i].T), a[i], b[i], eps)
        g_ref = jax.grad(oracle)(xi[i])
        np.testing.assert_allclose(np.asarray(gB[i]), np.asarray(g_ref),
                                   rtol=5e-3, atol=1e-6)


def test_batched_log_vjp_matches_scaling_vjp(batched_setup):
    """Log-domain batched VJP == scaling-space batched VJP (chain rule
    dW/dlogXi = dW/dXi * Xi)."""
    xi, zeta, a, b, eps = batched_setup
    lxi, lzeta = jnp.log(xi), jnp.log(zeta)
    g_lin = jax.grad(lambda z: jnp.sum(
        rot_factored_batched(z, zeta, a, b, eps, 1e-9, 20000, 1.0)))(xi)
    g_log = jax.grad(lambda z: jnp.sum(
        rot_log_factored_batched(z, lzeta, a, b, eps, 1e-9, 20000)))(lxi)
    np.testing.assert_allclose(np.asarray(g_log), np.asarray(g_lin * xi),
                               rtol=2e-3, atol=1e-6)


def test_batched_weight_grad_is_potential(batched_setup):
    """d W_b / d a_b = f_b* elementwise across the batch (envelope wrt the
    linear term), matching the single-problem contract."""
    xi, zeta, a, b, eps = batched_setup
    gB = jax.grad(lambda w: jnp.sum(
        rot_factored_batched(xi, zeta, w, b, eps, 1e-9, 20000, 1.0)))(a)
    for i in range(xi.shape[0]):
        gi = jax.grad(lambda w: rot_factored(xi[i], zeta[i], w, b[i], eps,
                                             1e-9, 20000, 1.0))(a[i])
        np.testing.assert_allclose(np.asarray(gB[i]), np.asarray(gi),
                                   rtol=1e-4, atol=1e-7)
