"""Envelope-theorem gradients (Prop 3.2) vs finite differences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    gaussian_features,
    gaussian_log_features,
    rot_factored,
    rot_log_factored,
)
from repro.core.features import GaussianFeatureMap


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    n, m, d, r = 40, 35, 2, 64
    x = jax.random.normal(k1, (n, d))
    y = jax.random.normal(k2, (m, d)) * 0.7
    eps = 0.8
    fm = GaussianFeatureMap(r=r, d=d, eps=eps, R=3.0)
    U = fm.init(k3)
    a = jnp.full((n,), 1.0 / n)
    b = jnp.full((m,), 1.0 / m)
    return x, y, U, a, b, eps, fm.q


def test_grad_xi_matches_fd(setup):
    x, y, U, a, b, eps, q = setup
    xi = gaussian_features(x, U, eps=eps, q=q)
    zeta = gaussian_features(y, U, eps=eps, q=q)

    f = lambda xi_: rot_factored(xi_, zeta, a, b, eps, 1e-9, 20000, 1.0)
    g = jax.grad(f)(xi)
    # directional finite difference
    key = jax.random.PRNGKey(9)
    v = jax.random.normal(key, xi.shape) * xi   # relative perturbation
    h = 1e-2    # f32: smaller steps drown in rounding noise
    fd = (f(xi + h * v) - f(xi - h * v)) / (2 * h)
    np.testing.assert_allclose(float(jnp.vdot(g, v)), float(fd), rtol=2e-2)


def test_grad_through_anchors_fd(setup):
    """The GAN path: d W / d anchors via features chain rule."""
    x, y, U, a, b, eps, q = setup

    def f(U_):
        xi = gaussian_features(x, U_, eps=eps, q=q)
        zeta = gaussian_features(y, U_, eps=eps, q=q)
        return rot_factored(xi, zeta, a, b, eps, 1e-9, 20000, 1.0)

    g = jax.grad(f)(U)
    key = jax.random.PRNGKey(11)
    v = jax.random.normal(key, U.shape)
    h = 3e-3    # f32-noise-safe step
    fd = (f(U + h * v) - f(U - h * v)) / (2 * h)
    np.testing.assert_allclose(float(jnp.vdot(g, v)), float(fd), rtol=3e-2)


def test_log_domain_grad_matches_scaling(setup):
    x, y, U, a, b, eps, q = setup

    def f_lin(U_):
        xi = gaussian_features(x, U_, eps=eps, q=q)
        zt = gaussian_features(y, U_, eps=eps, q=q)
        return rot_factored(xi, zt, a, b, eps, 1e-9, 20000, 1.0)

    def f_log(U_):
        lxi = gaussian_log_features(x, U_, eps=eps, q=q)
        lzt = gaussian_log_features(y, U_, eps=eps, q=q)
        return rot_log_factored(lxi, lzt, a, b, eps, 1e-9, 20000)

    g1 = jax.grad(f_lin)(U)
    g2 = jax.grad(f_log)(U)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=2e-3,
                               atol=1e-5)


def test_grad_weights_is_potential(setup):
    """d W / d a = alpha* (up to additive constant on the simplex)."""
    x, y, U, a, b, eps, q = setup
    xi = gaussian_features(x, U, eps=eps, q=q)
    zeta = gaussian_features(y, U, eps=eps, q=q)
    g_a = jax.grad(lambda a_: rot_factored(xi, zeta, a_, b, eps, 1e-9,
                                           20000, 1.0))(a)
    # tangent-space finite difference: move mass between two atoms
    h = 1e-4
    da = jnp.zeros_like(a).at[0].add(h).at[1].add(-h)
    f0 = rot_factored(xi, zeta, a, b, eps, 1e-9, 20000, 1.0)
    f1 = rot_factored(xi, zeta, a + da, b, eps, 1e-9, 20000, 1.0)
    fd = float((f1 - f0) / h)
    pred = float(g_a[0] - g_a[1])
    np.testing.assert_allclose(pred, fd, rtol=5e-2, atol=1e-4)


def test_memory_no_backprop_through_loop(setup):
    """The VJP must not depend on iteration count (envelope property):
    gradients from a 200-iter solve match a 20000-iter solve."""
    x, y, U, a, b, eps, q = setup
    xi = gaussian_features(x, U, eps=eps, q=q)
    zeta = gaussian_features(y, U, eps=eps, q=q)
    g1 = jax.grad(lambda z: rot_factored(z, zeta, a, b, eps, 1e-9, 200, 1.0))(xi)
    g2 = jax.grad(lambda z: rot_factored(z, zeta, a, b, eps, 1e-12, 20000, 1.0))(xi)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-3,
                               atol=1e-7)
