"""Launcher-layer unit tests: specs, shardings, loop-aware HLO analysis."""
import jax
import numpy as np

from repro.configs import get_config, get_shape
from repro.launch.hlo_analysis import parse_collectives, roofline_terms
from repro.launch.hlo_loops import analyze_hlo
from repro.launch.mesh import make_local_mesh
from repro.launch.specs import input_specs, param_shardings


def test_input_specs_cover_all_shapes():
    for arch in ("smollm_135m", "whisper_base", "internvl2_26b",
                 "mamba2_1p3b"):
        cfg = get_config(arch)
        for shp in ("train_4k", "prefill_32k", "decode_32k"):
            spec = input_specs(cfg, get_shape(shp))
            assert spec, (arch, shp)
            for leaf in jax.tree.leaves(spec):
                assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_param_shardings_divisible():
    """Every assigned mesh axis must divide its dim, for every leaf."""
    mesh = make_local_mesh(1, 1)
    for arch in ("smollm_135m", "deepseek_v3_671b", "zamba2_1p2b"):
        cfg = get_config(arch).tiny()
        p_shape = jax.eval_shape(
            lambda k: __import__("repro.models", fromlist=["init_params"]
                                 ).init_params(k, cfg),
            jax.random.PRNGKey(0))
        shards = param_shardings(mesh, cfg, p_shape)
        for leaf, sh in zip(jax.tree.leaves(p_shape),
                            jax.tree.leaves(shards)):
            for dim, axes in zip(leaf.shape, sh.spec):
                if axes is None:
                    continue
                axes = axes if isinstance(axes, tuple) else (axes,)
                total = 1
                for a in axes:
                    total *= mesh.shape[a]
                assert dim % total == 0, (leaf.shape, sh.spec)


def test_parse_collectives_ring_factors():
    hlo = """
  %ag = bf16[16,128] all-gather(%x), replica_groups=[16,16]
  %ar = f32[64] all-reduce(%y), replica_groups=[1,256]
  %cp = f32[8,8] collective-permute(%z)
"""
    st = parse_collectives(hlo, 256)
    assert st.counts == {"all-gather": 1, "all-reduce": 1,
                         "collective-permute": 1}
    ag = 16 * 128 * 2 * (15 / 16)
    ar = 2 * 64 * 4 * (255 / 256)
    cp = 8 * 8 * 4
    np.testing.assert_allclose(st.wire_bytes, ag + ar + cp, rtol=1e-6)


def test_roofline_terms_dominance():
    t = roofline_terms(197e12, 819e9 * 2, 50e9 * 0.5)   # 1s/2s/0.5s
    assert t["dominant"] == "memory"
    np.testing.assert_allclose(t["compute_s"], 1.0)
    np.testing.assert_allclose(t["memory_s"], 2.0)
    np.testing.assert_allclose(t["collective_s"], 0.5)


def test_loop_aware_analyzer_multiplies_trip_counts():
    """A dot inside a while body must count trip_count times."""
    hlo = """
%body (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %gte0 = s32[] get-tuple-element(%p), index=0
  %gte1 = f32[4,4] get-tuple-element(%p), index=1
  %d = f32[4,4] dot(%gte1, %gte1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %next = s32[] add(%gte0, %one)
  ROOT %t = (s32[], f32[4,4]) tuple(%next, %d)
}

%cond (p: (s32[], f32[4,4])) -> pred[] {
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[4,4]) -> f32[4,4] {
  %z = s32[] constant(0)
  %t0 = (s32[], f32[4,4]) tuple(%z, %a)
  %w = (s32[], f32[4,4]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[4,4] get-tuple-element(%w), index=1
}
"""
    res = analyze_hlo(hlo, 1)
    # one 4x4x4 dot (128 flops) x 7 trips
    np.testing.assert_allclose(res["flops_per_device"], 7 * 2 * 4 * 4 * 4)


def test_loop_aware_collectives_in_loops():
    hlo = """
%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8] get-tuple-element(%p), index=1
  %ar = f32[8] all-reduce(%x), replica_groups=[1,4], to_apply=%sum
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8]) tuple(%ni, %ar)
}

%sum (a: f32[], b: f32[]) -> f32[] {
  ROOT %s = f32[] add(%a, %b)
}

%cond (p: (s32[], f32[8])) -> pred[] {
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(3)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8]) -> f32[8] {
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8]) tuple(%z, %a)
  %w = (s32[], f32[8]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[8] get-tuple-element(%w), index=1
}
"""
    res = analyze_hlo(hlo, 4)
    per = 2 * 8 * 4 * (3 / 4)
    np.testing.assert_allclose(res["wire_bytes_per_device"], 3 * per)
    assert res["collective_counts"]["all-reduce"] == 3
