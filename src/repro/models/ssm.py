"""Mamba2 / SSD (state-space duality) blocks — arXiv:2405.21060.

Training uses the chunked SSD algorithm: the sequence is cut into chunks of
length Q; within a chunk the quadratic "attention-like" form runs on the
MXU, across chunks a linear recurrence on the (H, P, N) chunk states is
scanned. Decode is the O(1) recurrent update on a persistent state — this
is why the ssm/hybrid archs are the ones that RUN the long_500k shape.

Shapes: x (B, S, H*P) with head dim P, state dim N, shared B/C (n_groups=1).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .layers import init_linear, linear, rmsnorm, trunc_normal

__all__ = [
    "init_mamba2",
    "mamba2_train",
    "mamba2_decode",
    "init_mamba2_cache",
    "Mamba2Cache",
]


def _segsum(x: jax.Array) -> jax.Array:
    """(..., Q) -> (..., Q, Q) lower-triangular segment sums:
    out[i, j] = sum_{k=j+1..i} x[k]  (=-inf above diagonal)."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,        # (B, S, H, P) inputs (already dt-scaled outside? no: raw)
    dt: jax.Array,       # (B, S, H) positive step sizes
    A: jax.Array,        # (H,) negative decay rates
    Bm: jax.Array,       # (B, S, N) input matrix (n_groups=1, shared over heads)
    Cm: jax.Array,       # (B, S, N) output matrix
    *,
    chunk: int = 128,
    init_state: Optional[jax.Array] = None,   # (B, H, P, N)
):
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nC = Sp // chunk
    xb = x.reshape(Bsz, nC, chunk, H, P)
    dtb = dt.reshape(Bsz, nC, chunk, H)
    Bb = Bm.reshape(Bsz, nC, chunk, N)
    Cb = Cm.reshape(Bsz, nC, chunk, N)

    dA = dtb * A[None, None, None, :]                    # (B,C,Q,H) <= 0
    dA = jnp.transpose(dA, (0, 3, 1, 2))                 # (B,H,C,Q)
    dA_cs = jnp.cumsum(dA, axis=-1)                      # within-chunk cumsum

    # ---- intra-chunk (quadratic within chunk, MXU-friendly) ----
    L = jnp.exp(_segsum(dA))                             # (B,H,C,Q,Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cb, Bb)       # (B,C,Q,Q) shared/head
    xdt = xb * dtb[..., None]                            # dt-weighted input
    y_diag = jnp.einsum(
        "bcqk,bhcqk,bckhp->bcqhp", scores, L, xdt
    )

    # ---- chunk states ----
    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)      # (B,H,C,Q)
    states = jnp.einsum("bckn,bhck,bckhp->bchpn", Bb, decay_states, xdt)

    # ---- inter-chunk linear recurrence on states ----
    chunk_decay = jnp.exp(dA_cs[..., -1])                # (B,H,C)

    def scan_fn(h, inp):
        st, dec = inp                                    # (B,H,P,N), (B,H)
        h_new = h * dec[..., None, None] + st
        return h_new, h

    h0 = (
        jnp.zeros((Bsz, H, P, N), x.dtype)
        if init_state is None
        else init_state.astype(x.dtype)
    )
    states_t = jnp.moveaxis(states, 1, 0)                # (C,B,H,P,N)
    decay_t = jnp.moveaxis(chunk_decay, 2, 0)            # (C,B,H)
    final, prev_states = jax.lax.scan(scan_fn, h0, (states_t, decay_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)        # (B,C,H,P,N)

    # ---- inter-chunk contribution ----
    state_decay = jnp.exp(dA_cs)                         # (B,H,C,Q)
    y_off = jnp.einsum(
        "bcqn,bhcq,bchpn->bcqhp", Cb, state_decay, prev_states
    )

    y = (y_diag + y_off).reshape(Bsz, Sp, H, P)[:, :S]
    return y, final


# ---------------------------------------------------------------------------
# Full Mamba2 mixer layer
# ---------------------------------------------------------------------------


def ssd_context_parallel(
    x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
    Cm: jax.Array, *, chunk: int = 128,
) -> jax.Array:
    """SSD with the sequence sharded over the 'model' axis (context
    parallelism): each rank runs the chunked SSD on its LOCAL segment with
    zero initial state, ranks exchange one (B, H, P, N) state + one (B, H)
    segment-decay via all_gather, the true inbound state per rank comes
    from an associative linear-recurrence scan over ranks, and a cheap
    linear correction term is added locally.

    This removes the per-chunk resharding traffic of running the global
    chunk scan across a sharded axis (§Perf, mamba2 collective hillclimb).
    Falls back to plain ssd_chunked off-mesh.
    """
    from ..distributed.sharding import current_mesh_context

    ctx = current_mesh_context()
    if ctx is None or ctx.tp_axis is None:
        y, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
        return y
    tp = ctx.tp_axis
    dp = ctx.dp_axes if ctx.dp_axes else None
    from jax.sharding import PartitionSpec as P

    def body(x_l, dt_l, B_l, C_l):
        y0, h_loc = ssd_chunked(x_l, dt_l, A, B_l, C_l, chunk=chunk)
        dA = dt_l * A[None, None, :]                       # (B, S_l, H)
        dacs = jnp.cumsum(dA, axis=1)                      # within segment
        seg_decay = jnp.exp(dacs[:, -1, :])                # (B, H)
        hs = jax.lax.all_gather(h_loc, tp)                 # (R, B,H,P,N)
        ds = jax.lax.all_gather(seg_decay, tp)             # (R, B,H)

        def combine(a, b):
            d1, h1 = a
            d2, h2 = b
            return d1 * d2, h1 * d2[..., None, None] + h2

        D, H = jax.lax.associative_scan(combine, (ds, hs), axis=0)
        r = jax.lax.axis_index(tp)
        # inbound state = cumulative state after ranks 0..r-1 (zero for r=0)
        Hpad = jnp.concatenate([jnp.zeros_like(H[:1]), H], axis=0)
        init = jax.lax.dynamic_index_in_dim(Hpad, r, 0, keepdims=False)
        y_corr = jnp.einsum(
            "bsn,bhs,bhpn->bshp", C_l,
            jnp.exp(jnp.moveaxis(dacs, 1, 2)), init.astype(x_l.dtype))
        return y0 + y_corr.astype(y0.dtype)

    from ..distributed.sharding import shard_map

    fn = shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(dp, tp, None, None), P(dp, tp, None),
                  P(dp, tp, None), P(dp, tp, None)),
        out_specs=P(dp, tp, None, None),
        check_vma=False,
    )
    return fn(x, dt, Bm, Cm)


def init_mamba2(
    key, d_model: int, *, d_state: int = 128, head_dim: int = 64,
    expand: int = 2, conv_kernel: int = 4, dtype=jnp.float32,
):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    ks = jax.random.split(key, 4)
    conv_dim = d_inner + 2 * d_state
    out_std = 0.02 / (2.0 ** 0.5)
    return {
        # order: [z (gate), x, B, C, dt]
        "in_proj": init_linear(
            ks[0], d_model, 2 * d_inner + 2 * d_state + n_heads, dtype=dtype
        ),
        "conv_w": trunc_normal(ks[1], (conv_kernel, conv_dim), std=0.1, dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, n_heads, dtype=jnp.float32)
        ).astype(dtype),
        "dt_bias": jnp.zeros((n_heads,), dtype),
        "D": jnp.ones((n_heads,), dtype),
        "norm_w": jnp.ones((d_inner,), dtype),
        "out_proj": init_linear(ks[2], d_inner, d_model, std=out_std, dtype=dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over seq: x (B,S,C), w (K,C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(
        xp[:, k : k + x.shape[1], :] * w[k][None, None, :] for k in range(K)
    )
    return jax.nn.silu(out + b[None, None, :])


def _split_proj(zxbcdt, d_inner, d_state, n_heads):
    z = zxbcdt[..., :d_inner]
    xr = zxbcdt[..., d_inner : 2 * d_inner]
    Bm = zxbcdt[..., 2 * d_inner : 2 * d_inner + d_state]
    Cm = zxbcdt[..., 2 * d_inner + d_state : 2 * d_inner + 2 * d_state]
    dt = zxbcdt[..., 2 * d_inner + 2 * d_state :]
    return z, xr, Bm, Cm, dt


def mamba2_train(
    p, x: jax.Array, *, d_state: int = 128, head_dim: int = 64,
    expand: int = 2, chunk: int = 128,
) -> jax.Array:
    B, S, d_model = x.shape
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    zxbcdt = linear(p["in_proj"], x)
    z, xr, Bm, Cm, dt = _split_proj(zxbcdt, d_inner, d_state, n_heads)
    conv_in = jnp.concatenate([xr, Bm, Cm], axis=-1)
    conv_out = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
    xr = conv_out[..., :d_inner]
    Bm = conv_out[..., d_inner : d_inner + d_state]
    Cm = conv_out[..., d_inner + d_state :]
    dt = jax.nn.softplus(dt + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xr.reshape(B, S, n_heads, head_dim)
    # context-parallel on a mesh (seq sharded over 'model'), plain otherwise
    y = ssd_context_parallel(xh, dt, A, Bm, Cm, chunk=chunk)
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(B, S, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"])
    return linear(p["out_proj"], y)


class Mamba2Cache(NamedTuple):
    conv: jax.Array       # (B, K-1, conv_dim) rolling conv inputs
    state: jax.Array      # (B, H, P, N) SSM state
    length: jax.Array


def init_mamba2_cache(
    batch, d_model, *, d_state=128, head_dim=64, expand=2, conv_kernel=4,
    dtype=jnp.float32,
):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_dim = d_inner + 2 * d_state
    return Mamba2Cache(
        jnp.zeros((batch, conv_kernel - 1, conv_dim), dtype),
        jnp.zeros((batch, n_heads, head_dim, d_state), dtype),
        jnp.zeros((), jnp.int32),
    )


def mamba2_decode(
    p, x: jax.Array, cache: Mamba2Cache, *, d_state: int = 128,
    head_dim: int = 64, expand: int = 2,
):
    """O(1) single-token state update. x (B, 1, d_model)."""
    B = x.shape[0]
    d_model = x.shape[-1]
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    zxbcdt = linear(p["in_proj"], x)
    z, xr, Bm, Cm, dt = _split_proj(zxbcdt, d_inner, d_state, n_heads)
    conv_in = jnp.concatenate([xr, Bm, Cm], axis=-1)[:, 0]   # (B, conv_dim)
    hist = jnp.concatenate([cache.conv, conv_in[:, None, :]], axis=1)
    w = p["conv_w"]
    conv_out = jnp.einsum("bkc,kc->bc", hist, w) + p["conv_b"][None, :]
    conv_out = jax.nn.silu(conv_out)
    xr = conv_out[:, :d_inner]
    Bv = conv_out[:, d_inner : d_inner + d_state]
    Cv = conv_out[:, d_inner + d_state :]
    dtv = jax.nn.softplus(dt[:, 0] + p["dt_bias"][None, :])   # (B, H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dtv * A[None, :])                         # (B, H)
    xh = xr.reshape(B, n_heads, head_dim)
    upd = jnp.einsum("bhp,bn,bh->bhpn", xh, Bv, dtv)
    state = cache.state * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, Cv)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(B, 1, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"])
    out = linear(p["out_proj"], y)
    new_cache = Mamba2Cache(hist[:, 1:], state.astype(cache.state.dtype),
                            cache.length + 1)
    return out, new_cache
