from .model import (
    cache_logical_axes,
    decode_step,
    forward,
    init_caches,
    init_params,
    param_count,
    prefill,
    shard_caches,
    train_loss,
)

__all__ = [
    "cache_logical_axes",
    "decode_step",
    "forward",
    "init_caches",
    "init_params",
    "param_count",
    "prefill",
    "shard_caches",
    "train_loss",
]
