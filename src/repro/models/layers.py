"""Shared neural building blocks (pure JAX, functional params-as-pytrees)."""
from __future__ import annotations


import jax
import jax.numpy as jnp

__all__ = [
    "trunc_normal",
    "rmsnorm",
    "layernorm",
    "rotary_cos_sin",
    "apply_rotary",
    "init_linear",
    "linear",
    "init_mlp",
    "mlp",
    "init_embedding",
]


def trunc_normal(key, shape, std=0.02, dtype=jnp.float32):
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32).astype(dtype)


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(dtype)


def layernorm(x, w, b, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dtype)


def rotary_cos_sin(positions: jax.Array, head_dim: int, theta: float = 10000.0):
    """positions (...,) -> cos/sin (..., head_dim/2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rotary(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., S, H, hd); cos/sin (..., S, hd/2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def init_linear(key, d_in, d_out, *, bias=False, std=0.02, dtype=jnp.float32):
    p = {"w": trunc_normal(key, (d_in, d_out), std=std, dtype=dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_mlp(key, d_model, d_ff, *, gated=True, bias=False, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    out_std = 0.02 / (2.0 ** 0.5)
    if gated:
        return {
            "up": init_linear(ks[0], d_model, d_ff, bias=bias, dtype=dtype),
            "gate": init_linear(ks[1], d_model, d_ff, bias=bias, dtype=dtype),
            "down": init_linear(ks[2], d_ff, d_model, bias=bias, std=out_std, dtype=dtype),
        }
    return {
        "up": init_linear(ks[0], d_model, d_ff, bias=bias, dtype=dtype),
        "down": init_linear(ks[2], d_ff, d_model, bias=bias, std=out_std, dtype=dtype),
    }


def mlp(p, x, *, gated=True):
    if gated:
        return linear(p["down"], jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x))
    return linear(p["down"], jax.nn.gelu(linear(p["up"], x)))


def init_embedding(key, vocab, d_model, dtype=jnp.float32):
    return {"table": trunc_normal(key, (vocab, d_model), std=0.02, dtype=dtype)}
