"""Model assembly: configs -> init / forward / loss / prefill / decode.

Layer plans (configs.base.ArchConfig.layer_plan) are grouped into runs of
identical block kinds; each run's params are stacked on a leading axis and
executed with ``lax.scan`` (+ optional remat) so compile time and HBM stay
bounded at 61-layer scale. Hybrid (zamba2) shared-attention blocks keep a
single param set reused at every occurrence, each occurrence with its own
KV cache.

Sharding is expressed through logical axis hints (distributed.sharding):
activations (batch, seq, -) for train/prefill, KV caches (batch, kvseq, -)
for decode, vocab-parallel embedding/head.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core.objective import ExecutionPolicy
from ..distributed.sharding import current_mesh_context, shard
from .attention import (
    GQACache,
    MLACache,
    cross_attention,
    gqa_attend_step,
    gqa_train,
    init_cross_attention,
    init_gqa,
    init_gqa_cache,
    init_mla,
    init_mla_cache,
    mla_attend_step,
    mla_train,
)
from .layers import (
    init_embedding,
    init_linear,
    init_mlp,
    layernorm,
    linear,
    mlp,
    rmsnorm,
    trunc_normal,
)
from .moe import init_moe, moe_dense, moe_ep_local
from .ot_loss import init_ot_loss, ot_prototype_loss
from .ssm import (
    Mamba2Cache,
    init_mamba2,
    init_mamba2_cache,
    mamba2_decode,
    mamba2_train,
)

__all__ = [
    "init_params",
    "forward",
    "train_loss",
    "prefill",
    "decode_step",
    "init_caches",
    "group_plan",
    "param_count",
]


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _norm(p, x, cfg: ArchConfig):
    if cfg.norm == "layernorm":
        return layernorm(x, p["w"], p["b"], cfg.norm_eps)
    return rmsnorm(x, p["w"], cfg.norm_eps)


def _init_norm(cfg: ArchConfig, d=None):
    d = cfg.d_model if d is None else d
    if cfg.norm == "layernorm":
        return {"w": jnp.ones((d,), cfg.dtype), "b": jnp.zeros((d,), cfg.dtype)}
    return {"w": jnp.ones((d,), cfg.dtype)}


def group_plan(plan: List[str]) -> List[Tuple[str, int]]:
    groups: List[Tuple[str, int]] = []
    for kind in plan:
        if groups and groups[-1][0] == kind and kind != "shared_attn":
            groups[-1] = (kind, groups[-1][1] + 1)
        else:
            groups.append((kind, 1))
    return groups


def effective_window(cfg: ArchConfig, s_max: int) -> Optional[int]:
    if cfg.window is not None:
        return cfg.window
    if cfg.long_context_window is not None and s_max > 65536:
        return cfg.long_context_window
    return None


# ---------------------------------------------------------------------------
# per-block init / train / decode
# ---------------------------------------------------------------------------


def _init_block(key, kind: str, cfg: ArchConfig) -> Dict:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {}
    if kind in ("attn", "attn_moe", "shared_attn", "enc_attn"):
        p["norm1"] = _init_norm(cfg)
        p["attn"] = init_gqa(
            ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            qkv_bias=cfg.qkv_bias, dtype=cfg.dtype,
        )
    elif kind in ("mla", "mla_moe"):
        p["norm1"] = _init_norm(cfg)
        p["attn"] = init_mla(
            ks[0], cfg.d_model, cfg.n_heads, kv_lora=cfg.kv_lora,
            q_lora=cfg.q_lora, qk_nope=cfg.qk_nope, qk_rope=cfg.qk_rope,
            v_head=cfg.v_head, dtype=cfg.dtype,
        )
    elif kind == "mamba":
        p["norm1"] = _init_norm(cfg)
        p["mixer"] = init_mamba2(
            ks[0], cfg.d_model, d_state=cfg.ssm_state,
            head_dim=cfg.ssm_head_dim, expand=cfg.ssm_expand,
            conv_kernel=cfg.conv_kernel, dtype=cfg.dtype,
        )
        return p
    elif kind == "dec_attn":
        p["norm1"] = _init_norm(cfg)
        p["attn"] = init_gqa(
            ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            qkv_bias=cfg.qkv_bias, dtype=cfg.dtype,
        )
        p["norm_x"] = _init_norm(cfg)
        p["xattn"] = init_cross_attention(
            ks[2], cfg.d_model, cfg.n_heads, cfg.head_dim, dtype=cfg.dtype
        )
    else:
        raise ValueError(kind)

    # FFN half
    if kind.endswith("_moe"):
        p["norm2"] = _init_norm(cfg)
        p["moe"] = init_moe(
            ks[1], cfg.d_model, cfg.moe_d_ff, cfg.n_experts, dtype=cfg.dtype
        )
        if cfg.n_shared_experts:
            p["shared_mlp"] = init_mlp(
                ks[3], cfg.d_model, cfg.n_shared_experts * cfg.moe_d_ff,
                gated=cfg.mlp_gated, dtype=cfg.dtype,
            )
    elif cfg.d_ff:
        p["norm2"] = _init_norm(cfg)
        p["mlp"] = init_mlp(
            ks[1], cfg.d_model, cfg.d_ff, gated=cfg.mlp_gated, dtype=cfg.dtype
        )
    return p


def _ot_policy(cfg: ArchConfig) -> ExecutionPolicy:
    """The run-wide OT execution policy, derived from config. A pure
    (static, hashable) function of cfg — equal to the record the launch
    layer constructs once per run and logs."""
    return ExecutionPolicy.from_config(cfg)


def _moe_apply(p, x2: jax.Array, cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    """x2 (B, S, d) normed input -> (out, aux). EP under a mesh, dense otherwise."""
    B, S, d = x2.shape
    policy = _ot_policy(cfg)
    ctx = current_mesh_context()
    if ctx is None or ctx.tp_axis is None:
        out, aux = moe_dense(
            p["moe"], x2.reshape(-1, d), top_k=cfg.top_k, router=cfg.router,
            policy=policy,
        )
        return out.reshape(B, S, d), aux

    from jax.sharding import PartitionSpec as P

    dp = ctx.dp_axes if ctx.dp_axes else None
    tp = ctx.tp_axis
    fsdp_axes = ctx.dp_axes if (cfg.zero3 and ctx.dp_axes) else None
    fsdp = (fsdp_axes if fsdp_axes and len(fsdp_axes) > 1
            else (fsdp_axes[0] if fsdp_axes else None))

    def body(p_loc, x_loc):
        Bl, Sl, _ = x_loc.shape
        out, aux = moe_ep_local(
            p_loc, x_loc.reshape(-1, d), top_k=cfg.top_k,
            n_experts=cfg.n_experts, axis=tp, router=cfg.router,
            capacity_factor=cfg.capacity_factor,
            fsdp_axis=fsdp, policy=policy,
        )
        if dp:
            aux = jax.lax.pmean(aux, dp)
        return out.reshape(Bl, Sl, d), aux

    wspec_d1 = P(tp, fsdp, None) if fsdp else P(tp, None, None)
    wspec_d2 = P(tp, None, fsdp) if fsdp else P(tp, None, None)
    in_specs = (
        {
            "router": P(None, None),
            "up": wspec_d1,
            "gate": wspec_d1,
            "down": wspec_d2,
        },
        P(dp, tp, None),
    )
    out_specs = (P(dp, tp, None), P())
    from ..distributed.sharding import shard_map

    fn = shard_map(
        body, mesh=ctx.mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )
    return fn(p["moe"], x2)


def _block_train(kind: str, p, x: jax.Array, cfg: ArchConfig,
                 enc: Optional[jax.Array] = None,
                 window: Optional[int] = None) -> Tuple[jax.Array, jax.Array]:
    """Returns (x_out, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "mamba":
        h = mamba2_train(
            p["mixer"], _norm(p["norm1"], x, cfg), d_state=cfg.ssm_state,
            head_dim=cfg.ssm_head_dim, expand=cfg.ssm_expand,
            chunk=cfg.ssm_chunk,
        )
        return x + h, aux
    if kind in ("mla", "mla_moe"):
        h = mla_train(
            p["attn"], _norm(p["norm1"], x, cfg), n_heads=cfg.n_heads,
            kv_lora=cfg.kv_lora, qk_nope=cfg.qk_nope, qk_rope=cfg.qk_rope,
            v_head=cfg.v_head, rope_theta=cfg.rope_theta,
        )
    elif kind == "enc_attn":
        # bidirectional: full window, no causal mask -> use cross-attn math
        h = cross_attention(
            p["attn"], _norm(p["norm1"], x, cfg), _norm(p["norm1"], x, cfg),
            n_heads=cfg.n_heads, head_dim=cfg.head_dim,
        )
    else:
        h = gqa_train(
            p["attn"], _norm(p["norm1"], x, cfg), n_heads=cfg.n_heads,
            n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta, window=window if window else cfg.window,
        )
    x = x + h
    x = shard(x, "batch", "seq", None)
    if kind == "dec_attn":
        x = x + cross_attention(
            p["xattn"], _norm(p["norm_x"], x, cfg), enc,
            n_heads=cfg.n_heads, head_dim=cfg.head_dim,
        )
    if kind.endswith("_moe"):
        x2 = _norm(p["norm2"], x, cfg)
        out, aux = _moe_apply(p, x2, cfg)
        if "shared_mlp" in p:
            out = out + mlp(p["shared_mlp"], x2, gated=cfg.mlp_gated)
        x = x + out
    elif "mlp" in p:
        x = x + mlp(p["mlp"], _norm(p["norm2"], x, cfg), gated=cfg.mlp_gated)
    return shard(x, "batch", "seq", None), aux


def _block_decode(kind: str, p, x, cache, cfg: ArchConfig,
                  enc_kv=None, window: Optional[int] = None
                  ) -> Tuple[jax.Array, Any]:
    """Decode one token through one block, append-then-write style: the
    attention cache is READ-ONLY; this returns (x, update) where update is
    the small per-layer payload the caller scatters into the stacked cache
    once per step ((k,v) slot, (c_kv, rope) slot, or the full SSM state)."""
    if kind == "mamba":
        h, new_cache = mamba2_decode(
            p["mixer"], _norm(p["norm1"], x, cfg), cache,
            d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
            expand=cfg.ssm_expand,
        )
        return x + h, new_cache
    if kind in ("mla", "mla_moe"):
        h, c_new, r_new = mla_attend_step(
            p["attn"], _norm(p["norm1"], x, cfg), cache.c_kv, cache.k_rope,
            cache.length, n_heads=cfg.n_heads,
            kv_lora=cfg.kv_lora, qk_nope=cfg.qk_nope, qk_rope=cfg.qk_rope,
            v_head=cfg.v_head, rope_theta=cfg.rope_theta,
        )
        update = (c_new, r_new)
    else:
        h, k_new, v_new = gqa_attend_step(
            p["attn"], _norm(p["norm1"], x, cfg), cache.k, cache.v,
            cache.length, n_heads=cfg.n_heads,
            n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta,
            window=window,
        )
        update = (k_new, v_new)
    x = x + h
    if kind == "dec_attn":
        # cross-attention over cached encoder K/V
        k, v = enc_kv
        B = x.shape[0]
        xq = _norm(p["norm_x"], x, cfg)
        q = linear(p["xattn"]["wq"], xq).reshape(
            B, 1, cfg.n_heads, cfg.head_dim
        ) * (cfg.head_dim ** -0.5)
        s = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32)
        pr = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqs,bshd->bqhd", pr, v.astype(jnp.float32))
        o = o.reshape(B, 1, cfg.n_heads * cfg.head_dim).astype(x.dtype)
        x = x + linear(p["xattn"]["wo"], o)
    if kind.endswith("_moe"):
        x2 = _norm(p["norm2"], x, cfg)
        out, _ = _moe_apply_decode(p, x2, cfg)
        if "shared_mlp" in p:
            out = out + mlp(p["shared_mlp"], x2, gated=cfg.mlp_gated)
        x = x + out
    elif "mlp" in p:
        x = x + mlp(p["mlp"], _norm(p["norm2"], x, cfg), gated=cfg.mlp_gated)
    return x, update


def _moe_apply_decode(p, x2, cfg):
    """Decode-time MoE: tiny token count (B tokens) — dense combine over
    experts is affordable and avoids all_to_all latency on the decode path
    (batch x E x d_ff flops with B <= 128)."""
    B, S, d = x2.shape
    out, aux = moe_dense(
        p["moe"], x2.reshape(-1, d), top_k=cfg.top_k, router=cfg.router,
        policy=_ot_policy(cfg),
    )
    return out.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(key, cfg: ArchConfig) -> Dict:
    ks = iter(jax.random.split(key, 64))
    params: Dict[str, Any] = {}
    if cfg.input_kind in ("tokens", "encdec"):
        params["embed"] = init_embedding(next(ks), cfg.padded_vocab,
                                         cfg.d_model, dtype=cfg.dtype)
    if cfg.pos == "learned":
        params["pos"] = trunc_normal(next(ks), (65536, cfg.d_model),
                                     std=0.01, dtype=cfg.dtype)

    groups = group_plan(cfg.layer_plan())
    stacks = []
    shared_attn_done = False
    for kind, count in groups:
        if kind == "shared_attn":
            if not shared_attn_done:
                params["shared_attn"] = _init_block(next(ks), "attn", cfg)
                shared_attn_done = True
            stacks.append(None)
            continue
        keys = jax.random.split(next(ks), count)
        stacks.append(jax.vmap(lambda k: _init_block(k, kind, cfg))(keys))
    params["groups"] = stacks

    if cfg.family == "encdec":
        enc_keys = jax.random.split(next(ks), cfg.n_enc_layers)
        params["encoder"] = jax.vmap(
            lambda k: _init_block(k, "enc_attn", cfg)
        )(enc_keys)
        params["enc_norm"] = _init_norm(cfg)

    params["final_norm"] = _init_norm(cfg)
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(next(ks), cfg.d_model,
                                        cfg.padded_vocab, dtype=cfg.dtype)
    if cfg.mtp:
        params["mtp_block"] = _init_block(next(ks), "mla" if
                                          cfg.attention == "mla" else "attn",
                                          cfg)
        params["mtp_norm"] = _init_norm(cfg)
    if cfg.ot_loss_weight > 0:
        params["ot"] = init_ot_loss(
            next(ks), cfg.d_model, ot_dim=cfg.ot_dim, n_protos=cfg.ot_protos,
            n_features=cfg.ot_features, eps=cfg.ot_eps,
        )
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg: ArchConfig, batch: Dict) -> jax.Array:
    if cfg.input_kind == "embeds":
        x = batch["embeds"].astype(cfg.cdtype)
    else:
        tok = batch["tokens"]
        x = params["embed"]["table"].astype(cfg.cdtype)[tok]
    if cfg.pos == "learned":
        S = x.shape[1]
        x = x + params["pos"][:S][None].astype(cfg.cdtype)
    return shard(x, "batch", "seq", None)


def _run_decoder_groups(params, cfg: ArchConfig, x: jax.Array,
                        enc: Optional[jax.Array] = None):
    """Scan each stacked group; python-apply shared blocks."""
    aux_total = jnp.zeros((), jnp.float32)
    plan_groups = group_plan(cfg.layer_plan())
    for (kind, count), stack in zip(plan_groups, params["groups"]):
        if kind == "shared_attn":
            x, aux = _block_train("attn", params["shared_attn"], x, cfg)
            aux_total += aux
            continue

        def body(carry, p_l, _kind=kind):
            y, aux = _block_train(_kind, p_l, carry, cfg, enc=enc)
            return y, aux

        body_fn = body
        if cfg.remat:
            body_fn = jax.checkpoint(
                body_fn, policy=jax.checkpoint_policies.nothing_saveable
            )
        x, auxs = jax.lax.scan(body_fn, x, stack)
        aux_total += jnp.sum(auxs)
    return x, aux_total


def forward(params, cfg: ArchConfig, batch: Dict) -> Tuple[jax.Array, jax.Array]:
    """Returns (hidden (B,S,d) after final norm, aux losses)."""
    enc = None
    if cfg.family == "encdec":
        enc = _encode(params, cfg, batch)
    x = _embed_inputs(params, cfg, batch)
    x, aux = _run_decoder_groups(params, cfg, x, enc=enc)
    return _norm(params["final_norm"], x, cfg), aux


def _encode(params, cfg: ArchConfig, batch: Dict) -> jax.Array:
    x = batch["enc_embeds"].astype(cfg.cdtype)
    if cfg.pos == "learned":
        x = x + params["pos"][: x.shape[1]][None].astype(cfg.cdtype)
    x = shard(x, "batch", "seq", None)

    def body(carry, p_l):
        y, _ = _block_train("enc_attn", p_l, carry, cfg)
        return y, None

    body_fn = body
    if cfg.remat:
        body_fn = jax.checkpoint(
            body_fn, policy=jax.checkpoint_policies.nothing_saveable
        )
    x, _ = jax.lax.scan(body_fn, x, params["encoder"])
    return _norm(params["enc_norm"], x, cfg)


def _logits(params, cfg: ArchConfig, h: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        w = params["embed"]["table"].astype(h.dtype)
        logits = h @ w.T
    else:
        logits = linear(params["lm_head"], h)
    # 'model' can shard either the seq or the vocab dim of the logits, not
    # both: keep the upstream seq sharding when S > 1 (train/prefill),
    # vocab-parallel when decoding a single position.
    ctx = current_mesh_context()
    if ctx is not None and ctx.mode == "decode":
        return shard(logits, "batch", None, "vocab")
    return shard(logits, "batch", "seq", None)


def _xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def _head_weight(params, cfg: ArchConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"]["table"].T
    return params["lm_head"]["w"]


_XENT_CHUNKS = 16


def _xent_chunked(h: jax.Array, w: jax.Array, labels: jax.Array,
                  n_chunks: int = _XENT_CHUNKS) -> jax.Array:
    """Streaming cross-entropy over vocab chunks (never materializes the
    (B, S, V) logits — §Perf train-memory hillclimb). The chunk body is
    rematerialized in the backward pass, so peak memory is O(V / n_chunks)."""
    B, S, d = h.shape
    V = w.shape[1]
    chunk = -(-V // n_chunks)
    pad = n_chunks * chunk - V
    if pad:
        w = jnp.pad(w, ((0, 0), (0, pad)))

    def body(carry, i):
        m, s, gold = carry
        wc = jax.lax.dynamic_slice_in_dim(w, i * chunk, chunk, 1)
        logits = (h @ wc.astype(h.dtype)).astype(jnp.float32)   # (B,S,chunk)
        # padded vocab tail must not contribute
        col = i * chunk + jnp.arange(chunk)
        logits = jnp.where((col < V)[None, None, :], logits, -1e30)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        s = s * jnp.exp(m - m_new) + jnp.sum(
            jnp.exp(logits - m_new[..., None]), axis=-1)
        local = jnp.clip(labels - i * chunk, 0, chunk - 1)
        gold_c = jnp.take_along_axis(logits, local[..., None], -1)[..., 0]
        in_chunk = (labels >= i * chunk) & (labels < (i + 1) * chunk)
        gold = jnp.where(in_chunk, gold_c, gold)
        return (m_new, s, gold), None

    body = jax.checkpoint(body)
    m0 = jnp.full((B, S), -1e30, jnp.float32)
    s0 = jnp.zeros((B, S), jnp.float32)
    g0 = jnp.full((B, S), -1e30, jnp.float32)
    (m, s, gold), _ = jax.lax.scan(body, (m0, s0, g0),
                                   jnp.arange(n_chunks))
    lse = m + jnp.log(jnp.maximum(s, 1e-30))
    return jnp.mean(lse - gold)


def _lm_ce(params, cfg: ArchConfig, h: jax.Array, labels: jax.Array
           ) -> jax.Array:
    if cfg.padded_vocab >= 32768:
        return _xent_chunked(h, _head_weight(params, cfg), labels)
    return _xent(_logits(params, cfg, h), labels)


def train_loss(params, cfg: ArchConfig, batch: Dict,
               policy: Optional[ExecutionPolicy] = None
               ) -> Tuple[jax.Array, Dict]:
    """Full training objective. ``policy`` is the run-wide OT execution
    policy (constructed once by the launch layer); ``None`` derives the
    identical record from cfg."""
    if policy is None:
        policy = _ot_policy(cfg)
    h, aux = forward(params, cfg, batch)
    loss_ce = _lm_ce(params, cfg, h, batch["labels"])
    metrics = {"ce": loss_ce, "aux": aux}
    loss = loss_ce + 0.01 * aux
    if cfg.mtp:
        # multi-token prediction: one extra block on h predicts t+2
        hm, _ = _block_train(
            "mla" if cfg.attention == "mla" else "attn",
            params["mtp_block"], h, cfg,
        )
        hm = _norm(params["mtp_norm"], hm, cfg)
        loss_mtp = _lm_ce(params, cfg, hm[:, :-1], batch["labels"][:, 1:])
        metrics["mtp"] = loss_mtp
        loss = loss + 0.3 * loss_mtp
    if cfg.ot_loss_weight > 0:
        loss_ot = ot_prototype_loss(
            params["ot"], h, eps=cfg.ot_eps, n_tokens=cfg.ot_tokens,
            n_iter=cfg.ot_iters, policy=policy,
        )
        metrics["ot"] = loss_ot
        loss = loss + cfg.ot_loss_weight * loss_ot
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# serving: caches / prefill / decode
# ---------------------------------------------------------------------------


def init_caches(cfg: ArchConfig, batch: int, s_max: int) -> List[Any]:
    """Per-group stacked caches (leading axis = layers in group)."""
    win = effective_window(cfg, s_max)
    caches: List[Any] = []
    plan_groups = group_plan(cfg.layer_plan())

    def stack(c, n):
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), c)

    for kind, count in plan_groups:
        if kind in ("attn", "attn_moe", "dec_attn", "shared_attn"):
            c = init_gqa_cache(batch, s_max, cfg.n_kv_heads, cfg.head_dim,
                               window=win, dtype=cfg.cdtype)
        elif kind in ("mla", "mla_moe"):
            c = init_mla_cache(batch, s_max, kv_lora=cfg.kv_lora,
                               qk_rope=cfg.qk_rope, dtype=cfg.cdtype)
        elif kind == "mamba":
            c = init_mamba2_cache(
                batch, cfg.d_model, d_state=cfg.ssm_state,
                head_dim=cfg.ssm_head_dim, expand=cfg.ssm_expand,
                conv_kernel=cfg.conv_kernel, dtype=cfg.cdtype,
            )
        else:
            raise ValueError(kind)
        caches.append(c if kind == "shared_attn" else stack(c, count))
    return caches


def cache_logical_axes(cfg: ArchConfig) -> List[Any]:
    """Logical axis names per cache leaf (mirrors init_caches structure).

    GQA/MLA caches shard the KV sequence over 'model' (flash-decoding
    contract); Mamba states shard SSD heads over 'model'.
    """
    plan_groups = group_plan(cfg.layer_plan())
    specs: List[Any] = []
    for kind, count in plan_groups:
        lead = () if kind == "shared_attn" else (None,)
        if kind in ("attn", "attn_moe", "dec_attn", "shared_attn"):
            c = GQACache(
                k=lead + ("batch", "kvseq", None, None),
                v=lead + ("batch", "kvseq", None, None),
                length="skip",
            )
        elif kind in ("mla", "mla_moe"):
            c = MLACache(
                c_kv=lead + ("batch", "kvseq", None),
                k_rope=lead + ("batch", "kvseq", None),
                length="skip",
            )
        elif kind == "mamba":
            c = Mamba2Cache(
                conv=lead + ("batch", None, None),
                state=lead + ("batch", "heads", None, None),
                length="skip",
            )
        else:
            raise ValueError(kind)
        specs.append(c)
    return specs


def shard_caches(cfg: ArchConfig, caches):
    """Apply the decode sharding contract to a cache pytree."""
    specs = cache_logical_axes(cfg)
    leaves, treedef = jax.tree.flatten(caches)
    spec_leaves = jax.tree.flatten(
        specs,
        is_leaf=lambda x: isinstance(x, str)
        or (isinstance(x, tuple) and not isinstance(
            x, (GQACache, MLACache, Mamba2Cache))),
    )[0]
    assert len(leaves) == len(spec_leaves), (len(leaves), len(spec_leaves))
    out = [
        leaf if ax == "skip" else shard(leaf, *ax)
        for leaf, ax in zip(leaves, spec_leaves)
    ]
    return jax.tree.unflatten(treedef, out)


def decode_step(params, cfg: ArchConfig, token_batch: Dict,
                caches: List[Any], *, window: Optional[int] = None
                ) -> Tuple[jax.Array, List[Any]]:
    """One-token decode. token_batch: tokens (B,1) (+ enc_kv for encdec).

    ``window`` must be effective_window(cfg, s_max) of the serving session
    (rolling-buffer caches for SWA / hybrid long-context).
    """
    if cfg.input_kind == "embeds":
        x = token_batch["embeds"].astype(cfg.cdtype)
    else:
        x = params["embed"]["table"].astype(cfg.cdtype)[token_batch["tokens"]]
    if cfg.pos == "learned":
        # position = cache length of the first group
        pos = jax.tree.leaves(caches[0])[-1]
        pos = pos.reshape(-1)[0].astype(jnp.int32)
        x = x + jax.lax.dynamic_index_in_dim(
            params["pos"], pos, 0, keepdims=True
        )[None, 0].astype(cfg.cdtype)
    x = shard(x, "batch", None, None)

    enc_kv = token_batch.get("enc_kv")
    plan_groups = group_plan(cfg.layer_plan())
    new_caches = []

    def write_gqa(cache: GQACache, k_new, v_new, *, stacked: bool):
        """One scatter for the whole group — the only cache write."""
        seq_ax = 2 if stacked else 1
        s_cache = cache.k.shape[seq_ax]
        pos = cache.length.reshape(-1)[0]
        slot = jnp.mod(pos, s_cache) if window else jnp.minimum(
            pos, s_cache - 1)
        k_new = jnp.expand_dims(k_new, seq_ax)
        v_new = jnp.expand_dims(v_new, seq_ax)
        return GQACache(
            jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, slot, seq_ax),
            jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, slot, seq_ax),
            cache.length + 1,
        )

    def write_mla(cache: MLACache, c_new, r_new):
        pos = cache.length.reshape(-1)[0]
        return MLACache(
            jax.lax.dynamic_update_slice_in_dim(
                cache.c_kv, jnp.expand_dims(c_new, 2), pos, 2),
            jax.lax.dynamic_update_slice_in_dim(
                cache.k_rope, jnp.expand_dims(r_new, 2), pos, 2),
            cache.length + 1,
        )

    for (kind, count), cache, stack in zip(plan_groups, caches,
                                           params["groups"]):
        if kind == "shared_attn":
            x, (k_new, v_new) = _block_decode(
                "attn", params["shared_attn"], x, cache, cfg, window=window)
            new_caches.append(write_gqa(cache, k_new, v_new, stacked=False))
            continue

        if kind == "dec_attn":
            xs = (stack, cache, enc_kv["k"], enc_kv["v"])

            def body(carry, pc, _kind=kind):
                p_l, c_l, ek, ev = pc
                y, upd = _block_decode(_kind, p_l, carry, c_l, cfg,
                                       enc_kv=(ek, ev), window=window)
                return y, upd
        else:
            xs = (stack, cache)

            def body(carry, pc, _kind=kind):
                p_l, c_l = pc
                y, upd = _block_decode(_kind, p_l, carry, c_l, cfg,
                                       window=window)
                return y, upd

        x, upd = jax.lax.scan(body, x, xs)
        if kind == "mamba":
            new_caches.append(upd)          # full (small) SSM state stack
        elif kind in ("mla", "mla_moe"):
            new_caches.append(write_mla(cache, *upd))
        else:
            new_caches.append(write_gqa(cache, *upd, stacked=True))
    h = _norm(params["final_norm"], x, cfg)
    return _logits(params, cfg, h), new_caches


def prefill(params, cfg: ArchConfig, batch: Dict):
    """Prefill step for serving: full forward, returns last-position logits.

    Cache construction during prefill shares the forward compute (the
    dry-run prefii shape measures exactly this program). For simplicity and
    because the 32k cells only need the compiled artifact, the returned
    caches are rebuilt from a second pass of the cheap projections inside
    each block would duplicate code — instead we run the standard forward
    and return logits for the final position (the production system would
    fuse cache emission into the same scan; see launch/serve.py).
    """
    h, _ = forward(params, cfg, batch)
    return _logits(params, cfg, h[:, -1:, :])
