"""The paper's technique as a first-class LM training loss (DESIGN.md §4).

Final hidden states are treated as an empirical measure over tokens; a
learnable PROTOTYPE cloud is the second measure. Both are embedded by a
linear map f_gamma into a bounded ball (the h_gamma of the paper's GAN
objective, Eq. 18) and compared with the Sinkhorn divergence under a
LEARNED positive-feature kernel (Lemma-1 features with learnable anchors).

Everything differentiable pieces together exactly as in the paper:
  * factored kernel  -> O(r (n+m)) solver iterations,
  * envelope-theorem custom VJP -> no backprop through the Sinkhorn loop,
  * learnable theta = (anchors, prototypes, f_gamma).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.features import gaussian_log_features, gaussian_q
from ..core.objective import ExecutionPolicy, OTObjective
from ..distributed.sharding import shard
from .layers import trunc_normal

__all__ = ["init_ot_loss", "ot_prototype_loss", "subsample_tokens",
           "OT_RADIUS"]

OT_RADIUS = 2.0     # f_gamma output is tanh-bounded into B(0, OT_RADIUS)


def init_ot_loss(key, d_model: int, *, ot_dim: int, n_protos: int,
                 n_features: int, eps: float, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 3)
    q = gaussian_q(OT_RADIUS, eps, ot_dim)
    sigma = (q * eps / 4.0) ** 0.5
    return {
        "proj": trunc_normal(ks[0], (d_model, ot_dim), std=d_model ** -0.5,
                             dtype=jnp.float32),
        "protos": OT_RADIUS * 0.5 * jax.random.normal(
            ks[1], (n_protos, ot_dim), jnp.float32),
        "anchors": sigma * jax.random.normal(
            ks[2], (n_features, ot_dim), jnp.float32),
    }


def subsample_tokens(hidden: jax.Array, n_tokens: int) -> jax.Array:
    """Exactly ``min(n_tokens, B*S)`` tokens from a (B, S, d) batch.

    Evenly-spaced static gather over the flattened (batch, seq) grid — the
    token budget is honored EXACTLY. (The old stride arithmetic
    ``S // (n_tokens // B)`` overshot for small ``S`` and collapsed to the
    full sequence whenever ``n_tokens < B``.)
    """
    B, S, d = hidden.shape
    total = B * S
    n = min(int(n_tokens), total)
    idx = jnp.asarray(
        np.round(np.linspace(0, total - 1, n)).astype(np.int32))
    return hidden.reshape(total, d)[idx]


def ot_prototype_loss(
    p_ot: Dict,
    hidden: jax.Array,          # (B, S, d) final hidden states
    *,
    eps: float,
    n_tokens: int,
    n_iter: int,
    policy: Optional[ExecutionPolicy] = None,
) -> jax.Array:
    """Sinkhorn divergence between token states and learned prototypes.

    The solve runs through :class:`OTObjective` under ``policy`` — by
    default the training policy (bf16 factor storage, fused megakernel
    wherever the backend compiles Pallas). Pass the run-wide policy (e.g.
    ``ExecutionPolicy.from_config(cfg)``) to share cadence/backend/mesh
    settings with every other OT surface.
    """
    obj = OTObjective(
        eps=eps, tol=0.0, max_iter=n_iter,
        policy=policy if policy is not None else ExecutionPolicy.training(),
    )
    sample = subsample_tokens(hidden, n_tokens).astype(jnp.float32)
    sample = shard(sample, "batch", None)
    z = OT_RADIUS * jnp.tanh(sample @ p_ot["proj"])          # f_gamma
    protos = OT_RADIUS * jnp.tanh(p_ot["protos"])
    q = gaussian_q(OT_RADIUS, eps, z.shape[-1])
    lxi = gaussian_log_features(z, p_ot["anchors"], eps=eps, q=q)
    lzeta = gaussian_log_features(protos, p_ot["anchors"], eps=eps, q=q)
    # kappa floor (the paper's Lemma-3 perturbation): one constant feature
    # column guarantees k_theta >= kappa > 0 even when LEARNED anchors
    # drift away from the data — keeps the log-domain solver and its
    # envelope VJP NaN-free for any theta. kappa is set well below the
    # kernel scale at ot_eps (diam^2/eps ~ 32 -> log k >= -32) so it only
    # caps pathological pairs (a robust-OT cost ceiling of eps*41).
    kappa_col = jnp.full((1, 1), 0.5 * jnp.log(1e-18), jnp.float32)
    lxi = jnp.concatenate(
        [lxi, jnp.broadcast_to(kappa_col, (lxi.shape[0], 1))], axis=1)
    lzeta = jnp.concatenate(
        [lzeta, jnp.broadcast_to(kappa_col, (lzeta.shape[0], 1))], axis=1)
    geom = obj.factored(lxi, lzeta)
    a, b = obj.uniform_weights(geom)
    return obj.divergence(geom, a, b)
