"""Attention variants: GQA (+QKV bias, sliding window), MLA, cross-attention.

Memory-efficient (flash-style) chunked attention in pure JAX: the KV axis is
processed in chunks under a ``lax.scan`` with running (max, sum, acc) — no
(S_q x S_kv) score matrix ever materializes, which is what lets the 32k
prefill shapes compile inside v5e HBM. On real TPUs you would drop a Pallas
flash kernel in here; for this repo the Pallas budget is spent on the
paper's own hot-spots (see repro/kernels) and attention stays XLA-fusible.

Sharding contract (enforced by the caller via with_sharding_constraint):
  train/prefill:  q seq-sharded over 'model' (context parallelism),
                  k/v gathered (replicated over 'model').
  decode:         cache seq-sharded over 'model'; XLA auto-inserts the
                  flash-decoding style softmax collectives.

MLA (DeepSeek-V2/V3): trains on decompressed K/V (per-chunk decompression
inside the scan), decodes with *weight absorption* — scores and values are
contracted directly in the 512-dim compressed space, so the KV cache stays
(kv_lora + rope_dim) per token.

Sliding-window (SWA) decode uses a rolling cache of size ``window`` —
long_500k on h2o-danube holds 4096 cache rows, not 524288.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .layers import apply_rotary, init_linear, linear, rmsnorm, rotary_cos_sin

__all__ = [
    "init_gqa",
    "gqa_train",
    "gqa_decode",
    "init_gqa_cache",
    "init_mla",
    "mla_train",
    "mla_decode",
    "init_mla_cache",
    "init_cross_attention",
    "cross_attention",
    "chunked_attention",
]

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Flash-style chunked attention core
# ---------------------------------------------------------------------------


def chunked_attention(
    q: jax.Array,                     # (B, Q, H, D) — already scaled/roped
    kv: Any,                          # pytree; arrays have KV-seq on axis 1
    s_kv: int,
    *,
    score_fn: Callable[[jax.Array, Any], jax.Array],   # -> (B, H, Q, Ck)
    value_fn: Callable[[jax.Array, Any], jax.Array],   # probs -> (B, Q, H, D)
    mask_fn: Callable[[jax.Array], jax.Array],         # kv positions (Ck,) -> (B,1,Q,Ck) or (1,1,Q,Ck)
    kv_chunk: int = 1024,
) -> jax.Array:
    """Numerically-stable streaming softmax over KV chunks."""
    B, Q, H, D = q.shape
    kv_chunk = min(kv_chunk, s_kv)
    n_chunks = -(-s_kv // kv_chunk)
    pad = n_chunks * kv_chunk - s_kv
    if pad:
        kv = jax.tree.map(
            lambda x: jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2)),
            kv,
        )

    def slice_chunk(c):
        return jax.tree.map(
            lambda x: jax.lax.dynamic_slice_in_dim(x, c * kv_chunk, kv_chunk, 1),
            kv,
        )

    def body(carry, c):
        m, l, acc = carry
        kv_c = slice_chunk(c)
        pos_k = c * kv_chunk + jnp.arange(kv_chunk)
        s = score_fn(q, kv_c).astype(jnp.float32)          # (B, H, Q, Ck)
        valid = (pos_k < s_kv)[None, None, None, :]
        s = jnp.where(mask_fn(pos_k) & valid, s, _NEG_INF)
        m_c = jnp.max(s, axis=-1)                          # (B, H, Q)
        m_new = jnp.maximum(m, m_c)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        o_c = value_fn(p, kv_c)                            # (B, Q, H, D) f32
        acc_new = acc * jnp.transpose(corr, (0, 2, 1))[..., None] + o_c
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Q), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Q), jnp.float32)
    a0 = jnp.zeros((B, Q, H, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(n_chunks))
    l = jnp.maximum(jnp.transpose(l, (0, 2, 1))[..., None], 1e-30)
    return acc / l


# ---------------------------------------------------------------------------
# GQA (covers MHA when n_kv == n_heads; SWA via window)
# ---------------------------------------------------------------------------


def init_gqa(
    key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
    *, qkv_bias: bool = False, dtype=jnp.float32,
):
    ks = jax.random.split(key, 4)
    out_std = 0.02 / (2.0 ** 0.5)
    return {
        "wq": init_linear(ks[0], d_model, n_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "wk": init_linear(ks[1], d_model, n_kv * head_dim, bias=qkv_bias, dtype=dtype),
        "wv": init_linear(ks[2], d_model, n_kv * head_dim, bias=qkv_bias, dtype=dtype),
        "wo": init_linear(ks[3], n_heads * head_dim, d_model, std=out_std, dtype=dtype),
    }


def _gqa_score_fn(n_kv: int):
    def fn(q, kv_c):
        # q (B,Q,H,D) grouped as (B,Q,KH,G,D); k (B,Ck,KH,D)
        B, Q, H, D = q.shape
        G = H // n_kv
        qg = q.reshape(B, Q, n_kv, G, D)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kv_c["k"])
        return s.reshape(B, H, Q, -1)
    return fn


def _gqa_value_fn(n_kv: int):
    def fn(p, kv_c):
        B, H, Q, Ck = p.shape
        G = H // n_kv
        pg = p.reshape(B, n_kv, G, Q, Ck)
        o = jnp.einsum("bkgqs,bskd->bqkgd", pg, kv_c["v"].astype(jnp.float32))
        return o.reshape(B, Q, H, -1)
    return fn


def _causal_window_mask(pos_q: jax.Array, window: Optional[int]):
    """pos_q (Q,) global query positions -> mask_fn(pos_k (Ck,))."""

    def mask_fn(pos_k):
        m = pos_k[None, :] <= pos_q[:, None]
        if window is not None:
            m &= (pos_q[:, None] - pos_k[None, :]) < window
        return m[None, None, :, :]

    return mask_fn


def gqa_train(
    p, x: jax.Array, *, n_heads: int, n_kv: int, head_dim: int,
    rope_theta: float = 10000.0, window: Optional[int] = None,
    kv_chunk: int = 1024, positions: Optional[jax.Array] = None,
) -> jax.Array:
    """Full-sequence causal attention, (B, S, d) -> (B, S, d)."""
    B, S, _ = x.shape
    pos = jnp.arange(S) if positions is None else positions
    q = linear(p["wq"], x).reshape(B, S, n_heads, head_dim)
    k = linear(p["wk"], x).reshape(B, S, n_kv, head_dim)
    v = linear(p["wv"], x).reshape(B, S, n_kv, head_dim)
    cos, sin = rotary_cos_sin(pos, head_dim, rope_theta)
    q = apply_rotary(q, cos[None], sin[None]) * (head_dim ** -0.5)
    k = apply_rotary(k, cos[None], sin[None])
    out = chunked_attention(
        q, {"k": k, "v": v}, S,
        score_fn=_gqa_score_fn(n_kv),
        value_fn=_gqa_value_fn(n_kv),
        mask_fn=_causal_window_mask(pos, window),
        kv_chunk=kv_chunk,
    )
    return linear(p["wo"], out.reshape(B, S, n_heads * head_dim).astype(x.dtype))


class GQACache(NamedTuple):
    k: jax.Array          # (B, S_cache, KH, D)
    v: jax.Array
    length: jax.Array     # scalar int32 — tokens decoded so far (logical pos)


def gqa_attend_step(
    p, x: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
    length: jax.Array, *, n_heads: int, n_kv: int, head_dim: int,
    rope_theta: float = 10000.0, window: Optional[int] = None,
):
    """Append-then-write decode attention: the cache is READ-ONLY here.

    Returns (out, k_new (B,KH,D), v_new (B,KH,D)); the caller scatters the
    new slot into the stacked cache ONCE per step, outside the layer scan —
    this keeps the per-step HBM traffic at "read the cache once" instead of
    "copy the cache per layer" (EXPERIMENTS.md §Perf, decode hillclimb).
    """
    B = x.shape[0]
    s_cache = k_cache.shape[1]
    pos = length
    q = linear(p["wq"], x).reshape(B, 1, n_heads, head_dim)
    k = linear(p["wk"], x).reshape(B, 1, n_kv, head_dim)
    v = linear(p["wv"], x).reshape(B, 1, n_kv, head_dim)
    cos, sin = rotary_cos_sin(pos[None], head_dim, rope_theta)
    q = apply_rotary(q, cos[None], sin[None]) * (head_dim ** -0.5)
    k = apply_rotary(k, cos[None], sin[None])
    slots = jnp.arange(s_cache)
    if window:
        n_wraps = (pos - slots) // s_cache
        logical = slots + n_wraps * s_cache
        # STRICT < pos: the current slot's stale value is excluded; the
        # fresh token is attended via the explicit self term below.
        valid = (logical >= 0) & (logical < pos) & (pos - logical < window)
    else:
        valid = slots < pos
    G = n_heads // n_kv
    qg = q.reshape(B, 1, n_kv, G, head_dim)
    # mixed-precision einsums: read the bf16 cache directly, accumulate in
    # f32 — no materialized f32 cache copy (§Perf decode hillclimb, iter 2)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache,
                   preferred_element_type=jnp.float32
                   ).reshape(B, n_heads, 1, s_cache)
    s = jnp.where(valid[None, None, None, :], s, _NEG_INF)
    s_self = jnp.einsum("bqkgd,bqkd->bkgq", qg, k[:, 0][:, None],
                        preferred_element_type=jnp.float32
                        ).reshape(B, n_heads, 1, 1)
    s_all = jnp.concatenate([s, s_self], axis=-1)
    pr = jax.nn.softmax(s_all, axis=-1)
    pr_c, pr_s = pr[..., :-1], pr[..., -1:]
    pg = pr_c.reshape(B, n_kv, G, 1, s_cache).astype(v_cache.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", pg, v_cache,
                   preferred_element_type=jnp.float32)
    # self term: (B,KH,G,1) probs x (B,KH,D) values -> (B,1,KH,G,D)
    w_self = pr_s.reshape(B, n_kv, G)
    o_self = jnp.einsum("bkg,bkd->bkgd", w_self,
                        v[:, 0].astype(jnp.float32))[:, None]
    o = o + o_self
    o = o.reshape(B, 1, n_heads * head_dim).astype(x.dtype)
    out = linear(p["wo"], o)
    return out, k[:, 0].astype(k_cache.dtype), v[:, 0].astype(v_cache.dtype)


def init_gqa_cache(batch, s_max, n_kv, head_dim, *, window=None, dtype=jnp.float32):
    s_cache = min(s_max, window) if window else s_max
    z = jnp.zeros((batch, s_cache, n_kv, head_dim), dtype)
    return GQACache(z, z, jnp.zeros((), jnp.int32))


def gqa_decode(
    p, x: jax.Array, cache: GQACache, *, n_heads: int, n_kv: int,
    head_dim: int, rope_theta: float = 10000.0, window: Optional[int] = None,
):
    """Single-token decode. x (B, 1, d). Rolling buffer when window is set."""
    B = x.shape[0]
    s_cache = cache.k.shape[1]
    pos = cache.length                                    # logical position
    slot = jnp.mod(pos, s_cache) if window else pos       # physical slot
    q = linear(p["wq"], x).reshape(B, 1, n_heads, head_dim)
    k = linear(p["wk"], x).reshape(B, 1, n_kv, head_dim)
    v = linear(p["wv"], x).reshape(B, 1, n_kv, head_dim)
    cos, sin = rotary_cos_sin(pos[None], head_dim, rope_theta)
    q = apply_rotary(q, cos[None], sin[None]) * (head_dim ** -0.5)
    k = apply_rotary(k, cos[None], sin[None])
    k_all = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), slot, 1)
    v_all = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), slot, 1)
    # physical slot s holds logical position: (window rolling) or s directly
    slots = jnp.arange(s_cache)
    if window:
        # logical position of slot s: largest l <= pos with l = s (mod s_cache)
        n_wraps = (pos - slots) // s_cache          # floor div (negative-safe)
        logical = slots + n_wraps * s_cache
        valid = (logical >= 0) & (logical <= pos) & (pos - logical < window)
    else:
        logical = slots
        valid = slots <= pos
    G = n_heads // n_kv
    qg = q.reshape(B, 1, n_kv, G, head_dim)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_all).reshape(B, n_heads, 1, s_cache)
    s = jnp.where(valid[None, None, None, :], s.astype(jnp.float32), _NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    pg = pr.reshape(B, n_kv, G, 1, s_cache)
    o = jnp.einsum("bkgqs,bskd->bqkgd", pg, v_all.astype(jnp.float32))
    o = o.reshape(B, 1, n_heads * head_dim).astype(x.dtype)
    out = linear(p["wo"], o)
    return out, GQACache(k_all, v_all, pos + 1)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2/V3 multi-head latent attention)
# ---------------------------------------------------------------------------


def init_mla(
    key, d_model: int, n_heads: int, *, kv_lora: int = 512,
    q_lora: int = 1536, qk_nope: int = 128, qk_rope: int = 64,
    v_head: int = 128, dtype=jnp.float32,
):
    ks = jax.random.split(key, 8)
    out_std = 0.02 / (2.0 ** 0.5)
    return {
        "wq_down": init_linear(ks[0], d_model, q_lora, dtype=dtype),
        "q_norm": jnp.ones((q_lora,), dtype),
        "wq_up": init_linear(ks[1], q_lora, n_heads * (qk_nope + qk_rope), dtype=dtype),
        "wkv_down": init_linear(ks[2], d_model, kv_lora + qk_rope, dtype=dtype),
        "kv_norm": jnp.ones((kv_lora,), dtype),
        "wk_up": init_linear(ks[3], kv_lora, n_heads * qk_nope, dtype=dtype),
        "wv_up": init_linear(ks[4], kv_lora, n_heads * v_head, dtype=dtype),
        "wo": init_linear(ks[5], n_heads * v_head, d_model, std=out_std, dtype=dtype),
    }


def _mla_qkr(p, x, *, n_heads, qk_nope, qk_rope, pos, rope_theta):
    """Shared q computation. Returns q_nope (B,S,H,nope), q_rope (B,S,H,rope)."""
    B, S, _ = x.shape
    qc = rmsnorm(linear(p["wq_down"], x), p["q_norm"])
    q = linear(p["wq_up"], qc).reshape(B, S, n_heads, qk_nope + qk_rope)
    q_nope, q_rope = q[..., :qk_nope], q[..., qk_nope:]
    cos, sin = rotary_cos_sin(pos, qk_rope, rope_theta)
    q_rope = apply_rotary(q_rope, cos[None], sin[None])
    return q_nope, q_rope


def mla_train(
    p, x: jax.Array, *, n_heads: int, kv_lora: int = 512, qk_nope: int = 128,
    qk_rope: int = 64, v_head: int = 128, rope_theta: float = 10000.0,
    kv_chunk: int = 1024, positions: Optional[jax.Array] = None,
) -> jax.Array:
    """Training forward: K/V decompressed chunk-by-chunk inside the scan."""
    B, S, _ = x.shape
    # the streaming accumulator is shaped off q's last dim; we feed q_nope,
    # so the value head width must match (true for DS-V2/V3: 128 == 128).
    assert qk_nope == v_head, "mla_train requires qk_nope == v_head"
    pos = jnp.arange(S) if positions is None else positions
    scale = (qk_nope + qk_rope) ** -0.5
    q_nope, q_rope = _mla_qkr(
        p, x, n_heads=n_heads, qk_nope=qk_nope, qk_rope=qk_rope,
        pos=pos, rope_theta=rope_theta,
    )
    kvd = linear(p["wkv_down"], x)
    c_kv = rmsnorm(kvd[..., :kv_lora], p["kv_norm"])       # (B, S, kv_lora)
    k_rope = kvd[..., kv_lora:]                            # (B, S, qk_rope)
    cos, sin = rotary_cos_sin(pos, qk_rope, rope_theta)
    k_rope = apply_rotary(k_rope[:, :, None, :], cos[None], sin[None])[:, :, 0, :]

    wk = p["wk_up"]["w"]
    wv = p["wv_up"]["w"]

    def score_fn(q, kv_c):
        # decompress k for this chunk only
        k_nope = (kv_c["c"] @ wk.astype(kv_c["c"].dtype)).reshape(
            B, -1, n_heads, qk_nope
        )
        s = jnp.einsum("bqhd,bshd->bhqs", q["nope"], k_nope)
        s += jnp.einsum("bqhr,bsr->bhqs", q["rope"], kv_c["r"])
        return s * scale

    def value_fn(pr, kv_c):
        v = (kv_c["c"] @ wv.astype(kv_c["c"].dtype)).reshape(
            B, -1, n_heads, v_head
        )
        return jnp.einsum("bhqs,bshd->bqhd", pr, v.astype(jnp.float32))

    # chunked_attention expects q as an array for shape info; pack dict via
    # a light shim: we pass q_nope and close over q_rope-compatible dict.
    q_pack = {"nope": q_nope, "rope": q_rope}

    def score(qa, kv_c):
        return score_fn(q_pack, kv_c)

    def value(pr, kv_c):
        return value_fn(pr, kv_c)

    out = chunked_attention(
        q_nope, {"c": c_kv, "r": k_rope}, S,
        score_fn=score, value_fn=value,
        mask_fn=_causal_window_mask(pos, None),
        kv_chunk=kv_chunk,
    )  # (B, S, H, v_head) — value_fn returned v_head-dim, shapes consistent
    out = out.reshape(B, S, n_heads * v_head).astype(x.dtype)
    return linear(p["wo"], out)


class MLACache(NamedTuple):
    c_kv: jax.Array       # (B, S_max, kv_lora) compressed latents
    k_rope: jax.Array     # (B, S_max, qk_rope)
    length: jax.Array


def mla_attend_step(
    p, x: jax.Array, c_cache: jax.Array, r_cache: jax.Array,
    length: jax.Array, *, n_heads: int, kv_lora: int = 512,
    qk_nope: int = 128, qk_rope: int = 64, v_head: int = 128,
    rope_theta: float = 10000.0,
):
    """Append-then-write absorbed MLA decode (read-only compressed cache).

    Returns (out, c_new (B, kv_lora), r_new (B, qk_rope))."""
    B = x.shape[0]
    pos = length
    scale = (qk_nope + qk_rope) ** -0.5
    q_nope, q_rope = _mla_qkr(
        p, x, n_heads=n_heads, qk_nope=qk_nope, qk_rope=qk_rope,
        pos=pos[None], rope_theta=rope_theta,
    )
    kvd = linear(p["wkv_down"], x)
    c_new = rmsnorm(kvd[..., :kv_lora], p["kv_norm"])[:, 0]
    r_new = kvd[..., kv_lora:]
    cos, sin = rotary_cos_sin(pos[None], qk_rope, rope_theta)
    r_new = apply_rotary(r_new[:, :, None, :], cos[None], sin[None])[:, 0, 0]
    wk = p["wk_up"]["w"].reshape(kv_lora, n_heads, qk_nope)
    wv = p["wv_up"]["w"].reshape(kv_lora, n_heads, v_head)
    q_c = jnp.einsum("bqhd,chd->bqhc", q_nope, wk.astype(q_nope.dtype))
    s = jnp.einsum("bqhc,bsc->bhqs", q_c, c_cache)
    s += jnp.einsum("bqhr,bsr->bhqs", q_rope, r_cache)
    s = s.astype(jnp.float32) * scale
    valid = jnp.arange(c_cache.shape[1]) < pos       # strict: self separate
    s = jnp.where(valid[None, None, None, :], s, _NEG_INF)
    s_self = (jnp.einsum("bqhc,bc->bhq", q_c, c_new.astype(q_c.dtype))
              + jnp.einsum("bqhr,br->bhq", q_rope,
                           r_new.astype(q_rope.dtype))
              ).astype(jnp.float32)[..., None] * scale
    s_all = jnp.concatenate([s, s_self], axis=-1)
    pr = jax.nn.softmax(s_all, axis=-1).astype(c_cache.dtype)
    ctx_c = jnp.einsum("bhqs,bsc->bqhc", pr[..., :-1], c_cache)
    ctx_c = ctx_c + jnp.einsum("bhq,bc->bqhc", pr[..., -1], c_new)
    out = jnp.einsum("bqhc,chd->bqhd", ctx_c, wv.astype(ctx_c.dtype))
    out = out.reshape(B, 1, n_heads * v_head).astype(x.dtype)
    return (linear(p["wo"], out), c_new.astype(c_cache.dtype),
            r_new.astype(r_cache.dtype))


def init_mla_cache(batch, s_max, *, kv_lora=512, qk_rope=64, dtype=jnp.float32):
    return MLACache(
        jnp.zeros((batch, s_max, kv_lora), dtype),
        jnp.zeros((batch, s_max, qk_rope), dtype),
        jnp.zeros((), jnp.int32),
    )


def mla_decode(
    p, x: jax.Array, cache: MLACache, *, n_heads: int, kv_lora: int = 512,
    qk_nope: int = 128, qk_rope: int = 64, v_head: int = 128,
    rope_theta: float = 10000.0,
):
    """Absorbed decode: scores/values contract in the compressed space.

    q_c = q_nope @ W_uk  (per head, into kv_lora space);
    scores = q_c . c_kv + q_rope . k_rope;   ctx_c = P . c_kv;
    out = ctx_c @ W_uv (per head).
    Cache cost per token: kv_lora + qk_rope floats — MLA's whole point.
    """
    B = x.shape[0]
    pos = cache.length
    scale = (qk_nope + qk_rope) ** -0.5
    q_nope, q_rope = _mla_qkr(
        p, x, n_heads=n_heads, qk_nope=qk_nope, qk_rope=qk_rope,
        pos=pos[None], rope_theta=rope_theta,
    )
    kvd = linear(p["wkv_down"], x)
    c_new = rmsnorm(kvd[..., :kv_lora], p["kv_norm"])
    r_new = kvd[..., kv_lora:]
    cos, sin = rotary_cos_sin(pos[None], qk_rope, rope_theta)
    r_new = apply_rotary(r_new[:, :, None, :], cos[None], sin[None])[:, :, 0, :]
    c_all = jax.lax.dynamic_update_slice_in_dim(cache.c_kv, c_new.astype(cache.c_kv.dtype), pos, 1)
    r_all = jax.lax.dynamic_update_slice_in_dim(cache.k_rope, r_new.astype(cache.k_rope.dtype), pos, 1)
    wk = p["wk_up"]["w"].reshape(kv_lora, n_heads, qk_nope)
    wv = p["wv_up"]["w"].reshape(kv_lora, n_heads, v_head)
    q_c = jnp.einsum("bqhd,chd->bqhc", q_nope, wk.astype(q_nope.dtype))
    s = jnp.einsum("bqhc,bsc->bhqs", q_c, c_all)
    s += jnp.einsum("bqhr,bsr->bhqs", q_rope, r_all)
    s = s.astype(jnp.float32) * scale
    valid = jnp.arange(c_all.shape[1]) <= pos
    s = jnp.where(valid[None, None, None, :], s, _NEG_INF)
    pr = jax.nn.softmax(s, axis=-1).astype(c_all.dtype)
    ctx_c = jnp.einsum("bhqs,bsc->bqhc", pr, c_all)
    out = jnp.einsum("bqhc,chd->bqhd", ctx_c, wv.astype(ctx_c.dtype))
    out = out.reshape(B, 1, n_heads * v_head).astype(x.dtype)
    return linear(p["wo"], out), MLACache(c_all, r_all, pos + 1)


# ---------------------------------------------------------------------------
# Cross-attention (enc-dec / whisper)
# ---------------------------------------------------------------------------


def init_cross_attention(key, d_model, n_heads, head_dim, dtype=jnp.float32):
    return init_gqa(key, d_model, n_heads, n_heads, head_dim, dtype=dtype)


def cross_attention(
    p, x: jax.Array, enc: jax.Array, *, n_heads: int, head_dim: int,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Decoder states (B,S,d) attend over encoder states (B,T,d). No mask."""
    B, S, _ = x.shape
    T = enc.shape[1]
    q = linear(p["wq"], x).reshape(B, S, n_heads, head_dim) * (head_dim ** -0.5)
    k = linear(p["wk"], enc).reshape(B, T, n_heads, head_dim)
    v = linear(p["wv"], enc).reshape(B, T, n_heads, head_dim)
    out = chunked_attention(
        q, {"k": k, "v": v}, T,
        score_fn=_gqa_score_fn(n_heads),
        value_fn=_gqa_value_fn(n_heads),
        mask_fn=lambda pos_k: jnp.ones((1, 1, S, pos_k.shape[0]), bool),
        kv_chunk=kv_chunk,
    )
    return linear(p["wo"], out.reshape(B, S, n_heads * head_dim).astype(x.dtype))
