"""Mixture-of-Experts layer: top-k routing, shared experts, expert parallel.

Two execution paths, numerically equivalent (tested against each other):

* ``dense``  — capacity-free weighted-sum over experts via one einsum.
  Exact and simple; cost scales with E, so it is reserved for smoke tests
  and small-E research runs.

* ``ep``     — production expert parallelism inside ``shard_map``:
  experts are sharded over the 'model' mesh axis; each device's tokens are
  bucketed by destination rank (capacity-bounded), exchanged with a single
  ``all_to_all``, run through the local experts (fori_loop, per-expert
  capacity gather -> FFN -> scatter), and exchanged back. Metadata for the
  return scatter never leaves the source device — the return all_to_all is
  the mirror image of the send, so each source rank un-permutes with its
  own indices. Token drops happen when a capacity bucket overflows
  (capacity_factor config), as in every capacity-based MoE system.

Routing is either classic softmax top-k or the paper-integrated
``sinkhorn`` balanced assignment (repro.core.routing) — the linear-Sinkhorn
solver reused as a router, see DESIGN.md §4.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..core.objective import ExecutionPolicy
from ..core.routing import sinkhorn_route
from .layers import trunc_normal

__all__ = ["init_moe", "moe_dense", "moe_ep_local", "router_probs"]


def init_moe(
    key, d_model: int, d_ff: int, n_experts: int, *, dtype=jnp.float32
):
    ks = jax.random.split(key, 4)
    out_std = 0.02 / (2.0 ** 0.5)
    return {
        "router": trunc_normal(ks[0], (d_model, n_experts), std=0.02,
                               dtype=jnp.float32),  # router math stays f32
        "up": trunc_normal(ks[1], (n_experts, d_model, d_ff), std=0.02, dtype=dtype),
        "gate": trunc_normal(ks[2], (n_experts, d_model, d_ff), std=0.02, dtype=dtype),
        "down": trunc_normal(ks[3], (n_experts, d_ff, d_model), std=float(out_std), dtype=dtype),
    }


def router_probs(
    p, x: jax.Array, *, top_k: int, router: str = "softmax",
    sinkhorn_eps: float = 0.05,
    policy: Optional[ExecutionPolicy] = None,
):
    """x (T, d) -> (combine (T, E), aux_loss). combine is zero off top-k.

    ``policy`` is the run-wide OT execution policy (shared with the
    prototype loss); it shapes only the ``sinkhorn`` router's solve.
    """
    logits = (x.astype(jnp.float32) @ p["router"])
    T, E = logits.shape
    if router == "sinkhorn":
        r = sinkhorn_route(logits, top_k=top_k, eps=sinkhorn_eps,
                           policy=policy)
        return r.combine, r.balance_loss
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)                  # (T, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    combine = jnp.zeros((T, E), jnp.float32).at[
        jnp.arange(T)[:, None], idx
    ].set(gates)
    # Switch-style load balance loss
    load = jnp.mean((combine > 0).astype(jnp.float32), axis=0)
    imp = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(load * imp)
    return combine, aux


def _expert_ffn(w_up, w_gate, w_down, x):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def moe_dense(
    p, x: jax.Array, *, top_k: int, router: str = "softmax",
    policy: Optional[ExecutionPolicy] = None,
) -> tuple[jax.Array, jax.Array]:
    """Exact dense path: every token through every expert, combine-weighted.

    x (T, d) -> (T, d). Cost O(T E d f) — smoke/tests/small-E only.
    """
    combine, aux = router_probs(p, x, top_k=top_k, router=router,
                                policy=policy)
    h = jnp.einsum("td,edf->tef", x, p["gate"].astype(x.dtype))
    u = jnp.einsum("td,edf->tef", x, p["up"].astype(x.dtype))
    y = jax.nn.silu(h) * u
    out = jnp.einsum("tef,efd,te->td", y, p["down"].astype(x.dtype),
                     combine.astype(x.dtype))
    return out, aux


def moe_ep_local(
    p_local,                    # router replicated; up/gate/down LOCAL (E_loc, ...)
    x: jax.Array,               # (T_loc, d) local tokens
    *,
    top_k: int,
    n_experts: int,
    axis: str = "model",
    router: str = "softmax",
    capacity_factor: float = 1.25,
    fsdp_axis: Optional[str] = None,
    policy: Optional[ExecutionPolicy] = None,
) -> tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE body. MUST run inside shard_map over ``axis``.

    Experts sharded over ``axis``: rank r owns experts [r*E_loc, (r+1)*E_loc).
    With ``fsdp_axis`` set, expert weights arrive additionally sharded over
    that axis on their d/f dim and are all-gathered LAZILY, one expert at a
    time inside the expert loop — live gathered weights drop from
    (E_loc, d, f) x3 to (d, f) x3 (§Perf train-memory hillclimb).
    """
    T, d = x.shape
    n_ranks = jax.lax.psum(1, axis)     # portable axis size (0.4.x has no lax.axis_size)
    E_loc = n_experts // n_ranks
    combine, aux = router_probs(p_local, x, top_k=top_k, router=router,
                                policy=policy)
    aux = jax.lax.pmean(aux, axis)

    # ---- flatten (token, k) assignments ----
    gates_k, idx_k = jax.lax.top_k(combine, top_k)            # (T, k)
    tok_id = jnp.repeat(jnp.arange(T), top_k)                 # (T*k,)
    exp_id = idx_k.reshape(-1)                                # (T*k,)
    gate = gates_k.reshape(-1)
    dest = exp_id // E_loc                                    # target rank
    e_loc = exp_id % E_loc                                    # local expert there

    # ---- capacity-bounded send buckets ----
    A = T * top_k
    c_send = int(-(-A // n_ranks) * capacity_factor)
    c_send = max(8, ((c_send + 7) // 8) * 8)                  # align
    onehot_dest = jax.nn.one_hot(dest, n_ranks, dtype=jnp.int32)
    pos_in_dest = jnp.cumsum(onehot_dest, axis=0) - onehot_dest
    pos = jnp.sum(pos_in_dest * onehot_dest, axis=1)          # (A,)
    keep = pos < c_send
    slot = jnp.where(keep, dest * c_send + pos, n_ranks * c_send)

    send_x = jnp.zeros((n_ranks * c_send + 1, d), x.dtype).at[slot].set(
        x[tok_id], mode="drop"
    )[:-1]
    send_e = jnp.full((n_ranks * c_send + 1,), E_loc, jnp.int32).at[slot].set(
        e_loc, mode="drop"
    )[:-1]

    # ---- exchange: rows become (source_rank, c_send, ...) ----
    recv_x = jax.lax.all_to_all(
        send_x.reshape(n_ranks, c_send, d), axis, 0, 0, tiled=False
    ).reshape(n_ranks * c_send, d)
    recv_e = jax.lax.all_to_all(
        send_e.reshape(n_ranks, c_send), axis, 0, 0, tiled=False
    ).reshape(n_ranks * c_send)

    # ---- local experts: per-expert capacity gather -> FFN -> scatter ----
    Rn = n_ranks * c_send
    c_exp = int(-(-Rn // max(E_loc, 1)) * capacity_factor)
    c_exp = max(8, ((c_exp + 7) // 8) * 8)
    onehot_e = jax.nn.one_hot(recv_e, E_loc + 1, dtype=jnp.int32)
    pos_e = (jnp.cumsum(onehot_e, axis=0) - onehot_e)
    pos_e = jnp.sum(pos_e * onehot_e, axis=1)                 # (Rn,)
    valid = (recv_e < E_loc) & (pos_e < c_exp)
    out_rows = jnp.zeros((Rn, d), x.dtype)

    def run_expert(out_rows, e):
        sel_slot = jnp.where((recv_e == e) & valid, pos_e, c_exp)
        # gather up to c_exp tokens of expert e
        gather_idx = jnp.full((c_exp + 1,), Rn, jnp.int32).at[sel_slot].set(
            jnp.arange(Rn, dtype=jnp.int32), mode="drop"
        )[:-1]
        xe = jnp.concatenate([recv_x, jnp.zeros((1, d), x.dtype)], 0)[gather_idx]
        wu = jax.lax.dynamic_index_in_dim(p_local["up"], e, 0, False).astype(x.dtype)
        wg = jax.lax.dynamic_index_in_dim(p_local["gate"], e, 0, False).astype(x.dtype)
        wd = jax.lax.dynamic_index_in_dim(p_local["down"], e, 0, False).astype(x.dtype)
        if fsdp_axis is not None:
            # lazy ZeRO-3 gather: only THIS expert's weights materialize
            wu = jax.lax.all_gather(wu, fsdp_axis, axis=0, tiled=True)
            wg = jax.lax.all_gather(wg, fsdp_axis, axis=0, tiled=True)
            wd = jax.lax.all_gather(wd, fsdp_axis, axis=1, tiled=True)
        ye = _expert_ffn(wu, wg, wd, xe)                      # (c_exp, d)
        out_rows = out_rows.at[gather_idx].add(
            jnp.where((gather_idx < Rn)[:, None], ye, 0.0), mode="drop"
        )
        return out_rows, None

    # scan (not fori_loop): reverse-mode differentiable expert loop
    out_rows, _ = jax.lax.scan(
        run_expert, out_rows, jnp.arange(E_loc, dtype=jnp.int32)
    )

    # ---- exchange back (mirror) and un-permute with local metadata ----
    back = jax.lax.all_to_all(
        out_rows.reshape(n_ranks, c_send, d), axis, 0, 0, tiled=False
    ).reshape(n_ranks * c_send, d)
    back = jnp.concatenate([back, jnp.zeros((1, d), x.dtype)], 0)
    contrib = back[jnp.minimum(slot, n_ranks * c_send)]       # (A, d)
    contrib = jnp.where(keep[:, None], contrib, 0.0) * gate[:, None].astype(x.dtype)
    out = jnp.zeros((T, d), x.dtype).at[tok_id].add(contrib)
    return out, aux
