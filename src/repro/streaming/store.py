"""Paged feature storage for streaming (mutable) distributions.

A :class:`PagedFeatureStore` keeps one distribution's positive feature
rows in a FIXED-CAPACITY buffer carved into pages of ``page_size`` rows —
the KV-cache page-table idiom applied to OT supports. Insert and evict
write pages and flip weights; array shapes NEVER change, so one jitted
solver (``repro.streaming.StreamingSolver``) serves every update without
retracing.

Invariants the rest of the stack leans on:

* **Dead slots carry zero weight.** Every solver in the repo masks
  zero-weight atoms exactly (``u = 0`` / ``f = -inf``), so stale feature
  rows in evicted slots change nothing.
* **Feature rows stay strictly positive**, live or dead. Linear-space
  kernels divide by ``K^T u`` and log-space takes ``log Xi``; the buffer
  is initialized to ones and only ever overwritten with feature rows
  drawn from a positive feature map, so no masked path ever sees a zero
  or negative entry.
* **Per-page live counts ride as traced int32** (``page_live``): the
  paged Pallas kernels (``repro.kernels.paged``) skip all-dead pages via
  scalar-prefetch + ``pl.when`` without occupancy changes ever retracing.

Bookkeeping is host-side numpy + dicts (the serving dispatch-path rule:
no eager jnp glue); the device buffer syncs lazily, one fixed-shape
jitted ``dynamic_update_slice`` per dirty page with a TRACED page start —
flushing page 3 and page 17 replays the same executable.

The host-side page table is exposed CSR-style (``page_indices`` /
``page_indptr`` / ``last_page_len``) for occupancy accounting and the
allocation policy (pack new rows into the most-filled non-full page, so
live pages stay dense and dead pages stay skippable).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Hashable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.shapes import ot_bucket
from ..core.features import gaussian_features

__all__ = ["PagedFeatureStore", "StreamingDistribution", "bucket_capacity"]


def bucket_capacity(n: int, page_size: int) -> int:
    """Bucketed store capacity for ``n`` expected live rows: the
    ``ot_bucket`` of ``n`` plus one headroom page, rounded up to a whole
    number of pages (the paged kernels require exact multiples)."""
    cap = ot_bucket(max(1, n) + page_size)
    return ((cap + page_size - 1) // page_size) * page_size


@functools.partial(jax.jit, static_argnames=())
def _write_page(buf: jax.Array, block: jax.Array,
                start: jax.Array) -> jax.Array:
    """One dirty-page flush: overwrite ``page_size`` rows at ``start``.

    ``start`` is a traced scalar, so every page of a given buffer shape
    replays one compiled executable — flushes never retrace."""
    return jax.lax.dynamic_update_slice(
        buf, block, (start, jnp.zeros((), start.dtype)))


class PagedFeatureStore:
    """Fixed-capacity paged buffer of positive feature rows + weights.

    ``capacity`` must be a multiple of ``page_size``. Rows are addressed
    by caller-chosen hashable ids; ``add`` on an existing id overwrites
    its row in place (same slot), ``remove`` flips its weight to zero and
    frees the slot. The device mirror is synced by :meth:`flush` (called
    by :meth:`device_features`), page-granular.
    """

    def __init__(self, rank: int, capacity: int, *, page_size: int = 64,
                 dtype=np.float32):
        if page_size < 1 or page_size % 8 != 0:
            raise ValueError(
                f"page_size must be a positive multiple of 8, got "
                f"{page_size}")
        if capacity < page_size or capacity % page_size != 0:
            raise ValueError(
                f"capacity {capacity} must be a positive multiple of "
                f"page_size {page_size}")
        self.rank = int(rank)
        self.capacity = int(capacity)
        self.page_size = int(page_size)
        self.n_pages = capacity // page_size
        self.dtype = np.dtype(dtype)
        # ones, not zeros: dead rows must stay strictly positive so the
        # masked linear/log operators never see log(0) or divide into 0
        self._feats = np.ones((capacity, rank), self.dtype)
        self._weights = np.zeros((capacity,), self.dtype)
        self._live = np.zeros((capacity,), bool)
        self._page_live = np.zeros((self.n_pages,), np.int32)
        self._slot: Dict[Hashable, int] = {}
        self._alloc_order: List[int] = []   # pages in first-touch order
        self._dirty: set = set()            # page ids pending device sync
        self._dev_feats: Optional[jax.Array] = None
        self.version = 0                    # bumps on every mutation

    # -- occupancy / page table ---------------------------------------

    def __len__(self) -> int:
        return len(self._slot)

    @property
    def n_live(self) -> int:
        return len(self._slot)

    @property
    def page_live(self) -> np.ndarray:
        """Per-page live-slot counts, int32 ``(n_pages,)`` (copy)."""
        return self._page_live.copy()

    @property
    def page_indices(self) -> np.ndarray:
        """Physical ids of pages holding >= 1 live slot, in first-touch
        order (the CSR page-table view, host-side)."""
        return np.asarray(
            [p for p in self._alloc_order if self._page_live[p] > 0],
            np.int32)

    @property
    def page_indptr(self) -> np.ndarray:
        """CSR offsets over :attr:`page_indices`: slot
        ``page_indptr[i]:page_indptr[i+1]`` of the logical live ordering
        lives in page ``page_indices[i]``."""
        counts = self._page_live[self.page_indices]
        return np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)

    @property
    def last_page_len(self) -> int:
        """Live count of the most recently touched live page (the page
        new inserts drain into first when it is non-full)."""
        idx = self.page_indices
        return int(self._page_live[idx[-1]]) if idx.size else 0

    def ids(self) -> List[Hashable]:
        return list(self._slot)

    def slot_of(self, id_) -> int:
        return self._slot[id_]

    def live_mask(self) -> np.ndarray:
        return self._live.copy()

    def weights_host(self) -> np.ndarray:
        return self._weights.copy()

    def stats(self) -> Dict[str, object]:
        live_pages = int(np.count_nonzero(self._page_live))
        return {
            "capacity": self.capacity,
            "rank": self.rank,
            "page_size": self.page_size,
            "n_pages": self.n_pages,
            "n_live": self.n_live,
            "live_pages": live_pages,
            "occupancy": self.n_live / self.capacity,
            "page_occupancy": live_pages / self.n_pages,
            "version": self.version,
        }

    # -- mutation ------------------------------------------------------

    def _alloc_slot(self) -> int:
        """Pick a dead slot: most-filled non-full page first (keeps live
        pages dense so all-dead pages stay skippable), fresh page last."""
        best_page, best_count = -1, -1
        for p in range(self.n_pages):
            c = int(self._page_live[p])
            if 0 < c < self.page_size and c > best_count:
                best_page, best_count = p, c
        if best_page < 0:
            # no partially-filled page: open the first fully-dead one
            for p in range(self.n_pages):
                if self._page_live[p] == 0:
                    best_page = p
                    break
        if best_page < 0:
            raise ValueError(
                f"store full: capacity {self.capacity} exhausted "
                "(grow via StreamingDistribution rebucketing)")
        base = best_page * self.page_size
        for s in range(base, base + self.page_size):
            if not self._live[s]:
                return s
        raise AssertionError("page_live count out of sync with live mask")

    def add(self, ids: Sequence[Hashable], feats, weights) -> None:
        """Insert (or overwrite in place) rows for ``ids``.

        ``feats``: ``(k, rank)`` strictly positive rows; ``weights``:
        ``(k,)`` strictly positive masses. Raises before mutating if the
        batch does not fit the remaining capacity."""
        feats = np.asarray(feats, self.dtype)
        weights = np.asarray(weights, self.dtype)
        if feats.shape != (len(ids), self.rank):
            raise ValueError(
                f"feats shape {feats.shape} != ({len(ids)}, {self.rank})")
        if weights.shape != (len(ids),):
            raise ValueError(
                f"weights shape {weights.shape} != ({len(ids)},)")
        if np.any(weights <= 0) or not np.all(np.isfinite(weights)):
            raise ValueError("weights must be strictly positive and finite "
                             "(zero weight means dead — use remove)")
        # NaN slips through a bare `<= 0` comparison (NaN <= 0 is False):
        # a non-finite row would sit in a LIVE page where weight masking
        # cannot scrub it (0 * NaN = NaN inside the contractions), so the
        # invariant is enforced here, at the only write boundary
        if np.any(feats <= 0) or not np.all(np.isfinite(feats)):
            raise ValueError("feature rows must be strictly positive and "
                             "finite (linear-space positive-feature "
                             "invariant)")
        n_new = sum(1 for i in ids if i not in self._slot)
        if self.n_live + n_new > self.capacity:
            raise ValueError(
                f"insert of {n_new} new rows overflows capacity "
                f"{self.capacity} (live: {self.n_live})")
        for j, id_ in enumerate(ids):
            slot = self._slot.get(id_)
            if slot is None:
                slot = self._alloc_slot()
                self._slot[id_] = slot
                self._live[slot] = True
                page = slot // self.page_size
                self._page_live[page] += 1
                if page not in self._alloc_order:
                    self._alloc_order.append(page)
            self._feats[slot] = feats[j]
            self._weights[slot] = weights[j]
            self._dirty.add(slot // self.page_size)
        self.version += 1

    def remove(self, ids: Sequence[Hashable]) -> None:
        """Evict rows: weight -> 0, slot freed; the stale (positive)
        feature row stays in place — masked out, never read as data."""
        missing = [i for i in ids if i not in self._slot]
        if missing:
            raise KeyError(f"ids not in store: {missing[:5]}")
        for id_ in ids:
            slot = self._slot.pop(id_)
            self._live[slot] = False
            self._weights[slot] = 0.0
            self._page_live[slot // self.page_size] -= 1
            # no dirty mark: eviction touches weights/liveness only, the
            # stale feature bytes on device are already correct
        self.version += 1

    def set_weights(self, ids: Sequence[Hashable], weights) -> None:
        """Reweight live rows in place (no feature write, no flush)."""
        weights = np.asarray(weights, self.dtype)
        if np.any(weights <= 0):
            raise ValueError("weights must be strictly positive")
        for id_, w in zip(ids, weights):
            self._weights[self._slot[id_]] = w
        self.version += 1

    # -- device sync ---------------------------------------------------

    def flush(self) -> int:
        """Sync dirty pages to the device mirror; returns pages written."""
        if self._dev_feats is None:
            self._dev_feats = jnp.asarray(self._feats)
            n = len(self._dirty)
            self._dirty.clear()
            return n
        n = 0
        for page in sorted(self._dirty):
            base = page * self.page_size
            block = jnp.asarray(self._feats[base:base + self.page_size])
            self._dev_feats = _write_page(
                self._dev_feats, block, np.int32(base))
            n += 1
        self._dirty.clear()
        return n

    def device_features(self) -> jax.Array:
        """The ``(capacity, rank)`` device buffer, synced."""
        self.flush()
        return self._dev_feats

    def compact_grow(self, new_capacity: int) -> np.ndarray:
        """Repack live rows densely into a larger buffer (bucket-boundary
        crossing). Returns ``perm``: ``(new_capacity,)`` int array with
        ``perm[new_slot] = old_slot`` for moved rows and ``-1`` for empty
        slots — callers remap persisted per-slot state (warm-start
        potentials) through it."""
        if new_capacity < self.n_live:
            raise ValueError(
                f"new capacity {new_capacity} < {self.n_live} live rows")
        if new_capacity % self.page_size != 0:
            raise ValueError(
                f"new capacity {new_capacity} must be a multiple of "
                f"page_size {self.page_size}")
        perm = np.full((new_capacity,), -1, np.int64)
        feats = np.ones((new_capacity, self.rank), self.dtype)
        weights = np.zeros((new_capacity,), self.dtype)
        live = np.zeros((new_capacity,), bool)
        new_slot_of: Dict[Hashable, int] = {}
        for new_slot, (id_, old_slot) in enumerate(self._slot.items()):
            perm[new_slot] = old_slot
            feats[new_slot] = self._feats[old_slot]
            weights[new_slot] = self._weights[old_slot]
            live[new_slot] = True
            new_slot_of[id_] = new_slot
        self.capacity = int(new_capacity)
        self.n_pages = new_capacity // self.page_size
        self._feats, self._weights, self._live = feats, weights, live
        self._slot = new_slot_of
        self._page_live = np.asarray(
            [int(live[p * self.page_size:(p + 1) * self.page_size].sum())
             for p in range(self.n_pages)], np.int32)
        self._alloc_order = [p for p in range(self.n_pages)
                             if self._page_live[p] > 0]
        self._dirty = set()
        self._dev_feats = None      # full re-upload on next flush
        self.version += 1
        return perm


class StreamingDistribution:
    """A mutable weighted point set backed by a :class:`PagedFeatureStore`.

    Wraps one SIDE of a factored OT problem — the rows of ``Xi`` (or
    ``Zeta``) plus masses — at bucketed capacity. Build it
    :meth:`from_features` (precomputed positive rows, the
    ``FactoredPositive`` view) or :meth:`from_points` (raw points run
    through the Lemma-1 Gaussian feature map at the distribution's
    pinned ``eps`` — the ``GaussianPointCloud`` view, so later ``add``
    calls can pass points and featurize consistently).

    ``add`` past capacity triggers a bucket-boundary crossing: the store
    compact-grows to the next ``ot_bucket`` and the slot permutation is
    queued for the solver to remap its persisted warm-start potentials
    (:meth:`take_remap`).
    """

    def __init__(self, store: PagedFeatureStore, *, eps: float,
                 featurize: Optional[Callable[[np.ndarray], np.ndarray]]
                 = None):
        self.store = store
        self.eps = float(eps)
        self._featurize = featurize
        self._remaps: List[np.ndarray] = []

    # -- constructors --------------------------------------------------

    @classmethod
    def from_features(cls, ids: Sequence[Hashable], feats, weights, *,
                      eps: float, capacity: Optional[int] = None,
                      page_size: int = 64) -> "StreamingDistribution":
        feats = np.asarray(feats)
        cap = capacity or bucket_capacity(len(ids), page_size)
        store = PagedFeatureStore(feats.shape[1], cap, page_size=page_size)
        dist = cls(store, eps=eps)
        if len(ids):
            dist.add(ids, feats=feats, weights=weights)
        return dist

    @classmethod
    def from_points(cls, ids: Sequence[Hashable], points, weights,
                    anchors, *, eps: float, q: float = 1.0,
                    capacity: Optional[int] = None,
                    page_size: int = 64) -> "StreamingDistribution":
        anchors = np.asarray(anchors, np.float32)

        def featurize(pts: np.ndarray) -> np.ndarray:
            return np.asarray(
                gaussian_features(jnp.asarray(pts, jnp.float32),
                                  jnp.asarray(anchors), eps=eps, q=q))

        cap = capacity or bucket_capacity(len(ids), page_size)
        store = PagedFeatureStore(anchors.shape[0], cap,
                                  page_size=page_size)
        dist = cls(store, eps=eps, featurize=featurize)
        if len(ids):
            dist.add(ids, points=points, weights=weights)
        return dist

    # -- mutation ------------------------------------------------------

    def add(self, ids: Sequence[Hashable], *, feats=None, points=None,
            weights=None) -> None:
        """Insert/overwrite rows; pass ``feats`` (precomputed) or
        ``points`` (featurized through the pinned map). Grows the store
        through the next bucket boundary when needed."""
        if (feats is None) == (points is None):
            raise ValueError("pass exactly one of feats= or points=")
        if points is not None:
            if self._featurize is None:
                raise ValueError(
                    "this distribution was built from_features; "
                    "pass feats=, not points=")
            feats = self._featurize(np.asarray(points))
        if weights is None:
            raise ValueError("weights= is required")
        n_new = sum(1 for i in ids if i not in self.store._slot)
        if self.store.n_live + n_new > self.store.capacity:
            self._grow(self.store.n_live + n_new)
        self.store.add(ids, feats, weights)

    def remove(self, ids: Sequence[Hashable]) -> None:
        self.store.remove(ids)

    def _grow(self, needed: int) -> None:
        new_cap = bucket_capacity(needed, self.store.page_size)
        self._remaps.append(self.store.compact_grow(new_cap))

    def take_remap(self) -> Optional[np.ndarray]:
        """Composed slot permutation since the last call (or ``None``):
        ``perm[new_slot] = oldest_slot``. The solver pipes its persisted
        potentials through this after a bucket crossing."""
        if not self._remaps:
            return None
        perm = self._remaps[0]
        for nxt in self._remaps[1:]:
            keep = nxt >= 0
            composed = np.full_like(nxt, -1)
            composed[keep] = perm[nxt[keep]]
            perm = composed
        self._remaps = []
        return perm

    # -- solve-side views ----------------------------------------------

    @property
    def capacity(self) -> int:
        return self.store.capacity

    @property
    def n_live(self) -> int:
        return self.store.n_live

    def device_features(self) -> jax.Array:
        return self.store.device_features()

    def page_live(self) -> np.ndarray:
        return self.store.page_live

    def weights_host(self) -> np.ndarray:
        return self.store.weights_host()

    def live_mask(self) -> np.ndarray:
        return self.store.live_mask()
