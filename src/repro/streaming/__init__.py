"""Streaming supports: paged feature storage + incremental re-solve.

The mutable-distribution stack, bottom-up:

* :class:`~repro.streaming.store.PagedFeatureStore` — fixed-capacity
  paged buffer of positive feature rows; insert/evict flips weights and
  writes pages, never shapes.
* :class:`~repro.streaming.store.StreamingDistribution` — one mutable
  side of an OT problem (precomputed features or raw points through the
  pinned Gaussian feature map), with bucket-boundary rebucketing.
* :class:`~repro.streaming.solver.StreamingSolver` — warm-started
  incremental re-solves through one pre-planned jitted runner per
  ``(capacity, rank)`` bucket cell; zero post-warmup retraces.

The serving front end (mutation coalescing through the admission queue)
lives in ``repro.serving.streaming``.
"""
from .solver import StreamingPair, StreamingSolver
from .store import PagedFeatureStore, StreamingDistribution, bucket_capacity

__all__ = [
    "PagedFeatureStore",
    "StreamingDistribution",
    "StreamingPair",
    "StreamingSolver",
    "bucket_capacity",
]
