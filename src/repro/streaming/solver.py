"""Incremental re-solve engine over paged streaming distributions.

One :class:`StreamingSolver` owns an LRU of pre-planned jitted runners,
keyed by the bucket cell ``(C_x, C_y, r, page_size, eps, method)``. A
runner closes over the whole solve — normalization, the
:class:`~repro.core.paged.PagedFactored` geometry, warm-start masking,
the Sinkhorn while_loop — on FIXED buffer shapes, so every update at a
given capacity replays one compiled executable: zero post-warmup
retraces, amortized cost ``O(r * delta_n)`` extra iterations on top of
the warm-started tail.

Warm-start contract (the part that makes parity exact):

* scaling method: the runner builds ``u0 = where(a > 0, exp(f0/eps), 0)``
  so a COLD start (``f0 = 0``) is ``u0 = live_mask`` — elementwise equal
  to the unpadded dense solve's ``u0 = ones`` trajectory from iteration
  0, dead slots exactly zero throughout.
* log method: ``f0`` flows into ``_log_init``, which pins dead slots to
  ``-inf`` — inert in every LSE, exact from iteration 0.
* between solves, potentials persist host-side per pair; newly-live
  slots (inserts) and non-finite entries reset to 0 (= cold for that
  slot), bucket crossings remap through the store's slot permutation.

The dispatch path is host numpy end to end (PR 6 serving rule): runners
are warmed with numpy operands so steady-state numpy calls hit the same
jit cache entry, and the only device work per update is the dirty-page
flush plus the one runner call.
"""
from __future__ import annotations

import collections
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.paged import PagedFactored
from ..core.sinkhorn import (
    SinkhornResult,
    sinkhorn_geometry,
    sinkhorn_log_geometry,
)
from ..resilience.health import SolveHealth, classify
from .store import StreamingDistribution

__all__ = ["StreamingPair", "StreamingSolver"]

METHODS = ("scaling", "log")

# (C_x, C_y, r, page_size, eps, method)
RunnerKey = Tuple[int, int, int, int, float, str]


class StreamingPair:
    """One tracked OT problem between two streaming distributions, with
    its persisted warm-start potentials (host numpy, full capacity)."""

    __slots__ = ("name", "x", "y", "f", "g", "n_solves", "n_warm",
                 "last_health")

    def __init__(self, name: str, x: StreamingDistribution,
                 y: StreamingDistribution):
        if x.eps != y.eps:
            raise ValueError(
                f"pair sides drawn at different eps: {x.eps} vs {y.eps}")
        self.name = name
        self.x = x
        self.y = y
        self.f: Optional[np.ndarray] = None
        self.g: Optional[np.ndarray] = None
        self.n_solves = 0
        self.n_warm = 0
        self.last_health: Optional[SolveHealth] = None

    @property
    def eps(self) -> float:
        return self.x.eps


def _prep_init(saved: Optional[np.ndarray], live: np.ndarray,
               remap: Optional[np.ndarray], capacity: int
               ) -> Tuple[np.ndarray, int]:
    """Host-side warm-start preparation: remap through a bucket crossing,
    then reset dead / newly-live / non-finite slots to 0 (cold). Returns
    ``(f0, n_reset)`` where ``n_reset`` counts LIVE slots whose saved
    potential was non-finite — the poisoned-warm-state signal the solver's
    ``warm_resets`` counter aggregates."""
    f0 = np.zeros((capacity,), np.float32)
    if saved is None:
        return f0, 0
    if remap is not None:
        moved = remap >= 0
        f0[moved] = saved[remap[moved]]
    elif saved.shape[0] == capacity:
        f0[:] = saved
    else:                       # shape drifted without a remap: cold
        return f0, 0
    n_reset = int(np.sum(live & ~np.isfinite(f0)))
    f0 = np.where(live & np.isfinite(f0), f0, 0.0).astype(np.float32)
    return f0, n_reset


class StreamingSolver:
    """Warm-started incremental Sinkhorn over paged supports.

    Solver knobs mirror :func:`~repro.core.sinkhorn.sinkhorn_geometry`;
    ``method`` picks the iteration domain ("scaling" | "log"). One
    instance serves many pairs; runners are shared across pairs that land
    in the same bucket cell.
    """

    def __init__(self, *, method: str = "scaling", tol: float = 1e-6,
                 max_iter: int = 2000, momentum: float = 1.0,
                 use_pallas: Optional[bool] = None,
                 precision: str = "highest", max_runners: int = 8):
        if method not in METHODS:
            raise ValueError(f"method must be one of {METHODS}, "
                             f"got {method!r}")
        self.method = method
        self.tol = float(tol)
        self.max_iter = int(max_iter)
        self.momentum = float(momentum)
        self.use_pallas = use_pallas
        self.precision = precision
        self.max_runners = int(max_runners)
        self._runners: "collections.OrderedDict[RunnerKey, object]" = \
            collections.OrderedDict()
        self._pairs: Dict[str, StreamingPair] = {}
        self.warmups = 0
        # resilience accounting (see _solve)
        self.diverged = 0        # solves that ended non-finite (terminal)
        self.cold_fallbacks = 0  # warm failures retried cold, same runner
        self.state_resets = 0    # pairs whose persisted potentials dropped
        self.warm_resets = 0     # live slots with non-finite saved warm state

    # -- pair registry -------------------------------------------------

    def register(self, name: str, x: StreamingDistribution,
                 y: StreamingDistribution) -> StreamingPair:
        if name in self._pairs:
            raise ValueError(f"pair {name!r} already registered")
        pair = StreamingPair(name, x, y)
        self._pairs[name] = pair
        return pair

    def pair(self, name: str) -> StreamingPair:
        return self._pairs[name]

    @property
    def pairs(self) -> Tuple[str, ...]:
        return tuple(self._pairs)

    # -- runner cache --------------------------------------------------

    def _key(self, pair: StreamingPair) -> RunnerKey:
        sx, sy = pair.x.store, pair.y.store
        if sx.rank != sy.rank:
            raise ValueError(
                f"rank mismatch: {sx.rank} vs {sy.rank}")
        if sx.page_size != sy.page_size:
            raise ValueError(
                f"page_size mismatch: {sx.page_size} vs {sy.page_size}")
        return (sx.capacity, sy.capacity, sx.rank, sx.page_size,
                pair.eps, self.method)

    def _build(self, key: RunnerKey):
        _, _, _, page_size, eps, method = key
        tol, max_iter, momentum = self.tol, self.max_iter, self.momentum
        use_pallas, precision = self.use_pallas, self.precision

        def run(xi, zeta, live_x, live_y, wa, wb, f0, g0):
            a = wa / jnp.sum(wa)
            b = wb / jnp.sum(wb)
            geom = PagedFactored(
                xi=xi, zeta=zeta, page_live_x=live_x, page_live_y=live_y,
                page_size=page_size, eps=eps)
            if method == "log":
                # _log_init pins dead (a==0) slots to -inf exactly
                return sinkhorn_log_geometry(
                    geom, a, b, tol=tol, max_iter=max_iter,
                    momentum=momentum, f_init=f0, g_init=g0,
                    use_pallas=use_pallas, precision=precision)
            u0 = jnp.where(a > 0, jnp.exp(f0 / eps), 0.0)
            v0 = jnp.where(b > 0, jnp.exp(g0 / eps), 0.0)
            del v0  # scaling iteration starts on the v-update; only u0 seeds
            return sinkhorn_geometry(
                geom, a, b, tol=tol, max_iter=max_iter,
                momentum=momentum, u_init=u0,
                use_pallas=use_pallas, precision=precision)

        return jax.jit(run)

    def _runner(self, key: RunnerKey):
        fn = self._runners.get(key)
        if fn is not None:
            self._runners.move_to_end(key)
            return fn
        fn = self._build(key)
        self._runners[key] = fn
        while len(self._runners) > self.max_runners:
            self._runners.popitem(last=False)
        return fn

    def warmup(self, pair: StreamingPair) -> None:
        """Pre-trace the pair's runner on synthetic NUMPY operands (the
        steady-state dispatch path), so the first real update replays a
        compiled executable. Uniform all-live operands converge in O(1)
        iterations — warmup cost is one trace, not one real solve."""
        key = self._key(pair)
        C_x, C_y, r, page_size, _, _ = key
        fn = self._runner(key)
        # operand BACKING must match the real call exactly — numpy-backed
        # and device-backed operands are distinct jit cache entries — so:
        # features on device (the store's flushed mirror), everything
        # else host numpy (the dispatch-path rule)
        fn(jnp.ones((C_x, r), jnp.float32), jnp.ones((C_y, r), jnp.float32),
           np.full((C_x // page_size,), page_size, np.int32),
           np.full((C_y // page_size,), page_size, np.int32),
           np.ones((C_x,), np.float32), np.ones((C_y,), np.float32),
           np.zeros((C_x,), np.float32), np.zeros((C_y,), np.float32))
        self.warmups += 1

    @property
    def traces(self) -> int:
        """Total compiled traces across live runners — the retrace gate:
        flat after warmup, no matter how many updates flow through."""
        return sum(int(fn._cache_size()) for fn in self._runners.values())

    # -- solving -------------------------------------------------------

    def _solve(self, pair: StreamingPair, warm: bool) -> SinkhornResult:
        dx, dy = pair.x, pair.y
        remap_x, remap_y = dx.take_remap(), dy.take_remap()
        live_x, live_y = dx.live_mask(), dy.live_mask()
        warm_used = warm and pair.f is not None
        if warm_used:
            f0, rf = _prep_init(pair.f, live_x, remap_x, dx.capacity)
            g0, rg = _prep_init(pair.g, live_y, remap_y, dy.capacity)
            self.warm_resets += rf + rg
            pair.n_warm += 1
        else:
            f0 = np.zeros((dx.capacity,), np.float32)
            g0 = np.zeros((dy.capacity,), np.float32)
        fn = self._runner(self._key(pair))
        operands = (dx.device_features(), dy.device_features(),
                    dx.page_live(), dy.page_live(),
                    dx.weights_host(), dy.weights_host())
        res = fn(*operands, f0, g0)
        health = classify(res)
        if health.failed and warm_used:
            # post-mutation warm re-solve went non-finite: the persisted
            # potentials no longer fit the mutated state (or were subtly
            # poisoned). Fall back to a COLD solve through the SAME
            # compiled runner — zero-init operands hit the identical jit
            # cache entry, so the retry costs iterations, never a retrace.
            self.cold_fallbacks += 1
            res = fn(*operands,
                     np.zeros((dx.capacity,), np.float32),
                     np.zeros((dy.capacity,), np.float32))
            health = classify(res)
        pair.n_solves += 1
        pair.last_health = health
        if health.failed:
            # terminal divergence: drop the persisted potentials so the
            # NEXT solve starts cold instead of inheriting poison
            self.diverged += 1
            if pair.f is not None:
                self.state_resets += 1
            pair.f = pair.g = None
            return res
        pair.f = np.asarray(res.f)
        pair.g = np.asarray(res.g)
        return res

    def re_solve(self, pair: StreamingPair) -> SinkhornResult:
        """Warm-started solve from the pair's persisted potentials."""
        return self._solve(pair, warm=True)

    def cold_solve(self, pair: StreamingPair) -> SinkhornResult:
        """Zero-init solve through the SAME runner (the benchmark
        baseline: identical executable, no warm start)."""
        return self._solve(pair, warm=False)

    def update(self, pair: StreamingPair, *,
               add_x: Optional[dict] = None,
               remove_x=None,
               add_y: Optional[dict] = None,
               remove_y=None) -> SinkhornResult:
        """Apply mutations to both sides, then warm re-solve.

        ``add_x`` / ``add_y`` are kwarg dicts for
        :meth:`StreamingDistribution.add` (``ids`` + ``feats`` or
        ``points`` + ``weights``); ``remove_*`` are id sequences.
        Mutations land first (evictions before the solve, so their mass
        is gone from the marginals), then ONE warm re-solve runs.
        """
        if remove_x is not None:
            pair.x.remove(remove_x)
        if remove_y is not None:
            pair.y.remove(remove_y)
        if add_x is not None:
            pair.x.add(**add_x)
        if add_y is not None:
            pair.y.add(**add_y)
        return self.re_solve(pair)

    def stats(self) -> Dict[str, object]:
        return {
            "pairs": len(self._pairs),
            "runners": len(self._runners),
            "traces": self.traces,
            "warmups": self.warmups,
            "method": self.method,
            "diverged": self.diverged,
            "cold_fallbacks": self.cold_fallbacks,
            "state_resets": self.state_resets,
            "warm_resets": self.warm_resets,
        }
