"""Ladder executor for the core ``solve`` surface.

``solve_with_recovery`` runs a :class:`~repro.core.spec.SolveSpec` whose
``recovery`` field names a :class:`RecoveryPolicy`: the base
configuration solves first; on a failed verdict the executor climbs the
rung ladder, applying each rung's degradation CUMULATIVELY (see
:mod:`repro.resilience.policy`) and re-solving cold, until a verdict in
``policy.accept`` lands or the attempt/deadline budget runs out.

This is the offline/one-shot twin of the serving executor
(:meth:`OTService._recover_one <repro.serving.service.OTService>`): the
serving one routes retries through pre-planned batch-1 runners so they
never trace under traffic; here each attempt goes through the ordinary
``solve`` path, whose engines/stage-runners are cached per configuration
— a ladder climbed twice reuses every executable the first climb built.

The ``raise_eps`` rung respects the :class:`~repro.core.api.EpsSchedule`
warm-start semantics by construction: it installs a schedule starting at
``eps * eps_scale``, so the annealed cascade hands each stage's
potentials to the next and the final stage solves AT the requested eps —
the caller still gets the answer it asked for, reached along a
better-conditioned path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

from .health import SolveHealth, classify
from .policy import RecoveryPolicy

__all__ = ["RecoveredSolve", "solve_with_recovery"]

# scaling-domain methods and their log-domain twins; methods absent here
# (already log-domain, or cost-family conversions whose solver domain is
# not a free knob) skip the log_domain rung
LOG_TWIN = {
    "factored": "log_factored",
    "quadratic": "log_quadratic",
    "sharded": "sharded_log",
}
LOG_METHODS = ("log_factored", "log_quadratic", "sharded_log",
               "accelerated")


@dataclasses.dataclass(frozen=True)
class RecoveredSolve:
    """Outcome of a ladder run: the final result plus the climb record."""

    result: object                       # SinkhornResult
    health: SolveHealth
    attempts: int
    rungs: Tuple[str, ...]               # rungs actually executed, in order
    history: Tuple[Tuple[str, SolveHealth], ...]   # ("initial"/rung, verdict)

    @property
    def recovered(self) -> bool:
        return self.health.finite and self.attempts > 1


@dataclasses.dataclass
class _LadderState:
    """The cumulative configuration the ladder has degraded to."""

    method: str
    precision: str
    use_pallas: Optional[bool]
    inner_steps: Optional[int]
    check_every: Optional[int]
    schedule: object                     # Optional[EpsSchedule]


def apply_rung(state: _LadderState, rung: str, spec,
               policy: RecoveryPolicy) -> bool:
    """Mutate ``state`` with one rung's degradation; False = rung does
    not apply to this configuration (skipped, no attempt consumed)."""
    from ..core.api import EpsSchedule

    if rung == "log_domain":
        twin = LOG_TWIN.get(state.method)
        if twin is None or state.method in LOG_METHODS:
            return False
        state.method = twin
        return True
    if rung == "precision_f32":
        if state.precision == "highest":
            return False
        state.precision = "highest"
        return True
    if rung == "raise_eps":
        if not spec.geometry.anneal_capable:
            return False
        eps_init = float(spec.eps) * policy.eps_scale
        prev = state.schedule
        if prev is not None and prev.eps_init >= eps_init:
            return False
        state.schedule = EpsSchedule(eps_init=eps_init)
        return True
    if rung == "per_iteration":
        if (state.use_pallas is False and state.inner_steps == 1
                and state.check_every == 1):
            return False
        state.use_pallas = False
        state.inner_steps = 1
        state.check_every = 1
        return True
    if rung == "cold_restart":
        # the core surface has no warm-start inputs: every spec solve is
        # already cold, so a bare re-run of the same configuration cannot
        # change the outcome — the rung belongs to the serving/streaming
        # executors, which do hold warm state to discard
        return False
    raise ValueError(f"unknown rung {rung!r}")


def solve_with_recovery(spec, *, first_attempt=None) -> RecoveredSolve:
    """Run ``spec`` through its recovery ladder (see module docstring).

    ``first_attempt`` optionally supplies an ALREADY-COMPUTED result of
    the base configuration (e.g. a failed lane from a batched
    ``solve_many`` bucket), so the ladder does not pay for re-failing it.
    """
    from ..core.api import _auto_method, solve

    policy: Optional[RecoveryPolicy] = spec.recovery
    if policy is None:
        policy = RecoveryPolicy()
    base = spec.replace(recovery=None)
    t0 = time.monotonic()

    res = solve(base) if first_attempt is None else first_attempt
    health = classify(res)
    history: List[Tuple[str, SolveHealth]] = [("initial", health)]
    attempts = 1
    rungs_run: List[str] = []
    if health.verdict in policy.accept:
        return RecoveredSolve(res, health, attempts, (), tuple(history))

    method = base.method
    if method == "auto":
        method = _auto_method(base.problem(), base.policy.mesh)
    pol = base.policy
    state = _LadderState(
        method=method, precision=pol.precision, use_pallas=pol.use_pallas,
        inner_steps=pol.inner_steps, check_every=pol.check_every,
        schedule=base.schedule,
    )

    for rung in policy.ordered_rungs(health.verdict):
        if attempts >= policy.max_attempts:
            break
        if (policy.deadline_s is not None
                and time.monotonic() - t0 >= policy.deadline_s):
            break
        if not apply_rung(state, rung, base, policy):
            continue
        attempt_spec = base.replace(
            method=state.method,
            schedule=state.schedule,
            policy=dataclasses.replace(
                pol, precision=state.precision,
                use_pallas=state.use_pallas,
                inner_steps=state.inner_steps,
                check_every=state.check_every,
            ),
        )
        res = solve(attempt_spec)
        health = classify(res)
        attempts += 1
        rungs_run.append(rung)
        history.append((rung, health))
        if health.verdict in policy.accept:
            break
    return RecoveredSolve(res, health, attempts, tuple(rungs_run),
                          tuple(history))
