"""RecoveryPolicy: the ordered, bounded fallback ladder for failed solves.

The classic entropic-OT fix ladder (Cuturi, arXiv 1306.0895) — switch the
iteration to the log domain, raise eps — extended with the execution
degradations this stack actually has: precision escalation (bf16 factor
storage back to f32), dropping the fused megakernel to the per-iteration
XLA plan, and cold-restarting away from suspect warm potentials. A
:class:`RecoveryPolicy` names WHICH rungs may run, in WHAT order, and the
attempt/deadline budget; the executors live in
:mod:`repro.resilience.ladder` (core ``solve``) and
:class:`~repro.serving.service.OTService` (pre-planned serving runners).

Rung semantics are CUMULATIVE: each executed rung adds its degradation on
top of the previous ones (log domain + f32 + ...), so the ladder walks a
monotone sequence of increasingly conservative configurations rather than
trying each fix in isolation. Rungs that do not apply to the failing
solve (already log-domain; geometry pins its kernel to one eps; already
per-iteration) are skipped without consuming an attempt. Every recovery
attempt discards warm-start potentials — a retry must never inherit the
state that may have caused the failure — which makes the dedicated
``cold_restart`` rung the "retry the SAME configuration, cold" step; the
executors pull it to the front when the verdict is
``poisoned_warm_start`` (that failure is BY DEFINITION fixed by
discarding state, not by changing domain).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from .health import VERDICTS

__all__ = ["RUNGS", "RecoveryPolicy"]

# canonical order: cheapest numerically-targeted fix first, the paper-/
# Cuturi-classic log-domain switch, then precision, then eps escalation
# (annealed back down so the answer is still AT the requested eps), then
# execution-plan conservatism, then a bare cold retry
RUNGS: Tuple[str, ...] = (
    "log_domain",       # scaling -> log-domain twin of the method
    "precision_f32",    # bf16 factor storage -> full f32
    "raise_eps",        # EpsSchedule from eps*eps_scale, annealed back down
    "per_iteration",    # drop megakernel/fused plan -> per-iteration XLA
    "cold_restart",     # same configuration, warm potentials discarded
)


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """Bounded fallback ladder attached to a :class:`SolveSpec` (and to
    :class:`~repro.serving.service.OTService`).

    ``rungs``
        ordered subset of :data:`RUNGS` the executor may climb.
    ``max_attempts``
        TOTAL solve attempts including the original one (so
        ``max_attempts=1`` classifies but never retries).
    ``deadline_s``
        optional wall-clock budget for the whole ladder; checked between
        attempts (an in-flight solve is never interrupted).
    ``eps_scale``
        the ``raise_eps`` rung anneals from ``eps * eps_scale`` back down
        to the requested eps through the standard
        :class:`~repro.core.api.EpsSchedule` warm-start semantics.
    ``accept``
        verdicts treated as terminal success. The default accepts
        ``maxed_out``: a finite budget-capped partial solve is today's
        normal ``converged=False`` outcome and climbing further buys
        convergence speed, not safety. Narrow to ``("ok",)`` to make the
        ladder chase convergence itself.
    """

    rungs: Tuple[str, ...] = RUNGS
    max_attempts: int = 4
    deadline_s: Optional[float] = None
    eps_scale: float = 10.0
    accept: Tuple[str, ...] = ("ok", "maxed_out")

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(
                f"max_attempts must be >= 1, got {self.max_attempts}")
        if self.eps_scale <= 1.0:
            raise ValueError(
                f"eps_scale must be > 1 (raise eps), got {self.eps_scale}")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError(
                f"deadline_s must be positive, got {self.deadline_s}")
        unknown = [r for r in self.rungs if r not in RUNGS]
        if unknown:
            raise ValueError(
                f"unknown recovery rungs {unknown}; expected a subset of "
                f"{RUNGS}")
        if len(set(self.rungs)) != len(self.rungs):
            raise ValueError(f"duplicate rungs in {self.rungs}")
        bad = [v for v in self.accept if v not in VERDICTS]
        if bad:
            raise ValueError(
                f"accept names unknown verdicts {bad}; expected a subset "
                f"of {VERDICTS}")
        if not self.accept:
            raise ValueError("accept must name at least one verdict")

    def ordered_rungs(self, first_verdict: str) -> Tuple[str, ...]:
        """The climb order for a failure with ``first_verdict``: a
        poisoned warm start pulls ``cold_restart`` to the front (discard
        the suspect state before degrading anything else)."""
        if (first_verdict == "poisoned_warm_start"
                and "cold_restart" in self.rungs):
            rest = tuple(r for r in self.rungs if r != "cold_restart")
            return ("cold_restart",) + rest
        return self.rungs
