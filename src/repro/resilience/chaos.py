"""Deterministic fault injection for the resilience test matrix.

One seeded :class:`ChaosInjector` drives every fault class the recovery
stack must absorb:

* **NaN/Inf rows in features or weights** — the corruption lands at
  request-construction time so an exact repeat of a corrupted pair
  carries the SAME fingerprint (that is what lets the service quarantine
  repeat offenders instead of re-paying a full ladder per repeat).
* **Forced runner exceptions** — a hook the service calls right before
  the jitted megabatch dispatch; raising there simulates a device/
  runtime fault and must degrade to per-request recovery, never to an
  unhandled exception.
* **Clock skew** — a bounded deterministic jitter wrapped around the
  injected service clock, stressing the admission queue's max-wait aging
  (a skewed ``now`` must not wedge groups or crash ``pop_due``).
* **Warm-cache poisoning** — raw insertion of non-finite potentials
  (``store(..., validate=False)``), simulating a corrupted snapshot or a
  cache written by a pre-validation build; the get-side validation must
  evict them and the request must cold-solve.

Everything is a pure function of ``ChaosSpec.seed`` and call order, so a
chaos run is replayable and its expected counters can be asserted
exactly (the ``--chaos --strict`` lane of ``launch/ot_service``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import numpy as np

__all__ = ["ChaosSpec", "ChaosInjector"]

FAULT_KINDS = ("nan_feature", "inf_feature", "nan_weight")


@dataclasses.dataclass(frozen=True)
class ChaosSpec:
    """Knobs for one deterministic fault campaign."""

    seed: int = 0
    nan_feature_frac: float = 0.15   # pool fraction with a NaN feature row
    inf_feature_frac: float = 0.05   # pool fraction with an +inf feature row
    nan_weight_frac: float = 0.10    # pool fraction with a NaN weight entry
    runner_fault_frac: float = 0.05  # dispatches that raise in the runner
    clock_skew_s: float = 0.0        # max |skew| added per clock read

    def __post_init__(self):
        total = (self.nan_feature_frac + self.inf_feature_frac
                 + self.nan_weight_frac)
        if total > 1.0:
            raise ValueError(
                f"fault fractions sum to {total} > 1; they partition the "
                "pool")


class ChaosInjector:
    """Seeded fault source (see module docstring). All randomness flows
    through one ``default_rng(seed)``, so a given spec + call order
    replays identically."""

    def __init__(self, spec: ChaosSpec):
        self.spec = spec
        self.rng = np.random.default_rng(spec.seed)
        self.injected: Dict[str, int] = {k: 0 for k in FAULT_KINDS}
        self.runner_faults = 0
        self.clock_reads = 0

    # -- data corruption ----------------------------------------------------

    def assign_faults(self, n_pool: int) -> Tuple[str, ...]:
        """Deterministic fault class per pool index ("" = healthy):
        fractions of the pool get each corruption, shuffled so fault
        classes interleave across size classes."""
        kinds = []
        for kind, frac in (("nan_feature", self.spec.nan_feature_frac),
                           ("inf_feature", self.spec.inf_feature_frac),
                           ("nan_weight", self.spec.nan_weight_frac)):
            kinds += [kind] * int(round(frac * n_pool))
        kinds += [""] * (n_pool - len(kinds))
        self.rng.shuffle(kinds)
        return tuple(kinds)

    def corrupt_features(self, xi: np.ndarray, kind: str) -> np.ndarray:
        """Overwrite one feature row with NaN or +inf."""
        xi = np.array(xi, np.float32, copy=True)
        row = int(self.rng.integers(xi.shape[0]))
        xi[row] = np.nan if kind == "nan_feature" else np.inf
        self.injected[kind] += 1
        return xi

    def corrupt_weights(self, a: np.ndarray) -> np.ndarray:
        a = np.array(a, np.float32, copy=True)
        a[int(self.rng.integers(a.shape[0]))] = np.nan
        self.injected["nan_weight"] += 1
        return a

    # -- runtime faults -----------------------------------------------------

    def fault_hook(self) -> Callable:
        """A hook for ``OTService(chaos_hook=...)``: raises on a
        ``runner_fault_frac`` Bernoulli draw per dispatch."""

        def hook(shape, batch):
            if self.rng.random() < self.spec.runner_fault_frac:
                self.runner_faults += 1
                raise RuntimeError(
                    f"chaos: injected runner fault (cell {shape} B={batch})")

        return hook

    def skewed(self, clock: Callable[[], float]) -> Callable[[], float]:
        """Wrap a clock with bounded uniform jitter per read (can run
        backwards between reads — exactly the skew admission aging must
        survive)."""
        skew = self.spec.clock_skew_s
        if skew <= 0:
            return clock

        def read() -> float:
            self.clock_reads += 1
            return clock() + float(self.rng.uniform(-skew, skew))

        return read

    # -- cache poisoning ----------------------------------------------------

    def poison_warm_cache(self, cache, support_key: bytes, full_key: bytes,
                          n: int, m: int) -> None:
        """Insert NaN potentials under a real request's fingerprint,
        bypassing the put-side validation (a corrupted snapshot)."""
        f = np.full((n,), np.nan, np.float32)
        g = np.full((m,), np.nan, np.float32)
        cache.store(support_key, full_key, f, g, validate=False)

    def stats(self) -> Dict[str, int]:
        return dict(self.injected, runner_faults=self.runner_faults,
                    clock_reads=self.clock_reads)
