"""Resilient solves: failure classification, recovery ladders, chaos.

  health — SolveHealth verdicts (ok / maxed_out / diverged /
           poisoned_warm_start) classified host-side from any solve
  policy — RecoveryPolicy: the ordered, bounded, cumulative fallback
           ladder (log domain, f32, raise-eps annealing, per-iteration
           plan, cold restart)
  ladder — solve_with_recovery: the ladder executor for the core
           ``solve(spec)`` surface (serving has its own pre-planned twin)
  chaos  — deterministic seeded fault injection (NaN/Inf rows, runner
           exceptions, clock skew, warm-cache poisoning) for the
           ``ot_service --chaos`` lane and the test matrix
"""
from .chaos import ChaosInjector, ChaosSpec
from .health import VERDICTS, SolveHealth, classify, warm_is_poisoned
from .ladder import LOG_TWIN, RecoveredSolve, solve_with_recovery
from .policy import RUNGS, RecoveryPolicy

__all__ = [
    "ChaosInjector",
    "ChaosSpec",
    "LOG_TWIN",
    "RUNGS",
    "RecoveredSolve",
    "RecoveryPolicy",
    "SolveHealth",
    "VERDICTS",
    "classify",
    "solve_with_recovery",
    "warm_is_poisoned",
]
