"""Failure classification: one cheap host-side verdict per solve.

The solvers already surface a structured ``diverged`` flag
(:class:`~repro.core.sinkhorn.SinkhornResult`), but every caller was left
to interpret it alone — and a diverged solve whose warm start was itself
poisoned (NaN potentials inherited from an earlier blow-up) looks exactly
like a fresh numerical failure unless somebody checks the init. This
module is the shared vocabulary:

``ok``
    converged with finite marginal error and cost.
``maxed_out``
    hit the iteration budget but everything is finite — the result is a
    USABLE partial solve (today's ``converged=False`` semantics).
``diverged``
    the iteration blew up: non-finite marginal error or dual value
    (scaling-domain over/underflow at small eps, signed-Nystrom failure,
    NaN inputs).
``poisoned_warm_start``
    diverged AND the warm-start potentials handed to the solve were
    themselves corrupt (NaN/+inf anywhere, or ``-inf`` on an atom that
    carries mass). The distinction matters for recovery: a poisoned warm
    start is fixed by a cold restart, not by changing solver domain.

Classification is HOST-side on purpose: verdicts drive Python-level
control flow (retry ladders, cache eviction, refusals), so they pull the
scalar diagnostics once and never trace. Call it on concrete results
only — inside ``jit`` use ``SinkhornResult.diverged``, which stays a lazy
array property.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

__all__ = ["VERDICTS", "SolveHealth", "classify", "warm_is_poisoned"]

VERDICTS: Tuple[str, ...] = (
    "ok", "maxed_out", "diverged", "poisoned_warm_start",
)


@dataclasses.dataclass(frozen=True)
class SolveHealth:
    """One solve's verdict plus the scalar diagnostics it was read from."""

    verdict: str
    marginal_err: float
    cost: float
    n_iter: int
    converged: bool

    @property
    def ok(self) -> bool:
        return self.verdict == "ok"

    @property
    def finite(self) -> bool:
        """True when the result is safe to hand to a caller (converged or
        a usable finite partial solve)."""
        return self.verdict in ("ok", "maxed_out")

    @property
    def failed(self) -> bool:
        return not self.finite

    def describe(self) -> str:
        return (f"{self.verdict} (err={self.marginal_err:.3g} "
                f"cost={self.cost:.6g} iters={self.n_iter})")


def warm_is_poisoned(f0: Optional[np.ndarray], g0: Optional[np.ndarray],
                     a: Optional[np.ndarray] = None,
                     b: Optional[np.ndarray] = None) -> bool:
    """Were these warm-start potentials corrupt before the solve ran?

    NaN or ``+inf`` anywhere is poison. ``-inf`` is poison only on atoms
    that carry mass: zero-weight atoms legitimately sit at ``f = -inf``
    in the log domain (the exactness contract for bucket padding), so a
    blanket finiteness check would misclassify every padded solve.
    Without weights, ``-inf`` counts as poison (conservative).
    """
    for pot, w in ((f0, a), (g0, b)):
        if pot is None:
            continue
        x = np.asarray(pot, np.float64)
        if np.isnan(x).any() or np.isposinf(x).any():
            return True
        neg = np.isneginf(x)
        if not neg.any():
            continue
        if w is None:
            return True
        if neg[np.asarray(w, np.float64) > 0].any():
            return True
    return False


def classify(res, *, f_init: Optional[np.ndarray] = None,
             g_init: Optional[np.ndarray] = None,
             a: Optional[np.ndarray] = None,
             b: Optional[np.ndarray] = None) -> SolveHealth:
    """Verdict for ONE concrete (unbatched) solver result.

    ``res`` is anything with scalar ``marginal_err``/``cost``/``n_iter``/
    ``converged`` fields (a :class:`~repro.core.sinkhorn.SinkhornResult`
    or an unpadded lane of one). Pass the warm-start potentials the solve
    was LAUNCHED with (plus the weights, so legitimate ``-inf`` entries
    on dead atoms are not misread) to enable the
    ``poisoned_warm_start`` verdict.
    """
    err = float(np.asarray(res.marginal_err))
    cost = float(np.asarray(res.cost))
    n_iter = int(np.asarray(res.n_iter))
    converged = bool(np.asarray(res.converged))
    if np.isfinite(err) and np.isfinite(cost):
        verdict = "ok" if converged else "maxed_out"
    elif warm_is_poisoned(f_init, g_init, a, b):
        verdict = "poisoned_warm_start"
    else:
        verdict = "diverged"
    return SolveHealth(verdict=verdict, marginal_err=err, cost=cost,
                       n_iter=n_iter, converged=converged)
