"""OT-as-a-service: a persistent request-driven front end for the batched
solver engine.

The pieces (each its own module, composable and unit-testable):

* :class:`~repro.serving.runner_cache.RunnerCache` — pre-planned,
  warm-up-executed jitted runners per ``(OTBatchShape, B)`` bucket cell:
  steady-state requests never trace or compile.
* :class:`~repro.serving.admission.AdmissionQueue` — continuous batching
  of ragged requests into bucket-padded megabatches under a
  max-batch/max-wait policy.
* :class:`~repro.serving.warmstart.WarmStartCache` — fingerprinted
  potentials re-served through the engine's ``f_init``/``g_init`` path
  for repeat (exact) and near-repeat (good-init) pairs.

Usage::

    svc = OTService(eps=0.05, method="log_factored", max_batch=8,
                    max_wait=0.002)
    svc.warmup([(200, 150, 64)])          # pre-plan the expected buckets
    t = svc.submit(problem)               # -> Ticket
    svc.pump()                           # dispatch due megabatches
    svc.drain()                          # flush everything pending
    t.result                             # per-request unpadded SinkhornResult

``submit``/``pump``/``drain`` are synchronous and single-threaded by
design: the event loop (a driver script, an RPC handler, the open-loop
benchmark) owns scheduling, the service owns batching and caching. All
time is injected (``clock=``), so tests drive the max-wait policy with a
fake clock.
"""
from __future__ import annotations

import dataclasses
import time
from collections import Counter, OrderedDict
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..configs.shapes import OTBatchShape, ot_batch_bucket
from ..core.api import (
    BatchedSinkhorn,
    OTProblem,
    engine_cache_info,
    get_engine,
)
from ..core.sinkhorn import SinkhornResult
from ..resilience.health import SolveHealth, classify
from ..resilience.ladder import LOG_METHODS, LOG_TWIN
from ..resilience.policy import RecoveryPolicy
from .admission import AdmissionQueue, QueueFullError
from .runner_cache import RunnerCache
from .warmstart import WarmStartCache

__all__ = ["Ticket", "OTService", "Refusal", "QuarantineError",
           "QueueFullError"]


class QuarantineError(RuntimeError):
    """Submit-time refusal of a quarantined repeat-offender fingerprint
    (a request that has already exhausted the recovery ladder
    ``quarantine_after`` times — re-admitting it would burn a full ladder
    of solves for a known-unsolvable input)."""


@dataclasses.dataclass(frozen=True)
class Refusal:
    """Structured terminal refusal attached to a :class:`Ticket` whose
    request could not be recovered: the caller gets a reason and the last
    attempt's health instead of a NaN cost."""

    reason: str                      # "recovery_exhausted" | "runner_fault"
    detail: str
    health: Optional[SolveHealth]    # last attempt's verdict (if any ran)


# -- host-side padding/unpadding ---------------------------------------------
#
# The dispatch path deliberately stays in NUMPY until the single jitted
# runner call: every jnp slice/concat on a new shape eagerly compiles a
# tiny XLA executable (~tens of ms on CPU the first time) and pays a
# dispatch round trip every time after — measured to dominate per-request
# latency when the glue ran through jnp. Host-side padding is exact (same
# replicate/zero-fill semantics as core.api._pad_rows) and costs
# microseconds.


def _pad_np(arr, n_pad: int, *, replicate: bool,
            fill: float = 0.0) -> np.ndarray:
    x = np.asarray(arr)
    pad = n_pad - x.shape[0]
    if pad <= 0:
        return x
    if replicate:
        tail = np.broadcast_to(x[-1:], (pad,) + x.shape[1:])
    else:
        tail = np.full((pad,) + x.shape[1:], fill, x.dtype)
    return np.concatenate([x, tail], axis=0)


def _pad_kernel_np(ka: np.ndarray, kb: np.ndarray, shape: OTBatchShape,
                   quadratic: bool) -> Tuple[np.ndarray, np.ndarray]:
    if quadratic:
        ka = _pad_np(ka, shape.n_pad, replicate=True)
        ka = _pad_np(ka.T, shape.m_pad, replicate=True).T
        return ka, ka
    return (_pad_np(ka, shape.n_pad, replicate=True),
            _pad_np(kb, shape.m_pad, replicate=True))


def _unpad_np(host: Dict[str, np.ndarray], j: int, n: int,
              m: int) -> SinkhornResult:
    """Slice request ``j`` out of a batch result already pulled to host."""
    return SinkhornResult(
        u=host["u"][j, :n], v=host["v"][j, :m],
        f=host["f"][j, :n], g=host["g"][j, :m],
        cost=host["cost"][j], n_iter=host["n_iter"][j],
        marginal_err=host["marginal_err"][j],
        converged=host["converged"][j],
    )


class Ticket:
    """Handle for one submitted request; filled in by the dispatch path.

    A ticket always terminates in exactly one of two states: ``result``
    (a finite-or-classified solve — read ``health`` for the verdict) or
    ``refusal`` (the structured no-NaN failure contract when the recovery
    ladder is exhausted). ``attempts``/``rungs`` record the recovery work
    the request consumed."""

    __slots__ = ("seq", "t_submit", "t_done", "result", "warm_hit",
                 "warm_exact", "health", "refusal", "attempts", "rungs")

    def __init__(self, seq: int, t_submit: float):
        self.seq = seq
        self.t_submit = t_submit
        self.t_done: Optional[float] = None
        self.result: Optional[SinkhornResult] = None
        self.warm_hit = False
        self.warm_exact = False
        self.health: Optional[SolveHealth] = None
        self.refusal: Optional[Refusal] = None
        self.attempts = 1            # solve attempts consumed (>= 1 once run)
        self.rungs: Tuple[str, ...] = ()

    @property
    def done(self) -> bool:
        return self.result is not None or self.refusal is not None

    @property
    def latency(self) -> float:
        if self.t_done is None:
            raise RuntimeError("request not served yet")
        return self.t_done - self.t_submit


@dataclasses.dataclass
class _Admitted:
    """One admitted request: host-side kernel data + warm-start state +
    its ticket."""

    ticket: Ticket
    ka: np.ndarray
    kb: np.ndarray
    a: np.ndarray
    b: np.ndarray
    n: int
    m: int
    support_key: bytes
    full_key: bytes
    f0: Optional[np.ndarray]      # warm potentials (unpadded) or None
    g0: Optional[np.ndarray]
    problem: Optional[OTProblem] = None   # kept only when recovery may
    # need to re-derive kernel data under a different method/eps


class OTService:
    """Persistent OT solver service over the batched vmapped engine.

    Solver knobs mirror :class:`~repro.core.api.BatchedSinkhorn` (one
    service per solver configuration; the engine itself comes from the
    bounded :func:`~repro.core.api.get_engine` LRU so service and
    ``solve_many`` callers share executables and accounting). Serving
    knobs:

    ``max_batch``/``max_wait``
        admission policy (see :class:`AdmissionQueue`). Megabatches are
        additionally padded UP to power-of-two batch buckets
        (``ot_batch_bucket``) by replicating a real request lane — exact,
        the duplicate lanes are discarded — so the number of compiled
        runners stays at O(buckets x log max_batch).
    ``runner_capacity``
        LRU cap on live compiled runners.
    ``warm_capacity``/``warm_quant``/``warm_starts``
        warm-start cache size, fingerprint quantization, and a master
        switch (off = every request cold-starts; the A/B knob the
        benchmark uses).
    ``clock``
        time source (injectable for tests; defaults to
        ``time.monotonic``).

    Resilience knobs (all off by default — the happy path is unchanged):

    ``recovery``
        a :class:`~repro.resilience.policy.RecoveryPolicy`. When set,
        every dispatched lane is health-classified and failed requests
        climb the recovery ladder through PRE-PLANNED batch-1 rung
        runners (one small ``RunnerCache`` per cumulative rung
        configuration — retries never trigger a retrace storm; call
        :meth:`warmup_recovery` alongside :meth:`warmup` to pay all rung
        compiles up front). A request that exhausts the ladder gets a
        structured ``Refusal``, never a NaN cost.
    ``max_depth``
        admission-queue depth bound; ``submit`` raises
        :class:`QueueFullError` (load shedding) past it.
    ``quarantine_after``
        fingerprints that exhaust the ladder this many times are
        quarantined: later submits raise :class:`QuarantineError`
        instead of burning another full ladder.
    ``chaos_hook``
        ``hook(shape, batch)`` called before every main-path runner
        dispatch — the fault-injection seam
        (:meth:`repro.resilience.chaos.ChaosInjector.fault_hook`).
        Exceptions it raises are handled exactly like runner faults.
    """

    def __init__(
        self,
        *,
        eps: float,
        method: str = "log_factored",
        tol: float = 1e-6,
        max_iter: int = 2000,
        momentum: float = 1.0,
        use_pallas: Optional[bool] = None,
        inner_steps: Optional[int] = None,
        check_every: Optional[int] = None,
        precision: str = "highest",
        max_batch: int = 8,
        max_wait: float = 0.005,
        runner_capacity: int = 32,
        warm_capacity: int = 1024,
        warm_quant: float = 1e-6,
        warm_starts: bool = True,
        clock: Callable[[], float] = time.monotonic,
        recovery: Optional[RecoveryPolicy] = None,
        max_depth: Optional[int] = None,
        quarantine_after: int = 3,
        quarantine_capacity: int = 1024,
        chaos_hook: Optional[Callable[[OTBatchShape, int], None]] = None,
    ):
        self.engine = get_engine(
            eps=eps, method=method, tol=tol, max_iter=max_iter,
            momentum=momentum, use_pallas=use_pallas,
            inner_steps=inner_steps, check_every=check_every,
            precision=precision,
        )
        self.clock = clock
        self.max_batch = max_batch
        self.runners = RunnerCache(self.engine, capacity=runner_capacity,
                                   max_batch=max_batch)
        self.queue: AdmissionQueue[_Admitted] = AdmissionQueue(
            max_batch=max_batch, max_wait=max_wait, max_depth=max_depth)
        self.warm = WarmStartCache(capacity=warm_capacity, quant=warm_quant)
        self.warm_starts = warm_starts
        # -- resilience state ------------------------------------------------
        if recovery is not None and not isinstance(recovery, RecoveryPolicy):
            raise TypeError(
                f"recovery must be a RecoveryPolicy, got {type(recovery)}")
        if quarantine_after < 1:
            raise ValueError(
                f"quarantine_after must be >= 1, got {quarantine_after}")
        self.recovery = recovery
        self.quarantine_after = quarantine_after
        self.quarantine_capacity = quarantine_capacity
        self.chaos_hook = chaos_hook
        # full_key -> count of ladder exhaustions (bounded LRU)
        self._quarantine: "OrderedDict[bytes, int]" = OrderedDict()
        # cumulative rung config -> batch-1 RunnerCache (engines built
        # DIRECTLY, not through get_engine: recovery traffic must not
        # churn the global engine LRU the happy path lives in)
        self._rung_caches: Dict[Tuple, RunnerCache] = {}
        # served-request accounting (feeds stats() and the benchmark)
        self.served = 0
        self.batches = 0
        self.iters_warm = 0          # total solver iterations, warm-hit reqs
        self.iters_cold = 0
        self.served_warm = 0
        self.served_cold = 0
        # resilience accounting
        self.recovered = 0           # failed requests the ladder rescued
        self.refused = 0             # ladder exhausted -> structured Refusal
        self.runner_faults = 0       # runner/chaos exceptions absorbed
        self.quarantine_rejects = 0  # submits refused at quarantine
        self.recovery_attempts = 0   # total extra solves the ladder ran
        self.rung_hist: Counter = Counter()    # winning rung -> count
        self.health_hist: Counter = Counter()  # first-attempt verdicts

    # -- request path --------------------------------------------------------

    def submit(self, problem: Union[OTProblem, "SolveSpec"],
               now: Optional[float] = None) -> Ticket:
        """Admit one request: derive its kernel data and bucket cell, look
        up a warm start, enqueue. Returns the request's :class:`Ticket`
        (filled when a ``pump``/``drain`` dispatches its megabatch).

        Accepts a :class:`~repro.core.spec.SolveSpec` (the unified
        record): its geometry/weights become the request and its solver-
        facing fields are VALIDATED against this service's engine — a
        spec asking for a different eps/tol/max_iter/momentum than the
        service was built with is an error, not a silent reconfigure
        (services are per-configuration; the spec's execution policy and
        method are the service's to choose)."""
        from ..core.spec import SolveSpec
        if isinstance(problem, SolveSpec):
            spec = problem
            e = self.engine
            mismatches = [
                f"{name}: spec={got} != service={want}"
                for name, got, want in (
                    ("eps", float(spec.eps), float(e.eps)),
                    ("tol", float(spec.tol), float(e.tol)),
                    ("max_iter", int(spec.max_iter), int(e.max_iter)),
                    ("momentum", float(spec.momentum), float(e.momentum)),
                )
                if got != want
            ]
            if spec.schedule is not None:
                mismatches.append("schedule: serving solves are "
                                  "single-stage (no eps annealing)")
            if mismatches:
                raise ValueError(
                    "SolveSpec incompatible with this service's engine "
                    "(run one service per configuration): "
                    + "; ".join(mismatches))
            problem = spec.problem()
        if float(problem.eps) != float(self.engine.eps):
            raise ValueError(
                f"request declares eps={problem.eps} but this service "
                f"solves at eps={self.engine.eps}; run one service per eps"
            )
        now = self.clock() if now is None else now
        ticket = Ticket(self.queue.admitted, now)
        ka, kb = self.engine.kernel_data(problem)
        shape = self.engine.batch_shape(ka, kb)
        # everything downstream of here is host-side numpy (see the
        # module note above _pad_np); float32 is the serving dtype — the
        # runners are compiled for it, so admitting a float64 request
        # must not retrace them
        ka = np.asarray(ka, np.float32)
        kb = np.asarray(kb, np.float32)
        a = np.asarray(problem.a, np.float32)
        b = np.asarray(problem.b, np.float32)
        f0 = g0 = None
        support_key = full_key = b""
        if self.warm_starts or self.recovery is not None:
            # recovery needs the fingerprint too (quarantine is keyed on
            # it), so compute keys even when warm starts are disabled
            support_key, full_key = self.warm.keys_for(ka, kb, a, b)
        if self.recovery is not None:
            count = self._quarantine.get(full_key, 0)
            if count >= self.quarantine_after:
                self._quarantine.move_to_end(full_key)
                self.quarantine_rejects += 1
                raise QuarantineError(
                    f"request fingerprint exhausted the recovery ladder "
                    f"{count}x and is quarantined (quarantine_after="
                    f"{self.quarantine_after})")
        if self.warm_starts:
            hit = self.warm.lookup(support_key, full_key)
            if hit is not None:
                f0, g0 = hit.f, hit.g
                ticket.warm_hit = True
                ticket.warm_exact = hit.exact
        adm = _Admitted(
            ticket=ticket, ka=ka, kb=kb, a=a, b=b,
            n=a.shape[0], m=b.shape[0],
            support_key=support_key, full_key=full_key, f0=f0, g0=g0,
            problem=problem if self.recovery is not None else None,
        )
        self.queue.add(shape, adm, now)
        return ticket

    def pump(self, now: Optional[float] = None, force: bool = False) -> int:
        """Dispatch every due megabatch; returns requests completed."""
        now = self.clock() if now is None else now
        done = 0
        for shape, items in self.queue.pop_due(now, force=force):
            done += self._dispatch(shape, items)
        return done

    def drain(self) -> int:
        """Flush everything pending regardless of age; returns requests
        completed."""
        return self.pump(force=True)

    def solve_many(self, problems: Sequence[OTProblem]) -> List[SinkhornResult]:
        """Convenience batch entry: submit all, drain, return results in
        submission order (the serving twin of ``BatchedSinkhorn.solve_many``)."""
        tickets = [self.submit(p) for p in problems]
        self.drain()
        return [t.result for t in tickets]

    def next_deadline(self) -> Optional[float]:
        return self.queue.next_deadline()

    def pending(self) -> int:
        return len(self.queue)

    # -- planning ------------------------------------------------------------

    def warmup(
        self,
        cells: Iterable[Union[OTBatchShape, Tuple[int, int, int]]],
        batches: Optional[Iterable[int]] = None,
    ) -> int:
        """Pre-plan runners for the expected traffic shapes.

        ``cells`` are :class:`OTBatchShape`\\ s or raw ``(n, m, r)``
        support triples (bucketed here); every batch bucket up to
        ``max_batch`` is compiled per cell unless ``batches`` narrows it.
        Returns the number of runners built.
        """
        shapes = []
        for c in cells:
            if isinstance(c, OTBatchShape):
                shapes.append(c)
            else:
                n, m, r = c
                shapes.append(
                    OTBatchShape.for_quadratic(n, m)
                    if self.engine.method in self.engine._QUADRATIC
                    else OTBatchShape.for_problem(n, m, r)
                )
        return self.runners.warm(shapes, batches)

    # -- dispatch ------------------------------------------------------------

    def _dispatch(self, shape: OTBatchShape, items: List[_Admitted]) -> int:
        b_real = len(items)
        b_pad = ot_batch_bucket(b_real, self.max_batch)
        # pad dead lanes by REPLICATING a real request: the duplicates
        # converge exactly like their source (no all-zero-weight lane to
        # NaN-poison or stall the batched while_loop) and are discarded
        lanes = items + [items[-1]] * (b_pad - b_real)
        quadratic = self.engine.method in self.engine._QUADRATIC
        kas, kbs, aws, bws, f0s, g0s = [], [], [], [], [], []
        for it in lanes:
            ka, kb = _pad_kernel_np(it.ka, it.kb, shape, quadratic)
            kas.append(ka)
            kbs.append(kb)
            aws.append(_pad_np(it.a, shape.n_pad, replicate=False))
            bws.append(_pad_np(it.b, shape.m_pad, replicate=False))
            if it.f0 is None:        # zeros == the cold default init
                f0s.append(np.zeros((shape.n_pad,), np.float32))
                g0s.append(np.zeros((shape.m_pad,), np.float32))
            else:
                f0s.append(_pad_np(it.f0, shape.n_pad, replicate=False))
                g0s.append(_pad_np(it.g0, shape.m_pad, replicate=False))
        runner = self.runners.get(shape, b_pad)
        try:
            if self.chaos_hook is not None:
                self.chaos_hook(shape, b_pad)
            res = runner.run(np.stack(kas), np.stack(kbs), np.stack(aws),
                             np.stack(bws), np.stack(f0s), np.stack(g0s))
            # one device->host pull for the whole megabatch; per-request
            # unpadding is then pure numpy slicing
            host = {k: np.asarray(getattr(res, k))
                    for k in ("u", "v", "f", "g", "cost", "n_iter",
                              "marginal_err", "converged")}
        except Exception as exc:
            # infrastructure fault (chaos injection, a runner raising):
            # with recovery enabled the megabatch is absorbed — every
            # request retries solo through the ladder, starting with a
            # cold re-run of the base config — otherwise it propagates
            if self.recovery is None:
                raise
            self.runner_faults += 1
            for it in items:
                self._recover_one(it, None, fault=exc)
            self.served += b_real
            self.batches += 1
            return b_real
        t_done = self.clock()
        for j, it in enumerate(items):
            r = _unpad_np(host, j, it.n, it.m)
            h = classify(r, f_init=it.f0, g_init=it.g0, a=it.a, b=it.b)
            self.health_hist[h.verdict] += 1
            it.ticket.health = h
            if self.recovery is not None and \
                    h.verdict not in self.recovery.accept:
                self._recover_one(it, h)
                continue
            it.ticket.result = r
            it.ticket.t_done = t_done
            if self.warm_starts:
                self.warm.store(it.support_key, it.full_key, r.f, r.g,
                                it.a, it.b)
            iters = int(r.n_iter)
            if it.ticket.warm_hit:
                self.served_warm += 1
                self.iters_warm += iters
            else:
                self.served_cold += 1
                self.iters_cold += iters
        self.served += b_real
        self.batches += 1
        return b_real

    # -- recovery ladder -----------------------------------------------------

    def _base_state(self) -> Dict[str, object]:
        e = self.engine
        return dict(method=e.method, precision=e.precision,
                    use_pallas=e.use_pallas, inner_steps=e.inner_steps,
                    check_every=e.check_every)

    @staticmethod
    def _cfg_key(state: Dict[str, object], eps: float) -> Tuple:
        return (state["method"], float(eps), state["precision"],
                state["use_pallas"], state["inner_steps"],
                state["check_every"])

    def _rung_cache(self, state: Dict[str, object],
                    eps: float) -> RunnerCache:
        """Batch-1 RunnerCache for one cumulative ladder configuration.
        The engine is built DIRECTLY (not via ``get_engine``) so recovery
        traffic never churns the global engine LRU; runner compiles are
        still one-time per (config, cell) and pre-payable through
        :meth:`warmup_recovery`."""
        key = self._cfg_key(state, eps)
        cache = self._rung_caches.get(key)
        if cache is None:
            engine = BatchedSinkhorn(
                eps=float(eps), method=state["method"],
                tol=self.engine.tol, max_iter=self.engine.max_iter,
                momentum=self.engine.momentum,
                use_pallas=state["use_pallas"],
                inner_steps=state["inner_steps"],
                check_every=state["check_every"],
                precision=state["precision"],
            )
            cache = self._rung_caches[key] = RunnerCache(
                engine, capacity=8, max_batch=1)
        return cache

    def _apply_rung(self, state: Dict[str, object], rung: str,
                    it: _Admitted, first_cold: bool,
                    any_applied: bool) -> Tuple[bool, Optional[float]]:
        """Mutate ``state`` for one rung; returns ``(applicable,
        stage_eps)``. Inapplicable rungs (already in that state, geometry
        can't support it) return False and consume no attempt. Rungs are
        CUMULATIVE: each later rung keeps the degradations before it."""
        if rung == "log_domain":
            twin = LOG_TWIN.get(state["method"])
            if twin is None or state["method"] in LOG_METHODS:
                return False, None
            state["method"] = twin
            return True, None
        if rung == "precision_f32":
            if state["precision"] == "highest":
                return False, None
            state["precision"] = "highest"
            return True, None
        if rung == "raise_eps":
            geom = it.problem.geometry if it.problem is not None else None
            if geom is None or not getattr(geom, "anneal_capable", False):
                return False, None
            return True, float(self.engine.eps) * self.recovery.eps_scale
        if rung == "per_iteration":
            if (state["use_pallas"] is False and state["inner_steps"] == 1
                    and state["check_every"] == 1):
                return False, None
            state.update(use_pallas=False, inner_steps=1, check_every=1)
            return True, None
        if rung == "cold_restart":
            # every recovery attempt already solves cold, so a bare
            # restart only adds information when nothing cold has run
            # yet: a poisoned/warm first attempt, or a runner fault
            return (not any_applied and not first_cold), None
        return False, None

    def _run_rung(self, state: Dict[str, object], it: _Admitted,
                  eps: float, f0: Optional[np.ndarray],
                  g0: Optional[np.ndarray]) -> SinkhornResult:
        """One solo solve of ``it`` under a ladder configuration, through
        that configuration's pre-planned batch-1 runner."""
        cache = self._rung_cache(state, eps)
        engine = cache.engine
        # re-derive kernel data under the rung's method/eps (log features
        # for the log twin, geometry rebuilt for a raised eps)
        ka, kb = engine.kernel_data(it.problem)
        ka = np.asarray(ka, np.float32)
        kb = np.asarray(kb, np.float32)
        shape = engine.batch_shape(ka, kb)
        quadratic = engine.method in engine._QUADRATIC
        pka, pkb = _pad_kernel_np(ka, kb, shape, quadratic)
        pa = _pad_np(it.a, shape.n_pad, replicate=False)
        pb = _pad_np(it.b, shape.m_pad, replicate=False)
        if f0 is None:
            pf = np.zeros((shape.n_pad,), np.float32)
            pg = np.zeros((shape.m_pad,), np.float32)
        else:
            pf = _pad_np(np.asarray(f0, np.float32), shape.n_pad,
                         replicate=False)
            pg = _pad_np(np.asarray(g0, np.float32), shape.m_pad,
                         replicate=False)
        runner = cache.get(shape, 1)
        res = runner.run(pka[None], pkb[None], pa[None], pb[None],
                         pf[None], pg[None])
        host = {k: np.asarray(getattr(res, k))
                for k in ("u", "v", "f", "g", "cost", "n_iter",
                          "marginal_err", "converged")}
        return _unpad_np(host, 0, it.n, it.m)

    def _attempt(self, state: Dict[str, object], it: _Admitted,
                 stage_eps: Optional[float]) -> SinkhornResult:
        if stage_eps is None:
            return self._run_rung(state, it, float(self.engine.eps),
                                  None, None)
        # raise_eps is TWO stages with warm handoff — the EpsSchedule
        # cascade semantics: solve cold at the raised (easy) eps, then
        # anneal back down to the service eps warm-started from the
        # stage-1 potentials. Non-finite stage-1 entries (legitimate
        # -inf on dead atoms) hand off as 0, the cold init for that atom.
        r1 = self._run_rung(state, it, stage_eps, None, None)
        f1 = np.asarray(r1.f)
        g1 = np.asarray(r1.g)
        f0 = np.where(np.isfinite(f1), f1, 0.0)
        g0 = np.where(np.isfinite(g1), g1, 0.0)
        return self._run_rung(state, it, float(self.engine.eps), f0, g0)

    def _recover_one(self, it: _Admitted, first_health: Optional[SolveHealth],
                     fault: Optional[Exception] = None) -> None:
        """Climb the recovery ladder for one failed request. Terminal:
        fills either ``ticket.result`` (+health) or ``ticket.refusal``."""
        pol = self.recovery
        ticket = it.ticket
        if first_health is not None:
            order = pol.ordered_rungs(first_health.verdict)
        else:
            # runner fault: nothing numerical happened — retry the base
            # config cold first, then the standard ladder
            order = ("cold_restart",) + tuple(
                r for r in pol.rungs if r != "cold_restart")
        deadline = (time.monotonic() + pol.deadline_s
                    if pol.deadline_s is not None else None)
        state = self._base_state()
        applied: List[str] = []
        attempts = 1                       # the failed batched attempt
        last_health = first_health
        stage: Optional[float] = None      # sticks once raise_eps applies
        for rung in order:
            if attempts >= pol.max_attempts:
                break
            if deadline is not None and time.monotonic() > deadline:
                break
            ok, stage_eps = self._apply_rung(
                state, rung, it, first_cold=(it.f0 is None and fault is None),
                any_applied=bool(applied))
            if not ok:
                continue
            if stage_eps is not None:
                # cumulative: later rungs keep the two-stage eps cascade
                stage = stage_eps
            applied.append(rung)
            attempts += 1
            self.recovery_attempts += 1
            try:
                r = self._attempt(state, it, stage)
            except Exception:
                self.runner_faults += 1
                continue
            h = classify(r, a=it.a, b=it.b)
            last_health = h
            if h.verdict in pol.accept:
                ticket.result = r
                ticket.health = h
                ticket.t_done = self.clock()
                ticket.attempts = attempts
                ticket.rungs = tuple(applied)
                if self.warm_starts:
                    self.warm.store(it.support_key, it.full_key, r.f, r.g,
                                    it.a, it.b)
                self.recovered += 1
                self.rung_hist[rung] += 1
                self.served_cold += 1
                self.iters_cold += int(r.n_iter)
                return
        # ladder exhausted: structured refusal, never a NaN result
        reason = "runner_fault" if (fault is not None and not applied) \
            else "recovery_exhausted"
        detail = (f"{type(fault).__name__}: {fault}" if fault is not None
                  else f"ladder exhausted after {attempts} attempts "
                       f"(rungs tried: {applied or ['none applicable']})")
        ticket.refusal = Refusal(reason=reason, detail=detail,
                                 health=last_health)
        ticket.health = last_health
        ticket.t_done = self.clock()
        ticket.attempts = attempts
        ticket.rungs = tuple(applied)
        self.refused += 1
        count = self._quarantine.get(it.full_key, 0) + 1
        self._quarantine[it.full_key] = count
        self._quarantine.move_to_end(it.full_key)
        while len(self._quarantine) > self.quarantine_capacity:
            self._quarantine.popitem(last=False)

    def warmup_recovery(
        self,
        cells: Iterable[Union[OTBatchShape, Tuple[int, int, int]]],
        *,
        anneal: bool = True,
    ) -> int:
        """Pre-plan the batch-1 rung runners every ladder prefix can reach
        for the expected traffic cells — the recovery twin of
        :meth:`warmup`, and what keeps retries free of retrace storms
        (the chaos CI gate counts post-warmup compiles across rung caches
        too). ``anneal=False`` skips the raised-eps configs when no
        traffic geometry is anneal-capable. Returns runners built."""
        if self.recovery is None:
            return 0
        shapes = []
        for c in cells:
            if isinstance(c, OTBatchShape):
                shapes.append(c)
            else:
                n, m, r = c
                shapes.append(
                    OTBatchShape.for_quadratic(n, m)
                    if self.engine.method in self.engine._QUADRATIC
                    else OTBatchShape.for_problem(n, m, r)
                )
        base_eps = float(self.engine.eps)
        raised_eps = base_eps * self.recovery.eps_scale
        # walk the cumulative ladder, collecting every state a recovery
        # could solve under (cold_restart = the base state)
        states = [self._base_state()]
        state = self._base_state()
        for rung in self.recovery.rungs:
            if rung == "log_domain":
                twin = LOG_TWIN.get(state["method"])
                if twin is None or state["method"] in LOG_METHODS:
                    continue
                state["method"] = twin
            elif rung == "precision_f32":
                if state["precision"] == "highest":
                    continue
                state["precision"] = "highest"
            elif rung == "per_iteration":
                state.update(use_pallas=False, inner_steps=1, check_every=1)
            else:           # raise_eps / cold_restart don't mutate state
                continue
            states.append(dict(state))
        # the raised-eps stage composes with EVERY cumulative state (a
        # later rung keeps the eps cascade), so warm each state at both
        # eps levels
        configs = [(st, base_eps) for st in states]
        if anneal and "raise_eps" in self.recovery.rungs:
            configs += [(st, raised_eps) for st in states]
        built = 0
        for st, eps in configs:
            built += self._rung_cache(st, eps).warm(shapes, batches=(1,))
        return built

    # -- accounting ----------------------------------------------------------

    def stats(self) -> Dict[str, object]:
        """All serving-path cache/throughput counters in one snapshot:
        runner cache (compiles = misses, steady-state hits, retraces),
        warm-start cache (exact/near hit rates), the GLOBAL engine LRU
        (this service's engine is one entry in it), and per-class mean
        iteration counts (the measured warm-start win)."""
        return dict(
            runner=self.runners.snapshot(),
            warm=self.warm.snapshot(),
            engine=engine_cache_info(),
            served=self.served,
            batches=self.batches,
            pending=self.pending(),
            mean_batch=self.served / self.batches if self.batches else 0.0,
            mean_iters_warm=(self.iters_warm / self.served_warm
                             if self.served_warm else 0.0),
            mean_iters_cold=(self.iters_cold / self.served_cold
                             if self.served_cold else 0.0),
            shed=self.queue.shed,
            health=dict(self.health_hist),
            recovery=dict(
                enabled=self.recovery is not None,
                attempts=self.recovery_attempts,
                recovered=self.recovered,
                refused=self.refused,
                runner_faults=self.runner_faults,
                quarantine_rejects=self.quarantine_rejects,
                quarantined=sum(
                    1 for c in self._quarantine.values()
                    if c >= self.quarantine_after),
                rung_hist=dict(self.rung_hist),
                rung_configs=len(self._rung_caches),
                rung_runners=sum(
                    len(c) for c in self._rung_caches.values()),
                rung_compiles=sum(
                    c.misses for c in self._rung_caches.values()),
                rung_extra_traces=sum(
                    c.extra_traces for c in self._rung_caches.values()),
            ),
        )
