"""OT-as-a-service: a persistent request-driven front end for the batched
solver engine.

The pieces (each its own module, composable and unit-testable):

* :class:`~repro.serving.runner_cache.RunnerCache` — pre-planned,
  warm-up-executed jitted runners per ``(OTBatchShape, B)`` bucket cell:
  steady-state requests never trace or compile.
* :class:`~repro.serving.admission.AdmissionQueue` — continuous batching
  of ragged requests into bucket-padded megabatches under a
  max-batch/max-wait policy.
* :class:`~repro.serving.warmstart.WarmStartCache` — fingerprinted
  potentials re-served through the engine's ``f_init``/``g_init`` path
  for repeat (exact) and near-repeat (good-init) pairs.

Usage::

    svc = OTService(eps=0.05, method="log_factored", max_batch=8,
                    max_wait=0.002)
    svc.warmup([(200, 150, 64)])          # pre-plan the expected buckets
    t = svc.submit(problem)               # -> Ticket
    svc.pump()                           # dispatch due megabatches
    svc.drain()                          # flush everything pending
    t.result                             # per-request unpadded SinkhornResult

``submit``/``pump``/``drain`` are synchronous and single-threaded by
design: the event loop (a driver script, an RPC handler, the open-loop
benchmark) owns scheduling, the service owns batching and caching. All
time is injected (``clock=``), so tests drive the max-wait policy with a
fake clock.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..configs.shapes import OTBatchShape, ot_batch_bucket
from ..core.api import (
    OTProblem,
    engine_cache_info,
    get_engine,
)
from ..core.sinkhorn import SinkhornResult
from .admission import AdmissionQueue
from .runner_cache import RunnerCache
from .warmstart import WarmStartCache

__all__ = ["Ticket", "OTService"]


# -- host-side padding/unpadding ---------------------------------------------
#
# The dispatch path deliberately stays in NUMPY until the single jitted
# runner call: every jnp slice/concat on a new shape eagerly compiles a
# tiny XLA executable (~tens of ms on CPU the first time) and pays a
# dispatch round trip every time after — measured to dominate per-request
# latency when the glue ran through jnp. Host-side padding is exact (same
# replicate/zero-fill semantics as core.api._pad_rows) and costs
# microseconds.


def _pad_np(arr, n_pad: int, *, replicate: bool,
            fill: float = 0.0) -> np.ndarray:
    x = np.asarray(arr)
    pad = n_pad - x.shape[0]
    if pad <= 0:
        return x
    if replicate:
        tail = np.broadcast_to(x[-1:], (pad,) + x.shape[1:])
    else:
        tail = np.full((pad,) + x.shape[1:], fill, x.dtype)
    return np.concatenate([x, tail], axis=0)


def _pad_kernel_np(ka: np.ndarray, kb: np.ndarray, shape: OTBatchShape,
                   quadratic: bool) -> Tuple[np.ndarray, np.ndarray]:
    if quadratic:
        ka = _pad_np(ka, shape.n_pad, replicate=True)
        ka = _pad_np(ka.T, shape.m_pad, replicate=True).T
        return ka, ka
    return (_pad_np(ka, shape.n_pad, replicate=True),
            _pad_np(kb, shape.m_pad, replicate=True))


def _unpad_np(host: Dict[str, np.ndarray], j: int, n: int,
              m: int) -> SinkhornResult:
    """Slice request ``j`` out of a batch result already pulled to host."""
    return SinkhornResult(
        u=host["u"][j, :n], v=host["v"][j, :m],
        f=host["f"][j, :n], g=host["g"][j, :m],
        cost=host["cost"][j], n_iter=host["n_iter"][j],
        marginal_err=host["marginal_err"][j],
        converged=host["converged"][j],
    )


class Ticket:
    """Handle for one submitted request; filled in by the dispatch path."""

    __slots__ = ("seq", "t_submit", "t_done", "result", "warm_hit",
                 "warm_exact")

    def __init__(self, seq: int, t_submit: float):
        self.seq = seq
        self.t_submit = t_submit
        self.t_done: Optional[float] = None
        self.result: Optional[SinkhornResult] = None
        self.warm_hit = False
        self.warm_exact = False

    @property
    def done(self) -> bool:
        return self.result is not None

    @property
    def latency(self) -> float:
        if self.t_done is None:
            raise RuntimeError("request not served yet")
        return self.t_done - self.t_submit


@dataclasses.dataclass
class _Admitted:
    """One admitted request: host-side kernel data + warm-start state +
    its ticket."""

    ticket: Ticket
    ka: np.ndarray
    kb: np.ndarray
    a: np.ndarray
    b: np.ndarray
    n: int
    m: int
    support_key: bytes
    full_key: bytes
    f0: Optional[np.ndarray]      # warm potentials (unpadded) or None
    g0: Optional[np.ndarray]


class OTService:
    """Persistent OT solver service over the batched vmapped engine.

    Solver knobs mirror :class:`~repro.core.api.BatchedSinkhorn` (one
    service per solver configuration; the engine itself comes from the
    bounded :func:`~repro.core.api.get_engine` LRU so service and
    ``solve_many`` callers share executables and accounting). Serving
    knobs:

    ``max_batch``/``max_wait``
        admission policy (see :class:`AdmissionQueue`). Megabatches are
        additionally padded UP to power-of-two batch buckets
        (``ot_batch_bucket``) by replicating a real request lane — exact,
        the duplicate lanes are discarded — so the number of compiled
        runners stays at O(buckets x log max_batch).
    ``runner_capacity``
        LRU cap on live compiled runners.
    ``warm_capacity``/``warm_quant``/``warm_starts``
        warm-start cache size, fingerprint quantization, and a master
        switch (off = every request cold-starts; the A/B knob the
        benchmark uses).
    ``clock``
        time source (injectable for tests; defaults to
        ``time.monotonic``).
    """

    def __init__(
        self,
        *,
        eps: float,
        method: str = "log_factored",
        tol: float = 1e-6,
        max_iter: int = 2000,
        momentum: float = 1.0,
        use_pallas: Optional[bool] = None,
        inner_steps: Optional[int] = None,
        check_every: Optional[int] = None,
        precision: str = "highest",
        max_batch: int = 8,
        max_wait: float = 0.005,
        runner_capacity: int = 32,
        warm_capacity: int = 1024,
        warm_quant: float = 1e-6,
        warm_starts: bool = True,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.engine = get_engine(
            eps=eps, method=method, tol=tol, max_iter=max_iter,
            momentum=momentum, use_pallas=use_pallas,
            inner_steps=inner_steps, check_every=check_every,
            precision=precision,
        )
        self.clock = clock
        self.max_batch = max_batch
        self.runners = RunnerCache(self.engine, capacity=runner_capacity,
                                   max_batch=max_batch)
        self.queue: AdmissionQueue[_Admitted] = AdmissionQueue(
            max_batch=max_batch, max_wait=max_wait)
        self.warm = WarmStartCache(capacity=warm_capacity, quant=warm_quant)
        self.warm_starts = warm_starts
        # served-request accounting (feeds stats() and the benchmark)
        self.served = 0
        self.batches = 0
        self.iters_warm = 0          # total solver iterations, warm-hit reqs
        self.iters_cold = 0
        self.served_warm = 0
        self.served_cold = 0

    # -- request path --------------------------------------------------------

    def submit(self, problem: Union[OTProblem, "SolveSpec"],
               now: Optional[float] = None) -> Ticket:
        """Admit one request: derive its kernel data and bucket cell, look
        up a warm start, enqueue. Returns the request's :class:`Ticket`
        (filled when a ``pump``/``drain`` dispatches its megabatch).

        Accepts a :class:`~repro.core.spec.SolveSpec` (the unified
        record): its geometry/weights become the request and its solver-
        facing fields are VALIDATED against this service's engine — a
        spec asking for a different eps/tol/max_iter/momentum than the
        service was built with is an error, not a silent reconfigure
        (services are per-configuration; the spec's execution policy and
        method are the service's to choose)."""
        from ..core.spec import SolveSpec
        if isinstance(problem, SolveSpec):
            spec = problem
            e = self.engine
            mismatches = [
                f"{name}: spec={got} != service={want}"
                for name, got, want in (
                    ("eps", float(spec.eps), float(e.eps)),
                    ("tol", float(spec.tol), float(e.tol)),
                    ("max_iter", int(spec.max_iter), int(e.max_iter)),
                    ("momentum", float(spec.momentum), float(e.momentum)),
                )
                if got != want
            ]
            if spec.schedule is not None:
                mismatches.append("schedule: serving solves are "
                                  "single-stage (no eps annealing)")
            if mismatches:
                raise ValueError(
                    "SolveSpec incompatible with this service's engine "
                    "(run one service per configuration): "
                    + "; ".join(mismatches))
            problem = spec.problem()
        if float(problem.eps) != float(self.engine.eps):
            raise ValueError(
                f"request declares eps={problem.eps} but this service "
                f"solves at eps={self.engine.eps}; run one service per eps"
            )
        now = self.clock() if now is None else now
        ticket = Ticket(self.queue.admitted, now)
        ka, kb = self.engine.kernel_data(problem)
        shape = self.engine.batch_shape(ka, kb)
        # everything downstream of here is host-side numpy (see the
        # module note above _pad_np); float32 is the serving dtype — the
        # runners are compiled for it, so admitting a float64 request
        # must not retrace them
        ka = np.asarray(ka, np.float32)
        kb = np.asarray(kb, np.float32)
        a = np.asarray(problem.a, np.float32)
        b = np.asarray(problem.b, np.float32)
        f0 = g0 = None
        support_key = full_key = b""
        if self.warm_starts:
            support_key, full_key = self.warm.keys_for(ka, kb, a, b)
            hit = self.warm.lookup(support_key, full_key)
            if hit is not None:
                f0, g0 = hit.f, hit.g
                ticket.warm_hit = True
                ticket.warm_exact = hit.exact
        adm = _Admitted(
            ticket=ticket, ka=ka, kb=kb, a=a, b=b,
            n=a.shape[0], m=b.shape[0],
            support_key=support_key, full_key=full_key, f0=f0, g0=g0,
        )
        self.queue.add(shape, adm, now)
        return ticket

    def pump(self, now: Optional[float] = None, force: bool = False) -> int:
        """Dispatch every due megabatch; returns requests completed."""
        now = self.clock() if now is None else now
        done = 0
        for shape, items in self.queue.pop_due(now, force=force):
            done += self._dispatch(shape, items)
        return done

    def drain(self) -> int:
        """Flush everything pending regardless of age; returns requests
        completed."""
        return self.pump(force=True)

    def solve_many(self, problems: Sequence[OTProblem]) -> List[SinkhornResult]:
        """Convenience batch entry: submit all, drain, return results in
        submission order (the serving twin of ``BatchedSinkhorn.solve_many``)."""
        tickets = [self.submit(p) for p in problems]
        self.drain()
        return [t.result for t in tickets]

    def next_deadline(self) -> Optional[float]:
        return self.queue.next_deadline()

    def pending(self) -> int:
        return len(self.queue)

    # -- planning ------------------------------------------------------------

    def warmup(
        self,
        cells: Iterable[Union[OTBatchShape, Tuple[int, int, int]]],
        batches: Optional[Iterable[int]] = None,
    ) -> int:
        """Pre-plan runners for the expected traffic shapes.

        ``cells`` are :class:`OTBatchShape`\\ s or raw ``(n, m, r)``
        support triples (bucketed here); every batch bucket up to
        ``max_batch`` is compiled per cell unless ``batches`` narrows it.
        Returns the number of runners built.
        """
        shapes = []
        for c in cells:
            if isinstance(c, OTBatchShape):
                shapes.append(c)
            else:
                n, m, r = c
                shapes.append(
                    OTBatchShape.for_quadratic(n, m)
                    if self.engine.method in self.engine._QUADRATIC
                    else OTBatchShape.for_problem(n, m, r)
                )
        return self.runners.warm(shapes, batches)

    # -- dispatch ------------------------------------------------------------

    def _dispatch(self, shape: OTBatchShape, items: List[_Admitted]) -> int:
        b_real = len(items)
        b_pad = ot_batch_bucket(b_real, self.max_batch)
        # pad dead lanes by REPLICATING a real request: the duplicates
        # converge exactly like their source (no all-zero-weight lane to
        # NaN-poison or stall the batched while_loop) and are discarded
        lanes = items + [items[-1]] * (b_pad - b_real)
        quadratic = self.engine.method in self.engine._QUADRATIC
        kas, kbs, aws, bws, f0s, g0s = [], [], [], [], [], []
        for it in lanes:
            ka, kb = _pad_kernel_np(it.ka, it.kb, shape, quadratic)
            kas.append(ka)
            kbs.append(kb)
            aws.append(_pad_np(it.a, shape.n_pad, replicate=False))
            bws.append(_pad_np(it.b, shape.m_pad, replicate=False))
            if it.f0 is None:        # zeros == the cold default init
                f0s.append(np.zeros((shape.n_pad,), np.float32))
                g0s.append(np.zeros((shape.m_pad,), np.float32))
            else:
                f0s.append(_pad_np(it.f0, shape.n_pad, replicate=False))
                g0s.append(_pad_np(it.g0, shape.m_pad, replicate=False))
        runner = self.runners.get(shape, b_pad)
        res = runner.run(np.stack(kas), np.stack(kbs), np.stack(aws),
                         np.stack(bws), np.stack(f0s), np.stack(g0s))
        t_done = self.clock()
        # one device->host pull for the whole megabatch; per-request
        # unpadding is then pure numpy slicing
        host = {k: np.asarray(getattr(res, k))
                for k in ("u", "v", "f", "g", "cost", "n_iter",
                          "marginal_err", "converged")}
        for j, it in enumerate(items):
            r = _unpad_np(host, j, it.n, it.m)
            it.ticket.result = r
            it.ticket.t_done = t_done
            if self.warm_starts:
                self.warm.store(it.support_key, it.full_key, r.f, r.g)
            iters = int(r.n_iter)
            if it.ticket.warm_hit:
                self.served_warm += 1
                self.iters_warm += iters
            else:
                self.served_cold += 1
                self.iters_cold += iters
        self.served += b_real
        self.batches += 1
        return b_real

    # -- accounting ----------------------------------------------------------

    def stats(self) -> Dict[str, object]:
        """All serving-path cache/throughput counters in one snapshot:
        runner cache (compiles = misses, steady-state hits, retraces),
        warm-start cache (exact/near hit rates), the GLOBAL engine LRU
        (this service's engine is one entry in it), and per-class mean
        iteration counts (the measured warm-start win)."""
        return dict(
            runner=self.runners.snapshot(),
            warm=self.warm.snapshot(),
            engine=engine_cache_info(),
            served=self.served,
            batches=self.batches,
            pending=self.pending(),
            mean_batch=self.served / self.batches if self.batches else 0.0,
            mean_iters_warm=(self.iters_warm / self.served_warm
                             if self.served_warm else 0.0),
            mean_iters_cold=(self.iters_cold / self.served_cold
                             if self.served_cold else 0.0),
        )
