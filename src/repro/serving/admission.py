"""Admission queue: continuous batching of heterogeneous OT requests.

Incoming requests are ragged — every caller brings its own ``(n, m, r)``
— but the engine's throughput comes from solving bucket-padded
megabatches. The admission queue groups requests by their bucket cell
(:class:`~repro.configs.shapes.OTBatchShape`) and flushes a group when
either

* it holds ``max_batch`` requests (a full megabatch — dispatch now;
  waiting longer only adds latency), or
* its OLDEST request has waited ``max_wait`` seconds (the
  latency-vs-occupancy knob: higher traffic fills batches before the
  deadline, trickle traffic pays at most ``max_wait`` extra).

FIFO order is preserved within each bucket, so two requests of the same
shape complete in submission order. The queue is time-driven but owns no
clock: callers pass ``now`` (the service injects either a wall clock or a
test-controlled fake).

Depth is BOUNDED when ``max_depth`` is set: a stalled pump (or an
arrival burst past capacity) sheds load at submit time — ``add`` raises
:class:`QueueFullError` and counts the shed — instead of growing memory
without limit. Shedding at admission is the honest failure mode: the
caller gets an immediate structured refusal while queued requests keep
their latency budget.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Generic, Hashable, List, Optional, Tuple, TypeVar

__all__ = ["AdmissionQueue", "QueueFullError"]


class QueueFullError(RuntimeError):
    """Raised by :meth:`AdmissionQueue.add` when depth is at
    ``max_depth`` — the load-shedding refusal."""

T = TypeVar("T")


@dataclasses.dataclass
class _Group(Generic[T]):
    items: List[T]
    arrivals: List[float]       # parallel to items (submission times)


class AdmissionQueue(Generic[T]):
    """Bucket-keyed pending-request store with a max-batch/max-wait
    flush policy. Generic over the item payload; keys must be hashable
    (the service keys by ``OTBatchShape``)."""

    def __init__(self, *, max_batch: int = 8, max_wait: float = 0.005,
                 max_depth: Optional[int] = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait < 0:
            raise ValueError(f"max_wait must be >= 0, got {max_wait}")
        if max_depth is not None and max_depth < 1:
            raise ValueError(f"max_depth must be >= 1, got {max_depth}")
        self.max_batch = max_batch
        self.max_wait = max_wait
        self.max_depth = max_depth
        self._groups: Dict[Hashable, _Group[T]] = {}
        self.admitted = 0
        self.shed = 0               # submissions refused at the depth bound
        self.flushed_full = 0       # groups flushed because they filled
        self.flushed_aged = 0       # groups flushed on the max_wait deadline

    def __len__(self) -> int:
        return sum(len(g.items) for g in self._groups.values())

    @property
    def full(self) -> bool:
        return self.max_depth is not None and len(self) >= self.max_depth

    def add(self, key: Hashable, item: T, now: float) -> None:
        if self.full:
            self.shed += 1
            raise QueueFullError(
                f"admission queue at max_depth={self.max_depth} "
                f"({len(self)} pending) — request shed; retry after a "
                "pump/drain")
        group = self._groups.get(key)
        if group is None:
            group = self._groups[key] = _Group([], [])
        group.items.append(item)
        group.arrivals.append(now)
        self.admitted += 1

    def pop_due(self, now: float,
                force: bool = False) -> List[Tuple[Hashable, List[T]]]:
        """Flush and return every due megabatch as ``(key, items)``.

        Full groups flush in ``max_batch`` chunks regardless of age;
        a group whose oldest request has aged past ``max_wait`` flushes
        whatever it holds. ``force`` flushes everything (drain).
        """
        out: List[Tuple[Hashable, List[T]]] = []
        for key in list(self._groups):
            group = self._groups[key]
            while len(group.items) >= self.max_batch:
                out.append((key, group.items[: self.max_batch]))
                del group.items[: self.max_batch]
                del group.arrivals[: self.max_batch]
                self.flushed_full += 1
            if group.items and (
                force or now - group.arrivals[0] >= self.max_wait
            ):
                out.append((key, group.items))
                group.items, group.arrivals = [], []
                self.flushed_aged += 1
            if not group.items:
                del self._groups[key]
        return out

    def next_deadline(self) -> Optional[float]:
        """Earliest time a currently-pending group becomes due (its oldest
        arrival + ``max_wait``), or ``None`` when empty. Lets the serving
        loop sleep exactly until work exists instead of polling."""
        oldest = [g.arrivals[0] for g in self._groups.values() if g.arrivals]
        return min(oldest) + self.max_wait if oldest else None
