"""OT-as-a-service: the persistent serving layer over the batched engine.

  service      — OTService: submit/pump/drain request loop + stats
  runner_cache — bucket-keyed pre-planned jitted runners (zero steady-state
                 traces/compiles)
  admission    — max-batch/max-wait continuous batching of ragged requests
  warmstart    — fingerprinted potential cache for repeat/near-repeat pairs
  traffic      — synthetic heavy-tailed open-loop traffic + report
  streaming    — StreamingOTService: coalesced mutations over paged
                 supports, one warm re-solve per pair per flush
"""
from .admission import AdmissionQueue, QueueFullError
from .runner_cache import BucketRunner, RunnerCache
from .service import OTService, QuarantineError, Refusal, Ticket
from .streaming import MutationTicket, StreamingOTService
from .traffic import (
    Request,
    TrafficReport,
    TrafficSpec,
    make_traffic,
    run_open_loop,
    traffic_cells,
)
from .warmstart import WarmHit, WarmStartCache, fingerprint, request_keys

__all__ = [
    "AdmissionQueue",
    "BucketRunner",
    "MutationTicket",
    "OTService",
    "QuarantineError",
    "QueueFullError",
    "Refusal",
    "StreamingOTService",
    "Request",
    "RunnerCache",
    "Ticket",
    "TrafficReport",
    "TrafficSpec",
    "WarmHit",
    "WarmStartCache",
    "fingerprint",
    "make_traffic",
    "request_keys",
    "run_open_loop",
    "traffic_cells",
]
