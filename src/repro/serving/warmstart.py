"""Warm-start cache: fingerprinted potentials for repeat / near-repeat pairs.

Service traffic from millions of users is heavy-tailed: the same
distribution pairs (and small perturbations of them) recur constantly. A
converged Sinkhorn solve's potentials ``(f, g)`` are the perfect warm
start for a re-solve of the same pair — the solver exits at the first
convergence check — and a *good* init for a nearby pair. This module
fingerprints a request's kernel data and weights and re-serves cached
potentials through the engine's ``f_init``/``g_init`` path.

Two-level fingerprint
---------------------
* ``support_key`` — content hash of the QUANTIZED kernel data (features /
  log-features / dense cost). Quantization (``round(x / quant)``) makes
  the hash robust to sub-``quant`` float fuzz from re-deriving the same
  features (nondeterministic reduction order, device round trips).
* ``full_key`` — ``support_key`` extended with the quantized weights.

The cache is keyed on ``support_key``; a lookup whose stored ``full_key``
also matches is an EXACT hit (same pair up to quantization — the warm
solve converges to the same result, elementwise within solver tolerance),
otherwise a NEAR hit (same supports, different weights — the potentials
are merely a good init; the solve still converges to ITS OWN fixed point
exactly, just in fewer iterations). Both reduce iterations; only exact
hits allow serving byte-equal results.

Poisoning defense
-----------------
A diverged solve's potentials are NaN — re-serving them as a warm start
poisons every later request for the same pair (the NaN init propagates
through the first iteration). The cache therefore validates on BOTH
sides:

* **put** — ``store`` rejects potentials that are non-finite on any
  mass-carrying atom (``-inf`` on a zero-weight atom is the log domain's
  legitimate dead-slot encoding and is SANITIZED to 0, so stored entries
  are always fully finite). Rejects keep any previously-stored good
  entry.
* **get** — ``lookup`` re-validates the stored arrays and EVICTS corrupt
  entries (a snapshot written by a pre-validation build, bit flips, or a
  deliberate ``store(..., validate=False)`` in the chaos lane), counting
  the request as a miss: the caller cold-solves instead of inheriting
  NaNs.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Dict, Iterable, Optional, Tuple

import numpy as np

__all__ = ["fingerprint", "request_keys", "WarmHit", "WarmStartCache"]

# sentinel for +-inf / nan after division by quant: far outside any real
# quantized feature range, deterministic across platforms
_BIG = float(2**61)


def fingerprint(arrays: Iterable, *, quant: float = 1e-6) -> bytes:
    """Content hash of quantized arrays: shapes + ``round(x / quant)``.

    Deterministic across runs/processes (blake2b of the int64 grid), and
    invariant to perturbations that stay inside the same quantization
    cells. ``quant`` trades near-repeat tolerance against collision
    radius.
    """
    if quant <= 0:
        raise ValueError(f"quant must be positive, got {quant}")
    h = hashlib.blake2b(digest_size=16)
    for arr in arrays:
        x = np.asarray(arr, dtype=np.float64)
        q = np.nan_to_num(np.round(x / quant), nan=_BIG, posinf=_BIG,
                          neginf=-_BIG)
        h.update(np.int64(x.ndim).tobytes())
        h.update(np.asarray(x.shape, np.int64).tobytes())
        h.update(np.clip(q, -_BIG, _BIG).astype(np.int64).tobytes())
    return h.digest()


def request_keys(ka, kb, a, b, *, quant: float = 1e-6) -> Tuple[bytes, bytes]:
    """(support_key, full_key) for one request's kernel data + weights."""
    support = fingerprint((ka, kb), quant=quant)
    h = hashlib.blake2b(digest_size=16)
    h.update(support)
    h.update(fingerprint((a, b), quant=quant))
    return support, h.digest()


@dataclasses.dataclass(frozen=True)
class WarmHit:
    """A warm-start lookup result: cached potentials + hit class."""

    f: np.ndarray
    g: np.ndarray
    exact: bool          # full_key matched (same weights to quantization)


class WarmStartCache:
    """LRU of converged potentials keyed by support fingerprint.

    ``lookup`` refreshes recency; ``store`` inserts/overwrites (a re-solve
    of the same supports refreshes the stored potentials and weights-key).
    All counters are plain ints — cheap to snapshot for the service stats.
    """

    def __init__(self, *, capacity: int = 1024, quant: float = 1e-6):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.quant = quant
        self._entries: "OrderedDict[bytes, Tuple[bytes, np.ndarray, np.ndarray]]" = OrderedDict()
        self.exact_hits = 0
        self.near_hits = 0
        self.misses = 0
        self.evictions = 0
        self.poisoned_rejects = 0       # non-finite potentials refused on put
        self.poisoned_evictions = 0     # corrupt entries evicted on get

    def __len__(self) -> int:
        return len(self._entries)

    def keys_for(self, ka, kb, a, b) -> Tuple[bytes, bytes]:
        return request_keys(ka, kb, a, b, quant=self.quant)

    def lookup(self, support_key: bytes,
               full_key: bytes) -> Optional[WarmHit]:
        entry = self._entries.get(support_key)
        if entry is None:
            self.misses += 1
            return None
        stored_full, f, g = entry
        # get-side validation: stored entries are sanitized to be fully
        # finite, so ANY non-finite value marks corruption — evict and
        # cold-solve rather than re-serve poison
        if not (np.isfinite(f).all() and np.isfinite(g).all()):
            del self._entries[support_key]
            self.poisoned_evictions += 1
            self.misses += 1
            return None
        self._entries.move_to_end(support_key)
        exact = stored_full == full_key
        if exact:
            self.exact_hits += 1
        else:
            self.near_hits += 1
        return WarmHit(f=f, g=g, exact=exact)

    def store(self, support_key: bytes, full_key: bytes, f, g,
              a=None, b=None, *, validate: bool = True) -> bool:
        """Insert converged potentials; returns False when put-side
        validation refuses them (diverged solve — NaN/+inf anywhere, or
        ``-inf`` on a mass-carrying atom when weights are supplied).
        Legitimate ``-inf`` on zero-weight atoms is sanitized to 0 (the
        cold init for that atom) so stored entries are always fully
        finite and the get-side check stays a plain ``isfinite``.
        ``validate=False`` bypasses everything — the chaos/test hook for
        simulating a corrupted cache."""
        f = np.asarray(f)
        g = np.asarray(g)
        if validate:
            fin_f, fin_g = np.isfinite(f), np.isfinite(g)
            dead_f = (np.asarray(a) <= 0) if a is not None \
                else np.zeros(f.shape, bool)
            dead_g = (np.asarray(b) <= 0) if b is not None \
                else np.zeros(g.shape, bool)
            if not ((fin_f | dead_f).all() and (fin_g | dead_g).all()):
                self.poisoned_rejects += 1
                return False
            if not fin_f.all():
                f = np.where(fin_f, f, 0.0).astype(f.dtype)
            if not fin_g.all():
                g = np.where(fin_g, g, 0.0).astype(g.dtype)
        self._entries[support_key] = (full_key, f, g)
        self._entries.move_to_end(support_key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1
        return True

    @property
    def hits(self) -> int:
        return self.exact_hits + self.near_hits

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def snapshot(self) -> Dict[str, float]:
        return dict(size=len(self), capacity=self.capacity,
                    exact_hits=self.exact_hits, near_hits=self.near_hits,
                    misses=self.misses, evictions=self.evictions,
                    poisoned_rejects=self.poisoned_rejects,
                    poisoned_evictions=self.poisoned_evictions,
                    hit_rate=self.hit_rate)
