"""Synthetic serving traffic: heavy-tailed repeat requests, open-loop load.

Models the ROADMAP's "millions of users" shape without any external data:

* a POOL of distinct distribution pairs (each a positive-feature OT
  problem) across several ragged size classes (so requests land in
  several ``OTBatchShape`` buckets);
* requests sample the pool with repetition (``repeat_frac`` of requests
  re-serve an already-seen pair — heavy-tailed traffic re-requests the
  same pairs constantly) and ``near_frac`` of those re-jitter the WEIGHTS
  only (same supports, slightly different marginals — the warm-start
  cache's near-repeat class);
* arrivals follow a fixed exponential (Poisson) schedule at ``rate_hz``,
  generated ahead of time — OPEN-loop: arrival times never depend on
  completions, so queueing delay shows up in the latency percentiles
  instead of being absorbed by backpressure.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from ..core.api import OTProblem

__all__ = ["TrafficSpec", "Request", "make_traffic", "run_open_loop",
           "TrafficReport", "traffic_cells"]


@dataclasses.dataclass(frozen=True)
class TrafficSpec:
    """Knobs for one synthetic trace (all deterministic given ``seed``)."""

    n_requests: int = 200
    rate_hz: float = 200.0
    eps: float = 0.5
    r: int = 16
    size_classes: Tuple[Tuple[int, int], ...] = ((40, 56), (90, 70),
                                                 (150, 120))
    pool_size: int = 32
    repeat_frac: float = 0.6
    near_frac: float = 0.3       # fraction of repeats with re-jittered weights
    seed: int = 0


@dataclasses.dataclass
class Request:
    """One scheduled arrival: offset seconds from trace start + problem."""

    t_offset: float
    problem: OTProblem
    kind: str                    # "fresh" | "repeat" | "near"


def _pool_problem(rng: np.random.Generator, n: int, m: int, r: int,
                  eps: float) -> OTProblem:
    xi = np.asarray(rng.uniform(0.05, 1.05, (n, r)), np.float32)
    zeta = np.asarray(rng.uniform(0.05, 1.05, (m, r)), np.float32)
    a = np.asarray(rng.dirichlet(np.full(n, 2.0)), np.float32)
    b = np.asarray(rng.dirichlet(np.full(m, 2.0)), np.float32)
    a, b = a / a.sum(), b / b.sum()
    return OTProblem.from_features(xi, zeta, a, b, eps=eps)


def make_traffic(spec: TrafficSpec) -> List[Request]:
    """Deterministic request trace for ``spec`` (sorted by arrival)."""
    rng = np.random.default_rng(spec.seed)
    pool: List[OTProblem] = []
    for i in range(spec.pool_size):
        n, m = spec.size_classes[i % len(spec.size_classes)]
        # ragged within the class: sizes vary but stay inside one bucket
        n = int(rng.integers(max(2, n - n // 8), n + 1))
        m = int(rng.integers(max(2, m - m // 8), m + 1))
        pool.append(_pool_problem(rng, n, m, spec.r, spec.eps))
    gaps = rng.exponential(1.0 / spec.rate_hz, spec.n_requests)
    arrivals = np.cumsum(gaps)
    # Zipf-ish popularity over the pool: low indices dominate, matching
    # heavy-tailed production reuse
    ranks = np.arange(1, spec.pool_size + 1, dtype=np.float64)
    popularity = (1.0 / ranks) / (1.0 / ranks).sum()
    out: List[Request] = []
    seen: set = set()
    for t in arrivals:
        idx = int(rng.choice(spec.pool_size, p=popularity))
        base = pool[idx]
        if idx in seen and rng.random() < spec.repeat_frac:
            if rng.random() < spec.near_frac:
                # near-repeat: identical supports, re-jittered weights
                n, m = base.a.shape[0], base.b.shape[0]
                a = np.asarray(base.a) * np.asarray(
                    rng.uniform(0.9, 1.1, n), np.float32)
                b = np.asarray(base.b) * np.asarray(
                    rng.uniform(0.9, 1.1, m), np.float32)
                a, b = a / a.sum(), b / b.sum()
                p = OTProblem(geometry=base.geometry,
                              a=np.asarray(a, np.float32),
                              b=np.asarray(b, np.float32))
                out.append(Request(float(t), p, "near"))
            else:
                out.append(Request(float(t), base, "repeat"))
        else:
            seen.add(idx)
            out.append(Request(float(t), base, "fresh"))
    return out


@dataclasses.dataclass
class TrafficReport:
    """Measured open-loop serving outcome."""

    completed: int
    duration_s: float
    latencies_s: np.ndarray      # per-request, submission -> completion

    @property
    def rps(self) -> float:
        return self.completed / self.duration_s if self.duration_s else 0.0

    def percentile_ms(self, q: float) -> float:
        if len(self.latencies_s) == 0:
            return float("nan")
        return float(np.percentile(self.latencies_s, q) * 1e3)

    @property
    def p50_ms(self) -> float:
        return self.percentile_ms(50)

    @property
    def p99_ms(self) -> float:
        return self.percentile_ms(99)


def run_open_loop(
    service,
    traffic: Sequence[Request],
    *,
    clock: Optional[Callable[[], float]] = None,
    sleep: Callable[[float], None] = time.sleep,
    poll_s: float = 0.0002,
) -> TrafficReport:
    """Drive ``service`` with the pre-scheduled ``traffic`` trace.

    Submissions happen at their scheduled wall-clock offsets (open loop);
    between arrivals the loop pumps due megabatches and otherwise sleeps
    until the next arrival or admission deadline. Returns the measured
    latency/throughput report (latencies from each request's scheduled
    arrival, so queueing delay counts).
    """
    clock = service.clock if clock is None else clock
    tickets = []
    start = clock()
    for req in traffic:
        target = start + req.t_offset
        while True:
            now = clock()
            if now >= target:
                break
            service.pump(now)
            deadline = service.next_deadline()
            wait = target - now
            if deadline is not None:
                wait = min(wait, max(deadline - now, 0.0))
            sleep(min(wait, poll_s) if wait > 0 else 0.0)
        # enqueue at the REAL clock time (the max-wait aging policy must
        # see true arrival times, or a loop that slipped past the
        # schedule would flush every group instantly as batch-of-1) ...
        t = service.submit(req.problem)
        # ... but measure latency from the SCHEDULED arrival: a
        # submission that slipped because the loop was busy still pays
        # its lateness
        t.t_submit = target
        tickets.append(t)
        service.pump()
    service.drain()
    end = clock()
    lat = np.asarray([t.latency for t in tickets if t.done], np.float64)
    return TrafficReport(
        completed=sum(t.done for t in tickets),
        duration_s=end - start,
        latencies_s=lat,
    )


def traffic_cells(traffic: Sequence[Request], engine) -> List:
    """The set of bucket cells a trace will hit (for ``OTService.warmup``)."""
    shapes = []
    seen = set()
    for req in traffic:
        ka, kb = engine.kernel_data(req.problem)
        shape = engine.batch_shape(ka, kb)
        if shape not in seen:
            seen.add(shape)
            shapes.append(shape)
    return shapes
