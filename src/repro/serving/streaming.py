"""Streaming front end: mutation requests coalesced through the
admission queue, one warm re-solve per pair per flush.

Mutations to a streaming pair arrive ragged — a point added here, a few
evicted there — but every mutation invalidates the same thing (that
pair's coupling), so solving after each one wastes warm re-solves. The
service reuses :class:`~repro.serving.admission.AdmissionQueue` with the
PAIR NAME as the bucket key: mutation requests batch under the usual
max-batch/max-wait policy, and a due flush applies the whole batch to
the stores (removals before inserts, FIFO within each kind) before
running ONE warm ``re_solve``. Every ticket in the batch gets the same
post-batch result — the coupling of the state all their mutations
produced.

Like :class:`~repro.serving.service.OTService`, the loop is synchronous
and single-threaded with injected time: ``submit_update`` enqueues,
``pump``/``drain`` dispatch.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..core.sinkhorn import SinkhornResult
from ..streaming import StreamingDistribution, StreamingPair, StreamingSolver
from .admission import AdmissionQueue

__all__ = ["MutationTicket", "StreamingOTService"]


class MutationTicket:
    """Handle for one submitted mutation; resolved at the batch flush."""

    __slots__ = ("seq", "pair", "t_submit", "t_done", "result", "health")

    def __init__(self, seq: int, pair: str, t_submit: float):
        self.seq = seq
        self.pair = pair
        self.t_submit = t_submit
        self.t_done: Optional[float] = None
        self.result: Optional[SinkhornResult] = None
        self.health = None      # SolveHealth of the flush that served it

    @property
    def done(self) -> bool:
        return self.result is not None

    @property
    def latency(self) -> float:
        if self.t_done is None:
            raise RuntimeError("ticket not dispatched yet")
        return self.t_done - self.t_submit


class StreamingOTService:
    """Mutation-coalescing wrapper around :class:`StreamingSolver`.

    ``max_batch`` / ``max_wait`` are the admission policy per PAIR: a
    pair flushes when it accumulates ``max_batch`` pending mutations or
    its oldest one has waited ``max_wait`` seconds. ``solver`` defaults
    to a scaling-space :class:`StreamingSolver`; pass a configured one to
    pick the log domain / tolerances.
    """

    def __init__(self, *, solver: Optional[StreamingSolver] = None,
                 max_batch: int = 16, max_wait: float = 0.005,
                 clock: Callable[[], float] = time.monotonic):
        self.solver = solver if solver is not None else StreamingSolver()
        self.queue: AdmissionQueue = AdmissionQueue(
            max_batch=max_batch, max_wait=max_wait)
        self.clock = clock
        self._seq = 0
        self.dispatched = 0
        self.solves = 0

    # -- registry ------------------------------------------------------

    def register(self, name: str, x: StreamingDistribution,
                 y: StreamingDistribution, *,
                 warmup: bool = True) -> StreamingPair:
        """Track a pair; pre-traces its runner by default so the first
        flush replays a compiled executable."""
        pair = self.solver.register(name, x, y)
        if warmup:
            self.solver.warmup(pair)
        return pair

    # -- submission ----------------------------------------------------

    def submit_update(self, pair: str, *,
                      add_x: Optional[dict] = None,
                      remove_x: Optional[Sequence] = None,
                      add_y: Optional[dict] = None,
                      remove_y: Optional[Sequence] = None,
                      now: Optional[float] = None) -> MutationTicket:
        """Enqueue one mutation request against a registered pair.

        ``add_*`` are kwarg dicts for
        :meth:`~repro.streaming.StreamingDistribution.add`; ``remove_*``
        id sequences. The mutation is NOT applied here — it lands at the
        batch flush, together with every other pending mutation for the
        pair, before the single warm re-solve."""
        self.solver.pair(pair)      # KeyError on unknown pair
        now = self.clock() if now is None else now
        ticket = MutationTicket(self._seq, pair, now)
        self._seq += 1
        self.queue.add(pair, (ticket, add_x, remove_x, add_y, remove_y),
                       now)
        return ticket

    # -- dispatch ------------------------------------------------------

    def _apply(self, pair: StreamingPair,
               items: List[Tuple]) -> SinkhornResult:
        # removals first so a remove+re-add of the same id within one
        # batch nets out to the re-add (FIFO within each kind)
        for _, _, remove_x, _, remove_y in items:
            if remove_x:
                pair.x.remove(remove_x)
            if remove_y:
                pair.y.remove(remove_y)
        for _, add_x, _, add_y, _ in items:
            if add_x:
                pair.x.add(**add_x)
            if add_y:
                pair.y.add(**add_y)
        return self.solver.re_solve(pair)

    def pump(self, now: Optional[float] = None, force: bool = False) -> int:
        """Flush due mutation batches; returns tickets resolved."""
        now = self.clock() if now is None else now
        resolved = 0
        for name, items in self.queue.pop_due(now, force):
            pair = self.solver.pair(name)
            result = self._apply(pair, items)
            self.solves += 1
            t_done = self.clock() if force or now is None else now
            for ticket, *_ in items:
                ticket.result = result
                ticket.health = pair.last_health
                ticket.t_done = t_done
                resolved += 1
            self.dispatched += len(items)
        return resolved

    def drain(self) -> int:
        """Flush everything pending regardless of age."""
        return self.pump(force=True)

    def next_deadline(self) -> Optional[float]:
        return self.queue.next_deadline()

    @property
    def pending(self) -> int:
        return len(self.queue)

    def stats(self) -> Dict[str, object]:
        s = dict(self.solver.stats())
        s.update(
            pending=self.pending,
            dispatched=self.dispatched,
            solves=self.solves,
            coalesce_ratio=(self.dispatched / self.solves
                            if self.solves else 0.0),
            flushed_full=self.queue.flushed_full,
            flushed_aged=self.queue.flushed_aged,
        )
        return s
