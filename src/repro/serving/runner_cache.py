"""Bucket-keyed compiled-runner cache: pre-planned, warmed-up executables.

The batched engine's jitted vmapped solver retraces per distinct input
shape — for serving that means every new ``(B, n_pad, m_pad, r)``
combination pays a trace + compile inside a request's latency budget. The
runner cache removes that: each :class:`BucketRunner` owns ONE jitted
executable pinned to a single bucket cell (the per-batch-size pre-planned
decode-runner idiom), and is WARM-UP EXECUTED on synthetic data at build
time, so steady-state dispatches never trace or compile.

Runners always go through the engine's donated warm-start body
(``_solve_one_warm``): zero initial potentials are exactly the cold
default (``f = 0`` is ``u = 1``; the log solver starts from zeros before
pinning dead atoms), so one executable serves both cold and warm-started
megabatches — one code path, one compile, per cell.

Accounting: ``misses`` counts runner builds (each is exactly one
compile), ``hits`` steady-state reuse, and ``extra_traces`` any retrace a
runner's own jit suffered after warmup (dtype drift, weak-type leaks —
always a bug). The serving CI gate asserts ``misses`` and
``extra_traces`` stay at zero after warmup.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.shapes import OTBatchShape, ot_batch_bucket
from ..core.api import BatchedSinkhorn
from ..core.sinkhorn import SinkhornResult

__all__ = ["BucketRunner", "RunnerCache"]


@dataclasses.dataclass(frozen=True)
class _Cell:
    """One runner's fixed shapes."""

    shape: OTBatchShape
    batch: int

    def data_shapes(self, quadratic: bool):
        n, m, r = self.shape.n_pad, self.shape.m_pad, self.shape.r
        if quadratic:
            ka = kb = (self.batch, n, m)
        else:
            ka, kb = (self.batch, n, r), (self.batch, m, r)
        return ka, kb, (self.batch, n), (self.batch, m)


class BucketRunner:
    """One pre-planned executable for one ``(OTBatchShape, B)`` cell.

    Owns its own ``jax.jit`` wrapper (instead of sharing the engine's), so
    evicting a runner actually releases its compiled executable, and its
    trace count is observable per cell via ``traces``.
    """

    def __init__(self, engine: BatchedSinkhorn, shape: OTBatchShape,
                 batch: int, *, dtype=jnp.float32):
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        self.engine = engine
        self.cell = _Cell(shape, batch)
        self.dtype = jnp.dtype(dtype)
        self.quadratic = engine.method in engine._QUADRATIC
        self._fn = jax.jit(jax.vmap(engine._solve_one_warm),
                           donate_argnums=(4, 5))
        self.calls = 0
        self._warm = False

    @property
    def traces(self) -> int:
        """Number of tracings this runner's jit performed (1 after a clean
        warmup; anything above 1 is a steady-state recompile = a bug)."""
        return int(self._fn._cache_size())

    def expected_shapes(self):
        return self.cell.data_shapes(self.quadratic)

    def warmup(self) -> "BucketRunner":
        """Trace + compile + execute once on synthetic data that converges
        immediately (constant kernel, uniform weights), so the first real
        request pays neither compile nor first-dispatch overheads.

        Warmup inputs are HOST numpy arrays on purpose: the dispatch path
        feeds numpy (see ``service._pad_np``), and jax's jit cache keys
        numpy-backed and jax-array-backed calls separately — warming up
        with ``jnp`` arrays would leave the first real request to retrace.
        """
        if self._warm:
            return self
        dt = np.dtype(self.dtype)
        ka_s, kb_s, a_s, b_s = self.expected_shapes()
        if self.quadratic:
            ka = kb = np.zeros(ka_s, dt)                   # C = 0 -> K = 1
        elif self.engine.method == "factored":
            ka, kb = np.ones(ka_s, dt), np.ones(kb_s, dt)
        else:                                              # log features
            ka, kb = np.zeros(ka_s, dt), np.zeros(kb_s, dt)
        a = np.full(a_s, 1.0 / a_s[1], dt)
        b = np.full(b_s, 1.0 / b_s[1], dt)
        out = self._fn(ka, kb, a, b, np.zeros(a_s, dt), np.zeros(b_s, dt))
        jax.block_until_ready(out)
        self._warm = True
        return self

    def run(self, ka, kb, a, b, f0, g0) -> SinkhornResult:
        """Solve one bucket-padded megabatch; blocks until the result is
        ready (serving semantics — completion means the answer exists)."""
        expect = self.expected_shapes()
        got = tuple(tuple(x.shape) for x in (ka, kb, a, b))
        if got != expect:
            raise ValueError(
                f"runner cell {self.cell} expects shapes {expect}, got {got}"
            )
        self.calls += 1
        res = self._fn(ka, kb, a, b, f0, g0)
        jax.block_until_ready(res)
        return res


class RunnerCache:
    """LRU of :class:`BucketRunner`\\ s keyed by ``(OTBatchShape, B)``.

    ``get`` builds + warms up on miss (the ONLY place serving-path
    compiles happen); ``warm`` pre-plans a set of cells ahead of traffic.
    Evicted runners release their executables with them.
    """

    def __init__(self, engine: BatchedSinkhorn, *, capacity: int = 32,
                 max_batch: int = 8, dtype=jnp.float32):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.engine = engine
        self.capacity = capacity
        self.max_batch = max_batch
        self.dtype = dtype
        self._runners: "OrderedDict[Tuple[OTBatchShape, int], BucketRunner]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._runners)

    def batch_buckets(self) -> Tuple[int, ...]:
        """All batch-count cells traffic can land in: powers of two up to
        (and including) ``max_batch``."""
        out = []
        boundary = 1
        while boundary < self.max_batch:
            out.append(boundary)
            boundary *= 2
        out.append(self.max_batch)
        return tuple(out)

    def get(self, shape: OTBatchShape, batch: int) -> BucketRunner:
        key = (shape, ot_batch_bucket(batch, self.max_batch))
        runner = self._runners.get(key)
        if runner is not None:
            self.hits += 1
            self._runners.move_to_end(key)
            return runner
        self.misses += 1
        runner = BucketRunner(self.engine, key[0], key[1],
                              dtype=self.dtype).warmup()
        self._runners[key] = runner
        while len(self._runners) > self.capacity:
            self._runners.popitem(last=False)
            self.evictions += 1
        return runner

    def warm(self, shapes: Iterable[OTBatchShape],
             batches: Optional[Iterable[int]] = None) -> int:
        """Pre-plan every (shape x batch-bucket) cell; returns the number
        of runners built (compiles paid now rather than under traffic)."""
        built = 0
        for shape in shapes:
            for b in (self.batch_buckets() if batches is None else batches):
                before = self.misses
                self.get(shape, b)
                built += self.misses > before
        return built

    @property
    def extra_traces(self) -> int:
        """Tracings beyond the one each live runner's warmup performs —
        any steady-state recompile shows up here."""
        return sum(r.traces - 1 for r in self._runners.values())

    def snapshot(self) -> Dict[str, float]:
        return dict(size=len(self), capacity=self.capacity,
                    hits=self.hits, misses=self.misses,
                    evictions=self.evictions,
                    extra_traces=self.extra_traces,
                    dispatches=sum(r.calls for r in self._runners.values()))
