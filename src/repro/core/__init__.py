"""Core of the reproduction: linear-time Sinkhorn with positive features.

Public API:
  geometry    — the kernel-operator protocol: DenseCost / FactoredPositive /
                GaussianPointCloud / ArcCosinePointCloud / NystromLowRank /
                GridSeparable (one class per cost family)
  api         — unified front-end: solve()/solve_many()/BatchedSinkhorn/EpsSchedule
  spec        — SolveSpec: the one record naming a solve (geometry +
                target + ExecutionPolicy), accepted by solve/solve_many
                and the serving layer's submit()
  paged       — PagedFactored: fixed-capacity paged factor buffers for
                streaming supports (repro.streaming)
  features    — Lemma-1 Gaussian / Lemma-3 arc-cosine / learnable feature maps
  sinkhorn    — operator-generic solvers (Alg. 1) over any Geometry
  grad        — envelope-theorem custom VJPs (Prop. 3.2), incl. the generic
                rot_geometry rule that differentiates through any geometry
  divergence  — Sinkhorn divergence (Eq. 2) on any Geometry
  objective   — training-facing OTObjective + ExecutionPolicy (the ONE
                way to put an OT loss in a training loop)
  nystrom     — the paper's Nys baseline (NystromLowRank wrapper)
  sharded     — shard_map distributed solver (r-vector psum per iteration)
  routing     — Sinkhorn-balanced MoE routing
"""
from .accelerated import (
    accelerated_sinkhorn_geometry,
    accelerated_sinkhorn_log_factored,
)
from .api import (
    BatchedSinkhorn,
    EpsSchedule,
    OTProblem,
    clear_engine_cache,
    engine_cache_info,
    get_engine,
    set_engine_cache_capacity,
    solve,
    solve_annealed,
    solve_many,
    unpad_result,
)
from .barycenter import (
    BarycenterResult,
    barycenter_geometry,
    barycenter_log_factored,
)
from .features import (
    ArcCosineFeatureMap,
    GaussianFeatureMap,
    arccos_features,
    gaussian_features,
    gaussian_log_features,
    gaussian_q,
    lambert_w0,
)
from .geometry import (
    ArcCosinePointCloud,
    DenseCost,
    FactoredPositive,
    GaussianPointCloud,
    Geometry,
    GridSeparable,
    NystromLowRank,
    as_geometry,
    data_radius,
    gibbs_kernel,
    squared_euclidean,
)
from .grad import (
    rot_factored,
    rot_factored_batched,
    rot_geometry,
)
from .nystrom import nystrom_factors, sinkhorn_nystrom
from .objective import ExecutionPolicy, OTObjective
from .paged import PagedFactored
from .spec import SolveSpec
from .routing import sinkhorn_route
from .sharded import (
    RowShardedFactored,
    RowShardedGeometry,
    make_sharded_sinkhorn,
    sharded_sinkhorn_divergence,
    sharded_sinkhorn_factored,
    sharded_sinkhorn_geometry,
)
from .sinkhorn import (
    SinkhornResult,
    sinkhorn_factored,
    sinkhorn_geometry,
    sinkhorn_log_factored,
    sinkhorn_log_geometry,
    sinkhorn_log_quadratic,
    sinkhorn_operator,
    sinkhorn_quadratic,
)
from .divergence import (
    sinkhorn_divergence_features,
    sinkhorn_divergence_features_batched,
    sinkhorn_divergence_gaussian,
    sinkhorn_divergence_gaussian_batched,
    sinkhorn_divergence_geometry,
)

__all__ = [
    "ArcCosineFeatureMap",
    "ArcCosinePointCloud",
    "BarycenterResult",
    "BatchedSinkhorn",
    "DenseCost",
    "EpsSchedule",
    "FactoredPositive",
    "GaussianFeatureMap",
    "GaussianPointCloud",
    "Geometry",
    "ExecutionPolicy",
    "GridSeparable",
    "NystromLowRank",
    "OTObjective",
    "OTProblem",
    "PagedFactored",
    "SolveSpec",
    "RowShardedFactored",
    "RowShardedGeometry",
    "SinkhornResult",
    "accelerated_sinkhorn_geometry",
    "accelerated_sinkhorn_log_factored",
    "arccos_features",
    "as_geometry",
    "barycenter_geometry",
    "barycenter_log_factored",
    "data_radius",
    "gaussian_features",
    "gaussian_log_features",
    "gaussian_q",
    "gibbs_kernel",
    "lambert_w0",
    "make_sharded_sinkhorn",
    "nystrom_factors",
    "rot_factored",
    "rot_factored_batched",
    "rot_geometry",
    "sharded_sinkhorn_divergence",
    "sharded_sinkhorn_factored",
    "sharded_sinkhorn_geometry",
    "sinkhorn_divergence_features",
    "sinkhorn_divergence_features_batched",
    "sinkhorn_divergence_gaussian",
    "sinkhorn_divergence_gaussian_batched",
    "sinkhorn_divergence_geometry",
    "sinkhorn_factored",
    "sinkhorn_geometry",
    "sinkhorn_log_factored",
    "sinkhorn_log_geometry",
    "sinkhorn_log_quadratic",
    "sinkhorn_nystrom",
    "sinkhorn_operator",
    "sinkhorn_quadratic",
    "sinkhorn_route",
    "solve",
    "solve_annealed",
    "solve_many",
    "squared_euclidean",
    "unpad_result",
    "clear_engine_cache",
    "engine_cache_info",
    "get_engine",
    "set_engine_cache_capacity",
]
