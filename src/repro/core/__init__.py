"""Core of the reproduction: linear-time Sinkhorn with positive features.

Public API:
  api         — unified front-end: solve()/solve_many()/BatchedSinkhorn/EpsSchedule
  features    — Lemma-1 Gaussian / Lemma-3 arc-cosine / learnable feature maps
  sinkhorn    — factored + quadratic + log-domain solvers (Alg. 1)
  grad        — envelope-theorem custom VJPs (Prop. 3.2)
  divergence  — Sinkhorn divergence (Eq. 2)
  nystrom     — the paper's Nys baseline
  sharded     — shard_map distributed solver (r-vector psum per iteration)
  routing     — Sinkhorn-balanced MoE routing
"""
from .accelerated import accelerated_sinkhorn_log_factored
from .api import (
    BatchedSinkhorn,
    EpsSchedule,
    OTProblem,
    solve,
    solve_annealed,
    solve_many,
)
from .barycenter import BarycenterResult, barycenter_log_factored
from .features import (
    ArcCosineFeatureMap,
    GaussianFeatureMap,
    arccos_features,
    gaussian_features,
    gaussian_log_features,
    gaussian_q,
    lambert_w0,
)
from .geometry import data_radius, gibbs_kernel, squared_euclidean
from .grad import (
    rot_factored,
    rot_factored_batched,
    rot_log_factored,
    rot_log_factored_batched,
)
from .nystrom import nystrom_factors, sinkhorn_nystrom
from .routing import sinkhorn_route
from .sharded import make_sharded_sinkhorn, sharded_sinkhorn_factored
from .sinkhorn import (
    SinkhornResult,
    sinkhorn_factored,
    sinkhorn_log_factored,
    sinkhorn_log_quadratic,
    sinkhorn_operator,
    sinkhorn_quadratic,
)
from .divergence import (
    sinkhorn_divergence_features,
    sinkhorn_divergence_features_batched,
    sinkhorn_divergence_gaussian,
    sinkhorn_divergence_gaussian_batched,
)

__all__ = [
    "ArcCosineFeatureMap",
    "BarycenterResult",
    "BatchedSinkhorn",
    "EpsSchedule",
    "OTProblem",
    "accelerated_sinkhorn_log_factored",
    "barycenter_log_factored",
    "GaussianFeatureMap",
    "SinkhornResult",
    "solve",
    "solve_annealed",
    "solve_many",
    "arccos_features",
    "data_radius",
    "gaussian_features",
    "gaussian_log_features",
    "gaussian_q",
    "gibbs_kernel",
    "lambert_w0",
    "make_sharded_sinkhorn",
    "nystrom_factors",
    "rot_factored",
    "rot_factored_batched",
    "rot_log_factored",
    "rot_log_factored_batched",
    "sharded_sinkhorn_factored",
    "sinkhorn_divergence_features",
    "sinkhorn_divergence_features_batched",
    "sinkhorn_divergence_gaussian",
    "sinkhorn_divergence_gaussian_batched",
    "sinkhorn_factored",
    "sinkhorn_log_factored",
    "sinkhorn_log_quadratic",
    "sinkhorn_nystrom",
    "sinkhorn_operator",
    "sinkhorn_quadratic",
    "sinkhorn_route",
    "squared_euclidean",
]
