"""Sinkhorn divergence (Eq. 2) on positive-feature kernels.

    Wbar(mu, nu) = W(mu, nu) - 1/2 W(mu, mu) - 1/2 W(nu, nu)

All three terms share ONE feature evaluation per measure (xi for mu, zeta
for nu), so the divergence costs three linear-time solves and two feature
passes. Fully differentiable w.r.t. supports, weights and feature params via
the envelope-theorem VJPs in ``grad.py``.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .features import GaussianFeatureMap, gaussian_log_features
from .grad import rot_factored, rot_log_factored

__all__ = [
    "sinkhorn_divergence_features",
    "sinkhorn_divergence_gaussian",
]


def sinkhorn_divergence_features(
    xi: jax.Array,
    zeta: jax.Array,
    a: jax.Array,
    b: jax.Array,
    *,
    eps: float,
    tol: float = 1e-6,
    max_iter: int = 2000,
    log_domain: bool = False,
) -> jax.Array:
    """Wbar from precomputed (log-)features. ``xi``/``zeta`` are (n,r)/(m,r);
    if ``log_domain`` they are log-features."""
    rot = rot_log_factored if log_domain else rot_factored
    if log_domain:
        w_xy = rot(xi, zeta, a, b, eps, tol, max_iter)
        w_xx = rot(xi, xi, a, a, eps, tol, max_iter)
        w_yy = rot(zeta, zeta, b, b, eps, tol, max_iter)
    else:
        w_xy = rot(xi, zeta, a, b, eps, tol, max_iter, 1.0)
        w_xx = rot(xi, xi, a, a, eps, tol, max_iter, 1.0)
        w_yy = rot(zeta, zeta, b, b, eps, tol, max_iter, 1.0)
    return w_xy - 0.5 * (w_xx + w_yy)


def sinkhorn_divergence_gaussian(
    x: jax.Array,
    y: jax.Array,
    anchors: jax.Array,
    *,
    eps: float,
    q: float,
    a: Optional[jax.Array] = None,
    b: Optional[jax.Array] = None,
    tol: float = 1e-6,
    max_iter: int = 2000,
    log_domain: bool = True,
) -> jax.Array:
    """End-to-end divergence between point clouds with Lemma-1 features.

    Differentiable in ``x``, ``y`` (measure locations) and ``anchors``
    (the learnable theta of the paper's GAN objective, Eq. 18).
    """
    n, m = x.shape[0], y.shape[0]
    a = jnp.full((n,), 1.0 / n, x.dtype) if a is None else a
    b = jnp.full((m,), 1.0 / m, y.dtype) if b is None else b
    lxi = gaussian_log_features(x, anchors, eps=eps, q=q)
    lzeta = gaussian_log_features(y, anchors, eps=eps, q=q)
    if log_domain:
        return sinkhorn_divergence_features(
            lxi, lzeta, a, b, eps=eps, tol=tol, max_iter=max_iter,
            log_domain=True,
        )
    return sinkhorn_divergence_features(
        jnp.exp(lxi), jnp.exp(lzeta), a, b, eps=eps, tol=tol,
        max_iter=max_iter, log_domain=False,
    )
