"""Sinkhorn divergence (Eq. 2) on any Geometry.

    Wbar(mu, nu) = W(mu, nu) - 1/2 W(mu, mu) - 1/2 W(nu, nu)

The three terms share ONE parametrization: a Geometry supplies the (mu, nu)
kernel and its ``xx()``/``yy()`` self-geometries supply the two correction
terms, so the divergence costs three linear-time solves and (for factored
families) two feature passes. Fully differentiable w.r.t. supports, weights
and feature params via the envelope-theorem VJPs in ``grad.py`` — the
generic :func:`~repro.core.grad.rot_geometry` for the log-domain path, the
specialized scaling-space rule for positive features.

The ``*_batched`` variants evaluate B independent divergences (the OT-GAN
minibatch objective, Section 4) through the batched envelope VJPs — one
vmapped solve per term instead of 3B separate solver dispatches.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .features import gaussian_log_features
from .geometry import FactoredPositive, Geometry
from .grad import (
    rot_factored,
    rot_factored_batched,
    rot_geometry,
)

__all__ = [
    "sinkhorn_divergence_geometry",
    "sinkhorn_divergence_features",
    "sinkhorn_divergence_features_batched",
    "sinkhorn_divergence_gaussian",
    "sinkhorn_divergence_gaussian_batched",
]


def sinkhorn_divergence_geometry(
    geom: Geometry,
    a: Optional[jax.Array] = None,
    b: Optional[jax.Array] = None,
    *,
    tol: float = 1e-6,
    max_iter: int = 2000,
    mesh=None,
    mesh_axis: str = "data",
    use_pallas=None,
    inner_steps=None,
    check_every=None,
    precision: str = "highest",
) -> jax.Array:
    """Wbar on any log-capable Geometry with per-measure parametrization
    (factored, point-cloud, arccos, grid — families defining ``xx``/``yy``
    self-geometries; a bare DenseCost carries no (mu, mu) cost and cannot
    form the correction terms). Differentiable in the geometry's arrays
    and weights.

    With ``mesh=`` the three solves run inside one ``shard_map``: supports
    shard over ``mesh_axis``, each envelope solve uses the psum'd-LSE
    operators (one r-vector collective per half-iteration), and the same
    ``rot_geometry`` VJP keeps the result differentiable — including
    w.r.t. replicated leaves like shared anchors.

    ``use_pallas``/``inner_steps``/``check_every``/``precision`` are the
    execution-policy knobs of each forward solve (fused plan, megakernel
    cadence, bf16 factor storage — see ``sinkhorn_log_geometry``); they do
    not apply to the ``mesh=`` path, where sharded geometries always run
    the psum'd XLA operators."""
    if mesh is not None:
        from .sharded import sharded_sinkhorn_divergence

        return sharded_sinkhorn_divergence(
            mesh, geom, a, b, axis=mesh_axis, tol=tol, max_iter=max_iter,
        )
    n, m = geom.shape
    a = jnp.full((n,), 1.0 / n) if a is None else a
    b = jnp.full((m,), 1.0 / m) if b is None else b
    kw = dict(use_pallas=use_pallas, inner_steps=inner_steps,
              check_every=check_every, precision=precision)
    w_xy = rot_geometry(geom, a, b, tol, max_iter, **kw)
    w_xx = rot_geometry(geom.xx(), a, a, tol, max_iter, **kw)
    w_yy = rot_geometry(geom.yy(), b, b, tol, max_iter, **kw)
    return w_xy - 0.5 * (w_xx + w_yy)


def sinkhorn_divergence_features(
    xi: jax.Array,
    zeta: jax.Array,
    a: jax.Array,
    b: jax.Array,
    *,
    eps: float,
    tol: float = 1e-6,
    max_iter: int = 2000,
    log_domain: bool = False,
) -> jax.Array:
    """Wbar from precomputed (log-)features. ``xi``/``zeta`` are (n,r)/(m,r);
    if ``log_domain`` they are log-features."""
    if log_domain:
        geom = FactoredPositive(log_xi=xi, log_zeta=zeta, eps=eps)
        return sinkhorn_divergence_geometry(
            geom, a, b, tol=tol, max_iter=max_iter
        )
    # scaling-space path keeps the specialized factored envelope rule
    w_xy = rot_factored(xi, zeta, a, b, eps, tol, max_iter, 1.0)
    w_xx = rot_factored(xi, xi, a, a, eps, tol, max_iter, 1.0)
    w_yy = rot_factored(zeta, zeta, b, b, eps, tol, max_iter, 1.0)
    return w_xy - 0.5 * (w_xx + w_yy)


def sinkhorn_divergence_gaussian(
    x: jax.Array,
    y: jax.Array,
    anchors: jax.Array,
    *,
    eps: float,
    q: float,
    a: Optional[jax.Array] = None,
    b: Optional[jax.Array] = None,
    tol: float = 1e-6,
    max_iter: int = 2000,
    log_domain: bool = True,
) -> jax.Array:
    """End-to-end divergence between point clouds with Lemma-1 features.

    Differentiable in ``x``, ``y`` (measure locations) and ``anchors``
    (the learnable theta of the paper's GAN objective, Eq. 18).
    """
    n, m = x.shape[0], y.shape[0]
    a = jnp.full((n,), 1.0 / n, x.dtype) if a is None else a
    b = jnp.full((m,), 1.0 / m, y.dtype) if b is None else b
    lxi = gaussian_log_features(x, anchors, eps=eps, q=q)
    lzeta = gaussian_log_features(y, anchors, eps=eps, q=q)
    if log_domain:
        return sinkhorn_divergence_features(
            lxi, lzeta, a, b, eps=eps, tol=tol, max_iter=max_iter,
            log_domain=True,
        )
    return sinkhorn_divergence_features(
        jnp.exp(lxi), jnp.exp(lzeta), a, b, eps=eps, tol=tol,
        max_iter=max_iter, log_domain=False,
    )


# ---------------------------------------------------------------------------
# Batched variants (GAN-minibatch workload: B independent divergences)
# ---------------------------------------------------------------------------


def sinkhorn_divergence_features_batched(
    xi: jax.Array,          # (B, n, r) (log-)features per problem
    zeta: jax.Array,        # (B, m, r)
    a: jax.Array,           # (B, n)
    b: jax.Array,           # (B, m)
    *,
    eps: float,
    tol: float = 1e-6,
    max_iter: int = 2000,
    log_domain: bool = False,
) -> jax.Array:
    """Stacked Wbar, shape (B,). Three batched solves, each vmapped over
    the batch — differentiable through the batched envelope VJPs (the
    per-slice Geometry is built inside the vmapped body)."""
    if log_domain:
        def rot(p, q_, w, z):
            return jax.vmap(
                lambda p_, q__, w_, z_: rot_geometry(
                    FactoredPositive(log_xi=p_, log_zeta=q__, eps=eps),
                    w_, z_, tol, max_iter)
            )(p, q_, w, z)
    else:
        def rot(p, q_, w, z):
            return rot_factored_batched(p, q_, w, z, eps, tol, max_iter, 1.0)
    w_xy = rot(xi, zeta, a, b)
    w_xx = rot(xi, xi, a, a)
    w_yy = rot(zeta, zeta, b, b)
    return w_xy - 0.5 * (w_xx + w_yy)


def sinkhorn_divergence_gaussian_batched(
    x: jax.Array,           # (B, n, d) point clouds
    y: jax.Array,           # (B, m, d)
    anchors: jax.Array,     # (r, d) SHARED Lemma-1 anchors (learnable theta)
    *,
    eps: float,
    q: float,
    a: Optional[jax.Array] = None,
    b: Optional[jax.Array] = None,
    tol: float = 1e-6,
    max_iter: int = 2000,
    log_domain: bool = True,
) -> jax.Array:
    """End-to-end batched divergence, shape (B,): per-problem clouds with
    shared anchors — the exact GAN objective of Eq. 18 over a minibatch.
    Differentiable in ``x``, ``y`` and ``anchors``."""
    B, n, _ = x.shape
    m = y.shape[1]
    a = jnp.full((B, n), 1.0 / n, x.dtype) if a is None else a
    b = jnp.full((B, m), 1.0 / m, y.dtype) if b is None else b
    feat = jax.vmap(
        lambda pts: gaussian_log_features(pts, anchors, eps=eps, q=q)
    )
    lxi, lzeta = feat(x), feat(y)
    if not log_domain:
        lxi, lzeta = jnp.exp(lxi), jnp.exp(lzeta)
    return sinkhorn_divergence_features_batched(
        lxi, lzeta, a, b, eps=eps, tol=tol, max_iter=max_iter,
        log_domain=log_domain,
    )
