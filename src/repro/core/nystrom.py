"""Nystrom low-rank baseline (the paper's ``Nys``, Altschuler et al. '18).

K_tilde = K[:, S] (K[S, S] + lam I)^+ K[S, :]  with S a set of l landmark
columns. Applying K_tilde to a vector costs O(n l) — same asymptotics as the
positive-feature path — BUT entries of K_tilde can be NEGATIVE, so Sinkhorn
scalings can cross zero and the iteration diverges. The paper's Figures 1/3/5
show exactly this at small eps; our benchmark reproduces it (we detect the
failure via non-finite marginal error and report it).

We use uniform landmark sampling + ridge pseudo-inverse; the recursive
leverage-score sampler of [40] changes constants, not the failure mode
(documented deviation in DESIGN.md §6).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .geometry import squared_euclidean
from .sinkhorn import SinkhornResult, sinkhorn_operator

__all__ = ["NystromFactors", "nystrom_factors", "sinkhorn_nystrom"]


class NystromFactors(NamedTuple):
    """K_tilde = L @ Rt  with L (n, l), Rt (l, m)."""

    L: jax.Array
    Rt: jax.Array


def nystrom_factors(
    x: jax.Array,
    y: jax.Array,
    *,
    eps: float,
    rank: int,
    key: jax.Array,
    ridge: float = 1e-10,
) -> NystromFactors:
    """Landmark-Nystrom factorization of the Gibbs kernel exp(-C/eps)."""
    pool = jnp.concatenate([x, y], axis=0)
    idx = jax.random.choice(key, pool.shape[0], (rank,), replace=False)
    z = pool[idx]                                        # (l, d) landmarks
    K_xz = jnp.exp(-squared_euclidean(x, z) / eps)       # (n, l)
    K_zy = jnp.exp(-squared_euclidean(z, y) / eps)       # (l, m)
    K_zz = jnp.exp(-squared_euclidean(z, z) / eps)
    # eigenvalue-truncated pseudo-inverse (stable Nystrom in f32): invert
    # only the spectrum above tau * lambda_max, zero the rest.
    w, Q = jnp.linalg.eigh(K_zz)
    tau = ridge if ridge > 1e-8 else 1e-5
    keep = w > tau * jnp.max(w)
    w_inv = jnp.where(keep, 1.0 / jnp.where(keep, w, 1.0), 0.0)
    inv = (Q * w_inv[None, :]) @ Q.T
    return NystromFactors(L=K_xz @ inv, Rt=K_zy)


def sinkhorn_nystrom(
    factors: NystromFactors,
    a: jax.Array,
    b: jax.Array,
    *,
    eps: float,
    tol: float = 1e-6,
    max_iter: int = 2000,
) -> SinkhornResult:
    """Sinkhorn on the (possibly signed!) Nystrom kernel.

    Divergence shows up as non-finite/negative scalings -> marginal_err goes
    non-finite and ``converged`` stays False; callers treat that as the
    method's documented failure (paper Fig. 1, middle/left panels).
    """
    L, Rt = factors

    def matvec(v):
        return L @ (Rt @ v)

    def rmatvec(u):
        return Rt.T @ (L.T @ u)

    return sinkhorn_operator(
        matvec, rmatvec, a, b, eps=eps, tol=tol, max_iter=max_iter
    )
