"""Nystrom low-rank baseline (the paper's ``Nys``, Altschuler et al. '18).

K_tilde = K[:, S] (K[S, S] + lam I)^+ K[S, :]  with S a set of l landmark
columns. Applying K_tilde to a vector costs O(n l) — same asymptotics as the
positive-feature path — BUT entries of K_tilde can be NEGATIVE, so Sinkhorn
scalings can cross zero and the iteration diverges. The paper's Figures 1/3/5
show exactly this at small eps; our benchmark reproduces it, and the failure
is surfaced as ``SinkhornResult.diverged`` (non-finite marginal blow-up as a
structured flag rather than raw NaNs).

The representation now lives in :class:`repro.core.geometry.NystromLowRank`
— reachable from ``solve(problem, method="nystrom")`` — and this module is
the thin stable wrapper around it. We use uniform landmark sampling + ridge
pseudo-inverse; the recursive leverage-score sampler of [40] changes
constants, not the failure mode (documented deviation in DESIGN.md §6).
"""
from __future__ import annotations

from typing import NamedTuple

import jax

from .geometry import NystromLowRank
from .sinkhorn import SinkhornResult, sinkhorn_geometry

__all__ = ["NystromFactors", "nystrom_factors", "sinkhorn_nystrom"]


class NystromFactors(NamedTuple):
    """K_tilde = L @ Rt  with L (n, l), Rt (l, m)."""

    L: jax.Array
    Rt: jax.Array


def nystrom_factors(
    x: jax.Array,
    y: jax.Array,
    *,
    eps: float,
    rank: int,
    key: jax.Array,
    ridge: float = 1e-10,
) -> NystromFactors:
    """Landmark-Nystrom factorization of the Gibbs kernel exp(-C/eps)."""
    geom = NystromLowRank.from_point_clouds(
        x, y, eps=eps, rank=rank, key=key, ridge=ridge
    )
    return NystromFactors(L=geom.L, Rt=geom.Rt)


def sinkhorn_nystrom(
    factors: NystromFactors,
    a: jax.Array,
    b: jax.Array,
    *,
    eps: float,
    tol: float = 1e-6,
    max_iter: int = 2000,
) -> SinkhornResult:
    """Sinkhorn on the (possibly signed!) Nystrom kernel.

    Divergence shows up as non-finite/negative scalings -> marginal_err goes
    non-finite, ``converged`` stays False and ``diverged`` reports True;
    callers treat that as the method's documented failure (paper Fig. 1,
    middle/left panels).
    """
    geom = NystromLowRank(L=factors.L, Rt=factors.Rt, eps=eps)
    return sinkhorn_geometry(geom, a, b, tol=tol, max_iter=max_iter)
