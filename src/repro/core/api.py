"""Unified solver front-end: ``solve`` / ``BatchedSinkhorn`` / ``EpsSchedule``.

Every solver variant in the repo (scaling-space factored, log-domain
factored, accelerated AGM, dense quadratic baselines, signed Nystrom,
arc-cosine, separable-grid, shard_map distributed) is reachable through ONE
entry point:

    problem = OTProblem.from_point_clouds(x, y, anchors, eps=0.05)
    res = solve(problem, method="log_factored",
                schedule=EpsSchedule(eps_init=1.0, decay=0.5))

and batches of independent problems — the GAN-minibatch workload of the
paper's Section 4, and the "heavy traffic" serving shape of the ROADMAP —
go through the vmapped engine:

    engine = BatchedSinkhorn(eps=0.05, method="log_factored")
    results = engine.solve_many(problems)      # buckets, pads, vmaps

Design notes
------------
* **The Geometry protocol carries the kernel.** An :class:`OTProblem` is a
  thin ``(geometry, a, b)`` record; the geometry (``repro.core.geometry``)
  owns the kernel representation — features, log-features, dense cost,
  point clouds + anchors, Nystrom factors, or grid axes — and exposes the
  operators every solver consumes. There is no representation branching
  here: a ``method`` picks an *algorithm* (scaling-space, log-domain,
  accelerated, densified baseline, sharded) from a dispatch table, and
  every kernel application inside it routes through the geometry.
* **One kernel, many algorithms.** For a problem built from (log-)features
  the quadratic methods run on the *induced* cost ``C = -eps log(Xi Zeta^T)``
  (``geometry.cost_matrix()``) so all methods share one fixed point and
  agree to solver tolerance (the oracle-consistency contract tested in
  ``tests/test_api.py``). Problems built from point clouds use the true
  squared-Euclidean cost for the quadratic methods — the paper's ``Sin``
  baseline — so there the factored methods differ by the
  feature-approximation error (Theorem 3.1).
* **Annealing** (``EpsSchedule``) runs a geometric cascade
  ``eps_0 > eps_0*decay > ... > eps`` re-deriving each stage's kernel via
  ``geometry.rebuild_at(eps_k)`` and warm-starting the potentials (f, g) —
  equivalently ``u = e^{f/eps}`` — between stages. At small eps this cuts
  total iterations by a large factor versus a cold start (property-tested
  in ``tests/test_schedule.py``). Families whose kernel is pinned to one
  eps (explicit features, arc-cosine, Nystrom) cannot be annealed.
* **Batching** pads each problem's supports up to the power-of-two buckets
  in ``configs/shapes.py`` (``ot_bucket``) with ZERO-weight atoms — exact,
  not approximate, because every solver masks zero weights (see
  ``sinkhorn.masked_dual_value``) — groups problems by padded shape, and
  ``vmap``s the shared solver loop over the group. One ``lax.while_loop``
  then drives the whole batch: per-iteration work is a single batched thin
  contraction instead of B separate GEMV dispatches, which is where the
  >= 3x wall-clock win of ``benchmarks/bench_batch.py`` comes from.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from collections import OrderedDict
from functools import partial
from typing import Callable, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..configs.shapes import OTBatchShape
from .accelerated import accelerated_sinkhorn_geometry
from .geometry import (
    ArcCosinePointCloud,
    DenseCost,
    FactoredPositive,
    GaussianPointCloud,
    Geometry,
    GridSeparable,
    NystromLowRank,
    data_radius,
)
from .sinkhorn import (
    SinkhornResult,
    sinkhorn_geometry,
    sinkhorn_log_geometry,
)

__all__ = [
    "METHODS",
    "OTProblem",
    "EpsSchedule",
    "AnnealedResult",
    "BatchedSinkhorn",
    "solve",
    "solve_annealed",
    "solve_many",
    "unpad_result",
    "get_engine",
    "engine_cache_info",
    "set_engine_cache_capacity",
    "clear_engine_cache",
]

METHODS = (
    "auto",
    "factored",
    "log_factored",
    "accelerated",
    "quadratic",
    "log_quadratic",
    "arccos",
    "nystrom",
    "sharded",
    "sharded_log",
)


# ---------------------------------------------------------------------------
# Problem specification: a thin (geometry, a, b) record
# ---------------------------------------------------------------------------


def _uniform(n: int, dtype) -> jax.Array:
    return jnp.full((n,), 1.0 / n, dtype)


@dataclasses.dataclass(frozen=True)
class OTProblem:
    """One entropic OT problem: a Geometry (the kernel) plus marginals.

    The geometry owns the kernel representation; ``a``/``b`` are the
    measure weights (zeros allowed — zero-weight atoms are masked exactly
    by every solver, which is what makes bucket padding exact). The
    ``from_*`` constructors below are the stable public surface; kernel
    views (features, costs) live on the geometry itself.
    """

    geometry: Geometry
    a: jax.Array                       # (n,) weights, sum 1 (zeros allowed)
    b: jax.Array                       # (m,)

    def __post_init__(self):
        if not isinstance(self.geometry, Geometry):
            raise TypeError(
                "OTProblem.geometry must be a Geometry; build one via the "
                "from_* constructors or repro.core.geometry"
            )

    @property
    def eps(self) -> float:
        return self.geometry.eps

    # -- constructors -------------------------------------------------------

    @classmethod
    def from_geometry(cls, geometry: Geometry, a=None, b=None) -> "OTProblem":
        n, m = geometry.shape
        a = _uniform(n, jnp.float32) if a is None else a
        b = _uniform(m, jnp.float32) if b is None else b
        return cls(geometry=geometry, a=a, b=b)

    @classmethod
    def from_features(cls, xi, zeta, a=None, b=None, *, eps: float) -> "OTProblem":
        return cls.from_geometry(
            FactoredPositive(xi=xi, zeta=zeta, eps=eps),
            _uniform(xi.shape[0], xi.dtype) if a is None else a,
            _uniform(zeta.shape[0], zeta.dtype) if b is None else b,
        )

    @classmethod
    def from_log_features(cls, log_xi, log_zeta, a=None, b=None, *,
                          eps: float) -> "OTProblem":
        return cls.from_geometry(
            FactoredPositive(log_xi=log_xi, log_zeta=log_zeta, eps=eps),
            _uniform(log_xi.shape[0], log_xi.dtype) if a is None else a,
            _uniform(log_zeta.shape[0], log_zeta.dtype) if b is None else b,
        )

    @classmethod
    def from_cost(cls, C, a=None, b=None, *, eps: float) -> "OTProblem":
        return cls.from_geometry(
            DenseCost(C, eps),
            _uniform(C.shape[0], C.dtype) if a is None else a,
            _uniform(C.shape[1], C.dtype) if b is None else b,
        )

    @classmethod
    def from_point_clouds(cls, x, y, anchors, a=None, b=None, *, eps: float,
                          R: Optional[float] = None) -> "OTProblem":
        return cls.from_geometry(
            GaussianPointCloud.build(x, y, anchors, eps=eps, R=R),
            _uniform(x.shape[0], x.dtype) if a is None else a,
            _uniform(y.shape[0], y.dtype) if b is None else b,
        )

    @classmethod
    def from_grid(cls, axes_x, axes_y=None, a=None, b=None, *,
                  eps: float) -> "OTProblem":
        """Separable-grid problem (images / histograms): measures live on
        the cartesian product of the axis coordinates, weights in C order
        (``image.reshape(-1)``)."""
        return cls.from_geometry(
            GridSeparable.build(axes_x, axes_y, eps=eps), a, b
        )

    @property
    def anneal_capable(self) -> bool:
        return self.geometry.anneal_capable


# ---------------------------------------------------------------------------
# Epsilon annealing
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EpsSchedule:
    """Geometric eps cascade: eps_0, eps_0*decay, ... down to the target.

    Intermediate stages only need to hand a decent warm start to the next
    stage, so they stop at a LOOSE tolerance: stage tolerances decay
    geometrically from ``stage_tol`` down to ``sqrt(stage_tol * tol)`` —
    the final stage does the last push to ``tol`` (``stage_tols``). At run
    time each stage's target is additionally capped at the previous stage's
    ACHIEVED error, which makes the per-stage marginal error non-increasing
    by construction. Each intermediate stage is also capped at
    ``stage_iters`` iterations; the final stage gets the caller's full
    ``max_iter``.
    """

    eps_init: float
    decay: float = 0.5
    stage_iters: int = 400
    stage_tol: float = 1e-2

    def __post_init__(self):
        if not (0.0 < self.decay < 1.0):
            raise ValueError(f"decay must be in (0, 1), got {self.decay}")
        if self.eps_init <= 0:
            raise ValueError("eps_init must be positive")

    def stages(self, eps_final: float) -> Tuple[float, ...]:
        if self.eps_init <= eps_final:
            return (eps_final,)
        out = []
        e = self.eps_init
        # stop the geometric ladder once e is within sqrt(decay) of the
        # target and jump straight there — a penultimate stage a few
        # percent above eps_final would cost a full solve for no progress
        thresh = eps_final / math.sqrt(self.decay)
        while e > thresh:
            out.append(e)
            e *= self.decay
        out.append(eps_final)
        return tuple(out)

    def stage_tols(self, tol_final: float, n_stages: int) -> Tuple[float, ...]:
        """Per-stage marginal-error targets: geometric from ``stage_tol``
        down to sqrt(stage_tol * tol_final) across the intermediates, then
        ``tol_final``. Keeping intermediates loose is what buys the total-
        iteration win — tight intermediate solves at large eps do not
        transfer into a proportionally better warm start."""
        if n_stages <= 1 or self.stage_tol <= tol_final:
            return (tol_final,) * max(n_stages, 1)
        if n_stages == 2:
            return (self.stage_tol, tol_final)
        mid = math.sqrt(self.stage_tol * tol_final)
        ratio = (mid / self.stage_tol) ** (1.0 / (n_stages - 2))
        tols = [max(self.stage_tol * ratio**k, tol_final)
                for k in range(n_stages - 1)]
        return tuple(tols) + (tol_final,)


class AnnealedResult(NamedTuple):
    result: SinkhornResult            # final-stage solve (n_iter = TOTAL)
    stage_eps: Tuple[float, ...]
    stage_iters: jax.Array            # (S,) iterations per stage
    stage_errs: jax.Array             # (S,) marginal error at stage exit


# ---------------------------------------------------------------------------
# Dispatch: method -> (geometry coercion, solver runner)
# ---------------------------------------------------------------------------
#
# A method names an ALGORITHM; the geometry supplies the kernel operators.
# Coercers turn the problem's geometry into the one the algorithm runs on
# (identity for native methods, densification for the quadratic baselines,
# cost-family conversion for arccos / nystrom); runners call the matching
# operator-generic solver. No kernel application happens outside a Geometry.


def _run_scaling(geom, a, b, *, tol, max_iter, momentum, f_init, g_init,
                 mesh, mesh_axis, use_pallas=None, inner_steps=None,
                 check_every=None, precision="highest"):
    u_init = None if f_init is None else jnp.exp(f_init / geom.eps)
    return sinkhorn_geometry(
        geom, a, b, tol=tol, max_iter=max_iter, momentum=momentum,
        u_init=u_init, use_pallas=use_pallas, inner_steps=inner_steps,
        check_every=check_every, precision=precision,
    )


def _run_log(geom, a, b, *, tol, max_iter, momentum, f_init, g_init,
             mesh, mesh_axis, use_pallas=None, inner_steps=None,
             check_every=None, precision="highest"):
    return sinkhorn_log_geometry(
        geom, a, b, tol=tol, max_iter=max_iter, momentum=momentum,
        f_init=f_init, g_init=g_init, use_pallas=use_pallas,
        inner_steps=inner_steps, check_every=check_every,
        precision=precision,
    )


def _run_accelerated(geom, a, b, *, tol, max_iter, momentum, f_init, g_init,
                     mesh, mesh_axis, use_pallas=None, inner_steps=None,
                     check_every=None, precision="highest"):
    # AGM's Nesterov extrapolation IS its acceleration — an extra
    # over-relaxation has no defined place in the scheme, so reject rather
    # than silently drop it. The dual-gradient structure also keeps this
    # solver on the XLA log-operators (use_pallas is ignored), so the
    # megakernel block (inner_steps) is rejected too; the check cadence
    # applies as everywhere else.
    if momentum != 1.0:
        raise ValueError(
            "momentum (over-relaxation) is not supported by "
            "method='accelerated': the AGM extrapolation already plays "
            f"that role; got momentum={momentum}. Use momentum=1.0 or a "
            "plain method ('factored', 'log_factored', ...)."
        )
    if inner_steps is not None and int(inner_steps) > 1:
        raise ValueError(
            "inner_steps > 1 (the persistent megakernel) is not available "
            "for method='accelerated': the AGM body interleaves gradient "
            "extrapolation with exact block steps and has no fused plan. "
            "Use check_every= for the cadence win, or a plain method."
        )
    if precision != "highest":
        raise ValueError(
            "method='accelerated' differentiates the smoothed dual through "
            "its log-operators; the bf16 storage policy is not supported "
            f"here (got precision={precision!r})"
        )
    return accelerated_sinkhorn_geometry(
        geom, a, b, tol=tol, max_iter=max_iter, f_init=f_init, g_init=g_init,
        check_every=1 if check_every is None else check_every,
    )


def _run_sharded(geom, a, b, *, tol, max_iter, momentum, f_init, g_init,
                 mesh, mesh_axis, use_pallas=None, inner_steps=None,
                 check_every=None, precision="highest", mode="scaling"):
    from .sharded import sharded_sinkhorn_geometry

    if mesh is None:
        raise ValueError(f"method='sharded{'_log' * (mode == 'log')}' "
                         "requires a mesh=...")
    return sharded_sinkhorn_geometry(
        mesh, geom, a, b, axis=mesh_axis, mode=mode, tol=tol,
        max_iter=max_iter, momentum=momentum, f_init=f_init, g_init=g_init,
        inner_steps=inner_steps, check_every=check_every,
        precision=precision,
    )


def _coerce_native_factored(geom, eps, *, rank, key):
    if isinstance(geom, DenseCost):
        raise ValueError(
            "no factored kernel available (dense-cost problem); use a "
            "quadratic method or build the problem from point clouds"
        )
    return geom


def _coerce_identity(geom, eps, *, rank, key):
    return geom


def _coerce_densify(geom, eps, *, rank, key):
    if isinstance(geom, DenseCost):
        return geom
    return DenseCost(geom.cost_matrix(), eps)


def _coerce_arccos(geom, eps, *, rank, key):
    if isinstance(geom, ArcCosinePointCloud):
        return geom
    if isinstance(geom, GaussianPointCloud):
        # swap the cost family on the same supports: fresh arc-cosine
        # anchors (u ~ N(0, sigma^2 I)), rank defaulting to the problem's
        # existing anchor count
        from .features import ArcCosineFeatureMap

        r = geom.anchors.shape[0] if rank is None else rank
        fm = ArcCosineFeatureMap(r=r, d=geom.x.shape[-1])
        anchors = fm.init(jax.random.PRNGKey(0) if key is None else key)
        return ArcCosinePointCloud(
            geom.x, geom.y, anchors, eps=eps, s=fm.s, sigma=fm.sigma,
            kappa=fm.kappa,
        )
    raise ValueError(
        "method='arccos' needs point-cloud supports (an ArcCosinePointCloud "
        f"or GaussianPointCloud geometry); got {type(geom).__name__}"
    )


def _coerce_nystrom(geom, eps, *, rank, key):
    if isinstance(geom, NystromLowRank):
        return geom
    if isinstance(geom, (GaussianPointCloud, ArcCosinePointCloud)):
        r = geom.anchors.shape[0] if rank is None else rank
        return NystromLowRank.from_point_clouds(
            geom.x, geom.y, eps=eps, rank=r,
            key=jax.random.PRNGKey(0) if key is None else key,
        )
    raise ValueError(
        "method='nystrom' needs point-cloud supports (a NystromLowRank or "
        f"point-cloud geometry); got {type(geom).__name__}"
    )


# method -> (coerce geometry, runner). The only dispatch table in the file.
_SOLVERS: Dict[str, Tuple[Callable, Callable]] = {
    "factored": (_coerce_native_factored, _run_scaling),
    "log_factored": (_coerce_native_factored, _run_log),
    "accelerated": (_coerce_native_factored, _run_accelerated),
    "quadratic": (_coerce_densify, _run_scaling),
    "log_quadratic": (_coerce_densify, _run_log),
    "arccos": (_coerce_arccos, _run_log),
    "nystrom": (_coerce_nystrom, _run_scaling),
    "sharded": (_coerce_native_factored,
                partial(_run_sharded, mode="scaling")),
    "sharded_log": (_coerce_native_factored,
                    partial(_run_sharded, mode="log")),
}

# auto-dispatch table: first matching geometry type wins; factored
# geometries carrying linear-space features prefer the scaling solver.
_AUTO_METHODS: Tuple[Tuple[type, str], ...] = (
    (NystromLowRank, "nystrom"),
    (ArcCosinePointCloud, "arccos"),
    (DenseCost, "log_quadratic"),
    (GridSeparable, "log_factored"),
    (GaussianPointCloud, "log_factored"),
)


def _auto_method(problem: OTProblem, mesh=None) -> str:
    g = problem.geometry
    local = None
    for typ, meth in _AUTO_METHODS:
        if isinstance(g, typ):
            local = meth
            break
    if local is None:
        local = ("factored"
                 if isinstance(g, FactoredPositive) and g.xi is not None
                 else "log_factored")
    if mesh is None:
        return local
    # mesh given: select the sharded execution mode, scaling vs log
    # EXACTLY like the local table — explicit linear factors keep the
    # scaling iteration, every other family runs the psum'd-LSE log
    # domain (mandatory at the small eps where scalings over/underflow)
    return "sharded" if local == "factored" else "sharded_log"


def _solve_stage(
    problem: OTProblem,
    method: str,
    eps: float,
    *,
    tol: float,
    max_iter: int,
    momentum: float,
    f_init: Optional[jax.Array],
    g_init: Optional[jax.Array],
    mesh=None,
    mesh_axis: str = "data",
    rank: Optional[int] = None,
    key: Optional[jax.Array] = None,
    use_pallas: Optional[bool] = None,
    inner_steps: Optional[int] = None,
    check_every: Optional[int] = None,
    precision: str = "highest",
    donate: bool = False,
) -> SinkhornResult:
    """One solve at a fixed eps with optional warm-started potentials.

    ``donate=True`` routes the stage through a jitted runner that DONATES
    the warm-start potentials (``f_init``/``g_init``): an annealed cascade
    re-solving at each eps then reuses the previous stage's potential
    buffers instead of holding two copies live per stage. Only taken when
    the potentials are concrete arrays (donating under an outer trace is
    meaningless) and the solve is single-device.
    """
    if method not in _SOLVERS:
        raise ValueError(f"unknown method {method!r}; expected one of {METHODS}")
    if mesh is not None and not method.startswith("sharded"):
        # a mesh must never be silently dropped: local methods with a
        # sharded twin are promoted (matching solve_many's mapping),
        # everything else is rejected rather than run single-device
        twin = _SHARDED_TWIN.get(method)
        if twin is None or twin == "auto":
            raise ValueError(
                f"method={method!r} does not run on a mesh; with mesh= use "
                "method='auto', 'factored'/'sharded', or "
                "'log_factored'/'sharded_log'"
            )
        method = twin
    coerce, run = _SOLVERS[method]
    geom = coerce(problem.geometry.rebuild_at(eps), eps, rank=rank, key=key)
    if (donate and mesh is None
            and isinstance(f_init, jax.Array)
            and isinstance(g_init, jax.Array)
            and not isinstance(f_init, jax.core.Tracer)
            and not isinstance(g_init, jax.core.Tracer)):
        fn = _donating_stage_runner(
            method, int(max_iter), float(momentum), use_pallas,
            inner_steps, check_every, precision,
        )
        return fn(geom, problem.a, problem.b, f_init, g_init, tol)
    return run(
        geom, problem.a, problem.b, tol=tol, max_iter=max_iter,
        momentum=momentum, f_init=f_init, g_init=g_init, mesh=mesh,
        mesh_axis=mesh_axis, use_pallas=use_pallas,
        inner_steps=inner_steps, check_every=check_every,
        precision=precision,
    )


_DONATING_STAGE_CACHE: Dict[Tuple, Callable] = {}


def _donating_stage_runner(method, max_iter, momentum, use_pallas,
                           inner_steps, check_every, precision) -> Callable:
    """Jitted per-stage runner with the warm-start potentials donated.

    Keyed on every trace-time constant; the geometry rides as a pytree
    argument (its static metadata — eps, kinds — keys the jit cache), so
    an annealing cascade compiles one executable per stage eps and the
    potentials handed from stage k to stage k+1 give their buffers back.
    """
    key = (method, max_iter, momentum, use_pallas, inner_steps,
           check_every, precision)
    fn = _DONATING_STAGE_CACHE.get(key)
    if fn is None:
        run = _SOLVERS[method][1]

        @partial(jax.jit, donate_argnums=(3, 4))
        def fn(geom, a, b, f_init, g_init, tol):
            return run(
                geom, a, b, tol=tol, max_iter=max_iter, momentum=momentum,
                f_init=f_init, g_init=g_init, mesh=None, mesh_axis="data",
                use_pallas=use_pallas, inner_steps=inner_steps,
                check_every=check_every, precision=precision,
            )

        _DONATING_STAGE_CACHE[key] = fn
    return fn


def solve_annealed(
    problem: OTProblem,
    *,
    method: str = "auto",
    schedule: EpsSchedule,
    tol: float = 1e-6,
    max_iter: int = 2000,
    momentum: float = 1.0,
    mesh=None,
    mesh_axis: str = "data",
    rank: Optional[int] = None,
    key: Optional[jax.Array] = None,
    use_pallas: Optional[bool] = None,
    inner_steps: Optional[int] = None,
    check_every: Optional[int] = None,
    precision: str = "highest",
) -> AnnealedResult:
    """Annealed solve with per-stage diagnostics.

    Each stage solves at eps_k re-deriving the kernel via
    ``geometry.rebuild_at``, then hands its potentials (f, g) to the next
    stage as warm start. The returned ``result.n_iter`` is the TOTAL across
    stages so it compares directly against a cold-start solve's iteration
    count.
    """
    if method == "auto":
        method = _auto_method(problem, mesh)
    if not problem.geometry.anneal_capable:
        raise ValueError(
            "eps-annealing needs a geometry whose kernel is re-derivable at "
            f"any eps; {type(problem.geometry).__name__} pins the kernel to "
            "one eps. Build the problem from point clouds, a dense cost, or "
            "grid axes to enable annealing."
        )
    # NOTE: the stage loop below (ladder tols, prev_err cap, warm-started
    # f/g, total-iteration accumulation) has a vmap-compatible twin in
    # BatchedSinkhorn._make_cloud_solver — keep their semantics in sync.
    stages = schedule.stages(problem.eps)
    tols = schedule.stage_tols(tol, len(stages))
    f = g = None
    prev_err = None
    stage_iters, stage_errs = [], []
    res = None
    for k, e in enumerate(stages):
        last = k == len(stages) - 1
        # cap at the previous stage's achieved error -> per-stage marginal
        # error is non-increasing by construction
        tol_k = tols[k] if prev_err is None else jnp.minimum(tols[k], prev_err)
        res = _solve_stage(
            problem, method, e,
            tol=tol_k,
            max_iter=max_iter if last else schedule.stage_iters,
            momentum=momentum, f_init=f, g_init=g,
            mesh=mesh, mesh_axis=mesh_axis, rank=rank, key=key,
            use_pallas=use_pallas, inner_steps=inner_steps,
            check_every=check_every, precision=precision,
            # warm-started stages donate the previous stage's potential
            # buffers (two fewer live (n,)+(m,) copies per stage)
            donate=k > 0,
        )
        prev_err = res.marginal_err
        f, g = res.f, res.g
        stage_iters.append(res.n_iter)
        stage_errs.append(res.marginal_err)
    total = jnp.sum(jnp.stack(stage_iters))
    final = res._replace(n_iter=total)
    return AnnealedResult(
        final, stages, jnp.stack(stage_iters), jnp.stack(stage_errs)
    )


def solve(
    problem: OTProblem,
    *,
    method: str = "auto",
    schedule: Optional[EpsSchedule] = None,
    tol: float = 1e-6,
    max_iter: int = 2000,
    momentum: float = 1.0,
    mesh=None,
    mesh_axis: str = "data",
    rank: Optional[int] = None,
    key: Optional[jax.Array] = None,
    use_pallas: Optional[bool] = None,
    inner_steps: Optional[int] = None,
    check_every: Optional[int] = None,
    precision: str = "highest",
) -> SinkhornResult:
    """Solve one entropic OT problem with any solver variant in the repo.

    The preferred calling convention is ONE argument — a
    :class:`~repro.core.spec.SolveSpec` — which carries the geometry,
    weights, target (tol/max_iter/schedule) and an
    :class:`~repro.core.objective.ExecutionPolicy`::

        solve(SolveSpec(geometry=geom, tol=1e-6,
                        policy=ExecutionPolicy(precision="bf16")))

    The keyword form below remains as a back-compat wrapper; passing the
    legacy execution kwargs (``use_pallas=``/``inner_steps=``/
    ``check_every=``/``precision=``) with a bare problem emits a
    ``DeprecationWarning``.

    ``method``: "auto" | "factored" | "log_factored" | "accelerated" |
    "quadratic" | "log_quadratic" | "arccos" | "nystrom" | "sharded" |
    "sharded_log" (both need ``mesh``). "auto" dispatches on the
    problem's geometry type (and onto the sharded twins under ``mesh``).
    ``schedule``: optional :class:`EpsSchedule` eps-annealing cascade
    (anneal-capable geometries only).
    ``rank``/``key``: optional knobs for the cost-family converting
    methods — "arccos" draws ``rank`` fresh arc-cosine anchors with
    ``key``; "nystrom" samples ``rank`` landmarks with ``key``. A
    Nystrom run that blows up at small eps reports
    ``result.diverged == True`` (the paper's Fig. 1/3/5 failure mode)
    instead of handing back unexplained NaNs.
    ``mesh``/``mesh_axis``: run on a device mesh — with ``method="auto"``
    the solver picks the sharded execution mode matching the local table
    (scaling for explicit linear factors, psum'd-LSE log domain for
    everything else); ``method="sharded"``/``"sharded_log"`` force one.
    Supports shard over ``mesh_axis`` (padded with inert zero-weight
    atoms when ``n % p != 0``); per-iteration cross-device traffic is a
    single r-vector collective.
    ``use_pallas``: route the solver hot loop through the fused Pallas
    plan the geometry declares (``None`` = auto-on when the backend
    compiles Pallas, i.e. TPU; ``True`` forces it — interpret mode
    off-TPU; ``False`` forces the XLA operators). Families without a
    fused plan fall back to XLA operators either way.
    ``inner_steps``: iterations fused into ONE persistent megakernel
    launch (``kernels.fused_loop``: factors VMEM-resident, potentials
    on-chip, marginal error only at block boundaries) when the fused
    plan offers one. ``check_every``: convergence-check cadence in
    iterations (must be a multiple of ``inner_steps``); the XLA paths
    get the same fewer-syncs win from it. Auto (both ``None``): 8/8 on
    compiled TPU fused plans whose factors fit VMEM, 1/1 everywhere
    else. Converged results always satisfy ``err <= tol``; ``n_iter``
    becomes a multiple of the cadence and ``max_iter`` rounds up to one.
    Sharded methods reject ``inner_steps > 1`` (the block would drop the
    per-iteration psum) but honor ``check_every``.
    ``precision``: ``"highest"`` (default) or ``"bf16"`` — the
    mixed-precision execution policy: kernel factors (features,
    log-features, dense Gibbs kernels, low-rank factors) are STORED and
    STREAMED in bfloat16, halving the HBM bytes the memory-bound
    iteration streams, while every contraction and LSE accumulates in
    f32. Expect cost agreement with fp32 at the bf16 relative rounding
    (~1e-2 on potentials at moderate eps; tighter on costs); keep
    ``"highest"`` for small-eps log solves where log-features span
    hundreds of nats.
    """
    from .spec import SolveSpec  # lazy: spec imports this module

    if isinstance(problem, SolveSpec):
        spec = problem
        if spec.recovery is not None:
            from ..resilience.ladder import solve_with_recovery
            return solve_with_recovery(spec).result
        kw = spec.solver_kwargs()
        kw.pop("method")
        kw.pop("schedule")
        with spec.policy.scope():
            prob = spec.problem()
            meth = spec.method
            if meth == "auto":
                meth = _auto_method(prob, spec.policy.mesh)
            if spec.schedule is not None:
                return solve_annealed(
                    prob, method=meth, schedule=spec.schedule, **kw
                ).result
            return _solve_stage(
                prob, meth, prob.eps, f_init=None, g_init=None, **kw)
    if (use_pallas is not None or inner_steps is not None
            or check_every is not None or precision != "highest"):
        warnings.warn(
            "passing execution kwargs (use_pallas=/inner_steps=/"
            "check_every=/precision=) to solve() directly is deprecated: "
            "build a SolveSpec with an ExecutionPolicy "
            "(repro.core.spec) and call solve(spec)",
            DeprecationWarning, stacklevel=2)
    if method == "auto":
        method = _auto_method(problem, mesh)
    if schedule is not None:
        return solve_annealed(
            problem, method=method, schedule=schedule, tol=tol,
            max_iter=max_iter, momentum=momentum, mesh=mesh,
            mesh_axis=mesh_axis, rank=rank, key=key, use_pallas=use_pallas,
            inner_steps=inner_steps, check_every=check_every,
            precision=precision,
        ).result
    return _solve_stage(
        problem, method, problem.eps, tol=tol, max_iter=max_iter,
        momentum=momentum, f_init=None, g_init=None, mesh=mesh,
        mesh_axis=mesh_axis, rank=rank, key=key, use_pallas=use_pallas,
        inner_steps=inner_steps, check_every=check_every,
        precision=precision,
    )


# ---------------------------------------------------------------------------
# Batched engine
# ---------------------------------------------------------------------------


def _pad_rows(arr: jax.Array, n_pad: int, *, replicate: bool,
              fill: float = 0.0) -> jax.Array:
    """Pad axis 0 to n_pad: replicate the last row (features / supports —
    keeps log-features finite) or append ``fill`` (0 for weights/scalings,
    -inf for the sharded path's padded log-potentials). Shared by the
    batched engine and ``core.sharded`` so the padding semantics live in
    one place."""
    pad = n_pad - arr.shape[0]
    if pad <= 0:
        return arr
    if replicate:
        tail = jnp.broadcast_to(arr[-1:], (pad,) + arr.shape[1:])
    else:
        tail = jnp.full((pad,) + arr.shape[1:], fill, arr.dtype)
    return jnp.concatenate([arr, tail], axis=0)


# Batched-engine dispatch: method -> (stacked kernel data -> Geometry).
# ka/kb are one problem's slices of the stacked arrays; the builders run
# INSIDE the vmapped solver body, so every kernel application in the
# batched hot loop routes through the same Geometry operators as the
# single-problem path.
_ENGINE_GEOMETRIES: Dict[str, Callable[..., Geometry]] = {
    "factored": lambda ka, kb, eps: FactoredPositive(xi=ka, zeta=kb, eps=eps),
    "log_factored": lambda ka, kb, eps: FactoredPositive(
        log_xi=ka, log_zeta=kb, eps=eps),
    "accelerated": lambda ka, kb, eps: FactoredPositive(
        log_xi=ka, log_zeta=kb, eps=eps),
    "quadratic": lambda ka, kb, eps: DenseCost(ka, eps),
    "log_quadratic": lambda ka, kb, eps: DenseCost(ka, eps),
}

# runners are shared with the single-problem path: same method, same
# algorithm, whether vmapped or not
_ENGINE_RUNNERS: Dict[str, Callable] = {
    m: _SOLVERS[m][1] for m in _ENGINE_GEOMETRIES
}


class BatchedSinkhorn:
    """vmapped solver engine for batches of independent OT problems.

    All problems in a batch share the feature rank r (same anchors in the
    GAN workload); supports are padded to the power-of-two buckets of
    ``configs.shapes.ot_bucket`` with zero-weight atoms, which the masked
    solvers treat exactly. One jitted ``vmap`` of the shared solver loop
    drives each bucket group, so per-iteration work is one batched thin
    contraction instead of B separate kernel dispatches.

    Stacked entry points (``solve_stacked``, ``solve_point_clouds``) take
    already-uniform (B, ...) arrays; ``solve_many`` handles ragged problem
    lists via bucketing. Each per-problem solve constructs its Geometry
    from the stacked slices inside the vmapped body, so the batched path
    shares the operator implementations with everything else.
    """

    _FACTORED = ("factored", "log_factored", "accelerated")
    _QUADRATIC = ("quadratic", "log_quadratic")

    def __init__(
        self,
        *,
        eps: float,
        method: str = "log_factored",
        tol: float = 1e-6,
        max_iter: int = 2000,
        momentum: float = 1.0,
        schedule: Optional[EpsSchedule] = None,
        use_pallas: Optional[bool] = None,
        inner_steps: Optional[int] = None,
        check_every: Optional[int] = None,
        precision: str = "highest",
    ):
        if method not in self._FACTORED + self._QUADRATIC:
            raise ValueError(
                f"batched engine supports {self._FACTORED + self._QUADRATIC}, "
                f"got {method!r}"
            )
        self.eps = eps
        self.method = method
        self.tol = tol
        self.max_iter = max_iter
        self.momentum = momentum
        self.schedule = schedule
        # threaded into the vmapped solver bodies: vmap over the fused
        # Pallas kernels adds B as a leading grid axis, so the whole bucket
        # group runs through one fused plan — or one megakernel block
        # (inner_steps) — per iteration; check_every/precision apply the
        # shared cadence and mixed-precision policies per problem
        self.use_pallas = use_pallas
        self.inner_steps = inner_steps
        self.check_every = check_every
        self.precision = precision
        if schedule is not None and method not in ("log_factored",
                                                   "accelerated"):
            raise ValueError(
                "batched annealing runs in log domain (small-eps stages); "
                f"use method='log_factored' or 'accelerated', got {method!r}"
            )
        self._build_geometry = _ENGINE_GEOMETRIES[method]
        self._runner = _ENGINE_RUNNERS[method]
        self._vsolve_features = jax.jit(jax.vmap(self._solve_one))
        # warm-started twin: the incoming potentials are DONATED, so a
        # re-solve loop (GAN steps, annealing drivers) reuses the previous
        # solve's (B, n)/(B, m) potential buffers instead of holding both
        self._vsolve_features_warm = jax.jit(
            jax.vmap(self._solve_one_warm), donate_argnums=(4, 5),
        )
        self._vsolve_clouds_cache: Dict[Tuple[int, float], Callable] = {}

    # -- single-problem bodies (vmapped) ------------------------------------

    def _solve_one(self, ka, kb, a, b) -> SinkhornResult:
        """ka/kb: (log-)features (n, r)/(m, r) — or (C, unused) dense."""
        geom = self._build_geometry(ka, kb, self.eps)
        return self._runner(
            geom, a, b, tol=self.tol, max_iter=self.max_iter,
            momentum=self.momentum, f_init=None, g_init=None,
            mesh=None, mesh_axis="data", use_pallas=self.use_pallas,
            inner_steps=self.inner_steps, check_every=self.check_every,
            precision=self.precision,
        )

    def _solve_one_warm(self, ka, kb, a, b, f0, g0) -> SinkhornResult:
        geom = self._build_geometry(ka, kb, self.eps)
        return self._runner(
            geom, a, b, tol=self.tol, max_iter=self.max_iter,
            momentum=self.momentum, f_init=f0, g_init=g0,
            mesh=None, mesh_axis="data", use_pallas=self.use_pallas,
            inner_steps=self.inner_steps, check_every=self.check_every,
            precision=self.precision,
        )

    def _make_cloud_solver(self, d: int, R: float):
        """Geometry-mode body: the GaussianPointCloud is rebuilt per
        annealing stage. ``anchors`` is a broadcast argument (shared
        across the batch).

        NOTE: the stage loop is the vmap-compatible twin of the one in
        :func:`solve_annealed` (log-domain only, no per-stage diagnostics)
        — keep their semantics in sync."""
        if self.schedule is not None:
            stages = self.schedule.stages(self.eps)
            tols = self.schedule.stage_tols(self.tol, len(stages))
        else:
            stages, tols = (self.eps,), (self.tol,)

        def solve_one(anchors, x, y, a, b) -> SinkhornResult:
            f = g = None
            prev_err = None
            total = jnp.array(0, jnp.int32)
            res = None
            for k, e in enumerate(stages):
                last = k == len(stages) - 1
                tol_k = (tols[k] if prev_err is None
                         else jnp.minimum(tols[k], prev_err))
                geom = GaussianPointCloud(x, y, anchors, eps=e, R=R)
                res = self._runner(
                    geom, a, b, tol=tol_k,
                    max_iter=(self.max_iter if last
                              else self.schedule.stage_iters),
                    momentum=self.momentum, f_init=f, g_init=g,
                    mesh=None, mesh_axis="data", use_pallas=self.use_pallas,
                    inner_steps=self.inner_steps,
                    check_every=self.check_every, precision=self.precision,
                )
                prev_err = res.marginal_err
                f, g = res.f, res.g
                total = total + res.n_iter
            return res._replace(n_iter=total)

        return solve_one

    # -- stacked entry points ------------------------------------------------

    def solve_stacked(self, ka, kb, a, b, f_init=None,
                      g_init=None) -> SinkhornResult:
        """Solve B problems given stacked kernel data.

        factored: ``ka``/``kb`` = features (B, n, r)/(B, m, r);
        log_factored/accelerated: log-features; quadratic/log_quadratic:
        ``ka`` = cost matrices (B, n, m) and ``kb`` is ignored (pass ``ka``).
        Returns a stacked :class:`SinkhornResult` (leading axis B).

        ``f_init``/``g_init`` (both (B, n)/(B, m)) warm-start the
        potentials and are DONATED to the jitted solver: pass the previous
        solve's ``res.f``/``res.g`` in a re-solve loop and their buffers
        are reused in place rather than held alongside the new ones.
        """
        if self.schedule is not None:
            raise ValueError(
                "stacked features pin the kernel to one eps — annealing "
                "needs solve_point_clouds (geometry mode)"
            )
        if (f_init is None) != (g_init is None):
            raise ValueError(
                "pass both f_init and g_init (or neither) — the warm-start "
                "entry donates the pair"
            )
        if f_init is None:
            return self._vsolve_features(ka, kb, a, b)
        return self._vsolve_features_warm(ka, kb, a, b, f_init, g_init)

    def solve_point_clouds(self, x, y, anchors, a=None, b=None, *,
                           R: Optional[float] = None) -> SinkhornResult:
        """Solve B cloud pairs (B, n, d)/(B, m, d) with SHARED anchors.

        The one batched mode that composes with an ``EpsSchedule`` —
        stage features are rebuilt inside the vmapped body.

        ``R`` is a trace-time constant (Lemma 1's q comes from scalar
        Lambert-W math), so each distinct R compiles a fresh solver. Pass a
        fixed bound when calling in a training loop; the default rounds the
        batch's data radius UP to the next 0.5 step (any upper bound is
        valid for Lemma 1) so minibatches of similar scale share a cache
        entry instead of recompiling every step.
        """
        if self.method not in ("log_factored", "accelerated"):
            raise ValueError("point-cloud mode runs in log domain")
        B, n, _ = x.shape
        m = y.shape[1]
        if a is None:
            a = jnp.full((B, n), 1.0 / n, x.dtype)
        if b is None:
            b = jnp.full((B, m), 1.0 / m, y.dtype)
        if R is None:
            radius = data_radius(x, y)
            if isinstance(radius, jax.core.Tracer):
                # float(tracer) below would raise an opaque
                # ConcretizationTypeError from inside jnp — fail with the
                # actionable message instead: R is a TRACE-TIME constant.
                raise ValueError(
                    "solve_point_clouds cannot derive the default R from "
                    "data values under jit/vmap tracing (R is a trace-time "
                    "constant — Lemma 1's q comes from scalar Lambert-W "
                    "math). Pass R= explicitly inside jit, e.g. a fixed "
                    "upper bound on max_i ||p_i||."
                )
            R = math.ceil(float(radius) * 2.0) / 2.0
        d = anchors.shape[-1]
        key = d, round(R, 6)
        fn = self._vsolve_clouds_cache.get(key)
        if fn is None:
            fn = jax.jit(jax.vmap(
                self._make_cloud_solver(d, R),
                in_axes=(None, 0, 0, 0, 0),
            ))
            self._vsolve_clouds_cache[key] = fn
        return fn(anchors, x, y, a, b)

    # -- ragged entry point --------------------------------------------------

    def solve_many(
        self,
        problems: Sequence[OTProblem],
        *,
        f_inits: Optional[Sequence[Optional[jax.Array]]] = None,
        g_inits: Optional[Sequence[Optional[jax.Array]]] = None,
    ) -> List[SinkhornResult]:
        """Solve a ragged list of problems: bucket by padded shape, pad with
        zero-weight atoms, vmap each bucket, unpad. Exact w.r.t. per-problem
        solves (masked zero weights), order-preserving.

        ``f_inits``/``g_inits`` optionally warm-start individual problems
        (per-problem ``(n_i,)``/``(m_i,)`` arrays, ``None`` entries cold-
        start). Any bucket containing at least one warm entry routes through
        the donated warm twin; cold entries inside such a bucket are padded
        with ZEROS, which is exactly the cold default (``f = 0`` is ``u = 1``
        in scaling space, and the log solver's ``_log_init`` starts from
        zeros before pinning dead atoms), so mixing warm and cold problems
        in one bucket stays elementwise-exact.
        """
        if (f_inits is None) != (g_inits is None):
            raise ValueError(
                "pass both f_inits and g_inits (or neither) — warm starts "
                "come as potential pairs"
            )
        if f_inits is not None:
            if len(f_inits) != len(problems) or len(g_inits) != len(problems):
                raise ValueError(
                    f"f_inits/g_inits must match problems "
                    f"({len(problems)}), got {len(f_inits)}/{len(g_inits)}"
                )
            for i, (fi, gi) in enumerate(zip(f_inits, g_inits)):
                if (fi is None) != (gi is None):
                    raise ValueError(
                        f"problem {i}: pass both f_init and g_init (or "
                        "neither)"
                    )
        groups: Dict[OTBatchShape, List[int]] = {}
        datas: Dict[int, Tuple[jax.Array, jax.Array]] = {}
        for i, p in enumerate(problems):
            if float(p.eps) != float(self.eps):
                raise ValueError(
                    f"problem {i} declares eps={p.eps} but this engine "
                    f"solves at eps={self.eps}; build one engine per eps"
                )
            ka, kb = self.kernel_data(p)
            datas[i] = (ka, kb)
            groups.setdefault(self.batch_shape(ka, kb), []).append(i)

        out: List[Optional[SinkhornResult]] = [None] * len(problems)
        for shape, idxs in groups.items():
            kas, kbs, aws, bws, f0s, g0s = [], [], [], [], [], []
            warm = f_inits is not None and any(
                f_inits[i] is not None for i in idxs
            )
            for i in idxs:
                p = problems[i]
                ka, kb = self.pad_kernel_data(*datas[i], shape)
                kas.append(ka)
                kbs.append(kb)
                aws.append(_pad_rows(p.a, shape.n_pad, replicate=False))
                bws.append(_pad_rows(p.b, shape.m_pad, replicate=False))
                if warm:
                    fi = f_inits[i]
                    gi = g_inits[i]
                    if fi is None:                 # cold lane: zeros == cold
                        f0s.append(jnp.zeros((shape.n_pad,), p.a.dtype))
                        g0s.append(jnp.zeros((shape.m_pad,), p.b.dtype))
                    else:
                        f0s.append(_pad_rows(fi, shape.n_pad,
                                             replicate=False))
                        g0s.append(_pad_rows(gi, shape.m_pad,
                                             replicate=False))
            stacked = (jnp.stack(kas), jnp.stack(kbs),
                       jnp.stack(aws), jnp.stack(bws))
            if warm:
                res = self._vsolve_features_warm(
                    *stacked, jnp.stack(f0s), jnp.stack(g0s)
                )
            else:
                res = self._vsolve_features(*stacked)
            for j, i in enumerate(idxs):
                out[i] = unpad_result(res, j, problems[i].a.shape[0],
                                      problems[i].b.shape[0])
        return out

    # -- bucketing / padding helpers (shared with repro.serving) -------------

    def kernel_data(self, p: OTProblem) -> Tuple[jax.Array, jax.Array]:
        """The stacked-array representation of one problem's kernel under
        this engine's method: (log-)features for the factored methods, the
        dense cost (twice) for the quadratic ones."""
        geom = p.geometry.rebuild_at(self.eps)
        if self.method == "factored":
            return geom.features()
        if self.method in ("log_factored", "accelerated"):
            return geom.log_features()
        C = geom.cost_matrix()
        return C, C

    def batch_shape(self, ka: jax.Array, kb: jax.Array) -> OTBatchShape:
        """The bucket cell one problem's kernel data lands in — the key the
        ragged path groups by and the serving runner cache is keyed on."""
        if self.method in self._QUADRATIC:
            return OTBatchShape.for_quadratic(ka.shape[0], ka.shape[1])
        return OTBatchShape.for_problem(ka.shape[0], kb.shape[0], ka.shape[1])

    def pad_kernel_data(self, ka: jax.Array, kb: jax.Array,
                        shape: OTBatchShape) -> Tuple[jax.Array, jax.Array]:
        """Pad one problem's kernel data up to its bucket cell (replicated
        rows — exact, the added atoms carry zero weight)."""
        if self.method in self._QUADRATIC:
            ka = _pad_rows(ka, shape.n_pad, replicate=True)
            ka = _pad_rows(ka.T, shape.m_pad, replicate=True).T
            return ka, ka
        return (_pad_rows(ka, shape.n_pad, replicate=True),
                _pad_rows(kb, shape.m_pad, replicate=True))

    # deprecated private alias (pre-serving name)
    _kernel_data = kernel_data


def unpad_result(res: SinkhornResult, j: int, n: int, m: int) -> SinkhornResult:
    """Slice problem ``j`` out of a stacked bucket result, dropping the
    padded atoms: the inverse of the engine's bucket padding, shared by
    ``solve_many`` and the serving dispatch path."""
    return SinkhornResult(
        u=res.u[j, :n], v=res.v[j, :m],
        f=res.f[j, :n], g=res.g[j, :m],
        cost=res.cost[j], n_iter=res.n_iter[j],
        marginal_err=res.marginal_err[j],
        converged=res.converged[j],
    )


# ---------------------------------------------------------------------------
# Engine cache: LRU over solver configurations
# ---------------------------------------------------------------------------
#
# Every distinct (method, eps, tol, max_iter, ...) tuple owns a
# BatchedSinkhorn and thereby every jitted executable that engine ever
# compiled. Under service traffic with per-request tolerances that is a
# real leak, so the cache is a bounded LRU: least-recently-USED engines
# (and their executables) are dropped once the cap is hit. The stats feed
# the serving layer's cache accounting (``OTService.stats``).

_ENGINE_CACHE: "OrderedDict[Tuple, BatchedSinkhorn]" = OrderedDict()
_ENGINE_CACHE_CAPACITY = 8
_ENGINE_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0}


def engine_cache_info() -> Dict[str, int]:
    """Size/capacity/hit/miss/eviction counters of the ``solve_many``
    engine cache (copies — safe to diff across calls)."""
    return dict(size=len(_ENGINE_CACHE), capacity=_ENGINE_CACHE_CAPACITY,
                **_ENGINE_CACHE_STATS)


def set_engine_cache_capacity(capacity: int) -> None:
    """Re-cap the engine LRU; evicts oldest entries immediately if the new
    cap is below the current size."""
    global _ENGINE_CACHE_CAPACITY
    if capacity < 1:
        raise ValueError(f"capacity must be >= 1, got {capacity}")
    _ENGINE_CACHE_CAPACITY = capacity
    while len(_ENGINE_CACHE) > _ENGINE_CACHE_CAPACITY:
        _ENGINE_CACHE.popitem(last=False)
        _ENGINE_CACHE_STATS["evictions"] += 1


def clear_engine_cache() -> None:
    _ENGINE_CACHE.clear()
    for k in _ENGINE_CACHE_STATS:
        _ENGINE_CACHE_STATS[k] = 0


def get_engine(
    *,
    eps: float,
    method: str = "log_factored",
    tol: float = 1e-6,
    max_iter: int = 2000,
    momentum: float = 1.0,
    use_pallas: Optional[bool] = None,
    inner_steps: Optional[int] = None,
    check_every: Optional[int] = None,
    precision: str = "highest",
) -> BatchedSinkhorn:
    """The cached :class:`BatchedSinkhorn` for a solver configuration.

    LRU semantics: a hit refreshes recency; a miss builds the engine and
    may evict the least-recently-used one (its jitted executables go with
    it). ``solve_many`` and the serving layer both come through here, so
    repeated calls never retrace — and distinct per-request configurations
    can no longer pin unbounded compile caches.
    """
    key = (method, float(eps), float(tol), int(max_iter), float(momentum),
           use_pallas, inner_steps, check_every, precision)
    engine = _ENGINE_CACHE.get(key)
    if engine is not None:
        _ENGINE_CACHE.move_to_end(key)
        _ENGINE_CACHE_STATS["hits"] += 1
        return engine
    _ENGINE_CACHE_STATS["misses"] += 1
    engine = BatchedSinkhorn(
        eps=eps, method=method, tol=tol, max_iter=max_iter,
        momentum=momentum, use_pallas=use_pallas, inner_steps=inner_steps,
        check_every=check_every, precision=precision,
    )
    _ENGINE_CACHE[key] = engine
    while len(_ENGINE_CACHE) > _ENGINE_CACHE_CAPACITY:
        _ENGINE_CACHE.popitem(last=False)
        _ENGINE_CACHE_STATS["evictions"] += 1
    return engine


_SHARDED_TWIN = {
    "factored": "sharded", "sharded": "sharded",
    "log_factored": "sharded_log", "sharded_log": "sharded_log",
    "auto": "auto",
}


def solve_many(
    problems: Sequence[OTProblem],
    *,
    method: str = "log_factored",
    eps: Optional[float] = None,
    tol: float = 1e-6,
    max_iter: int = 2000,
    momentum: float = 1.0,
    use_pallas: Optional[bool] = None,
    inner_steps: Optional[int] = None,
    check_every: Optional[int] = None,
    precision: str = "highest",
    mesh=None,
    mesh_axis: str = "data",
    f_inits: Optional[Sequence[Optional[jax.Array]]] = None,
    g_inits: Optional[Sequence[Optional[jax.Array]]] = None,
) -> List[SinkhornResult]:
    """Convenience wrapper: batched solve of a ragged problem list.

    ``eps`` defaults to the (shared) eps of the problems; mixed-eps lists
    are rejected — build one engine per eps instead. Engines (and hence
    their jitted vmapped solvers) are cached per configuration in a
    bounded LRU (:func:`get_engine`), so calling this in a loop does not
    retrace and distinct per-request configurations cannot leak compile
    caches without bound.

    ``f_inits``/``g_inits`` warm-start individual problems (per-problem
    potentials from an earlier solve; ``None`` entries cold-start) — see
    :meth:`BatchedSinkhorn.solve_many`.

    With ``mesh=`` each problem runs through the shard_map solver (the
    sharded twin of ``method``: scaling or psum'd-LSE log domain). Sharded
    problems are dispatched sequentially — each solve already occupies the
    whole mesh, so there is no idle hardware for a vmapped batch to fill.

    A sequence of :class:`~repro.core.spec.SolveSpec` is also accepted —
    the preferred form. The specs must share one
    method/tol/max_iter/momentum/policy (engines are per-configuration;
    heterogeneous configs go through ``solve(spec)`` one at a time); the
    solver kwargs above are then ignored except ``f_inits``/``g_inits``.
    """
    if not problems:
        return []
    from .spec import SolveSpec  # lazy: spec imports this module

    if isinstance(problems[0], SolveSpec):
        specs: List[SolveSpec] = list(problems)
        head = specs[0]
        shared = (head.method, head.tol, head.max_iter, head.momentum,
                  head.policy, head.recovery)
        for s in specs:
            if not isinstance(s, SolveSpec):
                raise TypeError(
                    "solve_many: mixed SolveSpec and OTProblem entries")
            if (s.method, s.tol, s.max_iter, s.momentum,
                    s.policy, s.recovery) != shared:
                raise ValueError(
                    "solve_many(specs) needs one shared method/tol/"
                    "max_iter/momentum/policy/recovery across specs "
                    "(engines are per-configuration); call solve(spec) "
                    "per problem for heterogeneous configs")
            if s.schedule is not None or s.rank is not None \
                    or s.key is not None:
                raise ValueError(
                    "solve_many(specs) does not support schedule/rank/"
                    "key; call solve(spec) per problem")
        pol = head.policy
        if pol.mesh is not None:
            if f_inits is not None or g_inits is not None:
                raise ValueError(
                    "sharded solve_many dispatches sequentially; "
                    "per-problem warm starts are a batched-engine "
                    "feature — drop the mesh or the inits")
            twin = _SHARDED_TWIN.get(head.method)
            if twin is None:
                raise ValueError(
                    f"solve_many(mesh=...) supports methods "
                    f"{sorted(_SHARDED_TWIN)}, got {head.method!r}")
            return [solve(s.replace(method=twin)) for s in specs]
        eps_set = {float(s.eps) for s in specs}
        if len(eps_set) != 1:
            raise ValueError(
                f"mixed spec eps {sorted(eps_set)}; batched engines "
                "are per-eps — group specs by eps")
        eng_method = ("log_factored" if head.method == "auto"
                      else head.method)
        with pol.scope():
            engine = get_engine(
                eps=eps_set.pop(), method=eng_method, tol=head.tol,
                max_iter=head.max_iter, momentum=head.momentum,
                use_pallas=pol.use_pallas, inner_steps=pol.inner_steps,
                check_every=pol.check_every, precision=pol.precision,
            )
            results = engine.solve_many([s.problem() for s in specs],
                                        f_inits=f_inits, g_inits=g_inits)
        if head.recovery is not None:
            # failed lanes climb the ladder INDIVIDUALLY (batched lanes
            # are independent under vmap — a diverged lane never poisons
            # its siblings, so only the failures pay for retries); the
            # already-computed lane result seeds the ladder so the base
            # configuration is not re-failed
            from ..resilience.health import classify
            from ..resilience.ladder import solve_with_recovery
            for i, r in enumerate(results):
                fi = f_inits[i] if f_inits is not None else None
                gi = g_inits[i] if g_inits is not None else None
                h = classify(r, f_init=fi, g_init=gi,
                             a=specs[i].problem().a, b=specs[i].problem().b)
                if h.verdict not in head.recovery.accept:
                    results[i] = solve_with_recovery(
                        specs[i], first_attempt=r).result
        return results
    if (use_pallas is not None or inner_steps is not None
            or check_every is not None or precision != "highest"):
        warnings.warn(
            "passing execution kwargs (use_pallas=/inner_steps=/"
            "check_every=/precision=) to solve_many() directly is "
            "deprecated: build SolveSpecs with a shared ExecutionPolicy "
            "(repro.core.spec) and call solve_many(specs)",
            DeprecationWarning, stacklevel=2)
    eps_set = {float(p.eps) for p in problems}
    if eps is None:
        if len(eps_set) != 1:
            raise ValueError(f"mixed problem eps {sorted(eps_set)}; pass eps=")
        eps = eps_set.pop()
    if mesh is not None:
        if f_inits is not None or g_inits is not None:
            raise ValueError(
                "solve_many(mesh=...) dispatches problems sequentially "
                "through solve(); per-problem warm starts are a batched-"
                "engine feature — drop mesh= or the inits"
            )
        twin = _SHARDED_TWIN.get(method)
        if twin is None:
            raise ValueError(
                f"solve_many(mesh=...) supports methods "
                f"{sorted(_SHARDED_TWIN)}, got {method!r}"
            )
        # use_pallas is moot here: sharded geometries refuse fused local
        # plans (they would drop the psum), so the XLA operators always
        # run. inner_steps is NOT moot — it is passed through so the
        # sharded runner raises its clear megakernel-refusal error
        # instead of silently dropping the knob; check_every/precision
        # apply as everywhere.
        return [
            solve(p.__class__(p.geometry.rebuild_at(eps), p.a, p.b),
                  method=twin, tol=tol, max_iter=max_iter,
                  momentum=momentum, mesh=mesh, mesh_axis=mesh_axis,
                  inner_steps=inner_steps, check_every=check_every,
                  precision=precision)
            for p in problems
        ]
    engine = get_engine(
        eps=eps, method=method, tol=tol, max_iter=max_iter,
        momentum=momentum, use_pallas=use_pallas, inner_steps=inner_steps,
        check_every=check_every, precision=precision,
    )
    return engine.solve_many(problems, f_inits=f_inits, g_inits=g_inits)
