"""Unified solver front-end: ``solve`` / ``BatchedSinkhorn`` / ``EpsSchedule``.

Every solver variant in the repo (scaling-space factored, log-domain
factored, accelerated AGM, dense quadratic baselines, shard_map
distributed) is reachable through ONE entry point:

    problem = OTProblem.from_point_clouds(x, y, anchors, eps=0.05)
    res = solve(problem, method="log_factored",
                schedule=EpsSchedule(eps_init=1.0, decay=0.5))

and batches of independent problems — the GAN-minibatch workload of the
paper's Section 4, and the "heavy traffic" serving shape of the ROADMAP —
go through the vmapped engine:

    engine = BatchedSinkhorn(eps=0.05, method="log_factored")
    results = engine.solve_many(problems)      # buckets, pads, vmaps

Design notes
------------
* **One kernel, many algorithms.** For a problem built from (log-)features
  the quadratic methods run on the *induced* cost ``C = -eps log(Xi Zeta^T)``
  so all methods share one fixed point and agree to solver tolerance (the
  oracle-consistency contract tested in ``tests/test_api.py``). Problems
  built from point clouds use the true squared-Euclidean cost for the
  quadratic methods — the paper's ``Sin`` baseline — so there the factored
  methods differ by the feature-approximation error (Theorem 3.1).
* **Annealing** (``EpsSchedule``) runs a geometric cascade
  ``eps_0 > eps_0*decay > ... > eps`` re-deriving the stage kernel from the
  problem's geometry (or dense cost) and warm-starting the potentials
  (f, g) — equivalently ``u = e^{f/eps}`` — between stages. At small eps
  this cuts total iterations by a large factor versus a cold start
  (property-tested in ``tests/test_schedule.py``). Feature-only problems
  cannot be annealed: their kernel is pinned to the eps the features were
  drawn at.
* **Batching** pads each problem's supports up to the power-of-two buckets
  in ``configs/shapes.py`` (``ot_bucket``) with ZERO-weight atoms — exact,
  not approximate, because every solver masks zero weights (see
  ``sinkhorn.masked_dual_value``) — groups problems by padded shape, and
  ``vmap``s the shared solver loop over the group. One ``lax.while_loop``
  then drives the whole batch: per-iteration work is a single batched thin
  contraction instead of B separate GEMV dispatches, which is where the
  >= 3x wall-clock win of ``benchmarks/bench_batch.py`` comes from.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..configs.shapes import OTBatchShape, ot_bucket
from .accelerated import accelerated_sinkhorn_log_factored
from .features import gaussian_log_features, gaussian_q
from .geometry import data_radius, squared_euclidean
from .sinkhorn import (
    SinkhornResult,
    sinkhorn_factored,
    sinkhorn_log_factored,
    sinkhorn_log_quadratic,
    sinkhorn_quadratic,
)

__all__ = [
    "METHODS",
    "OTProblem",
    "EpsSchedule",
    "AnnealedResult",
    "BatchedSinkhorn",
    "solve",
    "solve_annealed",
    "solve_many",
]

METHODS = (
    "auto",
    "factored",
    "log_factored",
    "accelerated",
    "quadratic",
    "log_quadratic",
    "sharded",
)


# ---------------------------------------------------------------------------
# Problem specification
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OTProblem:
    """One entropic OT problem. Built from exactly one kernel source:
    positive features, log-features, a dense cost matrix, or raw point
    clouds + Gaussian anchors (the only form that supports eps-annealing
    and learnable-anchor gradients)."""

    a: jax.Array                       # (n,) weights, sum 1 (zeros allowed)
    b: jax.Array                       # (m,)
    eps: float
    xi: Optional[jax.Array] = None         # (n, r) positive features
    zeta: Optional[jax.Array] = None       # (m, r)
    log_xi: Optional[jax.Array] = None     # (n, r) log-features
    log_zeta: Optional[jax.Array] = None   # (m, r)
    C: Optional[jax.Array] = None          # (n, m) dense cost
    x: Optional[jax.Array] = None          # (n, d) support of mu
    y: Optional[jax.Array] = None          # (m, d) support of nu
    anchors: Optional[jax.Array] = None    # (r, d) Lemma-1 anchors
    R: Optional[float] = None              # data radius bound (geometry mode)

    # -- constructors -------------------------------------------------------

    @staticmethod
    def _uniform(n: int, dtype) -> jax.Array:
        return jnp.full((n,), 1.0 / n, dtype)

    @classmethod
    def from_features(cls, xi, zeta, a=None, b=None, *, eps: float) -> "OTProblem":
        a = cls._uniform(xi.shape[0], xi.dtype) if a is None else a
        b = cls._uniform(zeta.shape[0], zeta.dtype) if b is None else b
        return cls(a=a, b=b, eps=eps, xi=xi, zeta=zeta)

    @classmethod
    def from_log_features(cls, log_xi, log_zeta, a=None, b=None, *,
                          eps: float) -> "OTProblem":
        a = cls._uniform(log_xi.shape[0], log_xi.dtype) if a is None else a
        b = cls._uniform(log_zeta.shape[0], log_zeta.dtype) if b is None else b
        return cls(a=a, b=b, eps=eps, log_xi=log_xi, log_zeta=log_zeta)

    @classmethod
    def from_cost(cls, C, a=None, b=None, *, eps: float) -> "OTProblem":
        a = cls._uniform(C.shape[0], C.dtype) if a is None else a
        b = cls._uniform(C.shape[1], C.dtype) if b is None else b
        return cls(a=a, b=b, eps=eps, C=C)

    @classmethod
    def from_point_clouds(cls, x, y, anchors, a=None, b=None, *, eps: float,
                          R: Optional[float] = None) -> "OTProblem":
        a = cls._uniform(x.shape[0], x.dtype) if a is None else a
        b = cls._uniform(y.shape[0], y.dtype) if b is None else b
        R = float(data_radius(x, y)) if R is None else R
        return cls(a=a, b=b, eps=eps, x=x, y=y, anchors=anchors, R=R)

    # -- kernel views -------------------------------------------------------

    @property
    def has_geometry(self) -> bool:
        return self.x is not None

    @property
    def anneal_capable(self) -> bool:
        """Annealing needs the kernel re-derivable at arbitrary eps."""
        return self.has_geometry or self.C is not None

    def log_features_at(self, eps: float) -> Tuple[jax.Array, jax.Array]:
        """(log_xi, log_zeta) for the Gibbs kernel at ``eps``."""
        if self.has_geometry:
            q = gaussian_q(self.R, eps, self.x.shape[-1])
            lxi = gaussian_log_features(self.x, self.anchors, eps=eps, q=q)
            lzt = gaussian_log_features(self.y, self.anchors, eps=eps, q=q)
            return lxi, lzt
        if self.log_xi is None and self.xi is None:
            raise ValueError("no factored kernel available (dense-cost "
                             "problem); use a quadratic method")
        if eps != self.eps:
            raise ValueError(
                "feature-built problems pin the kernel to their native eps "
                f"({self.eps}); got {eps}. Build the problem with "
                "from_point_clouds to enable eps-annealing."
            )
        if self.log_xi is not None:
            return self.log_xi, self.log_zeta
        return jnp.log(self.xi), jnp.log(self.zeta)

    def features_at(self, eps: float) -> Tuple[jax.Array, jax.Array]:
        if self.xi is not None and eps == self.eps:
            return self.xi, self.zeta
        lxi, lzt = self.log_features_at(eps)
        return jnp.exp(lxi), jnp.exp(lzt)

    def cost_matrix(self) -> jax.Array:
        """Dense cost for the quadratic baselines. True cost in geometry
        mode (the paper's Sin baseline); the factored-kernel-induced cost
        ``-eps log(Xi Zeta^T)`` in feature mode so all methods share one
        fixed point."""
        if self.C is not None:
            return self.C
        if self.has_geometry:
            return squared_euclidean(self.x, self.y)
        if self.xi is not None:
            return -self.eps * jnp.log(self.xi @ self.zeta.T)
        # max-shifted product keeps peak memory at O(nm) instead of the
        # O(nmr) broadcast a direct pairwise LSE would allocate
        m1 = jnp.max(self.log_xi, axis=1, keepdims=True)      # (n, 1)
        m2 = jnp.max(self.log_zeta, axis=1, keepdims=True)    # (m, 1)
        K = jnp.exp(self.log_xi - m1) @ jnp.exp(self.log_zeta - m2).T
        return -self.eps * (jnp.log(K) + m1 + m2.T)


# ---------------------------------------------------------------------------
# Epsilon annealing
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EpsSchedule:
    """Geometric eps cascade: eps_0, eps_0*decay, ... down to the target.

    Intermediate stages only need to hand a decent warm start to the next
    stage, so they stop at a LOOSE tolerance: stage tolerances decay
    geometrically from ``stage_tol`` down to ``sqrt(stage_tol * tol)`` —
    the final stage does the last push to ``tol`` (``stage_tols``). At run
    time each stage's target is additionally capped at the previous stage's
    ACHIEVED error, which makes the per-stage marginal error non-increasing
    by construction. Each intermediate stage is also capped at
    ``stage_iters`` iterations; the final stage gets the caller's full
    ``max_iter``.
    """

    eps_init: float
    decay: float = 0.5
    stage_iters: int = 400
    stage_tol: float = 1e-2

    def __post_init__(self):
        if not (0.0 < self.decay < 1.0):
            raise ValueError(f"decay must be in (0, 1), got {self.decay}")
        if self.eps_init <= 0:
            raise ValueError("eps_init must be positive")

    def stages(self, eps_final: float) -> Tuple[float, ...]:
        if self.eps_init <= eps_final:
            return (eps_final,)
        out = []
        e = self.eps_init
        # stop the geometric ladder once e is within sqrt(decay) of the
        # target and jump straight there — a penultimate stage a few
        # percent above eps_final would cost a full solve for no progress
        thresh = eps_final / math.sqrt(self.decay)
        while e > thresh:
            out.append(e)
            e *= self.decay
        out.append(eps_final)
        return tuple(out)

    def stage_tols(self, tol_final: float, n_stages: int) -> Tuple[float, ...]:
        """Per-stage marginal-error targets: geometric from ``stage_tol``
        down to sqrt(stage_tol * tol_final) across the intermediates, then
        ``tol_final``. Keeping intermediates loose is what buys the total-
        iteration win — tight intermediate solves at large eps do not
        transfer into a proportionally better warm start."""
        if n_stages <= 1 or self.stage_tol <= tol_final:
            return (tol_final,) * max(n_stages, 1)
        if n_stages == 2:
            return (self.stage_tol, tol_final)
        mid = math.sqrt(self.stage_tol * tol_final)
        ratio = (mid / self.stage_tol) ** (1.0 / (n_stages - 2))
        tols = [max(self.stage_tol * ratio**k, tol_final)
                for k in range(n_stages - 1)]
        return tuple(tols) + (tol_final,)


class AnnealedResult(NamedTuple):
    result: SinkhornResult            # final-stage solve (n_iter = TOTAL)
    stage_eps: Tuple[float, ...]
    stage_iters: jax.Array            # (S,) iterations per stage
    stage_errs: jax.Array             # (S,) marginal error at stage exit


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------


def _auto_method(problem: OTProblem) -> str:
    if problem.has_geometry or problem.log_xi is not None:
        return "log_factored"
    if problem.xi is not None:
        return "factored"
    return "log_quadratic"


def _solve_stage(
    problem: OTProblem,
    method: str,
    eps: float,
    *,
    tol: float,
    max_iter: int,
    momentum: float,
    f_init: Optional[jax.Array],
    g_init: Optional[jax.Array],
    mesh=None,
    mesh_axis: str = "data",
) -> SinkhornResult:
    """One solve at a fixed eps with optional warm-started potentials."""
    if method == "factored":
        xi, zeta = problem.features_at(eps)
        u_init = None if f_init is None else jnp.exp(f_init / eps)
        return sinkhorn_factored(
            xi, zeta, problem.a, problem.b, eps=eps, tol=tol,
            max_iter=max_iter, momentum=momentum, u_init=u_init,
        )
    if method == "log_factored":
        lxi, lzt = problem.log_features_at(eps)
        return sinkhorn_log_factored(
            lxi, lzt, problem.a, problem.b, eps=eps, tol=tol,
            max_iter=max_iter, f_init=f_init, g_init=g_init,
        )
    if method == "accelerated":
        lxi, lzt = problem.log_features_at(eps)
        return accelerated_sinkhorn_log_factored(
            lxi, lzt, problem.a, problem.b, eps=eps, tol=tol,
            max_iter=max_iter, f_init=f_init, g_init=g_init,
        )
    if method == "quadratic":
        K = jnp.exp(-problem.cost_matrix() / eps)
        u_init = None if f_init is None else jnp.exp(f_init / eps)
        return sinkhorn_quadratic(
            K, problem.a, problem.b, eps=eps, tol=tol, max_iter=max_iter,
            momentum=momentum, u_init=u_init,
        )
    if method == "log_quadratic":
        return sinkhorn_log_quadratic(
            problem.cost_matrix(), problem.a, problem.b, eps=eps, tol=tol,
            max_iter=max_iter, f_init=f_init, g_init=g_init,
        )
    if method == "sharded":
        from .sharded import sharded_sinkhorn_factored

        if mesh is None:
            raise ValueError("method='sharded' requires a mesh=...")
        xi, zeta = problem.features_at(eps)
        return sharded_sinkhorn_factored(
            mesh, xi, zeta, problem.a, problem.b, eps=eps, axis=mesh_axis,
            tol=tol, max_iter=max_iter,
        )
    raise ValueError(f"unknown method {method!r}; expected one of {METHODS}")


def solve_annealed(
    problem: OTProblem,
    *,
    method: str = "auto",
    schedule: EpsSchedule,
    tol: float = 1e-6,
    max_iter: int = 2000,
    momentum: float = 1.0,
    mesh=None,
    mesh_axis: str = "data",
) -> AnnealedResult:
    """Annealed solve with per-stage diagnostics.

    Each stage solves at eps_k re-deriving the kernel from geometry / dense
    cost, then hands its potentials (f, g) to the next stage as warm start.
    The returned ``result.n_iter`` is the TOTAL across stages so it compares
    directly against a cold-start solve's iteration count.
    """
    if method == "auto":
        method = _auto_method(problem)
    if not problem.anneal_capable:
        raise ValueError(
            "eps-annealing needs a geometry- or cost-built problem; "
            "feature-built problems pin the kernel to one eps"
        )
    if method == "sharded":
        raise ValueError(
            "method='sharded' does not compose with an EpsSchedule: the "
            "shard_map solver has no warm-start inputs, so every stage "
            "would cold-start. Solve sharded without a schedule instead."
        )
    if method in ("factored", "log_factored", "accelerated") \
            and not problem.has_geometry and problem.C is not None:
        raise ValueError(
            f"method={method!r} needs a factored kernel, but this problem "
            "only carries a dense cost matrix; use a quadratic method or "
            "build the problem with from_point_clouds"
        )
    # NOTE: the stage loop below (ladder tols, prev_err cap, warm-started
    # f/g, total-iteration accumulation) has a vmap-compatible twin in
    # BatchedSinkhorn._make_cloud_solver — keep their semantics in sync.
    stages = schedule.stages(problem.eps)
    tols = schedule.stage_tols(tol, len(stages))
    f = g = None
    prev_err = None
    stage_iters, stage_errs = [], []
    res = None
    for k, e in enumerate(stages):
        last = k == len(stages) - 1
        # cap at the previous stage's achieved error -> per-stage marginal
        # error is non-increasing by construction
        tol_k = tols[k] if prev_err is None else jnp.minimum(tols[k], prev_err)
        res = _solve_stage(
            problem, method, e,
            tol=tol_k,
            max_iter=max_iter if last else schedule.stage_iters,
            momentum=momentum, f_init=f, g_init=g,
            mesh=mesh, mesh_axis=mesh_axis,
        )
        prev_err = res.marginal_err
        f, g = res.f, res.g
        stage_iters.append(res.n_iter)
        stage_errs.append(res.marginal_err)
    total = jnp.sum(jnp.stack(stage_iters))
    final = res._replace(n_iter=total)
    return AnnealedResult(
        final, stages, jnp.stack(stage_iters), jnp.stack(stage_errs)
    )


def solve(
    problem: OTProblem,
    *,
    method: str = "auto",
    schedule: Optional[EpsSchedule] = None,
    tol: float = 1e-6,
    max_iter: int = 2000,
    momentum: float = 1.0,
    mesh=None,
    mesh_axis: str = "data",
) -> SinkhornResult:
    """Solve one entropic OT problem with any solver variant in the repo.

    ``method``: "auto" | "factored" | "log_factored" | "accelerated" |
    "quadratic" | "log_quadratic" | "sharded" (needs ``mesh``).
    ``schedule``: optional :class:`EpsSchedule` eps-annealing cascade
    (geometry- or cost-built problems only).
    """
    if method == "auto":
        method = _auto_method(problem)
    if schedule is not None:
        return solve_annealed(
            problem, method=method, schedule=schedule, tol=tol,
            max_iter=max_iter, momentum=momentum, mesh=mesh,
            mesh_axis=mesh_axis,
        ).result
    return _solve_stage(
        problem, method, problem.eps, tol=tol, max_iter=max_iter,
        momentum=momentum, f_init=None, g_init=None, mesh=mesh,
        mesh_axis=mesh_axis,
    )


# ---------------------------------------------------------------------------
# Batched engine
# ---------------------------------------------------------------------------


def _pad_rows(arr: jax.Array, n_pad: int, *, replicate: bool) -> jax.Array:
    """Pad axis 0 to n_pad: replicate the last row (features / supports —
    keeps log-features finite) or append zeros (weights)."""
    pad = n_pad - arr.shape[0]
    if pad <= 0:
        return arr
    if replicate:
        fill = jnp.broadcast_to(arr[-1:], (pad,) + arr.shape[1:])
    else:
        fill = jnp.zeros((pad,) + arr.shape[1:], arr.dtype)
    return jnp.concatenate([arr, fill], axis=0)


class BatchedSinkhorn:
    """vmapped solver engine for batches of independent OT problems.

    All problems in a batch share the feature rank r (same anchors in the
    GAN workload); supports are padded to the power-of-two buckets of
    ``configs.shapes.ot_bucket`` with zero-weight atoms, which the masked
    solvers treat exactly. One jitted ``vmap`` of the shared solver loop
    drives each bucket group, so per-iteration work is one batched thin
    contraction instead of B separate kernel dispatches.

    Stacked entry points (``solve_stacked``, ``solve_point_clouds``) take
    already-uniform (B, ...) arrays; ``solve_many`` handles ragged problem
    lists via bucketing.
    """

    _FACTORED = ("factored", "log_factored", "accelerated")
    _QUADRATIC = ("quadratic", "log_quadratic")

    def __init__(
        self,
        *,
        eps: float,
        method: str = "log_factored",
        tol: float = 1e-6,
        max_iter: int = 2000,
        momentum: float = 1.0,
        schedule: Optional[EpsSchedule] = None,
    ):
        if method not in self._FACTORED + self._QUADRATIC:
            raise ValueError(
                f"batched engine supports {self._FACTORED + self._QUADRATIC}, "
                f"got {method!r}"
            )
        self.eps = eps
        self.method = method
        self.tol = tol
        self.max_iter = max_iter
        self.momentum = momentum
        self.schedule = schedule
        if schedule is not None and method not in ("log_factored",
                                                   "accelerated"):
            raise ValueError(
                "batched annealing runs in log domain (small-eps stages); "
                f"use method='log_factored' or 'accelerated', got {method!r}"
            )
        self._vsolve_features = jax.jit(jax.vmap(self._solve_one_features))
        self._vsolve_clouds_cache: Dict[Tuple[int, float], Callable] = {}

    # -- single-problem bodies (vmapped) ------------------------------------

    def _solve_one_features(self, ka, kb, a, b) -> SinkhornResult:
        """ka/kb: (log-)features (n, r)/(m, r) — or (C, unused) dense."""
        if self.method == "factored":
            return sinkhorn_factored(
                ka, kb, a, b, eps=self.eps, tol=self.tol,
                max_iter=self.max_iter, momentum=self.momentum,
            )
        if self.method == "log_factored":
            return sinkhorn_log_factored(
                ka, kb, a, b, eps=self.eps, tol=self.tol,
                max_iter=self.max_iter,
            )
        if self.method == "accelerated":
            return accelerated_sinkhorn_log_factored(
                ka, kb, a, b, eps=self.eps, tol=self.tol,
                max_iter=self.max_iter,
            )
        if self.method == "quadratic":
            return sinkhorn_quadratic(
                jnp.exp(-ka / self.eps), a, b, eps=self.eps, tol=self.tol,
                max_iter=self.max_iter, momentum=self.momentum,
            )
        return sinkhorn_log_quadratic(
            ka, a, b, eps=self.eps, tol=self.tol, max_iter=self.max_iter,
        )

    def _make_cloud_solver(self, d: int, R: float):
        """Geometry-mode body: features rebuilt per annealing stage.
        ``anchors`` is a broadcast argument (shared across the batch).

        NOTE: the stage loop is the vmap-compatible twin of the one in
        :func:`solve_annealed` (log-domain only, no per-stage diagnostics)
        — keep their semantics in sync."""
        if self.schedule is not None:
            stages = self.schedule.stages(self.eps)
            tols = self.schedule.stage_tols(self.tol, len(stages))
        else:
            stages, tols = (self.eps,), (self.tol,)

        def solve_one(anchors, x, y, a, b) -> SinkhornResult:
            f = g = None
            prev_err = None
            total = jnp.array(0, jnp.int32)
            res = None
            for k, e in enumerate(stages):
                last = k == len(stages) - 1
                tol_k = (tols[k] if prev_err is None
                         else jnp.minimum(tols[k], prev_err))
                q = gaussian_q(R, e, d)
                lxi = gaussian_log_features(x, anchors, eps=e, q=q)
                lzt = gaussian_log_features(y, anchors, eps=e, q=q)
                solver = (accelerated_sinkhorn_log_factored
                          if self.method == "accelerated"
                          else sinkhorn_log_factored)
                res = solver(
                    lxi, lzt, a, b, eps=e, tol=tol_k,
                    max_iter=(self.max_iter if last
                              else self.schedule.stage_iters),
                    f_init=f, g_init=g,
                )
                prev_err = res.marginal_err
                f, g = res.f, res.g
                total = total + res.n_iter
            return res._replace(n_iter=total)

        return solve_one

    # -- stacked entry points ------------------------------------------------

    def solve_stacked(self, ka, kb, a, b) -> SinkhornResult:
        """Solve B problems given stacked kernel data.

        factored: ``ka``/``kb`` = features (B, n, r)/(B, m, r);
        log_factored/accelerated: log-features; quadratic/log_quadratic:
        ``ka`` = cost matrices (B, n, m) and ``kb`` is ignored (pass ``ka``).
        Returns a stacked :class:`SinkhornResult` (leading axis B).
        """
        if self.schedule is not None:
            raise ValueError(
                "stacked features pin the kernel to one eps — annealing "
                "needs solve_point_clouds (geometry mode)"
            )
        return self._vsolve_features(ka, kb, a, b)

    def solve_point_clouds(self, x, y, anchors, a=None, b=None, *,
                           R: Optional[float] = None) -> SinkhornResult:
        """Solve B cloud pairs (B, n, d)/(B, m, d) with SHARED anchors.

        The one batched mode that composes with an ``EpsSchedule`` —
        stage features are rebuilt inside the vmapped body.

        ``R`` is a trace-time constant (Lemma 1's q comes from scalar
        Lambert-W math), so each distinct R compiles a fresh solver. Pass a
        fixed bound when calling in a training loop; the default rounds the
        batch's data radius UP to the next 0.5 step (any upper bound is
        valid for Lemma 1) so minibatches of similar scale share a cache
        entry instead of recompiling every step.
        """
        if self.method not in ("log_factored", "accelerated"):
            raise ValueError("point-cloud mode runs in log domain")
        B, n, _ = x.shape
        m = y.shape[1]
        if a is None:
            a = jnp.full((B, n), 1.0 / n, x.dtype)
        if b is None:
            b = jnp.full((B, m), 1.0 / m, y.dtype)
        if R is None:
            R = math.ceil(float(data_radius(x, y)) * 2.0) / 2.0
        d = anchors.shape[-1]
        key = d, round(R, 6)
        fn = self._vsolve_clouds_cache.get(key)
        if fn is None:
            fn = jax.jit(jax.vmap(
                self._make_cloud_solver(d, R),
                in_axes=(None, 0, 0, 0, 0),
            ))
            self._vsolve_clouds_cache[key] = fn
        return fn(anchors, x, y, a, b)

    # -- ragged entry point --------------------------------------------------

    def solve_many(self, problems: Sequence[OTProblem]) -> List[SinkhornResult]:
        """Solve a ragged list of problems: bucket by padded shape, pad with
        zero-weight atoms, vmap each bucket, unpad. Exact w.r.t. per-problem
        solves (masked zero weights), order-preserving."""
        groups: Dict[OTBatchShape, List[int]] = {}
        datas: Dict[int, Tuple[jax.Array, jax.Array]] = {}
        for i, p in enumerate(problems):
            if float(p.eps) != float(self.eps):
                raise ValueError(
                    f"problem {i} declares eps={p.eps} but this engine "
                    f"solves at eps={self.eps}; build one engine per eps"
                )
            ka, kb = self._kernel_data(p)
            datas[i] = (ka, kb)
            if self.method in self._QUADRATIC:
                shape = OTBatchShape(ot_bucket(ka.shape[0]),
                                     ot_bucket(ka.shape[1]), 0)
            else:
                shape = OTBatchShape.for_problem(
                    ka.shape[0], kb.shape[0], ka.shape[1]
                )
            groups.setdefault(shape, []).append(i)

        out: List[Optional[SinkhornResult]] = [None] * len(problems)
        for shape, idxs in groups.items():
            kas, kbs, aws, bws = [], [], [], []
            for i in idxs:
                p = problems[i]
                ka, kb = datas[i]
                if self.method in self._QUADRATIC:
                    ka = _pad_rows(ka, shape.n_pad, replicate=True)
                    ka = _pad_rows(ka.T, shape.m_pad, replicate=True).T
                    kb = ka
                else:
                    ka = _pad_rows(ka, shape.n_pad, replicate=True)
                    kb = _pad_rows(kb, shape.m_pad, replicate=True)
                kas.append(ka)
                kbs.append(kb)
                aws.append(_pad_rows(p.a, shape.n_pad, replicate=False))
                bws.append(_pad_rows(p.b, shape.m_pad, replicate=False))
            res = self._vsolve_features(
                jnp.stack(kas), jnp.stack(kbs), jnp.stack(aws), jnp.stack(bws)
            )
            for j, i in enumerate(idxs):
                p = problems[i]
                n, m = p.a.shape[0], p.b.shape[0]
                out[i] = SinkhornResult(
                    u=res.u[j, :n], v=res.v[j, :m],
                    f=res.f[j, :n], g=res.g[j, :m],
                    cost=res.cost[j], n_iter=res.n_iter[j],
                    marginal_err=res.marginal_err[j],
                    converged=res.converged[j],
                )
        return out

    def _kernel_data(self, p: OTProblem) -> Tuple[jax.Array, jax.Array]:
        if self.method == "factored":
            return p.features_at(self.eps)
        if self.method in ("log_factored", "accelerated"):
            return p.log_features_at(self.eps)
        C = p.cost_matrix()
        return C, C


_ENGINE_CACHE: Dict[Tuple, BatchedSinkhorn] = {}


def solve_many(
    problems: Sequence[OTProblem],
    *,
    method: str = "log_factored",
    eps: Optional[float] = None,
    tol: float = 1e-6,
    max_iter: int = 2000,
    momentum: float = 1.0,
) -> List[SinkhornResult]:
    """Convenience wrapper: batched solve of a ragged problem list.

    ``eps`` defaults to the (shared) eps of the problems; mixed-eps lists
    are rejected — build one engine per eps instead. Engines (and hence
    their jitted vmapped solvers) are cached per configuration, so calling
    this in a loop does not retrace.
    """
    if not problems:
        return []
    eps_set = {float(p.eps) for p in problems}
    if eps is None:
        if len(eps_set) != 1:
            raise ValueError(f"mixed problem eps {sorted(eps_set)}; pass eps=")
        eps = eps_set.pop()
    key = (method, float(eps), float(tol), int(max_iter), float(momentum))
    engine = _ENGINE_CACHE.get(key)
    if engine is None:
        engine = BatchedSinkhorn(
            eps=eps, method=method, tol=tol, max_iter=max_iter,
            momentum=momentum,
        )
        _ENGINE_CACHE[key] = engine
    return engine.solve_many(problems)
