"""Envelope-theorem differentiation of the ROT value (Prop. 3.2).

The paper proves G(K) = sup_{alpha,beta} <a,alpha> + <b,beta>
- eps (e^{alpha/eps})^T K e^{beta/eps} is differentiable on positive K with

    grad_K G = -eps * e^{alpha*/eps} (e^{beta*/eps})^T = -eps * u* v*^T .

Chaining through the factorization K = Xi Zeta^T gives O((n+m) r) gradients
WITHOUT backprop through the Sinkhorn loop:

    dW/dXi   = -eps * u* (Zeta^T v*)^T          (outer product, n x r)
    dW/dZeta = -eps * v* (Xi^T  u*)^T           (m x r)
    dW/da    = alpha* = eps log u*   (up to an additive constant — gradients
               on the simplex tangent space are well defined; cancels in the
               Sinkhorn divergence)

This is exactly the paper's "memory efficient" GAN gradient (Section 4,
Optimisation paragraph): the solver is a ``lax.while_loop`` and the backward
pass touches only its fixed point.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .sinkhorn import (
    sinkhorn_factored,
    sinkhorn_log_factored,
    sinkhorn_log_geometry,
)

__all__ = [
    "rot_geometry",
    "rot_factored",
    "rot_log_factored",
    "rot_factored_batched",
    "rot_log_factored_batched",
]


# ---------------------------------------------------------------------------
# Generic geometry envelope VJP
# ---------------------------------------------------------------------------
#
# The envelope theorem says dW/dtheta = -eps * d/dtheta [ u*^T K_theta v* ]
# at the FIXED optimal scalings — so the backward pass for ANY kernel
# parametrization is one differentiation of the geometry's own operator,
# with the potentials frozen. Writing the correlation in log space,
#
#     u^T K v = sum_i exp( f_i/eps + log(K e^{g/eps})_i ),
#
# every term is ~a_i at the fixed point (row marginals), so the expression
# is stable at any eps, and ``jax.grad`` of it w.r.t. the geometry pytree
# yields exactly the hand-derived rules below for factored kernels — while
# also covering point-cloud (learnable anchors!), arc-cosine and grid
# geometries with zero per-family code.


def rot_geometry(geom, a, b, tol=1e-6, max_iter=2000, *,
                 use_pallas=None, inner_steps=None, check_every=None,
                 precision="highest"):
    """W_hat_{eps,c}(mu, nu) on any log-capable Geometry; differentiable in
    the geometry's arrays (features, supports, anchors, grid axes) and in
    the weights via the envelope theorem — no backprop through the loop.

    The keyword-only knobs are the execution policy of the FORWARD solve
    (fused Pallas plan, megakernel cadence, bf16 factor storage — see
    ``sinkhorn_log_geometry``); the backward rule differentiates the
    frozen-potential correlation through the geometry's own hoisted
    operators and is policy-independent.
    """
    return _rot_geometry(geom, a, b, tol, max_iter, use_pallas,
                         inner_steps, check_every, precision)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _rot_geometry(geom, a, b, tol, max_iter, use_pallas, inner_steps,
                  check_every, precision):
    res = sinkhorn_log_geometry(
        geom, a, b, tol=tol, max_iter=max_iter, use_pallas=use_pallas,
        inner_steps=inner_steps, check_every=check_every,
        precision=precision,
    )
    return res.cost


def _rot_geom_fwd(geom, a, b, tol, max_iter, use_pallas, inner_steps,
                  check_every, precision):
    res = sinkhorn_log_geometry(
        geom, a, b, tol=tol, max_iter=max_iter, use_pallas=use_pallas,
        inner_steps=inner_steps, check_every=check_every,
        precision=precision,
    )
    return res.cost, (geom, res.f, res.g)


def _rot_geom_bwd(tol, max_iter, use_pallas, inner_steps, check_every,
                  precision, residuals, ct):
    geom, f, g = residuals
    eps = geom.eps
    from .sinkhorn import geometry_reduce

    reduce = geometry_reduce(geom)

    def neg_eps_corr(gm):
        # -eps u^T K_theta v with (f, g) frozen: the only theta-dependent
        # term of the dual at its optimum (zero-weight atoms carry
        # f = -inf and contribute exactly 0). Under shard_map the reduce
        # hook psums the local partial sums, so the correlation — and via
        # psum's transpose, every leaf cotangent, including replicated
        # leaves like shared anchors — accounts for all shards' terms.
        return -eps * reduce(jnp.exp(f / eps + gm.log_apply_k(g)))

    geom_bar = jax.grad(neg_eps_corr)(geom)
    geom_bar = jax.tree_util.tree_map(lambda t: ct * t, geom_bar)
    return geom_bar, ct * f, ct * g


_rot_geometry.defvjp(_rot_geom_fwd, _rot_geom_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def rot_factored(xi, zeta, a, b, eps, tol=1e-6, max_iter=2000, momentum=1.0):
    """W_hat_{eps,c_theta}(mu, nu) for K = xi zeta^T; differentiable in all
    four tensor args via the envelope theorem."""
    res = sinkhorn_factored(
        xi, zeta, a, b, eps=eps, tol=tol, max_iter=max_iter, momentum=momentum
    )
    return res.cost


def _rot_fwd(xi, zeta, a, b, eps, tol, max_iter, momentum):
    res = sinkhorn_factored(
        xi, zeta, a, b, eps=eps, tol=tol, max_iter=max_iter, momentum=momentum
    )
    return res.cost, (xi, zeta, a, b, res.u, res.v)


def _rot_bwd(eps, tol, max_iter, momentum, residuals, ct):
    xi, zeta, a, b, u, v = residuals
    zv = zeta.T @ v                     # (r,)
    xu = xi.T @ u                       # (r,)
    g_xi = (-eps * ct) * (u[:, None] * zv[None, :])
    g_zeta = (-eps * ct) * (v[:, None] * xu[None, :])
    # d/da = alpha* ; d/db = beta*  (envelope w.r.t. the linear terms)
    g_a = ct * eps * jnp.log(u)
    g_b = ct * eps * jnp.log(v)
    return g_xi, g_zeta, g_a, g_b


rot_factored.defvjp(_rot_fwd, _rot_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def rot_log_factored(log_xi, log_zeta, a, b, eps, tol=1e-6, max_iter=2000):
    """Log-domain twin of :func:`rot_factored` (small-eps safe).

    DEPRECATED as a training entry point: build a ``FactoredPositive``
    through :class:`~repro.core.objective.OTObjective` instead (same
    envelope rule via ``rot_geometry``, plus the fused/bf16/mesh execution
    policy). Kept as the hand-derived reference rule for parity tests.

    Gradient w.r.t. the *log*-features: dW/dlogXi = dW/dXi * Xi
        = -eps * (u (Zeta^T v)^T) .* Xi
    computed without materializing anything quadratic. For each entry,
    u_i Xi_ik = exp(f_i/eps + logXi_ik), again formed in log space.
    """
    res = sinkhorn_log_factored(log_xi, log_zeta, a, b, eps=eps, tol=tol,
                                max_iter=max_iter)
    return res.cost


def _rotl_fwd(log_xi, log_zeta, a, b, eps, tol, max_iter):
    res = sinkhorn_log_factored(log_xi, log_zeta, a, b, eps=eps, tol=tol,
                                max_iter=max_iter)
    return res.cost, (log_xi, log_zeta, a, b, res.f, res.g)


def _rotl_bwd(eps, tol, max_iter, residuals, ct):
    log_xi, log_zeta, a, b, f, g = residuals
    # stabilized: u_i Xi_ik = exp(f_i/eps + logXi_ik - M) * e^M, fold the
    # shared max out of both factors of the outer product.
    lu_xi = f[:, None] / eps + log_xi                       # log(u_i Xi_ik)
    lv_zeta = g[:, None] / eps + log_zeta                   # log(v_j Zeta_jk)
    m1 = jax.lax.stop_gradient(jnp.max(lu_xi))
    m2 = jax.lax.stop_gradient(jnp.max(lv_zeta))
    A = jnp.exp(lu_xi - m1)                                 # (n, r)
    Bm = jnp.exp(lv_zeta - m2)                              # (m, r)
    sB = jnp.sum(Bm, axis=0)                                # (r,) = e^{-m2} Zeta^T v
    sA = jnp.sum(A, axis=0)                                 # (r,) = e^{-m1} Xi^T u
    scale = -eps * ct * jnp.exp(m1 + m2)
    g_logxi = scale * A * sB[None, :]                       # = -eps ct u Xi .* (Zeta^T v)
    g_logzeta = scale * Bm * sA[None, :]
    g_a = ct * f
    g_b = ct * g
    return g_logxi, g_logzeta, g_a, g_b


rot_log_factored.defvjp(_rotl_fwd, _rotl_bwd)


# ---------------------------------------------------------------------------
# Batched envelope VJPs (the GAN-minibatch path: B independent problems)
# ---------------------------------------------------------------------------
#
# ``jax.vmap`` of a ``custom_vjp`` batches BOTH the forward solve and the
# envelope backward rule, so a batched divergence loss backprops at the same
# O(B (n+m) r) cost as the forward pass — still no unrolling through any
# Sinkhorn loop. These wrappers pin the nondiff scalars and vmap only the
# tensor args, matching ``api.BatchedSinkhorn``'s stacked layout.


def rot_factored_batched(xi, zeta, a, b, eps, tol=1e-6, max_iter=2000,
                         momentum=1.0):
    """Stacked W_hat over a leading batch axis: (B,n,r),(B,m,r),(B,n),(B,m)
    -> (B,). Differentiable in all four stacked tensors."""
    return jax.vmap(
        lambda x_, z_, a_, b_: rot_factored(x_, z_, a_, b_, eps, tol,
                                            max_iter, momentum)
    )(xi, zeta, a, b)


def rot_log_factored_batched(log_xi, log_zeta, a, b, eps, tol=1e-6,
                             max_iter=2000):
    """Log-domain twin of :func:`rot_factored_batched` (small-eps safe)."""
    return jax.vmap(
        lambda x_, z_, a_, b_: rot_log_factored(x_, z_, a_, b_, eps, tol,
                                                max_iter)
    )(log_xi, log_zeta, a, b)


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def rot_gibbs_sqeuclid(x, y, a, b, eps, tol=1e-6, max_iter=2000):
    """Quadratic-baseline ROT on the true squared-Euclidean Gibbs kernel,
    differentiable in the LOCATIONS via the envelope theorem:

        dW/dx_i = sum_j P_ij * d c(x_i, y_j)/dx_i = 2 (a_i x_i - [P y]_i)

    with P = diag(u) K diag(v).

    DEPRECATED as a training entry point: the dense-baseline arm of the
    GAN benchmark now solves a ``DenseCost`` geometry through
    ``rot_geometry``. Kept as the hand-derived reference rule."""
    from .geometry import squared_euclidean
    from .sinkhorn import sinkhorn_quadratic

    K = jnp.exp(-squared_euclidean(x, y) / eps)
    return sinkhorn_quadratic(K, a, b, eps=eps, tol=tol,
                              max_iter=max_iter).cost


def _rotg_fwd(x, y, a, b, eps, tol, max_iter):
    from .geometry import squared_euclidean
    from .sinkhorn import sinkhorn_quadratic

    K = jnp.exp(-squared_euclidean(x, y) / eps)
    res = sinkhorn_quadratic(K, a, b, eps=eps, tol=tol, max_iter=max_iter)
    return res.cost, (x, y, K, res.u, res.v, a, b)


def _rotg_bwd(eps, tol, max_iter, residuals, ct):
    x, y, K, u, v, a, b = residuals
    # P = diag(u) K diag(v); row sums = a, col sums = b at convergence
    Py = (u[:, None] * K * v[None, :]) @ y          # (n, d)
    Px = ((u[:, None] * K * v[None, :]).T) @ x      # (m, d)
    g_x = ct * 2.0 * (a[:, None] * x - Py)
    g_y = ct * 2.0 * (b[:, None] * y - Px)
    g_a = ct * eps * jnp.log(u)
    g_b = ct * eps * jnp.log(v)
    return g_x, g_y, g_a, g_b


rot_gibbs_sqeuclid.defvjp(_rotg_fwd, _rotg_bwd)
