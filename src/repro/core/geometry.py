"""Ground costs, Gibbs kernels and exact references for benchmarking."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "squared_euclidean",
    "gibbs_kernel",
    "neglog_kernel_cost",
    "data_radius",
]


def squared_euclidean(x: jax.Array, y: jax.Array) -> jax.Array:
    """C_ij = ||x_i - y_j||^2, shapes (n,d),(m,d) -> (n,m)."""
    x2 = jnp.sum(x * x, axis=-1)[:, None]
    y2 = jnp.sum(y * y, axis=-1)[None, :]
    C = x2 + y2 - 2.0 * (x @ y.T)
    return jnp.maximum(C, 0.0)


def gibbs_kernel(C: jax.Array, eps: float) -> jax.Array:
    """K = exp(-C / eps)."""
    return jnp.exp(-C / eps)


def neglog_kernel_cost(k_matrix: jax.Array, eps: float) -> jax.Array:
    """c(x,y) = -eps log k(x,y) — the kernel-first cost of Eq. (7)."""
    return -eps * jnp.log(k_matrix)


def data_radius(*point_sets: jax.Array) -> jax.Array:
    """R = max_i ||p_i||_2 over all supplied supports (for Lemma 1's q)."""
    return jnp.max(
        jnp.stack([jnp.max(jnp.linalg.norm(p, axis=-1)) for p in point_sets])
    )
