"""The Geometry layer: one kernel-operator protocol for every cost family.

The paper's central observation is that the *representation of the Gibbs
kernel* — dense matrix, exact positive-feature factorization ``K = Xi
Zeta^T`` (Lemmas 1/3), signed Nystrom low-rank (Altschuler et al. '18), or
a separable grid convolution — determines both the cost of a Sinkhorn
matvec and whether the iteration converges at all. A :class:`Geometry`
packages that choice behind one small operator protocol so every solver,
autodiff rule and Pallas dispatch in the repo is generic in the kernel:

    ``apply_k`` / ``apply_kt``          scaling-space matvecs  K v, K^T u
    ``log_apply_k`` / ``log_apply_kt``  log-domain operators
                                        log(K e^{g/eps}), log(K^T e^{f/eps})
    ``cost_matrix()``                   dense cost for the quadratic baselines
    ``dense_kernel()``                  the exact dense K the operators apply
    ``rebuild_at(eps)``                 re-derive the kernel at a new eps
                                        (``anneal_capable`` families only)
    ``features()`` / ``log_features()`` materialized positive factors
    ``xx()`` / ``yy()``                 the symmetric sub-geometries the
                                        Sinkhorn divergence needs
    ``pallas_ops()``                    hook consumed by ``kernels.ops``
                                        to pick fused TPU kernels

Cost families shipped here:

* :class:`DenseCost`          — explicit (n, m) cost, O(nm) matvecs; the
                                paper's ``Sin`` baseline and the universal
                                fallback every other family can densify to.
* :class:`FactoredPositive`   — explicit positive features (or
                                log-features): exact ``K = Xi Zeta^T``,
                                O(r(n+m)) matvecs, converges for any r.
* :class:`GaussianPointCloud` — Lemma-1 features rebuilt from (x, y,
                                anchors) at ANY eps: the one annealing- and
                                learnable-anchor-capable family.
* :class:`ArcCosinePointCloud`— Lemma-3 perturbed arc-cosine features
                                (relu-family kernels with a kappa > 0
                                positivity floor).
* :class:`NystromLowRank`     — the paper's ``Nys`` baseline: signed
                                low-rank factors; same O(l(n+m)) matvec
                                cost but no log-domain operators and a
                                documented small-eps divergence mode.
* :class:`GridSeparable`      — separable costs on regular grids: the
                                Gibbs kernel is a Kronecker product, so a
                                matvec is d axis-wise convolutions at
                                O(n^{1+1/d}) — the images/histograms
                                workload (convolutional Wasserstein).

Every class is a frozen dataclass registered as a JAX pytree (arrays are
leaves; eps and other scalars are static metadata), so geometries flow
through ``jit`` / ``vmap`` / ``grad`` and the envelope-theorem VJPs in
``grad.py`` can differentiate *through a geometry's parameters*.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.ops import check_precision
from ..kernels.tiling import compute_f32
from .features import (
    arccos_features,
    gaussian_log_features,
    gaussian_q,
)
from .features import _anchor_log_const  # noqa: F401  (pallas_ops hook)

__all__ = [
    "Geometry",
    "DenseCost",
    "FactoredPositive",
    "GaussianPointCloud",
    "ArcCosinePointCloud",
    "NystromLowRank",
    "GridSeparable",
    "as_geometry",
    "squared_euclidean",
    "gibbs_kernel",
    "neglog_kernel_cost",
    "data_radius",
]

_lse = jax.scipy.special.logsumexp


# ---------------------------------------------------------------------------
# Free functions (pre-protocol public API, still the shared primitives)
# ---------------------------------------------------------------------------


def squared_euclidean(x: jax.Array, y: jax.Array) -> jax.Array:
    """C_ij = ||x_i - y_j||^2, shapes (n,d),(m,d) -> (n,m)."""
    x2 = jnp.sum(x * x, axis=-1)[:, None]
    y2 = jnp.sum(y * y, axis=-1)[None, :]
    C = x2 + y2 - 2.0 * (x @ y.T)
    return jnp.maximum(C, 0.0)


def gibbs_kernel(C: jax.Array, eps: float) -> jax.Array:
    """K = exp(-C / eps)."""
    return jnp.exp(-C / eps)


def neglog_kernel_cost(k_matrix: jax.Array, eps: float) -> jax.Array:
    """c(x,y) = -eps log k(x,y) — the kernel-first cost of Eq. (7)."""
    return -eps * jnp.log(k_matrix)


def data_radius(*point_sets: jax.Array) -> jax.Array:
    """R = max_i ||p_i||_2 over all supplied supports (for Lemma 1's q)."""
    return jnp.max(
        jnp.stack([jnp.max(jnp.linalg.norm(p, axis=-1)) for p in point_sets])
    )


def _masked_log(w: jax.Array) -> jax.Array:
    """log w with log(0) pinned to -inf without 0*inf NaN hazards."""
    return jnp.where(w > 0, jnp.log(jnp.where(w > 0, w, 1.0)), -jnp.inf)


def _stored(arr: jax.Array, precision: str) -> jax.Array:
    """Apply the storage half of the mixed-precision execution policy.

    ``precision="bf16"`` keeps the loop-invariant kernel representation
    (features, log-features, dense Gibbs kernel, low-rank factors) in
    bfloat16 — halving the HBM bytes the roofline says the iteration is
    bound by — while every contraction/LSE still ACCUMULATES in f32 (the
    bf16 operand promotes on use; on TPU the widening convert fuses into
    the matmul, so only the streamed bytes change)."""
    check_precision(precision)
    return arr.astype(jnp.bfloat16) if precision == "bf16" else arr


def _compute(arr: jax.Array) -> jax.Array:
    """Upcast a bf16-STORED operand to f32 at application time.

    Placed INSIDE the operator closures so the hoisted array keeps bf16
    storage (and bf16 HBM streaming — XLA/Mosaic fuse the widening
    convert into the consuming contraction) while the multiply/accumulate
    runs in f32. Relying on dtype promotion instead is a trap: JAX's weak
    types demote ``weak-f32 @ bf16`` to a bf16 contraction, silently
    dropping the accumulation precision the policy guarantees. Thin alias
    of :func:`repro.kernels.tiling.compute_f32` — the kernels' register
    upcast — so the rule has one implementation."""
    return compute_f32(arr)


def _factored_log_apply(log_u: jax.Array, log_w: jax.Array,
                        s: jax.Array) -> jax.Array:
    """log( (e^{log_u} e^{log_w}^T) e^{s} ) via the exact two-stage LSE.

    Positivity of the factored kernel makes the split exact:
        out_i = LSE_k( log_u[i,k] + LSE_j( log_w[j,k] + s_j ) ).
    Cost O(r (n + m)) — the paper's linear-time matvec, in log space.
    """
    t = _lse(log_w + s[:, None], axis=0)          # (r,)
    return _lse(log_u + t[None, :], axis=1)


def _shifted_log_product(log_u: jax.Array, log_w: jax.Array) -> jax.Array:
    """log(e^{log_u} @ e^{log_w}^T) densely, max-shifted per row so peak
    memory stays O(nm) instead of the O(nmr) broadcast of a pairwise LSE."""
    m1 = jnp.max(log_u, axis=1, keepdims=True)                 # (n, 1)
    m2 = jnp.max(log_w, axis=1, keepdims=True)                 # (m, 1)
    K = jnp.exp(log_u - m1) @ jnp.exp(log_w - m2).T
    return _masked_log(K) + m1 + m2.T


# ---------------------------------------------------------------------------
# Protocol
# ---------------------------------------------------------------------------


class Geometry(abc.ABC):
    """One entropic-OT cost family: the kernel-operator protocol.

    Subclasses carry their own parametrization (cost matrix, features,
    point clouds + anchors, low-rank factors, grid axes) plus ``eps``, and
    expose the operators above. Capability flags:

    ``anneal_capable`` — ``rebuild_at(eps)`` re-derives the kernel at an
        arbitrary eps (geometry-parameterized families). Families whose
        kernel is pinned to the eps their factors were drawn at raise.
    ``supports_log`` — log-domain operators exist (requires an entrywise
        POSITIVE kernel; signed Nystrom factors do not qualify).
    ``supports_features`` — ``features()`` can materialize strictly
        positive factors (what ``method='sharded'`` and the fused Pallas
        iteration consume).
    """

    anneal_capable: bool = False
    supports_log: bool = True
    supports_features: bool = False

    # -- shape ---------------------------------------------------------------

    @property
    @abc.abstractmethod
    def shape(self) -> Tuple[int, int]:
        """(n, m): support sizes of the two measures."""

    # -- scaling-space operators ---------------------------------------------

    @abc.abstractmethod
    def apply_k(self, v: jax.Array) -> jax.Array:
        """K v, shape (m,) -> (n,)."""

    @abc.abstractmethod
    def apply_kt(self, u: jax.Array) -> jax.Array:
        """K^T u, shape (n,) -> (m,)."""

    def operators(self, *, precision: str = "highest"
                  ) -> Tuple[Callable, Callable]:
        """(matvec, rmatvec) with loop-invariant work HOISTED.

        Solvers call this once before entering their ``lax.while_loop`` so
        per-family precomputation (materializing exp(-C/eps), exponentiating
        log-features, building per-axis grid kernels) happens once per
        solve, not twice per iteration — XLA does not hoist such work out
        of a while_loop body. Defaults to the bound per-call operators.

        ``precision`` is the mixed-precision execution policy (see
        :func:`_stored`): ``"bf16"`` stores the hoisted kernel
        representation at half width with f32 accumulation. Families
        override to apply it; this default validates and ignores it (no
        hoisted representation to store).
        """
        check_precision(precision)
        return self.apply_k, self.apply_kt

    # -- log-domain operators ------------------------------------------------

    def log_apply_k(self, g: jax.Array) -> jax.Array:
        """log(K e^{g/eps}), shape (m,) -> (n,)."""
        raise ValueError(
            f"{type(self).__name__} has no log-domain operators "
            "(kernel entries are not guaranteed positive); use a "
            "scaling-space method"
        )

    def log_apply_kt(self, f: jax.Array) -> jax.Array:
        """log(K^T e^{f/eps}), shape (n,) -> (m,)."""
        raise ValueError(
            f"{type(self).__name__} has no log-domain operators "
            "(kernel entries are not guaranteed positive); use a "
            "scaling-space method"
        )

    def log_operators(self, *, precision: str = "highest"
                      ) -> Tuple[Callable, Callable]:
        """(log_matvec, log_rmatvec) with loop-invariant work hoisted —
        the log-domain twin of :meth:`operators` (``precision="bf16"``
        stores log-features/log-kernels at half width; every LSE still
        accumulates in f32)."""
        check_precision(precision)
        return self.log_apply_k, self.log_apply_kt

    # -- dense views ---------------------------------------------------------

    @abc.abstractmethod
    def cost_matrix(self) -> jax.Array:
        """Dense (n, m) ground cost for the quadratic baselines.

        Point-cloud families return the TRUE squared-Euclidean cost (the
        paper's ``Sin`` baseline); factored families return the induced
        cost ``-eps log(Xi Zeta^T)`` so all methods share one fixed point.
        """

    def dense_kernel(self) -> jax.Array:
        """The exact dense (n, m) kernel the operators apply — the oracle
        every operator is property-tested against."""
        return jnp.exp(self.log_dense_kernel())

    def log_dense_kernel(self) -> jax.Array:
        """log of :meth:`dense_kernel` (positive-kernel families)."""
        raise ValueError(
            f"{type(self).__name__} kernel may be signed; use dense_kernel()"
        )

    # -- eps handling --------------------------------------------------------

    def rebuild_at(self, eps: float) -> "Geometry":
        """This geometry's kernel re-derived at ``eps`` (annealing)."""
        if float(eps) == float(self.eps):
            return self
        raise ValueError(
            f"{type(self).__name__} pins the kernel to the eps its factors "
            f"were built at ({self.eps}); got {eps}. Build the problem from "
            "point clouds (GaussianPointCloud) to enable eps-annealing."
        )

    # -- factored views ------------------------------------------------------

    def features(self) -> Tuple[jax.Array, jax.Array]:
        """(xi, zeta): strictly positive factors with K = xi @ zeta.T."""
        raise ValueError(
            "no factored kernel available "
            f"({type(self).__name__}); use a quadratic method"
        )

    def log_features(self) -> Tuple[jax.Array, jax.Array]:
        """(log_xi, log_zeta) — log of :meth:`features`."""
        xi, zeta = self.features()
        return _masked_log(xi), _masked_log(zeta)

    # -- divergence sub-geometries -------------------------------------------

    def xx(self) -> "Geometry":
        """The (mu, mu) self-geometry — W(mu, mu) term of the divergence."""
        raise ValueError(
            f"{type(self).__name__} does not define self-geometries; the "
            "Sinkhorn divergence needs a per-measure parametrization"
        )

    def yy(self) -> "Geometry":
        """The (nu, nu) self-geometry — W(nu, nu) term of the divergence."""
        raise ValueError(
            f"{type(self).__name__} does not define self-geometries; the "
            "Sinkhorn divergence needs a per-measure parametrization"
        )

    # -- distribution hook ---------------------------------------------------

    @property
    def spmd_axis(self) -> Optional[str]:
        """Mesh axis this geometry's operators psum over, or ``None``.

        Single-device geometries return ``None``. The row-sharded wrappers
        in ``core.sharded`` return their mesh axis, which tells the solver
        core (``sinkhorn.py``) and the envelope VJP (``grad.py``) to psum
        every scalar reduction (marginal error, dual value, correlation
        term) so while_loop carries and results replicate across devices.
        """
        return None

    # -- accelerator dispatch ------------------------------------------------

    def pallas_ops(self) -> Optional[dict]:
        """Spec consumed by ``kernels.ops.geometry_ops`` to choose fused
        Pallas kernels (fused feature map, feature_contract, batched
        half-step). ``None`` means no fused path — callers fall back to the
        XLA operators above."""
        return None


class _FeatureKernelOps:
    """Mixin: the factored-kernel operators, derived entirely from
    ``features()`` / ``log_features()``. Shared by every positive-feature
    family so the O(r(n+m)) matvec and exact two-stage-LSE plumbing exists
    in exactly one place. ``operators()``/``log_operators()`` materialize
    the factors ONCE and close over them, so solver while_loops never
    recompute features per iteration."""

    def operators(self, *, precision: str = "highest"):
        xi, zeta = (_stored(w, precision) for w in self.features())
        return (lambda v: _compute(xi) @ (_compute(zeta).T @ v),
                lambda u: _compute(zeta) @ (_compute(xi).T @ u))

    def log_operators(self, *, precision: str = "highest"):
        eps = self.eps
        lxi, lzt = (_stored(w, precision) for w in self.log_features())
        return (lambda g: _factored_log_apply(_compute(lxi), _compute(lzt),
                                              g / eps),
                lambda f: _factored_log_apply(_compute(lzt), _compute(lxi),
                                              f / eps))

    def apply_k(self, v):
        return self.operators()[0](v)

    def apply_kt(self, u):
        return self.operators()[1](u)

    def log_apply_k(self, g):
        return self.log_operators()[0](g)

    def log_apply_kt(self, f):
        return self.log_operators()[1](f)

    def log_dense_kernel(self):
        lxi, lzt = self.log_features()
        return _shifted_log_product(lxi, lzt)


# ---------------------------------------------------------------------------
# Dense cost
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class DenseCost(Geometry):
    """Explicit (n, m) ground cost; Gibbs kernel K = exp(-C/eps).

    O(nm) matvecs — the universal fallback and the paper's ``Sin``
    baseline. Anneal-capable: the kernel is re-derivable at any eps.
    """

    C: jax.Array
    eps: float = dataclasses.field(metadata=dict(static=True))

    anneal_capable = True
    supports_log = True

    @property
    def shape(self) -> Tuple[int, int]:
        return self.C.shape

    def operators(self, *, precision: str = "highest"):
        # materialized ONCE per solve (bf16 storage under the policy)
        K = _stored(jnp.exp(-self.C / self.eps), precision)
        return (lambda v: _compute(K) @ v), (lambda u: _compute(K).T @ u)

    def log_operators(self, *, precision: str = "highest"):
        eps = self.eps
        negC = _stored(-self.C / eps, precision)
        return (lambda g: _lse(_compute(negC) + (g / eps)[None, :], axis=1),
                lambda f: _lse(_compute(negC) + (f / eps)[:, None], axis=0))

    def apply_k(self, v):
        return self.operators()[0](v)

    def apply_kt(self, u):
        return self.operators()[1](u)

    def log_apply_k(self, g):
        return self.log_operators()[0](g)

    def log_apply_kt(self, f):
        return self.log_operators()[1](f)

    def cost_matrix(self):
        return self.C

    def log_dense_kernel(self):
        return -self.C / self.eps

    def rebuild_at(self, eps: float) -> "DenseCost":
        return self if float(eps) == float(self.eps) else \
            DenseCost(self.C, float(eps))


# ---------------------------------------------------------------------------
# Exact positive-feature factorization (Lemma 1 / Lemma 3 output form)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class FactoredPositive(_FeatureKernelOps, Geometry):
    """K = Xi Zeta^T from explicit positive features or log-features.

    The paper's central object: every matvec costs O(r(n+m)) and — all
    entries being strictly positive — Sinkhorn converges for ANY r. The
    kernel is pinned to the eps the features were drawn at, so this family
    is not anneal-capable; use :class:`GaussianPointCloud` for annealing.
    """

    xi: Optional[jax.Array] = None
    zeta: Optional[jax.Array] = None
    log_xi: Optional[jax.Array] = None
    log_zeta: Optional[jax.Array] = None
    eps: float = dataclasses.field(kw_only=True,
                                   metadata=dict(static=True))

    anneal_capable = False
    supports_log = True
    supports_features = True

    def __post_init__(self):
        have_lin = self.xi is not None and self.zeta is not None
        have_log = self.log_xi is not None and self.log_zeta is not None
        if have_lin == have_log:
            raise ValueError(
                "FactoredPositive needs exactly one factor pair: "
                "(xi, zeta) or (log_xi, log_zeta)"
            )

    @property
    def shape(self) -> Tuple[int, int]:
        if self.xi is not None:
            return self.xi.shape[0], self.zeta.shape[0]
        return self.log_xi.shape[0], self.log_zeta.shape[0]

    @property
    def rank(self) -> int:
        return (self.xi if self.xi is not None else self.log_xi).shape[1]

    def features(self):
        if self.xi is not None:
            return self.xi, self.zeta
        return jnp.exp(self.log_xi), jnp.exp(self.log_zeta)

    def log_features(self):
        if self.log_xi is not None:
            return self.log_xi, self.log_zeta
        return _masked_log(self.xi), _masked_log(self.zeta)

    def cost_matrix(self):
        return -self.eps * self.log_dense_kernel()

    def xx(self) -> "FactoredPositive":
        if self.xi is not None:
            return FactoredPositive(xi=self.xi, zeta=self.xi, eps=self.eps)
        return FactoredPositive(log_xi=self.log_xi, log_zeta=self.log_xi,
                                eps=self.eps)

    def yy(self) -> "FactoredPositive":
        if self.zeta is not None:
            return FactoredPositive(xi=self.zeta, zeta=self.zeta,
                                    eps=self.eps)
        return FactoredPositive(log_xi=self.log_zeta, log_zeta=self.log_zeta,
                                eps=self.eps)

    def pallas_ops(self):
        if self.xi is not None:
            return {"kind": "factored", "xi": self.xi, "zeta": self.zeta}
        # log mode: hand the raw log-factors over so the log plan never
        # round-trips through exp (small-eps safety); the scaling plan
        # exponentiates once at plan-build time.
        return {"kind": "log_factored", "log_xi": self.log_xi,
                "log_zeta": self.log_zeta, "eps": self.eps}


# ---------------------------------------------------------------------------
# Lemma 1: Gaussian point clouds (anchors + eps-rebuildable)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class GaussianPointCloud(_FeatureKernelOps, Geometry):
    """Point clouds + Lemma-1 anchors: features re-derived at any eps.

    The only family that composes with an ``EpsSchedule`` (annealing) and
    exposes learnable-anchor gradients (the GAN theta of Eq. 18).
    ``cost_matrix`` is the TRUE squared-Euclidean cost — the ``Sin``
    baseline — while the operators apply the Lemma-1 Monte-Carlo kernel.
    """

    x: jax.Array                        # (n, d)
    y: jax.Array                        # (m, d)
    anchors: jax.Array                  # (r, d)
    eps: float = dataclasses.field(metadata=dict(static=True))
    R: float = dataclasses.field(metadata=dict(static=True))

    anneal_capable = True
    supports_log = True
    supports_features = True

    @classmethod
    def build(cls, x, y, anchors, *, eps: float,
              R: Optional[float] = None) -> "GaussianPointCloud":
        R = float(data_radius(x, y)) if R is None else float(R)
        return cls(x=x, y=y, anchors=anchors, eps=float(eps), R=R)

    @property
    def shape(self) -> Tuple[int, int]:
        return self.x.shape[0], self.y.shape[0]

    @property
    def q(self) -> float:
        return gaussian_q(self.R, self.eps, self.x.shape[-1])

    def log_features(self):
        q = self.q
        lxi = gaussian_log_features(self.x, self.anchors, eps=self.eps, q=q)
        lzt = gaussian_log_features(self.y, self.anchors, eps=self.eps, q=q)
        return lxi, lzt

    def features(self):
        lxi, lzt = self.log_features()
        return jnp.exp(lxi), jnp.exp(lzt)

    def cost_matrix(self):
        return squared_euclidean(self.x, self.y)

    def rebuild_at(self, eps: float) -> "GaussianPointCloud":
        return self if float(eps) == float(self.eps) else \
            GaussianPointCloud(self.x, self.y, self.anchors,
                               eps=float(eps), R=self.R)

    def xx(self) -> "GaussianPointCloud":
        return GaussianPointCloud(self.x, self.x, self.anchors,
                                  eps=self.eps, R=self.R)

    def yy(self) -> "GaussianPointCloud":
        return GaussianPointCloud(self.y, self.y, self.anchors,
                                  eps=self.eps, R=self.R)

    def pallas_ops(self):
        r = self.anchors.shape[0]
        log_const = (_anchor_log_const(self.anchors, self.q, self.eps)
                     - 0.5 * jnp.log(jnp.asarray(r, jnp.float32)))
        return {
            "kind": "gaussian",
            "x": self.x,
            "y": self.y,
            "anchors": self.anchors,
            "log_const": log_const,
            "inv_eps": 1.0 / self.eps,
        }


# ---------------------------------------------------------------------------
# Lemma 3: perturbed arc-cosine point clouds
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class ArcCosinePointCloud(_FeatureKernelOps, Geometry):
    """Lemma-3 perturbed arc-cosine kernel k_s(x, y) + kappa on point clouds.

    Features are relu-rectified random projections plus one constant
    sqrt(kappa) coordinate, so the kernel is bounded below by kappa > 0
    even though individual features may be zero (the log-features carry
    -inf entries, which the exact two-stage LSE handles).

    The induced cost is c = -eps log(k_s + kappa); its Gibbs kernel at eps
    is k_s + kappa for EVERY eps, i.e. the kernel is eps-invariant —
    annealing is a no-op for this family, hence not anneal-capable.
    """

    x: jax.Array                        # (n, d)
    y: jax.Array                        # (m, d)
    anchors: jax.Array                  # (r, d), u ~ N(0, sigma^2 I)
    eps: float = dataclasses.field(metadata=dict(static=True))
    s: int = dataclasses.field(default=1, metadata=dict(static=True))
    sigma: float = dataclasses.field(default=1.5, metadata=dict(static=True))
    kappa: float = dataclasses.field(default=1e-3, metadata=dict(static=True))

    anneal_capable = False
    supports_log = True
    supports_features = True

    def __post_init__(self):
        if not self.kappa > 0:
            raise ValueError(
                "ArcCosinePointCloud needs kappa > 0 (Lemma 3's positivity "
                f"floor), got {self.kappa}"
            )

    @property
    def shape(self) -> Tuple[int, int]:
        return self.x.shape[0], self.y.shape[0]

    def features(self):
        kw = dict(s=self.s, sigma=self.sigma, kappa=self.kappa)
        return (arccos_features(self.x, self.anchors, **kw),
                arccos_features(self.y, self.anchors, **kw))

    def cost_matrix(self):
        return -self.eps * self.log_dense_kernel()

    def xx(self) -> "ArcCosinePointCloud":
        return dataclasses.replace(self, y=self.x)

    def yy(self) -> "ArcCosinePointCloud":
        return dataclasses.replace(self, x=self.y)

    def pallas_ops(self):
        xi, zeta = self.features()
        return {"kind": "factored", "xi": xi, "zeta": zeta}


# ---------------------------------------------------------------------------
# Nystrom signed low-rank (the paper's Nys baseline)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class NystromLowRank(Geometry):
    """K_tilde = L @ Rt: landmark-Nystrom factors of the Gibbs kernel.

    Same O(l(n+m)) matvec cost as the positive-feature path, BUT entries
    of K_tilde can be NEGATIVE: Sinkhorn scalings can cross zero and the
    iteration diverges at small eps (paper Figs. 1/3/5). There is no
    log-domain operator (LSE needs positive entries) and no well-defined
    induced cost; divergence is surfaced through
    ``SinkhornResult.diverged`` rather than raw NaNs.
    """

    L: jax.Array                        # (n, l)
    Rt: jax.Array                       # (l, m)
    eps: float = dataclasses.field(metadata=dict(static=True))

    anneal_capable = False
    supports_log = False
    supports_features = False

    @classmethod
    def from_point_clouds(
        cls, x: jax.Array, y: jax.Array, *, eps: float, rank: int,
        key: jax.Array, ridge: float = 1e-10,
    ) -> "NystromLowRank":
        """Landmark-Nystrom factorization of exp(-||x-y||^2/eps).

        Uniform landmark sampling + eigenvalue-truncated pseudo-inverse
        (stable in f32): invert only the spectrum above tau * lambda_max.
        """
        pool = jnp.concatenate([x, y], axis=0)
        idx = jax.random.choice(key, pool.shape[0], (rank,), replace=False)
        z = pool[idx]                                       # (l, d) landmarks
        K_xz = jnp.exp(-squared_euclidean(x, z) / eps)      # (n, l)
        K_zy = jnp.exp(-squared_euclidean(z, y) / eps)      # (l, m)
        K_zz = jnp.exp(-squared_euclidean(z, z) / eps)
        w, Q = jnp.linalg.eigh(K_zz)
        tau = ridge if ridge > 1e-8 else 1e-5
        keep = w > tau * jnp.max(w)
        w_inv = jnp.where(keep, 1.0 / jnp.where(keep, w, 1.0), 0.0)
        inv = (Q * w_inv[None, :]) @ Q.T
        return cls(L=K_xz @ inv, Rt=K_zy, eps=float(eps))

    @property
    def shape(self) -> Tuple[int, int]:
        return self.L.shape[0], self.Rt.shape[1]

    @property
    def rank(self) -> int:
        return self.L.shape[1]

    def operators(self, *, precision: str = "highest"):
        L, Rt = _stored(self.L, precision), _stored(self.Rt, precision)
        return (lambda v: _compute(L) @ (_compute(Rt) @ v),
                lambda u: _compute(Rt).T @ (_compute(L).T @ u))

    def apply_k(self, v):
        return self.L @ (self.Rt @ v)

    def apply_kt(self, u):
        return self.Rt.T @ (self.L.T @ u)

    def dense_kernel(self):
        return self.L @ self.Rt

    def cost_matrix(self):
        raise ValueError(
            "the signed Nystrom kernel has no well-defined induced cost "
            "(-eps log K_tilde hits negative entries); build a DenseCost "
            "from the true ground cost instead"
        )


# ---------------------------------------------------------------------------
# Separable costs on regular grids (images / histograms workload)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class GridSeparable(Geometry):
    """Separable cost on a regular grid: C = sum_k c_k(i_k, j_k).

    The Gibbs kernel is then a Kronecker product K = K_1 x ... x K_d, so a
    matvec is d axis-wise convolutions — O(n^{1+1/d}) for n grid points
    instead of O(n^2) (convolutional Wasserstein; Solomon et al. '15).
    Per-axis costs are squared distances of the axis coordinates, so the
    total cost is the squared Euclidean distance between grid points.

    ``axes_x`` / ``axes_y`` are per-dimension coordinate vectors; measures
    live on the cartesian products in C (row-major) order, i.e. a weight
    vector is ``image.reshape(-1)``. Anneal-capable: the tiny per-axis
    kernels rebuild at any eps.
    """

    axes_x: Tuple[jax.Array, ...]       # d arrays, lengths (n_1, ..., n_d)
    axes_y: Tuple[jax.Array, ...]       # d arrays, lengths (m_1, ..., m_d)
    eps: float = dataclasses.field(metadata=dict(static=True))

    anneal_capable = True
    supports_log = True
    supports_features = False

    @classmethod
    def build(cls, axes_x, axes_y=None, *, eps: float) -> "GridSeparable":
        axes_x = tuple(jnp.asarray(t) for t in axes_x)
        axes_y = axes_x if axes_y is None else \
            tuple(jnp.asarray(t) for t in axes_y)
        return cls(axes_x=axes_x, axes_y=axes_y, eps=float(eps))

    def __post_init__(self):
        if len(self.axes_x) != len(self.axes_y) or not self.axes_x:
            raise ValueError(
                "GridSeparable needs matching, non-empty per-dimension axis "
                f"tuples; got {len(self.axes_x)} x and {len(self.axes_y)} y"
            )

    @property
    def ndim(self) -> int:
        return len(self.axes_x)

    @property
    def grid_shape_x(self) -> Tuple[int, ...]:
        return tuple(t.shape[0] for t in self.axes_x)

    @property
    def grid_shape_y(self) -> Tuple[int, ...]:
        return tuple(t.shape[0] for t in self.axes_y)

    @property
    def shape(self) -> Tuple[int, int]:
        n = m = 1
        for t in self.axes_x:
            n *= t.shape[0]
        for t in self.axes_y:
            m *= t.shape[0]
        return n, m

    def _axis_costs(self):
        """Per-axis (n_k, m_k) squared-distance costs."""
        return tuple(
            (tx[:, None] - ty[None, :]) ** 2
            for tx, ty in zip(self.axes_x, self.axes_y)
        )

    @staticmethod
    def _conv(mats, grid, v):
        """d axis-wise contractions: one small (n_k, m_k) matmul per axis."""
        V = v.reshape(grid)
        for k, Mk in enumerate(mats):
            V = jnp.moveaxis(jnp.tensordot(Mk, V, axes=(1, k)), 0, k)
        return V.reshape(-1)

    @staticmethod
    def _log_conv(log_mats, grid, s):
        """Sequential axis-wise LSE: exact because every K_k is positive."""
        out = s.reshape(grid)
        for k, logK in enumerate(log_mats):
            t = jnp.moveaxis(out, k, -1)                    # (..., in_k)
            t = _lse(logK[..., :, :] + t[..., None, :], axis=-1)
            out = jnp.moveaxis(t, -1, k)                    # (..., out_k)
        return out.reshape(-1)

    def operators(self, *, precision: str = "highest"):
        # per-axis kernels are tiny ((n_k, m_k), streamed once per
        # contraction) — bf16 storage is applied for policy uniformity,
        # not for a measurable byte win
        Ks = tuple(_stored(jnp.exp(-ck / self.eps), precision)  # built ONCE
                   for ck in self._axis_costs())
        KTs = tuple(Kk.T for Kk in Ks)
        gy, gx = self.grid_shape_y, self.grid_shape_x
        return (lambda v: self._conv([_compute(k) for k in Ks], gy, v),
                lambda u: self._conv([_compute(k) for k in KTs], gx, u))

    def log_operators(self, *, precision: str = "highest"):
        eps = self.eps
        logKs = tuple(_stored(-ck / eps, precision)
                      for ck in self._axis_costs())
        logKTs = tuple(lk.T for lk in logKs)
        gy, gx = self.grid_shape_y, self.grid_shape_x
        return (lambda g: self._log_conv([_compute(k) for k in logKs],
                                         gy, g / eps),
                lambda f: self._log_conv([_compute(k) for k in logKTs],
                                         gx, f / eps))

    def apply_k(self, v):
        return self.operators()[0](v)

    def apply_kt(self, u):
        return self.operators()[1](u)

    def log_apply_k(self, g):
        return self.log_operators()[0](g)

    def log_apply_kt(self, f):
        return self.log_operators()[1](f)

    def cost_matrix(self):
        C = None
        for ck in self._axis_costs():
            if C is None:
                C = ck
            else:
                n0, m0 = C.shape
                nk, mk = ck.shape
                C = (C[:, None, :, None] + ck[None, :, None, :]) \
                    .reshape(n0 * nk, m0 * mk)
        return C

    def log_dense_kernel(self):
        return -self.cost_matrix() / self.eps

    def rebuild_at(self, eps: float) -> "GridSeparable":
        return self if float(eps) == float(self.eps) else \
            GridSeparable(self.axes_x, self.axes_y, eps=float(eps))

    def xx(self) -> "GridSeparable":
        return GridSeparable(self.axes_x, self.axes_x, eps=self.eps)

    def yy(self) -> "GridSeparable":
        return GridSeparable(self.axes_y, self.axes_y, eps=self.eps)


# ---------------------------------------------------------------------------
# Pytree registration + coercion helper
# ---------------------------------------------------------------------------


def _register(cls):
    fields = dataclasses.fields(cls)
    data = [f.name for f in fields if not f.metadata.get("static")]
    meta = [f.name for f in fields if f.metadata.get("static")]
    jax.tree_util.register_dataclass(cls, data_fields=data, meta_fields=meta)
    return cls


for _cls in (DenseCost, FactoredPositive, GaussianPointCloud,
             ArcCosinePointCloud, NystromLowRank, GridSeparable):
    _register(_cls)


def as_geometry(obj, *, eps: Optional[float] = None) -> Geometry:
    """Coerce ``obj`` into a Geometry: pass-through for geometries, a dense
    (n, m) cost array becomes :class:`DenseCost` (requires ``eps``)."""
    if isinstance(obj, Geometry):
        return obj if eps is None else obj.rebuild_at(eps)
    arr = jnp.asarray(obj)
    if arr.ndim == 2:
        if eps is None:
            raise ValueError("as_geometry(cost_array) requires eps=")
        return DenseCost(arr, float(eps))
    raise TypeError(f"cannot interpret {type(obj).__name__} as a Geometry")
