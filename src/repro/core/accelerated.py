"""Accelerated Sinkhorn (paper Remark 2 / Appendix A.2, after Guminov et
al.): accelerated alternating minimization on the smoothed dual

    F(f, g) = <f, a> + <g, b> - eps * log( e^{f/eps}^T K e^{g/eps} )

which is L-smooth with L <= 2/eps. Each iteration takes the EXACT
alternating-minimization step on the better of the two blocks (a classic
Sinkhorn half-step, O(r(n+m)) on the factored kernel) plus a Nesterov
extrapolation with adaptive L search — the O(n r / sqrt(delta)) rate of
Theorem A.2 versus O(n r / delta) for plain Alg. 1.

Implementation keeps everything in log-space on the factored kernel
(exact two-stage LSE), so it composes with Lemma-1 features at small eps.

Convergence is measured on the sum of BOTH marginal errors (an exact block
step zeroes one of them by construction), which doubles the f32 noise
floor relative to the one-marginal solvers: tolerances below ~1e-6 may
exhaust ``max_iter`` with ``converged=False`` even at the fixed point.
Use ``sinkhorn_log_factored`` when you need the tightest f32 tolerances.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .geometry import FactoredPositive, Geometry
from .sinkhorn import (
    SinkhornResult,
    masked_dual_value,
)

__all__ = [
    "accelerated_sinkhorn_geometry",
    "accelerated_sinkhorn_log_factored",
]


def _lse(x, axis):
    return jax.scipy.special.logsumexp(x, axis=axis)


def accelerated_sinkhorn_log_factored(
    log_xi: jax.Array,       # (n, r)
    log_zeta: jax.Array,     # (m, r)
    a: jax.Array,
    b: jax.Array,
    *,
    eps: float,
    tol: float = 1e-6,
    max_iter: int = 2000,
    f_init: Optional[jax.Array] = None,
    g_init: Optional[jax.Array] = None,
) -> SinkhornResult:
    """AGM on an explicit positive-feature factorization (thin wrapper
    over :func:`accelerated_sinkhorn_geometry`)."""
    return accelerated_sinkhorn_geometry(
        FactoredPositive(log_xi=log_xi, log_zeta=log_zeta, eps=eps),
        a, b, tol=tol, max_iter=max_iter, f_init=f_init, g_init=g_init,
    )


def accelerated_sinkhorn_geometry(
    geom: Geometry,
    a: jax.Array,
    b: jax.Array,
    *,
    tol: float = 1e-6,
    max_iter: int = 2000,
    f_init: Optional[jax.Array] = None,
    g_init: Optional[jax.Array] = None,
    check_every: int = 1,
) -> SinkhornResult:
    """Accelerated alternating minimization on any log-capable Geometry.

    ``check_every`` applies the shared convergence-check cadence: the AGM
    body runs that many iterations per while_loop evaluation (unrolled, so
    the intermediate two-sided marginal errors — two extra operator
    applications each — are dead code XLA eliminates). Iteration counts
    become multiples of the cadence; a converged result still satisfies
    ``err <= tol``."""
    check_every = int(check_every)
    if check_every < 1:
        raise ValueError(f"check_every must be >= 1, got {check_every}")
    eps = geom.eps
    n, m = a.shape[0], b.shape[0]
    dtype = a.dtype
    loga, logb = jnp.log(a), jnp.log(b)

    # the same exact log-operators every log-domain solver uses, supplied
    # hoisted by the geometry (factored LSE, grid log-convolution, dense)
    log_K, log_K_T = geom.log_operators()

    def neg_F(f, g):
        # -F: convex objective to MINIMIZE; log-partition form
        logZ = _lse(log_K(g) + f / eps, axis=0)
        return eps * logZ - jnp.vdot(f, a) - jnp.vdot(g, b)

    grad_f = jax.grad(neg_F, argnums=0)
    grad_g = jax.grad(neg_F, argnums=1)

    class State(NamedTuple):
        it: jax.Array
        f: jax.Array
        g: jax.Array
        zf: jax.Array        # extrapolation sequence
        zg: jax.Array
        A: jax.Array         # accumulated weight
        err: jax.Array

    def body(s: State) -> State:
        beta = s.A / (s.A + 1.0)
        yf = beta * s.f + (1 - beta) * s.zf
        yg = beta * s.g + (1 - beta) * s.zg
        gf = grad_f(yf, yg)
        gg = grad_g(yf, yg)
        # pick the block with the larger gradient; take its EXACT argmin
        # (a Sinkhorn half-step), which is the AM step of Alg. 2.
        use_f = jnp.sum(gf * gf) >= jnp.sum(gg * gg)
        f_new = jnp.where(use_f, eps * (loga - log_K(yg)), yf)
        g_new = jnp.where(use_f, yg, eps * (logb - log_K_T(yf)))
        # dual (momentum) sequence update
        step = (s.A + 1.0) * eps / 2.0
        zf = s.zf - step * gf
        zg = s.zg - step * gg
        # BOTH marginals: right after an exact block step, that block's
        # marginal is feasible by construction — checking only one would
        # declare convergence vacuously.
        log_col = log_K_T(f_new) + g_new / eps
        log_row = log_K(g_new) + f_new / eps
        err = (jnp.sum(jnp.abs(jnp.exp(log_col) - b))
               + jnp.sum(jnp.abs(jnp.exp(log_row) - a)))
        return State(s.it + 1, f_new, g_new, zf, zg, s.A + 1.0, err)

    def block(s: State) -> State:
        for _ in range(check_every):
            s = body(s)
        return s

    def cond(s: State):
        return (s.it < max_iter) & (s.err > tol) & jnp.isfinite(s.err)

    z = jnp.zeros((n,), dtype) if f_init is None else f_init
    zg0 = jnp.zeros((m,), dtype) if g_init is None else g_init
    s = State(jnp.array(0, jnp.int32), z, zg0, z, zg0,
              jnp.asarray(1.0, dtype), jnp.asarray(jnp.inf, dtype))
    s = jax.lax.while_loop(cond, block, block(s))
    # finish with one exact f-step so the Eq.-6 shortcut holds
    f = eps * (loga - log_K(s.g))
    cost = masked_dual_value(a, b, f, s.g)
    u, v = jnp.exp(f / eps), jnp.exp(s.g / eps)
    return SinkhornResult(u, v, f, s.g, cost, s.it, s.err, s.err <= tol)
