"""Distributed linear Sinkhorn via ``shard_map``.

The factored kernel is what makes Sinkhorn *distributable*: shard the
SUPPORT of each measure over the ``data`` mesh axis —

    Xi   : (n/p, r) per device        Zeta : (m/p, r) per device
    u,a  : (n/p,)   per device        v,b  : (m/p,)   per device

Each half-iteration is a LOCAL thin contraction followed by ONE tiny
all-reduce of an r-vector:

    t = psum_data( Xi_loc^T u_loc )          # (r,)  <- r floats on the wire
    v_loc = b_loc / (Zeta_loc @ t)

Quadratic Sinkhorn would instead need every device to see all n columns of
K (an O(n m / p) all-to-all per iteration). The r-vector psum is the entire
communication cost of the paper's method — this is the collective-term win
quantified in EXPERIMENTS.md §Roofline.

The distribution-aware operators live in :class:`RowShardedFactored` — a
Geometry subclass whose ``apply_k``/``apply_kt`` psum the thin contraction
— so the SPMD body composes the exact same ``make_scaling_step`` building
block as the single-device solver, fed by a geometry like everywhere else.

Convergence is checked with a psum'd local L1 error, so the while_loop
carries a replicated scalar and all devices exit together (no divergence of
control flow — a requirement for SPMD).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .geometry import FactoredPositive, Geometry
from .sinkhorn import (
    SinkhornResult,
    make_scaling_step,
    masked_dual_value,
    run_marginal_loop,
)

__all__ = [
    "RowShardedFactored",
    "sharded_sinkhorn_factored",
    "sharded_sinkhorn_geometry",
    "make_sharded_sinkhorn",
]


@dataclasses.dataclass(frozen=True, eq=False)
class RowShardedFactored(FactoredPositive):
    """Per-device shard of a factored geometry, used INSIDE ``shard_map``.

    ``xi``/``zeta`` hold the local (n/p, r)/(m/p, r) feature rows; the
    operators produce locally-sharded outputs after psum-ing the shared
    r-vector over ``axis`` — the only cross-device traffic per iteration.

    Log-domain operators are DISABLED: the inherited factored LSE would
    reduce over only the local feature rows (a psum'd logsumexp is not
    implemented), silently dropping every other device's contribution.
    The sharded solver runs in scaling space.
    """

    axis: str = dataclasses.field(default="data",
                                  metadata=dict(static=True))

    supports_log = False

    def apply_k(self, v):                        # K v, sharded (n/p,)
        t = jax.lax.psum(self.zeta.T @ v, self.axis)     # (r,) replicated
        return self.xi @ t

    def apply_kt(self, u):                       # K^T u, sharded (m/p,)
        t = jax.lax.psum(self.xi.T @ u, self.axis)
        return self.zeta @ t

    def operators(self):
        # the psum'd matvecs read fields directly — nothing to hoist
        return self.apply_k, self.apply_kt

    def _no_log(self, *_):
        raise ValueError(
            "RowShardedFactored has no log-domain operators: the local LSE "
            "would miss the other shards' feature rows; use the "
            "scaling-space sharded solver"
        )

    log_apply_k = _no_log
    log_apply_kt = _no_log

    def log_operators(self):
        self._no_log()

    def pallas_ops(self):
        # the inherited "factored" spec would hand the LOCAL feature shard
        # to the fused plan, whose iteration has no psum — every other
        # device's rows would be silently dropped. No fused path.
        return None


def _sharded_body(xi, zeta, a, b, *, eps, tol, max_iter, axis):
    """Runs INSIDE shard_map. All arrays are per-device shards.

    Composes the SAME ``make_scaling_step`` block as the single-device
    solver — only the geometry (psum'd :class:`RowShardedFactored`
    operators) and the error reduction (psum'd local L1) are
    distribution-aware.
    """
    n_loc = a.shape[0]
    m_loc = b.shape[0]
    dtype = a.dtype
    geom = RowShardedFactored(xi=xi, zeta=zeta, eps=eps, axis=axis)

    step = make_scaling_step(
        geom.apply_k, geom.apply_kt, a, b,
        err_reduce=lambda e: jax.lax.psum(jnp.sum(e), axis),
    )
    u0 = jnp.ones((n_loc,), dtype)
    v0 = jnp.ones((m_loc,), dtype)
    it, (u, v, _), err = run_marginal_loop(
        step, (u0, v0, geom.apply_kt(u0)), tol=tol, max_iter=max_iter,
        dtype=dtype
    )
    f, g = eps * jnp.log(u), eps * jnp.log(v)
    cost = jax.lax.psum(masked_dual_value(a, b, f, g), axis)
    return SinkhornResult(u, v, f, g, cost, it, err, err <= tol)


def make_sharded_sinkhorn(mesh, *, axis: str = "data", eps: float,
                          tol: float = 1e-6, max_iter: int = 2000):
    """Build a shard_map'd solver bound to ``mesh``.

    Inputs are globally-shaped; supports shard over ``axis``; the feature
    dimension r and the result replicate.
    """
    body = partial(_sharded_body, eps=eps, tol=tol, max_iter=max_iter,
                   axis=axis)
    out_specs = SinkhornResult(
        u=P(axis), v=P(axis), f=P(axis), g=P(axis),
        cost=P(), n_iter=P(), marginal_err=P(), converged=P(),
    )
    from ..distributed.sharding import shard_map

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis), P(axis)),
        out_specs=out_specs,
        check_vma=False,
    )


def sharded_sinkhorn_factored(
    mesh, xi, zeta, a, b, *, eps: float, axis: str = "data",
    tol: float = 1e-6, max_iter: int = 2000
) -> SinkhornResult:
    fn = make_sharded_sinkhorn(mesh, axis=axis, eps=eps, tol=tol,
                               max_iter=max_iter)
    return fn(xi, zeta, a, b)


def sharded_sinkhorn_geometry(
    mesh, geom: Geometry, a, b, *, axis: str = "data",
    tol: float = 1e-6, max_iter: int = 2000
) -> SinkhornResult:
    """Shard-map solve of any feature-capable Geometry.

    Materializes the strictly positive factors once (``geom.features()``),
    shards their rows over ``axis`` and runs the psum'd scaling loop.
    """
    if not geom.supports_features:
        raise ValueError(
            "method='sharded' needs a geometry with materializable positive "
            f"features; {type(geom).__name__} has none"
        )
    xi, zeta = geom.features()
    return sharded_sinkhorn_factored(
        mesh, xi, zeta, a, b, eps=geom.eps, axis=axis, tol=tol,
        max_iter=max_iter,
    )
