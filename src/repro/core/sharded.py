"""Distributed linear Sinkhorn via ``shard_map``.

The factored kernel is what makes Sinkhorn *distributable*: shard the
SUPPORT of each measure over the ``data`` mesh axis —

    Xi   : (n/p, r) per device        Zeta : (m/p, r) per device
    u,a  : (n/p,)   per device        v,b  : (m/p,)   per device

Each half-iteration is a LOCAL thin contraction followed by ONE tiny
all-reduce of an r-vector:

    t = psum_data( Xi_loc^T u_loc )          # (r,)  <- r floats on the wire
    v_loc = b_loc / (Zeta_loc @ t)

Quadratic Sinkhorn would instead need every device to see all n columns of
K (an O(n m / p) all-to-all per iteration). The r-vector psum is the entire
communication cost of the paper's method — this is the collective-term win
quantified in EXPERIMENTS.md §Roofline.

Convergence is checked with a psum'd local L1 error, so the while_loop
carries a replicated scalar and all devices exit together (no divergence of
control flow — a requirement for SPMD).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .sinkhorn import (
    SinkhornResult,
    make_scaling_step,
    masked_dual_value,
    run_marginal_loop,
)

__all__ = ["sharded_sinkhorn_factored", "make_sharded_sinkhorn"]


def _sharded_body(xi, zeta, a, b, *, eps, tol, max_iter, axis):
    """Runs INSIDE shard_map. All arrays are per-device shards.

    Composes the SAME ``make_scaling_step`` block as the single-device
    solver — only the operators (psum'd thin contractions) and the error
    reduction (psum'd local L1) are distribution-aware.
    """
    n_loc = a.shape[0]
    m_loc = b.shape[0]
    dtype = a.dtype

    def rmatvec(u):                              # K^T u, sharded (m/p,)
        t = jax.lax.psum(xi.T @ u, axis)         # (r,) replicated
        return zeta @ t

    def matvec(v):                               # K v, sharded (n/p,)
        t = jax.lax.psum(zeta.T @ v, axis)
        return xi @ t

    step = make_scaling_step(
        matvec, rmatvec, a, b,
        err_reduce=lambda e: jax.lax.psum(jnp.sum(e), axis),
    )
    u0 = jnp.ones((n_loc,), dtype)
    v0 = jnp.ones((m_loc,), dtype)
    it, (u, v, _), err = run_marginal_loop(
        step, (u0, v0, rmatvec(u0)), tol=tol, max_iter=max_iter, dtype=dtype
    )
    f, g = eps * jnp.log(u), eps * jnp.log(v)
    cost = jax.lax.psum(masked_dual_value(a, b, f, g), axis)
    return SinkhornResult(u, v, f, g, cost, it, err, err <= tol)


def make_sharded_sinkhorn(mesh, *, axis: str = "data", eps: float,
                          tol: float = 1e-6, max_iter: int = 2000):
    """Build a shard_map'd solver bound to ``mesh``.

    Inputs are globally-shaped; supports shard over ``axis``; the feature
    dimension r and the result replicate.
    """
    body = partial(_sharded_body, eps=eps, tol=tol, max_iter=max_iter,
                   axis=axis)
    out_specs = SinkhornResult(
        u=P(axis), v=P(axis), f=P(axis), g=P(axis),
        cost=P(), n_iter=P(), marginal_err=P(), converged=P(),
    )
    from ..distributed.sharding import shard_map

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis), P(axis)),
        out_specs=out_specs,
        check_vma=False,
    )


def sharded_sinkhorn_factored(
    mesh, xi, zeta, a, b, *, eps: float, axis: str = "data",
    tol: float = 1e-6, max_iter: int = 2000
) -> SinkhornResult:
    fn = make_sharded_sinkhorn(mesh, axis=axis, eps=eps, tol=tol,
                               max_iter=max_iter)
    return fn(xi, zeta, a, b)
