"""Distributed Sinkhorn via ``shard_map`` — scaling space AND log domain.

The factored kernel is what makes Sinkhorn *distributable*: shard the
SUPPORT of each measure over the ``data`` mesh axis —

    Xi   : (n/p, r) per device        Zeta : (m/p, r) per device
    u,a  : (n/p,)   per device        v,b  : (m/p,)   per device

Each half-iteration is a LOCAL thin contraction followed by ONE tiny
all-reduce of an r-vector:

    t = psum_data( Xi_loc^T u_loc )          # (r,)  <- r floats on the wire
    v_loc = b_loc / (Zeta_loc @ t)

and the log-domain twin is the same traffic: a psum'd logsumexp
(:func:`~repro.distributed.sharding.psum_logsumexp` — ``pmax`` of local
maxima, shifted local sums, ``psum``) produces the replicated r-vector

    t_k = LSE_global_i( logXi[i,k] + f_i/eps )

after which the second LSE stage is purely local. Quadratic Sinkhorn would
instead need every device to see all n columns of K (an O(n m / p)
all-to-all per iteration). The r-vector collective is the entire
communication cost of the paper's method — the term quantified in
EXPERIMENTS.md §Roofline.

Sharding is a first-class execution mode of the Geometry layer:

* :class:`RowShardedGeometry` wraps ANY feature-capable geometry's
  per-device shard. Point-cloud families (Gaussian / arc-cosine) shard
  their raw supports and build local feature rows on device — no global
  feature materialization ever happens.
* :class:`RowShardedFactored` is the explicit-factor special case (kept as
  the stable public name for pre-wrapper callers).
* Both advertise ``spmd_axis``, which makes the UNCHANGED solver core
  (``sinkhorn_geometry`` / ``sinkhorn_log_geometry`` composing
  ``make_scaling_step`` / ``make_log_step`` / ``run_marginal_loop``) psum
  every scalar reduction: the while_loop carries a replicated marginal
  error (all devices exit together — an SPMD requirement) and the dual
  value replicates, which is also what lets ``grad.rot_geometry``'s
  envelope VJP run under ``shard_map`` unchanged.

Uneven supports (``n % p != 0``) are padded up to the next multiple of p
with ZERO-weight atoms whose initial potentials are pinned to ``-inf``
(log) / ``0`` (scaling), so padded atoms contribute exactly nothing to any
psum or LSE from iteration 0 — sharded results match the UNPADDED
single-device solve elementwise, not just at the fixed point.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..distributed.sharding import psum_logsumexp, shard_map
from .api import _pad_rows
from .geometry import (
    ArcCosinePointCloud,
    FactoredPositive,
    GaussianPointCloud,
    Geometry,
    _compute,
    _register,
    _stored,
)
from .grad import rot_geometry
from .sinkhorn import (
    SinkhornResult,
    sinkhorn_geometry,
    sinkhorn_log_geometry,
)

__all__ = [
    "RowShardedFactored",
    "RowShardedGeometry",
    "sharded_sinkhorn_factored",
    "sharded_sinkhorn_geometry",
    "sharded_sinkhorn_divergence",
    "make_sharded_sinkhorn",
]

_lse = jax.scipy.special.logsumexp


# ---------------------------------------------------------------------------
# psum'd factored operators (shared by both sharded geometry classes)
# ---------------------------------------------------------------------------


def _psum_factored_ops(xi, zeta, axis: str) -> Tuple[Callable, Callable]:
    """Scaling-space K v / K^T u on local feature rows: one r-vector psum
    per application — the paper's entire per-iteration traffic.
    ``_compute`` upcasts bf16-stored factor rows at application time so
    the local contraction and the psum'd r-vector stay f32."""

    def apply_k(v):                              # (m/p,) -> (n/p,)
        return _compute(xi) @ jax.lax.psum(_compute(zeta).T @ v, axis)

    def apply_kt(u):                             # (n/p,) -> (m/p,)
        return _compute(zeta) @ jax.lax.psum(_compute(xi).T @ u, axis)

    return apply_k, apply_kt


def _psum_factored_log_ops(lxi, lzt, eps: float,
                           axis: str) -> Tuple[Callable, Callable]:
    """Log-domain operators: the exact two-stage LSE of
    ``geometry._factored_log_apply`` with the FIRST stage distributed.

    Stage 1 reduces over the sharded support axis, so it runs through the
    psum'd logsumexp (pmax + psum of one r-vector — same wire cost as the
    scaling path); stage 2 reduces over the local r axis only. Positivity
    of the factored kernel keeps the split exact, and -inf log-features of
    zero-weight padded atoms drop out of both stages.
    """

    def log_apply_k(g):                          # log(K e^{g/eps}), (n/p,)
        t = psum_logsumexp(_compute(lzt) + (g / eps)[:, None],
                           axis, axis=0)                             # (r,)
        return _lse(_compute(lxi) + t[None, :], axis=1)

    def log_apply_kt(f):                         # log(K^T e^{f/eps}), (m/p,)
        t = psum_logsumexp(_compute(lxi) + (f / eps)[:, None], axis, axis=0)
        return _lse(_compute(lzt) + t[None, :], axis=1)

    return log_apply_k, log_apply_kt


# ---------------------------------------------------------------------------
# Sharded geometries (used INSIDE shard_map)
# ---------------------------------------------------------------------------


class _PsumOpsMixin:
    """The entire psum'd operator surface, derived from the host class's
    LOCAL ``features()``/``log_features()`` plus its ``axis``/``eps`` —
    one implementation shared by both sharded geometry classes so the
    collective wiring cannot drift between them."""

    @property
    def spmd_axis(self) -> Optional[str]:
        return self.axis

    def operators(self, *, precision: str = "highest"):
        # the mixed-precision policy composes with sharding for free: the
        # LOCAL factor rows store bf16, the psum'd r-vector stays f32
        xi, zeta = (_stored(w, precision) for w in self.features())
        return _psum_factored_ops(xi, zeta, self.axis)

    def log_operators(self, *, precision: str = "highest"):
        lxi, lzt = (_stored(w, precision) for w in self.log_features())
        return _psum_factored_log_ops(lxi, lzt, self.eps, self.axis)

    def apply_k(self, v):
        return self.operators()[0](v)

    def apply_kt(self, u):
        return self.operators()[1](u)

    def log_apply_k(self, g):
        return self.log_operators()[0](g)

    def log_apply_kt(self, f):
        return self.log_operators()[1](f)

    def pallas_ops(self):
        # a fused local plan has no psum in its iteration — every other
        # device's feature rows would be silently dropped. No fused path.
        return None


@dataclasses.dataclass(frozen=True, eq=False)
class RowShardedFactored(_PsumOpsMixin, FactoredPositive):
    """Per-device shard of a factored geometry, used INSIDE ``shard_map``.

    ``xi``/``zeta`` (or ``log_xi``/``log_zeta``) hold the local
    (n/p, r)/(m/p, r) feature rows; the operators produce locally-sharded
    outputs after reducing the shared r-vector over ``axis`` — the only
    cross-device traffic per iteration (a plain psum in scaling space, the
    psum'd logsumexp in log space).
    """

    axis: str = dataclasses.field(default="data",
                                  metadata=dict(static=True))

    def xx(self) -> "RowShardedFactored":
        lxi, _ = self.log_features()
        return RowShardedFactored(log_xi=lxi, log_zeta=lxi, eps=self.eps,
                                  axis=self.axis)

    def yy(self) -> "RowShardedFactored":
        _, lzt = self.log_features()
        return RowShardedFactored(log_xi=lzt, log_zeta=lzt, eps=self.eps,
                                  axis=self.axis)


@dataclasses.dataclass(frozen=True, eq=False)
class RowShardedGeometry(_PsumOpsMixin, Geometry):
    """Per-device shard of ANY feature-capable geometry, INSIDE shard_map.

    ``base`` carries the LOCAL rows of the wrapped family: point-cloud
    geometries (Gaussian, arc-cosine) hold their local support rows (x
    over n, y over m; anchors replicated) and derive local feature rows on
    device, so no global feature matrix is ever materialized; explicit
    factored geometries hold local factor rows. The operators are the
    psum'd thin contraction (scaling) / psum'd two-stage LSE (log), and
    ``spmd_axis`` tells the solver core to psum its scalar reductions.
    """

    base: Geometry
    axis: str = dataclasses.field(default="data",
                                  metadata=dict(static=True))

    @property
    def eps(self) -> float:
        return self.base.eps

    @property
    def shape(self) -> Tuple[int, int]:
        return self.base.shape              # LOCAL (n/p, m/p) shard shape

    @property
    def supports_log(self) -> bool:         # mirrors the wrapped family
        return self.base.supports_log

    @property
    def supports_features(self) -> bool:
        return self.base.supports_features

    def features(self):
        return self.base.features()         # local rows

    def log_features(self):
        return self.base.log_features()

    def cost_matrix(self):
        raise ValueError(
            "RowShardedGeometry has no dense cost view: each device holds "
            "only its local support rows; densify the wrapped geometry "
            "outside shard_map instead"
        )

    def xx(self) -> "RowShardedGeometry":
        return RowShardedGeometry(base=self.base.xx(), axis=self.axis)

    def yy(self) -> "RowShardedGeometry":
        return RowShardedGeometry(base=self.base.yy(), axis=self.axis)


for _cls in (RowShardedFactored, RowShardedGeometry):
    _register(_cls)


# ---------------------------------------------------------------------------
# Host-side plumbing: which fields shard, padding, spec construction
# ---------------------------------------------------------------------------

# Geometry family -> fields whose rows shard over the mesh axis. Every
# other array field (shared anchors, ...) replicates. First-measure fields
# have n rows; second-measure fields m rows.
_ROW_SHARDED_FIELDS = {
    FactoredPositive: ("xi", "zeta", "log_xi", "log_zeta"),
    GaussianPointCloud: ("x", "y"),
    ArcCosinePointCloud: ("x", "y"),
}
_N_FIELDS = ("xi", "log_xi", "x")


def _row_sharded_fields(geom: Geometry) -> Optional[Tuple[str, ...]]:
    for cls in type(geom).__mro__:
        if cls in _ROW_SHARDED_FIELDS:
            return _ROW_SHARDED_FIELDS[cls]
    return None


def _array_fields(geom: Geometry):
    """(name, value) for every non-static, non-None dataclass field — the
    geometry's pytree leaves, in field order."""
    out = []
    for fld in dataclasses.fields(geom):
        if fld.metadata.get("static"):
            continue
        val = getattr(geom, fld.name)
        if val is not None:
            out.append((fld.name, val))
    return out


def _static_kwargs(geom: Geometry) -> dict:
    return {fld.name: getattr(geom, fld.name)
            for fld in dataclasses.fields(geom)
            if fld.metadata.get("static")}


def _auto_mode(geom: Geometry) -> str:
    """Scaling vs log exactly like the local auto table
    (``api._auto_method``): explicit linear-space factors run the scaling
    iteration; every other family — point clouds, log-features — runs the
    small-eps-safe log domain."""
    if isinstance(geom, FactoredPositive) and geom.xi is not None:
        return "scaling"
    return "log"


def _prepare(mesh, geom: Geometry, axis: str):
    """Validate + coerce the geometry into a shardable family.

    Families with a row-sharding rule pass through (point clouds never
    materialize global features); other feature-capable families fall back
    to one global factor materialization.
    """
    if axis not in mesh.axis_names:
        raise ValueError(
            f"mesh has axes {mesh.axis_names}, no axis named {axis!r}"
        )
    if isinstance(geom, RowShardedGeometry):
        geom = geom.base
    if _row_sharded_fields(geom) is None:
        if not geom.supports_features:
            raise ValueError(
                "sharded solve needs a geometry with per-row feature "
                f"structure; {type(geom).__name__} has none (no positive "
                "factors to shard)"
            )
        xi, zeta = geom.features()
        geom = FactoredPositive(xi=xi, zeta=zeta, eps=geom.eps)
    return geom


def _shard_geometry_args(geom: Geometry, axis: str, p: int):
    """Pad the row-sharded fields to multiples of p and build the flat
    (arrays, in_specs, rebuild) triple the shard_map wrapper consumes.

    ``rebuild(*arrays)`` reconstructs the per-device geometry inside the
    body from the local array shards plus the (closed-over) static fields.
    """
    n, m = geom.shape
    n_pad = -(-n // p) * p
    m_pad = -(-m // p) * p
    row_fields = set(_row_sharded_fields(geom))
    names, arrays, specs = [], [], []
    for name, val in _array_fields(geom):
        if name in row_fields:
            target = n_pad if name in _N_FIELDS else m_pad
            val = _pad_rows(val, target, replicate=True)
            specs.append(P(axis, *([None] * (val.ndim - 1))))
        else:
            specs.append(P())                   # replicated (anchors, ...)
        names.append(name)
        arrays.append(val)
    cls = type(geom)
    statics = _static_kwargs(geom)

    def rebuild(*arrs) -> Geometry:
        return cls(**dict(zip(names, arrs)), **statics)

    return arrays, tuple(specs), rebuild, (n, m, n_pad, m_pad)


def _result_specs(axis: str) -> SinkhornResult:
    """Supports and potentials shard over ``axis``; the scalars (psum'd
    cost/error, loop counter) replicate."""
    return SinkhornResult(
        u=P(axis), v=P(axis), f=P(axis), g=P(axis),
        cost=P(), n_iter=P(), marginal_err=P(), converged=P(),
    )


# ---------------------------------------------------------------------------
# The SPMD bodies (run per device inside shard_map)
# ---------------------------------------------------------------------------


def _sharded_body(geom_local: Geometry, a, b, w1, w2, *, axis, mode,
                  tol, max_iter, momentum, check_every=1,
                  precision="highest") -> SinkhornResult:
    """Runs INSIDE shard_map. All arrays are per-device shards.

    Composes the SAME solver entry points as the single-device path —
    ``sinkhorn_geometry`` / ``sinkhorn_log_geometry`` with their
    ``make_scaling_step`` / ``make_log_step`` / ``run_marginal_loop``
    building blocks unchanged. The only distribution-aware pieces are the
    geometry's psum'd operators and the psum'd scalar reductions selected
    through ``geom.spmd_axis`` — masking, warm starts and momentum are
    byte-for-byte the single-device semantics.
    """
    if geom_local.spmd_axis is None:
        geom_local = RowShardedGeometry(base=geom_local, axis=axis)
    if mode == "log":
        return sinkhorn_log_geometry(
            geom_local, a, b, tol=tol, max_iter=max_iter, momentum=momentum,
            f_init=w1, g_init=w2, use_pallas=False,
            check_every=check_every, precision=precision,
        )
    return sinkhorn_geometry(
        geom_local, a, b, tol=tol, max_iter=max_iter, momentum=momentum,
        u_init=w1, use_pallas=False, check_every=check_every,
        precision=precision,
    )


def _divergence_body(geom_local: Geometry, a, b, *, axis, tol,
                     max_iter) -> jax.Array:
    """Sinkhorn divergence (Eq. 2) per device: three psum'd envelope
    solves through the UNCHANGED ``rot_geometry`` custom VJP — the psum'd
    dual value is already replicated, so the scalar (and its gradients,
    via psum's transpose) come out correct without divergence-specific
    distribution code."""
    g = RowShardedGeometry(base=geom_local, axis=axis)
    w_xy = rot_geometry(g, a, b, tol, max_iter)
    w_xx = rot_geometry(g.xx(), a, a, tol, max_iter)
    w_yy = rot_geometry(g.yy(), b, b, tol, max_iter)
    return w_xy - 0.5 * (w_xx + w_yy)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------


def sharded_sinkhorn_geometry(
    mesh, geom: Geometry, a, b, *, axis: str = "data", mode: str = "auto",
    tol: float = 1e-6, max_iter: int = 2000, momentum: float = 1.0,
    f_init: Optional[jax.Array] = None, g_init: Optional[jax.Array] = None,
    inner_steps: Optional[int] = None, check_every: Optional[int] = None,
    precision: str = "highest",
) -> SinkhornResult:
    """Shard-map solve of any feature-capable Geometry on ``mesh``.

    Inputs are globally shaped; supports shard over ``axis`` (padded to a
    multiple of the axis size with inert zero-weight atoms when
    ``n % p != 0``); the feature dimension r and the scalar results
    replicate. ``mode`` picks the iteration space: ``"scaling"`` (plain
    psum'd contractions), ``"log"`` (psum'd-LSE operators, mandatory at
    small eps where scalings over/underflow), or ``"auto"`` (the local
    auto table's choice: scaling for explicit linear factors, log for
    everything else). ``f_init``/``g_init`` warm-start the potentials
    (eps-annealing across sharded stages) and ``momentum`` applies the
    usual over-relaxation — semantics identical to the single-device
    solvers, whose step builders run unchanged inside the SPMD body.
    """
    if mode not in ("auto", "scaling", "log"):
        raise ValueError(
            f"mode must be 'auto' | 'scaling' | 'log', got {mode!r}"
        )
    if inner_steps is not None and int(inner_steps) > 1:
        raise ValueError(
            "inner_steps > 1 (the persistent megakernel) is not available "
            "on sharded solves: the fused block iterates on LOCAL feature "
            "rows only and would silently drop the per-iteration psum. "
            "Use check_every= for the fewer-syncs cadence win, or solve on "
            "one device for the megakernel."
        )
    check_every = 1 if check_every is None else int(check_every)
    geom = _prepare(mesh, geom, axis)
    if mode == "auto":
        mode = _auto_mode(geom)
    if mode == "log" and not geom.supports_log:
        raise ValueError(
            f"{type(geom).__name__} has no log-domain operators; use "
            "mode='scaling'"
        )
    p = mesh.shape[axis]
    arrays, geom_specs, rebuild, (n, m, n_pad, m_pad) = \
        _shard_geometry_args(geom, axis, p)
    dtype = a.dtype
    eps = geom.eps

    a_p = _pad_rows(a, n_pad, replicate=False)
    b_p = _pad_rows(b, m_pad, replicate=False)
    if mode == "log":
        # padded atoms start at -inf (and a = 0 forces the same through
        # the solver's masked _log_init) so they contribute exp(-inf) = 0
        # to every LSE from iteration 0 — sharded iterates match the
        # UNPADDED single-device solve elementwise, not just at the fixed
        # point
        w1 = jnp.zeros((n,), dtype) if f_init is None else f_init
        w2 = jnp.zeros((m,), dtype) if g_init is None else g_init
        w1 = _pad_rows(w1, n_pad, replicate=False, fill=-jnp.inf)
        w2 = _pad_rows(w2, m_pad, replicate=False, fill=-jnp.inf)
    else:
        # scaling space warm-starts u only (g_init is unused, exactly like
        # the single-device scaling runner): the first half-step rebuilds
        # v = b / K^T u from scratch. Zero scalings keep padded atoms inert.
        u0 = jnp.ones((n,), dtype) if f_init is None \
            else jnp.exp(f_init / eps)
        w1 = _pad_rows(u0, n_pad, replicate=False)
        w2 = _pad_rows(jnp.ones((m,), dtype), m_pad, replicate=False)

    def body(*args):
        geom_local = rebuild(*args[:len(arrays)])
        la, lb, lw1, lw2 = args[len(arrays):]
        return _sharded_body(
            geom_local, la, lb, lw1, lw2, axis=axis, mode=mode, tol=tol,
            max_iter=max_iter, momentum=momentum, check_every=check_every,
            precision=precision,
        )

    fn = shard_map(
        body, mesh=mesh,
        in_specs=geom_specs + (P(axis), P(axis), P(axis), P(axis)),
        out_specs=_result_specs(axis),
        check_vma=False,
    )
    res = fn(*arrays, a_p, b_p, w1, w2)
    if n_pad == n and m_pad == m:
        return res
    return res._replace(u=res.u[:n], v=res.v[:m],
                        f=res.f[:n], g=res.g[:m])


def sharded_sinkhorn_divergence(
    mesh, geom: Geometry, a: Optional[jax.Array] = None,
    b: Optional[jax.Array] = None, *, axis: str = "data",
    tol: float = 1e-6, max_iter: int = 2000,
) -> jax.Array:
    """Sharded Sinkhorn divergence: three psum'd log-domain envelope
    solves inside ONE shard_map. Differentiable in the geometry's arrays
    (supports, features, shared anchors) through ``rot_geometry``'s
    envelope VJP, which runs under shard_map unchanged — the psum'd dual
    value is replicated and psum's transpose routes every shard's
    contribution into the leaf cotangents."""
    geom = _prepare(mesh, geom, axis)
    if not geom.supports_log:
        raise ValueError(
            f"{type(geom).__name__} has no log-domain operators; the "
            "sharded divergence runs in log space"
        )
    n, m = geom.shape
    a = jnp.full((n,), 1.0 / n) if a is None else a
    b = jnp.full((m,), 1.0 / m) if b is None else b
    p = mesh.shape[axis]
    arrays, geom_specs, rebuild, (n, m, n_pad, m_pad) = \
        _shard_geometry_args(geom, axis, p)
    a_p = _pad_rows(a, n_pad, replicate=False)
    b_p = _pad_rows(b, m_pad, replicate=False)

    def body(*args):
        geom_local = rebuild(*args[:len(arrays)])
        la, lb = args[len(arrays):]
        return _divergence_body(geom_local, la, lb, axis=axis, tol=tol,
                                max_iter=max_iter)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=geom_specs + (P(axis), P(axis)),
        out_specs=P(),
        check_vma=False,
    )
    return fn(*arrays, a_p, b_p)


def sharded_sinkhorn_factored(
    mesh, xi, zeta, a, b, *, eps: float, axis: str = "data",
    mode: str = "scaling", tol: float = 1e-6, max_iter: int = 2000,
    momentum: float = 1.0, f_init: Optional[jax.Array] = None,
    g_init: Optional[jax.Array] = None,
) -> SinkhornResult:
    """Sharded solve on explicit positive factors K = xi @ zeta.T."""
    return sharded_sinkhorn_geometry(
        mesh, FactoredPositive(xi=xi, zeta=zeta, eps=eps), a, b,
        axis=axis, mode=mode, tol=tol, max_iter=max_iter, momentum=momentum,
        f_init=f_init, g_init=g_init,
    )


def make_sharded_sinkhorn(mesh, *, axis: str = "data", eps: float,
                          mode: str = "scaling", tol: float = 1e-6,
                          max_iter: int = 2000):
    """Build a solver ``fn(xi, zeta, a, b)`` bound to ``mesh``.

    Inputs are globally-shaped; supports shard over ``axis``; the feature
    dimension r and the result replicate.
    """

    def fn(xi, zeta, a, b) -> SinkhornResult:
        return sharded_sinkhorn_factored(
            mesh, xi, zeta, a, b, eps=eps, axis=axis, mode=mode, tol=tol,
            max_iter=max_iter,
        )

    return fn
