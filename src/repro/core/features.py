"""Positive feature maps (the paper's central object).

A *positive feature map* phi : X -> (R*_+)^r defines a kernel
``k(x, y) = <phi(x), phi(y)> > 0`` and therefore a cost
``c(x, y) = -eps * log k(x, y)`` whose Gibbs kernel factorizes EXACTLY:

    K = exp(-C / eps) = Xi @ Zeta.T,   Xi = phi(X) in R_+^{n x r}.

Every Sinkhorn matvec then costs O(r (n + m)) instead of O(n m), and —
because all entries are strictly positive — Sinkhorn converges for ANY r,
unlike signed low-rank approximations (Nystrom).

This module implements:
  * Lemma 1  — positive random features for the Gaussian kernel
               exp(-||x-y||^2 / eps)  (unbiased, ratio-bounded).
  * Lemma 3  — perturbed arc-cosine features k_s(x,y) + kappa.
  * learned  — Lemma-1 features with *learnable anchors* (the paper's GAN
               construction: phi_theta with theta the anchor locations).

All maps are computed in log-space first (numerically safe for small eps)
and exponentiated at the end; log-features feed the log-domain solver
directly.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

__all__ = [
    "lambert_w0",
    "gaussian_q",
    "GaussianFeatureMap",
    "ArcCosineFeatureMap",
    "init_gaussian_features",
    "gaussian_log_features",
    "gaussian_features",
    "arccos_features",
]


def lambert_w0(z: float, iters: int = 64) -> float:
    """Principal branch W0 of the Lambert function for z >= 0.

    Solves w * exp(w) = z with Halley's method. Config-time scalar math
    (numpy, not traced) — used to pick the variance q of Lemma 1.
    """
    if z < 0:
        raise ValueError("lambert_w0 defined here for z >= 0 only")
    if z == 0.0:
        return 0.0
    # Classic initial guess: log-based for large z, series for small.
    w = math.log1p(z) if z < math.e else math.log(z) - math.log(math.log(z))
    for _ in range(iters):
        ew = math.exp(w)
        f = w * ew - z
        # Halley step.
        denom = ew * (w + 1.0) - (w + 2.0) * f / (2.0 * w + 2.0)
        w_next = w - f / denom
        if abs(w_next - w) < 1e-15 * (1.0 + abs(w_next)):
            w = w_next
            break
        w = w_next
    return w


def gaussian_q(R: float, eps: float, d: int) -> float:
    """The paper's q = (R^2/eps) / (2 d W0(R^2 / (eps d))) (Lemma 1).

    q balances the variance of the anchor distribution rho = N(0, q*eps/4 I)
    against the amplitude bound psi = 2 (2q)^{d/2} of Assumption 1.
    """
    z = (R * R / eps) / d
    if z == 0.0:
        return 0.5  # limit: W0(z) ~ z, q -> 1/(2) * (z/(W0 z)) -> 1/2
    return z / (2.0 * lambert_w0(z))


# ---------------------------------------------------------------------------
# Lemma 1: Gaussian kernel exp(-||x - y||^2 / eps)
# ---------------------------------------------------------------------------
#
#   phi(x, u) = (2q)^{d/4} exp(-2 eps^-1 ||x - u||^2) exp(eps^-1 ||u||^2 / q)
#   u ~ rho = N(0, (q * eps / 4) I_d)
#   E_rho[phi(x,u) phi(y,u)] = exp(-||x-y||^2/eps)          (exact, unbiased)
#
# The per-anchor constant  c_k = (d/4) log(2q) + eps^-1 ||u_k||^2 / q  folds
# into a single additive log-offset, so
#
#   log phi(x, u_k) = c_k - 2 eps^-1 ||x - u_k||^2
#
# and the Monte-Carlo feature matrix (including the 1/sqrt(r) weight) is
#
#   log Xi[i, k] = c_k - (1/2) log r - 2 eps^-1 ||x_i - u_k||^2 .
#
# ||x - u||^2 expands to ||x||^2 + ||u||^2 - 2 x.u  — one MXU matmul plus
# rank-1 terms; this is what the Pallas kernel fuses with the exp.


@dataclasses.dataclass(frozen=True)
class GaussianFeatureMap:
    """Static config for Lemma-1 features."""

    r: int                 # number of random anchors
    d: int                 # ambient dimension
    eps: float             # entropic regularization (the kernel temperature)
    R: float               # data radius bound: x in B(0, R)

    @property
    def q(self) -> float:
        return gaussian_q(self.R, self.eps, self.d)

    @property
    def sigma2(self) -> float:
        # anchor distribution variance: q * eps / 4
        return self.q * self.eps / 4.0

    @property
    def psi(self) -> float:
        # Assumption-1 amplitude bound: 2 (2q)^{d/2}
        return 2.0 * (2.0 * self.q) ** (self.d / 2.0)

    def init(self, key: jax.Array) -> jax.Array:
        """Sample anchors U ~ N(0, sigma2 I), shape (r, d)."""
        return jnp.sqrt(self.sigma2) * jax.random.normal(
            key, (self.r, self.d), dtype=jnp.float32
        )


def init_gaussian_features(key: jax.Array, fmap: GaussianFeatureMap) -> jax.Array:
    return fmap.init(key)


def _anchor_log_const(anchors: jax.Array, q: float, eps: float) -> jax.Array:
    """c_k = (d/4) log(2q) + eps^-1 ||u_k||^2 / q, shape (r,)."""
    d = anchors.shape[-1]
    u2 = jnp.sum(anchors * anchors, axis=-1)
    return 0.25 * d * jnp.log(2.0 * q) + u2 / (q * eps)


def gaussian_log_features(
    x: jax.Array,
    anchors: jax.Array,
    *,
    eps: float,
    q: float,
    include_sqrt_r: bool = True,
) -> jax.Array:
    """log Xi, shape (n, r): log of the Lemma-1 Monte-Carlo feature matrix.

    x: (n, d) points; anchors: (r, d). Differentiable in both (the GAN path
    learns the anchors). Computed via the matmul expansion of ||x - u||^2 so
    the inner contraction hits the MXU on TPU.
    """
    x = jnp.asarray(x)
    anchors = jnp.asarray(anchors)
    r = anchors.shape[0]
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)            # (n, 1)
    u2 = jnp.sum(anchors * anchors, axis=-1)[None, :]       # (1, r)
    xu = x @ anchors.T                                      # (n, r)  MXU
    sqdist = x2 + u2 - 2.0 * xu
    logphi = _anchor_log_const(anchors, q, eps)[None, :] - 2.0 / eps * sqdist
    if include_sqrt_r:
        logphi = logphi - 0.5 * jnp.log(jnp.asarray(r, dtype=logphi.dtype))
    return logphi


def gaussian_features(
    x: jax.Array, anchors: jax.Array, *, eps: float, q: float
) -> jax.Array:
    """Xi = exp(log Xi): strictly positive feature matrix, shape (n, r)."""
    return jnp.exp(gaussian_log_features(x, anchors, eps=eps, q=q))


# ---------------------------------------------------------------------------
# Lemma 3: perturbed arc-cosine kernel k_s(x, y) + kappa
# ---------------------------------------------------------------------------
#
#   phi_ac(x, u) = sigma^{d/2} sqrt(2) max(0, u.x)^s exp(-||u||^2/4 (1 - 1/sigma^2))
#   u ~ N(0, sigma^2 I),  plus one constant coordinate sqrt(kappa).
#
# Output dim r + 1 (the kappa coordinate is shared). kappa > 0 guarantees
# k >= kappa > 0 even though individual relu features may be zero.


@dataclasses.dataclass(frozen=True)
class ArcCosineFeatureMap:
    r: int
    d: int
    s: int = 1              # rectification order (0: step, 1: relu, 2: sq-relu)
    sigma: float = 1.5      # importance-sampling widening (> 1)
    kappa: float = 1e-3     # positivity floor

    def init(self, key: jax.Array) -> jax.Array:
        return self.sigma * jax.random.normal(key, (self.r, self.d), jnp.float32)


def arccos_features(
    x: jax.Array, anchors: jax.Array, *, s: int, sigma: float, kappa: float
) -> jax.Array:
    """Arc-cosine positive features, shape (n, r + 1).

    k_theta(x, y) = (1/r) sum_k ac_k(x) ac_k(y) + kappa  ->  k_s(x, y) + kappa.
    """
    n = x.shape[0]
    r, d = anchors.shape
    proj = x @ anchors.T                                    # (n, r)
    rect = jnp.maximum(proj, 0.0) ** s if s > 0 else (proj > 0).astype(x.dtype)
    u2 = jnp.sum(anchors * anchors, axis=-1)[None, :]
    damp = jnp.exp(-0.25 * u2 * (1.0 - 1.0 / (sigma * sigma)))
    amp = sigma ** (d / 2.0) * jnp.sqrt(2.0)
    feats = amp * rect * damp / jnp.sqrt(float(r))
    const = jnp.full((n, 1), jnp.sqrt(kappa), dtype=feats.dtype)
    return jnp.concatenate([feats, const], axis=-1)
