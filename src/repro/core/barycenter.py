"""Wasserstein barycenters through kernel geometries (paper Fig. 6 / App C).

Iterative Bregman projections [Benamou et al. '15] where every kernel
application routes through a symmetric :class:`~repro.core.geometry.Geometry`
on the COMMON support — O(r n) for factored kernels, O(n^{1+1/d}) axis-wise
convolutions for :class:`~repro.core.geometry.GridSeparable` (image
barycenters). The paper's positive-sphere demonstration uses the ultimate
special case phi(x) = x (linear kernel, r = d); the general entry point
accepts any log-capable geometry — including Lemma-1 Gaussian features — so
barycenters inherit the paper's linear-time scaling. Log-domain throughout
(stable at small eps).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .geometry import FactoredPositive, Geometry

__all__ = [
    "BarycenterResult",
    "barycenter_geometry",
    "barycenter_log_factored",
]


def _lse(x, axis):
    return jax.scipy.special.logsumexp(x, axis=axis)


class BarycenterResult(NamedTuple):
    weights: jax.Array       # (n,) the barycenter histogram
    n_iter: jax.Array
    err: jax.Array           # L1 change of the barycenter per iteration
    converged: jax.Array


def barycenter_geometry(
    geom: Geometry,          # symmetric geometry on the COMMON support
    hists: jax.Array,        # (k, n) input histograms on that support
    *,
    weights: Optional[jax.Array] = None,   # (k,) barycentric weights
    tol: float = 1e-7,
    max_iter: int = 500,
) -> BarycenterResult:
    """Bregman-projection barycenter with geometry-supplied log-operators.

    ``geom`` must be symmetric (n == m) and log-capable; each projection
    applies K once per input histogram through ``geom.log_apply_k``.
    """
    n_g, m_g = geom.shape
    if n_g != m_g:
        raise ValueError(
            f"barycenter needs a symmetric geometry on one common support; "
            f"got shape {(n_g, m_g)}"
        )
    k, n = hists.shape
    if n != n_g:
        raise ValueError(
            f"histograms live on {n} atoms but the geometry has {n_g}"
        )
    eps = geom.eps
    lam = jnp.full((k,), 1.0 / k) if weights is None else weights
    log_hists = jnp.log(jnp.maximum(hists, 1e-38))

    # log(K e^{s}) for the k stacked log-scalings; the geometry operator
    # expects potentials (divided by eps internally), so feed eps * s.
    # Hoisted log_operators: any feature materialization happens once,
    # outside the Bregman while_loop.
    log_matvec = geom.log_operators()[0]
    log_K = jax.vmap(lambda s: log_matvec(eps * s))

    def body(state):
        it, lf, lg, _, logb_prev = state
        # project onto column constraints: g-update toward each a_i
        lKf = log_K(lf)                                 # (k, n)
        lg = log_hists - lKf
        # barycenter = weighted geometric mean of the row marginals
        lKg = log_K(lg)
        logb = jnp.sum(lam[:, None] * (lKg + lf), axis=0)
        logb = logb - _lse(logb, axis=0)                # normalize
        lf = logb[None, :] - lKg
        err = jnp.sum(jnp.abs(jnp.exp(logb) - jnp.exp(logb_prev)))
        return it + 1, lf, lg, err, logb

    def cond(state):
        it, _, _, err, _ = state
        return (it < max_iter) & (err > tol) & jnp.isfinite(err)

    lf0 = jnp.zeros((k, n))
    lg0 = jnp.zeros((k, n))
    logb0 = jnp.full((n,), -jnp.log(n))
    state = body((jnp.array(0, jnp.int32), lf0, lg0, jnp.inf, logb0))
    it, lf, lg, err, logb = jax.lax.while_loop(cond, body, state)
    return BarycenterResult(jnp.exp(logb), it, err, err <= tol)


def barycenter_log_factored(
    log_xi: jax.Array,       # (n, r) log-features of the COMMON support
    hists: jax.Array,        # (k, n) input histograms on that support
    *,
    eps: float,
    weights: Optional[jax.Array] = None,   # (k,) barycentric weights
    tol: float = 1e-7,
    max_iter: int = 500,
) -> BarycenterResult:
    """Factored-kernel barycenter: K = Xi Xi^T from one log-feature matrix
    (thin wrapper over :func:`barycenter_geometry`)."""
    geom = FactoredPositive(log_xi=log_xi, log_zeta=log_xi, eps=eps)
    return barycenter_geometry(
        geom, hists, weights=weights, tol=tol, max_iter=max_iter
    )
