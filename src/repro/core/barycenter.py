"""Wasserstein barycenters through factored kernels (paper Fig. 6 / App C).

Iterative Bregman projections [Benamou et al. '15] where every kernel
application is O(r n) via K = Xi Xi^T. The paper's positive-sphere
demonstration uses the ultimate special case phi(x) = x (linear kernel,
r = d); the general entry point accepts any positive feature matrix —
including Lemma-1 Gaussian features — so barycenters inherit the paper's
linear-time scaling. Log-domain throughout (stable at small eps).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["BarycenterResult", "barycenter_log_factored"]


def _lse(x, axis):
    return jax.scipy.special.logsumexp(x, axis=axis)


class BarycenterResult(NamedTuple):
    weights: jax.Array       # (n,) the barycenter histogram
    n_iter: jax.Array
    err: jax.Array           # L1 change of the barycenter per iteration
    converged: jax.Array


def barycenter_log_factored(
    log_xi: jax.Array,       # (n, r) log-features of the COMMON support
    hists: jax.Array,        # (k, n) input histograms on that support
    *,
    eps: float,
    weights: Optional[jax.Array] = None,   # (k,) barycentric weights
    tol: float = 1e-7,
    max_iter: int = 500,
) -> BarycenterResult:
    k, n = hists.shape
    lam = jnp.full((k,), 1.0 / k) if weights is None else weights
    log_hists = jnp.log(jnp.maximum(hists, 1e-38))

    def log_K(s):            # log(K e^{s}) with K = Xi Xi^T, per problem
        t = _lse(log_xi[None, :, :] + s[:, :, None], axis=1)   # (k, r)
        return _lse(log_xi[None, :, :] + t[:, None, :], axis=2)

    def body(state):
        it, lf, lg, _, logb_prev = state
        # project onto column constraints: g-update toward each a_i
        lKf = log_K(lf)                                 # (k, n)
        lg = log_hists - lKf
        # barycenter = weighted geometric mean of the row marginals
        lKg = log_K(lg)
        logb = jnp.sum(lam[:, None] * (lKg + lf), axis=0)
        logb = logb - _lse(logb, axis=0)                # normalize
        lf = logb[None, :] - lKg
        err = jnp.sum(jnp.abs(jnp.exp(logb) - jnp.exp(logb_prev)))
        return it + 1, lf, lg, err, logb

    def cond(state):
        it, _, _, err, _ = state
        return (it < max_iter) & (err > tol) & jnp.isfinite(err)

    lf0 = jnp.zeros((k, n))
    lg0 = jnp.zeros((k, n))
    logb0 = jnp.full((n,), -jnp.log(n))
    state = body((jnp.array(0, jnp.int32), lf0, lg0, jnp.inf, logb0))
    it, lf, lg, err, logb = jax.lax.while_loop(cond, body, state)
    return BarycenterResult(jnp.exp(logb), it, err, err <= tol)
