"""Paged factored geometry: the streaming layer's view of a mutable support.

:class:`PagedFactored` is a :class:`~repro.core.geometry.FactoredPositive`
twin whose factor buffers are fixed-capacity PAGED stores
(``repro.streaming.PagedFeatureStore``): the arrays are always
``(capacity, r)``, mutation writes pages and flips weights — shapes never
change, so one jitted solver serves every update. Dead slots carry
arbitrary (but strictly positive, in linear space) stale feature values;
correctness comes from the zero-weight masking every solver already does,
NOT from the page table. The per-page live counts (``page_live_x`` /
``page_live_y``) ride as traced int32 vectors so occupancy changes never
retrace; they feed the ``pallas_ops`` spec that lets the paged kernels
(``kernels.paged``) skip all-dead pages.

The XLA operators are inherited unchanged from ``_FeatureKernelOps`` —
masked, exact, page-agnostic — which is also the fallback on backends
without the paged fast path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .geometry import (
    Geometry,
    _FeatureKernelOps,
    _masked_log,
    _register,
)

__all__ = ["PagedFactored"]


@_register
@dataclasses.dataclass(frozen=True, eq=False)
class PagedFactored(_FeatureKernelOps, Geometry):
    """K = Xi Zeta^T on fixed-capacity paged factor buffers.

    ``xi``/``zeta`` (or ``log_xi``/``log_zeta``) are full-capacity
    ``(C, r)`` buffers; ``page_live_*`` are ``(C // page_size,)`` int32
    live-slot counts per page. The kernel is pinned to the eps the
    features were drawn at (like :class:`FactoredPositive`): streaming
    updates mutate supports, not the regularization.
    """

    xi: Optional[jax.Array] = None
    zeta: Optional[jax.Array] = None
    log_xi: Optional[jax.Array] = None
    log_zeta: Optional[jax.Array] = None
    page_live_x: jax.Array = None
    page_live_y: jax.Array = None
    page_size: int = dataclasses.field(default=64,
                                       metadata=dict(static=True))
    eps: float = dataclasses.field(kw_only=True,
                                   metadata=dict(static=True))

    anneal_capable = False
    supports_log = True
    supports_features = True

    def __post_init__(self):
        have_lin = self.xi is not None and self.zeta is not None
        have_log = self.log_xi is not None and self.log_zeta is not None
        if have_lin == have_log:
            raise ValueError(
                "PagedFactored needs exactly one factor pair: "
                "(xi, zeta) or (log_xi, log_zeta)"
            )
        if self.page_live_x is None or self.page_live_y is None:
            raise ValueError(
                "PagedFactored needs page_live_x and page_live_y "
                "(per-page int32 live-slot counts)"
            )

    @property
    def shape(self) -> Tuple[int, int]:
        if self.xi is not None:
            return self.xi.shape[0], self.zeta.shape[0]
        return self.log_xi.shape[0], self.log_zeta.shape[0]

    @property
    def rank(self) -> int:
        return (self.xi if self.xi is not None else self.log_xi).shape[1]

    def features(self):
        if self.xi is not None:
            return self.xi, self.zeta
        return jnp.exp(self.log_xi), jnp.exp(self.log_zeta)

    def log_features(self):
        if self.log_xi is not None:
            return self.log_xi, self.log_zeta
        return _masked_log(self.xi), _masked_log(self.zeta)

    def cost_matrix(self):
        return -self.eps * self.log_dense_kernel()

    def pallas_ops(self):
        # "paged" spec: scaling mode routes through the page-skipping
        # kernels (kernels.paged); log mode runs the standard log plan on
        # the flat factors (dead slots are -inf-pinned potentials — inert
        # in every LSE, no page predicate needed for correctness).
        spec = {
            "kind": "paged",
            "page_live_x": self.page_live_x,
            "page_live_y": self.page_live_y,
            "page_size": self.page_size,
            "eps": self.eps,
        }
        if self.xi is not None:
            spec.update(xi=self.xi, zeta=self.zeta)
        else:
            spec.update(log_xi=self.log_xi, log_zeta=self.log_zeta)
        return spec
