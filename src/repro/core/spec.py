"""SolveSpec: the one record that names a solve.

``solve()`` grew a kwarg pile (method, schedule, tol, max_iter, momentum,
mesh, mesh_axis, rank, key, use_pallas, inner_steps, check_every,
precision), :class:`~repro.core.objective.OTObjective` carried its own
copy of the same knobs for training, and the serving layer configured a
third copy on :class:`~repro.serving.service.OTService`. A
:class:`SolveSpec` collapses all three surfaces into one frozen record:

    WHAT   — ``geometry`` (+ optional ``a``/``b`` weights)
    TARGET — ``tol`` / ``max_iter`` / ``momentum`` / optional eps
             ``schedule``
    HOW    — ``method`` + an :class:`ExecutionPolicy` (backend pin,
             precision, fused-plan switch, megakernel cadence, mesh)

and the three front doors all accept it:

    solve(spec)                  # repro.core.api
    solve_many([spec, ...])      # shared-cell batched solves
    service.submit(spec)         # repro.serving (eps/method validated
                                 # against the service's engine)

The keyword forms remain as thin back-compat wrappers; passing the legacy
execution kwargs (``use_pallas=``/``inner_steps=``/``check_every=``/
``precision=``) alongside a bare problem emits a ``DeprecationWarning``
pointing here. Training code bridges via
:meth:`OTObjective.spec <repro.core.objective.OTObjective>` so a loss's
configuration and an offline solve of the same problem are literally the
same record.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax

from ..resilience.policy import RecoveryPolicy
from .api import EpsSchedule, OTProblem, METHODS
from .geometry import Geometry
from .objective import ExecutionPolicy

__all__ = ["SolveSpec"]


@dataclasses.dataclass(frozen=True)
class SolveSpec:
    """One solve, fully specified. See module docstring.

    ``a``/``b`` default to uniform weights over the geometry's supports.
    ``policy.mesh``/``policy.mesh_axis`` are the ONLY mesh knobs — the
    spec has no separate mesh argument, so a step function builds its
    policy once (``ExecutionPolicy.from_config(cfg, mesh=mesh)``) and
    every surface sees the same sharding decision. ``rank``/``key`` feed
    the cost-family-converting methods ("arccos", "nystrom").
    ``recovery`` optionally attaches a
    :class:`~repro.resilience.RecoveryPolicy`: ``solve(spec)`` then
    classifies the result and climbs the fallback ladder on failure
    (``solve_many`` re-solves failed lanes the same way).
    """

    geometry: Geometry
    a: Optional[jax.Array] = None
    b: Optional[jax.Array] = None
    method: str = "auto"
    schedule: Optional[EpsSchedule] = None
    tol: float = 1e-6
    max_iter: int = 2000
    momentum: float = 1.0
    policy: ExecutionPolicy = ExecutionPolicy()
    rank: Optional[int] = None
    key: Optional[jax.Array] = None
    recovery: Optional[RecoveryPolicy] = None

    def __post_init__(self):
        if not isinstance(self.geometry, Geometry):
            raise TypeError(
                "SolveSpec.geometry must be a Geometry (wrap raw factors "
                "via repro.core.geometry or OTProblem.from_*)")
        if self.method not in METHODS:
            raise ValueError(
                f"method must be one of {METHODS}, got {self.method!r}")
        if not isinstance(self.policy, ExecutionPolicy):
            raise TypeError("SolveSpec.policy must be an ExecutionPolicy")
        if self.recovery is not None and not isinstance(self.recovery,
                                                        RecoveryPolicy):
            raise TypeError(
                "SolveSpec.recovery must be a "
                "repro.resilience.RecoveryPolicy (or None)")

    # -- bridges -------------------------------------------------------

    @property
    def eps(self) -> float:
        return self.geometry.eps

    def problem(self) -> OTProblem:
        """The (geometry, a, b) record the engine layers consume."""
        return OTProblem.from_geometry(self.geometry, self.a, self.b)

    @classmethod
    def from_problem(cls, problem: OTProblem, **overrides) -> "SolveSpec":
        """Lift a legacy :class:`OTProblem` (plus optional field
        overrides) into a spec."""
        return cls(geometry=problem.geometry, a=problem.a, b=problem.b,
                   **overrides)

    def replace(self, **changes) -> "SolveSpec":
        return dataclasses.replace(self, **changes)

    def solver_kwargs(self) -> dict:
        """Every keyword ``api.solve`` takes, in one dict — the spec's
        expansion the back-compat wrapper path routes through."""
        return dict(
            method=self.method, schedule=self.schedule, tol=self.tol,
            max_iter=self.max_iter, momentum=self.momentum,
            mesh=self.policy.mesh, mesh_axis=self.policy.mesh_axis,
            rank=self.rank, key=self.key,
            **self.policy.solver_kwargs(),
        )

    def describe(self) -> str:
        n, m = self.geometry.shape
        sched = "-" if self.schedule is None else "anneal"
        return (f"{type(self.geometry).__name__}({n}x{m}) eps={self.eps} "
                f"method={self.method} tol={self.tol} sched={sched} | "
                f"{self.policy.describe()}")
