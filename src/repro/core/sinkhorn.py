"""Sinkhorn solvers: factored (linear-time), quadratic baseline, log-domain.

Algorithm 1 of the paper, generic in the kernel *operator*:

    repeat:  v <- b / K^T u ;  u <- a / K v
    until || v . (K^T u) - b ||_1 < tol

The factored path applies K = Xi @ Zeta^T as two thin matmuls — O(r(n+m))
per iteration. The loop is a ``lax.while_loop`` (non-differentiable on
purpose; gradients flow through the envelope theorem in ``grad.py``).

This module is organised as operator-generic BUILDING BLOCKS that every
solver in the repo composes:

  * ``make_scaling_step``   — one full scaling-space iteration (u, v, s)
  * ``make_log_step``       — one full log-domain iteration (f, g)
  * ``factored_log_matvecs``/``dense_log_matvecs`` — the log-space kernel
    operators shared with ``accelerated.py`` and ``api.py``
  * ``run_marginal_loop``   — the tol/max_iter while_loop shared by all

``api.solve`` and the ``BatchedSinkhorn`` engine (``api.py``) vmap these
blocks over a leading batch axis; ``sharded.py`` composes the same scaling
step with psum'd contractions inside ``shard_map``.

``sinkhorn_geometry`` / ``sinkhorn_log_geometry`` additionally accept
``use_pallas``: when the geometry declares a fused Pallas plan
(``Geometry.pallas_ops`` -> ``kernels.ops.geometry_ops``), the
``lax.while_loop`` body runs through the plan's fused kernels (feature
contraction + half-step with the marginal divide/subtract fused) instead
of the XLA operators — auto-on on TPU backends, opt-in interpret mode in
tests, elementwise-identical semantics either way.

Implementation notes
--------------------
* We reuse ``s = K^T u`` across the marginal check and the next v-update,
  so convergence monitoring is free (one matvec + one rmatvec per iter).
* Every solver ends on a **u-update**, so the row marginals are exact and
  the dual value collapses to  W_hat = eps (a . log u + b . log v) (Eq. 6).
* ``momentum`` in (1, 2) enables over-relaxed Sinkhorn (Thibault et al.),
  the cheap acceleration alternative to the paper's Remark-2 AGM variant.
* Log-domain solvers operate on (f, g) = eps (log u, log v) and use an
  exact two-stage logsumexp for the factored kernel (all entries positive):
      t_k       = LSE_i( logXi[i,k] + f_i / eps )
      (log K^T e^{f/eps})_j = LSE_k( logZeta[j,k] + t_k )
* Zero-weight atoms are SUPPORTED: a_i = 0 (resp. b_j = 0) atoms get
  u_i = 0 / f_i = -inf and are excluded from the masked dual value. This is
  what makes bucket-padding in the batched engine exact rather than
  approximate — padded atoms carry zero mass and change nothing.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.backend import resolve_backend
from ..kernels.ops import (
    check_precision,
    geometry_ops,
    notify_plan_selected,
    relax_log,
    relax_scaling,
)
from .geometry import DenseCost, FactoredPositive, Geometry, _masked_log

__all__ = [
    "SinkhornResult",
    "geometry_reduce",
    "make_scaling_step",
    "make_log_step",
    "factored_log_matvecs",
    "dense_log_matvecs",
    "run_marginal_loop",
    "masked_dual_value",
    "sinkhorn_operator",
    "sinkhorn_geometry",
    "sinkhorn_log_geometry",
    "sinkhorn_factored",
    "sinkhorn_quadratic",
    "sinkhorn_log_factored",
    "sinkhorn_log_quadratic",
    "dual_objective",
]


class SinkhornResult(NamedTuple):
    """Solver output. ``u``/``v`` are scalings; ``f``/``g`` potentials."""

    u: jax.Array
    v: jax.Array
    f: jax.Array            # eps * log u
    g: jax.Array            # eps * log v
    cost: jax.Array         # W_hat = eps (a.log u + b.log v)   (Eq. 6)
    n_iter: jax.Array
    marginal_err: jax.Array
    converged: jax.Array

    @property
    def diverged(self) -> jax.Array:
        """Structured divergence flag: the iteration blew up (non-finite
        marginal error or dual value) rather than merely not converging
        yet. This is how the signed-Nystrom small-eps failure mode (paper
        Figs. 1/3/5) is surfaced — ``converged=False, diverged=True`` —
        instead of handing callers raw NaNs to interpret. Implemented as a
        property so the pytree structure (vmap / shard_map out_specs) is
        unchanged."""
        return ~(jnp.isfinite(self.marginal_err) & jnp.isfinite(self.cost))

    @property
    def health(self):
        """Host-side :class:`~repro.resilience.health.SolveHealth` verdict
        for a CONCRETE unbatched result (``ok`` / ``maxed_out`` /
        ``diverged``). Pulls the scalar diagnostics to host — inside
        ``jit``/``vmap`` use :attr:`diverged`, which stays an array. The
        ``poisoned_warm_start`` verdict needs the warm-start context the
        result alone does not carry; classify through
        :func:`repro.resilience.classify` with ``f_init``/``g_init``
        to enable it."""
        from ..resilience.health import classify  # lazy: avoid cycle
        return classify(self)


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def masked_dual_value(a, b, f, g, reduce: Callable = jnp.sum):
    """W_hat = <a, f> + <b, g> with zero-weight atoms excluded.

    Padded atoms have a_i = 0 and f_i = -inf; a plain vdot would produce
    0 * -inf = nan, so both terms mask on strictly positive weight.
    ``reduce`` lets SPMD callers psum the local partial sums so the value
    replicates across devices (see :func:`geometry_reduce`).
    """
    ta = reduce(jnp.where(a > 0, a * f, 0.0))
    tb = reduce(jnp.where(b > 0, b * g, 0.0))
    return ta + tb


def geometry_reduce(geom: "Geometry") -> Callable[[jax.Array], jax.Array]:
    """The scalar-reduction hook a geometry's execution mode implies.

    Single-device geometries reduce with a plain ``jnp.sum``; row-sharded
    wrappers (``geom.spmd_axis`` set) additionally psum over the mesh axis
    so the marginal error driving the while_loop and the dual value are
    REPLICATED — every device exits the loop together (an SPMD
    requirement) and the cost needs no post-hoc collective.
    """
    ax = geom.spmd_axis
    if ax is None:
        return jnp.sum
    return lambda e: jax.lax.psum(jnp.sum(e), ax)


def make_scaling_step(
    matvec: Callable[[jax.Array], jax.Array],
    rmatvec: Callable[[jax.Array], jax.Array],
    a: jax.Array,
    b: jax.Array,
    *,
    momentum: float = 1.0,
    err_reduce: Callable[[jax.Array], jax.Array] = jnp.sum,
):
    """One full Alg.-1 iteration in scaling space.

    Returns ``step((u, v, s)) -> ((u', v', s'), err)`` where ``s = K^T u``
    is carried so the marginal check is free. ``err_reduce`` lets SPMD
    callers (``sharded.py``) psum the local L1 error into a replicated
    scalar.
    """

    def step(carry):
        u, v, s = carry
        # geometric over-relaxation: u <- u_old^{1-w} * u_new^{w}.
        # Dead (zero-mass) atoms are pinned to scaling 0 rather than left
        # to b/s: a stale kernel row under a dead slot can underflow its
        # contraction to exactly 0, and the resulting 0/0 = NaN would ride
        # the next matvec into every LIVE lane.
        v_new = relax_scaling(jnp.where(b > 0, b / s, 0.0), v, momentum)
        u_new = relax_scaling(jnp.where(a > 0, a / matvec(v_new), 0.0),
                              u, momentum)
        s_new = rmatvec(u_new)
        err = err_reduce(jnp.abs(v_new * s_new - b))
        return (u_new, v_new, s_new), err

    return step


def factored_log_matvecs(
    log_xi: jax.Array, log_zeta: jax.Array, *, eps: float
) -> Tuple[Callable, Callable]:
    """Exact two-stage LSE operators for K = Xi Zeta^T (all entries > 0).

        log_matvec(g)  = log(K   e^{g/eps})   (n,)
        log_rmatvec(f) = log(K^T e^{f/eps})   (m,)

    Cost O(r (n + m)) each. Thin wrapper over the
    :class:`~repro.core.geometry.FactoredPositive` geometry's operators —
    the single source of truth for the factored log-matvec math.
    """
    geom = FactoredPositive(log_xi=log_xi, log_zeta=log_zeta, eps=eps)
    return geom.log_operators()


def dense_log_matvecs(C: jax.Array, *, eps: float) -> Tuple[Callable, Callable]:
    """Dense O(nm) log-operators on the Gibbs kernel of cost matrix C
    (the :class:`~repro.core.geometry.DenseCost` geometry's operators)."""
    geom = DenseCost(C, eps)
    return geom.log_operators()


def make_log_step(
    log_matvec: Callable[[jax.Array], jax.Array],
    log_rmatvec: Callable[[jax.Array], jax.Array],
    a: jax.Array,
    b: jax.Array,
    *,
    eps: float,
    momentum: float = 1.0,
    err_reduce: Callable[[jax.Array], jax.Array] = jnp.sum,
):
    """One full log-domain iteration: ``step((f, g)) -> ((f', g'), err)``.

    ``momentum`` in (1, 2) applies the log-space over-relaxation
    ``f <- (1-w) f_old + w f_new`` — the exact log of the geometric
    relaxation in :func:`make_scaling_step` (-inf potentials of zero-weight
    atoms bypass the blend).
    """
    loga, logb = _masked_log(a), _masked_log(b)

    def step(carry):
        f, g = carry
        g = relax_log(eps * (logb - log_rmatvec(f)), g, momentum)
        f = relax_log(eps * (loga - log_matvec(g)), f, momentum)
        log_col = log_rmatvec(f) + g / eps       # log of column marginal
        err = err_reduce(jnp.abs(jnp.exp(log_col) - b))
        return (f, g), err

    return step


def run_marginal_loop(step, carry0, *, tol: float, max_iter: int, dtype,
                      steps_per_check: int = 1, iters_per_step: int = 1):
    """Run ``step`` until the marginal error drops below ``tol``.

    One mandatory check block is always taken (so e.g. u.Kv = 1 holds for
    the Eq.-6 dual shortcut). Returns ``(n_iter, carry, err)``.

    Cadence semantics (``check_every`` at the solver surface):
    ``steps_per_check`` step calls run back to back (Python-unrolled, so
    the intermediate error computations are dead code XLA eliminates)
    before each convergence check, and each step call itself advances
    ``iters_per_step`` iterations (1 for the per-iteration steps,
    ``inner_steps`` for the fused megakernel block step). The loop
    therefore checks the error — and a distributed run synchronizes on the
    replicated scalar — once every ``steps_per_check * iters_per_step``
    iterations; the result still satisfies ``err <= tol`` on convergence,
    but ``n_iter`` is a multiple of the cadence and ``max_iter`` is
    effectively rounded UP to the next multiple (a block that starts
    before the cap runs to completion). A divergence (non-finite error)
    inside a block is likewise detected at its boundary — NaN/inf iterates
    propagate, they never un-poison.

    Distribution hook: the loop itself is SPMD-agnostic — under
    ``shard_map`` the step's ``err_reduce`` (see :func:`geometry_reduce`)
    psums the error, so the while_loop carries a REPLICATED scalar and
    every device exits at the same iteration (no control-flow divergence).
    """
    cadence = steps_per_check * iters_per_step

    def body(state):
        it, carry, err = state
        for _ in range(steps_per_check):
            carry, err = step(carry)
        return it + cadence, carry, err

    def cond(state):
        it, _, err = state
        return (it < max_iter) & (err > tol) & jnp.isfinite(err)

    state0 = body((jnp.array(0, jnp.int32), carry0, jnp.asarray(jnp.inf, dtype)))
    return jax.lax.while_loop(cond, body, state0)


# ---------------------------------------------------------------------------
# Fused Pallas plan selection (the use_pallas policy)
# ---------------------------------------------------------------------------


def _maybe_pallas_plan(geom: Geometry, use_pallas: Optional[bool],
                       mode: str, precision: str = "highest"):
    """Resolve the ``use_pallas`` policy into a fused plan (or ``None``).

    ``None`` (auto) turns the fused path on exactly when the resolved
    execution backend COMPILES its Pallas lowering (tpu-mosaic AND
    gpu-triton — see ``kernels.backend``); interpret-only platforms keep
    the XLA operators. ``True`` forces the plan (interpret mode on CPU —
    the test configuration), ``False`` forces the XLA operators.
    Geometries without a fused plan (dense, Nystrom, grids) always fall
    back. Selections are reported through the
    ``kernels.ops.observe_plan_selection`` hook.
    """
    if geom.spmd_axis is not None:
        # a fused local plan would drop the psum — sharded geometries
        # always run the XLA operators (their pallas_ops return None too;
        # this guard keeps a forced use_pallas=True from probing them)
        return None
    if use_pallas is None:
        use_pallas = not resolve_backend().interpret
    if not use_pallas:
        return None
    plan = geometry_ops(geom, mode=mode, precision=precision)
    if plan is not None:
        notify_plan_selected({
            "geometry": type(geom).__name__,
            "mode": plan.mode,
            "kind": plan.kind,
            "precision": plan.precision,
        })
    return plan


def _resolve_cadence(plan, inner_steps: Optional[int],
                     check_every: Optional[int]):
    """Resolve the ``inner_steps`` / ``check_every`` knobs into concrete
    (inner, check) iteration counts.

    Auto policy (both ``None``): when the fused plan COMPILES (TPU) and
    offers a megakernel block step, run 8 iterations per launch and check
    convergence once per block; everywhere else keep today's
    check-every-iteration semantics (interpret-mode megakernels are a
    test/bench configuration, never an auto win). Explicit values are
    honored on every path — on the XLA operators ``inner_steps`` degrades
    to the same check cadence (unrolled steps, fewer error reductions and
    loop syncs), which is the documented fallback semantics.
    """
    auto = inner_steps is None and check_every is None
    if auto:
        if plan is not None and not plan.interpret \
                and plan.make_block_step is not None:
            return 8, 8, True
        return 1, 1, True
    inner = 1 if inner_steps is None else int(inner_steps)
    if inner < 1:
        raise ValueError(f"inner_steps must be >= 1, got {inner_steps}")
    check = inner if check_every is None else int(check_every)
    if check < 1:
        raise ValueError(f"check_every must be >= 1, got {check_every}")
    if check % inner != 0:
        raise ValueError(
            f"check_every ({check}) must be a multiple of inner_steps "
            f"({inner}): the marginal error only exists at megakernel "
            "block boundaries"
        )
    return inner, check, False


def _plan_loop(plan, step_args, *, tol, max_iter, dtype,
               inner_steps, check_every, momentum):
    """Shared hot-loop driver for both fused-plan modes: resolve the
    cadence, prefer the persistent megakernel block step (``inner_steps``
    iterations per launch, carries on-chip), fall back to the streaming
    per-iteration step at the same check cadence."""
    a, b = step_args
    inner, check, auto = _resolve_cadence(plan, inner_steps, check_every)
    block = None
    if inner > 1 and plan.make_block_step is not None:
        block = plan.make_block_step(a, b, inner_steps=inner,
                                     momentum=momentum)
    if block is not None:
        step, init = block
        return init, functools.partial(
            run_marginal_loop, step, tol=tol, max_iter=max_iter,
            dtype=dtype, steps_per_check=check // inner,
            iters_per_step=inner,
        )
    # no megakernel at this shape/budget: auto keeps the exact
    # per-iteration semantics; explicit knobs keep the check cadence
    # (unrolled steps) so iteration-count semantics stay identical
    step, init = plan.make_step(a, b, momentum=momentum)
    return init, functools.partial(
        run_marginal_loop, step, tol=tol, max_iter=max_iter, dtype=dtype,
        steps_per_check=1 if auto else check,
    )


def _finish_scaling(a, b, u, v, it, err, *, eps, tol,
                    reduce: Callable = jnp.sum) -> SinkhornResult:
    f, g = eps * _masked_log(u), eps * _masked_log(v)
    cost = masked_dual_value(a, b, f, g, reduce)
    return SinkhornResult(u, v, f, g, cost, it, err, err <= tol)


def _solve_scaling_plan(plan, a, b, *, eps, tol, max_iter, momentum,
                        u_init, inner_steps=None,
                        check_every=None) -> SinkhornResult:
    """Alg. 1 with the ``lax.while_loop`` body routed through the fused
    Pallas plan — semantics (masking, warm start, marginal check, momentum)
    identical to :func:`sinkhorn_operator` up to the check cadence
    (``inner_steps`` iterations per megakernel launch, error at block
    boundaries)."""
    n, m = a.shape[0], b.shape[0]
    dtype = a.dtype
    u0 = jnp.ones((n,), dtype) if u_init is None else u_init
    v0 = jnp.ones((m,), dtype)
    init, loop = _plan_loop(
        plan, (a, b), tol=tol, max_iter=max_iter, dtype=dtype,
        inner_steps=inner_steps, check_every=check_every, momentum=momentum,
    )
    it, (u, v, _), err = loop(init(u0, v0))
    return _finish_scaling(a, b, u, v, it, err, eps=eps, tol=tol)


# ---------------------------------------------------------------------------
# Scaling-space solvers
# ---------------------------------------------------------------------------


def sinkhorn_operator(
    matvec: Callable[[jax.Array], jax.Array],      # v (m,) -> K v (n,)
    rmatvec: Callable[[jax.Array], jax.Array],     # u (n,) -> K^T u (m,)
    a: jax.Array,
    b: jax.Array,
    *,
    eps: float,
    tol: float = 1e-6,
    max_iter: int = 2000,
    momentum: float = 1.0,
    u_init: Optional[jax.Array] = None,
    err_reduce: Callable[[jax.Array], jax.Array] = jnp.sum,
    check_every: int = 1,
) -> SinkhornResult:
    """Algorithm 1 on an abstract positive kernel operator.

    ``err_reduce`` is the SPMD hook: sharded callers pass the psum'd
    reduction of :func:`geometry_reduce` so the convergence scalar (and
    the dual value) replicate across devices. ``check_every`` sets the
    convergence-check cadence (see :func:`run_marginal_loop`): iteration
    counts become multiples of it, the converged result still satisfies
    ``err <= tol``.
    """
    n, m = a.shape[0], b.shape[0]
    dtype = a.dtype
    u0 = jnp.ones((n,), dtype) if u_init is None else u_init
    v0 = jnp.ones((m,), dtype)
    step = make_scaling_step(matvec, rmatvec, a, b, momentum=momentum,
                             err_reduce=err_reduce)
    it, (u, v, _), err = run_marginal_loop(
        step, (u0, v0, rmatvec(u0)), tol=tol, max_iter=max_iter,
        dtype=dtype, steps_per_check=int(check_every),
    )
    return _finish_scaling(a, b, u, v, it, err, eps=eps, tol=tol,
                           reduce=err_reduce)


def sinkhorn_geometry(
    geom: Geometry,
    a: jax.Array,
    b: jax.Array,
    *,
    tol: float = 1e-6,
    max_iter: int = 2000,
    momentum: float = 1.0,
    u_init: Optional[jax.Array] = None,
    use_pallas: Optional[bool] = None,
    inner_steps: Optional[int] = None,
    check_every: Optional[int] = None,
    precision: str = "highest",
) -> SinkhornResult:
    """Algorithm 1 in scaling space on any Geometry's native operators.

    This is the one scaling-space entry point every cost family shares:
    factored kernels get O(r(n+m)) iterations, grids get axis-wise
    convolutions, dense costs get the O(nm) baseline, and signed Nystrom
    factors run (and possibly diverge — see ``SinkhornResult.diverged``)
    without any representation branching at the call site.

    ``use_pallas`` selects between the geometry's HOISTED XLA operators
    and the fused Pallas plan (``kernels.ops.geometry_ops``) for the
    while_loop body: ``None`` auto-enables the plan on TPU backends only,
    ``True`` forces it (interpret mode off-TPU — the test path), ``False``
    forces the XLA operators. Either way per-family precomputation (dense
    Gibbs kernel, feature materialization, per-axis grid kernels) happens
    once per solve, not inside the while_loop.

    ``inner_steps`` fuses that many full iterations into ONE persistent
    megakernel launch (``kernels.fused_loop``) when the plan offers one
    (factors VMEM-resident, scalings on-chip, marginal error only at
    block boundaries); ``check_every`` sets the convergence-check cadence
    in iterations (a multiple of ``inner_steps``). Both default to an
    auto policy — 8/8 on compiled (TPU) fused plans whose working set
    fits VMEM, today's 1/1 semantics everywhere else; on the XLA
    operators an explicit ``inner_steps`` degrades to the same check
    cadence. Iteration counts become multiples of the cadence; converged
    results still satisfy ``err <= tol``. ``precision="bf16"`` stores and
    streams the kernel factors at half width with f32 accumulation (the
    mixed-precision execution policy).
    """
    check_precision(precision)
    plan = _maybe_pallas_plan(geom, use_pallas, "scaling", precision)
    if plan is not None:
        return _solve_scaling_plan(
            plan, a, b, eps=geom.eps, tol=tol, max_iter=max_iter,
            momentum=momentum, u_init=u_init, inner_steps=inner_steps,
            check_every=check_every,
        )
    _, check, _ = _resolve_cadence(None, inner_steps, check_every)
    matvec, rmatvec = geom.operators(precision=precision)
    return sinkhorn_operator(
        matvec, rmatvec, a, b, eps=geom.eps, tol=tol,
        max_iter=max_iter, momentum=momentum, u_init=u_init,
        err_reduce=geometry_reduce(geom), check_every=check,
    )


def sinkhorn_factored(
    xi: jax.Array,          # (n, r) strictly positive features of mu's support
    zeta: jax.Array,        # (m, r) strictly positive features of nu's support
    a: jax.Array,
    b: jax.Array,
    *,
    eps: float,
    tol: float = 1e-6,
    max_iter: int = 2000,
    momentum: float = 1.0,
    u_init: Optional[jax.Array] = None,
) -> SinkhornResult:
    """Linear-time Sinkhorn on K = xi @ zeta.T (the paper's Section 3.1)."""
    return sinkhorn_geometry(
        FactoredPositive(xi=xi, zeta=zeta, eps=eps), a, b, tol=tol,
        max_iter=max_iter, momentum=momentum, u_init=u_init,
    )


def sinkhorn_quadratic(
    K: jax.Array,           # (n, m) dense positive Gibbs kernel
    a: jax.Array,
    b: jax.Array,
    *,
    eps: float,
    tol: float = 1e-6,
    max_iter: int = 2000,
    momentum: float = 1.0,
    u_init: Optional[jax.Array] = None,
) -> SinkhornResult:
    """The paper's ``Sin`` baseline (Cuturi '13): dense O(nm) matvecs."""
    return sinkhorn_operator(
        lambda v: K @ v, lambda u: K.T @ u, a, b,
        eps=eps, tol=tol, max_iter=max_iter, momentum=momentum, u_init=u_init,
    )


# ---------------------------------------------------------------------------
# Log-domain (small-eps safe)
# ---------------------------------------------------------------------------


def sinkhorn_log_geometry(
    geom: Geometry,
    a: jax.Array,
    b: jax.Array,
    *,
    tol: float = 1e-6,
    max_iter: int = 2000,
    momentum: float = 1.0,
    f_init: Optional[jax.Array] = None,
    g_init: Optional[jax.Array] = None,
    use_pallas: Optional[bool] = None,
    inner_steps: Optional[int] = None,
    check_every: Optional[int] = None,
    precision: str = "highest",
) -> SinkhornResult:
    """Log-domain (small-eps safe) Sinkhorn on any log-capable Geometry.

    The geometry supplies its hoisted ``log_operators()`` — exact
    two-stage LSE for positive-factored families, axis-wise log-convolution
    for grids, dense LSE for explicit costs. ``f_init``/``g_init``
    warm-start the potentials (epsilon annealing); ``momentum`` applies the
    log-space over-relaxation of :func:`make_log_step`. ``use_pallas``
    routes the while_loop body through the fused log-feature Pallas plan
    (``kernels.ops.geometry_ops(mode="log")``) — auto-on when the backend
    compiles Pallas (TPU), opt-in interpret mode otherwise.

    ``inner_steps`` / ``check_every`` / ``precision`` are the log-domain
    twins of the :func:`sinkhorn_geometry` knobs: a persistent log
    megakernel block (potentials + stage-1 LSE carry on-chip), the
    convergence-check cadence (iteration counts become multiples of it),
    and bf16 log-feature storage with f32 LSE accumulation.
    """
    check_precision(precision)
    plan = _maybe_pallas_plan(geom, use_pallas, "log", precision)
    if plan is not None:
        return _solve_log_plan(
            plan, a, b, eps=geom.eps, tol=tol, max_iter=max_iter,
            momentum=momentum, f_init=f_init, g_init=g_init,
            inner_steps=inner_steps, check_every=check_every,
        )
    _, check, _ = _resolve_cadence(None, inner_steps, check_every)
    log_matvec, log_rmatvec = geom.log_operators(precision=precision)
    return _log_domain_solve(
        log_matvec, log_rmatvec, a, b, eps=geom.eps, tol=tol,
        max_iter=max_iter, momentum=momentum, f_init=f_init, g_init=g_init,
        err_reduce=geometry_reduce(geom), check_every=check,
    )


def _log_init(a, b, f_init, g_init):
    """Initial potentials, with zero-weight atoms pinned to -inf.

    The pin makes padding exact from ITERATION 0, not just at the fixed
    point: a dead atom's exp(-inf + ...) contributes nothing to the very
    first LSE, so a bucket/shard-padded solve's live iterates equal the
    unpadded solve's elementwise. (The iteration forces dead atoms to
    -inf after one step anyway — this just removes the transient.)
    Warm starts from a previous masked solve already carry -inf there,
    so the mask is idempotent.
    """
    n, m = a.shape[0], b.shape[0]
    dtype = a.dtype
    f0 = jnp.zeros((n,), dtype) if f_init is None else f_init
    g0 = jnp.zeros((m,), dtype) if g_init is None else g_init
    f0 = jnp.where(a > 0, f0, -jnp.inf)
    g0 = jnp.where(b > 0, g0, -jnp.inf)
    return f0, g0, dtype


def _finish_log(a, b, f, g, it, err, *, eps, tol,
                reduce: Callable = jnp.sum) -> SinkhornResult:
    cost = masked_dual_value(a, b, f, g, reduce)
    u, v = jnp.exp(f / eps), jnp.exp(g / eps)
    return SinkhornResult(u, v, f, g, cost, it, err, err <= tol)


def _log_domain_solve(
    log_matvec, log_rmatvec, a, b, *, eps, tol, max_iter, momentum=1.0,
    f_init=None, g_init=None,
    err_reduce: Callable[[jax.Array], jax.Array] = jnp.sum,
    check_every: int = 1,
) -> SinkhornResult:
    f0, g0, dtype = _log_init(a, b, f_init, g_init)
    step = make_log_step(log_matvec, log_rmatvec, a, b, eps=eps,
                         momentum=momentum, err_reduce=err_reduce)
    it, (f, g), err = run_marginal_loop(
        step, (f0, g0), tol=tol, max_iter=max_iter, dtype=dtype,
        steps_per_check=int(check_every),
    )
    return _finish_log(a, b, f, g, it, err, eps=eps, tol=tol,
                       reduce=err_reduce)


def _solve_log_plan(plan, a, b, *, eps, tol, max_iter, momentum,
                    f_init, g_init, inner_steps=None,
                    check_every=None) -> SinkhornResult:
    """Log-domain solve with the while_loop body routed through the fused
    log-feature Pallas plan — semantics identical to
    :func:`_log_domain_solve` (same iterates, masking, warm starts) up to
    the check cadence."""
    f0, g0, dtype = _log_init(a, b, f_init, g_init)
    init, loop = _plan_loop(
        plan, (a, b), tol=tol, max_iter=max_iter, dtype=dtype,
        inner_steps=inner_steps, check_every=check_every, momentum=momentum,
    )
    it, (f, g, _), err = loop(init(f0, g0))
    return _finish_log(a, b, f, g, it, err, eps=eps, tol=tol)


def sinkhorn_log_factored(
    log_xi: jax.Array,      # (n, r) log-features
    log_zeta: jax.Array,    # (m, r)
    a: jax.Array,
    b: jax.Array,
    *,
    eps: float,
    tol: float = 1e-6,
    max_iter: int = 2000,
    f_init: Optional[jax.Array] = None,
    g_init: Optional[jax.Array] = None,
) -> SinkhornResult:
    """Log-stabilized linear Sinkhorn via exact two-stage logsumexp.

    Positivity of the factored kernel makes the split LSE *exact*:
        log (K^T e^{f/eps})_j = LSE_k( logZeta_jk + LSE_i(logXi_ik + f_i/eps) ).
    Cost O(r (n + m)) per iteration, identical to the scaling-space path.
    ``f_init``/``g_init`` warm-start the potentials (epsilon annealing).
    """
    log_matvec, log_rmatvec = factored_log_matvecs(log_xi, log_zeta, eps=eps)
    return _log_domain_solve(
        log_matvec, log_rmatvec, a, b, eps=eps, tol=tol, max_iter=max_iter,
        f_init=f_init, g_init=g_init,
    )


def sinkhorn_log_quadratic(
    C: jax.Array,           # (n, m) cost matrix
    a: jax.Array,
    b: jax.Array,
    *,
    eps: float,
    tol: float = 1e-6,
    max_iter: int = 5000,
    f_init: Optional[jax.Array] = None,
    g_init: Optional[jax.Array] = None,
) -> SinkhornResult:
    """Dense log-domain Sinkhorn — the ground-truth oracle for benchmarks."""
    log_matvec, log_rmatvec = dense_log_matvecs(C, eps=eps)
    return _log_domain_solve(
        log_matvec, log_rmatvec, a, b, eps=eps, tol=tol, max_iter=max_iter,
        f_init=f_init, g_init=g_init,
    )


def dual_objective(
    f: jax.Array, g: jax.Array, a: jax.Array, b: jax.Array,
    K_apply: Callable[[jax.Array], jax.Array], *, eps: float
) -> jax.Array:
    """a.f + b.g - eps <e^{f/eps}, K e^{g/eps}> + eps   (Eq. 5)."""
    u, v = jnp.exp(f / eps), jnp.exp(g / eps)
    return jnp.vdot(a, f) + jnp.vdot(b, g) - eps * jnp.vdot(u, K_apply(v)) + eps
