"""Sinkhorn solvers: factored (linear-time), quadratic baseline, log-domain.

Algorithm 1 of the paper, generic in the kernel *operator*:

    repeat:  v <- b / K^T u ;  u <- a / K v
    until || v . (K^T u) - b ||_1 < tol

The factored path applies K = Xi @ Zeta^T as two thin matmuls — O(r(n+m))
per iteration. The loop is a ``lax.while_loop`` (non-differentiable on
purpose; gradients flow through the envelope theorem in ``grad.py``).

Implementation notes
--------------------
* We reuse ``s = K^T u`` across the marginal check and the next v-update,
  so convergence monitoring is free (one matvec + one rmatvec per iter).
* Every solver ends on a **u-update**, so the row marginals are exact and
  the dual value collapses to  W_hat = eps (a . log u + b . log v) (Eq. 6).
* ``momentum`` in (1, 2) enables over-relaxed Sinkhorn (Thibault et al.),
  the cheap acceleration alternative to the paper's Remark-2 AGM variant.
* Log-domain solvers operate on (f, g) = eps (log u, log v) and use an
  exact two-stage logsumexp for the factored kernel (all entries positive):
      t_k       = LSE_i( logXi[i,k] + f_i / eps )
      (log K^T e^{f/eps})_j = LSE_k( logZeta[j,k] + t_k )
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "SinkhornResult",
    "sinkhorn_operator",
    "sinkhorn_factored",
    "sinkhorn_quadratic",
    "sinkhorn_log_factored",
    "sinkhorn_log_quadratic",
    "dual_objective",
]


class SinkhornResult(NamedTuple):
    """Solver output. ``u``/``v`` are scalings; ``f``/``g`` potentials."""

    u: jax.Array
    v: jax.Array
    f: jax.Array            # eps * log u
    g: jax.Array            # eps * log v
    cost: jax.Array         # W_hat = eps (a.log u + b.log v)   (Eq. 6)
    n_iter: jax.Array
    marginal_err: jax.Array
    converged: jax.Array


# ---------------------------------------------------------------------------
# Scaling-space loop, generic in the operator
# ---------------------------------------------------------------------------


def sinkhorn_operator(
    matvec: Callable[[jax.Array], jax.Array],      # v (m,) -> K v (n,)
    rmatvec: Callable[[jax.Array], jax.Array],     # u (n,) -> K^T u (m,)
    a: jax.Array,
    b: jax.Array,
    *,
    eps: float,
    tol: float = 1e-6,
    max_iter: int = 2000,
    momentum: float = 1.0,
    u_init: Optional[jax.Array] = None,
) -> SinkhornResult:
    """Algorithm 1 on an abstract positive kernel operator."""
    n, m = a.shape[0], b.shape[0]
    dtype = a.dtype
    u0 = jnp.ones((n,), dtype) if u_init is None else u_init
    s0 = rmatvec(u0)
    v0 = jnp.ones((m,), dtype)

    def relax(new, old):
        if momentum == 1.0:
            return new
        # geometric over-relaxation: u <- u_old^{1-w} * u_new^{w}
        return old ** (1.0 - momentum) * new**momentum

    def cond(state):
        it, _, _, _, err = state
        return (it < max_iter) & (err > tol) & jnp.isfinite(err)

    def body(state):
        it, u, v, s, _ = state
        v_new = relax(b / s, v)
        u_new = relax(a / matvec(v_new), u)
        s_new = rmatvec(u_new)
        err = jnp.sum(jnp.abs(v_new * s_new - b))
        return it + 1, u_new, v_new, s_new, err

    # run one mandatory iteration so u.K v = 1 holds for the dual shortcut
    state0 = body((jnp.array(0, jnp.int32), u0, v0, s0, jnp.asarray(jnp.inf, dtype)))
    it, u, v, s, err = jax.lax.while_loop(cond, body, state0)
    cost = eps * (jnp.vdot(a, jnp.log(u)) + jnp.vdot(b, jnp.log(v)))
    f, g = eps * jnp.log(u), eps * jnp.log(v)
    return SinkhornResult(u, v, f, g, cost, it, err, err <= tol)


def sinkhorn_factored(
    xi: jax.Array,          # (n, r) strictly positive features of mu's support
    zeta: jax.Array,        # (m, r) strictly positive features of nu's support
    a: jax.Array,
    b: jax.Array,
    *,
    eps: float,
    tol: float = 1e-6,
    max_iter: int = 2000,
    momentum: float = 1.0,
    u_init: Optional[jax.Array] = None,
) -> SinkhornResult:
    """Linear-time Sinkhorn on K = xi @ zeta.T (the paper's Section 3.1)."""

    def matvec(v):
        return xi @ (zeta.T @ v)

    def rmatvec(u):
        return zeta @ (xi.T @ u)

    return sinkhorn_operator(
        matvec, rmatvec, a, b, eps=eps, tol=tol, max_iter=max_iter,
        momentum=momentum, u_init=u_init,
    )


def sinkhorn_quadratic(
    K: jax.Array,           # (n, m) dense positive Gibbs kernel
    a: jax.Array,
    b: jax.Array,
    *,
    eps: float,
    tol: float = 1e-6,
    max_iter: int = 2000,
    momentum: float = 1.0,
) -> SinkhornResult:
    """The paper's ``Sin`` baseline (Cuturi '13): dense O(nm) matvecs."""
    return sinkhorn_operator(
        lambda v: K @ v, lambda u: K.T @ u, a, b,
        eps=eps, tol=tol, max_iter=max_iter, momentum=momentum,
    )


# ---------------------------------------------------------------------------
# Log-domain (small-eps safe)
# ---------------------------------------------------------------------------


def _lse(x, axis):
    return jax.scipy.special.logsumexp(x, axis=axis)


def sinkhorn_log_factored(
    log_xi: jax.Array,      # (n, r) log-features
    log_zeta: jax.Array,    # (m, r)
    a: jax.Array,
    b: jax.Array,
    *,
    eps: float,
    tol: float = 1e-6,
    max_iter: int = 2000,
) -> SinkhornResult:
    """Log-stabilized linear Sinkhorn via exact two-stage logsumexp.

    Positivity of the factored kernel makes the split LSE *exact*:
        log (K^T e^{f/eps})_j = LSE_k( logZeta_jk + LSE_i(logXi_ik + f_i/eps) ).
    Cost O(r (n + m)) per iteration, identical to the scaling-space path.
    """
    n, m = a.shape[0], b.shape[0]
    dtype = a.dtype
    loga, logb = jnp.log(a), jnp.log(b)

    def log_rmatvec(f):         # -> log(K^T e^{f/eps}), (m,)
        t = _lse(log_xi + (f / eps)[:, None], axis=0)        # (r,)
        return _lse(log_zeta + t[None, :], axis=1)

    def log_matvec(g):          # -> log(K e^{g/eps}), (n,)
        t = _lse(log_zeta + (g / eps)[:, None], axis=0)      # (r,)
        return _lse(log_xi + t[None, :], axis=1)

    def body(state):
        it, f, g, _ = state
        g = eps * (logb - log_rmatvec(f))
        f = eps * (loga - log_matvec(g))
        log_col = log_rmatvec(f) + g / eps       # log of column marginal
        err = jnp.sum(jnp.abs(jnp.exp(log_col) - b))
        return it + 1, f, g, err

    def cond(state):
        it, _, _, err = state
        return (it < max_iter) & (err > tol) & jnp.isfinite(err)

    f0 = jnp.zeros((n,), dtype)
    g0 = jnp.zeros((m,), dtype)
    state = body((jnp.array(0, jnp.int32), f0, g0, jnp.asarray(jnp.inf, dtype)))
    it, f, g, err = jax.lax.while_loop(cond, body, state)
    cost = jnp.vdot(a, f) + jnp.vdot(b, g)
    u, v = jnp.exp(f / eps), jnp.exp(g / eps)
    return SinkhornResult(u, v, f, g, cost, it, err, err <= tol)


def sinkhorn_log_quadratic(
    C: jax.Array,           # (n, m) cost matrix
    a: jax.Array,
    b: jax.Array,
    *,
    eps: float,
    tol: float = 1e-6,
    max_iter: int = 5000,
) -> SinkhornResult:
    """Dense log-domain Sinkhorn — the ground-truth oracle for benchmarks."""
    n, m = a.shape[0], b.shape[0]
    dtype = a.dtype
    loga, logb = jnp.log(a), jnp.log(b)
    negC = -C / eps

    def body(state):
        it, f, g, _ = state
        g = eps * (logb - _lse(negC + (f / eps)[:, None], axis=0))
        f = eps * (loga - _lse(negC + (g / eps)[None, :], axis=1))
        log_col = _lse(negC + (f / eps)[:, None], axis=0) + g / eps
        err = jnp.sum(jnp.abs(jnp.exp(log_col) - b))
        return it + 1, f, g, err

    def cond(state):
        it, _, _, err = state
        return (it < max_iter) & (err > tol) & jnp.isfinite(err)

    f0, g0 = jnp.zeros((n,), dtype), jnp.zeros((m,), dtype)
    state = body((jnp.array(0, jnp.int32), f0, g0, jnp.asarray(jnp.inf, dtype)))
    it, f, g, err = jax.lax.while_loop(cond, body, state)
    cost = jnp.vdot(a, f) + jnp.vdot(b, g)
    return SinkhornResult(
        jnp.exp(f / eps), jnp.exp(g / eps), f, g, cost, it, err, err <= tol
    )


def dual_objective(
    f: jax.Array, g: jax.Array, a: jax.Array, b: jax.Array,
    K_apply: Callable[[jax.Array], jax.Array], *, eps: float
) -> jax.Array:
    """a.f + b.g - eps <e^{f/eps}, K e^{g/eps}> + eps   (Eq. 5)."""
    u, v = jnp.exp(f / eps), jnp.exp(g / eps)
    return jnp.vdot(a, f) + jnp.vdot(b, g) - eps * jnp.vdot(u, K_apply(v)) + eps
