"""Sinkhorn-balanced MoE routing built on the paper's solver.

Expert assignment is an entropic OT problem between tokens (uniform marginal
``a``) and experts (capacity marginal ``b``). The router's Gibbs kernel
``K = exp(logits / eps)`` is positive BY CONSTRUCTION — the "positive
feature" view degenerates gracefully here: the factorization K = Xi Zeta^T
holds with Xi = exp(h W_e / eps) only approximately, but since E (number of
experts) is tiny (<= 256) we can afford the exact n x E kernel while still
using the same operator-generic solver, its convergence monitoring, and its
envelope-theorem gradient discipline (no backprop through the loop; the
assignment matrix is treated as a constant plan, gradients flow through the
logits via the straight-through combine weights).

Used by deepseek-v2-236b / deepseek-v3-671b configs via ``router="sinkhorn"``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .geometry import DenseCost
from .objective import ExecutionPolicy, OTObjective

__all__ = ["SinkhornRouting", "sinkhorn_route"]


class SinkhornRouting(NamedTuple):
    combine: jax.Array       # (T, E) combine weights (rows sum ~ top_k mass)
    dispatch: jax.Array      # (T, E) bool-ish dispatch mask
    balance_loss: jax.Array  # scalar aux loss (load-balance residual)


def sinkhorn_route(
    logits: jax.Array,          # (T, E) router logits
    *,
    top_k: int,
    eps: float = 0.05,
    n_iter: int = 8,
    policy: Optional[ExecutionPolicy] = None,
) -> SinkhornRouting:
    """Balanced top-k assignment from an entropic OT plan.

    Fixed small iteration count (n_iter) keeps the op fully static for
    compilation; the plan is stop-gradiented (envelope discipline) and
    combine weights are straight-through so the router still trains.

    ``policy`` shares the training-wide :class:`ExecutionPolicy` with the
    other OT losses (check cadence, precision, backend pin). ``None``
    keeps the legacy check-every-iteration f32 behavior. The solve runs
    through the same ``OTObjective`` layer as every other training
    surface; with ``tol=0`` the error check is dead weight, so the policy
    defaults the check cadence to once per solve.
    """
    T, E = logits.shape
    a = jnp.full((T,), 1.0 / T, logits.dtype)
    b = jnp.full((E,), 1.0 / E, logits.dtype)
    if policy is not None and policy.check_every is None \
            and policy.inner_steps is None:
        policy = ExecutionPolicy(
            backend=policy.backend, precision=policy.precision,
            use_pallas=policy.use_pallas, check_every=n_iter,
        )
    obj = OTObjective(
        eps=eps, tol=0.0, max_iter=n_iter,
        policy=policy if policy is not None else ExecutionPolicy(),
    )
    # the router's Gibbs kernel K = exp(logits/eps) as a DenseCost geometry:
    # c = max(logits) - logits is the exact kernel-first cost (Eq. 7)
    geom = DenseCost(
        jax.lax.stop_gradient(jnp.max(logits) - logits), eps
    )
    res = obj.solve(geom, a, b)
    plan = res.u[:, None] * geom.dense_kernel() * res.v[None, :]       # (T,E)
    plan = jax.lax.stop_gradient(plan)
    # top-k experts per token under the BALANCED plan
    _, top_idx = jax.lax.top_k(plan, top_k)                            # (T,k)
    dispatch = jnp.zeros((T, E), logits.dtype).at[
        jnp.arange(T)[:, None], top_idx
    ].set(1.0)
    # combine weights: softmax of raw logits restricted to dispatched experts
    # (straight-through: gradient flows through the softmax, not the plan)
    masked = jnp.where(dispatch > 0, logits, -jnp.inf)
    combine = jax.nn.softmax(masked, axis=-1)
    combine = jnp.where(dispatch > 0, combine, 0.0)
    # aux balance loss: deviation of realized load from uniform
    load = jnp.mean(dispatch, axis=0)                                  # (E,)
    balance = E * jnp.sum(jnp.square(load - 1.0 / E))
    return SinkhornRouting(combine, dispatch, balance)
