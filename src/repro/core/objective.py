"""The one training-facing OT objective layer (ROADMAP: "close the loop").

Every place a Sinkhorn divergence appears inside a training step — the GAN
objective (paper Eq. 18), the LM prototype loss, Sinkhorn MoE routing —
used to carry its own solver configuration and its own legacy entry point,
bypassing the fused megakernel (PR 5), the backend policy (PR 7) and the
mesh sharding (PR 4) that the inference stack already uses. This module
packages the whole pipeline behind two small frozen records:

* :class:`ExecutionPolicy` — HOW a solve runs: backend pin, storage
  precision (bf16 factors with f32 accumulation), the ``use_pallas``
  fused-plan switch, megakernel cadence (``inner_steps``/``check_every``)
  and an optional mesh for sharded solves. All fields are static and
  hashable, so a policy can be closed over by ``jax.jit`` (or passed as a
  static argument) without ever retracing.

* :class:`OTObjective` — WHAT is being optimized: the entropic scale
  ``eps``, the iteration budget, and the policy. It builds geometries from
  embeddings (factored log-features, Gaussian point clouds with learnable
  anchors), evaluates the debiased divergence through the generic
  envelope-theorem VJP (no backprop through the ``lax.while_loop``), and
  exposes the raw balanced-transport solve for routing.

Training code should never call ``rot_*``/``sinkhorn_*`` directly — it
builds one ``OTObjective`` per loss and differentiates through it.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..kernels.backend import backend_scope, resolve_backend
from ..kernels.ops import check_precision
from .divergence import sinkhorn_divergence_geometry
from .geometry import FactoredPositive, GaussianPointCloud, Geometry
from .sinkhorn import SinkhornResult, sinkhorn_geometry

__all__ = ["ExecutionPolicy", "OTObjective"]


@dataclasses.dataclass(frozen=True)
class ExecutionPolicy:
    """How every solve issued by an :class:`OTObjective` executes.

    One record replaces the per-call-site ``use_pallas=``/``precision=``/
    ``inner_steps=`` keyword sprawl. Fields mirror the solver knobs:

    backend      pin solves to a named backend (``"tpu-mosaic"`` /
                 ``"gpu-triton"`` / ``"interpret"``); ``None`` keeps the
                 ambient ``kernels.backend`` resolution.
    precision    ``"highest"`` or ``"bf16"`` (half-width factor storage,
                 f32 accumulation — the PR-5 mixed-precision policy).
    use_pallas   ``None`` = auto (fused plan exactly when the backend
                 compiles Pallas), ``True``/``False`` force it.
    inner_steps  megakernel cadence: full Sinkhorn iterations per fused
                 launch (``None`` = auto: 8 on compiled fused plans).
    check_every  convergence-check cadence in iterations (multiple of
                 ``inner_steps``; ``None`` = auto).
    mesh         optional ``jax.sharding.Mesh`` — divergences run as ONE
                 ``shard_map`` with psum'd-LSE operators over ``mesh_axis``.
    """

    backend: Optional[str] = None
    precision: str = "highest"
    use_pallas: Optional[bool] = None
    inner_steps: Optional[int] = None
    check_every: Optional[int] = None
    mesh: Optional[Any] = None
    mesh_axis: str = "data"

    def __post_init__(self):
        check_precision(self.precision)

    # -- constructors -------------------------------------------------------

    @classmethod
    def training(cls, **overrides) -> "ExecutionPolicy":
        """The default policy for training-time losses: bf16 factor
        storage, fused megakernel wherever the backend compiles it."""
        kw: Dict[str, Any] = dict(precision="bf16")
        kw.update(overrides)
        return cls(**kw)

    @classmethod
    def from_config(cls, cfg, mesh: Optional[Any] = None) -> "ExecutionPolicy":
        """Build the run-wide policy from an ``ArchConfig``'s ``ot_*``
        execution fields (missing fields fall back to training defaults,
        so older/external config objects keep working)."""
        return cls(
            backend=getattr(cfg, "ot_backend", None),
            precision=getattr(cfg, "ot_precision", "bf16"),
            use_pallas=getattr(cfg, "ot_use_pallas", None),
            inner_steps=getattr(cfg, "ot_inner_steps", None),
            check_every=getattr(cfg, "ot_check_every", None),
            mesh=mesh,
        )

    # -- plumbing -----------------------------------------------------------

    def solver_kwargs(self) -> Dict[str, Any]:
        """The knobs threaded into ``sinkhorn_*``/``rot_geometry`` calls."""
        return dict(
            use_pallas=self.use_pallas,
            inner_steps=self.inner_steps,
            check_every=self.check_every,
            precision=self.precision,
        )

    def scope(self):
        """Context manager pinning the backend for the enclosed solves
        (no-op when the policy keeps the ambient resolution)."""
        if self.backend is None:
            return contextlib.nullcontext()
        return backend_scope(self.backend)

    def describe(self) -> str:
        """One-line summary for run/step logs."""
        be = self.backend or resolve_backend().name
        pallas = {None: "auto", True: "on", False: "off"}[self.use_pallas]
        cadence = ("auto" if self.inner_steps is None
                   and self.check_every is None
                   else f"{self.inner_steps or 1}/{self.check_every or 1}")
        mesh = "-" if self.mesh is None else (
            f"{dict(zip(self.mesh.axis_names, self.mesh.devices.shape))}"
            f"@{self.mesh_axis}")
        return (f"backend={be} precision={self.precision} pallas={pallas} "
                f"cadence={cadence} mesh={mesh}")


@dataclasses.dataclass(frozen=True)
class OTObjective:
    """A differentiable Sinkhorn-divergence objective bound to one policy.

    ``eps``/``tol``/``max_iter`` are the problem constants (static floats,
    hashable — safe to close over under ``jit``); ``policy`` is the
    execution record. Gradients flow through the envelope-theorem VJP of
    ``rot_geometry``: differentiable in supports, weights, learnable
    anchors and log-features with NO backprop through the Sinkhorn loop.
    """

    eps: float
    tol: float = 0.0
    max_iter: int = 100
    policy: ExecutionPolicy = ExecutionPolicy()

    def __post_init__(self):
        if self.max_iter < 1:
            raise ValueError(f"max_iter must be >= 1, got {self.max_iter}")

    # -- geometry construction from embeddings ------------------------------

    def factored(self, log_xi: jax.Array,
                 log_zeta: jax.Array) -> FactoredPositive:
        """Positive-feature geometry from precomputed LOG features
        (n,r)/(m,r) — the paper's K = Xi Zeta^T in log space."""
        return FactoredPositive(log_xi=log_xi, log_zeta=log_zeta,
                                eps=self.eps)

    def gaussian(self, x: jax.Array, y: jax.Array, anchors: jax.Array, *,
                 R: Optional[float] = None) -> GaussianPointCloud:
        """Point-cloud geometry under Lemma-1 Gaussian features with
        (learnable) ``anchors`` — the GAN theta of Eq. 18. ``R`` bounds the
        embedded data; ``None`` derives it from the clouds (NOT jit-stable:
        pass the static embedding radius inside traced code)."""
        return GaussianPointCloud.build(x, y, anchors, eps=self.eps, R=R)

    # -- losses / solves ----------------------------------------------------

    def divergence(self, geom: Geometry,
                   a: Optional[jax.Array] = None,
                   b: Optional[jax.Array] = None) -> jax.Array:
        """Debiased divergence Wbar(mu, nu) = W(mu,nu) - (W(mu,mu) +
        W(nu,nu))/2 — three envelope solves under this policy."""
        if geom.eps != self.eps:
            raise ValueError(
                f"geometry eps={geom.eps} != objective eps={self.eps}; "
                "build geometries through the objective")
        p = self.policy
        with p.scope():
            if p.mesh is not None:
                # sharded path: psum'd-LSE operators, fused plans do not
                # apply (sharded geometries always run the XLA operators)
                return sinkhorn_divergence_geometry(
                    geom, a, b, tol=self.tol, max_iter=self.max_iter,
                    mesh=p.mesh, mesh_axis=p.mesh_axis,
                )
            return sinkhorn_divergence_geometry(
                geom, a, b, tol=self.tol, max_iter=self.max_iter,
                **p.solver_kwargs(),
            )

    def __call__(self, geom: Geometry,
                 a: Optional[jax.Array] = None,
                 b: Optional[jax.Array] = None) -> jax.Array:
        return self.divergence(geom, a, b)

    def solve(self, geom: Geometry, a: jax.Array,
              b: jax.Array) -> SinkhornResult:
        """Raw balanced-transport solve (scaling space) under this policy —
        the routing entry point. NOT differentiable by itself: callers own
        the gradient discipline (routers stop-gradient the plan).

        Outside ``jit``, ``result.health`` classifies the outcome
        (``ok`` / ``maxed_out`` / ``diverged``); traced callers (the MoE
        router) read ``result.diverged``, which stays an array — the
        training-step guard (``TrainingSupervisor.admit_step``) is where
        a non-finite routing solve turns into a skipped step."""
        if geom.eps != self.eps:
            raise ValueError(
                f"geometry eps={geom.eps} != objective eps={self.eps}")
        with self.policy.scope():
            return sinkhorn_geometry(
                geom, a, b, tol=self.tol, max_iter=self.max_iter,
                **self.policy.solver_kwargs(),
            )

    def uniform_weights(self, geom: Geometry):
        n, m = geom.shape
        return (jnp.full((n,), 1.0 / n, jnp.float32),
                jnp.full((m,), 1.0 / m, jnp.float32))

    def spec(self, geom: Geometry,
             a: Optional[jax.Array] = None,
             b: Optional[jax.Array] = None,
             *, method: str = "auto"):
        """The :class:`~repro.core.spec.SolveSpec` naming this
        objective's solve of ``geom`` — the bridge that makes a training
        loss's configuration and an offline ``api.solve`` of the same
        problem literally one record."""
        from .spec import SolveSpec  # lazy: spec imports this module
        if geom.eps != self.eps:
            raise ValueError(
                f"geometry eps={geom.eps} != objective eps={self.eps}")
        return SolveSpec(geometry=geom, a=a, b=b, method=method,
                         tol=self.tol, max_iter=self.max_iter,
                         policy=self.policy)
