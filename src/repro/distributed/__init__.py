from .sharding import (
    MeshContext,
    current_mesh_context,
    logical_spec,
    shard,
    use_mesh_context,
)

__all__ = [
    "MeshContext",
    "current_mesh_context",
    "logical_spec",
    "shard",
    "use_mesh_context",
]
