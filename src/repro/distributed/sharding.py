"""Logical-axis sharding: one place that maps model axes onto mesh axes.

Model code annotates activations with LOGICAL axes ("batch", "seq",
"kvseq", "vocab", ...); the active :class:`MeshContext` turns those into
``with_sharding_constraint`` on the physical mesh. Without an active
context every hint is a no-op, so the same model code runs single-device
smoke tests and 512-chip dry-runs unchanged.

Physical scheme (DESIGN.md §5):
  batch  -> ('pod', 'data')  (or ('data',) single-pod)   — data parallel
  seq    -> 'model'          — context parallelism for train/prefill
  kvseq  -> 'model'          — decode: flash-decoding style KV partition
  vocab  -> 'model'          — column-parallel embedding / LM head
  expert -> 'model'          — expert parallelism (MoE)
  fsdp   -> 'data'           — ZeRO-3 parameter sharding (zero3 archs)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "MeshContext",
    "use_mesh_context",
    "current_mesh_context",
    "psum_logsumexp",
    "shard",
    "shard_map",
    "logical_spec",
]


def psum_logsumexp(x: jax.Array, axis_name: str, *, axis: int = 0) -> jax.Array:
    """Distributed logsumexp over a row-sharded array axis.

    Runs INSIDE ``shard_map``: reduces ``x`` over its local ``axis`` AND the
    mesh ``axis_name`` in one exact pass — ``pmax`` of the local maxima,
    shifted local sums, ``psum``, log. The result is replicated over
    ``axis_name`` and the only cross-device traffic is two collectives on
    the reduced shape (for the factored Sinkhorn kernel, one r-vector —
    the paper's whole communication cost).

    ``-inf``-safe: all ``-inf`` slices (the log-features of zero-weight
    padded atoms) shift by 0 instead of ``-inf`` so the result is a clean
    ``-inf`` rather than ``nan`` from ``(-inf) - (-inf)``.
    """
    local_max = jax.lax.stop_gradient(jnp.max(x, axis=axis))
    # pmax has no differentiation rule — and needs none: the shift cancels
    # out of the exact LSE identity, so stopping its gradient leaves the
    # derivative the ordinary (correct) softmax
    gmax = jax.lax.pmax(local_max, axis_name)
    shift = jax.lax.stop_gradient(jnp.where(jnp.isfinite(gmax), gmax, 0.0))
    local_sum = jnp.sum(jnp.exp(x - jnp.expand_dims(shift, axis)), axis=axis)
    return shift + jnp.log(jax.lax.psum(local_sum, axis_name))


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-portable ``shard_map``.

    Newer jax exposes ``jax.shard_map`` (with ``check_vma``); 0.4.x only has
    ``jax.experimental.shard_map.shard_map`` (with ``check_rep``). Every
    shard_map in this repo routes through here so the SPMD solvers and the
    multi-device tests run on both.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )

_state = threading.local()


class MeshContext:
    def __init__(self, mesh: Mesh, *, mode: str = "train"):
        self.mesh = mesh
        self.mode = mode                  # train | prefill | decode
        names = mesh.axis_names
        self.dp_axes: Tuple[str, ...] = tuple(
            a for a in ("pod", "data") if a in names
        )
        self.tp_axis: Optional[str] = "model" if "model" in names else None

    def resolve(self, logical: Optional[str]):
        if logical is None:
            return None
        if logical == "batch":
            return self.dp_axes if self.dp_axes else None
        if logical in ("seq", "kvseq", "vocab", "expert", "heads"):
            return self.tp_axis
        if logical == "fsdp":
            return "data" if "data" in self.mesh.axis_names else None
        raise KeyError(f"unknown logical axis {logical!r}")


@contextlib.contextmanager
def use_mesh_context(ctx: Optional[MeshContext]):
    prev = getattr(_state, "ctx", None)
    _state.ctx = ctx
    try:
        yield ctx
    finally:
        _state.ctx = prev


def current_mesh_context() -> Optional[MeshContext]:
    return getattr(_state, "ctx", None)


def logical_spec(*axes: Optional[str]) -> Optional[P]:
    ctx = current_mesh_context()
    if ctx is None:
        return None
    return P(*(ctx.resolve(a) for a in axes))


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain ``x`` to logical ``axes`` (one per dim; None = replicated)."""
    ctx = current_mesh_context()
    if ctx is None:
        return x
    spec = P(*(ctx.resolve(a) for a in axes))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec)
    )
