"""Fault tolerance & elasticity: checkpoint/restart, failure handling,
straggler mitigation — the policies a 1000+-node deployment needs, with a
CPU-simulatable supervisor (exercised in tests/test_fault_tolerance.py).

Design (DESIGN.md §5):

* **Checkpoint/restart.** CheckpointManager commits atomically; the data
  pipeline is a pure function of step, so restart = restore(params, opt)
  + skip-ahead. Save cadence amortizes: with save_every=k and MTBF_cluster
  = MTBF_node / N nodes, expected lost work is k/2 steps; k is chosen so
  (checkpoint_time + k/2 * step_time * P_fail) is minimized — the
  supervisor exposes ``suggest_save_every``.

* **Node failure -> elastic re-mesh.** On a hard failure the job restarts
  on the surviving slice: ``remesh_plan`` maps (2,16,16) -> (16,16) (drop
  the dead pod) or shrinks 'data'. Because every weight's sharding is a
  NamedSharding over logical axes, resharding is jax.device_put with the
  new sharding after restore — no format conversion.

* **Straggler mitigation.** Synchronous SPMD cannot skip a slow chip, so
  mitigation is (a) drop-to-checkpoint eviction of hosts whose step time
  exceeds p99 * tolerance for w consecutive windows (the supervisor tracks
  this), (b) within-step slack via gradient-accumulation microbatches that
  overlap the DP reduce-scatter of microbatch i with compute of i+1.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from ..checkpoint import CheckpointManager

__all__ = ["FaultToleranceConfig", "TrainingSupervisor", "remesh_plan",
           "suggest_save_every"]


@dataclasses.dataclass
class FaultToleranceConfig:
    save_every: int = 50
    keep: int = 3
    max_restarts: int = 10
    straggler_tolerance: float = 2.0      # x median step time
    straggler_windows: int = 3
    # bound on CONSECUTIVE steps skipped for non-finite OT metrics
    # (admit_step): past it the run aborts instead of silently making no
    # progress on a persistently-diverging objective
    max_consecutive_skips: int = 8


def suggest_save_every(step_time_s: float, ckpt_time_s: float,
                       node_mtbf_h: float, n_nodes: int) -> int:
    """Young/Daly optimal checkpoint interval, in steps."""
    mtbf_cluster_s = node_mtbf_h * 3600.0 / max(n_nodes, 1)
    interval_s = math.sqrt(2.0 * ckpt_time_s * mtbf_cluster_s)
    return max(1, int(interval_s / max(step_time_s, 1e-9)))


def remesh_plan(alive_pods: int, alive_per_pod: int) -> Dict:
    """Largest legal production mesh on the surviving slice."""
    if alive_pods >= 2 and alive_per_pod >= 256:
        return {"shape": (2, 16, 16), "axes": ("pod", "data", "model")}
    if alive_per_pod >= 256:
        return {"shape": (16, 16), "axes": ("data", "model")}
    # degraded: shrink data-parallelism, keep model sharding intact
    data = max(1, alive_per_pod // 16)
    return {"shape": (data, 16), "axes": ("data", "model")}


class TrainingSupervisor:
    """Wraps a step function with checkpointing + restart-on-failure.

    ``step_fn(state, step) -> state`` may raise (simulated node failure);
    the supervisor restores the last committed checkpoint and continues.
    Deterministic data (pure function of step) makes the replay exact.
    """

    def __init__(self, ckpt: CheckpointManager, cfg: FaultToleranceConfig):
        self.ckpt = ckpt
        self.cfg = cfg
        self.restarts = 0
        self.step_times: List[float] = []
        self.skipped_steps = 0          # total steps refused by admit_step
        self.consecutive_skips = 0      # current refusal streak

    def admit_step(self, metrics: Dict) -> bool:
        """Training-step guard for the OT objective layer: admit the step
        only when every numeric metric (OT loss, grad norm, ...) is
        finite.

        A diverged routing/GAN solve surfaces here as a NaN loss or grad
        norm — applying that update poisons the parameters permanently,
        so the caller keeps the OLD state on refusal (skip the step, keep
        training on the next batch). Refusals are counted; a streak
        longer than ``max_consecutive_skips`` aborts with ``RuntimeError``
        — at that point the objective is persistently diverging and
        skipping forever would burn the job silently.
        """
        bad = []
        for k, v in metrics.items():
            try:
                arr = np.asarray(v, dtype=np.float64)
            except (TypeError, ValueError):
                continue        # non-numeric metric (tags, names): ignore
            if not np.all(np.isfinite(arr)):
                bad.append(k)
        if not bad:
            self.consecutive_skips = 0
            return True
        self.skipped_steps += 1
        self.consecutive_skips += 1
        if self.consecutive_skips > self.cfg.max_consecutive_skips:
            raise RuntimeError(
                f"aborting: {self.consecutive_skips} consecutive steps "
                f"skipped on non-finite metrics {bad} (bound "
                f"max_consecutive_skips={self.cfg.max_consecutive_skips})")
        return False

    def run(self, state, start_step: int, n_steps: int,
            step_fn: Callable, *, on_restore: Optional[Callable] = None):
        step = start_step
        while step < start_step + n_steps:
            t0 = time.perf_counter()
            try:
                state = step_fn(state, step)
            except Exception:
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise
                latest = self.ckpt.latest_step()
                if latest is None:
                    raise
                state, _ = self.ckpt.restore(latest, state)
                if on_restore is not None:
                    state = on_restore(state)
                step = latest + 1
                continue
            self.step_times.append(time.perf_counter() - t0)
            if (step + 1) % self.cfg.save_every == 0:
                self.ckpt.save(step, state)
            step += 1
        self.ckpt.save(step - 1, state)
        self.ckpt.wait()
        return state, step

    def straggler_report(self) -> Dict:
        if len(self.step_times) < 4:
            return {"flagged": False}
        ts = sorted(self.step_times)
        median = ts[len(ts) // 2]
        worst = ts[-1]
        return {
            "flagged": worst > self.cfg.straggler_tolerance * median,
            "median_s": median,
            "worst_s": worst,
        }
