"""Error-feedback int8 gradient compression for the DP all-reduce.

At 512 chips the data-parallel gradient reduce-scatter moves
``bytes = 2 * P / pod_chips`` per step per link; int8 with per-block scales
cuts the wire bytes ~4x (bf16 -> int8 + 1 scale per 256 values). Error
feedback (Karimireddy et al. '19) keeps the residual locally so the
compression bias vanishes over steps.

``compressed_psum`` demonstrates the production pattern under shard_map:
quantize locally -> psum int32 accumulators -> dequantize. The main train
step keeps this OFF by default (config ``grad_compression``) because the
dry-run's roofline shows the big archs here are compute- or memory-bound,
not DP-bound (EXPERIMENTS.md §Roofline); it is wired and tested.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "QuantizedGrad",
    "quantize_int8",
    "dequantize_int8",
    "ef_compress_tree",
    "compressed_psum",
]

_BLOCK = 256


class QuantizedGrad(NamedTuple):
    q: jax.Array          # int8, padded flat
    scale: jax.Array      # f32, one per block
    n: int                # original size (static)


def quantize_int8(x: jax.Array) -> QuantizedGrad:
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % _BLOCK
    flat = jnp.pad(flat, (0, pad)).reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return QuantizedGrad(q, scale[:, 0], n)


def dequantize_int8(qg: QuantizedGrad, shape) -> jax.Array:
    flat = qg.q.astype(jnp.float32) * qg.scale[:, None]
    return flat.reshape(-1)[: qg.n].reshape(shape)


def ef_compress_tree(grads, error_buf):
    """Error-feedback compression of a gradient pytree.

    Returns (decompressed grads to apply, new error buffers). The
    *decompressed* value is what every replica applies, so replicas stay
    bit-identical; the residual (g + e - deq) is carried locally.
    """
    def one(g, e):
        target = g.astype(jnp.float32) + e
        qg = quantize_int8(target)
        deq = dequantize_int8(qg, g.shape)
        return deq.astype(g.dtype), target - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_buf)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        jax.tree.unflatten(treedef, [o[0] for o in outs]),
        jax.tree.unflatten(treedef, [o[1] for o in outs]),
    )


def init_error_buffers(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(x: jax.Array, axis: str) -> jax.Array:
    """int8-quantized all-reduce: each replica quantizes its shard-local
    contribution, the int8 payload is summed as int32 across ``axis``, and
    scales are combined conservatively (max). Call inside shard_map."""
    qg = quantize_int8(x)
    s_max = jax.lax.pmax(qg.scale, axis)
    # renormalize local ints to the shared scale to keep the sum exact
    ratio = qg.scale / s_max
    q_shared = jnp.round(qg.q.astype(jnp.float32) * ratio[:, None])
    total = jax.lax.psum(q_shared.astype(jnp.int32), axis)
    flat = total.astype(jnp.float32) * s_max[:, None]
    return flat.reshape(-1)[: qg.n].reshape(x.shape)
