"""AdamW + schedules + global-norm clipping (pure JAX, no optax).

Moments can be stored in bf16 (``moment_dtype``) — at 671B-over-512-chips
scale the optimizer state is the HBM budget, see EXPERIMENTS.md §Dry-run.
Weight decay is masked off 1-D leaves (norm scales, biases) by default.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "AdamWConfig",
    "AdamWState",
    "init_adamw",
    "adamw_update",
    "global_norm",
    "clip_by_global_norm",
    "cosine_schedule",
    "linear_warmup_cosine",
]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moment_dtype: str = "float32"


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def _decay_mask(params):
    # decay everything except 1-D leaves (norm scales / biases)
    return jax.tree.map(lambda p: p.ndim > 1, params)


def init_adamw(params, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(
    params, grads, state: AdamWState, cfg: AdamWConfig,
    lr_schedule: Optional[Callable[[jax.Array], jax.Array]] = None,
):
    """Returns (new_params, new_state, metrics)."""
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = cfg.lr if lr_schedule is None else lr_schedule(step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    mask = _decay_mask(params)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v, decay):
        g32 = g.astype(jnp.float32)
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    flat_mask = jax.tree.leaves(mask)
    out = [upd(p, g, m, v, dk) for p, g, m, v, dk in
           zip(flat_p, flat_g, flat_m, flat_v, flat_mask)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_p, AdamWState(step, new_m, new_v), metrics


def cosine_schedule(base_lr: float, total_steps: int, min_frac: float = 0.1):
    def fn(step):
        t = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        return base_lr * (min_frac + (1 - min_frac) * 0.5 *
                          (1 + jnp.cos(jnp.pi * t)))
    return fn


def linear_warmup_cosine(base_lr: float, warmup: int, total_steps: int,
                         min_frac: float = 0.1):
    cos = cosine_schedule(base_lr, max(total_steps - warmup, 1), min_frac)
    def fn(step):
        s = step.astype(jnp.float32)
        warm = base_lr * s / max(warmup, 1)
        return jnp.where(step <= warmup, warm, cos(step - warmup))
    return fn
