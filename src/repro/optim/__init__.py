from .adamw import (
    AdamWConfig,
    AdamWState,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
    init_adamw,
    linear_warmup_cosine,
)
from .compression import (
    QuantizedGrad,
    compressed_psum,
    dequantize_int8,
    ef_compress_tree,
    init_error_buffers,
    quantize_int8,
)

__all__ = [
    "AdamWConfig",
    "AdamWState",
    "QuantizedGrad",
    "adamw_update",
    "clip_by_global_norm",
    "compressed_psum",
    "cosine_schedule",
    "dequantize_int8",
    "ef_compress_tree",
    "global_norm",
    "init_adamw",
    "init_error_buffers",
    "linear_warmup_cosine",
    "quantize_int8",
]
