"""Shard-aware batch pipeline with exact skip-ahead resume.

The iterator is stateless modulo the step counter: ``batch_at(step)`` is a
pure function, so resume-after-restart and elastic re-sharding replay the
exact token stream. ``host_local_batch`` slices the global batch to the
rows this host owns under the active mesh (multi-host jax.Array assembly
via ``jax.make_array_from_process_local_data`` in a real pod; on a single
process it degenerates to the global batch).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax

from .synthetic import lm_batch

__all__ = ["DataConfig", "DataPipeline"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int
    global_batch: int
    seq_len: int
    vocab: int
    input_kind: str = "tokens"        # tokens | embeds | encdec
    d_model: int = 0                  # for stub-frontend archs


class DataPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        c = self.cfg
        batch = lm_batch(c.seed, step, c.global_batch, c.seq_len, c.vocab)
        if c.input_kind == "embeds":
            key = jax.random.fold_in(jax.random.PRNGKey(c.seed), step)
            emb = 0.02 * jax.random.normal(
                key, (c.global_batch, c.seq_len, c.d_model)
            )
            return {"embeds": emb, "labels": batch["labels"]}
        if c.input_kind == "encdec":
            key = jax.random.fold_in(jax.random.PRNGKey(c.seed), step)
            enc = 0.02 * jax.random.normal(
                key, (c.global_batch, c.seq_len, c.d_model)
            )
            return {"enc_embeds": enc, **batch}
        return batch

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, jax.Array]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1
