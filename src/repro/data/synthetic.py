"""Deterministic synthetic data: token streams + the paper's point clouds.

Everything is a pure function of (seed, step, shard), so any host can
regenerate any batch — this is what makes checkpoint-resume and elastic
re-sharding exact (no data-loader state to save).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "token_batch",
    "lm_batch",
    "gaussian_clouds",
    "sphere_clouds",
    "highdim_clouds",
]


def _fold(seed: int, *ints: int) -> jax.Array:
    key = jax.random.PRNGKey(seed)
    for i in ints:
        key = jax.random.fold_in(key, i)
    return key


def token_batch(seed: int, step: int, batch: int, seq: int, vocab: int,
                shard: int = 0) -> jax.Array:
    """Markov-ish synthetic tokens (correlated, so CE actually decreases)."""
    key = _fold(seed, step, shard)
    k1, k2 = jax.random.split(key)
    base = jax.random.randint(k1, (batch, seq), 0, vocab)
    # induce local structure: with p=0.5 copy the previous token + 1
    rep = jax.random.bernoulli(k2, 0.5, (batch, seq))
    shifted = jnp.roll(base, 1, axis=1)
    toks = jnp.where(rep, (shifted + 1) % vocab, base)
    return toks.astype(jnp.int32)


def lm_batch(seed: int, step: int, batch: int, seq: int, vocab: int,
             shard: int = 0) -> Dict[str, jax.Array]:
    toks = token_batch(seed, step, batch, seq + 1, vocab, shard)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


# ---- the paper's experimental settings (Figures 1, 3, 5) ----


def gaussian_clouds(seed: int, n: int, d: int = 2) -> Tuple[jax.Array, jax.Array]:
    """Fig. 1: N((1,..), I) vs N(0, 0.1 I) in R^d."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (n, d)) + 1.0
    y = jnp.sqrt(0.1) * jax.random.normal(k2, (n, d))
    return x, y


def sphere_clouds(seed: int, n: int) -> Tuple[jax.Array, jax.Array]:
    """Fig. 2/3: two von-Mises-ish caps on the unit sphere in R^3."""
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)

    def cap(key_dir, key_noise, center):
        v = 0.35 * jax.random.normal(key_noise, (n, 3)) + center
        return v / jnp.linalg.norm(v, axis=1, keepdims=True)

    x = cap(k1, k2, jnp.array([1.0, 0.0, 0.0]))
    y = cap(k3, k4, jnp.array([-0.5, 0.8, 0.0]))
    return x, y


def highdim_clouds(seed: int, n: int, d: int = 28) -> Tuple[jax.Array, jax.Array]:
    """Fig. 5 stand-in for the Higgs dataset: two anisotropic Gaussians in
    R^28 (signal/background surrogate; offline container has no downloads)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    A = 0.5 * jax.random.normal(k3, (d, d)) / jnp.sqrt(d)
    x = jax.random.normal(k1, (n, d)) @ (jnp.eye(d) + A)
    y = jax.random.normal(k2, (n, d)) - 0.5
    return x, y
