from .pipeline import DataConfig, DataPipeline
from .synthetic import (
    gaussian_clouds,
    highdim_clouds,
    lm_batch,
    sphere_clouds,
    token_batch,
)

__all__ = [
    "DataConfig",
    "DataPipeline",
    "gaussian_clouds",
    "highdim_clouds",
    "lm_batch",
    "sphere_clouds",
    "token_batch",
]
