"""Sharded, atomic, resumable checkpoints (no orbax in the container).

Layout:  <dir>/step_<N>/shard_<host>.npz  +  <dir>/step_<N>/COMMIT
Writes go to ``step_<N>.tmp`` and are renamed only after every array and
the manifest are flushed — a killed save can never corrupt the latest
checkpoint (crash-consistency test in tests/test_checkpoint.py). Restore
picks the newest COMMITted step. On a multi-host pod each host saves the
addressable shards of its jax.Arrays; here (single process) that is the
whole tree.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["CheckpointManager"]


_NATIVE_KINDS = set("fiub")


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in _NATIVE_KINDS:
            # ml_dtypes (bf16/f8) don't survive np.savez; widen to f32 —
            # restore() casts back to the template leaf dtype losslessly.
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 host_id: int = 0, async_save: bool = False):
        self.dir = directory
        self.keep = keep
        self.host_id = host_id
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ---------- save ----------

    def save(self, step: int, tree: Any, *, extra: Optional[Dict] = None):
        if self.async_save:
            self.wait()
            # snapshot to host memory before handing off to the thread
            flat = _flatten(tree)
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, extra or {})
            )
            self._thread.start()
        else:
            self._write(step, _flatten(tree), extra or {})

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: Dict[str, np.ndarray], extra: Dict):
        final = os.path.join(self.dir, f"step_{step:09d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, f"shard_{self.host_id}.npz"), **flat)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "extra": extra,
                       "n_arrays": len(flat)}, f)
        with open(os.path.join(tmp, "COMMIT"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # ---------- restore ----------

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "COMMIT")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int], template: Any
                ) -> Tuple[Any, Dict]:
        """Restore into the structure (and dtypes/shardings) of template."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:09d}")
        data = np.load(os.path.join(path, f"shard_{self.host_id}.npz"))
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        paths, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for p, leaf in paths:
            key = "/".join(str(x) for x in p)
            arr = data[key]
            leaves.append(
                jax.device_put(arr.astype(leaf.dtype))
                if hasattr(leaf, "dtype") else arr
            )
        return jax.tree_util.tree_unflatten(treedef, leaves), manifest
