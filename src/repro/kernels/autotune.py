"""Measured block-shape autotuner for the Pallas kernels.

``tiling.pick_block`` is a static heuristic: smallest lane multiple
covering the axis, capped at a hand-picked constant. That single constant
cannot be right across (n, r, dtype, backend) — interpret mode wants few
large blocks (per-block Python overhead dominates), a TPU wants
MXU-saturating tiles inside VMEM, Triton wants power-of-two tiles sized to
shared memory. This module makes the static pick the tuner's PRIOR rather
than the policy:

  * at first use of a ``(kernel, extents, dtype, backend)`` key the tuner
    times a small candidate grid of block shapes (lane-multiple powers of
    two around the static pick, the static pick always included) on real
    device buffers — median of 3 timed calls after a warmup — and caches
    the winner,
  * winners persist to a version-stamped JSON cache
    (``~/.cache/repro/tuning.json``, override via ``REPRO_TUNING_CACHE``)
    so a fresh process re-times nothing; corrupt or stale-version cache
    files are ignored and rewritten,
  * ``deterministic`` mode (the default — tuning is opt-in via
    ``REPRO_TUNE=1`` or :func:`configure`) skips all timing and returns
    exactly the static ``pick_block`` plan, so CI and tests stay
    reproducible.

The per-kernel PRIOR table below is also the single home of per-kernel cap
overrides (the fused feature map's n-cap of 256 used to be hardcoded in
``feature_map.py``) — no kernel carries private tiling constants anymore.

Kernel modules register a *runner factory* per kernel name: the tuner asks
it for a closure that executes the kernel once at given block sizes on
synthetic device buffers of the keyed extents. Registration happens at
kernel-module import, so there is no import cycle (this module never
imports the kernels).

``stats()`` exposes the trial/hit counters the CI ``tune-smoke`` job
asserts on: a second run against a warm cache must perform ZERO timing
trials.
"""
from __future__ import annotations

import contextlib
import itertools
import json
import os
import statistics
import time
from typing import Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from .backend import Backend, resolve_backend
from .tiling import LANE, pick_block, round_up

__all__ = [
    "CACHE_VERSION",
    "CACHE_ENV",
    "TUNE_ENV",
    "candidates",
    "cache_path",
    "clear_cache",
    "configure",
    "register_runner",
    "resolve",
    "reset_stats",
    "static_plan",
    "stats",
    "tuning",
    "tuning_enabled",
]

CACHE_VERSION = 1
CACHE_ENV = "REPRO_TUNING_CACHE"
TUNE_ENV = "REPRO_TUNE"
_DEFAULT_CACHE = os.path.join("~", ".cache", "repro", "tuning.json")

# ---------------------------------------------------------------------------
# Prior table: per-kernel, per-block-axis (extent key, cap). This is the
# static pick_block policy, owned in ONE place — kernels resolve through
# static_plan()/resolve() and carry no private tiling constants.
# ---------------------------------------------------------------------------

# axis spec: (extent name, cap, sequential-reduction axis?) — a seq axis is
# accumulated across grid steps inside the kernel, which parallel-grid
# (Triton) backends cannot do: there the axis is forced to a single block.
PRIORS: Dict[str, Dict[str, Tuple[str, int, bool]]] = {
    # t = Xi^T u — n is the reduction axis, but the gpu lowering uses the
    # split-k variant (per-cell partials), so n blocking stays free.
    "feature_contract": {
        "block_n": ("n", 512, False),
        "block_r": ("r", 512, False),
    },
    # Xi @ t (+ fused divide): one grid axis over rows, r rides whole.
    "feature_rows": {
        "block_n": ("n", 512, False),
    },
    # LSE twins of the two above.
    "log_contract": {
        "block_n": ("n", 512, False),
        "block_r": ("r", 512, False),
    },
    "log_rows": {
        "block_m": ("m", 512, False),
    },
    # fused Gaussian feature map: n-cap 256 keeps the working set
    # (bn*bd + br*bd + bn*br floats) under ~2 MiB — the cap that used to
    # live as a hardcoded pick_block(n, cap=256) inside feature_map.py.
    # d is a sequential accumulation axis (single block on Triton).
    "feature_map": {
        "block_n": ("n", 256, False),
        "block_r": ("r", 512, False),
        "block_d": ("d", 512, True),
    },
}

_RUNNERS: Dict[str, Callable] = {}

_STATS = {
    "trials": 0,        # timed candidate executions (warmups excluded)
    "keys_tuned": 0,    # keys resolved by fresh timing
    "memory_hits": 0,   # keys served from the in-process cache
    "disk_hits": 0,     # keys served from the persisted JSON cache
    "static": 0,        # keys served deterministically (tuning off)
}

_CONFIG: Dict[str, Optional[object]] = {
    "deterministic": None,   # None -> env REPRO_TUNE decides
    "cache_path": None,      # None -> env REPRO_TUNING_CACHE or default
}

_MEMORY: Dict[str, Dict[str, int]] = {}
_DISK: Optional[Dict[str, Dict[str, int]]] = None   # lazy-loaded file copy


# ---------------------------------------------------------------------------
# Configuration / stats
# ---------------------------------------------------------------------------


def configure(*, deterministic: Optional[bool] = None,
              cache_path: Optional[str] = None,
              _reset: bool = False) -> dict:
    """Set tuner policy; returns the previous config for restoration.
    ``deterministic=False`` enables measured tuning; ``None`` defers to
    the ``REPRO_TUNE`` env var (tuning on iff ``"1"``)."""
    previous = dict(_CONFIG)
    if _reset:
        _CONFIG.update(deterministic=None, cache_path=None)
    if deterministic is not None or _reset:
        _CONFIG["deterministic"] = deterministic
    if cache_path is not None or _reset:
        _CONFIG["cache_path"] = cache_path
        _invalidate_disk()
    return previous


def tuning_enabled() -> bool:
    det = _CONFIG["deterministic"]
    if det is not None:
        return not det
    return os.environ.get(TUNE_ENV, "0") == "1"


@contextlib.contextmanager
def tuning(*, deterministic: bool = False,
           cache_path: Optional[str] = None):
    """Scoped tuner policy: ``with autotune.tuning(cache_path=p): ...``."""
    previous = configure(deterministic=deterministic, cache_path=cache_path)
    try:
        yield
    finally:
        _CONFIG.update(previous)
        _invalidate_disk()


def stats() -> dict:
    return dict(_STATS)


def reset_stats() -> None:
    for k in _STATS:
        _STATS[k] = 0


def clear_cache(*, memory: bool = True, disk_copy: bool = True) -> None:
    """Drop the in-process caches. ``disk_copy=True`` also forgets the
    loaded file contents, so the next resolve re-reads the cache file —
    tests use this to simulate a fresh process."""
    if memory:
        _MEMORY.clear()
    if disk_copy:
        _invalidate_disk()


def cache_path() -> str:
    path = _CONFIG["cache_path"] or os.environ.get(CACHE_ENV) \
        or _DEFAULT_CACHE
    return os.path.expanduser(str(path))


def register_runner(kernel: str, factory: Callable) -> None:
    """Register ``factory(extents, dtype, backend) -> run(blocks)`` for a
    kernel name; ``run`` executes the kernel once, blocking on the result.
    Called by the kernel modules at import time."""
    _RUNNERS[kernel] = factory


# ---------------------------------------------------------------------------
# Static prior + candidate generation
# ---------------------------------------------------------------------------


def static_plan(kernel: str, extents: Dict[str, int],
                backend: Optional[Backend] = None) -> Dict[str, int]:
    """Today's ``pick_block`` answer for every block axis of ``kernel`` —
    the deterministic plan and the tuner's prior. Sequential-reduction
    axes collapse to a single whole-axis block on split-reduce backends
    (the Triton constraint)."""
    axes = PRIORS[kernel]
    plan = {}
    for block_name, (extent_name, cap, seq) in axes.items():
        size = int(extents[extent_name])
        if seq and backend is not None and backend.split_reduce:
            plan[block_name] = round_up(max(size, 1), LANE)
        else:
            plan[block_name] = pick_block(size, cap=cap)
    return plan


def candidates(kernel: str, extents: Dict[str, int],
               backend: Optional[Backend] = None,
               limit: int = 8) -> Tuple[Dict[str, int], ...]:
    """The candidate block plans timed for one key: a power-of-two grid
    around the static pick per axis (halved / doubled, clamped to
    [lane, padded extent]), cross-producted and truncated to ``limit``
    with the static plan always first — so the measured winner can never
    lose to the prior."""
    axes = PRIORS[kernel]
    prior = static_plan(kernel, extents, backend)
    options = []
    names = list(axes)
    for block_name in names:
        extent_name, _cap, seq = axes[block_name]
        size = int(extents[extent_name])
        p = prior[block_name]
        if seq and backend is not None and backend.split_reduce:
            options.append([p])        # single-block constraint
            continue
        padded = round_up(max(size, 1), LANE)
        vals = [p]
        if p // 2 >= LANE:
            vals.append(p // 2)
        if p * 2 <= padded:
            vals.append(p * 2)
        options.append(vals)
    plans = []
    for combo in itertools.product(*options):
        plan = dict(zip(names, combo))
        if plan not in plans:
            plans.append(plan)
    # static plan first (it is options[*][0]), then nearest variations
    return tuple(plans[:limit])


# ---------------------------------------------------------------------------
# Persistent cache
# ---------------------------------------------------------------------------


def _invalidate_disk() -> None:
    global _DISK
    _DISK = None


def _load_disk() -> Dict[str, Dict[str, int]]:
    global _DISK
    if _DISK is not None:
        return _DISK
    entries: Dict[str, Dict[str, int]] = {}
    try:
        with open(cache_path()) as fh:
            payload = json.load(fh)
        if (isinstance(payload, dict)
                and payload.get("version") == CACHE_VERSION
                and isinstance(payload.get("entries"), dict)):
            for key, entry in payload["entries"].items():
                blocks = entry.get("blocks") if isinstance(entry, dict) \
                    else None
                if isinstance(blocks, dict) and all(
                        isinstance(v, int) for v in blocks.values()):
                    entries[key] = {k: int(v) for k, v in blocks.items()}
        # corrupt payloads / stale versions fall through with entries={}
    except (OSError, ValueError):
        pass
    _DISK = entries
    return entries


def _persist(key: str, blocks: Dict[str, int], us: float) -> None:
    path = cache_path()
    payload = {"version": CACHE_VERSION, "entries": {}}
    try:
        with open(path) as fh:
            existing = json.load(fh)
        if (isinstance(existing, dict)
                and existing.get("version") == CACHE_VERSION
                and isinstance(existing.get("entries"), dict)):
            payload["entries"].update(existing["entries"])
    except (OSError, ValueError):
        pass
    payload["entries"][key] = {"blocks": blocks, "us": round(us, 2)}
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as fh:
            json.dump(payload, fh, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        return      # read-only cache dir: keep the in-process winner only
    disk = _load_disk()
    disk[key] = dict(blocks)


def _key(kernel: str, extents: Dict[str, int], dtype,
         backend: Backend) -> str:
    parts = [f"{k}={int(v)}" for k, v in sorted(extents.items())]
    return "|".join(
        [kernel, *parts, f"dtype={jnp.dtype(dtype).name}",
         f"backend={backend.name}", f"v{CACHE_VERSION}"]
    )


# ---------------------------------------------------------------------------
# Timing
# ---------------------------------------------------------------------------


def _time_plan(run: Callable, blocks: Dict[str, int],
               reps: int = 3) -> float:
    run(blocks)                      # warmup / compile (uncounted)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        run(blocks)
        ts.append(time.perf_counter() - t0)
    _STATS["trials"] += 1
    return statistics.median(ts)


def _tune(kernel: str, extents: Dict[str, int], dtype,
          backend: Backend) -> Dict[str, int]:
    factory = _RUNNERS.get(kernel)
    plans = candidates(kernel, extents, backend)
    if factory is None or len(plans) == 1:
        _STATS["static"] += 1
        return static_plan(kernel, extents, backend)
    run = factory(extents, dtype, backend)
    best_plan, best_t = None, None
    for plan in plans:
        t = _time_plan(run, plan)
        if best_t is None or t < best_t:
            best_plan, best_t = plan, t
    _STATS["keys_tuned"] += 1
    _persist(_key(kernel, extents, dtype, backend), best_plan,
             best_t * 1e6)
    return best_plan


# ---------------------------------------------------------------------------
# Public resolution entry point
# ---------------------------------------------------------------------------


def resolve(kernel: str, extents: Dict[str, int], dtype=jnp.float32,
            backend: Optional[Union[Backend, str]] = None,
            *, deterministic: Optional[bool] = None) -> Dict[str, int]:
    """Block plan for one kernel call: the measured winner when tuning is
    enabled (in-process cache, then the persisted JSON cache, then a fresh
    timing pass), else exactly the static ``pick_block`` prior.

    Called at trace time by the kernel wrappers (block sizes are static),
    so a jitted solver tunes on its first trace per shape and replays the
    cached plan afterwards. Timing runs on synthetic device buffers built
    from the keyed extents — never on the (possibly traced) runtime
    arrays.
    """
    be = resolve_backend(backend)
    det = (not tuning_enabled()) if deterministic is None else deterministic
    if det:
        _STATS["static"] += 1
        return static_plan(kernel, extents, be)
    key = _key(kernel, extents, dtype, be)
    hit = _MEMORY.get(key)
    if hit is not None:
        _STATS["memory_hits"] += 1
        return dict(hit)
    disk = _load_disk().get(key)
    if disk is not None and set(disk) == set(PRIORS[kernel]):
        _STATS["disk_hits"] += 1
        _MEMORY[key] = dict(disk)
        return dict(disk)
    plan = _tune(kernel, extents, dtype, be)
    _MEMORY[key] = dict(plan)
    return dict(plan)


def resolve_blocks(kernel: str, extents: Dict[str, int],
                   given: Dict[str, Optional[int]], dtype,
                   interpret: bool,
                   backend: Optional[Backend] = None) -> Dict[str, int]:
    """Kernel-wrapper helper: fill the ``block_* = None`` holes in
    ``given`` through :func:`resolve`, honoring explicit overrides."""
    if all(v is not None for v in given.values()):
        return {k: int(v) for k, v in given.items()}
    be = backend if backend is not None \
        else resolve_backend("interpret" if interpret else None)
    plan = resolve(kernel, extents, dtype, be)
    return {k: int(v) if v is not None else plan[k]
            for k, v in given.items()}


def _synthetic(shape, dtype, *, log: bool = False) -> jax.Array:
    """Deterministic device buffer for timing (contents are irrelevant to
    kernel runtime; values stay finite/positive for both domains)."""
    x = jnp.full(shape, 0.5, jnp.dtype(dtype))
    return jax.device_put(x if not log else x - 1.0)
