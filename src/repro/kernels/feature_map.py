"""Pallas kernel: fused Gaussian positive-feature map (Lemma 1).

Computes  Xi[i, k] = exp( c_k - (2/eps) * ||x_i - u_k||^2 )  without ever
materializing the (n, r) squared-distance matrix in HBM: the MXU produces
the x.u block, the VPU applies the rank-1 norm corrections and the exp, and
only the finished Xi tile is written back.

``log_space=True`` skips the exp in the epilogue and emits ``log Xi``
directly — the small-eps path, where the features themselves would
under/overflow f32 and the log-domain solver consumes ``log Xi`` through
the fused LSE kernels (``logmatvec``). Padded anchors carry
``log_const = -inf`` so their log-features are exactly ``-inf`` (the LSE
identity) and their linear features exactly 0.

Tiling: grid (n/bn, r/br, d/bd). The d axis is the innermost SEQUENTIAL
grid dimension — the x.u partial products accumulate in the f32 output
tile, and the epilogue on the last d-step applies norms (+ exp) in place.
That accumulation is a Mosaic-only idiom: on parallel-grid backends
(Triton) the d axis must ride in ONE block (``d_steps == 1``, enforced by
the tuner's single-block constraint for sequential axes), and point
dimensions too large for that refuse into the XLA feature map at the plan
layer (``backend.fused_map_max_d`` / ``kernels.backend.fused_map_admissible``)
rather than silently interpreting.

Block sizes resolve ``block_* = None`` through ``kernels.autotune``; the
n-cap of 256 that used to be hardcoded here now lives in the tuner's PRIOR
table (working set per step: bn*bd + br*bd + bn*br floats — caps
(256, 512, 512) keep it < 2 MiB, comfortably inside VMEM with double
buffering). Resolution happens OUTSIDE the jitted impl so the chosen
blocks are part of the jit cache key.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import autotune
from .backend import Backend
from .tiling import pad_axis

__all__ = ["gaussian_feature_map_kernel", "gaussian_feature_map_pallas"]


def gaussian_feature_map_kernel(
    x_ref, u_ref, x2_ref, u2c_ref, o_ref, *, inv_eps: float, d_steps: int,
    log_space: bool,
):
    """One (bn, br) output tile; accumulates over the d grid axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU: partial inner products x_blk @ u_blk^T, accumulated in-place.
    o_ref[...] += jax.lax.dot_general(
        x_ref[...],
        u_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == d_steps - 1)
    def _epilogue():
        dot = o_ref[...]
        # u2c packs  c_k - 2/eps * ||u_k||^2  (precombined in the wrapper);
        # x2 is ||x_i||^2.  log Xi = u2c - 2/eps * x2 + 4/eps * dot.
        log_xi = (
            u2c_ref[...]
            - (2.0 * inv_eps) * x2_ref[...]
            + (4.0 * inv_eps) * dot
        )
        o_ref[...] = log_xi if log_space else jnp.exp(log_xi)


@functools.partial(
    jax.jit,
    static_argnames=(
        "inv_eps", "block_n", "block_r", "block_d", "interpret", "log_space",
    ),
)
def _feature_map_impl(
    x: jax.Array,           # (n, d)
    anchors: jax.Array,     # (r, d)
    log_const: jax.Array,   # (r,)
    *,
    inv_eps: float,
    block_n: int,
    block_r: int,
    block_d: int,
    interpret: bool,
    log_space: bool,
) -> jax.Array:
    n, d = x.shape
    r = anchors.shape[0]
    # pad: zero-rows of x are sliced away; padded anchors get log_const=-inf
    # so their features are exactly 0 (or -inf log-features) and harmless to
    # downstream contractions / LSEs.
    xp = pad_axis(pad_axis(x, 0, block_n), 1, block_d)
    up = pad_axis(pad_axis(anchors, 0, block_r), 1, block_d)
    cp = pad_axis(log_const, 0, block_r, value=-jnp.inf)
    npad, dpad = xp.shape
    rpad = up.shape[0]

    x2 = jnp.sum(xp * xp, axis=-1, keepdims=True)            # (npad, 1)
    u2 = jnp.sum(up * up, axis=-1)                           # (rpad,)
    u2c = (cp - 2.0 * inv_eps * u2)[None, :]                 # (1, rpad)

    grid = (npad // block_n, rpad // block_r, dpad // block_d)
    out = pl.pallas_call(
        functools.partial(
            gaussian_feature_map_kernel, inv_eps=inv_eps, d_steps=grid[2],
            log_space=log_space,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_d), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_r, block_d), lambda i, j, k: (j, k)),
            pl.BlockSpec((block_n, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, block_r), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_r), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((npad, rpad), jnp.float32),
        interpret=interpret,
    )(xp, up, x2, u2c)
    return out[:n, :r]


def gaussian_feature_map_pallas(
    x: jax.Array,           # (n, d)
    anchors: jax.Array,     # (r, d)
    log_const: jax.Array,   # (r,) per-anchor offset (incl. -0.5 log r)
    *,
    inv_eps: float,
    block_n: Optional[int] = None,
    block_r: Optional[int] = None,
    block_d: Optional[int] = None,
    interpret: bool = False,
    log_space: bool = False,
    backend: Optional[Backend] = None,
) -> jax.Array:
    n, d = x.shape
    r = anchors.shape[0]
    blocks = autotune.resolve_blocks(
        "feature_map", {"n": n, "r": r, "d": d},
        {"block_n": block_n, "block_r": block_r, "block_d": block_d},
        x.dtype, interpret, backend)
    return _feature_map_impl(
        x, anchors, log_const, inv_eps=inv_eps, interpret=interpret,
        log_space=log_space, **blocks)


def _feature_map_runner(extents, dtype, backend):
    x = autotune._synthetic((extents["n"], extents["d"]), dtype)
    u = autotune._synthetic((extents["r"], extents["d"]), dtype)
    c = autotune._synthetic((extents["r"],), jnp.float32, log=True)

    def run(blocks):
        jax.block_until_ready(
            _feature_map_impl(x, u, c, inv_eps=1.0,
                              interpret=backend.interpret, log_space=False,
                              **blocks))

    return run


autotune.register_runner("feature_map", _feature_map_runner)
