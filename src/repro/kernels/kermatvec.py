"""Pallas TPU kernels for the factored-kernel Sinkhorn half-step.

One half-step  v <- b / (Zeta (Xi^T u))  splits into:

  phase 1  feature_contract : t = Xi^T u        (r, B) — reduction over n
  phase 2  sinkhorn_halfstep: v = b / (Zeta t)  (m, B) — matvec + divide FUSED

Fusing the marginal divide into phase 2 saves an HBM round-trip of the
(m, B) product — on a v5e at 819 GB/s that round-trip is the dominant cost
of the whole iteration once r is small (the op is memory-bound; see
EXPERIMENTS.md §Perf napkin math).

The batch dim B (independent Sinkhorn problems — GAN minibatch pairs) rides
whole in both kernels; the MXU sees (bn x r) @ (r x B) tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "feature_contract_pallas",
    "sinkhorn_halfstep_pallas",
]


def _feature_contract_kernel(xi_ref, u_ref, t_ref):
    """t += Xi_blk^T u_blk; n is the innermost (sequential) grid axis."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        t_ref[...] = jnp.zeros_like(t_ref)

    t_ref[...] += jax.lax.dot_general(
        xi_ref[...],
        u_ref[...],
        (((0,), (0,)), ((), ())),          # contract the n axis
        preferred_element_type=jnp.float32,
    )


def _pad0(arr, mult, value=0.0):
    pad = (-arr.shape[0]) % mult
    if pad == 0:
        return arr
    widths = [(0, pad)] + [(0, 0)] * (arr.ndim - 1)
    return jnp.pad(arr, widths, constant_values=value)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_r", "interpret")
)
def feature_contract_pallas(
    xi: jax.Array,          # (n, r)
    u: jax.Array,           # (n, B)
    *,
    block_n: int = 512,
    block_r: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """t = Xi^T u, shape (r, B). Zero-padded rows contribute nothing."""
    n, r = xi.shape
    B = u.shape[1]
    xp = _pad0(xi, block_n)
    up = _pad0(u, block_n)
    rpad = (-r) % block_r
    if rpad:
        xp = jnp.pad(xp, ((0, 0), (0, rpad)))
    grid = (xp.shape[1] // block_r, xp.shape[0] // block_n)
    t = pl.pallas_call(
        _feature_contract_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_r), lambda i, j: (j, i)),
            pl.BlockSpec((block_n, B), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, B), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[1], B), jnp.float32),
        interpret=interpret,
    )(xp, up)
    return t[:r]


def _halfstep_kernel(xi_ref, t_ref, marg_ref, o_ref):
    """o = marg / (Xi_blk @ t) — matvec + divide in one VMEM pass."""
    kv = jax.lax.dot_general(
        xi_ref[...],
        t_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = marg_ref[...] / kv


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def sinkhorn_halfstep_pallas(
    xi: jax.Array,          # (n, r) features of the side being updated
    t: jax.Array,           # (r, B)
    marg: jax.Array,        # (n, B)
    *,
    block_n: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """out = marg / (Xi @ t), shape (n, B). r rides whole in VMEM (r<=4096)."""
    n, r = xi.shape
    B = marg.shape[1]
    xp = _pad0(xi, block_n)
    # padded rows: marg=1 so the divide yields finite garbage we slice away
    mp = _pad0(marg, block_n, value=1.0)
    grid = (xp.shape[0] // block_n,)
    out = pl.pallas_call(
        _halfstep_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, r), lambda i: (i, 0)),
            pl.BlockSpec((r, B), lambda i: (0, 0)),
            pl.BlockSpec((block_n, B), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, B), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], B), jnp.float32),
        interpret=interpret,
    )(xp, t, mp)
    return out[:n]
