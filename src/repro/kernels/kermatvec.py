"""Pallas kernels for the factored-kernel Sinkhorn half-step.

One half-step  v <- b / (Zeta (Xi^T u))  splits into:

  phase 1  feature_contract : t = Xi^T u        (r, B) — reduction over n
  phase 2  sinkhorn_halfstep: v = b / (Zeta t)  (m, B) — matvec + divide FUSED

Fusing the marginal divide into phase 2 saves an HBM round-trip of the
(m, B) product — on a v5e at 819 GB/s that round-trip is the dominant cost
of the whole iteration once r is small (the op is memory-bound; see
EXPERIMENTS.md §Perf napkin math).

``feature_matvec_pallas`` is phase 2 WITHOUT the divide — the solver's
convergence check needs the raw column marginal ``K^T u`` once per
iteration, and it reuses the same tiling.

The batch dim B (independent Sinkhorn problems — GAN minibatch pairs) rides
whole in both kernels; the MXU sees (bn x r) @ (r x B) tiles. All trailing
dims (r, B) are padded to lane multiples via ``kernels.tiling`` with
neutral fills (0 for features/scalings, 1 for marginals feeding a divide)
and sliced back.

Backends: phase 2 is one parallel grid axis over rows — it lowers on both
Mosaic (TPU) and Triton (GPU) unchanged. Phase 1 accumulates across the n
grid axis into a revisited output block, which is a sequential-grid idiom
only Mosaic supports; ``split_reduce=True`` selects the split-k variant
(each grid cell writes its own partial slot, XLA sums the slots) that
parallel-grid backends can lower. Block sizes resolve ``block_* = None``
through ``kernels.autotune`` (static ``pick_block`` prior, measured winner
when tuning is enabled); resolution happens OUTSIDE the jitted impls so
the chosen blocks are part of the jit cache key.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import autotune
from .backend import Backend
from .tiling import LANE, compute_f32 as _f32, pad_axis

__all__ = [
    "feature_contract_pallas",
    "sinkhorn_halfstep_pallas",
    "feature_matvec_pallas",
]


def _feature_contract_kernel(xi_ref, u_ref, t_ref):
    """t += Xi_blk^T u_blk; n is the innermost (sequential) grid axis."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        t_ref[...] = jnp.zeros_like(t_ref)

    t_ref[...] += jax.lax.dot_general(
        _f32(xi_ref[...]),
        u_ref[...],
        (((0,), (0,)), ((), ())),          # contract the n axis
        preferred_element_type=jnp.float32,
    )


def _feature_contract_splitk_kernel(xi_ref, u_ref, t_ref):
    """Split-k twin: grid cell (i, j) writes its OWN (1, br, B) partial —
    no cross-program accumulation, so the kernel lowers on parallel-grid
    backends (Triton CTAs) where revisiting an output block is a race."""
    t_ref[...] = jax.lax.dot_general(
        _f32(xi_ref[...]),
        u_ref[...],
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[None]


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_r", "interpret")
)
def _feature_contract_impl(
    xi: jax.Array,          # (n, r)
    u: jax.Array,           # (n, B)
    *,
    block_n: int,
    block_r: int,
    interpret: bool,
) -> jax.Array:
    n, r = xi.shape
    B = u.shape[1]
    xp = pad_axis(pad_axis(xi, 0, block_n), 1, block_r)
    up = pad_axis(pad_axis(u, 0, block_n), 1, LANE)
    Bp = up.shape[1]
    grid = (xp.shape[1] // block_r, xp.shape[0] // block_n)
    t = pl.pallas_call(
        _feature_contract_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_r), lambda i, j: (j, i)),
            pl.BlockSpec((block_n, Bp), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, Bp), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[1], Bp), jnp.float32),
        interpret=interpret,
    )(xp, up)
    return t[:r, :B]


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_r", "interpret")
)
def _feature_contract_splitk_impl(
    xi: jax.Array,
    u: jax.Array,
    *,
    block_n: int,
    block_r: int,
    interpret: bool,
) -> jax.Array:
    n, r = xi.shape
    B = u.shape[1]
    xp = pad_axis(pad_axis(xi, 0, block_n), 1, block_r)
    up = pad_axis(pad_axis(u, 0, block_n), 1, LANE)
    Bp = up.shape[1]
    n_steps = xp.shape[0] // block_n
    grid = (xp.shape[1] // block_r, n_steps)
    partials = pl.pallas_call(
        _feature_contract_splitk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_r), lambda i, j: (j, i)),
            pl.BlockSpec((block_n, Bp), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_r, Bp), lambda i, j: (j, i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_steps, xp.shape[1], Bp),
                                       jnp.float32),
        interpret=interpret,
    )(xp, up)
    # the k-combine runs in XLA: one (n_steps, r, B) sum, race-free
    return jnp.sum(partials, axis=0)[:r, :B]


def feature_contract_pallas(
    xi: jax.Array,          # (n, r)
    u: jax.Array,           # (n, B)
    *,
    block_n: Optional[int] = None,
    block_r: Optional[int] = None,
    interpret: bool = False,
    split_reduce: bool = False,
    backend: Optional[Backend] = None,
) -> jax.Array:
    """t = Xi^T u, shape (r, B). Zero-padded rows/columns contribute 0."""
    n, r = xi.shape
    blocks = autotune.resolve_blocks(
        "feature_contract", {"n": n, "r": r, "B": u.shape[1]},
        {"block_n": block_n, "block_r": block_r}, xi.dtype, interpret,
        backend)
    impl = _feature_contract_splitk_impl if split_reduce \
        else _feature_contract_impl
    return impl(xi, u, interpret=interpret, **blocks)


def _halfstep_kernel(xi_ref, t_ref, marg_ref, o_ref):
    """o = marg / (Xi_blk @ t) — matvec + divide in one VMEM pass."""
    kv = jax.lax.dot_general(
        _f32(xi_ref[...]),
        t_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = marg_ref[...] / kv


def _matvec_kernel(xi_ref, t_ref, o_ref):
    """o = Xi_blk @ t — the divide-free twin (convergence-check marginal)."""
    o_ref[...] = jax.lax.dot_general(
        _f32(xi_ref[...]),
        t_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _matvec_like_call(kernel, xi, t, extra, *, block_n, interpret):
    """Shared tiling for the (n, r) @ (r, B) kernels: r rides whole (lane
    padded), n blocks, B lane padded; returns the (n, B) slice. One
    parallel grid axis over row blocks — lowers on Mosaic AND Triton."""
    n, r = xi.shape
    B = t.shape[1]
    xp = pad_axis(pad_axis(xi, 0, block_n), 1, LANE)
    tp = pad_axis(pad_axis(t, 0, LANE), 1, LANE)
    rp, Bp = tp.shape
    operands = [xp, tp]
    in_specs = [
        pl.BlockSpec((block_n, rp), lambda i: (i, 0)),
        pl.BlockSpec((rp, Bp), lambda i: (0, 0)),
    ]
    if extra is not None:
        operands.append(extra)
        in_specs.append(pl.BlockSpec((block_n, Bp), lambda i: (i, 0)))
    grid = (xp.shape[0] // block_n,)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_n, Bp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], Bp), jnp.float32),
        interpret=interpret,
    )(*operands)
    return out[:n, :B]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _halfstep_impl(xi, t, marg, *, block_n: int, interpret: bool):
    mp = pad_axis(pad_axis(marg, 0, block_n, value=1.0), 1, LANE, value=1.0)
    return _matvec_like_call(_halfstep_kernel, xi, t, mp,
                             block_n=block_n, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _matvec_impl(xi, t, *, block_n: int, interpret: bool):
    return _matvec_like_call(_matvec_kernel, xi, t, None,
                             block_n=block_n, interpret=interpret)


def _rows_blocks(xi, t, block_n, interpret, backend):
    return autotune.resolve_blocks(
        "feature_rows", {"n": xi.shape[0], "r": xi.shape[1],
                         "B": t.shape[1]},
        {"block_n": block_n}, xi.dtype, interpret, backend)


def sinkhorn_halfstep_pallas(
    xi: jax.Array,          # (n, r) features of the side being updated
    t: jax.Array,           # (r, B)
    marg: jax.Array,        # (n, B)
    *,
    block_n: Optional[int] = None,
    interpret: bool = False,
    backend: Optional[Backend] = None,
) -> jax.Array:
    """out = marg / (Xi @ t), shape (n, B). r rides whole in VMEM (r<=4096).

    Padded rows/columns: marg=1 so the divide yields finite garbage (or a
    harmless inf for all-zero feature rows) that the slice discards.
    """
    blocks = _rows_blocks(xi, t, block_n, interpret, backend)
    return _halfstep_impl(xi, t, marg, interpret=interpret, **blocks)


def feature_matvec_pallas(
    xi: jax.Array,          # (n, r)
    t: jax.Array,           # (r, B)
    *,
    block_n: Optional[int] = None,
    interpret: bool = False,
    backend: Optional[Backend] = None,
) -> jax.Array:
    """out = Xi @ t, shape (n, B) — no divide (marginal-check matvec)."""
    blocks = _rows_blocks(xi, t, block_n, interpret, backend)
    return _matvec_impl(xi, t, interpret=interpret, **blocks)


# ---------------------------------------------------------------------------
# Autotuner runners: execute one call at candidate blocks on synthetic
# device buffers of the keyed extents (see kernels.autotune).
# ---------------------------------------------------------------------------


def _contract_runner(extents, dtype, backend):
    xi = autotune._synthetic((extents["n"], extents["r"]), dtype)
    u = autotune._synthetic((extents["n"], extents["B"]), jnp.float32)
    impl = _feature_contract_splitk_impl if backend.split_reduce \
        else _feature_contract_impl

    def run(blocks):
        jax.block_until_ready(
            impl(xi, u, interpret=backend.interpret, **blocks))

    return run


def _rows_runner(extents, dtype, backend):
    xi = autotune._synthetic((extents["n"], extents["r"]), dtype)
    t = autotune._synthetic((extents["r"], extents["B"]), jnp.float32)
    marg = autotune._synthetic((extents["n"], extents["B"]), jnp.float32)

    def run(blocks):
        jax.block_until_ready(
            _halfstep_impl(xi, t, marg, interpret=backend.interpret,
                           **blocks))

    return run


autotune.register_runner("feature_contract", _contract_runner)
autotune.register_runner("feature_rows", _rows_runner)
