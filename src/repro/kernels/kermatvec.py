"""Pallas TPU kernels for the factored-kernel Sinkhorn half-step.

One half-step  v <- b / (Zeta (Xi^T u))  splits into:

  phase 1  feature_contract : t = Xi^T u        (r, B) — reduction over n
  phase 2  sinkhorn_halfstep: v = b / (Zeta t)  (m, B) — matvec + divide FUSED

Fusing the marginal divide into phase 2 saves an HBM round-trip of the
(m, B) product — on a v5e at 819 GB/s that round-trip is the dominant cost
of the whole iteration once r is small (the op is memory-bound; see
EXPERIMENTS.md §Perf napkin math).

``feature_matvec_pallas`` is phase 2 WITHOUT the divide — the solver's
convergence check needs the raw column marginal ``K^T u`` once per
iteration, and it reuses the same tiling.

The batch dim B (independent Sinkhorn problems — GAN minibatch pairs) rides
whole in both kernels; the MXU sees (bn x r) @ (r x B) tiles. All trailing
dims (r, B) are padded to lane multiples via ``kernels.tiling`` with
neutral fills (0 for features/scalings, 1 for marginals feeding a divide)
and sliced back.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tiling import LANE, compute_f32 as _f32, pad_axis, pick_block

__all__ = [
    "feature_contract_pallas",
    "sinkhorn_halfstep_pallas",
    "feature_matvec_pallas",
]


def _feature_contract_kernel(xi_ref, u_ref, t_ref):
    """t += Xi_blk^T u_blk; n is the innermost (sequential) grid axis."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        t_ref[...] = jnp.zeros_like(t_ref)

    t_ref[...] += jax.lax.dot_general(
        _f32(xi_ref[...]),
        u_ref[...],
        (((0,), (0,)), ((), ())),          # contract the n axis
        preferred_element_type=jnp.float32,
    )


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_r", "interpret")
)
def feature_contract_pallas(
    xi: jax.Array,          # (n, r)
    u: jax.Array,           # (n, B)
    *,
    block_n: Optional[int] = None,
    block_r: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """t = Xi^T u, shape (r, B). Zero-padded rows/columns contribute 0."""
    n, r = xi.shape
    B = u.shape[1]
    block_n = pick_block(n) if block_n is None else block_n
    block_r = pick_block(r) if block_r is None else block_r
    xp = pad_axis(pad_axis(xi, 0, block_n), 1, block_r)
    up = pad_axis(pad_axis(u, 0, block_n), 1, LANE)
    Bp = up.shape[1]
    grid = (xp.shape[1] // block_r, xp.shape[0] // block_n)
    t = pl.pallas_call(
        _feature_contract_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_r), lambda i, j: (j, i)),
            pl.BlockSpec((block_n, Bp), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, Bp), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[1], Bp), jnp.float32),
        interpret=interpret,
    )(xp, up)
    return t[:r, :B]


def _halfstep_kernel(xi_ref, t_ref, marg_ref, o_ref):
    """o = marg / (Xi_blk @ t) — matvec + divide in one VMEM pass."""
    kv = jax.lax.dot_general(
        _f32(xi_ref[...]),
        t_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = marg_ref[...] / kv


def _matvec_kernel(xi_ref, t_ref, o_ref):
    """o = Xi_blk @ t — the divide-free twin (convergence-check marginal)."""
    o_ref[...] = jax.lax.dot_general(
        _f32(xi_ref[...]),
        t_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _matvec_like_call(kernel, xi, t, extra, *, block_n, interpret):
    """Shared tiling for the (n, r) @ (r, B) kernels: r rides whole (lane
    padded), n blocks, B lane padded; returns the (n, B) slice."""
    n, r = xi.shape
    B = t.shape[1]
    block_n = pick_block(n) if block_n is None else block_n
    xp = pad_axis(pad_axis(xi, 0, block_n), 1, LANE)
    tp = pad_axis(pad_axis(t, 0, LANE), 1, LANE)
    rp, Bp = tp.shape
    operands = [xp, tp]
    in_specs = [
        pl.BlockSpec((block_n, rp), lambda i: (i, 0)),
        pl.BlockSpec((rp, Bp), lambda i: (0, 0)),
    ]
    if extra is not None:
        operands.append(extra)
        in_specs.append(pl.BlockSpec((block_n, Bp), lambda i: (i, 0)))
    grid = (xp.shape[0] // block_n,)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_n, Bp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], Bp), jnp.float32),
        interpret=interpret,
    )(*operands)
    return out[:n, :B]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def sinkhorn_halfstep_pallas(
    xi: jax.Array,          # (n, r) features of the side being updated
    t: jax.Array,           # (r, B)
    marg: jax.Array,        # (n, B)
    *,
    block_n: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """out = marg / (Xi @ t), shape (n, B). r rides whole in VMEM (r<=4096).

    Padded rows/columns: marg=1 so the divide yields finite garbage (or a
    harmless inf for all-zero feature rows) that the slice discards.
    """
    block_n = pick_block(xi.shape[0]) if block_n is None else block_n
    mp = pad_axis(pad_axis(marg, 0, block_n, value=1.0), 1, LANE, value=1.0)
    return _matvec_like_call(_halfstep_kernel, xi, t, mp,
                             block_n=block_n, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def feature_matvec_pallas(
    xi: jax.Array,          # (n, r)
    t: jax.Array,           # (r, B)
    *,
    block_n: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """out = Xi @ t, shape (n, B) — no divide (marginal-check matvec)."""
    return _matvec_like_call(_matvec_kernel, xi, t, None,
                             block_n=block_n, interpret=interpret)
