"""Pallas kernels for PAGED feature storage: masked matvecs that skip
all-dead pages.

The streaming layer (``repro.streaming``) keeps each distribution's
features in a fixed-capacity buffer carved into pages of ``page_size``
rows; insert/evict mutate pages and flip weights, never array shapes, so
nothing retraces. Dead slots carry zero weight — which every solver masks
exactly — so correctness never depends on the page table. What the page
table buys is a FAST PATH: a per-page liveness vector (``page_live``,
scalar-prefetched into SMEM) lets the kernels predicate whole page blocks
with ``pl.when`` and skip the MXU work for pages with no live slot at all.
A store at 25% occupancy then streams ~25% of the feature bytes per
iteration instead of 100%.

Three kernels mirror the dense trio in ``kermatvec``:

  paged_feature_contract : t = sum over LIVE pages of Xi_p^T u_p   (r, B)
  paged_halfstep         : out_p = marg_p / (Xi_p @ t) on live pages,
                           zeros on dead ones (marg is 0 there anyway)
  paged_feature_matvec   : the divide-free twin (convergence marginal)

All three are ELEMENTWISE equal to their unpaged twins whenever the dead
slots carry zero weight/scaling — property-tested in
``tests/test_streaming.py`` — because a dead slot's u/v is 0 (scaling
space), so a skipped page contributes exactly the 0 the dense kernel would
have computed.

Backend notes: the contract kernel accumulates across the page grid into
one revisited output block — the sequential-grid idiom only Mosaic (and
interpret mode) supports. Parallel-grid backends (``split_reduce=True``,
i.e. gpu-triton) have no paged fast path yet; callers (``ops.geometry_ops``)
fall back to the flat kernels / XLA masked operators there — a refusal,
never a silent interpret (the PR 7 rule).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backend import Backend
from .tiling import LANE, compute_f32 as _f32, pad_axis

__all__ = [
    "paged_feature_contract_pallas",
    "paged_halfstep_pallas",
    "paged_feature_matvec_pallas",
    "paged_contract_ref",
    "paged_matvec_ref",
    "paged_supported",
]


def paged_supported(backend: Optional[Backend]) -> bool:
    """Whether the paged fast path lowers on ``backend``: the contract
    kernel needs a sequential accumulation grid (Mosaic / interpret)."""
    return backend is None or not backend.split_reduce


def _check_paged(n: int, page_size: int, n_pages: int) -> None:
    if page_size % 8 != 0:
        raise ValueError(
            f"page_size must be a multiple of the f32 sublane (8), got "
            f"{page_size}"
        )
    if n != page_size * n_pages:
        raise ValueError(
            f"capacity {n} != page_size {page_size} * n_pages {n_pages}; "
            "paged buffers are exact multiples of the page granularity"
        )


# ---------------------------------------------------------------------------
# Contract: t = Xi^T u over live pages only
# ---------------------------------------------------------------------------


def _paged_contract_kernel(live_ref, xi_ref, u_ref, t_ref):
    """t += Xi_p^T u_p for live pages; dead pages skip the dot entirely.

    The page axis is the (sequential) grid; ``live_ref`` is the
    scalar-prefetched per-page live count in SMEM, so the predicate is
    known before the page's feature block is even needed."""
    p = pl.program_id(0)

    @pl.when(p == 0)
    def _init():
        t_ref[...] = jnp.zeros_like(t_ref)

    @pl.when(live_ref[p] > 0)
    def _acc():
        t_ref[...] += jax.lax.dot_general(
            _f32(xi_ref[...]),
            u_ref[...],
            (((0,), (0,)), ((), ())),          # contract the page-row axis
            preferred_element_type=jnp.float32,
        )


@functools.partial(jax.jit, static_argnames=("page_size", "interpret"))
def _paged_contract_impl(
    xi: jax.Array,          # (C, r) paged feature buffer
    u: jax.Array,           # (C, B)
    page_live: jax.Array,   # (n_pages,) int32 live-slot counts
    *,
    page_size: int,
    interpret: bool,
) -> jax.Array:
    C, r = xi.shape
    B = u.shape[1]
    xp = pad_axis(xi, 1, LANE)
    up = pad_axis(u, 1, LANE)
    rp, Bp = xp.shape[1], up.shape[1]
    n_pages = C // page_size
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_pages,),
        in_specs=[
            pl.BlockSpec((page_size, rp), lambda p, live: (p, 0)),
            pl.BlockSpec((page_size, Bp), lambda p, live: (p, 0)),
        ],
        out_specs=pl.BlockSpec((rp, Bp), lambda p, live: (0, 0)),
    )
    t = pl.pallas_call(
        _paged_contract_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rp, Bp), jnp.float32),
        interpret=interpret,
    )(page_live, xp, up)
    return t[:r, :B]


def paged_feature_contract_pallas(
    xi: jax.Array,          # (C, r)
    u: jax.Array,           # (C, B)
    page_live: jax.Array,   # (n_pages,) int32
    *,
    page_size: int,
    interpret: bool = False,
    backend: Optional[Backend] = None,
) -> jax.Array:
    """t = Xi^T u over live pages, shape (r, B).

    Exact vs the dense contract whenever dead slots carry u = 0 (the
    zero-weight masking invariant); all-dead pages are skipped, so a
    sparse store streams only its live pages' bytes."""
    _check_paged(xi.shape[0], page_size, page_live.shape[0])
    return _paged_contract_impl(xi, u, page_live, page_size=page_size,
                                interpret=interpret)


# ---------------------------------------------------------------------------
# Row kernels: halfstep / matvec with dead pages writing zeros
# ---------------------------------------------------------------------------


def _paged_halfstep_kernel(live_ref, xi_ref, t_ref, marg_ref, o_ref):
    p = pl.program_id(0)

    @pl.when(live_ref[p] > 0)
    def _live():
        kv = jax.lax.dot_general(
            _f32(xi_ref[...]),
            t_ref[...],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        o_ref[...] = marg_ref[...] / kv

    @pl.when(live_ref[p] == 0)
    def _dead():
        # a dead slot's marginal is 0 and the kernel is positive, so the
        # dense quotient is 0 too — writing zeros IS the exact value
        o_ref[...] = jnp.zeros_like(o_ref)


def _paged_matvec_kernel(live_ref, xi_ref, t_ref, o_ref):
    p = pl.program_id(0)

    @pl.when(live_ref[p] > 0)
    def _live():
        o_ref[...] = jax.lax.dot_general(
            _f32(xi_ref[...]),
            t_ref[...],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(live_ref[p] == 0)
    def _dead():
        # dead rows' matvec output is only ever consumed multiplied by a
        # zero scaling/weight; zeros keep it finite (and skip the MXU)
        o_ref[...] = jnp.zeros_like(o_ref)


def _paged_rows_call(kernel, xi, t, extra, page_live, *, page_size,
                     interpret):
    C, r = xi.shape
    B = t.shape[1]
    xp = pad_axis(xi, 1, LANE)
    tp = pad_axis(pad_axis(t, 0, LANE), 1, LANE)
    rp, Bp = tp.shape
    operands = [page_live, xp, tp]
    in_specs = [
        pl.BlockSpec((page_size, rp), lambda p, live: (p, 0)),
        pl.BlockSpec((rp, Bp), lambda p, live: (0, 0)),
    ]
    if extra is not None:
        operands.append(extra)
        in_specs.append(pl.BlockSpec((page_size, Bp), lambda p, live: (p, 0)))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(C // page_size,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((page_size, Bp), lambda p, live: (p, 0)),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((C, Bp), jnp.float32),
        interpret=interpret,
    )(*operands)
    return out[:, :B]


@functools.partial(jax.jit, static_argnames=("page_size", "interpret"))
def _paged_halfstep_impl(xi, t, marg, page_live, *, page_size: int,
                         interpret: bool):
    mp = pad_axis(marg, 1, LANE, value=1.0)
    return _paged_rows_call(_paged_halfstep_kernel, xi, t, mp, page_live,
                            page_size=page_size, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("page_size", "interpret"))
def _paged_matvec_impl(xi, t, page_live, *, page_size: int, interpret: bool):
    return _paged_rows_call(_paged_matvec_kernel, xi, t, None, page_live,
                            page_size=page_size, interpret=interpret)


def paged_halfstep_pallas(
    xi: jax.Array,          # (C, r)
    t: jax.Array,           # (r, B)
    marg: jax.Array,        # (C, B) target marginal (0 on dead slots)
    page_live: jax.Array,   # (n_pages,) int32
    *,
    page_size: int,
    interpret: bool = False,
    backend: Optional[Backend] = None,
) -> jax.Array:
    """out = marg / (Xi @ t) on live pages, zeros on all-dead pages."""
    _check_paged(xi.shape[0], page_size, page_live.shape[0])
    return _paged_halfstep_impl(xi, t, marg, page_live,
                                page_size=page_size, interpret=interpret)


def paged_feature_matvec_pallas(
    xi: jax.Array,          # (C, r)
    t: jax.Array,           # (r, B)
    page_live: jax.Array,   # (n_pages,) int32
    *,
    page_size: int,
    interpret: bool = False,
    backend: Optional[Backend] = None,
) -> jax.Array:
    """out = Xi @ t on live pages, zeros on all-dead pages (no divide)."""
    _check_paged(xi.shape[0], page_size, page_live.shape[0])
    return _paged_matvec_impl(xi, t, page_live, page_size=page_size,
                              interpret=interpret)


# ---------------------------------------------------------------------------
# XLA references (parity oracles + the fallback the geometry's operators use)
# ---------------------------------------------------------------------------


def paged_contract_ref(xi, u, page_live, *, page_size: int) -> jax.Array:
    """Masked XLA twin of :func:`paged_feature_contract_pallas`."""
    C, r = xi.shape
    n_pages = C // page_size
    mask = jnp.repeat((page_live > 0).astype(xi.dtype), page_size)
    return _f32(xi).T @ (u * mask[:, None])


def paged_matvec_ref(xi, t, page_live, *, page_size: int) -> jax.Array:
    """Masked XLA twin of :func:`paged_feature_matvec_pallas`."""
    mask = jnp.repeat((page_live > 0).astype(xi.dtype), page_size)
    return (_f32(xi) @ t) * mask[:, None]
