"""Persistent multi-iteration Sinkhorn megakernel (scaling + log twins).

The per-iteration fused plan (``kernels.ops``) still pays 4-5 Pallas/XLA
dispatches per Sinkhorn iteration and round-trips ``u/v`` (resp. ``f/g``)
and every intermediate through HBM. ``BENCH_seed.json`` puts the resulting
hot loop at 0.16-0.39 GFLOP/s — dispatch and memory traffic, not FLOPs.
This module collapses ``inner_steps`` FULL iterations into ONE
``pallas_call``:

  * Xi/Zeta are fetched from HBM exactly once per launch and stay resident
    in VMEM for all ``inner_steps`` iterations (whole-array blocks; the
    plan layer only selects this kernel when the working set fits the VMEM
    budget — larger shapes keep the streaming per-iteration plan),
  * ``u/v`` (scaling mode) resp. ``f/g`` and the stage-1 LSE carry (log
    mode) live entirely on-chip across iterations — the ``lax.fori_loop``
    runs INSIDE the kernel body,
  * the marginal error is computed once, at the block boundary, and is the
    only scalar that leaves the chip per block.

Numerics are the per-iteration plan's, step for step: the same
``s = Zeta^T (Xi^T u)`` carry reuse, the same momentum relaxations, the
same exact joint-max LSE stabilization in log mode — so a block of
``inner_steps`` megakernel iterations matches ``inner_steps`` unfused plan
steps elementwise at the block boundary (single-tile shapes; multi-tile
shapes differ only by f32 summation order).

Mixed precision: feature operands may arrive in bf16 (the
``precision="bf16"`` execution policy — half the HBM stream). Kernels
upcast feature tiles to f32 in registers; every contraction and LSE
accumulates in f32.

On CPU (CI) the kernels run in ``interpret=True`` mode; on TPU the same
bodies compile to Mosaic. ``relax_scaling`` / ``relax_log`` are canonical
here (shared with the XLA solvers through ``kernels.ops``) so this module
stays import-cycle-free.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .backend import Backend
from .logmatvec import _finite_or_zero
from .tiling import LANE, compute_f32 as _f32, pad_axis, round_up

__all__ = [
    "relax_scaling",
    "relax_log",
    "block_vmem_bytes",
    "block_plan_fits",
    "sinkhorn_block_pallas",
    "log_sinkhorn_block_pallas",
]

# sublane quantum covering both f32 (8) and bf16 (16) second-to-minor dims
_SUBLANE_ANY = 16

# Legacy working-set ceilings for the whole-array megakernel, used when no
# Backend record is supplied (the interpret-flag compat surface). The
# canonical per-backend budgets live in ``kernels.backend`` — TPU's 12 MiB
# VMEM (double-buffering headroom under ~16 MiB/core), GPU's 192 KiB
# shared-memory bound (a gridless Triton pallas_call is ONE CTA), and the
# interpret guard against accidentally materializing huge arrays.
VMEM_BUDGET_COMPILED = 12 * 2**20
VMEM_BUDGET_INTERPRET = 512 * 2**20


# ---------------------------------------------------------------------------
# Over-relaxation (canonical definitions; re-exported by kernels.ops)
# ---------------------------------------------------------------------------


def relax_scaling(new: jax.Array, old: jax.Array,
                  momentum: float) -> jax.Array:
    """Geometric over-relaxation  u <- old^{1-w} * new^w  (Thibault et al.),
    the scaling-space form. ``momentum`` is a trace-time constant.

    Zero scalings (zero-weight / bucket-padded atoms pin u = 0 from the
    first iteration) bypass the blend: for w > 1 the geometric mean hits
    0^{1-w} = inf and 0 * inf = NaN, which would poison the marginal error
    and silently stop the while_loop. Masked entries take ``new`` verbatim
    — the exact twin of the -inf guard in :func:`relax_log`."""
    if momentum == 1.0:
        return new
    mixed = old ** (1.0 - momentum) * new ** momentum
    return jnp.where((old > 0) & (new > 0), mixed, new)


def relax_log(new: jax.Array, old: jax.Array, momentum: float) -> jax.Array:
    """Log-space over-relaxation  f <- (1-w) old + w new  — the exact log of
    the geometric scaling relaxation. Atoms whose potential is pinned at
    -inf (zero weight) bypass the blend: (1-w)*(-inf) + w*(-inf) is NaN for
    w > 1, so the masked entries take ``new`` verbatim."""
    if momentum == 1.0:
        return new
    mixed = (1.0 - momentum) * old + momentum * new
    return jnp.where(jnp.isfinite(old) & jnp.isfinite(new), mixed, new)


# ---------------------------------------------------------------------------
# VMEM budget policy
# ---------------------------------------------------------------------------


def block_vmem_bytes(n: int, m: int, r: int, B: int = 1,
                     feature_dtype=jnp.float32) -> int:
    """Working-set bytes of one megakernel launch (padded shapes).

    Factors dominate: (n + m) * r at the feature storage width; the
    carried vectors and intermediates are O((n + m + r) * B) f32 — B
    stays UNPADDED in both megakernels (B = 1 on the solver path;
    batching rides the vmap grid axis).
    """
    np_, mp = round_up(n, _SUBLANE_ANY), round_up(m, _SUBLANE_ANY)
    rp = round_up(r, LANE)
    fbytes = jnp.dtype(feature_dtype).itemsize
    factors = (np_ + mp) * rp * fbytes
    vectors = (3 * np_ + 4 * mp + 2 * rp) * B * 4
    return factors + vectors


def block_plan_fits(n: int, m: int, r: int, B: int = 1,
                    feature_dtype=jnp.float32,
                    interpret: bool = False,
                    backend: Optional[Backend] = None) -> bool:
    """Whether the whole-array megakernel is admissible at this shape.

    With a :class:`~repro.kernels.backend.Backend` record the admission
    gate is the record's own budget — 12 MiB VMEM on tpu-mosaic, 192 KiB
    shared memory on gpu-triton (one CTA holds the whole working set), a
    materialization guard on interpret — and backends whose megakernel
    lowering is disabled refuse outright. Without a record the legacy
    interpret-flag behavior applies (compat surface for existing call
    sites and tests)."""
    bytes_ = block_vmem_bytes(n, m, r, B, feature_dtype)
    if backend is not None:
        return backend.megakernel and bytes_ <= backend.block_budget
    budget = VMEM_BUDGET_INTERPRET if interpret else VMEM_BUDGET_COMPILED
    return bytes_ <= budget


def _pad_rows_rep(arr: jax.Array, mult: int) -> jax.Array:
    """Pad axis 0 to a multiple of ``mult`` by REPLICATING the last row.

    Scaling-mode feature pads must stay strictly positive (a zero feature
    row paired with the padded atom's a = 0 weight would divide 0/0); a
    replicated row keeps ``Xi @ t > 0`` while the zero-weight pairing pins
    the padded scaling to exactly 0 — the bucket-padding contract."""
    pad = (-arr.shape[0]) % mult
    if pad == 0:
        return arr
    tail = jnp.broadcast_to(arr[-1:], (pad,) + arr.shape[1:])
    return jnp.concatenate([arr, tail], axis=0)


# ---------------------------------------------------------------------------
# Scaling-mode megakernel
# ---------------------------------------------------------------------------


def _contract(w: jax.Array, x: jax.Array) -> jax.Array:
    """(n, r)^T @ (n, B) -> (r, B), f32 accumulation."""
    return jax.lax.dot_general(
        w, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )


def _matvec(w: jax.Array, t: jax.Array) -> jax.Array:
    """(n, r) @ (r, B) -> (n, B), f32 accumulation."""
    return jax.lax.dot_general(
        w, t, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )


def _block_kernel(xi_ref, zeta_ref, a_ref, b_ref, u0_ref, v0_ref, s0_ref,
                  u_ref, v_ref, s_ref, err_ref, *, inner_steps: int,
                  momentum: float):
    """``inner_steps`` full Alg.-1 iterations, all carries on-chip.

    Identical step semantics to the per-iteration plan
    (``ops._scaling_plan``): carry (u, v, s = Zeta^T (Xi^T u)); the
    marginal error |v . s - b|_1 is emitted once, at the block boundary.
    Padded support rows are exact zero-weight atoms (b = 0, v = 0), so
    they contribute exactly 0 to the reduction. B is UNPADDED: unlike
    one-shot kernels — whose garbage pad-lane outputs get sliced after a
    single pass — the megakernel feeds its lanes back into the next
    on-chip iteration, where a zero-filled marginal column would turn
    into 0/0 NaN on the second step; and padding B to a full lane would
    multiply the on-chip carry footprint 128x for the solver's B = 1.
    """
    xi = _f32(xi_ref[...])          # (n, r) — VMEM-resident for the block
    zeta = _f32(zeta_ref[...])      # (m, r)
    a = a_ref[...]
    b = b_ref[...]

    def one(_, carry):
        u, v, s = carry
        v_new = relax_scaling(b / s, v, momentum)
        t = _contract(zeta, v_new)                    # (r, B)
        u_new = relax_scaling(a / _matvec(xi, t), u, momentum)
        t2 = _contract(xi, u_new)                     # (r, B)
        s_new = _matvec(zeta, t2)                     # (m, B)
        return u_new, v_new, s_new

    u, v, s = jax.lax.fori_loop(
        0, inner_steps, one, (u0_ref[...], v0_ref[...], s0_ref[...])
    )
    u_ref[...] = u
    v_ref[...] = v
    s_ref[...] = s
    err_ref[0, 0] = jnp.sum(jnp.abs(v * s - b))


@functools.partial(
    jax.jit, static_argnames=("inner_steps", "momentum", "interpret")
)
def sinkhorn_block_pallas(
    xi: jax.Array,          # (n, r) features (f32 or bf16 storage)
    zeta: jax.Array,        # (m, r)
    a: jax.Array,           # (n, B) target marginals (zeros = dead atoms)
    b: jax.Array,           # (m, B)
    u0: jax.Array,          # (n, B) scaling carry at block entry
    v0: jax.Array,          # (m, B)
    s0: jax.Array,          # (m, B) carried  s = Zeta^T (Xi^T u0)
    *,
    inner_steps: int,
    momentum: float = 1.0,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One megakernel block: ``inner_steps`` scaling-space iterations.

    Returns ``(u, v, s, err)`` — the plan-step carry after the block plus
    the block-boundary marginal error (a scalar). Padding: feature rows
    replicate (positive), weights/scalings pad 0 (inert zero-weight
    atoms), ``s0`` pads 1 (divide-safe; the padded v stays 0 because its b
    is 0), so padded lanes contribute exactly nothing to the carries or
    the error.
    """
    n, r = xi.shape
    m = zeta.shape[0]
    B = a.shape[1]
    xp = _pad_rows_rep(pad_axis(xi, 1, LANE), _SUBLANE_ANY)
    zp = _pad_rows_rep(pad_axis(zeta, 1, LANE), _SUBLANE_ANY)
    ap = pad_axis(a, 0, _SUBLANE_ANY)
    bp = pad_axis(b, 0, _SUBLANE_ANY)
    up = pad_axis(u0, 0, _SUBLANE_ANY)
    vp = pad_axis(v0, 0, _SUBLANE_ANY)
    sp = pad_axis(s0, 0, _SUBLANE_ANY, value=1.0)
    npad, mpad = xp.shape[0], zp.shape[0]
    u, v, s, err = pl.pallas_call(
        functools.partial(_block_kernel, inner_steps=inner_steps,
                          momentum=momentum),
        out_shape=(
            jax.ShapeDtypeStruct((npad, B), jnp.float32),
            jax.ShapeDtypeStruct((mpad, B), jnp.float32),
            jax.ShapeDtypeStruct((mpad, B), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ),
        interpret=interpret,
    )(xp, zp, ap, bp, up, vp, sp)
    return u[:n], v[:m], s[:m], err[0, 0]


# ---------------------------------------------------------------------------
# Log-mode megakernel (small-eps twin)
# ---------------------------------------------------------------------------


def _lse_rows(lw: jax.Array, t: jax.Array, n_cols: int) -> jax.Array:
    """out[j, c] = LSE_k(lw[j, k] + t[k, c]) with the exact per-column
    joint max (B unrolled at trace time — B = 1 on the solver path)."""
    cols = []
    for c in range(n_cols):
        z = lw + t[:, c][None, :]                      # (m, r)
        mx = _finite_or_zero(jnp.max(z, axis=1, keepdims=True))
        cols.append(
            (mx + jnp.log(jnp.sum(jnp.exp(z - mx), axis=1,
                                  keepdims=True)))[:, 0]
        )
    return jnp.stack(cols, axis=1)                     # (m, B)


def _lse_contract(lw: jax.Array, s: jax.Array, n_cols: int) -> jax.Array:
    """out[k, c] = LSE_i(lw[i, k] + s[i, c]) — the stage-1 contraction."""
    cols = []
    for c in range(n_cols):
        z = lw + s[:, c][:, None]                      # (n, r)
        mx = _finite_or_zero(jnp.max(z, axis=0, keepdims=True))
        cols.append(
            (mx + jnp.log(jnp.sum(jnp.exp(z - mx), axis=0,
                                  keepdims=True)))[0]
        )
    return jnp.stack(cols, axis=1)                     # (r, B)


def _log_block_kernel(lxi_ref, lzt_ref, loga_ref, logb_ref, b_ref,
                      f0_ref, g0_ref, t0_ref, f_ref, g_ref, t_ref, err_ref,
                      *, inner_steps: int, eps: float, momentum: float,
                      n_cols: int):
    """``inner_steps`` full log-domain iterations on-chip.

    Step semantics identical to ``ops._log_plan``: carry (f, g, t1) with
    t1 = LSE_i(logXi + f/eps) reused by both the next g-update and the
    block-boundary marginal check. The B columns are UNROLLED at trace
    time with the exact per-column joint max (the ``logmatvec``
    stabilization contract), so B stays unpadded — B = 1 on the solver
    path, batching rides the vmap grid axis.
    """
    lxi = _f32(lxi_ref[...])        # (n, r) log-features, VMEM-resident
    lzt = _f32(lzt_ref[...])        # (m, r)
    loga = loga_ref[...]
    logb = logb_ref[...]

    def one(_, carry):
        f, g, t1 = carry
        g_new = relax_log(eps * (logb - _lse_rows(lzt, t1, n_cols)),
                          g, momentum)
        t2 = _lse_contract(lzt, g_new / eps, n_cols)
        f_new = relax_log(eps * (loga - _lse_rows(lxi, t2, n_cols)),
                          f, momentum)
        t3 = _lse_contract(lxi, f_new / eps, n_cols)
        return f_new, g_new, t3

    f, g, t = jax.lax.fori_loop(
        0, inner_steps, one, (f0_ref[...], g0_ref[...], t0_ref[...])
    )
    f_ref[...] = f
    g_ref[...] = g
    t_ref[...] = t
    log_col = _lse_rows(lzt, t, n_cols) + g / eps
    err_ref[0, 0] = jnp.sum(jnp.abs(jnp.exp(log_col) - b_ref[...]))


@functools.partial(
    jax.jit, static_argnames=("inner_steps", "eps", "momentum", "interpret")
)
def log_sinkhorn_block_pallas(
    log_xi: jax.Array,      # (n, r) log-features (f32 or bf16 storage)
    log_zeta: jax.Array,    # (m, r)
    loga: jax.Array,        # (n, B) masked-log weights (-inf = dead atom)
    logb: jax.Array,        # (m, B)
    b: jax.Array,           # (m, B) linear column marginal (error check)
    f0: jax.Array,          # (n, B) potential carry at block entry
    g0: jax.Array,          # (m, B)
    t0: jax.Array,          # (r, B) carried stage-1 LSE of f0
    *,
    inner_steps: int,
    eps: float,
    momentum: float = 1.0,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One megakernel block: ``inner_steps`` log-domain iterations.

    Returns ``(f, g, t, err)`` — the log plan-step carry after the block
    plus the block-boundary marginal error. Padding: support rows
    replicate the last log-feature row while their weights/potentials pad
    ``-inf`` (the LSE identity) and the linear ``b`` pads 0 — exact
    zero-weight atoms end to end; the feature minor (r) axis pads
    ``-inf``.
    """
    n, r = log_xi.shape
    m = log_zeta.shape[0]
    B = loga.shape[1]
    ninf = -jnp.inf
    xp = _pad_rows_rep(pad_axis(log_xi, 1, LANE, value=ninf), _SUBLANE_ANY)
    zp = _pad_rows_rep(pad_axis(log_zeta, 1, LANE, value=ninf),
                       _SUBLANE_ANY)
    # B stays UNPADDED (columns are trace-time unrolled; B = 1 on the
    # solver path) — only the support rows and the feature/LSE minor dim
    # take lane padding, all with the -inf LSE identity.
    lap = pad_axis(loga, 0, _SUBLANE_ANY, value=ninf)
    lbp = pad_axis(logb, 0, _SUBLANE_ANY, value=ninf)
    bp = pad_axis(b, 0, _SUBLANE_ANY)
    fp = pad_axis(f0, 0, _SUBLANE_ANY, value=ninf)
    gp = pad_axis(g0, 0, _SUBLANE_ANY, value=ninf)
    tp = pad_axis(t0, 0, LANE, value=ninf)
    npad, mpad = xp.shape[0], zp.shape[0]
    rpad = tp.shape[0]
    f, g, t, err = pl.pallas_call(
        functools.partial(_log_block_kernel, inner_steps=inner_steps,
                          eps=eps, momentum=momentum, n_cols=B),
        out_shape=(
            jax.ShapeDtypeStruct((npad, B), jnp.float32),
            jax.ShapeDtypeStruct((mpad, B), jnp.float32),
            jax.ShapeDtypeStruct((rpad, B), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ),
        interpret=interpret,
    )(xp, zp, lap, lbp, bp, fp, gp, tp)
    return f[:n], g[:m], t[:r], err[0, 0]
