"""Pure-jnp oracles for every Pallas kernel in this package.

Tests sweep shapes/dtypes and assert_allclose(kernel(interpret=True), ref).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "gaussian_feature_map_ref",
    "feature_contract_ref",
    "feature_matvec_ref",
    "sinkhorn_halfstep_ref",
    "log_matvec_ref",
    "log_feature_contract_ref",
    "log_halfstep_ref",
]


def gaussian_feature_map_ref(
    x: jax.Array,          # (n, d)
    anchors: jax.Array,    # (r, d)
    log_const: jax.Array,  # (r,)  per-anchor additive log offset (incl -log r / 2)
    *,
    inv_eps: float,
    log_space: bool = False,
) -> jax.Array:
    """Xi[i,k] = exp(log_const[k] - 2/eps ||x_i - u_k||^2), shape (n, r).

    ``log_space=True`` returns ``log Xi`` (no exp) — the small-eps twin.
    Besides being the test oracle, this is the STREAMING fallback the plan
    layer executes when the fused map refuses to lower (the single-d-block
    constraint on parallel-grid backends; see ``kernels.backend``)."""
    x2 = jnp.sum(x * x, axis=-1)[:, None]
    u2 = jnp.sum(anchors * anchors, axis=-1)[None, :]
    sq = x2 + u2 - 2.0 * (x @ anchors.T)
    log_xi = log_const[None, :] - 2.0 * inv_eps * sq
    return log_xi if log_space else jnp.exp(log_xi)


def feature_contract_ref(xi: jax.Array, u: jax.Array) -> jax.Array:
    """t = Xi^T u : (n, r), (n, B) -> (r, B). Phase 1 of a Sinkhorn half-step."""
    return xi.T @ u


def sinkhorn_halfstep_ref(
    xi: jax.Array,         # (n, r) features of the side being updated
    t: jax.Array,          # (r, B) pre-contracted other side
    marg: jax.Array,       # (n, B) target marginal
) -> jax.Array:
    """out = marg / (Xi @ t) : the fused matvec + marginal divide."""
    return marg / (xi @ t)


def feature_matvec_ref(xi: jax.Array, t: jax.Array) -> jax.Array:
    """out = Xi @ t : (n, r), (r, B) -> (n, B). The divide-free twin of
    :func:`sinkhorn_halfstep_ref` (marginal-check matvec)."""
    return xi @ t


def log_matvec_ref(log_m: jax.Array, t: jax.Array) -> jax.Array:
    """out_j = logsumexp_k(log_m[j, k] + t[k]) : (m, r), (r,) -> (m,)."""
    return jax.scipy.special.logsumexp(log_m + t[None, :], axis=1)


def log_feature_contract_ref(log_w: jax.Array, s: jax.Array) -> jax.Array:
    """t[k, c] = LSE_i(log_w[i, k] + s[i, c]) : (n, r), (n, B) -> (r, B)."""
    return jax.scipy.special.logsumexp(
        log_w[:, :, None] + s[:, None, :], axis=0)


def log_halfstep_ref(log_w: jax.Array, t: jax.Array, lmarg: jax.Array,
                     *, scale: float = 1.0) -> jax.Array:
    """out = scale * (lmarg - LSE_k(log_w[:, k] + t[k, :])), shape (m, B)."""
    lse = jax.scipy.special.logsumexp(
        log_w[:, :, None] + t[None, :, :], axis=1)
    return scale * (lmarg - lse)
