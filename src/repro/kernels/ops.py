"""Jitted public wrappers over the Pallas kernels.

On CPU (this container) every kernel runs in ``interpret=True`` mode — the
kernel body executes in Python/XLA-CPU for correctness validation; on TPU
the same BlockSpecs compile to Mosaic. ``interpret`` is resolved once from
the backend unless overridden.

``fused_sinkhorn_iteration`` composes the kernels into one full Alg.-1
iteration (v then u) — this is the paper's O(r(n+m)) hot loop as it would
run on hardware.

``geometry_ops`` is the consumer of the Geometry layer's ``pallas_ops()``
hook: the GEOMETRY decides which fused kernels apply to its cost family
(fused Lemma-1 feature map + feature_contract + half-step for Gaussian
point clouds, feature_contract + half-step for explicit factors), and call
sites just ask for the plan instead of hard-coding a kernel choice.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .feature_map import gaussian_feature_map_pallas
from .kermatvec import feature_contract_pallas, sinkhorn_halfstep_pallas
from .logmatvec import log_matvec_pallas

__all__ = [
    "default_interpret",
    "gaussian_feature_map",
    "feature_contract",
    "sinkhorn_halfstep",
    "log_matvec",
    "fused_sinkhorn_iteration",
    "batched_sinkhorn_halfstep",
    "fused_batched_sinkhorn_iteration",
    "GeometryOps",
    "geometry_ops",
]


def default_interpret() -> bool:
    """Pallas interpret mode iff we're not actually on TPU."""
    return jax.default_backend() != "tpu"


def gaussian_feature_map(
    x: jax.Array,
    anchors: jax.Array,
    log_const: jax.Array,
    *,
    inv_eps: float,
    interpret: Optional[bool] = None,
) -> jax.Array:
    interpret = default_interpret() if interpret is None else interpret
    return gaussian_feature_map_pallas(
        x, anchors, log_const, inv_eps=inv_eps, interpret=interpret
    )


def feature_contract(
    xi: jax.Array, u: jax.Array, *, interpret: Optional[bool] = None
) -> jax.Array:
    interpret = default_interpret() if interpret is None else interpret
    return feature_contract_pallas(xi, u, interpret=interpret)


def sinkhorn_halfstep(
    xi: jax.Array,
    t: jax.Array,
    marg: jax.Array,
    *,
    interpret: Optional[bool] = None,
) -> jax.Array:
    interpret = default_interpret() if interpret is None else interpret
    return sinkhorn_halfstep_pallas(xi, t, marg, interpret=interpret)


def log_matvec(
    log_m: jax.Array, t: jax.Array, *, interpret: Optional[bool] = None
) -> jax.Array:
    interpret = default_interpret() if interpret is None else interpret
    return log_matvec_pallas(log_m, t, interpret=interpret)


def fused_sinkhorn_iteration(
    xi: jax.Array,          # (n, r)
    zeta: jax.Array,        # (m, r)
    a: jax.Array,           # (n, B)
    b: jax.Array,           # (m, B)
    u: jax.Array,           # (n, B) current scaling
    *,
    interpret: Optional[bool] = None,
):
    """One full Sinkhorn iteration on the factored kernel, Pallas end to end.

        t   = Xi^T u            (contract)
        v   = b / (Zeta t)      (fused halfstep)
        s   = Zeta^T v          (contract)
        u'  = a / (Xi s)        (fused halfstep)

    Returns (u', v).
    """
    t = feature_contract(xi, u, interpret=interpret)
    v = sinkhorn_halfstep(zeta, t, b, interpret=interpret)
    s = feature_contract(zeta, v, interpret=interpret)
    u_new = sinkhorn_halfstep(xi, s, a, interpret=interpret)
    return u_new, v


def batched_sinkhorn_halfstep(
    xi: jax.Array,          # (B, n, r) per-problem features of updated side
    u: jax.Array,           # (B, m) other side's current scaling
    marg: jax.Array,        # (B, n) target marginal of the updated side
    zeta: jax.Array,        # (B, m, r) features contracted against u
    *,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """One fused half-step  v_b = marg_b / (Xi_b (Zeta_b^T u_b))  for B
    independent problems (per-problem features, e.g. the BatchedSinkhorn
    engine's bucket groups). Pallas batching adds B as a leading grid axis,
    so the MXU still sees the same (block_n x r) tiles back to back.
    """

    def one(xi_b, u_b, marg_b, zeta_b):
        t = feature_contract(zeta_b, u_b[:, None], interpret=interpret)
        return sinkhorn_halfstep(xi_b, t, marg_b[:, None],
                                 interpret=interpret)[:, 0]

    return jax.vmap(one)(xi, u, marg, zeta)


def fused_batched_sinkhorn_iteration(
    xi: jax.Array,          # (B, n, r)
    zeta: jax.Array,        # (B, m, r)
    a: jax.Array,           # (B, n)
    b: jax.Array,           # (B, m)
    u: jax.Array,           # (B, n) current scalings
    *,
    interpret: Optional[bool] = None,
):
    """One full Alg.-1 iteration for B independent problems, Pallas end to
    end:

        t_b  = Xi_b^T u_b ;  v_b = b_b / (Zeta_b t_b)
        s_b  = Zeta_b^T v_b ; u_b' = a_b / (Xi_b s_b)

    Returns (u', v) stacked. Unlike :func:`fused_sinkhorn_iteration` (one
    shared kernel, B marginal columns), every problem here has its own
    feature matrices — the GAN-minibatch shape.

    This is the TPU lowering of the batched engine's hot loop (vmap adds B
    as a leading Pallas grid axis). ``api.BatchedSinkhorn`` itself lowers
    the same math through plain XLA contractions — on CPU these kernels
    only run in interpret mode, so the engine does not route through them;
    wiring the engine's factored method onto this path is the TPU
    deployment step.
    """
    v = batched_sinkhorn_halfstep(zeta, u, b, xi, interpret=interpret)
    u_new = batched_sinkhorn_halfstep(xi, v, a, zeta, interpret=interpret)
    return u_new, v


# ---------------------------------------------------------------------------
# Geometry-chosen dispatch (the pallas_ops() hook consumer)
# ---------------------------------------------------------------------------


class GeometryOps(NamedTuple):
    """Fused Pallas execution plan for one geometry's cost family.

    ``features``  — the materialized positive factors (xi, zeta) the plan
                    operates on; for Gaussian point clouds these come out
                    of the fused feature-map kernel (MXU dot + rank-1 norm
                    corrections + exp, no (n, r) sq-dist tensor in HBM).
    ``iteration`` — ``(a, b, u) -> (u', v)``: one full Alg.-1 iteration
                    (contract, half-step, contract, half-step), marginals
                    and scalings as (n, B)/(m, B) column blocks.
    """

    features: Tuple[jax.Array, jax.Array]
    iteration: Callable[[jax.Array, jax.Array, jax.Array],
                        Tuple[jax.Array, jax.Array]]


def _factored_plan(xi, zeta, interpret) -> GeometryOps:
    def iteration(a, b, u):
        return fused_sinkhorn_iteration(
            xi, zeta, a, b, u, interpret=interpret
        )

    return GeometryOps(features=(xi, zeta), iteration=iteration)


def geometry_ops(geom, *, interpret: Optional[bool] = None
                 ) -> Optional[GeometryOps]:
    """Fused-kernel plan for ``geom``, chosen by the geometry itself.

    Returns ``None`` when the geometry declares no fused path (dense
    costs, signed Nystrom factors, grids) — callers then fall back to the
    geometry's XLA operators. The spec format is owned by
    ``Geometry.pallas_ops``; this function only maps specs to kernels.
    """
    spec = geom.pallas_ops()
    if spec is None:
        return None
    interpret = default_interpret() if interpret is None else interpret
    kind = spec["kind"]
    if kind == "factored":
        return _factored_plan(spec["xi"], spec["zeta"], interpret)
    if kind == "gaussian":
        xi = gaussian_feature_map(
            spec["x"], spec["anchors"], spec["log_const"],
            inv_eps=spec["inv_eps"], interpret=interpret,
        )
        zeta = gaussian_feature_map(
            spec["y"], spec["anchors"], spec["log_const"],
            inv_eps=spec["inv_eps"], interpret=interpret,
        )
        return _factored_plan(xi, zeta, interpret)
    raise ValueError(f"unknown pallas_ops spec kind {kind!r}")
