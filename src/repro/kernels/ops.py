"""Jitted public wrappers over the Pallas kernels.

On CPU (this container) every kernel runs in ``interpret=True`` mode — the
kernel body executes in Python/XLA-CPU for correctness validation; on TPU
the same BlockSpecs compile to Mosaic. ``interpret`` is resolved once from
the backend unless overridden.

``fused_sinkhorn_iteration`` composes the kernels into one full Alg.-1
iteration (v then u) — this is the paper's O(r(n+m)) hot loop as it would
run on hardware.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .feature_map import gaussian_feature_map_pallas
from .kermatvec import feature_contract_pallas, sinkhorn_halfstep_pallas
from .logmatvec import log_matvec_pallas

__all__ = [
    "default_interpret",
    "gaussian_feature_map",
    "feature_contract",
    "sinkhorn_halfstep",
    "log_matvec",
    "fused_sinkhorn_iteration",
    "batched_sinkhorn_halfstep",
    "fused_batched_sinkhorn_iteration",
]


def default_interpret() -> bool:
    """Pallas interpret mode iff we're not actually on TPU."""
    return jax.default_backend() != "tpu"


def gaussian_feature_map(
    x: jax.Array,
    anchors: jax.Array,
    log_const: jax.Array,
    *,
    inv_eps: float,
    interpret: Optional[bool] = None,
) -> jax.Array:
    interpret = default_interpret() if interpret is None else interpret
    return gaussian_feature_map_pallas(
        x, anchors, log_const, inv_eps=inv_eps, interpret=interpret
    )


def feature_contract(
    xi: jax.Array, u: jax.Array, *, interpret: Optional[bool] = None
) -> jax.Array:
    interpret = default_interpret() if interpret is None else interpret
    return feature_contract_pallas(xi, u, interpret=interpret)


def sinkhorn_halfstep(
    xi: jax.Array,
    t: jax.Array,
    marg: jax.Array,
    *,
    interpret: Optional[bool] = None,
) -> jax.Array:
    interpret = default_interpret() if interpret is None else interpret
    return sinkhorn_halfstep_pallas(xi, t, marg, interpret=interpret)


def log_matvec(
    log_m: jax.Array, t: jax.Array, *, interpret: Optional[bool] = None
) -> jax.Array:
    interpret = default_interpret() if interpret is None else interpret
    return log_matvec_pallas(log_m, t, interpret=interpret)


def fused_sinkhorn_iteration(
    xi: jax.Array,          # (n, r)
    zeta: jax.Array,        # (m, r)
    a: jax.Array,           # (n, B)
    b: jax.Array,           # (m, B)
    u: jax.Array,           # (n, B) current scaling
    *,
    interpret: Optional[bool] = None,
):
    """One full Sinkhorn iteration on the factored kernel, Pallas end to end.

        t   = Xi^T u            (contract)
        v   = b / (Zeta t)      (fused halfstep)
        s   = Zeta^T v          (contract)
        u'  = a / (Xi s)        (fused halfstep)

    Returns (u', v).
    """
    t = feature_contract(xi, u, interpret=interpret)
    v = sinkhorn_halfstep(zeta, t, b, interpret=interpret)
    s = feature_contract(zeta, v, interpret=interpret)
    u_new = sinkhorn_halfstep(xi, s, a, interpret=interpret)
    return u_new, v


def batched_sinkhorn_halfstep(
    xi: jax.Array,          # (B, n, r) per-problem features of updated side
    u: jax.Array,           # (B, m) other side's current scaling
    marg: jax.Array,        # (B, n) target marginal of the updated side
    zeta: jax.Array,        # (B, m, r) features contracted against u
    *,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """One fused half-step  v_b = marg_b / (Xi_b (Zeta_b^T u_b))  for B
    independent problems (per-problem features, e.g. the BatchedSinkhorn
    engine's bucket groups). Pallas batching adds B as a leading grid axis,
    so the MXU still sees the same (block_n x r) tiles back to back.
    """

    def one(xi_b, u_b, marg_b, zeta_b):
        t = feature_contract(zeta_b, u_b[:, None], interpret=interpret)
        return sinkhorn_halfstep(xi_b, t, marg_b[:, None],
                                 interpret=interpret)[:, 0]

    return jax.vmap(one)(xi, u, marg, zeta)


def fused_batched_sinkhorn_iteration(
    xi: jax.Array,          # (B, n, r)
    zeta: jax.Array,        # (B, m, r)
    a: jax.Array,           # (B, n)
    b: jax.Array,           # (B, m)
    u: jax.Array,           # (B, n) current scalings
    *,
    interpret: Optional[bool] = None,
):
    """One full Alg.-1 iteration for B independent problems, Pallas end to
    end:

        t_b  = Xi_b^T u_b ;  v_b = b_b / (Zeta_b t_b)
        s_b  = Zeta_b^T v_b ; u_b' = a_b / (Xi_b s_b)

    Returns (u', v) stacked. Unlike :func:`fused_sinkhorn_iteration` (one
    shared kernel, B marginal columns), every problem here has its own
    feature matrices — the GAN-minibatch shape.

    This is the TPU lowering of the batched engine's hot loop (vmap adds B
    as a leading Pallas grid axis). ``api.BatchedSinkhorn`` itself lowers
    the same math through plain XLA contractions — on CPU these kernels
    only run in interpret mode, so the engine does not route through them;
    wiring the engine's factored method onto this path is the TPU
    deployment step.
    """
    v = batched_sinkhorn_halfstep(zeta, u, b, xi, interpret=interpret)
    u_new = batched_sinkhorn_halfstep(xi, v, a, zeta, interpret=interpret)
    return u_new, v
