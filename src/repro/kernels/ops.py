"""Jitted public wrappers over the Pallas kernels + the fused solve plans.

Execution policy is a first-class :class:`~repro.kernels.backend.Backend`
record (``kernels.backend.resolve_backend``): tpu-mosaic compiles the
sequential-grid kernels as written; gpu-triton compiles too but routes
grid reductions through their split-k variants and admission-gates the
megakernel at shared-memory size; only platforms with no compiled lowering
interpret. Every wrapper accepts ``backend=`` (record or name, resolved
upstream or here); ``backend="interpret"`` is the test configuration (the
legacy ``interpret=`` bool kwarg is gone).

``fused_sinkhorn_iteration`` composes the kernels into one full Alg.-1
iteration (v then u) — this is the paper's O(r(n+m)) hot loop as it would
run on hardware.

``geometry_ops`` is the consumer of the Geometry layer's ``pallas_ops()``
hook: the GEOMETRY decides which fused kernels apply to its cost family
(fused Lemma-1 feature map + feature_contract + half-step for Gaussian
point clouds, feature_contract + half-step for explicit factors, the LSE
twins for log-features), and call sites just ask for the plan instead of
hard-coding a kernel choice. The returned :class:`GeometryOps` carries,
besides the canonical fused ``iteration``, a ``make_step`` builder whose
step is drop-in compatible with ``core.sinkhorn.run_marginal_loop`` — that
is how ``sinkhorn_geometry`` / ``sinkhorn_log_geometry`` route their
``lax.while_loop`` hot loop through the fused kernels (``use_pallas``).

``observe_plan_selection`` is the test hook: while the context is active,
every fused-plan selection on a solve path appends an event dict, so tests
can assert the hot loop really ran through the plan.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Callable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .backend import Backend, fused_map_admissible, resolve_backend
from .feature_map import gaussian_feature_map_pallas
from .fused_loop import (
    block_plan_fits,
    log_sinkhorn_block_pallas,
    relax_log,
    relax_scaling,
    sinkhorn_block_pallas,
)
from .kermatvec import (
    feature_contract_pallas,
    feature_matvec_pallas,
    sinkhorn_halfstep_pallas,
)
from .logmatvec import (
    log_feature_contract_pallas,
    log_halfstep_pallas,
    log_matvec_pallas,
)
from .paged import (
    paged_feature_contract_pallas,
    paged_feature_matvec_pallas,
    paged_halfstep_pallas,
    paged_supported,
)
from .ref import gaussian_feature_map_ref

__all__ = [
    "gaussian_feature_map",
    "feature_contract",
    "feature_matvec",
    "sinkhorn_halfstep",
    "log_matvec",
    "log_feature_contract",
    "log_halfstep",
    "fused_sinkhorn_iteration",
    "fused_log_sinkhorn_iteration",
    "batched_sinkhorn_halfstep",
    "fused_batched_sinkhorn_iteration",
    "relax_scaling",
    "relax_log",
    "PRECISIONS",
    "check_precision",
    "GeometryOps",
    "geometry_ops",
    "observe_plan_selection",
    "notify_plan_selected",
]


# ---------------------------------------------------------------------------
# Thin backend-resolving wrappers
# ---------------------------------------------------------------------------


def gaussian_feature_map(
    x: jax.Array,
    anchors: jax.Array,
    log_const: jax.Array,
    *,
    inv_eps: float,
    log_space: bool = False,
    backend: Optional[Backend] = None,
) -> jax.Array:
    be = resolve_backend(backend)
    if not fused_map_admissible(x.shape[1], be):
        # the fused map's d axis is a sequential accumulation grid; when it
        # cannot ride in one block on a parallel-grid backend, REFUSE into
        # the streaming XLA map — never silently interpret.
        return gaussian_feature_map_ref(
            x, anchors, log_const, inv_eps=inv_eps, log_space=log_space)
    return gaussian_feature_map_pallas(
        x, anchors, log_const, inv_eps=inv_eps, interpret=be.interpret,
        log_space=log_space, backend=be,
    )


def feature_contract(
    xi: jax.Array, u: jax.Array, *,
    backend: Optional[Backend] = None,
) -> jax.Array:
    be = resolve_backend(backend)
    return feature_contract_pallas(xi, u, interpret=be.interpret,
                                   split_reduce=be.split_reduce, backend=be)


def feature_matvec(
    xi: jax.Array, t: jax.Array, *,
    backend: Optional[Backend] = None,
) -> jax.Array:
    be = resolve_backend(backend)
    return feature_matvec_pallas(xi, t, interpret=be.interpret, backend=be)


def sinkhorn_halfstep(
    xi: jax.Array,
    t: jax.Array,
    marg: jax.Array,
    *,
    backend: Optional[Backend] = None,
) -> jax.Array:
    be = resolve_backend(backend)
    return sinkhorn_halfstep_pallas(xi, t, marg, interpret=be.interpret,
                                    backend=be)


def log_matvec(
    log_m: jax.Array, t: jax.Array, *,
    backend: Optional[Backend] = None,
) -> jax.Array:
    be = resolve_backend(backend)
    return log_matvec_pallas(log_m, t, interpret=be.interpret, backend=be)


def log_feature_contract(
    log_w: jax.Array, s: jax.Array, *,
    backend: Optional[Backend] = None,
) -> jax.Array:
    be = resolve_backend(backend)
    return log_feature_contract_pallas(
        log_w, s, interpret=be.interpret, split_reduce=be.split_reduce,
        backend=be)


def log_halfstep(
    log_w: jax.Array,
    t: jax.Array,
    lmarg: jax.Array,
    *,
    scale: float = 1.0,
    backend: Optional[Backend] = None,
) -> jax.Array:
    be = resolve_backend(backend)
    return log_halfstep_pallas(log_w, t, lmarg, scale=scale,
                               interpret=be.interpret, backend=be)


# ---------------------------------------------------------------------------
# Fused full iterations
# ---------------------------------------------------------------------------


def fused_sinkhorn_iteration(
    xi: jax.Array,          # (n, r)
    zeta: jax.Array,        # (m, r)
    a: jax.Array,           # (n, B)
    b: jax.Array,           # (m, B)
    u: jax.Array,           # (n, B) current scaling
    *,
    backend: Optional[Backend] = None,
):
    """One full Sinkhorn iteration on the factored kernel, Pallas end to end.

        t   = Xi^T u            (contract)
        v   = b / (Zeta t)      (fused halfstep)
        s   = Zeta^T v          (contract)
        u'  = a / (Xi s)        (fused halfstep)

    Returns (u', v).
    """
    be = resolve_backend(backend)
    t = feature_contract(xi, u, backend=be)
    v = sinkhorn_halfstep(zeta, t, b, backend=be)
    s = feature_contract(zeta, v, backend=be)
    u_new = sinkhorn_halfstep(xi, s, a, backend=be)
    return u_new, v


def fused_log_sinkhorn_iteration(
    log_xi: jax.Array,      # (n, r)
    log_zeta: jax.Array,    # (m, r)
    loga: jax.Array,        # (n, B) masked-log weights
    logb: jax.Array,        # (m, B)
    f: jax.Array,           # (n, B) current potential
    *,
    eps: float,
    backend: Optional[Backend] = None,
):
    """One full LOG-domain Sinkhorn iteration, Pallas end to end:

        t  = LSE-contract(logXi, f/eps)                  (r, B)
        g  = eps (log b - LSE(logZeta + t))              (fused log halfstep)
        s  = LSE-contract(logZeta, g/eps)                (r, B)
        f' = eps (log a - LSE(logXi + s))                (fused log halfstep)

    Returns (f', g) — the small-eps twin of :func:`fused_sinkhorn_iteration`.
    """
    be = resolve_backend(backend)
    t = log_feature_contract(log_xi, f / eps, backend=be)
    g = log_halfstep(log_zeta, t, logb, scale=eps, backend=be)
    s = log_feature_contract(log_zeta, g / eps, backend=be)
    f_new = log_halfstep(log_xi, s, loga, scale=eps, backend=be)
    return f_new, g


def batched_sinkhorn_halfstep(
    xi: jax.Array,          # (B, n, r) per-problem features of updated side
    u: jax.Array,           # (B, m) other side's current scaling
    marg: jax.Array,        # (B, n) target marginal of the updated side
    zeta: jax.Array,        # (B, m, r) features contracted against u
    *,
    backend: Optional[Backend] = None,
) -> jax.Array:
    """One fused half-step  v_b = marg_b / (Xi_b (Zeta_b^T u_b))  for B
    independent problems (per-problem features, e.g. the BatchedSinkhorn
    engine's bucket groups). Pallas batching adds B as a leading grid axis,
    so the MXU still sees the same (block_n x r) tiles back to back.
    """
    be = resolve_backend(backend)

    def one(xi_b, u_b, marg_b, zeta_b):
        t = feature_contract(zeta_b, u_b[:, None], backend=be)
        return sinkhorn_halfstep(xi_b, t, marg_b[:, None],
                                 backend=be)[:, 0]

    return jax.vmap(one)(xi, u, marg, zeta)


def fused_batched_sinkhorn_iteration(
    xi: jax.Array,          # (B, n, r)
    zeta: jax.Array,        # (B, m, r)
    a: jax.Array,           # (B, n)
    b: jax.Array,           # (B, m)
    u: jax.Array,           # (B, n) current scalings
    *,
    backend: Optional[Backend] = None,
):
    """One full Alg.-1 iteration for B independent problems, Pallas end to
    end:

        t_b  = Xi_b^T u_b ;  v_b = b_b / (Zeta_b t_b)
        s_b  = Zeta_b^T v_b ; u_b' = a_b / (Xi_b s_b)

    Returns (u', v) stacked. Unlike :func:`fused_sinkhorn_iteration` (one
    shared kernel, B marginal columns), every problem here has its own
    feature matrices — the GAN-minibatch shape.

    ``api.BatchedSinkhorn`` reaches the same kernels through its vmapped
    per-problem solver when ``use_pallas`` is on: vmap adds B as a leading
    Pallas grid axis, exactly as here.
    """
    be = resolve_backend(backend)
    v = batched_sinkhorn_halfstep(zeta, u, b, xi, backend=be)
    u_new = batched_sinkhorn_halfstep(xi, v, a, zeta, backend=be)
    return u_new, v


# ---------------------------------------------------------------------------
# Over-relaxation: relax_scaling / relax_log are canonical in
# kernels.fused_loop (imported above, re-exported here) so the megakernel
# module stays import-cycle-free while the XLA solvers in core.sinkhorn
# keep importing them from this namespace.
# ---------------------------------------------------------------------------
# Geometry-chosen dispatch (the pallas_ops() hook consumer)
# ---------------------------------------------------------------------------


def _masked_log(w: jax.Array) -> jax.Array:
    """log w with log(0) pinned to -inf without 0*inf NaN hazards (local
    twin of ``core.geometry._masked_log`` — kernels must not import core)."""
    return jnp.where(w > 0, jnp.log(jnp.where(w > 0, w, 1.0)), -jnp.inf)


PRECISIONS = ("highest", "bf16")


def check_precision(precision: str) -> str:
    """Validate a ``precision=`` execution-policy value (shared with
    ``core.geometry``; kernels must not import core)."""
    if precision not in PRECISIONS:
        raise ValueError(
            f"unknown precision {precision!r}; expected one of {PRECISIONS}"
        )
    return precision


def _store_features(xi, zeta, precision: str):
    """Apply the storage half of the mixed-precision policy: bf16 halves
    the HBM stream of the (n, r)/(m, r) factors — the roofline-dominant
    bytes — while every kernel upcasts tiles to f32 in registers, so the
    contraction/LSE ACCUMULATION precision is unchanged."""
    check_precision(precision)
    if precision == "bf16":
        return xi.astype(jnp.bfloat16), zeta.astype(jnp.bfloat16)
    return xi, zeta


class GeometryOps(NamedTuple):
    """Fused Pallas execution plan for one geometry's cost family.

    ``mode``      — "scaling" (features/scalings) or "log" (log-features/
                    potentials, the small-eps path).
    ``kind``      — the ``pallas_ops()`` spec kind the plan was built from.
    ``features``  — the materialized factors the plan operates on:
                    (xi, zeta) in scaling mode, (log_xi, log_zeta) in log
                    mode; for Gaussian point clouds these come out of the
                    fused feature-map kernel (MXU dot + rank-1 norm
                    corrections + exp — or no exp in log mode — with no
                    (n, r) sq-dist tensor in HBM).
    ``iteration`` — one full fused Alg.-1 iteration:
                    scaling  ``(a, b, u) -> (u', v)``,
                    log      ``(loga, logb, f) -> (f', g)``,
                    marginals/scalings/potentials as (n, B)/(m, B) columns.
    ``make_step`` — ``(a, b, *, momentum, err_reduce) -> (step, init)``
                    where ``step`` is drop-in compatible with
                    ``core.sinkhorn.run_marginal_loop`` and ELEMENTWISE
                    matches ``make_scaling_step`` / ``make_log_step`` over
                    the geometry's XLA operators (same iterates, same
                    marginal error, same masking) — the solver hot loop.
                    ``init`` lifts the primal/dual start values into the
                    loop carry, which tacks on the reusable intermediate
                    (``s = K^T u`` in scaling mode, the stage-1 LSE
                    ``t = LSE(logXi + f/eps)`` in log mode) so the
                    convergence check costs nothing extra per iteration.
    ``apply_kt``  — scaling mode only: ``u (n,) -> K^T u (m,)`` for the
                    loop-carry initialization.
    ``eps``       — log mode only: the regularization the potentials live
                    at.
    ``make_block_step`` — ``(a, b, *, inner_steps, momentum) ->
                    Optional[(step, init)]``: the PERSISTENT megakernel
                    plan. ``step`` advances ``inner_steps`` full
                    iterations in ONE ``pallas_call`` (``fused_loop``) —
                    factors VMEM-resident, carries on-chip, marginal error
                    emitted at the block boundary only — over the SAME
                    carry as ``make_step`` (so the two are
                    interchangeable in ``run_marginal_loop`` and match
                    elementwise at block boundaries). Returns ``None``
                    when the working set exceeds the VMEM budget
                    (``fused_loop.block_plan_fits``) — callers then fall
                    back to the streaming per-iteration ``make_step``.
    ``interpret`` — whether the plan's kernels run in interpret mode
                    (``backend.interpret`` — kept as a flat field for the
                    solver auto policy and existing call sites).
    ``backend``   — the resolved :class:`Backend` record the plan was
                    built at (budgets, split-k routing, megakernel
                    admission all key off it).
    ``precision`` — the execution policy the plan was built at
                    ("highest" | "bf16"): bf16 stores/streams the factors
                    at half width; all contractions and LSE accumulations
                    stay f32.
    """

    mode: str
    kind: str
    features: Tuple[jax.Array, jax.Array]
    iteration: Callable
    make_step: Callable
    apply_kt: Optional[Callable] = None
    eps: Optional[float] = None
    make_block_step: Optional[Callable] = None
    interpret: bool = False
    precision: str = "highest"
    backend: Optional[Backend] = None


def _scaling_plan(kind: str, xi, zeta, be: Backend,
                  precision: str = "highest") -> GeometryOps:
    xi, zeta = _store_features(xi, zeta, precision)

    def iteration(a, b, u):
        return fused_sinkhorn_iteration(xi, zeta, a, b, u, backend=be)

    def apply_kt(u):
        t = feature_contract(xi, u[:, None], backend=be)
        return feature_matvec(zeta, t, backend=be)[:, 0]

    def make_step(a, b, *, momentum: float = 1.0,
                  err_reduce: Callable = jnp.sum):
        ac = a[:, None]

        def step(carry):
            u, v, s = carry
            v_new = relax_scaling(b / s, v, momentum)
            t = feature_contract(zeta, v_new[:, None], backend=be)
            if momentum == 1.0:
                # matvec + marginal divide fused in one VMEM pass
                u_new = sinkhorn_halfstep(xi, t, ac, backend=be)[:, 0]
            else:
                kv = feature_matvec(xi, t, backend=be)[:, 0]
                u_new = relax_scaling(a / kv, u, momentum)
            t2 = feature_contract(xi, u_new[:, None], backend=be)
            s_new = feature_matvec(zeta, t2, backend=be)[:, 0]
            err = err_reduce(jnp.abs(v_new * s_new - b))
            return (u_new, v_new, s_new), err

        def init(u0, v0):
            return (u0, v0, apply_kt(u0))

        return step, init

    def make_block_step(a, b, *, inner_steps: int, momentum: float = 1.0):
        n, m = a.shape[0], b.shape[0]
        if not block_plan_fits(n, m, xi.shape[1], 1, xi.dtype, backend=be):
            return None
        ac, bc = a[:, None], b[:, None]

        def step(carry):
            u, v, s = carry
            u2, v2, s2, err = sinkhorn_block_pallas(
                xi, zeta, ac, bc, u[:, None], v[:, None], s[:, None],
                inner_steps=inner_steps, momentum=momentum,
                interpret=be.interpret,
            )
            return (u2[:, 0], v2[:, 0], s2[:, 0]), err

        def init(u0, v0):
            return (u0, v0, apply_kt(u0))

        return step, init

    return GeometryOps(mode="scaling", kind=kind, features=(xi, zeta),
                       iteration=iteration, make_step=make_step,
                       apply_kt=apply_kt, make_block_step=make_block_step,
                       interpret=be.interpret, precision=precision,
                       backend=be)


def _log_plan(kind: str, log_xi, log_zeta, eps: float, be: Backend,
              precision: str = "highest") -> GeometryOps:
    log_xi, log_zeta = _store_features(log_xi, log_zeta, precision)

    def iteration(loga, logb, f):
        return fused_log_sinkhorn_iteration(
            log_xi, log_zeta, loga, logb, f, eps=eps, backend=be
        )

    def contract_f(f):
        """Stage-1 LSE over logXi — the carried intermediate: computing it
        once per iteration serves BOTH the convergence check and the next
        iteration's g-update (the log twin of carrying ``s = K^T u``)."""
        return log_feature_contract(log_xi, f[:, None] / eps, backend=be)

    def make_step(a, b, *, momentum: float = 1.0,
                  err_reduce: Callable = jnp.sum):
        loga = _masked_log(a)[:, None]
        logb = _masked_log(b)[:, None]
        zero = jnp.zeros_like(logb)

        def step(carry):
            f, g, t1 = carry                     # t1 = LSE(logXi + f/eps)
            g_new = relax_log(
                log_halfstep(log_zeta, t1, logb, scale=eps,
                             backend=be)[:, 0], g, momentum)
            t2 = log_feature_contract(log_zeta, g_new[:, None] / eps,
                                      backend=be)
            f_new = relax_log(
                log_halfstep(log_xi, t2, loga, scale=eps,
                             backend=be)[:, 0], f, momentum)
            t3 = contract_f(f_new)
            lse = log_halfstep(log_zeta, t3, zero, scale=-1.0,
                               backend=be)[:, 0]
            log_col = lse + g_new / eps
            err = err_reduce(jnp.abs(jnp.exp(log_col) - b))
            return (f_new, g_new, t3), err

        def init(f0, g0):
            return (f0, g0, contract_f(f0))

        return step, init

    def make_block_step(a, b, *, inner_steps: int, momentum: float = 1.0):
        n, m = a.shape[0], b.shape[0]
        if not block_plan_fits(n, m, log_xi.shape[1], 1, log_xi.dtype,
                               backend=be):
            return None
        loga = _masked_log(a)[:, None]
        logb = _masked_log(b)[:, None]
        bc = b[:, None]

        def step(carry):
            f, g, t1 = carry
            f2, g2, t2, err = log_sinkhorn_block_pallas(
                log_xi, log_zeta, loga, logb, bc,
                f[:, None], g[:, None], t1,
                inner_steps=inner_steps, eps=eps, momentum=momentum,
                interpret=be.interpret,
            )
            return (f2[:, 0], g2[:, 0], t2), err

        def init(f0, g0):
            return (f0, g0, contract_f(f0))

        return step, init

    return GeometryOps(mode="log", kind=kind, features=(log_xi, log_zeta),
                       iteration=iteration, make_step=make_step, eps=eps,
                       make_block_step=make_block_step,
                       interpret=be.interpret, precision=precision,
                       backend=be)


def _paged_scaling_plan(kind: str, xi, zeta, live_x, live_y,
                        page_size: int, be: Backend,
                        precision: str = "highest") -> GeometryOps:
    """Scaling plan over PAGED factor buffers: each contract / half-step
    predicates per page on the live counts (``kernels.paged``), skipping
    the MXU work for all-dead pages. Elementwise equal to
    :func:`_scaling_plan` whenever dead slots carry zero weight/scaling —
    the streaming store's invariant. No megakernel block step yet: paged
    updates run the streaming per-iteration path."""
    xi, zeta = _store_features(xi, zeta, precision)
    kw = dict(page_size=page_size, interpret=be.interpret, backend=be)

    def iteration(a, b, u):
        t = paged_feature_contract_pallas(xi, u, live_x, **kw)
        v = paged_halfstep_pallas(zeta, t, b, live_y, **kw)
        s = paged_feature_contract_pallas(zeta, v, live_y, **kw)
        u_new = paged_halfstep_pallas(xi, s, a, live_x, **kw)
        return u_new, v

    def apply_kt(u):
        t = paged_feature_contract_pallas(xi, u[:, None], live_x, **kw)
        return paged_feature_matvec_pallas(zeta, t, live_y, **kw)[:, 0]

    def make_step(a, b, *, momentum: float = 1.0,
                  err_reduce: Callable = jnp.sum):
        ac = a[:, None]

        def step(carry):
            u, v, s = carry
            # the paged matvec writes ZEROS on all-dead pages, so b / s is
            # 0/0 there — mask to the flat plan's value (b = 0 -> v = 0)
            v_new = relax_scaling(jnp.where(b > 0, b / s, 0.0), v, momentum)
            t = paged_feature_contract_pallas(zeta, v_new[:, None], live_y,
                                              **kw)
            if momentum == 1.0:
                u_new = paged_halfstep_pallas(xi, t, ac, live_x, **kw)[:, 0]
            else:
                kv = paged_feature_matvec_pallas(xi, t, live_x, **kw)[:, 0]
                u_new = relax_scaling(jnp.where(a > 0, a / kv, 0.0), u,
                                      momentum)
            t2 = paged_feature_contract_pallas(xi, u_new[:, None], live_x,
                                               **kw)
            s_new = paged_feature_matvec_pallas(zeta, t2, live_y, **kw)[:, 0]
            err = err_reduce(jnp.abs(v_new * s_new - b))
            return (u_new, v_new, s_new), err

        def init(u0, v0):
            return (u0, v0, apply_kt(u0))

        return step, init

    return GeometryOps(mode="scaling", kind=kind, features=(xi, zeta),
                       iteration=iteration, make_step=make_step,
                       apply_kt=apply_kt, make_block_step=None,
                       interpret=be.interpret, precision=precision,
                       backend=be)


def geometry_ops(geom, *,
                 mode: str = "scaling",
                 precision: str = "highest",
                 backend: Optional[Backend] = None) -> Optional[GeometryOps]:
    """Fused-kernel plan for ``geom``, chosen by the geometry itself.

    ``mode="scaling"`` builds the linear-feature plan (Alg. 1 on scalings);
    ``mode="log"`` builds the log-feature plan (small-eps potentials, exact
    two-stage LSE through the fused log kernels). Returns ``None`` when the
    geometry declares no fused path (dense costs, signed Nystrom factors,
    grids) — callers then fall back to the geometry's XLA operators. The
    spec format is owned by ``Geometry.pallas_ops``; this function only
    maps specs to kernels.

    ``precision="bf16"`` stores/streams the (log-)factors — including the
    feature blocks produced by the fused Gaussian map for point-cloud
    geometries — at half width; contractions and LSE accumulations stay
    f32 (see ``_store_features``).

    ``backend=`` pins the plan to a resolved :class:`Backend` record or
    name (``"interpret"`` is the test configuration); otherwise the
    ambient policy applies. The whole plan — kernel routing (split-k on
    parallel-grid backends), fused-map admissibility, megakernel budget —
    keys off the one record.
    """
    if mode not in ("scaling", "log"):
        raise ValueError(f"unknown plan mode {mode!r}")
    check_precision(precision)
    spec = geom.pallas_ops()
    if spec is None:
        return None
    be = resolve_backend(backend)
    kind = spec["kind"]
    if kind == "factored":
        xi, zeta = spec["xi"], spec["zeta"]
        if mode == "scaling":
            return _scaling_plan(kind, xi, zeta, be, precision)
        return _log_plan(kind, _masked_log(xi), _masked_log(zeta),
                         float(geom.eps), be, precision)
    if kind == "log_factored":
        lxi, lzt = spec["log_xi"], spec["log_zeta"]
        if mode == "log":
            return _log_plan(kind, lxi, lzt, float(spec["eps"]), be,
                             precision)
        return _scaling_plan(kind, jnp.exp(lxi), jnp.exp(lzt), be,
                             precision)
    if kind == "paged":
        if "xi" in spec:
            xi, zeta = spec["xi"], spec["zeta"]
            lxi = lzt = None
        else:
            lxi, lzt = spec["log_xi"], spec["log_zeta"]
            xi, zeta = jnp.exp(lxi), jnp.exp(lzt)
        if mode == "log":
            # dead slots are -inf-pinned potentials — inert in every LSE —
            # so the standard log plan on the flat factors is already
            # exact; there is no paged log fast path (yet)
            if lxi is None:
                lxi, lzt = _masked_log(xi), _masked_log(zeta)
            return _log_plan(kind, lxi, lzt, float(spec["eps"]), be,
                             precision)
        if not paged_supported(be):
            # parallel-grid backends (Triton) cannot lower the paged
            # contract's sequential accumulation — refuse into the flat
            # split-k kernels (still masked-exact), never interpret
            return _scaling_plan(kind, xi, zeta, be, precision)
        return _paged_scaling_plan(
            kind, xi, zeta, spec["page_live_x"], spec["page_live_y"],
            int(spec["page_size"]), be, precision)
    if kind == "gaussian":
        fmap = functools.partial(
            gaussian_feature_map,
            anchors=spec["anchors"], log_const=spec["log_const"],
            inv_eps=spec["inv_eps"], backend=be,
            log_space=(mode == "log"),
        )
        xi, zeta = fmap(spec["x"]), fmap(spec["y"])
        if mode == "scaling":
            return _scaling_plan(kind, xi, zeta, be, precision)
        return _log_plan(kind, xi, zeta, float(geom.eps), be, precision)
    raise ValueError(f"unknown pallas_ops spec kind {kind!r}")


# ---------------------------------------------------------------------------
# Plan-selection hook (test observability)
# ---------------------------------------------------------------------------

_PLAN_OBSERVERS: List[Callable[[dict], None]] = []


def notify_plan_selected(event: dict) -> None:
    """Called by the solvers when a fused plan is installed on a hot loop.

    Fires at TRACE time (plan selection is a Python-level decision), so a
    jitted solve notifies on its first call per compilation."""
    for cb in list(_PLAN_OBSERVERS):
        cb(dict(event))


@contextlib.contextmanager
def observe_plan_selection():
    """Collect plan-selection events: ``with observe_plan_selection() as ev:
    solve(...)`` then assert on ``ev`` (list of dicts with ``geometry`` /
    ``mode`` / ``kind`` keys)."""
    events: List[dict] = []
    _PLAN_OBSERVERS.append(events.append)
    try:
        yield events
    finally:
        _PLAN_OBSERVERS.remove(events.append)
