"""Shared tile-shape policy for every Pallas kernel in this package.

TPU vector registers are (sublane, lane) = (8, 128) for f32, and Mosaic
lays arrays out in multiples of those — a BlockSpec whose trailing dim is
not a multiple of 128 either fails to lower or silently wastes the lane
dimension. Every kernel therefore pads its operands to lane multiples with
a NEUTRAL value (0 for linear features / scalings, -inf for log-space
entries, 1 for marginals that feed a divide) and slices the result back.

This module is the single owner of that policy:

  * :func:`pad_axis`   — pad one axis up to a multiple with a fill value
  * :func:`pick_block` — block-size selection keyed on the actual extent:
    the smallest lane multiple covering the axis, capped so the working
    set stays inside VMEM. Small problems get small tiles (no 512-wide
    tiles for r=3), large problems get MXU-saturating ones.

Kernels accept ``block_* = None`` and resolve through :func:`pick_block`,
so the (n, m, r, B)-keyed selection happens in exactly one place.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["LANE", "SUBLANE", "round_up", "pad_axis", "pick_block",
           "compute_f32"]

LANE = 128      # trailing-dim quantum (f32)
SUBLANE = 8     # second-to-last-dim quantum (f32)


def compute_f32(x: jax.Array) -> jax.Array:
    """Upcast a reduced-precision (bf16-stored) feature tile to f32 in
    registers — the compute half of the mixed-precision policy: storage
    and HBM streaming may be bf16, every contraction/LSE ACCUMULATES in
    f32 (Mosaic fuses the widening convert into the consuming op). Shared
    by every kernel in this package so the rule lives in one place."""
    return x.astype(jnp.float32) if x.dtype != jnp.float32 else x


def round_up(size: int, mult: int = LANE) -> int:
    """Smallest multiple of ``mult`` >= ``size``."""
    return ((size + mult - 1) // mult) * mult


def pad_axis(arr: jax.Array, axis: int, mult: int,
             value: float = 0.0) -> jax.Array:
    """Pad ``axis`` of ``arr`` up to a multiple of ``mult`` with ``value``.

    The fill must be NEUTRAL for the kernel consuming the array: 0 for
    linear features/scalings (contributes nothing to a dot), ``-inf`` for
    log entries (identity of logsumexp), 1 for marginals whose divide
    output is sliced away.
    """
    size = arr.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return arr
    widths = [(0, 0)] * arr.ndim
    widths[axis] = (0, pad)
    return jnp.pad(arr, widths, constant_values=value)


def pick_block(size: int, cap: int = 512, mult: int = LANE) -> int:
    """Block size for an axis of extent ``size``: the smallest multiple of
    ``mult`` covering the axis, capped at ``cap`` (itself a multiple of
    ``mult``). With this policy a padded axis always divides evenly by the
    chosen block, so grids never need remainder handling."""
    assert cap % mult == 0, (cap, mult)
    return min(round_up(max(size, 1), mult), cap)
