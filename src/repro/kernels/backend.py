"""Execution-backend policy for the Pallas kernel package.

Every kernel used to resolve a bare ``interpret: bool`` from
``jax.default_backend() != "tpu"`` — which silently handed a GPU backend
the *interpreted* kernels (orders of magnitude slow). This module replaces
that bool with a first-class :class:`Backend` record, resolved ONCE per
call site from the runtime platform with env/API overrides:

  * ``tpu-mosaic``  — kernels compile through the Mosaic TPU backend;
    sequential grid axes may accumulate into revisited output blocks, and
    the persistent megakernel is admitted up to the VMEM budget.
  * ``gpu-triton``  — kernels compile through Pallas's Triton lowering.
    Grid programs are PARALLEL CTAs: cross-program accumulation into a
    shared output block is a race, so reduction-over-grid kernels must run
    their split-k variants (partials per grid cell + an XLA combine) and
    the fused feature map must cover the d axis in a single block. The
    megakernel admission budget is shared-memory-sized, not VMEM-sized.
  * ``interpret``   — the Python/XLA interpreter (CPU CI, tests). Reached
    only on platforms with no compiled lowering, or by explicit override.

The record carries everything the kernels/plan layer key decisions on:
lane/sublane quanta, the megakernel admission budget, whether grid
reductions need split-k, and the interpret flag. ``resolve_backend()`` is
the single owner of the policy.

Overrides, highest precedence first:

  1. an explicit ``backend=`` record or name at the call site
     (``backend="interpret"`` is the test configuration — the legacy
     ``interpret=`` bool kwarg is gone),
  2. :func:`set_backend` / :func:`backend_scope` (process-level API),
  3. the ``REPRO_BACKEND`` env var (one of the three names above),
  4. ``jax.default_backend()``.
"""
from __future__ import annotations

import contextlib
import os
from typing import NamedTuple, Optional, Union

import jax

from .tiling import LANE, SUBLANE, round_up

__all__ = [
    "Backend",
    "BACKEND_ENV",
    "BACKEND_NAMES",
    "MEGAKERNEL_BUDGET_TPU",
    "MEGAKERNEL_BUDGET_GPU",
    "MEGAKERNEL_BUDGET_INTERPRET",
    "backend_scope",
    "fused_map_admissible",
    "resolve_backend",
    "set_backend",
]

BACKEND_ENV = "REPRO_BACKEND"

# Megakernel (whole-array persistent block) admission budgets. TPU: VMEM is
# ~16 MiB/core; 12 MiB leaves double-buffering headroom. GPU: a Triton
# pallas_call with no grid is one CTA whose whole working set must sit in
# shared memory / registers — 192 KiB covers an H100 SM with headroom, so
# only genuinely tiny problems are admitted and everything else refuses
# into the streaming per-iteration plan. Interpret: no real memory bound;
# the cap only guards against accidentally materializing huge arrays.
MEGAKERNEL_BUDGET_TPU = 12 * 2**20
MEGAKERNEL_BUDGET_GPU = 192 * 2**10
MEGAKERNEL_BUDGET_INTERPRET = 512 * 2**20


class Backend(NamedTuple):
    """Resolved execution policy threaded through kernels and plans.

    ``name``            — "tpu-mosaic" | "gpu-triton" | "interpret".
    ``platform``        — the ``jax.default_backend()`` string the record
                          was resolved from (informational).
    ``interpret``       — run ``pallas_call`` in interpret mode.
    ``lane``/``sublane``— tile quanta for the trailing / second-to-last
                          dims (the padding contract of ``kernels.tiling``).
    ``block_budget``    — megakernel working-set admission budget in bytes
                          (``fused_loop.block_plan_fits`` reads this).
    ``megakernel``      — whether the persistent whole-array megakernel
                          lowers on this backend at all.
    ``split_reduce``    — grid programs are parallel (Triton CTAs): kernels
                          that reduce ACROSS grid steps must use their
                          split-k variants (per-cell partials + XLA
                          combine) instead of accumulating into a
                          revisited output block.
    ``fused_map_max_d`` — fused Gaussian feature map: largest lane-padded
                          point dimension the single-d-block constraint
                          admits (0 = sequential d grid allowed, no limit).
                          Over the limit, the plan layer refuses into the
                          XLA (streaming) feature map rather than
                          interpreting.
    """

    name: str
    platform: str
    interpret: bool
    lane: int = LANE
    sublane: int = SUBLANE
    block_budget: int = MEGAKERNEL_BUDGET_INTERPRET
    megakernel: bool = True
    split_reduce: bool = False
    fused_map_max_d: int = 0


def _tpu(platform: str = "tpu") -> Backend:
    return Backend(name="tpu-mosaic", platform=platform, interpret=False,
                   lane=LANE, sublane=SUBLANE,
                   block_budget=MEGAKERNEL_BUDGET_TPU,
                   megakernel=True, split_reduce=False, fused_map_max_d=0)


def _gpu(platform: str = "gpu") -> Backend:
    return Backend(name="gpu-triton", platform=platform, interpret=False,
                   lane=LANE, sublane=SUBLANE,
                   block_budget=MEGAKERNEL_BUDGET_GPU,
                   megakernel=True, split_reduce=True, fused_map_max_d=512)


def _interpret(platform: str) -> Backend:
    return Backend(name="interpret", platform=platform, interpret=True,
                   lane=LANE, sublane=SUBLANE,
                   block_budget=MEGAKERNEL_BUDGET_INTERPRET,
                   megakernel=True, split_reduce=False, fused_map_max_d=0)


_BUILDERS = {
    "tpu-mosaic": _tpu,
    "gpu-triton": _gpu,
    "interpret": _interpret,
}
BACKEND_NAMES = tuple(_BUILDERS)

_GPU_PLATFORMS = ("gpu", "cuda", "rocm")

# process-level override installed by set_backend / backend_scope
_OVERRIDE: Optional[Backend] = None


def _from_name(name: str, platform: Optional[str] = None) -> Backend:
    try:
        builder = _BUILDERS[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; expected one of {BACKEND_NAMES}"
        ) from None
    return builder(platform or jax.default_backend())


def _platform_default(platform: str) -> Backend:
    """The compiled-where-possible policy: TPU and GPU backends COMPILE
    their Pallas lowering; only platforms with no lowering interpret."""
    if platform == "tpu":
        return _tpu(platform)
    if platform in _GPU_PLATFORMS:
        return _gpu(platform)
    return _interpret(platform)


def resolve_backend(
    backend: Optional[Union[Backend, str]] = None,
) -> Backend:
    """Resolve the execution backend for a kernel/plan call site.

    Explicit ``backend`` (record or name — ``"interpret"`` is the test
    configuration) wins; otherwise the ambient policy applies
    (:func:`set_backend` override, then ``REPRO_BACKEND``, then
    ``jax.default_backend()``). A GPU platform resolves to ``gpu-triton``
    with ``interpret=False`` — the interpreter is never selected silently
    on a compiled-capable backend.
    """
    if isinstance(backend, Backend):
        return backend
    if backend is not None:
        return _from_name(backend)
    if _OVERRIDE is not None:
        return _OVERRIDE
    env = os.environ.get(BACKEND_ENV)
    if env:
        return _from_name(env)
    return _platform_default(jax.default_backend())


def set_backend(backend: Optional[Union[Backend, str]]) -> Optional[Backend]:
    """Install (or clear, with ``None``) the process-level backend
    override. Returns the previous override so callers can restore it."""
    global _OVERRIDE
    previous = _OVERRIDE
    _OVERRIDE = None if backend is None else resolve_backend(backend)
    return previous


@contextlib.contextmanager
def backend_scope(backend: Union[Backend, str]):
    """``with backend_scope("gpu-triton"): ...`` — scoped override."""
    previous = set_backend(backend)
    try:
        yield resolve_backend()
    finally:
        set_backend(previous)


def fused_map_admissible(d: int, backend: Backend) -> bool:
    """Whether the fused Gaussian feature map lowers on ``backend`` for
    point dimension ``d``. On split-reduce backends (Triton) the d axis
    must ride in ONE block — a sequential accumulation grid would race —
    so lane-padded ``d`` must fit ``fused_map_max_d``; refusals fall back
    to the XLA feature map (see ``kernels.ops.gaussian_feature_map``)."""
    if not backend.split_reduce or backend.fused_map_max_d <= 0:
        return True
    return round_up(d, backend.lane) <= backend.fused_map_max_d
