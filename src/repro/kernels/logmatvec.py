"""Pallas kernels: stabilized log-space factored Sinkhorn operators.

Three kernels cover the exact two-stage log-domain update (small-eps regime
where scalings under/overflow f32):

  * ``log_matvec_pallas``          — the original single-column row-LSE
        out_j = logsumexp_k( log_m[j, k] + t[k] )
    with EXACT per-row max stabilization (B = 1 keeps the joint max 2D).
  * ``log_feature_contract_pallas`` — stage 1 of the fused log iteration:
        t[k, c] = logsumexp_i( log_w[i, k] + s[i, c] )      (r, B)
    reduction over n via online ``logaddexp`` accumulation across n-blocks.
  * ``log_halfstep_pallas``         — stage 2 with the DIVIDE-FREE log
    half-step fused (the log-space twin of ``sinkhorn_halfstep_pallas``):
        out[j, c] = scale * ( lmarg[j, c] - logsumexp_k(log_w[j,k]+t[k,c]) )
    ``scale=eps`` yields the potential update  g = eps (log b - log K^T u);
    ``scale=-1, lmarg=0`` yields the raw LSE (convergence check).

Stabilization in the B-column kernels is EXACT: the B loop is unrolled at
trace time (B is static) and each column takes a 2-D ``log_w + s[:, c]``
broadcast with the true joint max — identical numerics to the XLA
``logsumexp`` two-stage path, which is what makes the fused log hot loop
elementwise-match the operator path even at small eps where log entries
span hundreds of nats. B is therefore expected SMALL (the solvers run at
B = 1 and batch via vmap, which adds a leading Pallas grid axis); a
separable max-shift matmul would scale to wide B but underflows ~87 nats
below its bound, which is exactly the regime the log domain exists for.

Row-local stabilization happens inside the tile, so nothing quadratic ever
leaves VMEM. r rides whole per tile (r <= 4096 in all configs) and is
lane-padded with ``-inf`` (the logsumexp identity) via ``kernels.tiling``
then sliced back.

Backends: the row kernels are one parallel grid axis — they lower on
Mosaic and Triton unchanged. The stage-1 contraction's online-logaddexp
accumulation across n-blocks is a sequential-grid idiom; parallel-grid
backends (``split_reduce=True``) run the split-k variant — each grid cell
writes its own per-block partial LSE and XLA combines them with one final
``logsumexp`` over the block axis (LSE is associative, so the combine is
exact up to f32 rounding order). Block sizes resolve through
``kernels.autotune`` outside the jit boundary.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import autotune
from .backend import Backend
from .tiling import LANE, compute_f32 as _f32, pad_axis

__all__ = [
    "log_matvec_pallas",
    "log_feature_contract_pallas",
    "log_halfstep_pallas",
]


def _finite_or_zero(m: jax.Array) -> jax.Array:
    """Pin all-(-inf) shift rows/cols to 0 so ``x - m`` never produces NaN."""
    return jnp.where(jnp.isfinite(m), m, 0.0)


def _log_matvec_kernel(logm_ref, t_ref, o_ref):
    s = _f32(logm_ref[...]) + t_ref[...]              # (bm, r)
    m = jnp.max(s, axis=1, keepdims=True)             # exact joint row max
    m = _finite_or_zero(m)
    o_ref[...] = m + jnp.log(
        jnp.sum(jnp.exp(s - m), axis=1, keepdims=True)
    )


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def _log_matvec_impl(
    log_m: jax.Array,       # (m, r)
    t: jax.Array,           # (r,)
    *,
    block_m: int,
    interpret: bool,
) -> jax.Array:
    m, r = log_m.shape
    lp = pad_axis(pad_axis(log_m, 0, block_m, value=-jnp.inf),
                  1, LANE, value=-jnp.inf)
    tp = pad_axis(t, 0, LANE)       # added to -inf columns: fill irrelevant
    rp = lp.shape[1]
    grid = (lp.shape[0] // block_m,)
    out = pl.pallas_call(
        _log_matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, rp), lambda i: (i, 0)),
            pl.BlockSpec((1, rp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((lp.shape[0], 1), jnp.float32),
        interpret=interpret,
    )(lp, tp[None, :])
    return out[:m, 0]


def log_matvec_pallas(
    log_m: jax.Array,       # (m, r)
    t: jax.Array,           # (r,)
    *,
    block_m: Optional[int] = None,
    interpret: bool = False,
    backend: Optional[Backend] = None,
) -> jax.Array:
    blocks = autotune.resolve_blocks(
        "log_rows", {"m": log_m.shape[0], "r": log_m.shape[1], "B": 1},
        {"block_m": block_m}, log_m.dtype, interpret, backend)
    return _log_matvec_impl(log_m, t, interpret=interpret, **blocks)


def _block_lse_cols(lw: jax.Array, s_ref, n_cols: int) -> jax.Array:
    """Per-column exact-joint-max LSE of one (bn, br) block: column c
    reduces ``lw + s[:, c]`` over axis 0. Returns (br, B)."""
    cols = []
    for c in range(n_cols):
        z = lw + s_ref[:, c][:, None]                  # (bn, br)
        m = _finite_or_zero(jnp.max(z, axis=0, keepdims=True))
        cols.append(
            (m + jnp.log(jnp.sum(jnp.exp(z - m), axis=0, keepdims=True)))[0]
        )                                              # (br,)
    return jnp.stack(cols, axis=1)                     # (br, B)


def _log_contract_kernel(lw_ref, s_ref, t_ref, *, n_cols: int):
    """t = logaddexp(t, LSE_i(lw_blk + s_blk)); n sequential grid axis.

    Per column c the (bn, br) broadcast ``lw + s[:, c]`` is reduced with
    its exact joint column max — B is unrolled at trace time."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        t_ref[...] = jnp.full_like(t_ref, -jnp.inf)

    contrib = _block_lse_cols(_f32(lw_ref[...]), s_ref, n_cols)
    t_ref[...] = jnp.logaddexp(t_ref[...], contrib)


def _log_contract_splitk_kernel(lw_ref, s_ref, t_ref, *, n_cols: int):
    """Split-k twin: cell (i, j) writes its own (1, br, B) partial LSE —
    no cross-program logaddexp, so the kernel lowers on parallel grids;
    the combine is one exact XLA ``logsumexp`` over the block axis."""
    t_ref[...] = _block_lse_cols(_f32(lw_ref[...]), s_ref, n_cols)[None]


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_r", "interpret")
)
def _log_contract_impl(
    log_w: jax.Array,       # (n, r) log-features
    s: jax.Array,           # (n, B) log-scalings (f / eps columns)
    *,
    block_n: int,
    block_r: int,
    interpret: bool,
) -> jax.Array:
    n, r = log_w.shape
    B = s.shape[1]
    lp = pad_axis(pad_axis(log_w, 0, block_n, value=-jnp.inf),
                  1, block_r, value=-jnp.inf)
    sp = pad_axis(s, 0, block_n, value=-jnp.inf)
    grid = (lp.shape[1] // block_r, lp.shape[0] // block_n)
    t = pl.pallas_call(
        functools.partial(_log_contract_kernel, n_cols=B),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_r), lambda i, j: (j, i)),
            pl.BlockSpec((block_n, B), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, B), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((lp.shape[1], B), jnp.float32),
        interpret=interpret,
    )(lp, sp)
    return t[:r]


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_r", "interpret")
)
def _log_contract_splitk_impl(
    log_w: jax.Array,
    s: jax.Array,
    *,
    block_n: int,
    block_r: int,
    interpret: bool,
) -> jax.Array:
    n, r = log_w.shape
    B = s.shape[1]
    lp = pad_axis(pad_axis(log_w, 0, block_n, value=-jnp.inf),
                  1, block_r, value=-jnp.inf)
    sp = pad_axis(s, 0, block_n, value=-jnp.inf)
    n_steps = lp.shape[0] // block_n
    grid = (lp.shape[1] // block_r, n_steps)
    partials = pl.pallas_call(
        functools.partial(_log_contract_splitk_kernel, n_cols=B),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_r), lambda i, j: (j, i)),
            pl.BlockSpec((block_n, B), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_r, B), lambda i, j: (j, i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_steps, lp.shape[1], B),
                                       jnp.float32),
        interpret=interpret,
    )(lp, sp)
    return jax.scipy.special.logsumexp(partials, axis=0)[:r]


def log_feature_contract_pallas(
    log_w: jax.Array,       # (n, r) log-features
    s: jax.Array,           # (n, B) log-scalings (f / eps columns)
    *,
    block_n: Optional[int] = None,
    block_r: Optional[int] = None,
    interpret: bool = False,
    split_reduce: bool = False,
    backend: Optional[Backend] = None,
) -> jax.Array:
    """t[k, c] = LSE_i(log_w[i, k] + s[i, c]), shape (r, B).

    The log-space twin of ``feature_contract_pallas``: -inf-padded rows
    are the LSE identity, so padding contributes nothing. B stays
    unpadded — the column loop is unrolled (B = 1 on the solver path).
    """
    n, r = log_w.shape
    blocks = autotune.resolve_blocks(
        "log_contract", {"n": n, "r": r, "B": s.shape[1]},
        {"block_n": block_n, "block_r": block_r}, log_w.dtype, interpret,
        backend)
    impl = _log_contract_splitk_impl if split_reduce else _log_contract_impl
    return impl(log_w, s, interpret=interpret, **blocks)


def _log_halfstep_kernel(lw_ref, t_ref, lmarg_ref, o_ref, *, scale: float,
                         n_cols: int):
    """o = scale * (lmarg - LSE_k(lw + t)) — LSE matvec + log half-step
    (subtract instead of divide) in one VMEM pass. Per column c the
    (bm, r) broadcast ``lw + t[:, c]`` takes its exact joint row max — B
    is unrolled at trace time."""
    lw = _f32(lw_ref[...])                             # (bm, r)
    cols = []
    for c in range(n_cols):
        z = lw + t_ref[:, c][None, :]                  # (bm, r)
        m = _finite_or_zero(jnp.max(z, axis=1, keepdims=True))
        lse = m + jnp.log(jnp.sum(jnp.exp(z - m), axis=1, keepdims=True))
        cols.append(lse[:, 0])                         # (bm,)
    lse_all = jnp.stack(cols, axis=1)                  # (bm, B)
    o_ref[...] = scale * (lmarg_ref[...] - lse_all)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_m", "interpret")
)
def _log_halfstep_impl(
    log_w: jax.Array,       # (m, r) log-features of the side being updated
    t: jax.Array,           # (r, B) stage-1 output
    lmarg: jax.Array,       # (m, B) log target marginal (0 for raw LSE)
    *,
    scale: float,
    block_m: int,
    interpret: bool,
) -> jax.Array:
    m, r = log_w.shape
    B = t.shape[1]
    lp = pad_axis(pad_axis(log_w, 0, block_m, value=-jnp.inf),
                  1, LANE, value=-jnp.inf)
    tp = pad_axis(t, 0, LANE, value=-jnp.inf)
    mp = pad_axis(lmarg, 0, block_m)
    rp = tp.shape[0]
    grid = (lp.shape[0] // block_m,)
    out = pl.pallas_call(
        functools.partial(_log_halfstep_kernel, scale=scale, n_cols=B),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, rp), lambda i: (i, 0)),
            pl.BlockSpec((rp, B), lambda i: (0, 0)),
            pl.BlockSpec((block_m, B), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, B), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((lp.shape[0], B), jnp.float32),
        interpret=interpret,
    )(lp, tp, mp)
    return out[:m]


def log_halfstep_pallas(
    log_w: jax.Array,       # (m, r) log-features of the side being updated
    t: jax.Array,           # (r, B) stage-1 output
    lmarg: jax.Array,       # (m, B) log target marginal (0 for raw LSE)
    *,
    scale: float = 1.0,
    block_m: Optional[int] = None,
    interpret: bool = False,
    backend: Optional[Backend] = None,
) -> jax.Array:
    """out = scale * (lmarg - LSE_k(log_w[:, k] + t[k, :])), shape (m, B).

    The B-column generalization of :func:`log_matvec_pallas` with the
    divide-free log half-step fused: ``scale=eps`` gives the potential
    update ``eps (log b - log K^T e^{f/eps})`` directly; ``scale=-1`` with
    ``lmarg=0`` recovers the raw LSE. r rides whole in VMEM; B stays
    unpadded (unrolled columns, B = 1 on the solver path).
    """
    blocks = autotune.resolve_blocks(
        "log_rows", {"m": log_w.shape[0], "r": log_w.shape[1],
                     "B": t.shape[1]},
        {"block_m": block_m}, log_w.dtype, interpret, backend)
    return _log_halfstep_impl(log_w, t, lmarg, scale=scale,
                              interpret=interpret, **blocks)


# ---------------------------------------------------------------------------
# Autotuner runners
# ---------------------------------------------------------------------------


def _log_contract_runner(extents, dtype, backend):
    lw = autotune._synthetic((extents["n"], extents["r"]), dtype, log=True)
    s = autotune._synthetic((extents["n"], extents["B"]), jnp.float32,
                            log=True)
    impl = _log_contract_splitk_impl if backend.split_reduce \
        else _log_contract_impl

    def run(blocks):
        jax.block_until_ready(
            impl(lw, s, interpret=backend.interpret, **blocks))

    return run


def _log_rows_runner(extents, dtype, backend):
    lw = autotune._synthetic((extents["m"], extents["r"]), dtype, log=True)
    t = autotune._synthetic((extents["r"], extents["B"]), jnp.float32,
                            log=True)
    lmarg = autotune._synthetic((extents["m"], extents["B"]), jnp.float32,
                                log=True)

    def run(blocks):
        jax.block_until_ready(
            _log_halfstep_impl(lw, t, lmarg, scale=1.0,
                               interpret=backend.interpret, **blocks))

    return run


autotune.register_runner("log_contract", _log_contract_runner)
autotune.register_runner("log_rows", _log_rows_runner)
