"""Pallas TPU kernel: stabilized log-space factored matvec.

    out_j = logsumexp_k( log_m[j, k] + t[k] )

This is the per-row half of the exact two-stage log-domain Sinkhorn update
(small-eps regime where scalings under/overflow f32). Row-local max
stabilization happens inside the tile, so nothing quadratic ever leaves
VMEM. r rides whole per tile (r <= 4096 in all configs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["log_matvec_pallas"]


def _log_matvec_kernel(logm_ref, t_ref, o_ref):
    s = logm_ref[...] + t_ref[...]                    # (bm, r)
    m = jnp.max(s, axis=1, keepdims=True)             # row max
    m = jnp.where(jnp.isfinite(m), m, 0.0)            # all -inf rows -> 0
    o_ref[...] = m + jnp.log(
        jnp.sum(jnp.exp(s - m), axis=1, keepdims=True)
    )


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def log_matvec_pallas(
    log_m: jax.Array,       # (m, r)
    t: jax.Array,           # (r,)
    *,
    block_m: int = 512,
    interpret: bool = False,
) -> jax.Array:
    m, r = log_m.shape
    pad = (-m) % block_m
    lp = jnp.pad(log_m, ((0, pad), (0, 0)), constant_values=-jnp.inf)
    grid = (lp.shape[0] // block_m,)
    out = pl.pallas_call(
        _log_matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, r), lambda i: (i, 0)),
            pl.BlockSpec((1, r), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((lp.shape[0], 1), jnp.float32),
        interpret=interpret,
    )(lp, t[None, :])
    return out[:m, 0]
