"""Pallas TPU kernels for the paper's compute hot-spots.

  feature_map  — fused Gaussian positive-feature map (Lemma 1)
  kermatvec    — factored-kernel contraction + fused Sinkhorn half-step
  logmatvec    — stabilized log-space matvec (small-eps path)

Each kernel ships with a pure-jnp oracle in ``ref.py``; tests sweep shapes
and dtypes in interpret mode. ``ops.py`` holds the jitted public wrappers.
"""
from .ops import (
    batched_sinkhorn_halfstep,
    default_interpret,
    feature_contract,
    fused_batched_sinkhorn_iteration,
    fused_sinkhorn_iteration,
    gaussian_feature_map,
    log_matvec,
    sinkhorn_halfstep,
)

__all__ = [
    "batched_sinkhorn_halfstep",
    "default_interpret",
    "feature_contract",
    "fused_batched_sinkhorn_iteration",
    "fused_sinkhorn_iteration",
    "gaussian_feature_map",
    "log_matvec",
    "sinkhorn_halfstep",
]
