"""Pallas TPU kernels for the paper's compute hot-spots.

  feature_map  — fused Gaussian positive-feature map (Lemma 1), linear or
                 log-space epilogue
  kermatvec    — factored-kernel contraction + fused Sinkhorn half-step
  logmatvec    — stabilized log-space LSE contraction + fused log half-step
                 (small-eps path)
  fused_loop   — persistent multi-iteration megakernel (scaling + log):
                 ``inner_steps`` full iterations per launch, factors
                 VMEM-resident, carries on-chip, error at block boundaries
  paged        — page-predicated matvecs over fixed-capacity streaming
                 feature stores (all-dead pages skipped via ``pl.when``)
  tiling       — shared lane-padding + block-size selection policy

Each kernel ships with a pure-jnp oracle in ``ref.py``; tests sweep shapes
and dtypes in interpret mode. ``ops.py`` holds the jitted public wrappers
plus ``geometry_ops`` — the fused execution plan the solvers route their
hot loop through (``use_pallas``). ``backend.py`` owns the three-way
execution policy (tpu-mosaic / gpu-triton / interpret) and ``autotune.py``
the measured block-shape tuner that fills every ``block_*=None``.
"""
from . import autotune
from .backend import (
    BACKEND_NAMES,
    Backend,
    backend_scope,
    fused_map_admissible,
    resolve_backend,
    set_backend,
)
from .fused_loop import (
    block_plan_fits,
    block_vmem_bytes,
    log_sinkhorn_block_pallas,
    sinkhorn_block_pallas,
)
from .paged import (
    paged_feature_contract_pallas,
    paged_feature_matvec_pallas,
    paged_halfstep_pallas,
    paged_supported,
)
from .ops import (
    PRECISIONS,
    GeometryOps,
    batched_sinkhorn_halfstep,
    check_precision,
    feature_contract,
    feature_matvec,
    fused_batched_sinkhorn_iteration,
    fused_log_sinkhorn_iteration,
    fused_sinkhorn_iteration,
    gaussian_feature_map,
    geometry_ops,
    log_feature_contract,
    log_halfstep,
    log_matvec,
    observe_plan_selection,
    sinkhorn_halfstep,
)

__all__ = [
    "BACKEND_NAMES",
    "Backend",
    "GeometryOps",
    "PRECISIONS",
    "autotune",
    "backend_scope",
    "batched_sinkhorn_halfstep",
    "fused_map_admissible",
    "resolve_backend",
    "set_backend",
    "block_plan_fits",
    "block_vmem_bytes",
    "check_precision",
    "log_sinkhorn_block_pallas",
    "sinkhorn_block_pallas",
    "paged_feature_contract_pallas",
    "paged_feature_matvec_pallas",
    "paged_halfstep_pallas",
    "paged_supported",
    "feature_contract",
    "feature_matvec",
    "fused_batched_sinkhorn_iteration",
    "fused_log_sinkhorn_iteration",
    "fused_sinkhorn_iteration",
    "gaussian_feature_map",
    "geometry_ops",
    "log_feature_contract",
    "log_halfstep",
    "log_matvec",
    "observe_plan_selection",
    "sinkhorn_halfstep",
]
