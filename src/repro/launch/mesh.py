"""Production mesh builders. Functions, not module constants — importing
this module never touches jax device state."""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """(2, 16, 16) pod x data x model multi-pod, or (16, 16) single-pod.

    Single-pod uses the first 256 devices so the same
    ``--xla_force_host_platform_device_count=512`` process serves both.
    """
    if multi_pod:
        shape = (2, 16, 16)
        axes = ("pod", "data", "model")
    else:
        shape = (16, 16)
        axes = ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devs)} — set XLA_FLAGS="
            "--xla_force_host_platform_device_count=512 before importing jax"
        )
    return Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def make_local_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Tiny mesh over available devices (smoke tests / examples)."""
    n = data * model
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(f"need {n} devices, have {len(devs)}")
    return Mesh(np.asarray(devs[:n]).reshape(data, model), ("data", "model"))
