"""OT-as-a-service driver: serve a synthetic open-loop trace and report.

    PYTHONPATH=src python -m repro.launch.ot_service --requests 200 \
        --rate 150 --max-batch 4 --max-wait-ms 4

Builds a heavy-tailed request trace (:mod:`repro.serving.traffic`),
pre-plans runners for every bucket cell the trace hits, then serves the
trace open-loop and prints throughput/latency percentiles plus the
serving-path cache counters. ``--no-warm-starts`` A/Bs the potential
re-serving; ``--strict`` exits nonzero if any runner traced or compiled
after warmup (the zero-recompile serving invariant).

``--stream`` switches to the STREAMING service instead: a pool of
mutable pairs (paged feature stores) receives a synthetic stream of
insert/evict mutations coalesced through the admission queue
(:class:`repro.serving.StreamingOTService`), one warm re-solve per pair
per flush. ``--strict`` then gates ZERO post-warmup runner retraces
across every mutation.

``--chaos`` runs the RESILIENCE lane: a seeded fault campaign
(:class:`repro.resilience.ChaosInjector`) mixes NaN/inf feature rows,
NaN weights, an adversarially small eps (Gaussian features underflow ->
the scaling path diverges; the log rung recovers), injected runner
exceptions, a poisoned warm cache and a skewed clock into the traffic,
with the recovery ladder + quarantine enabled. ``--strict`` then gates:
every request terminates in a finite result or a STRUCTURED refusal (no
NaN cost is ever returned), zero post-warmup compiles/retraces across
the main AND rung runner caches, and zero unhandled exceptions.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from ..serving import (
    OTService,
    TrafficSpec,
    make_traffic,
    run_open_loop,
    traffic_cells,
)


def run_stream(args) -> int:
    """Synthetic mutation traffic through the streaming service."""
    from ..serving import StreamingOTService
    from ..streaming import StreamingDistribution, StreamingSolver

    rng = np.random.default_rng(args.seed)
    r, n, eps = args.rank, args.stream_n, args.eps
    n_pairs = max(1, min(args.pool, 8))
    svc = StreamingOTService(
        solver=StreamingSolver(method="scaling", tol=args.tol,
                               use_pallas=False),
        max_batch=args.max_batch, max_wait=args.max_wait_ms * 1e-3,
    )

    def positive_feats(k):
        return (np.abs(rng.normal(size=(k, r))) + 0.05).astype(np.float32)

    t0 = time.monotonic()
    for p in range(n_pairs):
        dx = StreamingDistribution.from_features(
            [(p, "x", i) for i in range(n)], positive_feats(n),
            np.ones(n, np.float32), eps=eps)
        dy = StreamingDistribution.from_features(
            [(p, "y", i) for i in range(n)], positive_feats(n),
            np.ones(n, np.float32), eps=eps)
        svc.register(f"pair{p}", dx, dy)
        svc.solver.re_solve(svc.solver.pair(f"pair{p}"))
    traces0 = svc.solver.traces
    print(f"[ot-service] stream warmup: {n_pairs} pairs at n={n} r={r} "
          f"({svc.solver.stats()['runners']} runners, "
          f"{traces0} traces) in {time.monotonic() - t0:.1f}s")

    k = max(1, n // 50)                 # <= 2% of the support per update
    tickets = []
    # ids already scheduled for removal in a not-yet-flushed mutation:
    # coalesced batches apply every removal, so sampling must avoid them
    pending_rm = {p: set() for p in range(n_pairs)}
    t0 = time.monotonic()
    for j in range(args.requests):
        p = int(rng.integers(n_pairs))
        pair = svc.solver.pair(f"pair{p}")
        live = [i for i in pair.x.store.ids() if i not in pending_rm[p]]
        rm = [live[int(i)] for i in
              rng.choice(len(live), size=k, replace=False)]
        pending_rm[p].update(rm)
        tickets.append(svc.submit_update(
            f"pair{p}", remove_x=rm,
            add_x=dict(ids=[(p, "new", j, i) for i in range(k)],
                       feats=positive_feats(k),
                       weights=np.ones(k, np.float32))))
        svc.pump()
    svc.drain()
    dt = time.monotonic() - t0
    lat = sorted(t.latency for t in tickets)
    stats = svc.stats()
    retraces = svc.solver.traces - traces0
    print(f"[ot-service] streamed {len(tickets)} mutations over "
          f"{n_pairs} pairs in {dt:.2f}s ({len(tickets) / dt:.1f} "
          f"updates/s, delta_n={k}/{n} per update)")
    print(f"[ot-service] latency p50={lat[len(lat) // 2] * 1e3:.2f}ms "
          f"p99={lat[int(len(lat) * 0.99)] * 1e3:.2f}ms")
    print(f"[ot-service] coalescing: {stats['solves']} warm re-solves "
          f"for {stats['dispatched']} mutations "
          f"(ratio {stats['coalesce_ratio']:.2f}); "
          f"post-warmup retraces={retraces}")
    if args.strict and retraces:
        print("[ot-service] STRICT FAILURE: streaming runner retraced "
              "after warmup", file=sys.stderr)
        return 1
    return 0


def run_chaos(args) -> int:
    """Chaos-tested serving: seeded fault campaign through the recovery
    ladder, with the no-NaN / no-retrace / no-unhandled-exception gates."""
    from collections import Counter

    from ..core.api import OTProblem, solve
    from ..core.geometry import GaussianPointCloud
    from ..resilience import ChaosInjector, ChaosSpec, RecoveryPolicy
    from ..serving import QuarantineError, QueueFullError

    eps = args.chaos_eps
    r = args.rank
    rng = np.random.default_rng(args.seed)
    inj = ChaosInjector(ChaosSpec(
        seed=args.seed, nan_feature_frac=0.15, inf_feature_frac=0.10,
        nan_weight_frac=0.10, runner_fault_frac=0.08, clock_skew_s=0.005))

    # -- fault-assigned problem pool ----------------------------------------
    # healthy slots alternate between two classes: "gauss" (Gaussian
    # features at an adversarially small eps — exp(-d^2/eps) underflows,
    # the scaling path diverges, the LOG rung recovers a finite result)
    # and "benign" (explicit positive features — converges as-is)
    pool_n = args.pool
    size_classes = ((24, 20), (40, 32))
    kinds = inj.assign_faults(pool_n)
    problems, classes = [], []
    healthy_seen = 0
    for i, kind in enumerate(kinds):
        n, m = size_classes[i % len(size_classes)]
        xi = np.asarray(rng.uniform(0.05, 1.05, (n, r)), np.float32)
        zeta = np.asarray(rng.uniform(0.05, 1.05, (m, r)), np.float32)
        a = np.full(n, 1.0 / n, np.float32)
        b = np.full(m, 1.0 / m, np.float32)
        if kind == "":
            if healthy_seen % 2 == 0:
                x = np.asarray(rng.normal(size=(n, 2)), np.float32)
                y = np.asarray(rng.normal(size=(m, 2)), np.float32)
                anchors = np.asarray(rng.normal(size=(r, 2)), np.float32)
                geom = GaussianPointCloud.build(x, y, anchors, eps=eps)
                problems.append(OTProblem(geometry=geom, a=a, b=b))
                classes.append("gauss_small_eps")
            else:
                problems.append(OTProblem.from_features(xi, zeta, a, b,
                                                        eps=eps))
                classes.append("benign")
            healthy_seen += 1
        elif kind == "nan_weight":
            problems.append(OTProblem.from_features(
                xi, zeta, inj.corrupt_weights(a), b, eps=eps))
            classes.append(kind)
        else:
            problems.append(OTProblem.from_features(
                inj.corrupt_features(xi, kind), zeta, a, b, eps=eps))
            classes.append(kind)

    svc = OTService(
        eps=eps, method="factored", tol=args.tol, max_iter=300,
        max_batch=args.max_batch, max_wait=args.max_wait_ms * 1e-3,
        recovery=RecoveryPolicy(), quarantine_after=2,
        max_depth=16, chaos_hook=inj.fault_hook(),
        clock=inj.skewed(time.monotonic),
    )

    cells, seen = [], set()
    for p in problems:
        ka, kb = svc.engine.kernel_data(p)
        shape = svc.engine.batch_shape(ka, kb)
        if shape not in seen:
            seen.add(shape)
            cells.append(shape)
    t0 = time.monotonic()
    built_main = svc.warmup(cells)
    built_rungs = svc.warmup_recovery(cells)
    print(f"[ot-chaos] warmup: {built_main} main + {built_rungs} rung "
          f"runners over {len(cells)} cells in {time.monotonic() - t0:.1f}s")

    # fp32 log-domain ground truth for the healthy classes, under the
    # SAME iteration budget as the service: parity then measures whether
    # a recovered result IS the log-domain answer (not an iteration-count
    # artifact)
    ref_cost = {}
    for i, cls in enumerate(classes):
        if cls in ("gauss_small_eps", "benign"):
            res = solve(problems[i], method="log_factored", tol=args.tol,
                        max_iter=300)
            ref_cost[i] = float(res.cost)

    # -- drive: round-robin closed loop with fault handling -----------------
    outcomes = Counter()
    tickets = []
    unhandled = 0
    poisoned = False
    t0 = time.monotonic()
    for j in range(args.requests):
        i = j % pool_n
        if not poisoned and j == pool_n and ref_cost:
            # one full round served: corrupt a healthy pair's warm-cache
            # entry under its REAL fingerprint (bypassing put-validation)
            # — its next repeat must evict on get and cold-solve
            i0 = next(iter(ref_cost))
            ka, kb = svc.engine.kernel_data(problems[i0])
            sk, fk = svc.warm.keys_for(
                np.asarray(ka, np.float32), np.asarray(kb, np.float32),
                np.asarray(problems[i0].a, np.float32),
                np.asarray(problems[i0].b, np.float32))
            inj.poison_warm_cache(svc.warm, sk, fk,
                                  problems[i0].a.shape[0],
                                  problems[i0].b.shape[0])
            poisoned = True
        try:
            tickets.append((i, svc.submit(problems[i])))
        except QuarantineError:
            outcomes["quarantined_submit"] += 1
            continue
        except QueueFullError:
            outcomes["shed_submit"] += 1
            continue
        except Exception:
            unhandled += 1
            continue
        try:
            svc.pump()
        except Exception:
            unhandled += 1
    try:
        svc.drain()
    except Exception:
        unhandled += 1
    dt = time.monotonic() - t0

    # -- shed burst: overflow the bounded queue without pumping -------------
    benign = [i for i, c in enumerate(classes) if c == "benign"]
    if benign:
        for _ in range(20):
            try:
                tickets.append((benign[0], svc.submit(problems[benign[0]])))
            except QueueFullError:
                outcomes["shed_submit"] += 1
            except QuarantineError:
                outcomes["quarantined_submit"] += 1
        try:
            svc.drain()
        except Exception:
            unhandled += 1

    # -- verdicts, parity, gates --------------------------------------------
    nonterminal = sum(not t.done for _, t in tickets)
    nan_served = 0
    parity = 0.0
    per_class = {}
    for i, t in tickets:
        cls = classes[i]
        hist = per_class.setdefault(cls, Counter())
        if t.refusal is not None:
            hist["refused:" + t.refusal.reason] += 1
        elif t.result is not None:
            v = t.health.verdict if t.health is not None else "?"
            hist[("recovered:" + "+".join(t.rungs)) if t.rungs else v] += 1
            c = float(t.result.cost)
            if not np.isfinite(c):
                nan_served += 1
            elif i in ref_cost:
                parity = max(parity,
                             abs(c - ref_cost[i]) / max(1.0, abs(ref_cost[i])))
    stats = svc.stats()
    rec, runner, warm = stats["recovery"], stats["runner"], stats["warm"]
    post_main = runner["misses"] - built_main
    post_rung = rec["rung_compiles"] - built_rungs

    print(f"[ot-chaos] drove {len(tickets)} admitted requests over "
          f"{pool_n} pool entries in {dt:.2f}s; injected: {inj.stats()}")
    print(f"[ot-chaos] fault mix -> outcomes:")
    for cls in sorted(per_class):
        print(f"[ot-chaos]   {cls:16s} {dict(per_class[cls])}")
    print(f"[ot-chaos] submit refusals: {dict(outcomes)}")
    print(f"[ot-chaos] recovery: attempts={rec['attempts']} "
          f"recovered={rec['recovered']} refused={rec['refused']} "
          f"runner_faults={rec['runner_faults']} "
          f"rung_hist={rec['rung_hist']} "
          f"quarantined={rec['quarantined']} shed={stats['shed']}")
    print(f"[ot-chaos] warm cache: poisoned_rejects="
          f"{warm['poisoned_rejects']} poisoned_evictions="
          f"{warm['poisoned_evictions']}")
    print(f"[ot-chaos] parity: recovered/served healthy results within "
          f"{parity:.2e} (rel) of fp32 log-domain ground truth")
    print(f"[ot-chaos] compiles after warmup: main={post_main} "
          f"rung={post_rung} extra_traces="
          f"{runner['extra_traces'] + rec['rung_extra_traces']}; "
          f"unhandled exceptions={unhandled}; "
          f"non-terminal tickets={nonterminal}; "
          f"NaN results served={nan_served}")

    failures = []
    if nonterminal:
        failures.append(f"{nonterminal} tickets not terminal")
    if nan_served:
        failures.append(f"{nan_served} NaN-cost results served")
    if unhandled:
        failures.append(f"{unhandled} unhandled exceptions")
    if rec["recovered"] == 0:
        failures.append("recovery ladder never rescued a request")
    if rec["refused"] == 0:
        failures.append("no structured refusals (faults not exercised)")
    if warm["poisoned_evictions"] == 0:
        failures.append("poisoned warm entry was not evicted on get")
    if stats["shed"] == 0:
        failures.append("queue depth bound never shed")
    if post_main or post_rung or runner["extra_traces"] \
            or rec["rung_extra_traces"]:
        failures.append(
            f"post-warmup compiles/retraces (main={post_main} "
            f"rung={post_rung})")
    if parity > 1e-3:
        failures.append(f"parity {parity:.2e} vs ground truth")
    if args.strict and failures:
        print("[ot-chaos] STRICT FAILURE: " + "; ".join(failures),
              file=sys.stderr)
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--rate", type=float, default=150.0,
                    help="open-loop arrival rate (requests/second)")
    ap.add_argument("--eps", type=float, default=0.5)
    ap.add_argument("--rank", type=int, default=16)
    ap.add_argument("--pool", type=int, default=32,
                    help="distinct distribution pairs in the traffic pool")
    ap.add_argument("--repeat-frac", type=float, default=0.6)
    ap.add_argument("--near-frac", type=float, default=0.3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--method", default="log_factored")
    ap.add_argument("--tol", type=float, default=1e-6)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-wait-ms", type=float, default=4.0)
    ap.add_argument("--no-warm-starts", action="store_true")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any post-warmup trace/compile")
    ap.add_argument("--stream", action="store_true",
                    help="serve synthetic MUTATION traffic through the "
                         "streaming service (paged stores + incremental "
                         "re-solve) instead of the request-trace service")
    ap.add_argument("--stream-n", type=int, default=400,
                    help="--stream: live support size per distribution")
    ap.add_argument("--chaos", action="store_true",
                    help="run the resilience lane: seeded fault injection "
                         "through the recovery ladder (see module doc)")
    ap.add_argument("--chaos-eps", type=float, default=1e-4,
                    help="--chaos: the adversarially small eps the "
                         "Gaussian-feature class underflows at")
    args = ap.parse_args(argv)

    if args.chaos:
        return run_chaos(args)
    if args.stream:
        return run_stream(args)

    spec = TrafficSpec(
        n_requests=args.requests, rate_hz=args.rate, eps=args.eps,
        r=args.rank, pool_size=args.pool, repeat_frac=args.repeat_frac,
        near_frac=args.near_frac, seed=args.seed,
    )
    traffic = make_traffic(spec)
    svc = OTService(
        eps=spec.eps, method=args.method, tol=args.tol,
        max_batch=args.max_batch, max_wait=args.max_wait_ms * 1e-3,
        warm_starts=not args.no_warm_starts,
    )
    cells = traffic_cells(traffic, svc.engine)
    t0 = time.monotonic()
    built = svc.warmup(cells)
    print(f"[ot-service] warmup: {built} runners over {len(cells)} bucket "
          f"cells in {time.monotonic() - t0:.1f}s")

    report = run_open_loop(svc, traffic)
    stats = svc.stats()
    runner, warm = stats["runner"], stats["warm"]
    print(f"[ot-service] served {report.completed}/{len(traffic)} requests "
          f"in {report.duration_s:.2f}s ({report.rps:.1f} req/s)")
    print(f"[ot-service] latency p50={report.p50_ms:.2f}ms "
          f"p99={report.p99_ms:.2f}ms "
          f"(from scheduled arrival, queueing included)")
    print(f"[ot-service] batches={stats['batches']} "
          f"mean_batch={stats['mean_batch']:.2f}")
    print(f"[ot-service] warm-start: hit_rate={warm['hit_rate']:.3f} "
          f"(exact={warm['exact_hits']} near={warm['near_hits']} "
          f"miss={warm['misses']}); mean iters "
          f"warm={stats['mean_iters_warm']:.2f} "
          f"cold={stats['mean_iters_cold']:.2f}")
    post_warmup_compiles = runner["misses"] - built
    print(f"[ot-service] runners: size={runner['size']} "
          f"steady-state hits={runner['hits']} "
          f"post-warmup compiles={post_warmup_compiles} "
          f"extra_traces={runner['extra_traces']}")
    if args.strict and (post_warmup_compiles or runner["extra_traces"]):
        print("[ot-service] STRICT FAILURE: serving path traced/compiled "
              "after warmup", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
