"""OT-as-a-service driver: serve a synthetic open-loop trace and report.

    PYTHONPATH=src python -m repro.launch.ot_service --requests 200 \
        --rate 150 --max-batch 4 --max-wait-ms 4

Builds a heavy-tailed request trace (:mod:`repro.serving.traffic`),
pre-plans runners for every bucket cell the trace hits, then serves the
trace open-loop and prints throughput/latency percentiles plus the
serving-path cache counters. ``--no-warm-starts`` A/Bs the potential
re-serving; ``--strict`` exits nonzero if any runner traced or compiled
after warmup (the zero-recompile serving invariant).

``--stream`` switches to the STREAMING service instead: a pool of
mutable pairs (paged feature stores) receives a synthetic stream of
insert/evict mutations coalesced through the admission queue
(:class:`repro.serving.StreamingOTService`), one warm re-solve per pair
per flush. ``--strict`` then gates ZERO post-warmup runner retraces
across every mutation.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from ..serving import (
    OTService,
    TrafficSpec,
    make_traffic,
    run_open_loop,
    traffic_cells,
)


def run_stream(args) -> int:
    """Synthetic mutation traffic through the streaming service."""
    from ..serving import StreamingOTService
    from ..streaming import StreamingDistribution, StreamingSolver

    rng = np.random.default_rng(args.seed)
    r, n, eps = args.rank, args.stream_n, args.eps
    n_pairs = max(1, min(args.pool, 8))
    svc = StreamingOTService(
        solver=StreamingSolver(method="scaling", tol=args.tol,
                               use_pallas=False),
        max_batch=args.max_batch, max_wait=args.max_wait_ms * 1e-3,
    )

    def positive_feats(k):
        return (np.abs(rng.normal(size=(k, r))) + 0.05).astype(np.float32)

    t0 = time.monotonic()
    for p in range(n_pairs):
        dx = StreamingDistribution.from_features(
            [(p, "x", i) for i in range(n)], positive_feats(n),
            np.ones(n, np.float32), eps=eps)
        dy = StreamingDistribution.from_features(
            [(p, "y", i) for i in range(n)], positive_feats(n),
            np.ones(n, np.float32), eps=eps)
        svc.register(f"pair{p}", dx, dy)
        svc.solver.re_solve(svc.solver.pair(f"pair{p}"))
    traces0 = svc.solver.traces
    print(f"[ot-service] stream warmup: {n_pairs} pairs at n={n} r={r} "
          f"({svc.solver.stats()['runners']} runners, "
          f"{traces0} traces) in {time.monotonic() - t0:.1f}s")

    k = max(1, n // 50)                 # <= 2% of the support per update
    tickets = []
    # ids already scheduled for removal in a not-yet-flushed mutation:
    # coalesced batches apply every removal, so sampling must avoid them
    pending_rm = {p: set() for p in range(n_pairs)}
    t0 = time.monotonic()
    for j in range(args.requests):
        p = int(rng.integers(n_pairs))
        pair = svc.solver.pair(f"pair{p}")
        live = [i for i in pair.x.store.ids() if i not in pending_rm[p]]
        rm = [live[int(i)] for i in
              rng.choice(len(live), size=k, replace=False)]
        pending_rm[p].update(rm)
        tickets.append(svc.submit_update(
            f"pair{p}", remove_x=rm,
            add_x=dict(ids=[(p, "new", j, i) for i in range(k)],
                       feats=positive_feats(k),
                       weights=np.ones(k, np.float32))))
        svc.pump()
    svc.drain()
    dt = time.monotonic() - t0
    lat = sorted(t.latency for t in tickets)
    stats = svc.stats()
    retraces = svc.solver.traces - traces0
    print(f"[ot-service] streamed {len(tickets)} mutations over "
          f"{n_pairs} pairs in {dt:.2f}s ({len(tickets) / dt:.1f} "
          f"updates/s, delta_n={k}/{n} per update)")
    print(f"[ot-service] latency p50={lat[len(lat) // 2] * 1e3:.2f}ms "
          f"p99={lat[int(len(lat) * 0.99)] * 1e3:.2f}ms")
    print(f"[ot-service] coalescing: {stats['solves']} warm re-solves "
          f"for {stats['dispatched']} mutations "
          f"(ratio {stats['coalesce_ratio']:.2f}); "
          f"post-warmup retraces={retraces}")
    if args.strict and retraces:
        print("[ot-service] STRICT FAILURE: streaming runner retraced "
              "after warmup", file=sys.stderr)
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--rate", type=float, default=150.0,
                    help="open-loop arrival rate (requests/second)")
    ap.add_argument("--eps", type=float, default=0.5)
    ap.add_argument("--rank", type=int, default=16)
    ap.add_argument("--pool", type=int, default=32,
                    help="distinct distribution pairs in the traffic pool")
    ap.add_argument("--repeat-frac", type=float, default=0.6)
    ap.add_argument("--near-frac", type=float, default=0.3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--method", default="log_factored")
    ap.add_argument("--tol", type=float, default=1e-6)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-wait-ms", type=float, default=4.0)
    ap.add_argument("--no-warm-starts", action="store_true")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any post-warmup trace/compile")
    ap.add_argument("--stream", action="store_true",
                    help="serve synthetic MUTATION traffic through the "
                         "streaming service (paged stores + incremental "
                         "re-solve) instead of the request-trace service")
    ap.add_argument("--stream-n", type=int, default=400,
                    help="--stream: live support size per distribution")
    args = ap.parse_args(argv)

    if args.stream:
        return run_stream(args)

    spec = TrafficSpec(
        n_requests=args.requests, rate_hz=args.rate, eps=args.eps,
        r=args.rank, pool_size=args.pool, repeat_frac=args.repeat_frac,
        near_frac=args.near_frac, seed=args.seed,
    )
    traffic = make_traffic(spec)
    svc = OTService(
        eps=spec.eps, method=args.method, tol=args.tol,
        max_batch=args.max_batch, max_wait=args.max_wait_ms * 1e-3,
        warm_starts=not args.no_warm_starts,
    )
    cells = traffic_cells(traffic, svc.engine)
    t0 = time.monotonic()
    built = svc.warmup(cells)
    print(f"[ot-service] warmup: {built} runners over {len(cells)} bucket "
          f"cells in {time.monotonic() - t0:.1f}s")

    report = run_open_loop(svc, traffic)
    stats = svc.stats()
    runner, warm = stats["runner"], stats["warm"]
    print(f"[ot-service] served {report.completed}/{len(traffic)} requests "
          f"in {report.duration_s:.2f}s ({report.rps:.1f} req/s)")
    print(f"[ot-service] latency p50={report.p50_ms:.2f}ms "
          f"p99={report.p99_ms:.2f}ms "
          f"(from scheduled arrival, queueing included)")
    print(f"[ot-service] batches={stats['batches']} "
          f"mean_batch={stats['mean_batch']:.2f}")
    print(f"[ot-service] warm-start: hit_rate={warm['hit_rate']:.3f} "
          f"(exact={warm['exact_hits']} near={warm['near_hits']} "
          f"miss={warm['misses']}); mean iters "
          f"warm={stats['mean_iters_warm']:.2f} "
          f"cold={stats['mean_iters_cold']:.2f}")
    post_warmup_compiles = runner["misses"] - built
    print(f"[ot-service] runners: size={runner['size']} "
          f"steady-state hits={runner['hits']} "
          f"post-warmup compiles={post_warmup_compiles} "
          f"extra_traces={runner['extra_traces']}")
    if args.strict and (post_warmup_compiles or runner["extra_traces"]):
        print("[ot-service] STRICT FAILURE: serving path traced/compiled "
              "after warmup", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
