"""Parse lowered/compiled HLO text for collective traffic + roofline terms.

cost_analysis() gives FLOPs and HBM bytes; collective bytes are NOT in it,
so we regex the (SPMD-partitioned, per-device) HLO module: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
result shape is converted to wire bytes with ring-algorithm factors.

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

__all__ = [
    "HW",
    "CollectiveStats",
    "parse_collectives",
    "roofline_terms",
]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_ARR_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12        # bf16 / chip
    hbm_bw: float = 819e9             # B/s / chip
    ici_bw: float = 50e9              # B/s / link


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, float]   # result-shape bytes (per device)
    wire_bytes: float                 # ring-model bytes on the wire / device


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_ARR_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].strip("{} ")
        if first:
            return len(first.split(","))
    return default


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    counts: Dict[str, int] = {}
    bytes_by_kind: Dict[str, float] = {}
    wire = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue                      # count async pairs once (at -start)
        shape_str, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(shape_str)
        g = _group_size(line, n_devices)
        frac = (g - 1) / max(g, 1)
        if kind == "all-gather":
            w = nbytes * frac             # result is the gathered buffer
        elif kind == "reduce-scatter":
            w = nbytes * (g - 1)          # result is the scattered shard
        elif kind == "all-reduce":
            w = 2.0 * nbytes * frac       # ring RS+AG
        elif kind == "all-to-all":
            w = nbytes * frac
        else:                             # collective-permute
            w = nbytes
        counts[kind] = counts.get(kind, 0) + 1
        bytes_by_kind[kind] = bytes_by_kind.get(kind, 0.0) + nbytes
        wire += w
    return CollectiveStats(counts, bytes_by_kind, wire)


def roofline_terms(
    flops_per_device: float,
    hbm_bytes_per_device: float,
    wire_bytes_per_device: float,
    hw: HW = HW(),
) -> Dict[str, float]:
    """The three roofline terms, in seconds (per step, per device)."""
    compute_s = flops_per_device / hw.peak_flops
    memory_s = hbm_bytes_per_device / hw.hbm_bw
    collective_s = wire_bytes_per_device / hw.ici_bw
    dominant = max(
        ("compute", compute_s), ("memory", memory_s),
        ("collective", collective_s), key=lambda kv: kv[1],
    )[0]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
    }
